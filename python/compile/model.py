"""L2: JAX model fwd/bwd with Schrödinger's FP fake-quantization.

A ResNet-style CNN (the paper evaluates ResNet18/ImageNet; per DESIGN.md
§2 we train a shape-reduced residual CNN end-to-end through the real
three-layer stack and drive the ImageNet-scale tables from layer traces).

Every stashed tensor — each conv/fc weight and each post-activation — is
wrapped in :func:`kernels.qmantissa.fake_quant`, the straight-through
stochastic mantissa truncation whose bitlengths are themselves inputs to
the compiled step.  The Rust coordinator owns the adaptation policy:

* Quantum Mantissa: pass ``lr_n > 0``, ``stochastic=1``; the per-tensor
  bitlengths descend under the Eq. 7 footprint-weighted penalty.
* BitChop: pass ``lr_n = 0`` and set all activation bitlengths to the
  controller's network-wide ``n`` (weights to the container max).
* Baselines: all bitlengths = container max (23 for FP32, 7 for BF16).

The exported entry points take and return *flat positional* tensors; the
exact order is recorded in ``artifacts/manifest.json`` by ``aot.py``.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .kernels.gecko_stats import gecko_exponent_bits
from .kernels.qmantissa import fake_quant, stochastic_nbits

# ----------------------------------------------------------------------------
# Architecture: input 16x16x3, 10 classes.
#   c0  : conv3x3  3->16                 (a0: 16x16x16)
#   b1c1: conv3x3 16->16                 (a1)
#   b1c2: conv3x3 16->16 + skip(a0)      (a2)
#   d1  : conv3x3 s2 16->32              (a3: 8x8x32)
#   b2c1: conv3x3 32->32                 (a4)
#   b2c2: conv3x3 32->32 + skip(a3)      (a5)
#   gap + fc 32->10                      (a6: pooled features, stashed)
# ----------------------------------------------------------------------------

IMAGE = (16, 16, 3)
NUM_CLASSES = 10
BATCH = 64

LAYERS = ["c0", "b1c1", "b1c2", "d1", "b2c1", "b2c2", "fc"]
NUM_Q = len(LAYERS)  # quantized weight tensors == quantized activations

WEIGHT_SHAPES = [
    (3, 3, 3, 16),
    (3, 3, 16, 16),
    (3, 3, 16, 16),
    (3, 3, 16, 32),
    (3, 3, 32, 32),
    (3, 3, 32, 32),
    (32, NUM_CLASSES),
]
BIAS_SHAPES = [(16,), (16,), (16,), (32,), (32,), (32,), (NUM_CLASSES,)]

ACT_SHAPES = [
    (BATCH, 16, 16, 16),
    (BATCH, 16, 16, 16),
    (BATCH, 16, 16, 16),
    (BATCH, 8, 8, 32),
    (BATCH, 8, 8, 32),
    (BATCH, 8, 8, 32),
    (BATCH, 32),
]


def _prod(s):
    out = 1
    for d in s:
        out *= d
    return out


# Eq. 7 footprint weights λ_i: each tensor's share of the total stashed
# footprint (elements, since every element carries the same container).
_W_ELEMS = [_prod(s) for s in WEIGHT_SHAPES]
_A_ELEMS = [_prod(s) for s in ACT_SHAPES]
_TOTAL = float(sum(_W_ELEMS) + sum(_A_ELEMS))
LAMBDA_W = [e / _TOTAL for e in _W_ELEMS]
LAMBDA_A = [e / _TOTAL for e in _A_ELEMS]


class StepHyper(NamedTuple):
    lr: jax.Array  # SGD learning rate
    momentum: jax.Array  # SGD momentum
    lr_n: jax.Array  # bitlength learning rate (0 disables QM)
    gamma: jax.Array  # Eq. 7 regularizer strength
    mmax: jax.Array  # container mantissa bits as f32 (23. or 7.)
    stochastic: jax.Array  # i32: 1 = stochastic fractional bitlengths
    step: jax.Array  # i32: PRNG folding counter


def _conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def _quantize(x, n, u, hyper: StepHyper):
    """fake_quant with the stochastic switch: deterministic = u pinned to 0
    (so floor(n) is used) — matches the paper's round-up deployment when the
    coordinator passes already-rounded integer bitlengths."""
    u_eff = jnp.where(hyper.stochastic == 1, u, jnp.float32(0.0))
    return fake_quant(x, n, u_eff, hyper.mmax)


def forward(params, n_w, n_a, x, hyper: StepHyper):
    """Forward pass; returns (logits, activations list post-quant)."""
    ws = params["w"]
    bs = params["b"]
    key = jax.random.fold_in(jax.random.PRNGKey(0x5FB0), hyper.step)
    us = jax.random.uniform(key, (2 * NUM_Q,))

    def qw(i):
        return _quantize(ws[i], n_w[i], us[i], hyper)

    def qa(i, a):
        return _quantize(a, n_a[i], us[NUM_Q + i], hyper)

    acts = []
    a = qa(0, jax.nn.relu(_conv(x, qw(0)) + bs[0]))
    acts.append(a)
    h = qa(1, jax.nn.relu(_conv(a, qw(1)) + bs[1]))
    acts.append(h)
    a = qa(2, jax.nn.relu(_conv(h, qw(2)) + bs[2] + a))
    acts.append(a)
    a = qa(3, jax.nn.relu(_conv(a, qw(3), stride=2) + bs[3]))
    acts.append(a)
    h = qa(4, jax.nn.relu(_conv(a, qw(4)) + bs[4]))
    acts.append(h)
    a = qa(5, jax.nn.relu(_conv(h, qw(5)) + bs[5] + a))
    acts.append(a)
    pooled = qa(6, jnp.mean(a, axis=(1, 2)))
    acts.append(pooled)
    logits = pooled @ qw(6) + bs[6]
    return logits, acts


def task_loss(params, n_w, n_a, x, y, hyper: StepHyper):
    logits, acts = forward(params, n_w, n_a, x, hyper)
    logp = jax.nn.log_softmax(logits)
    ce = -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))
    return ce, acts


def total_loss(params, n_w, n_a, x, y, hyper: StepHyper):
    """Eq. 7: L = L_task + γ Σ λ_i n_i (footprint-weighted bit penalty)."""
    ce, acts = task_loss(params, n_w, n_a, x, y, hyper)
    lam_w = jnp.asarray(LAMBDA_W, jnp.float32)
    lam_a = jnp.asarray(LAMBDA_A, jnp.float32)
    penalty = jnp.sum(lam_w * jnp.clip(n_w, 0.0, hyper.mmax)) + jnp.sum(
        lam_a * jnp.clip(n_a, 0.0, hyper.mmax)
    )
    return ce + hyper.gamma * penalty, (ce, acts)


# ----------------------------------------------------------------------------
# Entry points (flat positional signatures; see aot.py for the manifest).
# ----------------------------------------------------------------------------


def _unflatten_params(flat):
    ws = list(flat[: len(WEIGHT_SHAPES)])
    bs = list(flat[len(WEIGHT_SHAPES) : 2 * len(WEIGHT_SHAPES)])
    return {"w": ws, "b": bs}


def _stats(acts, params):
    """Per-layer footprint statistics the coordinator aggregates.

    Returns (act_gecko_bits, w_gecko_bits, act_zero_frac) — the Gecko
    encoded exponent size for every stashed tensor plus each activation's
    zero fraction (feeds the JS / GIST++ baselines of Fig. 13)."""
    a_bits = jnp.stack([gecko_exponent_bits(a) for a in acts]).astype(jnp.float32)
    w_bits = jnp.stack([gecko_exponent_bits(w) for w in params["w"]]).astype(
        jnp.float32
    )
    zfrac = jnp.stack([jnp.mean((a == 0).astype(jnp.float32)) for a in acts])
    return a_bits, w_bits, zfrac


def train_step(*args):
    """One SGD+momentum step with fake-quantized stash tensors.

    Flat inputs (order fixed, mirrored in the manifest):
      w[7], b[7], mw[7], mb[7]      params + momentum buffers
      n_w (7,), n_a (7,)            learnable bitlengths
      x (B,16,16,3) f32, y (B,) i32
      lr, momentum, lr_n, gamma, mmax   f32 scalars
      stochastic, step                  i32 scalars
    Flat outputs:
      w'[7], b'[7], mw'[7], mb'[7], n_w', n_a',
      task_loss, total_loss,
      n_used_w (7,) i32, n_used_a (7,) i32,
      act_gecko_bits (7,), w_gecko_bits (7,), act_zero_frac (7,)
    """
    nw = len(WEIGHT_SHAPES)
    params = _unflatten_params(args[: 2 * nw])
    mom = _unflatten_params(args[2 * nw : 4 * nw])
    n_w, n_a, x, y = args[4 * nw : 4 * nw + 4]
    lr, momentum, lr_n, gamma, mmax, stochastic, step = args[4 * nw + 4 :]
    hyper = StepHyper(lr, momentum, lr_n, gamma, mmax, stochastic, step)

    grad_fn = jax.value_and_grad(total_loss, argnums=(0, 1, 2), has_aux=True)
    (tot, (ce, acts)), (g_p, g_nw, g_na) = grad_fn(params, n_w, n_a, x, y, hyper)

    def upd(p, m, g):
        m2 = momentum * m + g
        return p - lr * m2, m2

    new_w, new_mw = zip(
        *[upd(p, m, g) for p, m, g in zip(params["w"], mom["w"], g_p["w"])]
    )
    new_b, new_mb = zip(
        *[upd(p, m, g) for p, m, g in zip(params["b"], mom["b"], g_p["b"])]
    )

    n_w2 = jnp.clip(n_w - lr_n * g_nw, 0.0, mmax)
    n_a2 = jnp.clip(n_a - lr_n * g_na, 0.0, mmax)

    # Bitlengths actually used this step (for exact footprint accounting).
    key = jax.random.fold_in(jax.random.PRNGKey(0x5FB0), step)
    us = jax.random.uniform(key, (2 * NUM_Q,))
    u_eff = jnp.where(stochastic == 1, us, jnp.zeros_like(us))
    n_used_w = stochastic_nbits(n_w, u_eff[:NUM_Q], mmax)
    n_used_a = stochastic_nbits(n_a, u_eff[NUM_Q:], mmax)

    a_bits, w_bits, zfrac = _stats(acts, params)

    return (
        *new_w,
        *new_b,
        *new_mw,
        *new_mb,
        n_w2,
        n_a2,
        ce,
        tot,
        n_used_w,
        n_used_a,
        a_bits,
        w_bits,
        zfrac,
    )


def eval_step(*args):
    """Validation: deployment-style deterministic quantization (bitlengths
    rounded up, §IV-A-4).  Inputs: w[7], b[7], n_w, n_a, mmax, x, y.
    Outputs: (correct_count i32, mean_ce f32)."""
    nw = len(WEIGHT_SHAPES)
    params = _unflatten_params(args[: 2 * nw])
    n_w, n_a, mmax, x, y = args[2 * nw :]
    hyper = StepHyper(
        jnp.float32(0),
        jnp.float32(0),
        jnp.float32(0),
        jnp.float32(0),
        mmax,
        jnp.int32(0),
        jnp.int32(0),
    )
    logits, _ = forward(params, jnp.ceil(n_w), jnp.ceil(n_a), x, hyper)
    correct = jnp.sum((jnp.argmax(logits, axis=1) == y).astype(jnp.int32))
    logp = jax.nn.log_softmax(logits)
    ce = -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))
    return correct, ce


def forward_acts(*args):
    """Dump the post-quantization stashed activations for one batch — the
    Rust side feeds these through the Gecko/SFP codecs (Figs. 9/10/12/13)
    and the codec criterion benches.  Inputs: w[7], b[7], n_w, n_a, mmax,
    stochastic, step, x.  Outputs: a0..a6."""
    nw = len(WEIGHT_SHAPES)
    params = _unflatten_params(args[: 2 * nw])
    n_w, n_a, mmax, stochastic, step, x = args[2 * nw :]
    hyper = StepHyper(
        jnp.float32(0),
        jnp.float32(0),
        jnp.float32(0),
        jnp.float32(0),
        mmax,
        stochastic,
        step,
    )
    _, acts = forward(params, n_w, n_a, x, hyper)
    return tuple(acts)
