"""AOT: lower every L2 entry point to HLO *text* + write the manifest.

HLO text (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids that xla_extension 0.5.1
(the version behind the published ``xla`` crate) rejects; the text parser
reassigns ids and round-trips cleanly.  Lowered with ``return_tuple=True``
so the Rust side always unwraps a tuple.

``artifacts/manifest.json`` records, for every artifact, the ordered input
and output tensor specs (name/shape/dtype) so the Rust runtime can marshal
literals without guessing.  Python runs only at build time; ``make
artifacts`` is a no-op when inputs are unchanged.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(name, shape, dtype="f32"):
    return {"name": name, "shape": list(shape), "dtype": dtype}


def _param_specs(prefix=""):
    out = []
    for nm, s in zip(model.LAYERS, model.WEIGHT_SHAPES):
        out.append(spec(f"{prefix}w_{nm}", s))
    for nm, s in zip(model.LAYERS, model.BIAS_SHAPES):
        out.append(spec(f"{prefix}b_{nm}", s))
    return out


def entry_specs():
    """(inputs, outputs) per entry point; order == positional order."""
    L = model.NUM_Q
    scal_f = lambda n: spec(n, ())
    scal_i = lambda n: spec(n, (), "i32")

    train_in = (
        _param_specs()
        + _param_specs("m_")
        + [spec("n_w", (L,)), spec("n_a", (L,))]
        + [
            spec("x", (model.BATCH, *model.IMAGE)),
            spec("y", (model.BATCH,), "i32"),
            scal_f("lr"),
            scal_f("momentum"),
            scal_f("lr_n"),
            scal_f("gamma"),
            scal_f("mmax"),
            scal_i("stochastic"),
            scal_i("step"),
        ]
    )
    train_out = (
        [spec(f"w_{nm}'", s) for nm, s in zip(model.LAYERS, model.WEIGHT_SHAPES)]
        + [spec(f"b_{nm}'", s) for nm, s in zip(model.LAYERS, model.BIAS_SHAPES)]
        + [spec(f"mw_{nm}'", s) for nm, s in zip(model.LAYERS, model.WEIGHT_SHAPES)]
        + [spec(f"mb_{nm}'", s) for nm, s in zip(model.LAYERS, model.BIAS_SHAPES)]
        + [
            spec("n_w'", (L,)),
            spec("n_a'", (L,)),
            scal_f("task_loss"),
            scal_f("total_loss"),
            spec("n_used_w", (L,), "i32"),
            spec("n_used_a", (L,), "i32"),
            spec("act_gecko_bits", (L,)),
            spec("w_gecko_bits", (L,)),
            spec("act_zero_frac", (L,)),
        ]
    )

    eval_in = _param_specs() + [
        spec("n_w", (L,)),
        spec("n_a", (L,)),
        scal_f("mmax"),
        spec("x", (model.BATCH, *model.IMAGE)),
        spec("y", (model.BATCH,), "i32"),
    ]
    eval_out = [scal_i("correct"), scal_f("loss")]

    fa_in = _param_specs() + [
        spec("n_w", (L,)),
        spec("n_a", (L,)),
        scal_f("mmax"),
        scal_i("stochastic"),
        scal_i("step"),
        spec("x", (model.BATCH, *model.IMAGE)),
    ]
    fa_out = [spec(f"a_{nm}", s) for nm, s in zip(model.LAYERS, model.ACT_SHAPES)]

    return {
        "train_step": (model.train_step, train_in, train_out),
        "eval_step": (model.eval_step, eval_in, eval_out),
        "forward_acts": (model.forward_acts, fa_in, fa_out),
    }


_DT = {"f32": jnp.float32, "i32": jnp.int32}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/model.hlo.txt")
    args = ap.parse_args()
    art_dir = os.path.dirname(os.path.abspath(args.out)) or "."
    os.makedirs(art_dir, exist_ok=True)

    manifest = {
        "batch": model.BATCH,
        "image": list(model.IMAGE),
        "num_classes": model.NUM_CLASSES,
        "layers": model.LAYERS,
        "weight_shapes": [list(s) for s in model.WEIGHT_SHAPES],
        "bias_shapes": [list(s) for s in model.BIAS_SHAPES],
        "act_shapes": [list(s) for s in model.ACT_SHAPES],
        "lambda_w": model.LAMBDA_W,
        "lambda_a": model.LAMBDA_A,
        "artifacts": {},
    }

    for name, (fn, ins, outs) in entry_specs().items():
        shapes = [jax.ShapeDtypeStruct(tuple(s["shape"]), _DT[s["dtype"]]) for s in ins]
        lowered = jax.jit(fn, keep_unused=True).lower(*shapes)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(art_dir, fname), "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {"file": fname, "inputs": ins, "outputs": outs}
        print(f"lowered {name}: {len(text)} chars, {len(ins)} in / {len(outs)} out")

    with open(os.path.join(art_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)

    # Back-compat sentinel for the Makefile dependency (model.hlo.txt):
    with open(args.out, "w") as f:
        f.write("# see manifest.json; artifacts are per-entry-point\n")
    print(f"manifest -> {os.path.join(art_dir, 'manifest.json')}")


if __name__ == "__main__":
    main()
