"""L1 Pallas kernel: Gecko exponent-encoding footprint statistics.

Gecko (§IV-C) losslessly compresses the 8-bit biased exponents of stashed
tensors.  Values stream in groups of 64 treated as an 8x8 matrix; each of
the 8 columns shares a base exponent (the row-0 exponent, stored raw in
8 b).  Rows 1..7 are stored as deltas from the column base in
[magnitude, sign] format, with one 3-bit width field per row sized by a
leading-one detector over the row's 8 magnitudes.

Bit accounting per group (mirrored bit-exactly by ``rust/src/gecko``):

    row 0           : 8 x 8 b bases                     = 64 b
    rows 1..7, each : 3 b width + 8 x (w_r + 1) b       (w_r in 0..6)
                      3 b width + 8 x 8 b raw escape    (w_r >= 7)

The raw escape (width code 7) covers deltas whose magnitude needs 7 or 8
bits, keeping the scheme lossless over the full exponent range.  This
kernel computes only the encoded *size* (the paper's on-line footprint
accounting); the actual bitstream encoder/decoder is the Rust `gecko`
module on the request path.

Runs as a Pallas kernel so footprint accounting lives in the same fused
HLO as the training step: blocks of GROUPS_PER_BLOCK x 8 x 8 exponents
stream through VMEM, one reduction per block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

GROUP = 64  # values per Gecko group (8x8)
GROUPS_PER_BLOCK = 128  # 128 groups = 8192 values = 32 KiB f32 per block
BASE_ROW_BITS = 64  # 8 bases x 8 b
WIDTH_FIELD_BITS = 3
RAW_ESCAPE_WIDTH = 7  # width code meaning "raw 8 b exponents, no sign bit"


def _delta_width(mag: jax.Array) -> jax.Array:
    """Bits needed for a magnitude: 32 - clz(mag), 0 for mag == 0."""
    return 32 - jax.lax.clz(mag.astype(jnp.int32))


def _gecko_kernel(x_ref, o_ref):
    bits = jax.lax.bitcast_convert_type(x_ref[...], jnp.uint32)
    exp = ((bits >> jnp.uint32(23)) & jnp.uint32(0xFF)).astype(jnp.int32)
    base = exp[:, :, 0:1, :]  # (1, G, 1, 8) row-0 bases
    delta = exp[:, :, 1:, :] - base  # (1, G, 7, 8)
    width = _delta_width(jnp.abs(delta))
    w_row = jnp.max(width, axis=3)  # (1, G, 7)
    row_bits = jnp.where(
        w_row <= 6,
        WIDTH_FIELD_BITS + 8 * (w_row + 1),
        WIDTH_FIELD_BITS + 8 * 8,
    )
    o_ref[...] = (BASE_ROW_BITS + jnp.sum(row_bits, axis=2)).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("groups_per_block",))
def gecko_exponent_bits(
    x: jax.Array, *, groups_per_block: int = GROUPS_PER_BLOCK
) -> jax.Array:
    """Total encoded exponent bits for ``x`` under Gecko delta encoding.

    ``x`` is flattened and padded to a multiple of 64 by repeating the
    tensor's last value (a zero-delta pad, the hardware pads the trailing
    partial group the same way).  Returns a scalar i32 bit count.
    """
    flat = x.reshape(-1)
    total = flat.shape[0]
    pad = (-total) % (GROUP * groups_per_block)
    if pad:
        flat = jnp.concatenate([flat, jnp.broadcast_to(flat[-1], (pad,))])
    n_groups = flat.shape[0] // GROUP
    grid = n_groups // groups_per_block
    tiled = flat.reshape(grid, groups_per_block, 8, 8)

    per_group = pl.pallas_call(
        _gecko_kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec((1, groups_per_block, 8, 8), lambda i: (i, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, groups_per_block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((grid, groups_per_block), jnp.int32),
        interpret=True,
    )(tiled)

    # Remove the bits attributed to whole groups of pure padding; a partial
    # trailing group is charged in full, exactly as the hardware would pad.
    used_groups = (total + GROUP - 1) // GROUP
    flat_costs = per_group.reshape(-1)
    keep = jnp.arange(flat_costs.shape[0]) < used_groups
    return jnp.sum(jnp.where(keep, flat_costs, 0))
