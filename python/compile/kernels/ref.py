"""Pure-jnp / numpy oracles for the Pallas kernels.

Every kernel in this package must be bit-exact against its oracle here;
``python/tests`` sweeps shapes, dtype containers, and bitlengths with
hypothesis.  The same reference semantics are re-implemented in Rust
(``rust/src/formats``, ``rust/src/gecko``) and cross-checked through
golden files, so this module is the single source of truth for the
numeric format.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

F32_MANT_BITS = 23


def mantissa_quant_ref(x: jax.Array, nbits) -> jax.Array:
    """Eq. 5: keep the top ``nbits`` mantissa bits, truncating the rest."""
    bits = jax.lax.bitcast_convert_type(jnp.asarray(x, jnp.float32), jnp.uint32)
    shift = jnp.uint32(F32_MANT_BITS) - jnp.asarray(nbits, jnp.uint32)
    mask = jnp.uint32(0xFFFFFFFF) << shift
    return jax.lax.bitcast_convert_type(bits & mask, jnp.float32)


def mantissa_quant_np(x: np.ndarray, nbits: int) -> np.ndarray:
    """NumPy twin of :func:`mantissa_quant_ref` (golden-file generation)."""
    bits = np.asarray(x, np.float32).view(np.uint32)
    mask = np.uint32(0xFFFFFFFF << (F32_MANT_BITS - int(nbits)) & 0xFFFFFFFF)
    return (bits & mask).view(np.float32)


def gecko_exponent_bits_np(x: np.ndarray) -> int:
    """Bit-count oracle for Gecko delta encoding (see gecko_stats.py)."""
    flat = np.asarray(x, np.float32).reshape(-1)
    total = flat.shape[0]
    pad = (-total) % 64
    if pad:
        flat = np.concatenate([flat, np.broadcast_to(flat[-1], (pad,))])
    exps = ((flat.view(np.uint32) >> 23) & 0xFF).astype(np.int64)
    groups = exps.reshape(-1, 8, 8)
    bits = 0
    for g in groups:
        bits += 64  # row-0 bases
        delta = g[1:] - g[0:1]
        mag = np.abs(delta)
        width = np.where(mag == 0, 0, np.floor(np.log2(np.maximum(mag, 1))) + 1)
        w_row = width.max(axis=1).astype(np.int64)
        row = np.where(w_row <= 6, 3 + 8 * (w_row + 1), 3 + 64)
        bits += int(row.sum())
    return int(bits)


def gecko_fixed_bias_bits_np(x: np.ndarray, bias: int = 127, group: int = 8) -> int:
    """Bit-count oracle for Gecko's fixed-bias mode (§IV-C, groups of 8)."""
    flat = np.asarray(x, np.float32).reshape(-1)
    total = flat.shape[0]
    pad = (-total) % group
    if pad:
        flat = np.concatenate([flat, np.broadcast_to(flat[-1], (pad,))])
    exps = ((flat.view(np.uint32) >> 23) & 0xFF).astype(np.int64)
    delta = exps.reshape(-1, group) - bias
    mag = np.abs(delta)
    width = np.where(mag == 0, 0, np.floor(np.log2(np.maximum(mag, 1))) + 1)
    w_g = width.max(axis=1).astype(np.int64)
    per_group = np.where(w_g <= 6, 3 + group * (w_g + 1), 3 + group * 8)
    return int(per_group.sum())


def exponent_histogram_np(x: np.ndarray) -> np.ndarray:
    """256-bin histogram of biased exponents (Fig. 9 oracle)."""
    exps = (np.asarray(x, np.float32).reshape(-1).view(np.uint32) >> 23) & 0xFF
    return np.bincount(exps, minlength=256)
