"""L1 Pallas kernel: mantissa-container quantization (Schrödinger's FP Eq. 5-6).

The paper's Quantum Mantissa / BitChop datapath truncates the least
significant mantissa bits of an IEEE-754 float while leaving sign and
exponent untouched (Eq. 5):

    Q(M, n) = M & ((2^n - 1) << (m - n))

where ``m`` is the container's mantissa length (23 for FP32, 7 for
BFloat16-contained-in-FP32) and ``n`` the number of mantissa bits kept.

The kernel operates on the raw f32 bit pattern: everything is expressed as
``bitcast -> mask -> bitcast`` so it lowers to pure VPU (elementwise) ops on
TPU and never perturbs the MXU matmul fusion around it.  The mask depends
only on a per-tensor scalar ``n``, matching the paper's observation that
per-tensor stochastic-bitlength granularity is sufficient (§IV-A-3).

TPU mapping (DESIGN.md §Hardware-Adaptation): tensors are flattened and
tiled into ``BLOCK``-element VMEM blocks (multiples of the 8x128 VPU lane
layout); the HBM<->VMEM schedule is expressed with a 1-D grid BlockSpec.
``interpret=True`` is mandatory in this environment (CPU PJRT cannot run
Mosaic custom-calls) — structure, not wallclock, is what we optimize here.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Elements per VMEM block: 64 sublanes x 128 lanes = 8192 f32 = 32 KiB per
# buffer; in+out double-buffered comfortably fits the ~16 MiB VMEM budget.
BLOCK = 8192

# f32 container constants.
F32_MANT_BITS = 23
BF16_MANT_BITS = 7
FULL_MASK = 0xFFFF_FFFF


def _quant_kernel(n_ref, x_ref, o_ref):
    """Zero out all but the top ``n`` mantissa bits of each f32 lane."""
    n = n_ref[0]
    bits = jax.lax.bitcast_convert_type(x_ref[...], jnp.uint32)
    # shift in [0, 23]: n == 23 keeps everything, n == 0 keeps sign+exponent.
    shift = (F32_MANT_BITS - n).astype(jnp.uint32)
    mask = jnp.uint32(FULL_MASK) << shift
    o_ref[...] = jax.lax.bitcast_convert_type(bits & mask, jnp.float32)


@functools.partial(jax.jit, static_argnames=("block",))
def mantissa_quant(x: jax.Array, nbits: jax.Array, *, block: int = BLOCK) -> jax.Array:
    """Truncate ``x``'s mantissas to ``nbits`` bits (Eq. 5), any shape.

    ``nbits`` is a traced i32 scalar so the same compiled artifact serves
    every bitlength — the Rust coordinator owns the adaptation policy.
    For a BFloat16 container pass ``nbits <= 7``; the f32 bit pattern of a
    bf16 value is recovered exactly because ``23 - n >= 16`` then.
    """
    orig_shape = x.shape
    flat = x.reshape(-1)
    total = flat.shape[0]
    pad = (-total) % block
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    grid = flat.shape[0] // block
    tiled = flat.reshape(grid, block)
    n_arr = jnp.asarray(nbits, jnp.int32).reshape(1)

    out = pl.pallas_call(
        _quant_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((grid, block), jnp.float32),
        interpret=True,
    )(n_arr, tiled)

    return out.reshape(-1)[:total].reshape(orig_shape)


def stochastic_nbits(n: jax.Array, u: jax.Array, mmax: jax.Array) -> jax.Array:
    """Fractional-bitlength resolution (Eq. 6).

    ``n`` is the real-valued learnable bitlength, ``u`` a uniform [0,1)
    sample drawn once per tensor per step, ``mmax`` the container mantissa
    length (23. or 7.).  Returns the integer bitlength actually used:
    floor(n)+1 with probability frac(n), floor(n) otherwise, clipped to
    [0, mmax].
    """
    nc = jnp.clip(n, 0.0, mmax)
    ni = jnp.floor(nc)
    frac = nc - ni
    return (ni + (u < frac).astype(jnp.float32)).astype(jnp.int32)


@functools.partial(jax.custom_vjp, nondiff_argnums=())
def fake_quant(x, n, u, mmax):
    """Straight-through fake-quantization with a learnable bitlength.

    Forward: stochastic integer bitlength from (n, u), mantissa truncation
    via the Pallas kernel.  Backward: STE for ``x`` (gradient passes
    through unchanged); for ``n`` the expected-value derivative
    d E[Q(x,n)] / dn = Q(x, floor(n)+1) - Q(x, floor(n)) contracted with
    the output cotangent (§IV-A-1, the "function of the weight values and
    gradients" overhead the paper describes).  ``u`` and ``mmax`` get zero
    gradients.
    """
    n_used = stochastic_nbits(n, u, mmax)
    return mantissa_quant(x, n_used)


def _fake_quant_fwd(x, n, u, mmax):
    y = fake_quant(x, n, u, mmax)
    return y, (x, n, mmax)


def _mask_ref(x, n_int):
    """Pure-jnp Eq. 5 for the bwd pass (cheap, avoids a second kernel)."""
    bits = jax.lax.bitcast_convert_type(x, jnp.uint32)
    shift = (F32_MANT_BITS - n_int).astype(jnp.uint32)
    mask = jnp.uint32(FULL_MASK) << shift
    return jax.lax.bitcast_convert_type(bits & mask, jnp.float32)


def _fake_quant_bwd(res, g):
    x, n, mmax = res
    nc = jnp.clip(n, 0.0, mmax)
    ni = jnp.floor(nc).astype(jnp.int32)
    mmax_i = mmax.astype(jnp.int32)
    q_lo = _mask_ref(x, ni)
    q_hi = _mask_ref(x, jnp.minimum(ni + 1, mmax_i))
    # d/dn of the expected quantized value: the value of the next mantissa
    # bit.  Zero when clipped at the container ceiling.
    g_n = jnp.sum(g * (q_hi - q_lo))
    at_ceiling = (nc >= mmax).astype(jnp.float32)
    g_n = g_n * (1.0 - at_ceiling)
    return g, g_n, jnp.zeros_like(n), jnp.zeros_like(mmax)


fake_quant.defvjp(_fake_quant_fwd, _fake_quant_bwd)
