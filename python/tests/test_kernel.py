"""Kernel-vs-oracle correctness: the CORE numeric-format signal.

Hypothesis sweeps shapes and bitlengths; every comparison is bit-exact
(u32 view equality), not allclose — Eq. 5 truncation is deterministic.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.qmantissa import (
    BLOCK,
    fake_quant,
    mantissa_quant,
    stochastic_nbits,
)
from compile.kernels.gecko_stats import gecko_exponent_bits
from compile.kernels import ref


def _rand(shape, seed=0, scale=10.0):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal(shape) * scale).astype(np.float32)


# ---------------------------------------------------------------- mantissa


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(min_value=0, max_value=23),
    total=st.integers(min_value=1, max_value=3000),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_mantissa_quant_matches_oracle(n, total, seed):
    x = _rand((total,), seed)
    got = np.asarray(mantissa_quant(jnp.asarray(x), n))
    want = ref.mantissa_quant_np(x, n)
    np.testing.assert_array_equal(got.view(np.uint32), want.view(np.uint32))


@pytest.mark.parametrize("n", [0, 1, 3, 7, 12, 23])
def test_mantissa_quant_multiblock(n):
    """Shapes spanning multiple Pallas grid blocks, non-multiple remainder."""
    x = _rand((2 * BLOCK + 77,), seed=n)
    got = np.asarray(mantissa_quant(jnp.asarray(x), n))
    want = ref.mantissa_quant_np(x, n)
    np.testing.assert_array_equal(got.view(np.uint32), want.view(np.uint32))


@pytest.mark.parametrize("n", [0, 4, 7])
def test_bf16_container_path(n):
    """n <= 7 zeroes at least the lower 16 bits => valid bf16 payloads."""
    x = _rand((513,), seed=3)
    got = np.asarray(mantissa_quant(jnp.asarray(x), n)).view(np.uint32)
    assert (got & 0xFFFF == 0).all()


def test_quant_idempotent():
    x = _rand((1000,), 7)
    q1 = np.asarray(mantissa_quant(jnp.asarray(x), 5))
    q2 = np.asarray(mantissa_quant(jnp.asarray(q1), 5))
    np.testing.assert_array_equal(q1.view(np.uint32), q2.view(np.uint32))


def test_quant_full_width_is_identity():
    x = _rand((1000,), 9)
    q = np.asarray(mantissa_quant(jnp.asarray(x), 23))
    np.testing.assert_array_equal(q.view(np.uint32), x.view(np.uint32))


def test_quant_preserves_sign_and_exponent():
    x = _rand((4096,), 11)
    q = np.asarray(mantissa_quant(jnp.asarray(x), 0)).view(np.uint32)
    np.testing.assert_array_equal(q, x.view(np.uint32) & 0xFF800000)


def test_quant_error_bound():
    """|x - Q(x,n)| < 2^(e - n + 1): truncation drops < 1 ulp at bit n."""
    x = _rand((4096,), 13)
    for n in [1, 4, 8]:
        q = np.asarray(mantissa_quant(jnp.asarray(x), n))
        exp = np.floor(np.log2(np.abs(x)))
        bound = 2.0 ** (exp - n)
        assert (np.abs(x - q) <= bound + 1e-30).all()


# ------------------------------------------------------------- stochastic n


def test_stochastic_nbits_integer_passthrough():
    n = jnp.asarray([0.0, 3.0, 23.0])
    out = stochastic_nbits(n, jnp.asarray([0.99, 0.5, 0.0]), jnp.float32(23.0))
    np.testing.assert_array_equal(np.asarray(out), [0, 3, 23])


def test_stochastic_nbits_fractional():
    n = jnp.float32(4.3)
    lo = stochastic_nbits(n, jnp.float32(0.9), jnp.float32(23.0))  # 0.9 >= .3
    hi = stochastic_nbits(n, jnp.float32(0.1), jnp.float32(23.0))  # 0.1 < .3
    assert int(lo) == 4 and int(hi) == 5


def test_stochastic_nbits_clips():
    out = stochastic_nbits(
        jnp.asarray([-3.0, 99.0]), jnp.asarray([0.5, 0.5]), jnp.float32(7.0)
    )
    np.testing.assert_array_equal(np.asarray(out), [0, 7])


# NOTE: st.floats is unusable in this environment (FTZ python build), so
# fractional bitlengths are generated from integer milli-bits.
@settings(max_examples=40, deadline=None)
@given(
    nf_milli=st.integers(min_value=0, max_value=23_000),
    u_milli=st.integers(min_value=0, max_value=999),
)
def test_stochastic_nbits_bracket(nf_milli, u_milli):
    nf, u = nf_milli / 1000.0, u_milli / 1000.0
    out = int(stochastic_nbits(jnp.float32(nf), jnp.float32(u), jnp.float32(23.0)))
    lo = int(np.floor(np.float32(nf)))
    assert lo <= out <= min(lo + 1, 23)


# ---------------------------------------------------------------- gradients


def test_fake_quant_ste_passthrough():
    x = jnp.asarray(_rand((256,), 5))
    g = jax.grad(lambda v: jnp.sum(fake_quant(v, jnp.float32(4.0), jnp.float32(0.0), jnp.float32(23.0)) * 3.0))(x)
    np.testing.assert_allclose(np.asarray(g), 3.0)


def test_fake_quant_bitlength_gradient_sign():
    """More bits => closer to x => lower L2 error: d||x-q||^2/dn < 0."""
    x = jnp.asarray(_rand((4096,), 6))

    def err(n):
        q = fake_quant(x, n, jnp.float32(0.0), jnp.float32(23.0))
        return jnp.sum((jax.lax.stop_gradient(x) - q) ** 2)

    g = jax.grad(err)(jnp.float32(3.0))
    assert float(g) < 0.0


def test_fake_quant_gradient_zero_at_ceiling():
    x = jnp.asarray(_rand((128,), 8))
    g = jax.grad(
        lambda n: jnp.sum(fake_quant(x, n, jnp.float32(0.0), jnp.float32(23.0)) ** 2)
    )(jnp.float32(23.0))
    assert float(g) == 0.0


def test_fake_quant_expected_value_gradient():
    """g_n equals <g, Q(x, n+1) - Q(x, n)> for integer n."""
    x = jnp.asarray(_rand((512,), 4))
    n0 = 5

    def f(n):
        return jnp.sum(fake_quant(x, n, jnp.float32(0.9), jnp.float32(23.0)))

    g = float(jax.grad(f)(jnp.float32(n0)))
    q_lo = ref.mantissa_quant_np(np.asarray(x), n0)
    q_hi = ref.mantissa_quant_np(np.asarray(x), n0 + 1)
    np.testing.assert_allclose(g, float((q_hi - q_lo).sum()), rtol=1e-5)


# -------------------------------------------------------------- gecko stats


@settings(max_examples=25, deadline=None)
@given(
    total=st.integers(min_value=1, max_value=5000),
    seed=st.integers(min_value=0, max_value=2**16),
    scale=st.sampled_from([1e-3, 1.0, 1e4]),
)
def test_gecko_bits_matches_oracle(total, seed, scale):
    x = _rand((total,), seed, scale)
    got = int(gecko_exponent_bits(jnp.asarray(x)))
    assert got == ref.gecko_exponent_bits_np(x)


def test_gecko_bits_constant_tensor_minimal():
    """All-equal exponents -> every delta row is width 0: 64 + 7*(3+8) b."""
    x = np.full((64,), 1.5, np.float32)
    assert int(gecko_exponent_bits(jnp.asarray(x))) == 64 + 7 * (3 + 8)


def test_gecko_bits_never_worse_than_escape():
    x = _rand((4096,), 21, scale=1e30)  # extreme exponents
    got = int(gecko_exponent_bits(jnp.asarray(x)))
    groups = 4096 // 64
    assert got <= groups * (64 + 7 * (3 + 64))


def test_gecko_bits_beats_raw_on_trained_like_values():
    """Gaussian values (trained-tensor-like): compressed < 8 b/exponent."""
    x = _rand((8192,), 22, scale=1.0)
    got = int(gecko_exponent_bits(jnp.asarray(x)))
    assert got < 8192 * 8


def test_gecko_zeros_tensor():
    x = np.zeros((300,), np.float32)
    assert int(gecko_exponent_bits(jnp.asarray(x))) == ref.gecko_exponent_bits_np(x)


def test_fixed_bias_oracle_sane():
    x = _rand((1024,), 23)
    bits = ref.gecko_fixed_bias_bits_np(x)
    assert 0 < bits < 1024 * (8 + 1)
