"""Regenerate the cross-language golden file consumed by rust/tests/golden.rs."""

import json

import numpy as np

from compile.kernels import ref


def main() -> None:
    rng = np.random.default_rng(0x60)
    vals = (rng.standard_normal(512) * np.exp(rng.standard_normal(512) * 2)).astype(
        np.float32
    )
    vals[::17] = 0.0
    vals[3] = 1e30
    vals[7] = 1e-30  # exponent extremes

    golden = {
        "values_bits": [int(b) for b in vals.view(np.uint32)],
        "quant": {
            str(n): [int(b) for b in ref.mantissa_quant_np(vals, n).view(np.uint32)]
            for n in [0, 1, 3, 7, 12, 23]
        },
        "gecko_delta_bits": ref.gecko_exponent_bits_np(vals),
        "gecko_fixed_bits": ref.gecko_fixed_bias_bits_np(vals),
        "exp_histogram_nonzero": {
            str(i): int(c)
            for i, c in enumerate(ref.exponent_histogram_np(vals))
            if c > 0
        },
    }
    with open("tests/golden/format_golden.json", "w") as f:
        json.dump(golden, f)
    print(f"golden written: {len(vals)} values")


if __name__ == "__main__":
    main()
