"""L2 model: shapes, training dynamics, entry-point contracts."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

import compile.model as M


def _init(seed=0):
    rng = np.random.default_rng(seed)
    ws = [
        jnp.asarray(
            rng.standard_normal(s).astype(np.float32)
            * np.sqrt(2.0 / np.prod(s[:-1]))
        )
        for s in M.WEIGHT_SHAPES
    ]
    bs = [jnp.zeros(s, jnp.float32) for s in M.BIAS_SHAPES]
    return ws, bs


def _batch(seed=0):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((M.BATCH, *M.IMAGE)).astype(np.float32)
    y = rng.integers(0, M.NUM_CLASSES, M.BATCH).astype(np.int32)
    return jnp.asarray(x), jnp.asarray(y)


def _hyper(lr=0.05, lr_n=0.0, gamma=0.0, mmax=23.0, stochastic=0, step=0):
    return (
        jnp.float32(lr),
        jnp.float32(0.9),
        jnp.float32(lr_n),
        jnp.float32(gamma),
        jnp.float32(mmax),
        jnp.int32(stochastic),
        jnp.int32(step),
    )


def _run_step(ws, bs, mw, mb, n_w, n_a, x, y, **kw):
    return M.train_step(*ws, *bs, *mw, *mb, n_w, n_a, x, y, *_hyper(**kw))


class TestForward:
    def test_activation_shapes(self):
        ws, bs = _init()
        x, _ = _batch()
        n = jnp.full((M.NUM_Q,), 23.0)
        hyper = M.StepHyper(*_hyper())
        logits, acts = M.forward({"w": ws, "b": bs}, n, n, x, hyper)
        assert logits.shape == (M.BATCH, M.NUM_CLASSES)
        assert [a.shape for a in acts] == [tuple(s) for s in M.ACT_SHAPES]

    def test_activations_nonnegative_post_relu(self):
        ws, bs = _init(1)
        x, _ = _batch(1)
        n = jnp.full((M.NUM_Q,), 23.0)
        _, acts = M.forward({"w": ws, "b": bs}, n, n, x, M.StepHyper(*_hyper()))
        for a in acts[:-1]:  # pooled features are means of ReLU outputs too
            assert float(jnp.min(a)) >= 0.0

    def test_quantized_forward_bits_actually_truncated(self):
        ws, bs = _init(2)
        x, _ = _batch(2)
        n = jnp.full((M.NUM_Q,), 3.0)
        _, acts = M.forward({"w": ws, "b": bs}, n, n, x, M.StepHyper(*_hyper()))
        bits = np.asarray(acts[0]).view(np.uint32)
        assert (bits & ((1 << 20) - 1) == 0).all()  # 23-3 low bits zero


class TestTrainStep:
    def test_output_count_and_shapes(self):
        ws, bs = _init()
        mw = [jnp.zeros_like(w) for w in ws]
        mb = [jnp.zeros_like(b) for b in bs]
        n = jnp.full((M.NUM_Q,), 23.0)
        x, y = _batch()
        out = _run_step(ws, bs, mw, mb, n, n, x, y)
        assert len(out) == 4 * M.NUM_Q + 9
        for i, s in enumerate(M.WEIGHT_SHAPES):
            assert out[i].shape == tuple(s)

    def test_loss_decreases_fullprec(self):
        ws, bs = _init(3)
        mw = [jnp.zeros_like(w) for w in ws]
        mb = [jnp.zeros_like(b) for b in bs]
        n = jnp.full((M.NUM_Q,), 23.0)
        x, y = _batch(3)
        losses = []
        for step in range(15):
            out = _run_step(ws, bs, mw, mb, n, n, x, y, step=step)
            ws, bs = list(out[:7]), list(out[7:14])
            mw, mb = list(out[14:21]), list(out[21:28])
            losses.append(float(out[30]))
        assert losses[-1] < losses[0] * 0.8

    def test_bitlengths_descend_under_penalty(self):
        ws, bs = _init(4)
        mw = [jnp.zeros_like(w) for w in ws]
        mb = [jnp.zeros_like(b) for b in bs]
        n_w = jnp.full((M.NUM_Q,), 23.0)
        n_a = jnp.full((M.NUM_Q,), 23.0)
        x, y = _batch(4)
        for step in range(10):
            out = _run_step(
                ws, bs, mw, mb, n_w, n_a, x, y,
                lr_n=5.0, gamma=0.1, stochastic=1, step=step,
            )
            ws, bs = list(out[:7]), list(out[7:14])
            mw, mb = list(out[14:21]), list(out[21:28])
            n_w, n_a = out[28], out[29]
        assert float(jnp.mean(n_a)) < 23.0
        assert float(jnp.mean(n_w)) < 23.0

    def test_bitlengths_frozen_when_lr_n_zero(self):
        ws, bs = _init(5)
        mw = [jnp.zeros_like(w) for w in ws]
        mb = [jnp.zeros_like(b) for b in bs]
        n_a = jnp.asarray([4.0] * M.NUM_Q)
        n_w = jnp.full((M.NUM_Q,), 23.0)
        x, y = _batch(5)
        out = _run_step(ws, bs, mw, mb, n_w, n_a, x, y, lr_n=0.0, gamma=0.1)
        np.testing.assert_array_equal(np.asarray(out[29]), np.asarray(n_a))

    def test_n_used_respects_container(self):
        ws, bs = _init(6)
        mw = [jnp.zeros_like(w) for w in ws]
        mb = [jnp.zeros_like(b) for b in bs]
        n = jnp.full((M.NUM_Q,), 23.0)  # above bf16 ceiling
        x, y = _batch(6)
        out = _run_step(ws, bs, mw, mb, n, n, x, y, mmax=7.0)
        assert (np.asarray(out[32]) <= 7).all()
        assert (np.asarray(out[33]) <= 7).all()

    def test_stats_outputs_sane(self):
        ws, bs = _init(7)
        mw = [jnp.zeros_like(w) for w in ws]
        mb = [jnp.zeros_like(b) for b in bs]
        n = jnp.full((M.NUM_Q,), 23.0)
        x, y = _batch(7)
        out = _run_step(ws, bs, mw, mb, n, n, x, y)
        a_bits, w_bits, zfrac = out[34], out[35], out[36]
        a_elems = [int(np.prod(s)) for s in M.ACT_SHAPES]
        for i in range(M.NUM_Q):
            assert 0 < float(a_bits[i]) <= a_elems[i] * (64 + 7 * 67) / 64
            assert 0.0 <= float(zfrac[i]) <= 1.0
        # ReLU outputs should have a sizable zero fraction
        assert float(zfrac[0]) > 0.1


class TestEvalStep:
    def test_correct_count_range(self):
        ws, bs = _init(8)
        n = jnp.full((M.NUM_Q,), 23.0)
        x, y = _batch(8)
        correct, ce = M.eval_step(*ws, *bs, n, n, jnp.float32(23.0), x, y)
        assert 0 <= int(correct) <= M.BATCH
        assert float(ce) > 0

    def test_eval_rounds_bitlengths_up(self):
        ws, bs = _init(9)
        x, y = _batch(9)
        n_frac = jnp.full((M.NUM_Q,), 3.2)
        n_ceil = jnp.full((M.NUM_Q,), 4.0)
        a = M.eval_step(*ws, *bs, n_frac, n_frac, jnp.float32(23.0), x, y)
        b = M.eval_step(*ws, *bs, n_ceil, n_ceil, jnp.float32(23.0), x, y)
        assert int(a[0]) == int(b[0]) and float(a[1]) == float(b[1])


class TestForwardActs:
    def test_shapes_and_quantization(self):
        ws, bs = _init(10)
        x, _ = _batch(10)
        n = jnp.full((M.NUM_Q,), 2.0)
        acts = M.forward_acts(
            *ws, *bs, n, n, jnp.float32(23.0), jnp.int32(0), jnp.int32(0), x
        )
        assert [a.shape for a in acts] == [tuple(s) for s in M.ACT_SHAPES]
        bits = np.asarray(acts[1]).view(np.uint32)
        assert (bits & ((1 << 21) - 1) == 0).all()


class TestLambdaWeights:
    def test_lambdas_sum_to_one(self):
        assert abs(sum(M.LAMBDA_W) + sum(M.LAMBDA_A) - 1.0) < 1e-9

    def test_activations_dominate(self):
        """Paper §VI-A: activations are the bulk of the stashed footprint."""
        assert sum(M.LAMBDA_A) > 0.9
