//! Ablation sweep over the accelerator model: how do the Table II gains
//! move with DRAM bandwidth, on-chip buffer size, and batch size?  This is
//! the "design-choice ablation" DESIGN.md calls out for hwsim.
//!
//! Run: `cargo run --release --example hwsim_sweep`

use sfp::formats::Container;
use sfp::hwsim::{gains, simulate_pass, AccelConfig, ComputeType, LayerBits, PassStats};
use sfp::report::FootprintModel;
use sfp::traces::{resnet18, NetworkTrace};

fn pass(cfg: &AccelConfig, net: &NetworkTrace, batch: usize, model: &FootprintModel, ct: ComputeType) -> PassStats {
    let n = net.layers.len();
    let bits: Vec<LayerBits> = net
        .layers
        .iter()
        .enumerate()
        .map(|(i, l)| {
            let f = model.layer(l, i as f64 / n as f64, batch, i as u64);
            LayerBits {
                weight: f.total_weight_bits(),
                act: f.total_act_bits(),
            }
        })
        .collect();
    let idx = std::cell::Cell::new(0);
    simulate_pass(cfg, net, batch, ct, &move |_| {
        let i = idx.get();
        idx.set(i + 1);
        bits[i % bits.len()]
    })
}

fn main() {
    let net = resnet18();
    let qm = FootprintModel::sfp_qm(Container::Bf16);
    let fp32 = FootprintModel::fp32();

    println!("== DRAM bandwidth sweep (batch 256) ==");
    println!("{:>10} {:>12} {:>12} {:>10}", "GB/s", "QM speedup", "QM energy", "membound%");
    for gbs in [12.8, 25.6, 51.2, 102.4, 204.8] {
        let cfg = AccelConfig {
            dram_bw_bits: gbs * 8e9,
            ..Default::default()
        };
        let base = pass(&cfg, &net, 256, &fp32, ComputeType::Fp32);
        let v = pass(&cfg, &net, 256, &qm, ComputeType::Bf16);
        let (s, e) = gains(&base, &v);
        println!(
            "{gbs:>10.1} {s:>11.2}x {e:>11.2}x {:>9.0}%",
            100.0 * v.memory_bound_layers as f64 / v.total_layer_passes as f64
        );
    }

    println!("\n== on-chip buffer sweep (batch 256) ==");
    println!("{:>10} {:>14} {:>12}", "MiB", "FP32 traffic", "QM speedup");
    for mib in [4.0, 8.0, 16.0, 32.0, 64.0, 128.0] {
        let cfg = AccelConfig {
            buffer_bytes: mib * 1024.0 * 1024.0,
            ..Default::default()
        };
        let base = pass(&cfg, &net, 256, &fp32, ComputeType::Fp32);
        let v = pass(&cfg, &net, 256, &qm, ComputeType::Bf16);
        let (s, _) = gains(&base, &v);
        println!("{mib:>10.0} {:>12.1}Gb {s:>11.2}x", base.dram_bits / 1e9);
    }

    println!("\n== batch-size sweep ==");
    println!("{:>8} {:>12} {:>12} {:>12}", "batch", "BF16 speed", "QM speed", "BC speed");
    let bc = FootprintModel::sfp_bc(Container::Bf16);
    let bf = FootprintModel::bf16();
    for batch in [32, 64, 128, 256, 512] {
        let cfg = AccelConfig::default();
        let base = pass(&cfg, &net, batch, &fp32, ComputeType::Fp32);
        let b = gains(&base, &pass(&cfg, &net, batch, &bf, ComputeType::Bf16)).0;
        let q = gains(&base, &pass(&cfg, &net, batch, &qm, ComputeType::Bf16)).0;
        let c = gains(&base, &pass(&cfg, &net, batch, &bc, ComputeType::Bf16)).0;
        println!("{batch:>8} {b:>11.2}x {q:>11.2}x {c:>11.2}x");
    }
}
