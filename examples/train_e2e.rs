//! End-to-end validation driver (DESIGN.md §6): train the residual CNN
//! through the full three-layer stack — Pallas kernels inside the JAX
//! train step, AOT-lowered to HLO, executed from Rust over PJRT — for all
//! four variants, and print the paper's headline quantities: loss curves,
//! validation accuracy vs the FP32 baseline, learned bitlengths, and the
//! exact footprint ledger.  Results land in EXPERIMENTS.md.
//!
//! Run: `cargo run --release --example train_e2e -- [--epochs 9] [--steps 60] [--out results/e2e]`

use sfp::coordinator::{TrainConfig, Trainer, Variant};
use sfp::formats::Container;
use sfp::report::figures;
use sfp::runtime::Runtime;
use sfp::stats::{EncodedWidthCdf, ExponentHistogram};
use sfp::util::cli::Args;
use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let out = PathBuf::from(args.get_or("out", "results/e2e"));
    std::fs::create_dir_all(&out)?;
    let rt = Runtime::load(&PathBuf::from(args.get_or("artifacts", "artifacts")))?;
    println!("platform: {}", rt.platform());

    let cfg = |variant| TrainConfig {
        variant,
        epochs: args.get_usize("epochs", 9),
        steps_per_epoch: args.get_usize("steps", 60),
        eval_batches: args.get_usize("eval-batches", 8),
        lr0: args.get_f64("lr", 0.05) as f32,
        momentum: 0.9,
        seed: args.get_usize("seed", 42) as u64,
        out_dir: Some(out.clone()),
        ..Default::default()
    };

    let t0 = std::time::Instant::now();
    println!("== FP32 baseline ==");
    let fp32 = Trainer::new(&rt, cfg(Variant::Fp32)).run()?;
    println!("== BF16 baseline ==");
    let bf16 = Trainer::new(&rt, cfg(Variant::Bf16)).run()?;
    println!("== SFP_QM (BF16 container) ==");
    let mut qm_trainer = Trainer::new(&rt, cfg(Variant::SfpQm(Container::Bf16)));
    let qm = qm_trainer.run()?;
    println!("== SFP_BC (BF16 container) ==");
    let bc = Trainer::new(&rt, cfg(Variant::SfpBc(Container::Bf16))).run()?;

    println!("\n{:<14} {:>8} {:>11} {:>11}", "variant", "val_acc", "vs FP32", "vs BF16");
    for r in [&fp32, &bf16, &qm, &bc] {
        println!(
            "{:<14} {:>7.2}% {:>10.1}% {:>10.1}%",
            r.label,
            100.0 * r.final_val_acc,
            100.0 * r.footprint.relative_to(&r.footprint_fp32),
            100.0 * r.footprint.relative_to(&r.footprint_bf16),
        );
    }
    println!(
        "\naccuracy deltas vs FP32: QM {:+.2}%, BC {:+.2}% (paper: -0.40 / +0.01 on ResNet18)",
        100.0 * (qm.final_val_acc - fp32.final_val_acc),
        100.0 * (bc.final_val_acc - fp32.final_val_acc),
    );
    println!("QM learned n_a = {:?}", qm.final_n_a);
    println!("QM learned n_w = {:?}", qm.final_n_w);
    println!("BC bitlength histogram mean = {:.2}", bc.bc_histogram.mean());

    // figures from the e2e runs
    figures::fig_accuracy(&out.join("fig2_accuracy_qm.csv"), &fp32, &qm)?;
    figures::fig3_bitlengths(&out.join("fig3_qm_bitlengths.csv"), &qm)?;
    figures::fig4_per_layer(&out.join("fig4_qm_per_layer.csv"), &qm)?;
    figures::fig_accuracy(&out.join("fig6_accuracy_bc.csv"), &bf16, &bc)?;
    figures::fig7_bc_bits(&out.join("fig7_bc_bits.csv"), &bc, None)?;
    figures::fig8_bc_histogram(&out.join("fig8_bc_histogram.csv"), &bc)?;

    // figs 9/10 from the real trained tensors (weights are step inputs we
    // hold host-side; activations come from the forward_acts artifact)
    let mut hw = ExponentHistogram::new();
    let mut cw = EncodedWidthCdf::new();
    for w in qm_trainer.weights() {
        hw.add_vals(w.as_f32()?);
        cw.add_vals(w.as_f32()?);
    }
    let mut ha = ExponentHistogram::new();
    let mut ca = EncodedWidthCdf::new();
    for a in qm_trainer.dump_acts(0)? {
        ha.add_vals(a.as_f32()?);
        ca.add_vals(a.as_f32()?);
    }
    figures::fig9_exponents(&out.join("fig9_exponents_e2e.csv"), &hw, &ha)?;
    figures::fig10_cdf(&out.join("fig10_gecko_cdf_e2e.csv"), &cw, &ca)?;
    println!(
        "\ne2e exponent stats: weights {:.1}% within ±8 of bias; acts {:.1}% zeros; {:.1}% of act exps <=5b after Gecko",
        100.0 * hw.mass_near_bias(8),
        100.0 * ha.bins[0] as f64 / ha.total.max(1) as f64,
        100.0 * ca.cdf_at(5),
    );
    println!("wrote CSVs to {} ({:.1}s total)", out.display(), t0.elapsed().as_secs_f64());
    Ok(())
}
