//! Codec deep-dive: exercise the Gecko + SFP bitstreams on value streams
//! with very different statistics and print the encoded-size anatomy —
//! the hands-on version of Figs. 9/10.
//!
//! Run: `cargo run --release --example codec_roundtrip`

use sfp::formats::Container;
use sfp::gecko::{self, Mode};
use sfp::sfp::SfpCodec;
use sfp::stats::EncodedWidthCdf;
use sfp::traces::ValueModel;

fn show(label: &str, vals: &[f32], mant_bits: u32, elide_sign: bool) {
    let exps = gecko::exponents(vals);
    let delta = gecko::encode(&exps, Mode::Delta);
    assert_eq!(gecko::decode(&delta, Mode::Delta), exps, "lossless");
    let fixed_mode = Mode::FixedBias { bias: 127, group: 8 };
    let fixed = gecko::encode(&exps, fixed_mode);
    assert_eq!(gecko::decode(&fixed, fixed_mode), exps, "lossless");

    let codec = SfpCodec::new(Container::Bf16, elide_sign);
    let full = codec.compress(vals, mant_bits);

    let mut cdf = EncodedWidthCdf::new();
    cdf.add_exponents(&exps);

    println!("--- {label} ({} values, n={mant_bits}) ---", vals.len());
    println!(
        "  gecko delta : {:.3} b/exponent (payload {:.3} + metadata {:.3})",
        delta.total_bits() as f64 / vals.len() as f64,
        delta.payload_bits as f64 / vals.len() as f64,
        delta.metadata_bits as f64 / vals.len() as f64,
    );
    println!(
        "  gecko fixed : {:.3} b/exponent",
        fixed.total_bits() as f64 / vals.len() as f64
    );
    println!(
        "  encoded-width CDF: {:>4.1}% <=1b, {:>4.1}% <=4b, {:>4.1}% <=5b",
        100.0 * cdf.cdf_at(1),
        100.0 * cdf.cdf_at(4),
        100.0 * cdf.cdf_at(5),
    );
    println!(
        "  SFP total   : {:.3} b/value = {:.1}% of BF16 ({} compressor cycles, {:.2} values/cycle)",
        full.total_bits() as f64 / vals.len() as f64,
        100.0 * full.ratio(Container::Bf16),
        full.cycles,
        vals.len() as f64 / full.cycles as f64,
    );
}

fn main() {
    let n = 64 * 4096;
    show(
        "post-ReLU activations (clustered zeros)",
        &ValueModel::relu_act().sample_values(n, 11, true),
        3,
        true,
    );
    show(
        "hswish activations (dense)",
        &ValueModel::hswish_act().sample_values(n, 12, false),
        3,
        false,
    );
    show(
        "trained weights (plateaued exponents)",
        &ValueModel::weights().sample_values(n, 13, false),
        4,
        false,
    );
    // adversarial: white-noise bit patterns still roundtrip, just without
    // compression wins
    let mut rng = sfp::traces::SplitMix64::new(14);
    let noise: Vec<f32> = (0..n)
        .map(|_| f32::from_bits((rng.next_u64() as u32) & 0x7F7F_FFFF))
        .collect();
    show("adversarial white-noise exponents", &noise, 7, false);
}
