//! Quickstart: the 60-second tour of the public API.
//!
//! 1. Quantize a tensor's mantissas (the paper's Eq. 5 datapath).
//! 2. Compress it with the Gecko/SFP codec and get the footprint split.
//! 3. Ask the hwsim what that footprint buys on the modelled accelerator.
//!
//! Run: `cargo run --release --example quickstart`

use sfp::formats::{quantize, Container};
use sfp::hwsim::{gains, simulate_pass, AccelConfig, ComputeType, LayerBits};
use sfp::report::FootprintModel;
use sfp::sfp::SfpCodec;
use sfp::traces::{resnet18, ValueModel};

fn main() {
    // --- 1. mantissa truncation -----------------------------------------
    let x = 3.14159265f32;
    println!("mantissa containers for {x}:");
    for n in [23u32, 7, 4, 1, 0] {
        let q = quantize(x, n, Container::Fp32);
        println!("  n={n:>2}: {q:<12} bits={:#034b}", q.to_bits());
    }

    // --- 2. compress a trained-like tensor ------------------------------
    let vals = ValueModel::relu_act().sample_values(64 * 1024, 1, true);
    let codec = SfpCodec::new(Container::Bf16, /*elide_sign=*/ true);
    let n = 3; // say BitChop settled at 3 mantissa bits
    let c = codec.compress(&vals, n);
    let back = codec.decompress(&c);
    assert!(vals
        .iter()
        .zip(&back)
        .all(|(&v, &b)| quantize(v, n, Container::Bf16).to_bits() == b.to_bits()));
    println!(
        "\nSFP codec @ n={n}: {:.2} b/value ({:.1}% of BF16, {:.1}% of FP32), lossless after quantization",
        c.total_bits() as f64 / vals.len() as f64,
        100.0 * c.ratio(Container::Bf16),
        100.0 * c.total_bits() as f64 / (32.0 * vals.len() as f64),
    );

    // --- 3. what does that buy at ImageNet scale? ------------------------
    let net = resnet18();
    let cfg = AccelConfig::default();
    let batch = 256;
    let layer_bits = |model: &FootprintModel| -> Vec<LayerBits> {
        let n_layers = net.layers.len();
        net.layers
            .iter()
            .enumerate()
            .map(|(i, l)| {
                let f = model.layer(l, i as f64 / n_layers as f64, batch, i as u64);
                LayerBits {
                    weight: f.total_weight_bits(),
                    act: f.total_act_bits(),
                }
            })
            .collect()
    };
    let b_fp32 = layer_bits(&FootprintModel::fp32());
    let b_qm = layer_bits(&FootprintModel::sfp_qm(Container::Bf16));
    let i1 = std::cell::Cell::new(0);
    let fp32 = simulate_pass(&cfg, &net, batch, ComputeType::Fp32, &|_| {
        let i = i1.get();
        i1.set(i + 1);
        b_fp32[i % b_fp32.len()]
    });
    let i2 = std::cell::Cell::new(0);
    let qm = simulate_pass(&cfg, &net, batch, ComputeType::Bf16, &|_| {
        let i = i2.get();
        i2.set(i + 1);
        b_qm[i % b_qm.len()]
    });
    let (speed, energy) = gains(&fp32, &qm);
    println!(
        "\nResNet18/ImageNet training pass on the modelled accelerator:\n  SFP_QM vs FP32: {speed:.2}x faster, {energy:.2}x more energy-efficient\n  (paper Table II: 2.30x / 6.12x)"
    );
}
