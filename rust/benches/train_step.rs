//! PJRT train-step latency — the end-to-end hot loop of the coordinator
//! (compiled HLO with the Pallas quantizers inside).  Requires `make
//! artifacts`; skips gracefully when artifacts are missing.

use sfp::coordinator::{TrainConfig, Trainer, Variant};
use sfp::formats::Container;
use sfp::runtime::Runtime;
use sfp::util::bench::Bench;
use std::path::Path;

fn main() {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("artifacts/manifest.json missing — run `make artifacts`; skipping");
        return;
    }
    let rt = match Runtime::load(dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("runtime load failed ({e:#}); skipping");
            return;
        }
    };
    let batch = rt.manifest.batch as f64;

    let b = Bench::new("train_step").with_epochs(5);
    for (label, variant) in [
        ("fp32", Variant::Fp32),
        ("bf16", Variant::Bf16),
        ("sfp_qm", Variant::SfpQm(Container::Bf16)),
        ("sfp_bc", Variant::SfpBc(Container::Bf16)),
    ] {
        let cfg = TrainConfig {
            variant,
            epochs: 1,
            steps_per_epoch: 1,
            eval_batches: 1,
            out_dir: None,
            ..Default::default()
        };
        let mut trainer = Trainer::new(&rt, cfg);
        // one step per iteration (samples/s = batch/step-latency)
        b.run(&format!("step_{label}"), batch, || {
            trainer.run_one_step_for_bench().expect("step");
        });
    }

    let cfg = TrainConfig {
        variant: Variant::Fp32,
        epochs: 1,
        steps_per_epoch: 1,
        eval_batches: 1,
        out_dir: None,
        ..Default::default()
    };
    let trainer = Trainer::new(&rt, cfg);
    let b = Bench::new("eval_and_dump").with_epochs(5);
    b.run("eval_step", batch, || {
        trainer.evaluate().expect("eval");
    });
    b.run("forward_acts_dump", batch, || {
        trainer.dump_acts(0).expect("dump");
    });
}
