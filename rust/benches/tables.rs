//! End-to-end table regeneration benches: one per paper table, plus the
//! per-figure footprint models.  These time the full analytic pipeline
//! (value-model sampling -> codecs -> hwsim).

use sfp::formats::Container;
use sfp::hwsim::AccelConfig;
use sfp::report::{fig13_rows, tables, FootprintModel};
use sfp::traces::{mobilenet_v3_small, resnet18};
use sfp::util::bench::{black_box, Bench};

fn main() {
    let b = Bench::new("tables").with_epochs(5);
    b.run("table1_both_networks", 2.0, || {
        black_box(tables::table1());
    });
    b.run("table2_both_networks", 2.0, || {
        black_box(tables::table2(&AccelConfig::default(), 256));
    });

    let b = Bench::new("footprint_models");
    let rn = resnet18();
    let mv = mobilenet_v3_small();
    b.run("resnet18_sfp_qm", rn.layers.len() as f64, || {
        black_box(FootprintModel::sfp_qm(Container::Bf16).network(&rn, 256));
    });
    b.run("mobilenet_sfp_bc", mv.layers.len() as f64, || {
        black_box(FootprintModel::sfp_bc(Container::Bf16).network(&mv, 256));
    });
    b.run("fig13_resnet18", 7.0, || {
        black_box(fig13_rows(&rn, 256));
    });
}
