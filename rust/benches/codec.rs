//! Codec throughput benches — the L3 hot path (§Perf).  Measures the
//! Gecko exponent codec, the full SFP pack/unpack pipe, and the pure
//! accounting path, in values/second on trained-like streams.

use sfp::formats::Container;
use sfp::gecko::{self, Mode};
use sfp::sfp::{sfp_bits, SfpCodec};
use sfp::traces::ValueModel;
use sfp::util::bench::{black_box, Bench};

fn main() {
    let n = 64 * 4096; // 256k values per iteration
    let acts = ValueModel::relu_act().sample_values(n, 1, true);
    let weights = ValueModel::weights().sample_values(n, 2, false);
    let act_exps = gecko::exponents(&acts);

    let b = Bench::new("gecko");
    b.run("exponents_extract", n as f64, || {
        black_box(gecko::exponents(black_box(&acts)));
    });
    b.run("encode_delta_acts", n as f64, || {
        black_box(gecko::encode(black_box(&act_exps), Mode::Delta));
    });
    let enc = gecko::encode(&act_exps, Mode::Delta);
    b.run("decode_delta_acts", n as f64, || {
        black_box(gecko::decode(black_box(&enc), Mode::Delta));
    });
    b.run("encoded_bits_only", n as f64, || {
        black_box(gecko::encoded_bits(black_box(&act_exps), Mode::Delta));
    });
    let fixed = Mode::FixedBias { bias: 127, group: 8 };
    b.run("encode_fixed_acts", n as f64, || {
        black_box(gecko::encode(black_box(&act_exps), fixed));
    });

    let b = Bench::new("sfp_codec");
    for (label, vals, elide) in [("acts", &acts, true), ("weights", &weights, false)] {
        let codec = SfpCodec::new(Container::Bf16, elide);
        for n_mant in [1u32, 4, 7] {
            b.run(&format!("compress_{label}_n{n_mant}"), n as f64, || {
                black_box(codec.compress(black_box(vals), n_mant));
            });
        }
        let c = codec.compress(vals, 4);
        b.run(&format!("decompress_{label}_n4"), n as f64, || {
            black_box(codec.decompress(black_box(&c)));
        });
        b.run(&format!("bits_only_{label}_n4"), n as f64, || {
            black_box(sfp_bits(black_box(vals), 4, Container::Bf16, elide));
        });
    }
}
