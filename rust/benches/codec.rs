//! Codec throughput benches — the L3 hot path (see EXPERIMENTS.md §Perf).
//! Measures the Gecko exponent codec and the full SFP pack/unpack pipe
//! with the word-parallel kernels against the scalar reference (asserting
//! the ≥4× gecko encode speedup the kernels exist for), then every
//! [`StashCodec`] end-to-end in GB/s of f32 payload.
//!
//! Besides stdout, the run emits `results-codec/lab_manifest.json` (one
//! synthetic job per case, `wall_ms` = median time for one pass over the
//! stream) so `repro inspect results-codec --baseline BENCH_codec.json
//! --gate PCT` gates codec regressions exactly like lab-run regressions.

use sfp::formats::{Container, ExponentLayout};
use sfp::gecko::{self, Kernel, Mode, SegReader};
use sfp::sfp::{sfp_bits, SfpCodec};
use sfp::stash::{
    ContainerMeta, GeckoStashCodec, JsStashCodec, RawStashCodec, SfpStashCodec, StashCodec,
};
use sfp::traces::ValueModel;
use sfp::util::bench::{black_box, Bench, Report};
use sfp::util::json::Json;
use std::collections::BTreeMap;

/// One manifest row: a bench case with its median per-pass wall clock and
/// payload throughput.
struct Case {
    label: String,
    wall_ms: f64,
    gbps: f64,
}

impl Case {
    fn new(label: &str, bytes: f64, r: Report) -> Case {
        // 1 byte/ns = 1 (decimal) GB/s, so bytes/median_ns is GB/s.
        let gbps = bytes / r.median_ns;
        println!("    {label}: {gbps:.2} GB/s");
        Case {
            label: label.to_string(),
            wall_ms: r.median_ns / 1e6,
            gbps,
        }
    }
}

fn write_manifest(cases: &[Case]) {
    let jobs: Vec<Json> = cases
        .iter()
        .map(|c| {
            let mut j = BTreeMap::new();
            j.insert("label".to_string(), Json::Str(c.label.clone()));
            j.insert("wall_ms".to_string(), Json::Num(c.wall_ms));
            j.insert("gbps".to_string(), Json::Num(c.gbps));
            j.insert("status".to_string(), Json::Str("executed".to_string()));
            Json::Obj(j)
        })
        .collect();
    let mut m = BTreeMap::new();
    m.insert("mode".to_string(), Json::Str("bench-codec".to_string()));
    m.insert("total_jobs".to_string(), Json::Num(cases.len() as f64));
    m.insert("executed".to_string(), Json::Num(cases.len() as f64));
    m.insert("cached".to_string(), Json::Num(0.0));
    m.insert("failed".to_string(), Json::Num(0.0));
    m.insert("skipped".to_string(), Json::Num(0.0));
    m.insert(
        "wall_ms".to_string(),
        Json::Num(cases.iter().map(|c| c.wall_ms).sum()),
    );
    m.insert("jobs".to_string(), Json::Arr(jobs));
    std::fs::create_dir_all("results-codec").expect("create results-codec");
    std::fs::write("results-codec/lab_manifest.json", Json::Obj(m).to_string())
        .expect("write codec bench manifest");
    println!("manifest -> results-codec/lab_manifest.json");
}

fn main() {
    let n = 64 * 4096; // 256k values = 1 MiB of f32 payload per pass
    let f32_bytes = (n * 4) as f64;
    let exp_bytes = n as f64; // gecko packs one exponent byte per value
    let acts = ValueModel::relu_act().sample_values(n, 1, true);
    let weights = ValueModel::weights().sample_values(n, 2, false);
    let act_exps = gecko::exponents(&acts);
    let mut cases: Vec<Case> = Vec::new();

    // -- gecko exponent codec: word kernels vs the scalar reference --
    let b = Bench::new("gecko");
    b.run("exponents_extract", n as f64, || {
        black_box(gecko::exponents(black_box(&acts)));
    });
    let kernel_pair = |case: &str, mode: Mode| -> (Report, Report) {
        let scalar = b.run(&format!("{case}_scalar"), n as f64, || {
            black_box(gecko::encode_kernel(black_box(&act_exps), mode, Kernel::Scalar));
        });
        let word = b.run(&format!("{case}_word"), n as f64, || {
            black_box(gecko::encode_kernel(black_box(&act_exps), mode, Kernel::Word));
        });
        println!(
            "    {case}: word {:.2} GB/s, {:.2}x over scalar",
            exp_bytes / word.median_ns,
            scalar.median_ns / word.median_ns,
        );
        (scalar, word)
    };
    let (delta_scalar, delta_word) = kernel_pair("encode_delta_acts", Mode::Delta);
    let fixed = Mode::FixedBias { bias: 127, group: 8 };
    kernel_pair("encode_fixed_acts", fixed);
    cases.push(Case::new("gecko/encode_delta_word", exp_bytes, delta_word));
    let enc = gecko::encode(&act_exps, Mode::Delta);
    for (kernel, label) in [(Kernel::Scalar, "scalar"), (Kernel::Word, "word")] {
        let r = b.run(&format!("decode_delta_acts_{label}"), n as f64, || {
            let mut payload = SegReader::single(&enc.payload, enc.payload_bits);
            let mut meta = SegReader::single(&enc.metadata, enc.metadata_bits);
            black_box(gecko::decode_readers_kernel(
                &mut payload,
                &mut meta,
                enc.count,
                Mode::Delta,
                kernel,
            ));
        });
        if kernel == Kernel::Word {
            cases.push(Case::new("gecko/decode_delta_word", exp_bytes, r));
        }
    }
    b.run("encoded_bits_only", n as f64, || {
        black_box(gecko::encoded_bits(black_box(&act_exps), Mode::Delta));
    });
    // The word kernels are this PR's reason to exist: hold the ≥4x
    // single-thread gecko delta-encode speedup (relative, same process —
    // machine-independent) or fail the bench run loudly.
    let speedup = delta_scalar.median_ns / delta_word.median_ns;
    assert!(
        speedup >= 4.0,
        "gecko delta encode word kernel must be >= 4x scalar, got {speedup:.2}x"
    );

    // -- full SFP pipe: word vs scalar compress, then decompress --
    let b = Bench::new("sfp_codec");
    for (label, vals, elide) in [("acts", &acts, true), ("weights", &weights, false)] {
        let codec = SfpCodec::new(Container::Bf16, elide);
        for n_mant in [1u32, 4, 7] {
            let word = b.run(&format!("compress_{label}_n{n_mant}"), n as f64, || {
                black_box(codec.compress_kernel(black_box(vals), n_mant, Kernel::Word));
            });
            if n_mant == 4 {
                let scalar = b.run(&format!("compress_{label}_n4_scalar"), n as f64, || {
                    black_box(codec.compress_kernel(black_box(vals), n_mant, Kernel::Scalar));
                });
                println!(
                    "    compress_{label}_n4: word {:.2} GB/s, {:.2}x over scalar",
                    f32_bytes / word.median_ns,
                    scalar.median_ns / word.median_ns,
                );
                cases.push(Case::new(&format!("sfp/compress_{label}_n4"), f32_bytes, word));
            }
        }
        let c = codec.compress(vals, 4);
        let r = b.run(&format!("decompress_{label}_n4"), n as f64, || {
            black_box(codec.decompress(black_box(&c)));
        });
        cases.push(Case::new(&format!("sfp/decompress_{label}_n4"), f32_bytes, r));
        b.run(&format!("bits_only_{label}_n4"), n as f64, || {
            black_box(sfp_bits(black_box(vals), 4, Container::Bf16, elide));
        });
    }

    // -- every StashCodec end-to-end (encode + decode GB/s of f32) --
    let b = Bench::new("stash_codec");
    let codecs: [&dyn StashCodec; 4] = [
        &GeckoStashCodec,
        &SfpStashCodec,
        &RawStashCodec,
        &JsStashCodec,
    ];
    let meta = ContainerMeta::new(Container::Bf16, 7);
    for codec in codecs {
        let name = codec.name();
        let r = b.run(&format!("encode_{name}"), n as f64, || {
            black_box(codec.encode(black_box(&acts), &meta));
        });
        cases.push(Case::new(&format!("stash/encode_{name}"), f32_bytes, r));
        let enc = codec.encode(&acts, &meta);
        let r = b.run(&format!("decode_{name}"), n as f64, || {
            black_box(codec.decode(black_box(&enc), &meta));
        });
        cases.push(Case::new(&format!("stash/decode_{name}"), f32_bytes, r));
    }

    // -- block-shared exponent layout (Flexpoint-style) on the gecko path:
    // one shared exponent per 16-value block, max-reduced at encode --
    let blk = ContainerMeta::new(Container::Bf16, 7)
        .with_layout(ExponentLayout::BlockShared { block: 16, bits: 8 });
    let r = b.run("encode_gecko_blk16", n as f64, || {
        black_box(GeckoStashCodec.encode(black_box(&acts), &blk));
    });
    cases.push(Case::new("stash/encode_gecko_blk16", f32_bytes, r));
    let enc = GeckoStashCodec.encode(&acts, &blk);
    let r = b.run("decode_gecko_blk16", n as f64, || {
        black_box(GeckoStashCodec.decode(black_box(&enc), &blk));
    });
    cases.push(Case::new("stash/decode_gecko_blk16", f32_bytes, r));

    write_manifest(&cases);
}
