//! Lab orchestration bench: a 2 models × 2 codecs × 2 budgets stash grid
//! (plus its consolidation job) run three ways — serial, parallel
//! (work-stealing), and warm-cache — with per-job timings surfaced in the
//! emitted `lab_manifest.json`.
//!
//! Acceptance gates (CI executes this bench):
//!   * parallel grid wall-clock <= serial on machines with >= 4 cores
//!   * parallel artifacts byte-identical to serial (content fingerprints)
//!   * warm re-run resolves 100% from cache, executing zero jobs
//!   * disabled tracing is free: 1M no-op spans cost <= 1% of the serial
//!     grid wall-clock

use sfp::formats::Container;
use sfp::lab::{self, JobGraph, JobSpec, JobStatus, ResultCache, StashSpec};
use sfp::report::footprint::STREAM_SEED;
use sfp::stash::CodecKind;
use std::time::Instant;

fn smoke_2x2x2() -> JobGraph {
    let mut g = JobGraph::new();
    let mut runs = Vec::new();
    for model in ["resnet18", "mobilenet"] {
        for codec in [CodecKind::Gecko, CodecKind::Js] {
            for budget in [0usize, 256 * 1024] {
                runs.push(g.push(
                    JobSpec::StashRun(StashSpec {
                        model: model.into(),
                        policy: "qm".into(),
                        codec,
                        container: Container::Bf16,
                        batch: 128,
                        budget_bytes: budget,
                        sample: 8 * 1024,
                        seed: STREAM_SEED,
                        threads: 0,
                        layout: String::new(),
                    }),
                    vec![],
                ));
            }
        }
    }
    g.push(JobSpec::StashSummary, runs);
    g
}

fn fresh_cache(name: &str) -> ResultCache {
    let dir = std::env::temp_dir().join(format!("sfp_lab_bench_{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    ResultCache::open(&dir).expect("open bench cache")
}

fn main() {
    let graph = smoke_2x2x2();
    println!("== bench group: lab ==");
    println!("grid: {} jobs (2 models x 2 codecs x 2 budgets + summary)", graph.len());

    let cache_serial = fresh_cache("serial");
    let t0 = Instant::now();
    let serial = lab::run_serial(&graph, &cache_serial);
    let serial_ms = t0.elapsed().as_secs_f64() * 1e3;

    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4);
    let cache_parallel = fresh_cache("parallel");
    let t0 = Instant::now();
    let parallel = lab::run_parallel(&graph, &cache_parallel, threads);
    let parallel_ms = t0.elapsed().as_secs_f64() * 1e3;

    let t0 = Instant::now();
    let warm = lab::run_parallel(&graph, &cache_parallel, threads);
    let warm_ms = t0.elapsed().as_secs_f64() * 1e3;

    // per-job timings, surfaced in the manifest as in every lab run
    let manifest = std::env::temp_dir().join(format!(
        "sfp_lab_bench_manifest_{}.json",
        std::process::id()
    ));
    lab::write_manifest(&manifest, &parallel, parallel_ms, "parallel").expect("manifest");
    for r in &parallel {
        println!("lab/{}: {:>8.1} ms ({:?})", r.label, r.wall_ms, r.status);
    }
    println!(
        "lab/serial: {serial_ms:.1} ms  lab/parallel_{threads}_threads: {parallel_ms:.1} ms \
         ({:.2}x)  lab/warm_cache: {warm_ms:.1} ms",
        serial_ms / parallel_ms.max(1e-9),
    );
    println!("manifest (per-job timings) -> {}", manifest.display());

    let mut failed = false;

    // every job healthy in both modes
    if !serial.iter().all(|r| r.ok()) || !parallel.iter().all(|r| r.ok()) {
        eprintln!("FAIL: lab jobs failed in the bench grid");
        failed = true;
    }

    // parallel artifacts byte-identical to serial (content fingerprints)
    for (s, p) in serial.iter().zip(&parallel) {
        if s.hash != p.hash || s.artifacts != p.artifacts {
            eprintln!(
                "FAIL: artifact divergence between serial and parallel for {}",
                s.label
            );
            failed = true;
        }
    }

    // warm re-run must be pure cache hits, executing zero jobs
    if !warm.iter().all(|r| r.status == JobStatus::Cached) {
        eprintln!("FAIL: warm re-run executed jobs instead of hitting the cache");
        failed = true;
    }

    // observability off must be observability free: a disabled span is
    // one relaxed atomic load and no allocation, so even a million of
    // them (far beyond any real grid) must vanish against the serial
    // wall-clock
    assert!(!sfp::obs::enabled(), "bench runs with tracing disabled");
    const SPAN_ITERS: u64 = 2_000_000;
    let t0 = Instant::now();
    for i in 0..SPAN_ITERS {
        let sp = sfp::obs::span("bench", "noop");
        std::hint::black_box(&sp);
        std::hint::black_box(i);
    }
    let ns_per_span = t0.elapsed().as_nanos() as f64 / SPAN_ITERS as f64;
    let per_million_ms = ns_per_span * 1_000_000.0 / 1e6;
    println!(
        "lab/disabled_span: {ns_per_span:.1} ns/span ({per_million_ms:.2} ms per 1M spans \
         vs serial {serial_ms:.1} ms)"
    );
    if per_million_ms > 0.01 * serial_ms {
        eprintln!(
            "FAIL: disabled-span overhead {per_million_ms:.2} ms per 1M spans exceeds 1% of \
             serial grid wall-clock {serial_ms:.1} ms"
        );
        failed = true;
    }

    // the point of the subsystem: the parallel grid must not be slower
    // than the serial loop it replaced (skip on machines too narrow to
    // possibly show a win; gate leaves no fudge — with >= 4 workers the
    // expected margin is >= 2x)
    if threads >= 4 && parallel_ms > serial_ms {
        eprintln!(
            "FAIL: parallel grid wall-clock {parallel_ms:.1} ms exceeds serial {serial_ms:.1} ms"
        );
        failed = true;
    }

    if failed {
        std::process::exit(1);
    }
}
