//! Stash subsystem benches: worker-pool encode scaling vs a single
//! thread (acceptance gate: the pool must sustain >= 2x single-thread
//! encode throughput), zero-copy decode vs the materialized restore
//! baseline (acceptance gate: zero-copy must win), parallel restore, and
//! arena store/load overhead.

use sfp::formats::Container;
use sfp::gecko::SegReader;
use sfp::serve::StashService;
use sfp::stash::{
    ChunkArena, ChunkSeq, CodecKind, ContainerMeta, EncodedStreams, GeckoStashCodec,
    RawStashCodec, Stash, StashCodec, StashConfig, TensorId, CHUNK_BYTES,
};
use sfp::traces::ValueModel;
use sfp::util::bench::{black_box, Bench};
use std::time::Instant;

/// One training step's worth of stash traffic: `tensors` tensors of
/// `vals_per_tensor` trained-like activation values.
fn workload(tensors: usize, vals_per_tensor: usize) -> Vec<Vec<f32>> {
    (0..tensors)
        .map(|i| ValueModel::relu_act().sample_values(vals_per_tensor, i as u64, true))
        .collect()
}

fn main() {
    let tensors = 32;
    let vals_per_tensor = 64 * 1024;
    let total = (tensors * vals_per_tensor) as f64;
    let data = workload(tensors, vals_per_tensor);
    let meta = ContainerMeta::new(Container::Bf16, 3).with_sign_elision(true);

    // --- encode scaling: direct single-thread codec vs the pool ---------
    // The pool path hands each tensor an owned copy (put takes Vec<f32>,
    // as the trainer does); clone in the baseline too so the comparison
    // is like-for-like.
    let b = Bench::new("stash_encode").with_epochs(5);
    let r_single = b.run("single_thread", total, || {
        for vals in &data {
            let owned = vals.clone();
            black_box(GeckoStashCodec.encode(black_box(&owned), &meta));
        }
    });

    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4);
    let pool_stash = Stash::new(StashConfig {
        codec: CodecKind::Gecko,
        threads,
        queue_depth: 2 * threads,
        chunk_values: 16 * 1024,
        budget_bytes: 0,
    });
    let r_pool = b.run(&format!("pool_{threads}_threads"), total, || {
        for (i, vals) in data.iter().enumerate() {
            pool_stash.put(TensorId::act(i), vals.clone(), meta);
        }
        pool_stash.flush();
        for i in 0..data.len() {
            pool_stash.discard(TensorId::act(i));
        }
    });
    let speedup = r_single.median_ns / r_pool.median_ns;
    println!(
        "pool_speedup: {speedup:.2}x over single thread with {threads} workers (target >= 2x)"
    );
    // Acceptance gate: with >= 4 workers the pool must sustain >= 2x the
    // single-thread encode throughput.  Fail the bench run (CI executes
    // it) instead of warning into the void; skip the gate on machines too
    // narrow to possibly meet it, and gate on best-observed epochs (min)
    // so shared-runner noise can't flake a healthy pool.
    let gate_failed = threads >= 4 && r_single.min_ns / r_pool.min_ns < 2.0;

    // --- zero-copy decode vs the materialized restore baseline ----------
    // The pre-refactor restore copied every stream out of the arena as a
    // fresh Vec<u64> before decoding; the zero-copy path pins the chunks
    // and decodes them in place.  Gate on the raw-FP32 codec, where the
    // copied bytes are largest relative to decode work (the advantage is
    // structural, so the gate is noise-tolerant); gecko is reported
    // alongside ungated.
    let arena = ChunkArena::new();
    let raw_meta = ContainerMeta::new(Container::Fp32, 23);
    let big = ValueModel::weights().sample_values(1 << 20, 99, false);
    let raw_enc = RawStashCodec.encode(&big, &raw_meta);
    let raw_seqs: Vec<ChunkSeq> = raw_enc
        .streams
        .iter()
        .map(|(w, bits)| arena.store(w, *bits))
        .collect();
    let b = Bench::new("stash_decode").with_epochs(7);
    let r_mat = b.run("materialized_raw", big.len() as f64, || {
        let streams: Vec<(Vec<u64>, usize)> = raw_seqs
            .iter()
            .map(|s| (arena.load(s), s.len_bits))
            .collect();
        let enc = EncodedStreams {
            count: raw_enc.count,
            streams,
            bits: raw_enc.bits,
        };
        black_box(RawStashCodec.decode(&enc, &raw_meta));
    });
    let r_zc = b.run("zero_copy_raw", big.len() as f64, || {
        let pins: Vec<_> = raw_seqs.iter().map(|s| arena.pin(s)).collect();
        let segs: Vec<Vec<&[u64]>> = pins.iter().map(|p| p.segs()).collect();
        let mut readers: Vec<SegReader> = segs
            .iter()
            .zip(&pins)
            .map(|(s, p)| SegReader::new(s, p.len_bits))
            .collect();
        black_box(RawStashCodec.decode_view(raw_enc.count, &mut readers, &raw_meta));
    });
    let decode_speedup = r_mat.min_ns / r_zc.min_ns;
    println!(
        "decode_zero_copy_speedup: {decode_speedup:.2}x over the materialized baseline (gate: >= 1x)"
    );
    let decode_gate_failed = decode_speedup < 1.0;

    let gecko_enc = GeckoStashCodec.encode_chunked(&data[0], &meta, 16 * 1024);
    let gecko_seqs: Vec<ChunkSeq> = gecko_enc
        .streams
        .iter()
        .map(|(w, bits)| arena.store(w, *bits))
        .collect();
    b.run("materialized_gecko", vals_per_tensor as f64, || {
        let streams: Vec<(Vec<u64>, usize)> = gecko_seqs
            .iter()
            .map(|s| (arena.load(s), s.len_bits))
            .collect();
        let enc = EncodedStreams {
            count: gecko_enc.count,
            streams,
            bits: gecko_enc.bits,
        };
        black_box(GeckoStashCodec.decode(&enc, &meta));
    });
    b.run("zero_copy_gecko", vals_per_tensor as f64, || {
        let pins: Vec<_> = gecko_seqs.iter().map(|s| arena.pin(s)).collect();
        let segs: Vec<Vec<&[u64]>> = pins.iter().map(|p| p.segs()).collect();
        let mut readers: Vec<SegReader> = segs
            .iter()
            .zip(&pins)
            .map(|(s, p)| SegReader::new(s, p.len_bits))
            .collect();
        black_box(GeckoStashCodec.decode_view(gecko_enc.count, &mut readers, &meta));
    });

    // --- full round-trip: put + flush + parallel take -------------------
    let b = Bench::new("stash_roundtrip").with_epochs(5);
    let stash = Stash::new(StashConfig {
        codec: CodecKind::Gecko,
        threads,
        queue_depth: 2 * threads,
        chunk_values: 16 * 1024,
        budget_bytes: 0,
    });
    let ids: Vec<TensorId> = (0..data.len()).map(TensorId::act).collect();
    b.run("put_flush_take_all", total, || {
        for (i, vals) in data.iter().enumerate() {
            stash.put(TensorId::act(i), vals.clone(), meta);
        }
        stash.flush();
        black_box(stash.take_all(&ids));
    });

    // --- chunked encode overhead vs one-shot ----------------------------
    let b = Bench::new("stash_codec").with_epochs(5);
    let one = &data[0];
    b.run("encode_one_shot", vals_per_tensor as f64, || {
        black_box(GeckoStashCodec.encode(black_box(one), &meta));
    });
    b.run("encode_chunked_4k", vals_per_tensor as f64, || {
        black_box(GeckoStashCodec.encode_chunked(black_box(one), &meta, 4096));
    });
    let enc = GeckoStashCodec.encode(one, &meta);
    b.run("decode", vals_per_tensor as f64, || {
        black_box(GeckoStashCodec.decode(black_box(&enc), &meta));
    });

    // --- steady-state arena reuse: allocation must plateau --------------
    let stash = Stash::new(StashConfig {
        codec: CodecKind::Gecko,
        threads,
        queue_depth: 2 * threads,
        chunk_values: 16 * 1024,
        budget_bytes: 0,
    });
    let t0 = Instant::now();
    let steps = 20;
    let mut allocated_after_first = 0;
    for step in 0..steps {
        for (i, vals) in data.iter().enumerate() {
            stash.put(TensorId::act(i), vals.clone(), meta);
        }
        stash.flush();
        for i in 0..data.len() {
            stash.discard(TensorId::act(i));
        }
        if step == 0 {
            allocated_after_first = stash.arena_allocated_bytes();
        }
    }
    println!(
        "arena_steady_state: {:.2} MB allocated after step 1, {:.2} MB after {steps} steps ({:.1} steps/s)",
        allocated_after_first as f64 / 1e6,
        stash.arena_allocated_bytes() as f64 / 1e6,
        steps as f64 / t0.elapsed().as_secs_f64(),
    );

    // --- multi-tenant serve: leased facades over one shared arena -------
    // Print-only (no gate): the same round-trip when two leases split a
    // budgeted service — evictions and spill faults on purpose — next to
    // the unlimited single-tenant numbers above, plus the per-tenant
    // counters `repro serve` reports.
    let service = StashService::new(8 * CHUNK_BYTES, None);
    let leases = [
        service.lease("bench-a", 4 * CHUNK_BYTES, 0).expect("lease a"),
        service.lease("bench-b", 4 * CHUNK_BYTES, 0).expect("lease b"),
    ];
    let serve_cfg = StashConfig {
        codec: CodecKind::Gecko,
        threads,
        queue_depth: 2 * threads,
        chunk_values: 16 * 1024,
        budget_bytes: 0,
    };
    let tenants: Vec<Stash> = leases.iter().map(|l| l.open(serve_cfg)).collect();
    let b = Bench::new("stash_serve").with_epochs(3);
    b.run("two_leases_shared_arena", 2.0 * total, || {
        for stash in &tenants {
            for (i, vals) in data.iter().enumerate() {
                stash.put(TensorId::act(i), vals.clone(), meta);
            }
            stash.flush();
        }
        for stash in &tenants {
            black_box(stash.take_all(&ids));
        }
    });
    for lease in &leases {
        let st = lease.stats();
        println!(
            "serve_lease {}: {} evictions, {} spill faults under a {} KiB budget",
            lease.label(),
            st.evictions,
            st.faults,
            lease.budget_bytes() / 1024,
        );
    }

    if gate_failed {
        eprintln!("FAIL: pool encode speedup below the 2x acceptance gate");
    }
    if decode_gate_failed {
        eprintln!("FAIL: zero-copy decode slower than the materialized restore baseline");
    }
    if gate_failed || decode_gate_failed {
        std::process::exit(1);
    }
}
