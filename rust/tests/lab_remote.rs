//! Process-backend integration tests: real `repro worker` subprocesses
//! (the binary Cargo built for this test run) executing lab jobs over the
//! shared content-addressed cache.
//!
//! Covers the PR's acceptance criteria end-to-end:
//!   * artifacts from the process backend are byte-identical to the
//!     in-process serial reference (fingerprint comparison per job);
//!   * a worker subprocess killed mid-job (abort probe) poisons exactly
//!     its dependent cone — the run completes, the failure is recorded,
//!     the cache holds no partial entry for the killed job, and a re-run
//!     attempts only the poisoned cone while siblings resolve cached;
//!   * a panicking job body fails gracefully inside the worker (the
//!     subprocess survives and keeps serving).

use sfp::formats::Container;
use sfp::lab::{
    run_serial, run_with_backend, JobGraph, JobSpec, JobStatus, ProcessBackend, ResultCache,
    StashSpec,
};
use sfp::stash::CodecKind;
use std::path::PathBuf;

/// The `repro` binary Cargo built alongside this test.
fn worker_program() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_repro"))
}

fn tdir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("sfp_lab_remote_{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn tiny_stash(codec: CodecKind) -> JobSpec {
    JobSpec::StashRun(StashSpec {
        model: "resnet18".into(),
        policy: "qm".into(),
        codec,
        container: Container::Bf16,
        batch: 64,
        budget_bytes: 0,
        sample: 1024,
        seed: 0x5EED,
        threads: 0,
        layout: String::new(),
    })
}

fn probe(mode: &str, payload: usize) -> JobSpec {
    JobSpec::Probe {
        mode: mode.into(),
        payload,
    }
}

#[test]
fn process_backend_matches_serial_fingerprints_and_warm_runs_cached() {
    let mut g = JobGraph::new();
    let a = g.push(tiny_stash(CodecKind::Gecko), vec![]);
    let b = g.push(tiny_stash(CodecKind::Raw), vec![]);
    g.push(JobSpec::StashSummary, vec![a, b]);
    g.push(probe("ok", 7), vec![]);

    let cache_serial = ResultCache::open(&tdir("ref")).unwrap();
    let serial = run_serial(&g, &cache_serial);
    assert!(serial.iter().all(|r| r.status == JobStatus::Executed));

    let cache_proc = ResultCache::open(&tdir("proc")).unwrap();
    let backend = ProcessBackend::new(cache_proc.root(), 2, Some(worker_program())).unwrap();
    let proc = run_with_backend(&g, &cache_proc, 2, &backend);
    assert!(
        proc.iter().all(|r| r.status == JobStatus::Executed),
        "{proc:?}"
    );

    // the remote-execution guarantee: same hashes, byte-identical artifacts
    for (s, p) in serial.iter().zip(&proc) {
        assert_eq!(s.hash, p.hash, "{}", s.label);
        assert_eq!(
            s.artifacts, p.artifacts,
            "artifact fingerprints must not depend on the backend ({})",
            s.label
        );
        assert!(!p.artifacts.is_empty(), "{}", p.label);
    }

    // warm re-run: everything resolves orchestrator-side from the cache
    let backend = ProcessBackend::new(cache_proc.root(), 2, Some(worker_program())).unwrap();
    let warm = run_with_backend(&g, &cache_proc, 2, &backend);
    assert!(warm.iter().all(|r| r.status == JobStatus::Cached), "{warm:?}");
}

#[test]
fn killed_worker_poisons_exactly_its_cone() {
    let root = tdir("kill");
    let mut g = JobGraph::new();
    // the abort probe takes the whole worker subprocess down mid-job
    let killed = g.push(probe("abort", 1), vec![]);
    let downstream = g.push(probe("ok", 2), vec![killed]);
    let sib1 = g.push(tiny_stash(CodecKind::Gecko), vec![]);
    let sib2 = g.push(probe("ok", 3), vec![]);

    let hashes = g.hashes();
    let cache = ResultCache::open(&root).unwrap();
    let backend = ProcessBackend::new(cache.root(), 2, Some(worker_program())).unwrap();
    let reports = run_with_backend(&g, &cache, 2, &backend);

    // the run completed and recorded the worker death against the one job
    match &reports[killed].status {
        JobStatus::Failed(e) => assert!(
            e.contains("died mid-job"),
            "failure names the worker death: {e}"
        ),
        other => panic!("killed job must fail, got {other:?}"),
    }
    assert_eq!(reports[downstream].status, JobStatus::Skipped);
    assert_eq!(reports[sib1].status, JobStatus::Executed, "{reports:?}");
    assert_eq!(reports[sib2].status, JobStatus::Executed, "{reports:?}");

    // no partial committed entry for the killed job (only staging can leak,
    // and only until the next cache open sweeps the dead worker's pid)
    assert!(!root.join(format!("probe-{}", hashes[killed])).exists());
    drop(backend);

    // re-open (sweeps the dead worker's orphaned staging) and re-run: only
    // the poisoned cone is attempted, siblings come straight from cache
    let cache = ResultCache::open(&root).unwrap();
    for entry in std::fs::read_dir(&root).unwrap().flatten() {
        let name = entry.file_name().to_string_lossy().into_owned();
        assert!(
            !name.starts_with(".tmp-"),
            "orphaned staging of the killed worker must be swept, found {name}"
        );
    }
    let backend = ProcessBackend::new(cache.root(), 2, Some(worker_program())).unwrap();
    let rerun = run_with_backend(&g, &cache, 2, &backend);
    assert!(matches!(rerun[killed].status, JobStatus::Failed(_)));
    assert_eq!(rerun[downstream].status, JobStatus::Skipped);
    assert_eq!(rerun[sib1].status, JobStatus::Cached);
    assert_eq!(rerun[sib2].status, JobStatus::Cached);
}

#[test]
fn panicking_job_fails_inside_a_surviving_worker() {
    let cache = ResultCache::open(&tdir("panic")).unwrap();
    let mut g = JobGraph::new();
    let boom = g.push(probe("panic", 1), vec![]);
    // chained after the panic on the same single worker: only a surviving
    // subprocess can execute them
    let after1 = g.push(probe("ok", 2), vec![]);
    let after2 = g.push(probe("ok", 3), vec![]);

    let backend = ProcessBackend::new(cache.root(), 1, Some(worker_program())).unwrap();
    let reports = run_with_backend(&g, &cache, 1, &backend);
    match &reports[boom].status {
        JobStatus::Failed(e) => assert!(e.contains("panicked"), "{e}"),
        other => panic!("panicking job must fail, got {other:?}"),
    }
    assert_eq!(reports[after1].status, JobStatus::Executed);
    assert_eq!(reports[after2].status, JobStatus::Executed);
}
