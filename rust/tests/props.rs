//! Property-based invariants across the numeric-format stack (in-tree
//! `util::prop` harness — proptest is unavailable offline).

use sfp::baselines::{self, ActKind};
use sfp::coordinator::BitChop;
use sfp::formats::{quantize, truncate_mantissa, Container};
use sfp::gecko::{self, Mode};
use sfp::sfp::{sfp_bits, SfpCodec};
use sfp::stats::EncodedWidthCdf;
use sfp::util::prop::{check, Gen};

fn arbitrary_vals(g: &mut Gen) -> Vec<f32> {
    let len = g.usize_in(1, 2000);
    // mix: fully arbitrary finite floats, trained-like, and zero-heavy
    match g.u32_in(0, 2) {
        0 => g.vec_f32(len, |g| g.finite_f32()),
        1 => g.vec_f32(len, |g| g.gaussian_f32(3.0)),
        _ => g.vec_f32(len, |g| {
            if g.bool() {
                0.0
            } else {
                g.gaussian_f32(0.1)
            }
        }),
    }
}

#[test]
fn prop_gecko_delta_roundtrip() {
    check("gecko delta encode∘decode = id", 200, |g| {
        let vals = arbitrary_vals(g);
        let exps = gecko::exponents(&vals);
        let enc = gecko::encode(&exps, Mode::Delta);
        assert_eq!(gecko::decode(&enc, Mode::Delta), exps);
    });
}

#[test]
fn prop_gecko_fixed_roundtrip() {
    check("gecko fixed encode∘decode = id", 200, |g| {
        let vals = arbitrary_vals(g);
        let exps = gecko::exponents(&vals);
        let mode = Mode::FixedBias {
            bias: g.u32_in(0, 255) as u8,
            group: g.usize_in(1, 32),
        };
        let enc = gecko::encode(&exps, mode);
        assert_eq!(gecko::decode(&enc, mode), exps);
    });
}

#[test]
fn prop_gecko_size_accounting_exact() {
    check("encoded_bits == materialized size", 150, |g| {
        let vals = arbitrary_vals(g);
        let exps = gecko::exponents(&vals);
        for mode in [Mode::Delta, Mode::FixedBias { bias: 127, group: 8 }] {
            assert_eq!(gecko::encoded_bits(&exps, mode), gecko::encode(&exps, mode).total_bits());
        }
    });
}

#[test]
fn prop_sfp_roundtrip_is_truncation() {
    check("sfp decompress∘compress = truncate", 120, |g| {
        let vals = arbitrary_vals(g);
        let n = g.u32_in(0, 23);
        let container = if g.bool() { Container::Fp32 } else { Container::Bf16 };
        let elide = g.bool();
        let signed_ok = !elide || vals.iter().all(|v| v.to_bits() >> 31 == 0);
        let vals: Vec<f32> = if elide && !signed_ok {
            vals.iter().map(|v| f32::from_bits(v.to_bits() & 0x7FFF_FFFF)).collect()
        } else {
            vals
        };
        let codec = SfpCodec::new(container, elide);
        let c = codec.compress(&vals, n);
        let back = codec.decompress(&c);
        assert_eq!(back.len(), vals.len());
        for (&v, &b) in vals.iter().zip(&back) {
            assert_eq!(quantize(v, n, container).to_bits(), b.to_bits());
        }
    });
}

#[test]
fn prop_sfp_bits_matches_compressor() {
    check("sfp_bits == compressor total", 100, |g| {
        let vals = arbitrary_vals(g);
        let n = g.u32_in(0, 23);
        let elide = g.bool();
        let codec = SfpCodec::new(Container::Fp32, elide);
        assert_eq!(
            sfp_bits(&vals, n, Container::Fp32, elide),
            codec.compress(&vals, n).total_bits()
        );
    });
}

#[test]
fn prop_truncation_error_bounded() {
    check("|x - Q(x,n)| < 2^(e-n)", 200, |g| {
        let x = g.gaussian_f32(100.0);
        if x == 0.0 {
            return;
        }
        let n = g.u32_in(0, 23);
        let q = truncate_mantissa(x, n);
        let e = x.abs().log2().floor();
        assert!((x - q).abs() <= 2f32.powf(e - n as f32) * (1.0 + 1e-6));
        // truncation moves toward zero, never away
        assert!(q.abs() <= x.abs());
        assert!(q == 0.0 || q.signum() == x.signum());
    });
}

#[test]
fn prop_quantize_idempotent_and_monotone_bits() {
    check("Q(Q(x,n),n) = Q(x,n); bits(n+1) refines", 200, |g| {
        let x = g.finite_f32();
        let n = g.u32_in(0, 22);
        let q1 = truncate_mantissa(x, n);
        assert_eq!(truncate_mantissa(q1, n).to_bits(), q1.to_bits());
        // coarser quantization of a finer one equals direct coarse quant
        let fine = truncate_mantissa(x, n + 1);
        assert_eq!(truncate_mantissa(fine, n).to_bits(), q1.to_bits());
    });
}

#[test]
fn prop_bitchop_bounded() {
    check("bitchop stays in [0, n_max]", 60, |g| {
        let n_max = g.u32_in(1, 23);
        let mut bc = BitChop::new(n_max);
        for _ in 0..300 {
            let loss = g.f64_unit() * 10.0;
            let b = bc.observe(loss);
            assert!(b <= n_max);
        }
    });
}

#[test]
fn prop_width_cdf_masses_sum_to_one() {
    check("cdf(8) == 1 and monotone", 100, |g| {
        let vals = arbitrary_vals(g);
        let mut c = EncodedWidthCdf::new();
        c.add_vals(&vals);
        let mut prev = 0.0;
        for b in 0..=8 {
            let v = c.cdf_at(b);
            assert!(v >= prev - 1e-12);
            prev = v;
        }
        assert!((c.cdf_at(8) - 1.0).abs() < 1e-12);
    });
}

#[test]
fn prop_baselines_sane() {
    check("baseline footprints are ordered sanely", 100, |g| {
        let count = g.usize_in(1, 1_000_000);
        let zf = g.f64_unit();
        let dense = baselines::dense_bits(count, Container::Bf16);
        let js = baselines::js_bits(count, zf, Container::Bf16);
        for kind in [ActKind::ReluPool, ActKind::ReluConv, ActKind::Dense] {
            let gist = baselines::gist_pp_bits(count, zf, kind, Container::Bf16);
            assert!(gist <= dense, "GIST++ never inflates");
        }
        // JS can inflate but by at most the tag bits
        assert!(js <= dense + count);
    });
}

#[test]
fn prop_footprint_additivity() {
    check("component ledger adds linearly", 100, |g| {
        use sfp::stats::{ComponentBits, Footprint};
        let mk = |g: &mut Gen| ComponentBits {
            sign: g.f64_unit() * 1e6,
            exponent: g.f64_unit() * 1e6,
            mantissa: g.f64_unit() * 1e6,
            metadata: g.f64_unit() * 1e6,
        };
        let a = mk(g);
        let b = mk(g);
        let mut f = Footprint::default();
        f.activations.add(a);
        f.activations.add(b);
        assert!((f.total() - (a.total() + b.total())).abs() < 1e-6);
    });
}

#[test]
fn prop_hwsim_monotone_in_traffic() {
    check("less traffic => no more time/energy", 40, |g| {
        use sfp::hwsim::{simulate_pass, AccelConfig, ComputeType, LayerBits};
        use sfp::traces::resnet18;
        let cfg = AccelConfig::default();
        let net = resnet18();
        let w1 = 8.0 + g.f64_unit() * 24.0;
        let w2 = g.f64_unit() * w1; // strictly less
        let batch = g.usize_in(16, 512);
        let mk = |word: f64| {
            move |l: &sfp::traces::LayerTrace| LayerBits {
                weight: l.weight_elems as f64 * word,
                act: l.act_elems as f64 * word * batch as f64,
            }
        };
        let hi = simulate_pass(&cfg, &net, batch, ComputeType::Fp32, &mk(w1));
        let lo = simulate_pass(&cfg, &net, batch, ComputeType::Fp32, &mk(w2));
        assert!(lo.time_s <= hi.time_s * (1.0 + 1e-9));
        assert!(lo.energy_j <= hi.energy_j * (1.0 + 1e-9));
    });
}
