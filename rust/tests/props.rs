//! Property-based invariants across the numeric-format stack (in-tree
//! `util::prop` harness — proptest is unavailable offline).

use sfp::baselines::{self, ActKind};
use sfp::coordinator::BitChop;
use sfp::formats::{quantize, truncate_mantissa, Container, ExponentLayout};
use sfp::gecko::{self, Mode};
use sfp::policy::sweep::{build_policy, PolicyKind, SweepConfig};
use sfp::policy::StepSignals;
use sfp::stats::ExpRangeStats;
use sfp::sfp::{sfp_bits, SfpCodec};
use sfp::stash::{
    CodecKind, ContainerMeta, GeckoStashCodec, JsStashCodec, RawStashCodec, SfpStashCodec, Stash,
    StashCodec, StashConfig, TensorId,
};
use sfp::stats::EncodedWidthCdf;
use sfp::util::prop::{check, Gen};

fn arbitrary_vals(g: &mut Gen) -> Vec<f32> {
    let len = g.usize_in(1, 2000);
    // mix: fully arbitrary finite floats, trained-like, and zero-heavy
    match g.u32_in(0, 2) {
        0 => g.vec_f32(len, |g| g.finite_f32()),
        1 => g.vec_f32(len, |g| g.gaussian_f32(3.0)),
        _ => g.vec_f32(len, |g| {
            if g.bool() {
                0.0
            } else {
                g.gaussian_f32(0.1)
            }
        }),
    }
}

#[test]
fn prop_gecko_delta_roundtrip() {
    check("gecko delta encode∘decode = id", 200, |g| {
        let vals = arbitrary_vals(g);
        let exps = gecko::exponents(&vals);
        let enc = gecko::encode(&exps, Mode::Delta);
        assert_eq!(gecko::decode(&enc, Mode::Delta), exps);
    });
}

#[test]
fn prop_gecko_fixed_roundtrip() {
    check("gecko fixed encode∘decode = id", 200, |g| {
        let vals = arbitrary_vals(g);
        let exps = gecko::exponents(&vals);
        let mode = Mode::FixedBias {
            bias: g.u32_in(0, 255) as u8,
            group: g.usize_in(1, 32),
        };
        let enc = gecko::encode(&exps, mode);
        assert_eq!(gecko::decode(&enc, mode), exps);
    });
}

#[test]
fn prop_gecko_size_accounting_exact() {
    check("encoded_bits == materialized size", 150, |g| {
        let vals = arbitrary_vals(g);
        let exps = gecko::exponents(&vals);
        for mode in [Mode::Delta, Mode::FixedBias { bias: 127, group: 8 }] {
            assert_eq!(gecko::encoded_bits(&exps, mode), gecko::encode(&exps, mode).total_bits());
        }
    });
}

#[test]
fn prop_sfp_roundtrip_is_truncation() {
    check("sfp decompress∘compress = truncate", 120, |g| {
        let vals = arbitrary_vals(g);
        let n = g.u32_in(0, 23);
        let container = if g.bool() { Container::Fp32 } else { Container::Bf16 };
        let elide = g.bool();
        let signed_ok = !elide || vals.iter().all(|v| v.to_bits() >> 31 == 0);
        let vals: Vec<f32> = if elide && !signed_ok {
            vals.iter().map(|v| f32::from_bits(v.to_bits() & 0x7FFF_FFFF)).collect()
        } else {
            vals
        };
        let codec = SfpCodec::new(container, elide);
        let c = codec.compress(&vals, n);
        let back = codec.decompress(&c);
        assert_eq!(back.len(), vals.len());
        for (&v, &b) in vals.iter().zip(&back) {
            assert_eq!(quantize(v, n, container).to_bits(), b.to_bits());
        }
    });
}

#[test]
fn prop_sfp_bits_matches_compressor() {
    check("sfp_bits == compressor total", 100, |g| {
        let vals = arbitrary_vals(g);
        let n = g.u32_in(0, 23);
        let elide = g.bool();
        let codec = SfpCodec::new(Container::Fp32, elide);
        assert_eq!(
            sfp_bits(&vals, n, Container::Fp32, elide),
            codec.compress(&vals, n).total_bits()
        );
    });
}

#[test]
fn prop_truncation_error_bounded() {
    check("|x - Q(x,n)| < 2^(e-n)", 200, |g| {
        let x = g.gaussian_f32(100.0);
        if x == 0.0 {
            return;
        }
        let n = g.u32_in(0, 23);
        let q = truncate_mantissa(x, n);
        let e = x.abs().log2().floor();
        assert!((x - q).abs() <= 2f32.powf(e - n as f32) * (1.0 + 1e-6));
        // truncation moves toward zero, never away
        assert!(q.abs() <= x.abs());
        assert!(q == 0.0 || q.signum() == x.signum());
    });
}

#[test]
fn prop_quantize_idempotent_and_monotone_bits() {
    check("Q(Q(x,n),n) = Q(x,n); bits(n+1) refines", 200, |g| {
        let x = g.finite_f32();
        let n = g.u32_in(0, 22);
        let q1 = truncate_mantissa(x, n);
        assert_eq!(truncate_mantissa(q1, n).to_bits(), q1.to_bits());
        // coarser quantization of a finer one equals direct coarse quant
        let fine = truncate_mantissa(x, n + 1);
        assert_eq!(truncate_mantissa(fine, n).to_bits(), q1.to_bits());
    });
}

#[test]
fn prop_bitchop_bounded() {
    check("bitchop stays in [0, n_max]", 60, |g| {
        let n_max = g.u32_in(1, 23);
        let mut bc = BitChop::new(n_max);
        for _ in 0..300 {
            let loss = g.f64_unit() * 10.0;
            let b = bc.observe(loss);
            assert!(b <= n_max);
        }
    });
}

#[test]
fn prop_width_cdf_masses_sum_to_one() {
    check("cdf(8) == 1 and monotone", 100, |g| {
        let vals = arbitrary_vals(g);
        let mut c = EncodedWidthCdf::new();
        c.add_vals(&vals);
        let mut prev = 0.0;
        for b in 0..=8 {
            let v = c.cdf_at(b);
            assert!(v >= prev - 1e-12);
            prev = v;
        }
        assert!((c.cdf_at(8) - 1.0).abs() < 1e-12);
    });
}

#[test]
fn prop_baselines_sane() {
    check("baseline footprints are ordered sanely", 100, |g| {
        let count = g.usize_in(1, 1_000_000);
        let zf = g.f64_unit();
        let dense = baselines::dense_bits(count, Container::Bf16);
        let js = baselines::js_bits(count, zf, Container::Bf16);
        for kind in [ActKind::ReluPool, ActKind::ReluConv, ActKind::Dense] {
            let gist = baselines::gist_pp_bits(count, zf, kind, Container::Bf16);
            assert!(gist <= dense, "GIST++ never inflates");
        }
        // JS can inflate but by at most the tag bits
        assert!(js <= dense + count);
    });
}

#[test]
fn prop_footprint_additivity() {
    check("component ledger adds linearly", 100, |g| {
        use sfp::stats::{ComponentBits, Footprint};
        let mk = |g: &mut Gen| ComponentBits {
            sign: g.f64_unit() * 1e6,
            exponent: g.f64_unit() * 1e6,
            mantissa: g.f64_unit() * 1e6,
            metadata: g.f64_unit() * 1e6,
        };
        let a = mk(g);
        let b = mk(g);
        let mut f = Footprint::default();
        f.activations.add(a);
        f.activations.add(b);
        assert!((f.total() - (a.total() + b.total())).abs() < 1e-6);
    });
}

/// Arbitrary container metadata covering both containers, every mantissa
/// length including the paper's 1-bit extreme, and both exponent modes
/// (FixedBias with small groups yields the ~3-bit exponent fields).
fn arbitrary_meta(g: &mut Gen) -> ContainerMeta {
    let container = if g.bool() { Container::Fp32 } else { Container::Bf16 };
    let mant = [0u32, 1, 2, 7, 23, g.u32_in(0, 23)][g.usize_in(0, 5)];
    let exp_mode = if g.bool() {
        Mode::Delta
    } else {
        Mode::FixedBias {
            bias: g.u32_in(0, 255) as u8,
            group: g.usize_in(1, 32),
        }
    };
    ContainerMeta::new(container, mant).with_exp_mode(exp_mode)
}

#[test]
fn prop_stash_roundtrip_bit_exact_every_codec() {
    check("stash→restore == quantize for every StashCodec", 25, |g| {
        let mut vals = arbitrary_vals(g);
        let mut meta = arbitrary_meta(g);
        if g.bool() {
            // sign elision requires a non-negative tensor
            for v in vals.iter_mut() {
                *v = f32::from_bits(v.to_bits() & 0x7FFF_FFFF);
            }
            meta = meta.with_sign_elision(true);
        }
        for kind in CodecKind::all() {
            let stash = Stash::new(StashConfig {
                codec: kind,
                threads: g.usize_in(1, 4),
                queue_depth: g.usize_in(1, 4),
                chunk_values: g.usize_in(1, 800),
                // sometimes squeeze the arena so the spill tier engages
                budget_bytes: if g.bool() { g.usize_in(1, 128) * 1024 } else { 0 },
            });
            stash.put(TensorId::act(0), vals.clone(), meta);
            stash.flush();
            let back = stash.take(TensorId::act(0)).unwrap();
            assert_eq!(back.len(), vals.len(), "{kind:?}");
            for (i, (&v, &b)) in vals.iter().zip(&back).enumerate() {
                assert_eq!(
                    meta.quantized(v).to_bits(),
                    b.to_bits(),
                    "{kind:?} i={i} mant={} mode={:?}",
                    meta.mant_bits,
                    meta.exp_mode(),
                );
            }
            assert_eq!(stash.failures(), 0, "{kind:?}");
        }
    });
}

/// Exponent layouts across every representation family, weighted toward
/// the corner cases: 1-bit windows, bias extremes (1/127/254), single-value
/// and oversized blocks (ragged tails come from the arbitrary lengths).
fn arbitrary_layout(g: &mut Gen) -> ExponentLayout {
    match g.u32_in(0, 3) {
        0 => ExponentLayout::Width { bits: g.u32_in(1, 8), mode: Mode::Delta },
        1 => ExponentLayout::Width {
            bits: g.u32_in(1, 8),
            mode: Mode::FixedBias {
                bias: g.u32_in(0, 255) as u8,
                group: g.usize_in(1, 32),
            },
        },
        2 => ExponentLayout::Bias {
            bits: g.u32_in(1, 8),
            bias: [1u8, 127, 254, g.u32_in(1, 254) as u8][g.usize_in(0, 3)],
        },
        _ => ExponentLayout::BlockShared {
            block: [1usize, 3, 16, 64][g.usize_in(0, 3)],
            bits: g.u32_in(1, 8),
        },
    }
}

#[test]
fn prop_stash_roundtrip_bit_exact_every_layout() {
    check("restore == quantized_slice for every layout × codec", 30, |g| {
        let mut vals = arbitrary_vals(g);
        let mant = [0u32, 1, 3, 7, 23][g.usize_in(0, 4)];
        let container = if g.bool() { Container::Fp32 } else { Container::Bf16 };
        let mut meta = ContainerMeta::new(container, mant).with_layout(arbitrary_layout(g));
        if g.bool() {
            // sign elision requires a non-negative tensor
            for v in vals.iter_mut() {
                *v = f32::from_bits(v.to_bits() & 0x7FFF_FFFF);
            }
            meta = meta.with_sign_elision(true);
        }
        let expect = meta.quantized_slice(&vals);
        for kind in CodecKind::all() {
            let stash = Stash::new(StashConfig {
                codec: kind,
                threads: g.usize_in(1, 4),
                queue_depth: g.usize_in(1, 4),
                chunk_values: g.usize_in(1, 800),
                // sometimes squeeze the arena so the spill tier engages
                budget_bytes: if g.bool() { g.usize_in(1, 64) * 1024 } else { 0 },
            });
            stash.put(TensorId::act(0), vals.clone(), meta);
            stash.flush();
            let back = stash.take(TensorId::act(0)).unwrap();
            assert_eq!(back.len(), expect.len(), "{kind:?} layout={:?}", meta.layout);
            let bad = expect
                .iter()
                .zip(&back)
                .position(|(e, b)| e.to_bits() != b.to_bits());
            assert!(
                bad.is_none(),
                "{kind:?} first mismatch at {bad:?} layout={:?} mant={mant}",
                meta.layout,
            );
            assert_eq!(stash.failures(), 0, "{kind:?}");
        }
    });
}

#[test]
fn prop_stash_chunked_encode_equals_one_shot() {
    check("encode_chunked == encode for any chunk size", 60, |g| {
        let vals = arbitrary_vals(g);
        let meta = arbitrary_meta(g);
        let chunk = g.usize_in(1, 3000);
        let codecs: [&dyn StashCodec; 4] =
            [&GeckoStashCodec, &SfpStashCodec, &RawStashCodec, &JsStashCodec];
        for codec in codecs {
            let one = codec.encode(&vals, &meta);
            let cat = codec.encode_chunked(&vals, &meta, chunk);
            assert_eq!(one.count, cat.count, "{} chunk={chunk}", codec.name());
            assert_eq!(one.streams, cat.streams, "{} chunk={chunk}", codec.name());
            assert!(
                (one.bits.total() - cat.bits.total()).abs() < 1e-9,
                "{} component ledger drift",
                codec.name()
            );
        }
    });
}

#[test]
fn prop_stash_ledger_conserves_bits() {
    check("ledger residency returns to zero after takes", 15, |g| {
        let stash = Stash::new(StashConfig {
            codec: [CodecKind::Gecko, CodecKind::Sfp, CodecKind::Raw, CodecKind::Js][g.usize_in(0, 3)],
            threads: g.usize_in(1, 4),
            queue_depth: 2,
            chunk_values: 512,
            budget_bytes: 0,
        });
        let k = g.usize_in(1, 6);
        for i in 0..k {
            let vals = g.vec_f32(g.usize_in(1, 1500), |g| g.gaussian_f32(2.0));
            stash.put(TensorId::weight(i), vals, ContainerMeta::new(Container::Fp32, 4));
        }
        stash.flush();
        let s = stash.ledger();
        assert_eq!(s.writes, k as u64);
        let stored: f64 = (0..k)
            .map(|i| stash.stored_bits(TensorId::weight(i)).unwrap().total())
            .sum();
        assert!((s.resident.total() - stored).abs() < 1e-9);
        assert!((s.written_bits - stored).abs() < 1e-9);
        for i in 0..k {
            stash.take(TensorId::weight(i)).unwrap();
        }
        let s = stash.ledger();
        assert!(s.resident.total().abs() < 1e-9);
        // every tensor read back exactly once
        assert!((s.read_bits - s.written_bits).abs() < 1e-9);
        assert_eq!(stash.arena_in_use_bytes(), 0);
    });
}

#[test]
fn prop_stash_restore_bit_exact_under_eviction_churn() {
    // Random DRAM budgets force spill-tier churn; interleaved puts and
    // restores across all codecs — including the 1-mantissa-bit / 0-bit
    // extremes and tight fixed-bias exponent groups — must stay bit-exact
    // whether a tensor's chunks are resident, spilled, or a mix.
    check("spill churn keeps restores bit-exact", 12, |g| {
        for kind in CodecKind::all() {
            let stash = Stash::new(StashConfig {
                codec: kind,
                threads: g.usize_in(1, 3),
                queue_depth: g.usize_in(1, 4),
                chunk_values: g.usize_in(64, 1024),
                // 1..64 KiB: from below a single chunk to a couple chunks
                budget_bytes: g.usize_in(1, 64) * 1024,
            });
            let mut live: Vec<(usize, Vec<f32>, ContainerMeta)> = Vec::new();
            let mut next_id = 0usize;
            for _round in 0..g.usize_in(2, 4) {
                for _ in 0..g.usize_in(1, 3) {
                    let mant = [0u32, 1, 1, 7][g.usize_in(0, 3)];
                    let container = if g.bool() { Container::Fp32 } else { Container::Bf16 };
                    let mut meta = ContainerMeta::new(container, mant);
                    if g.bool() {
                        meta = meta.with_exp_mode(Mode::FixedBias {
                            bias: g.u32_in(100, 140) as u8,
                            group: g.usize_in(4, 16),
                        });
                    }
                    let mut vals = g.vec_f32(g.usize_in(1, 6000), |g| g.gaussian_f32(2.0));
                    if g.bool() {
                        for v in vals.iter_mut() {
                            *v = f32::from_bits(v.to_bits() & 0x7FFF_FFFF);
                        }
                        meta = meta.with_sign_elision(true);
                    }
                    stash.put(TensorId::act(next_id), vals.clone(), meta);
                    live.push((next_id, vals, meta));
                    next_id += 1;
                }
                stash.flush();
                // restore a random subset mid-run, under budget pressure
                while !live.is_empty() && g.bool() {
                    let k = g.usize_in(0, live.len() - 1);
                    let (id, vals, meta) = live.swap_remove(k);
                    let back = stash.take(TensorId::act(id)).expect("resident");
                    assert_eq!(back.len(), vals.len(), "{kind:?}");
                    for (&v, &b) in vals.iter().zip(&back) {
                        assert_eq!(meta.quantized(v).to_bits(), b.to_bits(), "{kind:?}");
                    }
                }
            }
            for (id, vals, meta) in live {
                let back = stash.take(TensorId::act(id)).expect("resident");
                assert_eq!(back.len(), vals.len(), "{kind:?}");
                for (&v, &b) in vals.iter().zip(&back) {
                    assert_eq!(meta.quantized(v).to_bits(), b.to_bits(), "{kind:?}");
                }
            }
            assert_eq!(stash.failures(), 0, "{kind:?}");
            assert_eq!(stash.arena_in_use_bytes(), 0, "{kind:?}");
            assert_eq!(stash.arena_spill_bytes(), 0, "{kind:?}");
        }
    });
}

#[test]
fn stash_extreme_container_one_mantissa_bit() {
    // The paper's most aggressive configuration: 1 mantissa bit in a BF16
    // container with tight fixed-bias exponent groups (~3-bit delta
    // fields on trained-like streams) — still bit-exact, and far below
    // the dense BF16 footprint.
    use sfp::traces::ValueModel;
    let vals = ValueModel::relu_act().sample_values(64 * 512, 17, true);
    let meta = ContainerMeta::new(Container::Bf16, 1)
        .with_exp_mode(Mode::FixedBias { bias: 124, group: 8 })
        .with_sign_elision(true);
    let stash = Stash::new(StashConfig {
        codec: CodecKind::Gecko,
        threads: 2,
        queue_depth: 2,
        chunk_values: 4096,
        budget_bytes: 0,
    });
    stash.put(TensorId::act(0), vals.clone(), meta);
    stash.flush();
    let bits = stash.stored_bits(TensorId::act(0)).unwrap().total();
    let ratio = bits / (16.0 * vals.len() as f64);
    assert!(ratio < 0.6, "1-bit container ratio vs BF16 = {ratio}");
    let back = stash.take(TensorId::act(0)).unwrap();
    for (&v, &b) in vals.iter().zip(&back) {
        assert_eq!(meta.quantized(v).to_bits(), b.to_bits());
    }
}

#[test]
fn prop_policy_checkpoint_restore_bit_exact() {
    // Acceptance property: checkpoint → restore round-trips bit-exactly
    // (the restored policy's own checkpoint equals the original), and a
    // mid-run restore continues with identical subsequent ContainerPlans
    // under an arbitrary loss/LR-change tail.
    use sfp::traces::resnet18;
    let net = resnet18();
    let layers = net.layers.len();
    check("policy checkpoint/restore continues identically", 8, |g| {
        let cfg = SweepConfig {
            epochs: 9,
            steps_per_epoch: 10,
            batch: 8,
            container: Container::Bf16,
            sample: 512,
            seed: g.u64(),
        };
        // random-but-plausible exponent streams per layer
        let mk_stats = |g: &mut sfp::util::prop::Gen, lo: u32, hi: u32| -> Vec<ExpRangeStats> {
            (0..layers)
                .map(|_| {
                    let exps: Vec<u8> =
                        (0..512).map(|_| g.u32_in(lo, hi) as u8).collect();
                    ExpRangeStats::from_exponents(&exps)
                })
                .collect()
        };
        let act_stats = mk_stats(g, 118, 132);
        let weight_stats = mk_stats(g, 116, 126);
        let prefix = g.usize_in(1, 60);
        let tail = g.usize_in(5, 40);
        let series: Vec<(f64, bool)> = (0..prefix + tail)
            .map(|_| (g.f64_unit() * 5.0, g.f64_unit() < 0.05))
            .collect();
        for kind in PolicyKind::all() {
            let mut p1 = build_policy(kind, &net, &cfg);
            let drive = |p: &mut dyn sfp::policy::BitPolicy,
                         range: std::ops::Range<usize>| {
                let mut plans = Vec::new();
                for step in range {
                    let (loss, lr_changed) = series[step];
                    if lr_changed {
                        p.notify_lr_change();
                    }
                    plans.push(p.observe(&StepSignals {
                        epoch: step / cfg.steps_per_epoch,
                        step,
                        loss,
                        lr_changed,
                        learned_n_a: None,
                        learned_n_w: None,
                        act_stats: &act_stats,
                        weight_stats: &weight_stats,
                    }));
                }
                plans
            };
            drive(p1.as_mut(), 0..prefix);
            let ck = p1.checkpoint();
            let mut p2 = build_policy(kind, &net, &cfg);
            p2.restore(&ck).expect("restore");
            assert_eq!(ck, p2.checkpoint(), "{kind:?}: checkpoint not bit-stable");
            let a = drive(p1.as_mut(), prefix..prefix + tail);
            let b = drive(p2.as_mut(), prefix..prefix + tail);
            assert_eq!(a, b, "{kind:?}: restored policy diverged");
        }
    });
}

#[test]
fn prop_hwsim_monotone_in_traffic() {
    check("less traffic => no more time/energy", 40, |g| {
        use sfp::hwsim::{simulate_pass, AccelConfig, ComputeType, LayerBits};
        use sfp::traces::resnet18;
        let cfg = AccelConfig::default();
        let net = resnet18();
        let w1 = 8.0 + g.f64_unit() * 24.0;
        let w2 = g.f64_unit() * w1; // strictly less
        let batch = g.usize_in(16, 512);
        let mk = |word: f64| {
            move |l: &sfp::traces::LayerTrace| LayerBits {
                weight: l.weight_elems as f64 * word,
                act: l.act_elems as f64 * word * batch as f64,
            }
        };
        let hi = simulate_pass(&cfg, &net, batch, ComputeType::Fp32, &mk(w1));
        let lo = simulate_pass(&cfg, &net, batch, ComputeType::Fp32, &mk(w2));
        assert!(lo.time_s <= hi.time_s * (1.0 + 1e-9));
        assert!(lo.energy_j <= hi.energy_j * (1.0 + 1e-9));
    });
}
