//! Integration over the real PJRT runtime + AOT artifacts.  These tests
//! need `make artifacts` to have run; they skip (with a notice) otherwise
//! so `cargo test` works in a fresh checkout.

use sfp::coordinator::{TrainConfig, Trainer, Variant};
use sfp::formats::Container;
use sfp::runtime::{HostTensor, Runtime};
use std::path::Path;

// The PJRT client wraps Rc handles (not Sync), so each test thread owns
// its own runtime via thread_local; the artifact compile is ~1s.
thread_local! {
    static RT: std::cell::OnceCell<Option<Runtime>> = const { std::cell::OnceCell::new() };
}

fn with_runtime<R>(f: impl FnOnce(&Runtime) -> R) -> Option<R> {
    RT.with(|cell| {
        cell.get_or_init(|| {
            let dir = Path::new("artifacts");
            if !dir.join("manifest.json").exists() {
                eprintln!("skipping integration tests: run `make artifacts` first");
                return None;
            }
            Some(Runtime::load(dir).expect("runtime load"))
        })
        .as_ref()
        .map(f)
    })
}

fn quick_cfg(variant: Variant) -> TrainConfig {
    TrainConfig {
        variant,
        epochs: 1,
        steps_per_epoch: 3,
        eval_batches: 1,
        out_dir: None,
        ..Default::default()
    }
}

#[test]
fn loads_all_three_artifacts() {
    with_runtime(|rt| {
        for name in ["train_step", "eval_step", "forward_acts"] {
            assert!(rt.manifest.artifact(name).is_ok(), "{name}");
        }
        assert_eq!(rt.manifest.num_layers(), 7);
    });
}

#[test]
fn train_step_reduces_loss_fp32() {
    with_runtime(|rt| {
        let cfg = TrainConfig {
            epochs: 1,
            steps_per_epoch: 12,
            eval_batches: 1,
            out_dir: None,
            ..Default::default()
        };
        let mut t = Trainer::new(rt, cfg);
        let first = t.run_one_step_for_bench().unwrap();
        let mut last = first;
        for _ in 0..11 {
            last = t.run_one_step_for_bench().unwrap();
        }
        assert!(last < first, "loss {first} -> {last}");
    });
}

#[test]
fn qm_bitlengths_descend_through_pjrt() {
    with_runtime(|rt| {
        let mut cfg = quick_cfg(Variant::SfpQm(Container::Bf16));
        cfg.steps_per_epoch = 10;
        let mut t = Trainer::new(rt, cfg);
        let res = t.run().unwrap();
        let mean_a: f32 = res.final_n_a.iter().sum::<f32>() / res.final_n_a.len() as f32;
        assert!(mean_a < 7.0, "n_a should drop below the bf16 ceiling: {mean_a}");
        assert!(res.final_n_a.iter().all(|&b| (0.0..=7.0).contains(&b)));
    });
}

#[test]
fn bc_controller_engages_through_pjrt() {
    with_runtime(|rt| {
        let mut cfg = quick_cfg(Variant::SfpBc(Container::Bf16));
        cfg.steps_per_epoch = 15;
        let res = Trainer::new(rt, cfg).run().unwrap();
        assert!(res.bc_histogram.total() == 15);
        assert!(res.bc_histogram.mean() <= 7.0);
    });
}

#[test]
fn footprint_ledger_fp32_is_identity() {
    with_runtime(|rt| {
        let res = Trainer::new(rt, quick_cfg(Variant::Fp32)).run().unwrap();
        let rel = res.footprint.relative_to(&res.footprint_fp32);
        assert!((rel - 1.0).abs() < 1e-9, "{rel}");
        let bf = Trainer::new(rt, quick_cfg(Variant::Bf16)).run().unwrap();
        let rel = bf.footprint.relative_to(&bf.footprint_fp32);
        assert!((rel - 0.5).abs() < 1e-9, "{rel}");
    });
}

#[test]
fn sfp_variant_reduces_footprint_e2e() {
    with_runtime(|rt| {
        let mut cfg = quick_cfg(Variant::SfpBc(Container::Bf16));
        cfg.steps_per_epoch = 8;
        let res = Trainer::new(rt, cfg).run().unwrap();
        let rel = res.footprint.relative_to(&res.footprint_fp32);
        assert!(rel < 0.55, "SFP_BC must beat BF16's 0.5 eventually: {rel}");
    });
}

#[test]
fn eval_step_accuracy_in_range() {
    with_runtime(|rt| {
        let t = Trainer::new(rt, quick_cfg(Variant::Fp32));
        let (acc, loss) = t.evaluate().unwrap();
        assert!((0.0..=1.0).contains(&acc));
        assert!(loss.is_finite() && loss > 0.0);
    });
}

#[test]
fn forward_acts_are_quantized_and_shaped() {
    with_runtime(|rt| {
        let t = Trainer::new(rt, quick_cfg(Variant::Fp32)).into_bits_forced(2.0);
        let acts = t.dump_acts(0).unwrap();
        assert_eq!(acts.len(), rt.manifest.num_layers());
        for (a, spec) in acts.iter().zip(&rt.manifest.act_shapes) {
            assert_eq!(&a.shape, spec);
        }
        // with n=2 the low 21 mantissa bits must be zero
        let bits = acts[0].as_f32().unwrap();
        assert!(bits.iter().all(|v| v.to_bits() & ((1 << 21) - 1) == 0));
    });
}

#[test]
fn runtime_rejects_bad_inputs() {
    with_runtime(|rt| {
        let err = rt.call("train_step", &[]).unwrap_err();
        assert!(format!("{err:#}").contains("inputs"));
        let err = rt.call("nonexistent", &[]).unwrap_err();
        assert!(format!("{err:#}").contains("no executable"));
        // wrong dtype in slot 0
        let spec = &rt.manifest.artifact("eval_step").unwrap().inputs;
        let mut bad: Vec<HostTensor> = spec.iter().map(HostTensor::zeros).collect();
        bad[0] = HostTensor::i32(&spec[0].shape, vec![0; spec[0].elems()]);
        let err = rt.call("eval_step", &bad).unwrap_err();
        assert!(format!("{err:#}").contains("mismatch"));
    });
}

#[test]
fn deterministic_same_seed_same_loss() {
    with_runtime(|rt| {
        let run = || {
            let mut t = Trainer::new(rt, quick_cfg(Variant::Fp32));
            t.run_one_step_for_bench().unwrap()
        };
        assert_eq!(run(), run());
    });
}
