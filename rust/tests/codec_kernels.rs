//! Differential properties of the word-parallel codec kernels (in-tree
//! `util::prop` harness): for every codec, both containers, every
//! mantissa length including the 0/1-bit extremes, both exponent modes,
//! ragged tails, and arbitrary chunk/segment splits, [`Kernel::Word`]
//! must emit and consume streams bit-identical to the [`Kernel::Scalar`]
//! reference.  This equivalence is what keeps content hashes and lab
//! cache fingerprints kernel-independent (CI proves the same property
//! end-to-end with a scalar-populated warm cache).

use sfp::formats::{Container, ExponentLayout};
use sfp::gecko::{self, Kernel, Mode, SegReader};
use sfp::sfp::SfpCodec;
use sfp::stash::{
    ContainerMeta, GeckoStashCodec, JsStashCodec, RawStashCodec, SfpStashCodec, StashCodec,
};
use sfp::util::prop::{check, Gen};

fn codecs() -> [&'static dyn StashCodec; 4] {
    [&GeckoStashCodec, &SfpStashCodec, &RawStashCodec, &JsStashCodec]
}

/// Value streams whose lengths hug the 64-value group boundary (exact
/// multiples, one short, one over) plus fully arbitrary lengths, over
/// arbitrary-finite / trained-like / zero-heavy distributions.
fn ragged_vals(g: &mut Gen) -> Vec<f32> {
    let len = match g.u32_in(0, 4) {
        0 => g.usize_in(1, 63),
        1 => 64 * g.usize_in(1, 6),
        2 => 64 * g.usize_in(1, 6) + g.usize_in(1, 63),
        3 => g.usize_in(1, 2000),
        _ => 1,
    };
    match g.u32_in(0, 2) {
        0 => g.vec_f32(len, |g| g.finite_f32()),
        1 => g.vec_f32(len, |g| g.gaussian_f32(3.0)),
        _ => g.vec_f32(len, |g| {
            if g.bool() {
                0.0
            } else {
                g.gaussian_f32(0.1)
            }
        }),
    }
}

/// Container metadata biased toward the paper's extremes: 0- and 1-bit
/// mantissas, both containers, both exponent modes (tight fixed-bias
/// groups included).
fn extreme_meta(g: &mut Gen) -> ContainerMeta {
    let container = if g.bool() { Container::Fp32 } else { Container::Bf16 };
    let mant = [0u32, 0, 1, 1, 7, 23][g.usize_in(0, 5)];
    let exp_mode = if g.bool() {
        Mode::Delta
    } else {
        Mode::FixedBias {
            bias: g.u32_in(0, 255) as u8,
            group: g.usize_in(1, 32),
        }
    };
    ContainerMeta::new(container, mant).with_exp_mode(exp_mode)
}

fn strip_signs(vals: &mut [f32]) {
    for v in vals.iter_mut() {
        *v = f32::from_bits(v.to_bits() & 0x7FFF_FFFF);
    }
}

fn bit_pattern(vals: &[f32]) -> Vec<u32> {
    vals.iter().map(|v| v.to_bits()).collect()
}

#[test]
fn prop_word_and_scalar_streams_identical_every_codec() {
    check("word streams == scalar streams, every codec", 40, |g| {
        let mut vals = ragged_vals(g);
        let mut meta = extreme_meta(g);
        if g.bool() {
            strip_signs(&mut vals);
            meta = meta.with_sign_elision(true);
        }
        for codec in codecs() {
            let ctx = format!("{} len={} {meta:?}", codec.name(), vals.len());
            let s = codec.encode_kernel(&vals, &meta, Kernel::Scalar);
            let w = codec.encode_kernel(&vals, &meta, Kernel::Word);
            assert_eq!(s.count, w.count, "{ctx}");
            assert_eq!(s.streams, w.streams, "{ctx}");
            // both kernels decode both kernels' (identical) bytes, and the
            // result is the container quantization of the input
            let ds = codec.decode_kernel(&s, &meta, Kernel::Scalar);
            let dw = codec.decode_kernel(&w, &meta, Kernel::Word);
            assert_eq!(bit_pattern(&ds), bit_pattern(&dw), "{ctx}");
            assert_eq!(dw.len(), vals.len(), "{ctx}");
            for (i, (&v, &b)) in vals.iter().zip(&dw).enumerate() {
                assert_eq!(meta.quantized(v).to_bits(), b.to_bits(), "{ctx} i={i}");
            }
        }
    });
}

/// Metadata over the full [`ExponentLayout`] axis: bias windows including
/// the 1/254 extremes, block-shared fields with non-power-of-two blocks,
/// narrow per-value widths — crossed with the 0/1-bit mantissa corners.
fn layout_meta(g: &mut Gen) -> ContainerMeta {
    let container = if g.bool() { Container::Fp32 } else { Container::Bf16 };
    let mant = [0u32, 0, 1, 1, 7, 23][g.usize_in(0, 5)];
    let layout = match g.u32_in(0, 2) {
        0 => ExponentLayout::Width {
            bits: g.u32_in(1, 8),
            mode: if g.bool() {
                Mode::Delta
            } else {
                Mode::FixedBias {
                    bias: g.u32_in(0, 255) as u8,
                    group: g.usize_in(1, 32),
                }
            },
        },
        1 => ExponentLayout::Bias {
            bits: g.u32_in(1, 8),
            bias: [1u8, 127, 254, g.u32_in(1, 254) as u8][g.usize_in(0, 3)],
        },
        _ => ExponentLayout::BlockShared {
            block: [1usize, 3, 16, 64][g.usize_in(0, 3)],
            bits: g.u32_in(1, 8),
        },
    };
    ContainerMeta::new(container, mant).with_layout(layout)
}

#[test]
fn prop_word_and_scalar_streams_identical_every_layout() {
    check("word == scalar across exponent layouts", 40, |g| {
        let mut vals = ragged_vals(g);
        let mut meta = layout_meta(g);
        if g.bool() {
            strip_signs(&mut vals);
            meta = meta.with_sign_elision(true);
        }
        let expect = bit_pattern(&meta.quantized_slice(&vals));
        for codec in codecs() {
            let ctx = format!("{} len={} {meta:?}", codec.name(), vals.len());
            let s = codec.encode_kernel(&vals, &meta, Kernel::Scalar);
            let w = codec.encode_kernel(&vals, &meta, Kernel::Word);
            assert_eq!(s.count, w.count, "{ctx}");
            assert_eq!(s.streams, w.streams, "{ctx}");
            let ds = codec.decode_kernel(&s, &meta, Kernel::Scalar);
            let dw = codec.decode_kernel(&w, &meta, Kernel::Word);
            assert_eq!(bit_pattern(&ds), bit_pattern(&dw), "{ctx}");
            assert_eq!(bit_pattern(&dw), expect, "{ctx}");
            // chunked word encode stays on block/group boundaries, so it
            // must still match the scalar one-shot stream
            let chunk = g.usize_in(1, 3000);
            let cat = codec.encode_chunked_kernel(&vals, &meta, chunk, Kernel::Word);
            assert_eq!(s.streams, cat.streams, "{ctx} chunk={chunk}");
        }
    });
}

#[test]
fn prop_gecko_word_kernel_bit_identical_across_modes() {
    check("gecko word == scalar across modes", 150, |g| {
        let vals = ragged_vals(g);
        let exps = gecko::exponents(&vals);
        let mode = if g.bool() {
            Mode::Delta
        } else {
            Mode::FixedBias {
                bias: g.u32_in(0, 255) as u8,
                group: g.usize_in(1, 32),
            }
        };
        let s = gecko::encode_kernel(&exps, mode, Kernel::Scalar);
        let w = gecko::encode_kernel(&exps, mode, Kernel::Word);
        let ctx = format!("{mode:?} len={}", exps.len());
        assert_eq!(s.payload, w.payload, "{ctx}");
        assert_eq!(s.payload_bits, w.payload_bits, "{ctx}");
        assert_eq!(s.metadata, w.metadata, "{ctx}");
        assert_eq!(s.metadata_bits, w.metadata_bits, "{ctx}");
        assert_eq!(gecko::decode(&w, mode), exps, "{ctx}");
    });
}

#[test]
fn prop_sfp_word_kernel_bit_identical() {
    check("sfp word == scalar", 100, |g| {
        let mut vals = ragged_vals(g);
        let n = [0u32, 1, 7, 23][g.usize_in(0, 3)];
        let container = if g.bool() { Container::Fp32 } else { Container::Bf16 };
        let elide = g.bool();
        if elide {
            strip_signs(&mut vals);
        }
        let bias = [None, None, Some(127u8), Some(3)][g.usize_in(0, 3)];
        let codec = SfpCodec::new(container, elide).with_bias(bias);
        let s = codec.compress_kernel(&vals, n, Kernel::Scalar);
        let w = codec.compress_kernel(&vals, n, Kernel::Word);
        let ctx = format!("{container} n={n} elide={elide} bias={bias:?} len={}", vals.len());
        assert_eq!(s.payload, w.payload, "{ctx}");
        assert_eq!(s.payload_bits, w.payload_bits, "{ctx}");
        assert_eq!(s.metadata, w.metadata, "{ctx}");
        assert_eq!(s.metadata_bits, w.metadata_bits, "{ctx}");
        assert_eq!(s.cycles, w.cycles, "{ctx}");
        let back_w = bit_pattern(&codec.decompress(&w));
        let back_s = bit_pattern(&codec.decompress(&s));
        assert_eq!(back_w, back_s, "{ctx}");
    });
}

#[test]
fn prop_chunked_word_encode_equals_scalar_one_shot() {
    // Chunk-boundary splits: the pool encodes tensors in chunk_values
    // pieces, so a word-kernel chunked encode must equal the scalar
    // one-shot stream for any chunk size.
    check("chunked word == one-shot scalar", 40, |g| {
        let vals = ragged_vals(g);
        let meta = extreme_meta(g);
        let chunk = g.usize_in(1, 3000);
        for codec in codecs() {
            let one = codec.encode_kernel(&vals, &meta, Kernel::Scalar);
            let cat = codec.encode_chunked_kernel(&vals, &meta, chunk, Kernel::Word);
            assert_eq!(one.count, cat.count, "{} chunk={chunk}", codec.name());
            assert_eq!(one.streams, cat.streams, "{} chunk={chunk} {meta:?}", codec.name());
        }
    });
}

#[test]
fn prop_word_decode_across_segment_splits() {
    // Arena streams arrive as multi-segment SegReaders (one segment per
    // 32 KiB chunk); the word kernels' bulk reads must stay exact when
    // stream words are split at arbitrary segment boundaries.
    check("word decode across segment splits", 40, |g| {
        let vals = ragged_vals(g);
        let meta = extreme_meta(g);
        for codec in codecs() {
            let enc = codec.encode_kernel(&vals, &meta, Kernel::Scalar);
            let parts: Vec<Vec<&[u64]>> = enc
                .streams
                .iter()
                .map(|(words, _)| {
                    let cut = g.usize_in(0, words.len());
                    let cut2 = g.usize_in(cut, words.len());
                    vec![&words[..cut], &words[cut..cut2], &words[cut2..]]
                })
                .collect();
            let mut readers: Vec<SegReader> = parts
                .iter()
                .zip(&enc.streams)
                .map(|(segs, (_, bits))| SegReader::new(segs, *bits))
                .collect();
            let dw = codec.decode_view_kernel(enc.count, &mut readers, &meta, Kernel::Word);
            assert_eq!(dw.len(), vals.len(), "{}", codec.name());
            for (i, (&v, &b)) in vals.iter().zip(&dw).enumerate() {
                assert_eq!(
                    meta.quantized(v).to_bits(),
                    b.to_bits(),
                    "{} i={i} {meta:?}",
                    codec.name()
                );
            }
        }
    });
}
