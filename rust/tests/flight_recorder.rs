//! Flight-recorder integration tests against the built `repro` binary:
//! the always-on adaptation-event stream lands in `events.jsonl` with at
//! least one bitlength change per adaptive policy, metrics snapshots are
//! deterministic across serial and parallel execution, counter tracks
//! show up in the Chrome trace, and `repro inspect` reads runs back,
//! diffs them, and gates wall clock against a perf baseline.

use sfp::util::json::Json;
use std::collections::BTreeSet;
use std::path::{Path, PathBuf};
use std::process::{Command, Output};

/// The `repro` binary Cargo built alongside this test.
fn repro() -> Command {
    Command::new(env!("CARGO_BIN_EXE_repro"))
}

fn tdir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("sfp_flight_{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// Run and assert success, returning captured output.
fn run_ok(cmd: &mut Command) -> Output {
    let out = cmd.output().expect("spawn repro");
    assert!(
        out.status.success(),
        "repro failed\nstdout:\n{}\nstderr:\n{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr),
    );
    out
}

fn stdout_of(out: &Output) -> String {
    String::from_utf8_lossy(&out.stdout).into_owned()
}

fn parse_json(path: &Path) -> Json {
    let text = std::fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("read {}: {e}", path.display()));
    Json::parse(&text).unwrap_or_else(|e| panic!("parse {}: {e}", path.display()))
}

/// A small serial policy sweep writing its run directory to `out`,
/// reusing `cache` so a second invocation resolves fully cached.
fn policy_sweep(out: &Path, cache: &Path) -> Output {
    run_ok(
        repro()
            .args(["policy", "--model", "resnet18", "--policy", "all"])
            .args(["--sample", "4096", "--serial"])
            .arg("--out")
            .arg(out)
            .arg("--cache")
            .arg(cache),
    )
}

#[test]
fn policy_sweep_records_events_per_adaptive_policy_and_inspect_reads_them() {
    let root = tdir("events");
    let (a, b, cache) = (root.join("a"), root.join("b"), root.join("cache"));
    policy_sweep(&a, &cache);

    // The always-on event stream exists without --trace and records at
    // least one stored-bitlength change from every adaptive policy in
    // the sweep: QM (mantissa), QE (exponent), BitWave (network-wide).
    let text = std::fs::read_to_string(a.join("events.jsonl")).expect("events.jsonl");
    let events: Vec<Json> = text
        .lines()
        .map(|l| Json::parse(l).expect("event line"))
        .collect();
    assert!(!events.is_empty(), "events.jsonl is empty");
    let sources: BTreeSet<&str> = events
        .iter()
        .filter(|e| e.get("kind").and_then(Json::as_str) == Some("bitlength"))
        .filter_map(|e| e.get("source").and_then(Json::as_str))
        .collect();
    for src in ["qm", "qe", "bitwave"] {
        assert!(
            sources.contains(src),
            "no bitlength event from {src} (saw {sources:?})"
        );
    }

    // Single-run inspect prints the health summary and replayed
    // bitlength trajectories.
    let out = run_ok(repro().arg("inspect").arg(&a));
    let text = stdout_of(&out);
    assert!(text.contains("bitlength trajectories"), "{text}");
    assert!(text.contains("bitlength changes"), "{text}");
    assert!(text.contains(" -> "), "no trajectory arrows:\n{text}");

    // Baseline round-trip: record this run, then gate against it — the
    // run that produced a baseline always passes its own gate.
    let bench = root.join("BENCH_test.json");
    let mut wb = repro();
    wb.arg("inspect").arg(&a);
    wb.arg("--write-baseline").arg(&bench);
    run_ok(&mut wb);
    let base = parse_json(&bench);
    assert!(base.get("total_wall_ms").and_then(Json::as_f64).unwrap() > 0.0);
    assert_eq!(base.get("total_jobs").and_then(Json::as_f64), Some(4.0));
    let out = run_ok(
        repro()
            .arg("inspect")
            .arg(&a)
            .arg("--baseline")
            .arg(&bench)
            .args(["--gate", "200"]),
    );
    assert!(stdout_of(&out).contains("perf gate OK"));

    // An absurdly tight baseline must trip the regression gate.
    let tight = root.join("BENCH_tight.json");
    std::fs::write(&tight, r#"{"total_wall_ms": 0.0001}"#).unwrap();
    let out = repro()
        .arg("inspect")
        .arg(&a)
        .arg("--baseline")
        .arg(&tight)
        .args(["--gate", "0"])
        .output()
        .expect("spawn repro");
    assert!(!out.status.success(), "tight baseline should fail the gate");
    assert!(
        String::from_utf8_lossy(&out.stderr).contains("perf regression"),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr),
    );

    // Warm re-run into a second directory (shared cache), then diff:
    // identical configs against the same cache are fingerprint-identical.
    policy_sweep(&b, &cache);
    let out = run_ok(repro().arg("inspect").arg(&a).arg(&b));
    let text = stdout_of(&out);
    assert!(text.contains("4 jobs fingerprint-identical, 0 differ"), "{text}");

    let _ = std::fs::remove_dir_all(&root);
}

/// A tiny stash sweep (two budget points + summary) into `out`.
fn stash_sweep(out: &Path, serial: bool, trace: Option<&Path>) -> Output {
    let mut cmd = repro();
    cmd.args(["stash", "--model", "resnet18", "--sample", "1024"])
        .args(["--batch", "64", "--budget-bytes", "0,262144"])
        .arg("--out")
        .arg(out)
        .arg("--cache")
        .arg(out.join("cache"));
    if serial {
        cmd.arg("--serial");
    }
    if let Some(path) = trace {
        cmd.arg("--trace").arg(path);
    }
    run_ok(&mut cmd)
}

#[test]
fn metrics_snapshot_is_deterministic_across_serial_and_parallel() {
    let root = tdir("metrics");
    let (sdir, pdir) = (root.join("serial"), root.join("par"));
    let trace_path = sdir.join("trace.json");
    stash_sweep(&sdir, true, Some(&trace_path));
    stash_sweep(&pdir, false, None);

    let (Json::Obj(ms), Json::Obj(mp)) = (
        parse_json(&sdir.join("metrics.json")),
        parse_json(&pdir.join("metrics.json")),
    ) else {
        panic!("metrics.json is not an object");
    };

    // Same counters present either way, and the work-accounting ones
    // agree exactly: the snapshot layout must not depend on the
    // execution mode, only latency distributions may differ.
    let ks: Vec<&String> = ms.keys().collect();
    let kp: Vec<&String> = mp.keys().collect();
    assert_eq!(ks, kp, "metrics key sets differ between serial and parallel");
    for key in [
        "lab_jobs_done_total",
        "lab_jobs_executed_total",
        "lab_jobs_failed_total",
        "lab_jobs_cached_total",
    ] {
        assert_eq!(
            ms.get(key).and_then(Json::as_f64),
            mp.get(key).and_then(Json::as_f64),
            "{key} differs between serial and parallel"
        );
    }
    assert_eq!(ms.get("lab_jobs_done_total").and_then(Json::as_f64), Some(3.0));
    assert_eq!(ms.get("lab_jobs_failed_total").and_then(Json::as_f64), Some(0.0));

    // Monotone counters never go negative, and histogram quantiles are
    // ordered, in both snapshots.
    for m in [&ms, &mp] {
        for (key, v) in m {
            match v {
                Json::Num(x) => assert!(*x >= 0.0, "{key} = {x}"),
                Json::Obj(h) => {
                    let q = |k: &str| h.get(k).and_then(Json::as_f64).unwrap_or(0.0);
                    assert!(q("p50_us") <= q("p99_us"), "{key}: p50 > p99");
                }
                _ => {}
            }
        }
    }

    // The traced serial run rendered counter tracks into the Chrome
    // trace ("ph":"C" with numeric args) and exported timeseries.json
    // in the same shape the trace was built from.
    let trace = parse_json(&trace_path);
    let trace_events = trace
        .get("traceEvents")
        .and_then(Json::as_arr)
        .expect("traceEvents");
    let counter_names: BTreeSet<&str> = trace_events
        .iter()
        .filter(|e| e.get("ph").and_then(Json::as_str) == Some("C"))
        .filter_map(|e| e.get("name").and_then(Json::as_str))
        .collect();
    assert!(
        counter_names.contains("stash_bytes"),
        "no stash_bytes counter track (saw {counter_names:?})"
    );
    assert!(
        counter_names.contains("stash_queue_depth"),
        "no stash_queue_depth counter track (saw {counter_names:?})"
    );
    let series = parse_json(&sdir.join("timeseries.json"));
    let samples = series.as_arr().expect("timeseries.json array");
    assert!(!samples.is_empty());
    for s in samples {
        assert!(s.get("track").and_then(Json::as_str).is_some());
        assert!(s.get("value").and_then(Json::as_f64).is_some());
    }

    let _ = std::fs::remove_dir_all(&root);
}
