//! ImageNet-scale footprint models: per-layer stored bits for every
//! compression variant, built by running the *real* codecs over sampled
//! value streams from each layer's [`ValueModel`].
//!
//! Sampling: per tensor we draw `SAMPLE` representative values, measure
//! exact encoded bits with the production codec paths, and scale by the
//! tensor's true element count — the codecs are linear in group count, so
//! the scaling is exact up to one partial group.
//!
//! The stash-measured counterpart of this model lives in
//! [`crate::lab::measure`]: `repro stash` lab jobs store the *same*
//! seeded streams through the real codec paths and gate the divergence
//! (exact for gecko at the model's own `SAMPLE`/`STREAM_SEED`, exact for
//! raw and js at any sample, reported-ungated for sfp's metadata framing).

use crate::baselines::{self, ActKind};
use crate::formats::Container;
use crate::gecko;
use crate::hwsim::LayerBits;
use crate::stash::{CodecKind, ContainerMeta, Stash, StashConfig, TensorId};
use crate::stats::{ComponentBits, Footprint};
use crate::traces::{values_with_exponents, LayerTrace, NetworkTrace};
use anyhow::anyhow;

/// Values sampled per tensor for codec measurement.
pub const SAMPLE: usize = 64 * 512;

/// Per-tensor stream seed scheme shared by the analytic footprint model,
/// the stash measurement ([`stash_measured_bits`], `repro stash`), and the
/// policy sweep (`repro policy`): layer `i` draws from
/// `STREAM_SEED ^ i ^ <component seed>`, so every measurement path sees
/// bit-identical streams and their cross-checks are exact.
pub const STREAM_SEED: u64 = 0x5EED;
pub const ACT_EXP_SEED: u64 = 0xAC7;
pub const ACT_VAL_SEED: u64 = 0x7A1;
pub const WEIGHT_EXP_SEED: u64 = 0x3E1;
pub const WEIGHT_VAL_SEED: u64 = 0x3F2;

/// Mantissa bitlength policy for a variant at ImageNet scale.
#[derive(Debug, Clone)]
pub enum MantissaPolicy {
    /// Container-native (23 or 7): the FP32/BF16 baselines.
    Full,
    /// Per-layer adaptive bits (Quantum Mantissa): (act_bits, weight_bits)
    /// by relative depth, interpolated from measured e2e bitlengths.
    PerLayer {
        act_bits: Vec<u32>,
        weight_bits: Vec<u32>,
    },
    /// Network-wide activation bits (BitChop); weights stay at container.
    NetworkWide { act_bits: f64 },
}

impl MantissaPolicy {
    /// Defaults calibrated from this repo's e2e QM run (EXPERIMENTS.md):
    /// first layer needs a few bits, the bulk settles at 1-2 (paper Fig 4).
    pub fn qm_default() -> Self {
        MantissaPolicy::PerLayer {
            act_bits: vec![2, 1, 1, 1, 2],
            weight_bits: vec![3, 2, 2, 2, 3],
        }
    }

    /// Paper Fig. 7: BitChop averages 4-5 bits on BF16, 5-6 on FP32.
    pub fn bc_default(container: Container) -> Self {
        MantissaPolicy::NetworkWide {
            act_bits: match container {
                Container::Bf16 => 4.5,
                Container::Fp32 => 5.5,
            },
        }
    }

    /// Bits for layer at depth-quantile `frac` (0..1).
    pub fn bits_at(&self, frac: f64, weights: bool, container: Container) -> f64 {
        match self {
            MantissaPolicy::Full => container.mant_bits() as f64,
            MantissaPolicy::NetworkWide { act_bits } => {
                if weights {
                    container.mant_bits() as f64
                } else {
                    act_bits.min(container.mant_bits() as f64)
                }
            }
            MantissaPolicy::PerLayer {
                act_bits,
                weight_bits,
            } => {
                let v = if weights { weight_bits } else { act_bits };
                let idx = ((frac * v.len() as f64) as usize).min(v.len() - 1);
                (v[idx] as f64).min(container.mant_bits() as f64)
            }
        }
    }

    /// The integer per-layer `(act_bits, weight_bits)` container schedule
    /// this policy induces over `layers` layers — the single source of
    /// truth shared by the analytic model ([`FootprintModel::from_schedule`])
    /// and the stash sweep (`repro stash`), so their stored-bytes numbers
    /// are comparable (fractional averages like BitChop's 4.5 b round to
    /// the nearest storable container).
    pub fn integer_schedule(&self, layers: usize, container: Container) -> Vec<(u32, u32)> {
        let n = layers.max(1);
        (0..layers)
            .map(|i| {
                let f = i as f64 / n as f64;
                (
                    self.bits_at(f, false, container).round() as u32,
                    self.bits_at(f, true, container).round() as u32,
                )
            })
            .collect()
    }
}

/// One layer's stored bits under one variant, split by component.
#[derive(Debug, Clone, Copy)]
pub struct LayerFootprint {
    pub acts: ComponentBits,
    pub weights: ComponentBits,
}

impl LayerFootprint {
    pub fn total_act_bits(&self) -> f64 {
        self.acts.total()
    }
    pub fn total_weight_bits(&self) -> f64 {
        self.weights.total()
    }
}

/// The footprint model for a (network, variant) pair.
pub struct FootprintModel {
    pub container: Container,
    pub policy: MantissaPolicy,
    /// Apply Gecko + sign elision + adaptive mantissas (false = raw
    /// container, the FP32/BF16 baselines).
    pub sfp: bool,
}

impl FootprintModel {
    pub fn fp32() -> Self {
        Self {
            container: Container::Fp32,
            policy: MantissaPolicy::Full,
            sfp: false,
        }
    }

    pub fn bf16() -> Self {
        Self {
            container: Container::Bf16,
            policy: MantissaPolicy::Full,
            sfp: false,
        }
    }

    pub fn sfp_qm(container: Container) -> Self {
        Self {
            container,
            policy: MantissaPolicy::qm_default(),
            sfp: true,
        }
    }

    pub fn sfp_bc(container: Container) -> Self {
        Self {
            container,
            policy: MantissaPolicy::bc_default(container),
            sfp: true,
        }
    }

    /// SFP model over an explicit integer `(act_bits, weight_bits)` per-layer
    /// schedule (see [`MantissaPolicy::integer_schedule`]) — what `repro
    /// stash` compares its measured stored-bytes against.
    pub fn from_schedule(container: Container, schedule: &[(u32, u32)]) -> Self {
        Self {
            container,
            policy: MantissaPolicy::PerLayer {
                act_bits: schedule.iter().map(|&(a, _)| a).collect(),
                weight_bits: schedule.iter().map(|&(_, w)| w).collect(),
            },
            sfp: true,
        }
    }

    /// Per-batch stored bits of one layer (`batch` samples of activations,
    /// one copy of weights).
    pub fn layer(&self, l: &LayerTrace, depth_frac: f64, batch: usize, seed: u64) -> LayerFootprint {
        let act_elems = (l.act_elems * batch) as f64;
        let w_elems = l.weight_elems as f64;
        let n_a = self.policy.bits_at(depth_frac, false, self.container);
        let n_w = self.policy.bits_at(depth_frac, true, self.container);

        if !self.sfp {
            let cb = self.container.total_bits() as f64;
            return LayerFootprint {
                acts: ComponentBits {
                    sign: act_elems,
                    exponent: 8.0 * act_elems,
                    mantissa: (cb - 9.0) * act_elems,
                    metadata: 0.0,
                },
                weights: ComponentBits {
                    sign: w_elems,
                    exponent: 8.0 * w_elems,
                    mantissa: (cb - 9.0) * w_elems,
                    metadata: 0.0,
                },
            };
        }

        // --- SFP: measure Gecko exponent bits on sampled streams.
        let a_exps = l.act_model.sample_exponents(SAMPLE, seed ^ ACT_EXP_SEED);
        let a_enc = gecko::encoded_bits(&a_exps, gecko::Mode::Delta) as f64;
        let a_scale = act_elems / SAMPLE as f64;
        let w_sample = SAMPLE.min(l.weight_elems.max(64));
        let w_exps = l.weight_model.sample_exponents(w_sample, seed ^ WEIGHT_EXP_SEED);
        let w_enc = gecko::encoded_bits(&w_exps, gecko::Mode::Delta) as f64;
        let w_scale = w_elems / w_sample as f64;

        // Gecko bit split: metadata = 3 b per delta row (7 per group of 64)
        let meta_frac = |count: f64| count / 64.0 * (7.0 * gecko::WIDTH_FIELD_BITS as f64);

        LayerFootprint {
            acts: ComponentBits {
                sign: if l.nonneg_act { 0.0 } else { act_elems },
                exponent: a_enc * a_scale - meta_frac(act_elems),
                mantissa: n_a * act_elems,
                metadata: meta_frac(act_elems),
            },
            weights: ComponentBits {
                sign: w_elems,
                exponent: w_enc * w_scale - meta_frac(w_elems),
                mantissa: n_w * w_elems,
                metadata: meta_frac(w_elems),
            },
        }
    }

    /// Whole-network per-batch footprint.
    pub fn network(&self, net: &NetworkTrace, batch: usize) -> Footprint {
        let n = net.layers.len().max(1);
        let mut out = Footprint::default();
        for (i, l) in net.layers.iter().enumerate() {
            let lf = self.layer(l, i as f64 / n as f64, batch, STREAM_SEED ^ i as u64);
            out.activations.add(lf.acts);
            out.weights.add(lf.weights);
        }
        out
    }
}

/// Per-layer stored bits *measured* through a real [`Stash`]: one sampled
/// value stream per tensor (seeds mirror [`FootprintModel::layer`], so the
/// streams are the ones the analytic model sizes Gecko on) encoded under
/// the integer `(act_bits, weight_bits)` schedule, scaled to full tensor
/// size.  This is the `repro stash` measurement path factored out so
/// `table2 --source stash` can drive the hwsim with measured bytes.
pub fn stash_measured_bits(
    net: &NetworkTrace,
    schedule: &[(u32, u32)],
    container: Container,
    batch: usize,
    kind: CodecKind,
) -> anyhow::Result<Vec<LayerBits>> {
    assert_eq!(schedule.len(), net.layers.len());
    let stash = Stash::new(StashConfig {
        codec: kind,
        ..Default::default()
    });
    let mut scales = Vec::with_capacity(net.layers.len());
    for (i, l) in net.layers.iter().enumerate() {
        let seed = STREAM_SEED ^ i as u64;
        let (n_a, n_w) = schedule[i];
        let a_exps = l.act_model.sample_exponents(SAMPLE, seed ^ ACT_EXP_SEED);
        let a_vals = values_with_exponents(&a_exps, seed ^ ACT_VAL_SEED, l.nonneg_act);
        let a_meta = ContainerMeta::new(container, n_a).with_sign_elision(l.nonneg_act);
        stash.put(TensorId::act(i), a_vals, a_meta);
        let w_count = SAMPLE.min(l.weight_elems.max(64));
        let w_exps = l.weight_model.sample_exponents(w_count, seed ^ WEIGHT_EXP_SEED);
        let w_vals = values_with_exponents(&w_exps, seed ^ WEIGHT_VAL_SEED, false);
        stash.put(TensorId::weight(i), w_vals, ContainerMeta::new(container, n_w));
        scales.push((
            (l.act_elems * batch) as f64 / SAMPLE as f64,
            l.weight_elems as f64 / w_count as f64,
        ));
    }
    stash.flush();
    if stash.failures() > 0 {
        return Err(anyhow!("{} stash encode jobs failed", stash.failures()));
    }
    let mut out = Vec::with_capacity(net.layers.len());
    for (i, (a_scale, w_scale)) in scales.iter().enumerate() {
        let a = stash
            .stored_bits(TensorId::act(i))
            .ok_or_else(|| anyhow!("activation {i} not resident"))?;
        let w = stash
            .stored_bits(TensorId::weight(i))
            .ok_or_else(|| anyhow!("weight {i} not resident"))?;
        out.push(LayerBits {
            weight: w.total() * w_scale,
            act: a.total() * a_scale,
        });
    }
    Ok(out)
}

/// Activation-only footprints for the Fig. 13 comparison set.
pub struct Fig13Row {
    pub label: String,
    /// Total activation bits per batch.
    pub bits: f64,
}

/// Fig. 13: cumulative activation footprint of BF16, JS, GIST++, SFP_BC,
/// SFP_QM, and the JS-combined SFP variants.
pub fn fig13_rows(net: &NetworkTrace, batch: usize) -> Vec<Fig13Row> {
    let n = net.layers.len().max(1);
    let qm = FootprintModel::sfp_qm(Container::Bf16);
    let bc = FootprintModel::sfp_bc(Container::Bf16);

    let mut bf16 = 0.0;
    let mut js = 0.0;
    let mut gist = 0.0;
    let mut sfp_bc = 0.0;
    let mut sfp_qm = 0.0;
    let mut sfp_bc_js = 0.0;
    let mut sfp_qm_js = 0.0;

    for (i, l) in net.layers.iter().enumerate() {
        let count = l.act_elems * batch;
        let zf = l.act_model.zero_frac;
        bf16 += baselines::dense_bits(count, Container::Bf16) as f64;
        js += baselines::js_bits(count, zf, Container::Bf16) as f64;
        gist += baselines::gist_pp_bits(count, zf, l.act_kind, Container::Bf16) as f64;
        let f = i as f64 / n as f64;
        let qm_bits = qm.layer(l, f, batch, 7 ^ i as u64).total_act_bits();
        let bc_bits = bc.layer(l, f, batch, 9 ^ i as u64).total_act_bits();
        sfp_qm += qm_bits;
        sfp_bc += bc_bits;
        sfp_qm_js += baselines::sfp_combined_bits(count, zf, qm_bits as usize) as f64;
        sfp_bc_js += baselines::sfp_combined_bits(count, zf, bc_bits as usize) as f64;
    }

    vec![
        Fig13Row { label: "BF16".into(), bits: bf16 },
        Fig13Row { label: "JS".into(), bits: js },
        Fig13Row { label: "GIST++".into(), bits: gist },
        Fig13Row { label: "SFP_BC".into(), bits: sfp_bc },
        Fig13Row { label: "SFP_QM".into(), bits: sfp_qm },
        Fig13Row { label: "SFP_BC+JS".into(), bits: sfp_bc_js },
        Fig13Row { label: "SFP_QM+JS".into(), bits: sfp_qm_js },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::traces::{mobilenet_v3_small, resnet18};

    #[test]
    fn bf16_is_half_of_fp32() {
        let net = resnet18();
        let f32f = FootprintModel::fp32().network(&net, 256);
        let bf = FootprintModel::bf16().network(&net, 256);
        let r = bf.relative_to(&f32f);
        assert!((r - 0.5).abs() < 1e-9, "{r}");
    }

    #[test]
    fn table1_bands_resnet18() {
        // Paper Table I: SFP_QM 14.7%, SFP_BC 23.7% of FP32 on ResNet18.
        let net = resnet18();
        let f32f = FootprintModel::fp32().network(&net, 256);
        let qm = FootprintModel::sfp_qm(Container::Bf16).network(&net, 256);
        let bc = FootprintModel::sfp_bc(Container::Bf16).network(&net, 256);
        let rq = qm.relative_to(&f32f);
        let rb = bc.relative_to(&f32f);
        assert!((0.10..0.22).contains(&rq), "QM rel {rq}");
        assert!((0.17..0.32).contains(&rb), "BC rel {rb}");
        assert!(rq < rb, "QM must beat BC");
    }

    #[test]
    fn table1_bands_mobilenet() {
        // Paper: MNv3-Small QM 24.9%, BC 27.2% — worse than ResNet18
        // (no ReLU sign elision on most activations, denser values).
        let net = mobilenet_v3_small();
        let f32f = FootprintModel::fp32().network(&net, 256);
        let qm = FootprintModel::sfp_qm(Container::Bf16).network(&net, 256);
        let rq = qm.relative_to(&f32f);
        assert!((0.15..0.33).contains(&rq), "QM rel {rq}");
        let rn_qm = FootprintModel::sfp_qm(Container::Bf16)
            .network(&resnet18(), 256)
            .relative_to(&FootprintModel::fp32().network(&resnet18(), 256));
        assert!(rq > rn_qm, "MNv3 compresses worse than RN18");
    }

    #[test]
    fn fig13_ordering_resnet18() {
        // Paper §VI-B on ResNet18: BF16 > JS > GIST++ > SFP_BC > SFP_QM,
        // combined variants best (10×/8× over BF16).
        let rows = fig13_rows(&resnet18(), 256);
        let get = |l: &str| rows.iter().find(|r| r.label == l).unwrap().bits;
        assert!(get("JS") < get("BF16"));
        assert!(get("GIST++") <= get("JS"));
        assert!(get("SFP_BC") < get("GIST++"));
        assert!(get("SFP_QM") < get("SFP_BC"));
        assert!(get("SFP_QM+JS") < get("SFP_QM"));
        // §VI-B: "this further improves compression ratios to 10x and 8x"
        // (vs the 32-bit starting point).
        let qm_js_fp32 = 2.0 * get("BF16") / get("SFP_QM+JS");
        let bc_js_fp32 = 2.0 * get("BF16") / get("SFP_BC+JS");
        assert!((6.0..14.0).contains(&qm_js_fp32), "combined qm {qm_js_fp32}");
        assert!((5.0..12.0).contains(&bc_js_fp32), "combined bc {bc_js_fp32}");
        assert!(qm_js_fp32 > bc_js_fp32);
    }

    #[test]
    fn fig13_mobilenet_js_gist_powerless() {
        // §VI-B: MNv3 has little ReLU sparsity — JS/GIST++ barely help,
        // SFP still gets ~2× over BF16.
        let rows = fig13_rows(&mobilenet_v3_small(), 256);
        let get = |l: &str| rows.iter().find(|r| r.label == l).unwrap().bits;
        assert!(get("JS") > 0.9 * get("BF16"), "JS shouldn't help much");
        assert!(get("GIST++") > 0.85 * get("BF16"));
        let sfp_gain = get("BF16") / get("SFP_QM");
        assert!((1.5..3.5).contains(&sfp_gain), "sfp gain {sfp_gain}");
    }

    #[test]
    fn stash_measured_bits_matches_analytic_gecko() {
        // the gecko component-stream codec lays bits out exactly as the
        // analytic model accounts them: per-layer deltas stay under 1%
        let net = resnet18();
        let sched = MantissaPolicy::qm_default().integer_schedule(net.layers.len(), Container::Bf16);
        let measured =
            stash_measured_bits(&net, &sched, Container::Bf16, 256, CodecKind::Gecko).unwrap();
        let analytic = FootprintModel::from_schedule(Container::Bf16, &sched);
        let n = net.layers.len();
        for (i, (l, m)) in net.layers.iter().zip(&measured).enumerate() {
            let lf = analytic.layer(l, (i as f64 + 0.5) / n as f64, 256, STREAM_SEED ^ i as u64);
            let expected = lf.total_act_bits() + lf.total_weight_bits();
            let got = m.act + m.weight;
            assert!(
                ((got - expected) / expected).abs() < 0.01,
                "layer {i}: measured {got} vs analytic {expected}"
            );
        }
    }

    #[test]
    fn component_split_fig12_shape() {
        // Fig. 12: under SFP_QM exponents dominate what remains.
        let net = resnet18();
        let qm = FootprintModel::sfp_qm(Container::Bf16).network(&net, 256);
        let a = qm.activations;
        assert!(a.exponent > a.mantissa, "exp {} vs mant {}", a.exponent, a.mantissa);
        assert!(a.sign < 0.05 * a.total(), "sign share");
    }
}
