//! Figure drivers: every plot in the paper's evaluation becomes a CSV with
//! the same series (DESIGN.md §4 maps figure → driver).  Training-derived
//! figures (2/3/4/6/7/8) consume a [`RunResult`]; value-distribution
//! figures (9/10) can come from the live e2e model *or* the ImageNet-scale
//! trace models; 12/13 come from the footprint models.

use super::footprint::{fig13_rows, FootprintModel};
use crate::coordinator::metrics::CsvSink;
use crate::coordinator::RunResult;
use crate::formats::Container;
use crate::obs::AdaptEvent;
use crate::stats::{EncodedWidthCdf, ExponentHistogram, Footprint};
use crate::traces::{mobilenet_v3_small, resnet18, NetworkTrace};
use anyhow::{anyhow, Result};
use std::path::Path;

/// Figs 2 & 6: validation accuracy per epoch, variant vs baseline.
pub fn fig_accuracy(path: &Path, baseline: &RunResult, variant: &RunResult) -> Result<()> {
    let mut csv = CsvSink::create(path, &["epoch", "baseline_acc", "variant_acc"])?;
    for (b, v) in baseline.epochs.iter().zip(&variant.epochs) {
        csv.row(&[b.epoch as f64, b.val_acc, v.val_acc])?;
    }
    csv.flush()
}

/// Fig 3: weighted mean mantissa bitlengths (+ min/max spread) per epoch.
pub fn fig3_bitlengths(path: &Path, qm: &RunResult) -> Result<()> {
    let mut csv = CsvSink::create(
        path,
        &["epoch", "wmean_a", "mean_a", "min_a", "max_a", "mean_w"],
    )?;
    for e in &qm.epochs {
        let min = e.per_layer_bits_a.iter().cloned().fold(f64::MAX, f64::min);
        let max = e.per_layer_bits_a.iter().cloned().fold(0.0, f64::max);
        csv.row(&[
            e.epoch as f64,
            e.wmean_bits_a,
            e.mean_bits_a,
            min,
            max,
            e.mean_bits_w,
        ])?;
    }
    csv.flush()
}

/// Fig 4: per-layer activation bitlengths at each epoch end.
pub fn fig4_per_layer(path: &Path, qm: &RunResult) -> Result<()> {
    let layers = qm
        .epochs
        .first()
        .map(|e| e.per_layer_bits_a.len())
        .unwrap_or(0);
    let mut header = vec!["epoch".to_string()];
    header.extend((0..layers).map(|i| format!("layer{i}")));
    let refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut csv = CsvSink::create(path, &refs)?;
    for e in &qm.epochs {
        let mut row = vec![e.epoch as f64];
        row.extend(e.per_layer_bits_a.iter().cloned());
        csv.row(&row)?;
    }
    csv.flush()
}

/// Fig 7: BitChop mean mantissa bits per epoch (BF16 and FP32 runs).
pub fn fig7_bc_bits(path: &Path, bf16: &RunResult, fp32: Option<&RunResult>) -> Result<()> {
    let mut csv = CsvSink::create(path, &["epoch", "bf16_bits", "fp32_bits"])?;
    for (i, e) in bf16.epochs.iter().enumerate() {
        let f = fp32
            .and_then(|r| r.epochs.get(i))
            .map(|e| e.mean_bits_a)
            .unwrap_or(f64::NAN);
        csv.row(&[e.epoch as f64, e.mean_bits_a, f])?;
    }
    csv.flush()
}

/// Fig 8: histogram of BitChop bitlengths across batches.
pub fn fig8_bc_histogram(path: &Path, bc: &RunResult) -> Result<()> {
    let mut csv = CsvSink::create(path, &["bits", "batches"])?;
    for (b, &c) in bc.bc_histogram.counts.iter().enumerate() {
        csv.row(&[b as f64, c as f64])?;
    }
    csv.flush()
}

/// Replay one recorded bitlength-event stream (a `(tensor class,
/// component)` pair) into the layer-mean stored width at the end of each
/// epoch.  Per-layer events update their layer; network-wide events
/// (`layer: None`, BitWave) update every layer.  Each layer's starting
/// width is the `from` of its first event; layers the policy never
/// touched keep their `seed` fallback.  Returns `None` when the run
/// recorded no events for this stream — callers fall back to the
/// measured per-epoch means.
fn replay_mean_bits(
    events: &[AdaptEvent],
    class: &str,
    component: &str,
    layers: usize,
    seed: &[f64],
    epochs: usize,
) -> Option<Vec<f64>> {
    let mut stream: Vec<&AdaptEvent> = events
        .iter()
        .filter(|e| {
            e.kind == "bitlength"
                && e.tensor_class.as_deref() == Some(class)
                && e.component.as_deref() == Some(component)
        })
        .collect();
    if stream.is_empty() || layers == 0 {
        return None;
    }
    stream.sort_by_key(|e| (e.epoch.unwrap_or(0), e.step.unwrap_or(0)));
    let mut state: Vec<f64> = (0..layers)
        .map(|i| seed.get(i).copied().unwrap_or(f64::NAN))
        .collect();
    let mut seeded = vec![false; layers];
    for e in &stream {
        match e.layer {
            Some(l) if l < layers => {
                if !seeded[l] {
                    state[l] = e.from;
                    seeded[l] = true;
                }
            }
            None => {
                for l in 0..layers {
                    if !seeded[l] {
                        state[l] = e.from;
                        seeded[l] = true;
                    }
                }
            }
            _ => {}
        }
    }
    let mut out = Vec::with_capacity(epochs);
    let mut idx = 0;
    for epoch in 0..epochs {
        while idx < stream.len() && stream[idx].epoch.unwrap_or(0) <= epoch {
            let e = stream[idx];
            match e.layer {
                Some(l) if l < layers => state[l] = e.to,
                None => state.iter_mut().for_each(|s| *s = e.to),
                _ => {}
            }
            idx += 1;
        }
        out.push(state.iter().sum::<f64>() / layers as f64);
    }
    Some(out)
}

/// Footprint-over-time: per-epoch stash traffic of a run (what an
/// adapting container actually wrote/read each epoch, plus the stored
/// bitlength trajectory) — the policy engine's adaptation curve on real
/// stored bytes.  Requires a run with `TrainConfig::stash` set.
///
/// The bitlength columns replay the run's *recorded* adaptation events
/// (`RunResult::events`, the flight recorder's thread-local capture):
/// the layer-mean stored mantissa/exponent width at each epoch end.
/// A run whose policy recorded no events for a stream (fixed variants,
/// exponent-passive policies) falls back to the measured per-epoch
/// means, as before.
pub fn footprint_over_time(path: &Path, run: &RunResult) -> Result<()> {
    let layers = run
        .epochs
        .first()
        .map(|e| e.per_layer_bits_a.len())
        .unwrap_or(0);
    let seed_mant: Vec<f64> = run
        .epochs
        .first()
        .map(|e| e.per_layer_bits_a.clone())
        .unwrap_or_default();
    let seed_exp: Vec<f64> =
        vec![run.epochs.first().map(|e| e.mean_exp_bits_a).unwrap_or(8.0); layers];
    let n = run.stash_epochs.len();
    let mant = replay_mean_bits(&run.events, "act", "mant", layers, &seed_mant, n);
    let exp = replay_mean_bits(&run.events, "act", "exp", layers, &seed_exp, n);
    let mut csv = CsvSink::create(
        path,
        &[
            "epoch",
            "written_mb",
            "read_mb",
            "spill_written_mb",
            "spill_read_mb",
            "ratio_vs_fp32",
            "mean_bits_a",
            "mean_exp_bits_a",
        ],
    )?;
    for (i, e) in run.stash_epochs.iter().enumerate() {
        let (fallback_bits, fallback_exp) = run
            .epochs
            .get(i)
            .map(|s| (s.mean_bits_a, s.mean_exp_bits_a))
            .unwrap_or((f64::NAN, f64::NAN));
        let bits = mant.as_ref().map_or(fallback_bits, |v| v[i]);
        let exp = exp.as_ref().map_or(fallback_exp, |v| v[i]);
        csv.row(&[
            i as f64,
            e.written_bits / 8e6,
            e.read_bits / 8e6,
            e.spill_written_bits / 8e6,
            e.spill_read_bits / 8e6,
            e.ratio_vs_fp32(),
            bits,
            exp,
        ])?;
    }
    csv.flush()
}

/// Fig 9: exponent value distribution for weights and activations.
pub fn fig9_exponents(
    path: &Path,
    weights: &ExponentHistogram,
    acts: &ExponentHistogram,
) -> Result<()> {
    let mut csv = CsvSink::create(path, &["exponent", "weight_frac", "act_frac"])?;
    for e in 0..256usize {
        let w = weights.bins[e] as f64 / weights.total.max(1) as f64;
        let a = acts.bins[e] as f64 / acts.total.max(1) as f64;
        if w > 0.0 || a > 0.0 {
            csv.row(&[e as f64, w, a])?;
        }
    }
    csv.flush()
}

/// Fig 9 from the ImageNet-scale trace value models.
pub fn fig9_from_trace(net: &NetworkTrace, samples_per_layer: usize) -> (ExponentHistogram, ExponentHistogram) {
    let mut hw = ExponentHistogram::new();
    let mut ha = ExponentHistogram::new();
    for (i, l) in net.layers.iter().enumerate() {
        let w = l.weight_model.sample_values(samples_per_layer, 0xF19 ^ i as u64, false);
        let a = l.act_model.sample_values(samples_per_layer, 0xF90 ^ i as u64, l.nonneg_act);
        hw.add_vals(&w);
        ha.add_vals(&a);
    }
    (hw, ha)
}

/// Fig 10: CDF of post-Gecko encoded exponent widths.
pub fn fig10_cdf(path: &Path, weights: &EncodedWidthCdf, acts: &EncodedWidthCdf) -> Result<()> {
    let mut csv = CsvSink::create(path, &["bits", "weight_cdf", "act_cdf"])?;
    for b in 0..=8usize {
        csv.row(&[b as f64, weights.cdf_at(b), acts.cdf_at(b)])?;
    }
    csv.flush()
}

/// Fig 10 inputs from the trace value models.
pub fn fig10_from_trace(net: &NetworkTrace, samples_per_layer: usize) -> (EncodedWidthCdf, EncodedWidthCdf) {
    let mut cw = EncodedWidthCdf::new();
    let mut ca = EncodedWidthCdf::new();
    for (i, l) in net.layers.iter().enumerate() {
        cw.add_exponents(&l.weight_model.sample_exponents(samples_per_layer, 0xA10 ^ i as u64));
        ca.add_exponents(&l.act_model.sample_exponents(samples_per_layer, 0xA90 ^ i as u64));
    }
    (cw, ca)
}

/// Fig 12: relative footprint by component for FP32/BF16/SFP_BC/SFP_QM.
pub fn fig12_components(path: &Path, net: &NetworkTrace, batch: usize) -> Result<()> {
    let rows: Vec<(&str, Footprint)> = vec![
        ("fp32", FootprintModel::fp32().network(net, batch)),
        ("bf16", FootprintModel::bf16().network(net, batch)),
        ("sfp_bc", FootprintModel::sfp_bc(Container::Bf16).network(net, batch)),
        ("sfp_qm", FootprintModel::sfp_qm(Container::Bf16).network(net, batch)),
    ];
    let base = rows[0].1.total();
    let mut csv = CsvSink::create(
        path,
        &[
            "variant_idx",
            "w_sign",
            "w_exp",
            "w_mant",
            "w_meta",
            "a_sign",
            "a_exp",
            "a_mant",
            "a_meta",
            "total_rel_fp32",
        ],
    )?;
    for (i, (_, f)) in rows.iter().enumerate() {
        csv.row(&[
            i as f64,
            f.weights.sign / base,
            f.weights.exponent / base,
            f.weights.mantissa / base,
            f.weights.metadata / base,
            f.activations.sign / base,
            f.activations.exponent / base,
            f.activations.mantissa / base,
            f.activations.metadata / base,
            f.total() / base,
        ])?;
    }
    csv.flush()
}

/// Fig 13: cumulative activation footprint comparison.
pub fn fig13(path: &Path, net: &NetworkTrace, batch: usize) -> Result<()> {
    let rows = fig13_rows(net, batch);
    let mut csv = CsvSink::create(path, &["scheme_idx", "bits", "rel_bf16"])?;
    let bf16 = rows[0].bits;
    for (i, r) in rows.iter().enumerate() {
        csv.row(&[i as f64, r.bits, r.bits / bf16])?;
    }
    csv.flush()
}

/// Emit one trace-source figure (ids 9, 10, 12, 13) into `dir`, returning
/// the file names written — the figure half of `repro fig` factored out so
/// lab figure jobs and the CLI share one driver.
pub fn trace_figure(dir: &Path, id: usize, batch: usize, sample: usize) -> Result<Vec<String>> {
    std::fs::create_dir_all(dir)?;
    match id {
        9 => {
            let (hw, ha) = fig9_from_trace(&resnet18(), sample);
            fig9_exponents(&dir.join("fig9_exponents.csv"), &hw, &ha)?;
            Ok(vec!["fig9_exponents.csv".into()])
        }
        10 => {
            let (cw, ca) = fig10_from_trace(&resnet18(), sample);
            fig10_cdf(&dir.join("fig10_gecko_cdf.csv"), &cw, &ca)?;
            Ok(vec!["fig10_gecko_cdf.csv".into()])
        }
        12 => {
            let mut out = Vec::new();
            for net in [resnet18(), mobilenet_v3_small()] {
                let name = format!("fig12_components_{}.csv", net.name.to_lowercase());
                fig12_components(&dir.join(&name), &net, batch)?;
                out.push(name);
            }
            Ok(out)
        }
        13 => {
            let mut out = Vec::new();
            for net in [resnet18(), mobilenet_v3_small()] {
                let name = format!("fig13_activation_{}.csv", net.name.to_lowercase());
                fig13(&dir.join(&name), &net, batch)?;
                out.push(name);
            }
            Ok(out)
        }
        other => Err(anyhow!("not a trace-source figure id: {other} (9|10|12|13)")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::traces::resnet18;

    fn tdir() -> std::path::PathBuf {
        let d = std::env::temp_dir().join("sfp_fig_test");
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn fig9_trace_is_biased_around_127() {
        let (hw, ha) = fig9_from_trace(&resnet18(), 4096);
        assert!(hw.mass_near_bias(10) > 0.95);
        // activations carry a zero spike at bin 0 plus near-bias mass
        let zero_frac = ha.bins[0] as f64 / ha.total as f64;
        assert!(zero_frac > 0.2, "zero spike {zero_frac}");
        assert!(ha.mass_near_bias(10) + zero_frac > 0.95);
        fig9_exponents(&tdir().join("fig9.csv"), &hw, &ha).unwrap();
    }

    #[test]
    fn fig10_trace_matches_paper_claims() {
        // §IV-C: "almost 90% of the exponents become lower than 16" (≤5 b
        // encoded incl. sign) and ≥20% of weights / 40% of acts at 1 bit.
        let (cw, ca) = fig10_from_trace(&resnet18(), 64 * 256);
        assert!(cw.cdf_at(5) > 0.85, "weights ≤5b: {}", cw.cdf_at(5));
        assert!(ca.cdf_at(5) > 0.80, "acts ≤5b: {}", ca.cdf_at(5));
        assert!(cw.cdf_at(1) > 0.08, "weights 1b: {}", cw.cdf_at(1));
        assert!(ca.cdf_at(1) > 0.22, "acts 1b: {}", ca.cdf_at(1));
        fig10_cdf(&tdir().join("fig10.csv"), &cw, &ca).unwrap();
    }

    #[test]
    fn footprint_over_time_emits() {
        use crate::coordinator::train::EpochStats;
        use crate::stash::EpochTraffic;
        let mut run = RunResult::default();
        for i in 0..3 {
            run.stash_epochs.push(EpochTraffic {
                written_bits: 8e6 * (3.0 - i as f64),
                read_bits: 8e6 * (3.0 - i as f64),
                written_fp32_bits: 32e6,
                ..Default::default()
            });
            run.epochs.push(EpochStats {
                epoch: i,
                mean_bits_a: 7.0 - i as f64,
                mean_exp_bits_a: 8.0 - i as f64,
                ..Default::default()
            });
        }
        let p = tdir().join("fpot.csv");
        footprint_over_time(&p, &run).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text.lines().count(), 4);
        assert!(text.starts_with("epoch,written_mb"));
        // no recorded events: the bitlength column is the measured mean
        let row0: Vec<&str> = text.lines().nth(1).unwrap().split(',').collect();
        assert_eq!(row0[6].parse::<f64>().unwrap(), 7.0);
    }

    #[test]
    fn footprint_over_time_replays_recorded_events() {
        use crate::coordinator::train::EpochStats;
        use crate::stash::EpochTraffic;
        use std::borrow::Cow;
        let mut run = RunResult::default();
        for i in 0..3 {
            run.stash_epochs.push(EpochTraffic {
                written_bits: 8e6,
                written_fp32_bits: 32e6,
                ..Default::default()
            });
            run.epochs.push(EpochStats {
                epoch: i,
                mean_bits_a: 4.2, // measured mean: must NOT be used
                mean_exp_bits_a: 8.0,
                per_layer_bits_a: vec![8.0, 8.0],
                ..Default::default()
            });
        }
        let bit = |epoch, step, layer: Option<usize>, from: f64, to: f64| AdaptEvent {
            ts_us: 0,
            pid: 1,
            kind: Cow::Borrowed("bitlength"),
            source: Cow::Borrowed("qm"),
            trigger: Cow::Borrowed("qm_gradient_step"),
            layer,
            tensor_class: Some(Cow::Borrowed("act")),
            component: Some(Cow::Borrowed("mant")),
            epoch: Some(epoch),
            step: Some(step),
            from,
            to,
            detail: None,
            arg_job: None,
            owner: None,
        };
        // layer 0 drops 8→7 in epoch 0, then 7→5 in epoch 2; layer 1
        // never adapts and keeps its recorded starting width (8)
        run.events = vec![bit(2, 80, Some(0), 7.0, 5.0), bit(0, 10, Some(0), 8.0, 7.0)];
        let p = tdir().join("fpot_replay.csv");
        footprint_over_time(&p, &run).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        let means: Vec<f64> = text
            .lines()
            .skip(1)
            .map(|l| l.split(',').nth(6).unwrap().parse().unwrap())
            .collect();
        assert_eq!(means, vec![7.5, 7.5, 6.5]);
        // exponent stream recorded nothing: measured fallback holds
        let exps: Vec<f64> = text
            .lines()
            .skip(1)
            .map(|l| l.split(',').nth(7).unwrap().parse().unwrap())
            .collect();
        assert_eq!(exps, vec![8.0, 8.0, 8.0]);

        // a network-wide (layer: None) event rewrites every layer
        run.events.push(bit(1, 40, None, 8.0, 6.0));
        footprint_over_time(&p, &run).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        let means: Vec<f64> = text
            .lines()
            .skip(1)
            .map(|l| l.split(',').nth(6).unwrap().parse().unwrap())
            .collect();
        assert_eq!(means, vec![7.5, 6.0, 5.5]);
    }

    #[test]
    fn fig12_and_13_emit() {
        fig12_components(&tdir().join("fig12.csv"), &resnet18(), 64).unwrap();
        fig13(&tdir().join("fig13.csv"), &resnet18(), 64).unwrap();
        let text = std::fs::read_to_string(tdir().join("fig12.csv")).unwrap();
        assert_eq!(text.lines().count(), 5);
    }
}
