//! Table/figure generation — one driver per experiment in DESIGN.md §4.

pub mod footprint;
pub mod figures;
pub mod tables;

pub use footprint::{fig13_rows, Fig13Row, FootprintModel, MantissaPolicy};
