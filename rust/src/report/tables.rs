//! Table I / Table II drivers (and the hwsim coupling they share).

use super::footprint::{stash_measured_bits, FootprintModel, MantissaPolicy};
use crate::formats::Container;
use crate::hwsim::{gains, simulate_pass_with_bits, AccelConfig, ComputeType, LayerBits, PassStats};
use crate::stash::CodecKind;
use crate::traces::{mobilenet_v3_small, resnet18, NetworkTrace};

/// One Table I row: footprint relative to FP32 for each variant.
#[derive(Debug, Clone)]
pub struct Table1Row {
    pub network: String,
    pub bf16_rel: f64,
    pub qm_rel: f64,
    pub bc_rel: f64,
}

/// Regenerate Table I's footprint columns from the trace models.
pub fn table1() -> Vec<Table1Row> {
    [resnet18(), mobilenet_v3_small()]
        .into_iter()
        .map(|net| {
            let fp32 = FootprintModel::fp32().network(&net, 256);
            let bf16 = FootprintModel::bf16().network(&net, 256);
            let qm = FootprintModel::sfp_qm(Container::Bf16).network(&net, 256);
            let bc = FootprintModel::sfp_bc(Container::Bf16).network(&net, 256);
            Table1Row {
                network: net.name.clone(),
                bf16_rel: bf16.relative_to(&fp32),
                qm_rel: qm.relative_to(&fp32),
                bc_rel: bc.relative_to(&fp32),
            }
        })
        .collect()
}

/// One Table II row: speedup and energy-efficiency gain vs FP32.
#[derive(Debug, Clone)]
pub struct Table2Row {
    pub network: String,
    pub bf16: (f64, f64),
    pub qm: (f64, f64),
    pub bc: (f64, f64),
    /// Fraction of layer passes that are memory bound at FP32 / under QM.
    pub membound_fp32: f64,
    pub membound_qm: f64,
}

fn pass_for(
    cfg: &AccelConfig,
    net: &NetworkTrace,
    batch: usize,
    model: &FootprintModel,
    compute: ComputeType,
) -> PassStats {
    let n = net.layers.len().max(1);
    let bits: Vec<LayerBits> = net
        .layers
        .iter()
        .enumerate()
        .map(|(i, l)| {
            let lf = model.layer(l, i as f64 / n as f64, batch, 0xBEEF ^ i as u64);
            LayerBits {
                weight: lf.total_weight_bits(),
                act: lf.total_act_bits(),
            }
        })
        .collect();
    simulate_pass_with_bits(cfg, net, batch, compute, &bits)
}

/// Regenerate Table II from the trace models + hwsim.
pub fn table2(cfg: &AccelConfig, batch: usize) -> Vec<Table2Row> {
    [resnet18(), mobilenet_v3_small()]
        .into_iter()
        .map(|net| {
            let fp32 = pass_for(cfg, &net, batch, &FootprintModel::fp32(), ComputeType::Fp32);
            let bf16 = pass_for(cfg, &net, batch, &FootprintModel::bf16(), ComputeType::Bf16);
            let qm = pass_for(
                cfg,
                &net,
                batch,
                &FootprintModel::sfp_qm(Container::Bf16),
                ComputeType::Bf16,
            );
            let bc = pass_for(
                cfg,
                &net,
                batch,
                &FootprintModel::sfp_bc(Container::Bf16),
                ComputeType::Bf16,
            );
            Table2Row {
                network: net.name.clone(),
                bf16: gains(&fp32, &bf16),
                qm: gains(&fp32, &qm),
                bc: gains(&fp32, &bc),
                membound_fp32: fp32.memory_bound_layers as f64 / fp32.total_layer_passes as f64,
                membound_qm: qm.memory_bound_layers as f64 / qm.total_layer_passes as f64,
            }
        })
        .collect()
}

/// Table II with the SFP columns' per-layer bits *measured* through the
/// stash (`repro table2 --source stash`) instead of the analytic footprint
/// model — the raw-container baselines stay analytic because dense
/// containers are exact by construction.
pub fn table2_stash(cfg: &AccelConfig, batch: usize) -> anyhow::Result<Vec<Table2Row>> {
    [resnet18(), mobilenet_v3_small()]
        .into_iter()
        .map(|net| -> anyhow::Result<Table2Row> {
            let n = net.layers.len();
            let fp32 = pass_for(cfg, &net, batch, &FootprintModel::fp32(), ComputeType::Fp32);
            let bf16 = pass_for(cfg, &net, batch, &FootprintModel::bf16(), ComputeType::Bf16);
            let qm_sched = MantissaPolicy::qm_default().integer_schedule(n, Container::Bf16);
            let qm_bits =
                stash_measured_bits(&net, &qm_sched, Container::Bf16, batch, CodecKind::Gecko)?;
            let qm = simulate_pass_with_bits(cfg, &net, batch, ComputeType::Bf16, &qm_bits);
            let bc_sched =
                MantissaPolicy::bc_default(Container::Bf16).integer_schedule(n, Container::Bf16);
            let bc_bits =
                stash_measured_bits(&net, &bc_sched, Container::Bf16, batch, CodecKind::Gecko)?;
            let bc = simulate_pass_with_bits(cfg, &net, batch, ComputeType::Bf16, &bc_bits);
            Ok(Table2Row {
                network: net.name.clone(),
                bf16: gains(&fp32, &bf16),
                qm: gains(&fp32, &qm),
                bc: gains(&fp32, &bc),
                membound_fp32: fp32.memory_bound_layers as f64 / fp32.total_layer_passes as f64,
                membound_qm: qm.memory_bound_layers as f64 / qm.total_layer_passes as f64,
            })
        })
        .collect()
}

/// Table I rows as a deterministic JSON array (the lab's `table1` job
/// artifact).
pub fn table1_json(rows: &[Table1Row]) -> crate::util::json::Json {
    use crate::util::json::Json;
    use std::collections::BTreeMap;
    Json::Arr(
        rows.iter()
            .map(|r| {
                let mut m = BTreeMap::new();
                m.insert("network".to_string(), Json::Str(r.network.clone()));
                m.insert("bf16_rel".to_string(), Json::Num(r.bf16_rel));
                m.insert("qm_rel".to_string(), Json::Num(r.qm_rel));
                m.insert("bc_rel".to_string(), Json::Num(r.bc_rel));
                Json::Obj(m)
            })
            .collect(),
    )
}

/// Table II rows as a deterministic JSON array (the lab's `table2` job
/// artifact).
pub fn table2_json(rows: &[Table2Row]) -> crate::util::json::Json {
    use crate::util::json::Json;
    use std::collections::BTreeMap;
    Json::Arr(
        rows.iter()
            .map(|r| {
                let mut m = BTreeMap::new();
                m.insert("network".to_string(), Json::Str(r.network.clone()));
                m.insert("bf16_speedup".to_string(), Json::Num(r.bf16.0));
                m.insert("bf16_energy".to_string(), Json::Num(r.bf16.1));
                m.insert("qm_speedup".to_string(), Json::Num(r.qm.0));
                m.insert("qm_energy".to_string(), Json::Num(r.qm.1));
                m.insert("bc_speedup".to_string(), Json::Num(r.bc.0));
                m.insert("bc_energy".to_string(), Json::Num(r.bc.1));
                m.insert("membound_fp32".to_string(), Json::Num(r.membound_fp32));
                m.insert("membound_qm".to_string(), Json::Num(r.membound_qm));
                Json::Obj(m)
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_shape_matches_paper() {
        let rows = table1();
        let rn = &rows[0];
        assert!((rn.bf16_rel - 0.5).abs() < 1e-9);
        // Paper: RN18 QM 14.7%, BC 23.7%; MNv3 24.9% / 27.2%.
        assert!((0.10..0.22).contains(&rn.qm_rel), "{}", rn.qm_rel);
        assert!((0.17..0.32).contains(&rn.bc_rel), "{}", rn.bc_rel);
        let mv = &rows[1];
        assert!(mv.qm_rel > rn.qm_rel, "MNv3 compresses worse");
        assert!(mv.qm_rel <= mv.bc_rel + 1e-9, "QM <= BC");
    }

    #[test]
    fn table2_shape_matches_paper() {
        let rows = table2(&AccelConfig::default(), 256);
        for r in &rows {
            // Paper Table II bands: BF16 1.53-1.72×, SFP 2.15-2.37× speed;
            // BF16 2.0×, SFP_QM 3.95-6.12×, SFP_BC 3.84-4.54× energy.
            assert!((1.2..2.0).contains(&r.bf16.0), "{} bf16 speed {}", r.network, r.bf16.0);
            // NOTE: MobileNetV3 overshoots the paper's 2.37x (we get ~4x)
            // because the analytic roofline underestimates its compute
            // floor — recorded as a known deviation in EXPERIMENTS.md.
            assert!((1.8..4.6).contains(&r.qm.0), "{} qm speed {}", r.network, r.qm.0);
            assert!((1.8..4.6).contains(&r.bc.0), "{} bc speed {}", r.network, r.bc.0);
            assert!((r.bf16.1 - 2.0).abs() < 0.1, "{} bf16 energy {}", r.network, r.bf16.1);
            assert!((3.0..7.5).contains(&r.qm.1), "{} qm energy {}", r.network, r.qm.1);
            assert!((2.8..6.0).contains(&r.bc.1), "{} bc energy {}", r.network, r.bc.1);
            // who-wins ordering
            assert!(r.qm.0 >= r.bc.0 - 0.05, "qm >= bc speed");
            assert!(r.qm.1 > r.bc.1 - 0.05, "qm >= bc energy");
            assert!(r.qm.0 > r.bf16.0, "sfp beats bf16");
        }
    }

    #[test]
    fn table2_stash_source_tracks_analytic() {
        // measured-bytes Table II must land near the analytic table (the
        // gecko stash layout matches the analytic accounting bit-for-bit,
        // so gains differ only by sampling-scale rounding)
        let analytic = table2(&AccelConfig::default(), 256);
        let measured = table2_stash(&AccelConfig::default(), 256).unwrap();
        for (a, m) in analytic.iter().zip(&measured) {
            assert_eq!(a.network, m.network);
            assert!(
                (a.qm.0 - m.qm.0).abs() / a.qm.0 < 0.05,
                "{}: qm speed {} vs {}",
                a.network,
                a.qm.0,
                m.qm.0
            );
            assert!(
                (a.qm.1 - m.qm.1).abs() / a.qm.1 < 0.05,
                "{}: qm energy {} vs {}",
                a.network,
                a.qm.1,
                m.qm.1
            );
            assert!(m.bc.0 > 1.0 && m.bc.1 > 1.0);
        }
    }

    #[test]
    fn compression_shifts_layers_compute_bound() {
        // §VI-C: "layers that were previously memory bound ... now becoming
        // compute bound".
        let rows = table2(&AccelConfig::default(), 256);
        for r in &rows {
            assert!(
                r.membound_qm < r.membound_fp32,
                "{}: {} -> {}",
                r.network,
                r.membound_fp32,
                r.membound_qm
            );
        }
    }
}
