//! `repro` — the Schrödinger's FP leader binary.
//!
//! Subcommands (DESIGN.md §4 experiment index):
//!   train    run one training variant end-to-end through PJRT (a cached
//!            lab job: identical configs reuse the cached run)
//!   table1   footprint columns of Table I (trace models)
//!   table2   performance / energy of Table II (hwsim)
//!   fig      regenerate a figure's CSV (--id 2|3|4|6|7|8|9|10|12|13)
//!   compress demo the Gecko/SFP codecs on a synthetic tensor
//!   stash    stash-subsystem sweep over a trace model: store/restore real
//!            compressed tensors, cross-check stored bytes against the
//!            analytic footprint model (runs as lab jobs, one per budget)
//!   serve    multi-tenant stash-service load scenario: N simulated training
//!            sessions lease slices of one shared chunk arena; emits
//!            serve_sweep.json with per-tenant restore latency (DRAM hit vs
//!            spill fault), throughput, and the fair-eviction probe verdict
//!   policy   adaptation-policy sweep over the trace models through the
//!            unified BitPolicy engine (runs as parallel lab jobs)
//!   all      materialize the paper grid — policies × models, codecs ×
//!            budgets, tables, figures, e2e variants when artifacts exist —
//!            as one lab DAG: parallel, dependency-aware, and served from
//!            the content-addressed cache on warm re-runs
//!   inspect  read a run's flight-recorder outputs (manifest + metrics +
//!            events.jsonl): health summary, per-layer bitlength
//!            trajectories, two-run diffs, and perf-regression gating
//!            against a checked-in BENCH_*.json baseline
//!
//! Every sweep executes through `sfp::lab`: jobs are content-hashed
//! configs, results live in a content-addressed cache, and each run emits
//! a `lab_manifest.json` of every artifact + hash + timing.

use anyhow::{anyhow, Result};
use sfp::coordinator::Variant;
use sfp::formats::Container;
use sfp::hwsim::AccelConfig;
use sfp::lab::{
    self, JobGraph, JobReport, JobSpec, JobStatus, ResultCache, ServeSpec, StashSpec, TrainSpec,
};
use sfp::obs::{self, Level, ObsConfig, ProgressLine};
use sfp::policy::sweep::{self, PolicyKind, SweepConfig};
use sfp::report::footprint::{SAMPLE, STREAM_SEED};
use sfp::report::{figures, tables};
use sfp::runtime::Runtime;
use sfp::sfp::SfpCodec;
use sfp::stash::CodecKind;
use sfp::stats::ExponentHistogram;
use sfp::traces::ValueModel;
use sfp::util::cli::Args;
use sfp::util::json::Json;
use sfp::{oerror, oinfo, overbose};
use std::path::{Path, PathBuf};
use std::time::Instant;

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    let code = match run(cmd, &args) {
        Ok(()) => 0,
        Err(e) => {
            oerror!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(cmd: &str, args: &Args) -> Result<()> {
    let level = if args.has_flag("quiet") || args.has_flag("q") {
        Level::Quiet
    } else if args.has_flag("verbose") || args.has_flag("v") {
        Level::Verbose
    } else {
        Level::Normal
    };
    let tracing = args.get("trace").is_some() || std::env::var("SFP_TRACE").as_deref() == Ok("1");
    obs::init(&ObsConfig { tracing, level });
    match cmd {
        "train" => cmd_train(args),
        "table1" => cmd_table1(args),
        "table2" => cmd_table2(args),
        "fig" => cmd_fig(args),
        "compress" => cmd_compress(args),
        "stash" => cmd_stash(args),
        "serve" => cmd_serve(args),
        "policy" => cmd_policy(args),
        "all" => cmd_all(args),
        "inspect" => cmd_inspect(args),
        "worker" => cmd_worker(args),
        _ => {
            print_help();
            Ok(())
        }
    }
}

fn print_help() {
    oinfo!(
        "repro — Schrödinger's FP reproduction\n\
         \n\
         USAGE: repro <command> [--options]\n\
         \n\
         train     --variant fp32|bf16|qm|bc|qmqe|bw [--container bf16|fp32]\n\
         \u{20}         [--epochs N] [--steps N] [--out DIR] [--artifacts DIR]\n\
         \u{20}         [--stash gecko|sfp|raw|js] (store real compressed tensors per step)\n\
         \u{20}         [--budget-bytes N] (arena DRAM budget; cold chunks spill to disk)\n\
         table1    print Table I footprint columns (trace models)\n\
         table2    print Table II perf/energy (hwsim) [--batch N] [--source model|stash]\n\
         fig       --id 2|3|4|6|7|8|9|10|12|13 [--out DIR] [--source trace|e2e]\n\
         compress  codec demo [--count N] [--mantissa N]\n\
         stash     --model resnet18|mobilenet [--policy qm|bc|full]\n\
         \u{20}         [--codec gecko|sfp|raw|js] [--batch N] [--sample N]\n\
         \u{20}         [--budget-bytes N[,N...]] (spill-tier sweep axis; JSON in <out>)\n\
         \u{20}         [--layout width:B|bias:B:BIAS|block:BLK[:BITS]] (exponent\n\
         \u{20}         container layout; default per-value width, delta-coded)\n\
         serve     --tenants N[,N...] (session-fleet scaling axis, default 1,8,64)\n\
         \u{20}         [--model resnet18|mobilenet] [--policy qm|bc|full]\n\
         \u{20}         [--codec gecko|sfp|raw|js] [--steps N] [--sample N]\n\
         \u{20}         [--budget-bytes N] (per-lease DRAM budget; cold runs spill)\n\
         \u{20}         [--smoke] (tiny CI scenario) [--expect-cached]\n\
         \u{20}         leased facades share one arena; emits <out>/serve_sweep.json\n\
         policy    --model resnet18|mobilenet|all\n\
         \u{20}         [--policy qmqe|bitwave|qm|af|flexpoint|fp8|bf16|all]\n\
         \u{20}         [--epochs N] [--steps N] [--batch N] [--sample N] [--out DIR]\n\
         \u{20}         [--verify-restore] (check mid-run checkpoint/restore continuity)\n\
         \u{20}         cross-paper families (AdaptivFloat windows, Flexpoint block\n\
         \u{20}         exponents, fp8/bf16 presets) land in <out>/crosspaper.json\n\
         all       materialize the paper grid as one parallel, cached lab run\n\
         \u{20}         [--smoke] (tiny CI grid) [--serial] [--jobs N] [--cache DIR]\n\
         \u{20}         [--budget-bytes N[,N...]] [--artifacts DIR] [--out DIR]\n\
         \u{20}         [--expect-cached] (fail unless 100% cache hits, zero executed)\n\
         \u{20}         [--backend process --workers N] (subprocess execution backend)\n\
         inspect   RUN_DIR [RUN_DIR2] — flight-recorder readout of a lab run:\n\
         \u{20}         health summary, per-layer bitlength and exponent-layout\n\
         \u{20}         trajectories from\n\
         \u{20}         events.jsonl, and (with RUN_DIR2) a two-run diff of artifact\n\
         \u{20}         fingerprints, per-job wall-clock, and metrics counters.\n\
         \u{20}         [--baseline BENCH.json [--gate PCT]] fails on perf regression\n\
         \u{20}         (wall clock above baseline + PCT%); [--write-baseline FILE]\n\
         \u{20}         records the current run as the new baseline\n\
         worker    serve lab jobs from stdin against a shared cache (spawned by\n\
         \u{20}         the process backend; not normally run by hand) --cache DIR\n\
         \n\
         every lab-backed command also takes --backend inprocess|process and\n\
         --workers N: the process backend ships jobs to `repro worker`\n\
         subprocesses over the shared content-addressed cache, so artifacts\n\
         stay byte-identical and a crashed worker only fails its own job.\n\
         \n\
         global flags: --quiet/-q (errors only), -v/--verbose (extra\n\
         diagnostics), --trace FILE (write a Chrome trace-event JSON of\n\
         Trainer/stash/lab spans plus flight-recorder counter tracks —\n\
         resident/spill bytes, stash queue depth, cache hit ratio, worker\n\
         utilization; Perfetto-loadable; also enabled by SFP_TRACE=1).\n\
         Tracing never changes artifact bytes: manifests and cached\n\
         artifacts stay fingerprint-identical with it on.\n\
         \n\
         lab runs write <out>/lab_manifest.json (every job: artifacts + hash +\n\
         timing), a <out>/metrics.json latency/counter snapshot, and the\n\
         flight recorder's <out>/events.jsonl adaptation-event stream (always\n\
         on; plus <out>/timeseries.json when traced) — written even when a\n\
         run aborts partway — and reuse the content-addressed cache in\n\
         <out>/lab-cache.  `repro inspect <out>` reads them all back."
    );
}

fn container_of(args: &Args) -> Container {
    match args.get_or("container", "bf16").as_str() {
        "fp32" => Container::Fp32,
        _ => Container::Bf16,
    }
}

fn out_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.get_or("out", "results"))
}

fn open_cache(args: &Args) -> Result<ResultCache> {
    let dir = match args.get("cache") {
        Some(d) => PathBuf::from(d),
        None => out_dir(args).join("lab-cache"),
    };
    ResultCache::open(&dir)
}

fn parse_budgets(args: &Args, default: Vec<usize>) -> Result<Vec<usize>> {
    match args.get("budget-bytes") {
        None => Ok(default),
        Some(s) => {
            let mut v = Vec::new();
            for tok in s.split(',') {
                v.push(tok.trim().parse::<usize>().map_err(|_| {
                    anyhow!("bad --budget-bytes entry '{tok}' (comma-separated bytes; 0 = unlimited)")
                })?);
            }
            Ok(v)
        }
    }
}

/// Run a lab graph in the mode the flags select; any failed job is a
/// command failure (after the manifest and every healthy branch landed).
/// `--serial` is the deterministic in-process reference; `--backend
/// process` dispatches cache misses to `repro worker` subprocesses
/// (`--workers N` of them, sharing the content-addressed cache).
///
/// When the run itself aborts (bad backend, spawn failure, poisoned
/// scheduler) the flight-recorder exports still land in `<out>` — a
/// partial run's metrics and events are exactly what diagnosis needs.
fn run_lab(
    graph: &JobGraph,
    cache: &ResultCache,
    args: &Args,
) -> Result<(Vec<JobReport>, f64, &'static str)> {
    let res = run_lab_inner(graph, cache, args);
    if res.is_err() {
        let dir = out_dir(args);
        let flushed = std::fs::create_dir_all(&dir)
            .map_err(anyhow::Error::from)
            .and_then(|()| write_obs_exports(args, &dir));
        if let Err(e) = flushed {
            oerror!("flight-recorder export after aborted run failed: {e:#}");
        }
    }
    res
}

fn run_lab_inner(
    graph: &JobGraph,
    cache: &ResultCache,
    args: &Args,
) -> Result<(Vec<JobReport>, f64, &'static str)> {
    let t0 = Instant::now();
    let workers = args.get_usize("workers", args.get_usize("jobs", 0));
    let resolved = if args.has_flag("serial") {
        1
    } else {
        lab::resolve_workers(graph, workers)
    };
    // live single-line readout on stderr (TTY only; inert otherwise)
    let _progress = ProgressLine::start(graph.len(), resolved);
    // pull-style lab gauges (cache hit ratio, worker utilization, jobs in
    // flight) sampled while the grid runs; inert unless tracing is on
    let _sampler = obs::LabSampler::start(resolved);
    let (reports, mode) = if args.has_flag("serial") {
        (lab::run_serial(graph, cache), "serial")
    } else {
        match args.get_or("backend", "inprocess").as_str() {
            "inprocess" => (lab::run_parallel(graph, cache, workers), "parallel"),
            "process" => {
                // one worker subprocess per scheduler thread, in lockstep
                // with run_with_backend's own resolution
                let backend = lab::ProcessBackend::new(cache.root(), resolved, None)?;
                (
                    lab::run_with_backend(graph, cache, resolved, &backend),
                    "process",
                )
            }
            other => return Err(anyhow!("unknown --backend {other} (inprocess|process)")),
        }
    };
    Ok((reports, t0.elapsed().as_secs_f64() * 1e3, mode))
}

fn fail_on_errors(reports: &[JobReport]) -> Result<()> {
    let failures: Vec<String> = reports
        .iter()
        .filter_map(|r| match &r.status {
            JobStatus::Failed(e) => Some(format!("{}: {e}", r.label)),
            _ => None,
        })
        .collect();
    if failures.is_empty() {
        Ok(())
    } else {
        Err(anyhow!("{} lab job(s) failed:\n  {}", failures.len(), failures.join("\n  ")))
    }
}

/// Flight-recorder exports after a lab run: the `metrics.json` snapshot
/// and the `events.jsonl` adaptation-event stream (always on) next to
/// `lab_manifest.json`, plus — when tracing — the drained counter samples
/// as `timeseries.json` and the Chrome trace (spans + counter tracks)
/// at `--trace PATH`.  Exports read only process-global sinks — they
/// never touch the cache or the manifest.
fn write_obs_exports(args: &Args, dir: &Path) -> Result<()> {
    obs::metrics::write_snapshot(&dir.join("metrics.json"))?;
    let adapt = obs::events::take_events();
    obs::events::write_jsonl(&dir.join("events.jsonl"), &adapt)?;
    if !adapt.is_empty() {
        overbose!("events: {} adaptation events -> events.jsonl", adapt.len());
    }
    let samples = obs::timeseries::take_samples();
    if !samples.is_empty() {
        obs::timeseries::write_json(&dir.join("timeseries.json"), &samples)?;
    }
    if let Some(path) = args.get("trace") {
        let n = obs::trace::write_chrome_trace_with(Path::new(path), &samples)?;
        oinfo!("trace: {n} events -> {path}");
    }
    Ok(())
}

/// Append one `{"kind":"restore_latency_summary",...}` row (p50/p99 per
/// tier: DRAM hit vs. spill fault) to the *surfaced* copy of
/// `stash_sweep.json`.  The cached artifact is never touched — latency is
/// an observation of this process, not part of the content-addressed
/// result — and a run that restored nothing (e.g. fully cached) appends
/// nothing.
fn append_restore_latency_summary(path: &Path) -> Result<()> {
    let dram = obs::metrics::RESTORE_DRAM_US.summary();
    let fault = obs::metrics::RESTORE_FAULT_US.summary();
    if dram.count + fault.count == 0 {
        return Ok(());
    }
    let text = std::fs::read_to_string(path)?;
    let parsed = Json::parse(&text).map_err(|e| anyhow!("parse {}: {e}", path.display()))?;
    let Json::Arr(mut rows) = parsed else {
        return Err(anyhow!("{} is not a JSON array", path.display()));
    };
    let mut row = std::collections::BTreeMap::new();
    row.insert(
        "kind".to_string(),
        Json::Str("restore_latency_summary".to_string()),
    );
    row.insert("dram_hit_us".to_string(), dram.to_json());
    row.insert("spill_fault_us".to_string(), fault.to_json());
    rows.push(Json::Obj(row));
    std::fs::write(path, Json::Arr(rows).to_string())?;
    Ok(())
}

/// Copy one job's cached artifacts to `dest`, optionally renaming a
/// single-artifact job's file.  The report's artifact list was verified
/// when the run resolved the job, so the files are read directly.
fn surface_artifacts(
    cache: &ResultCache,
    report: &JobReport,
    dest: &Path,
    rename: Option<&str>,
) -> Result<()> {
    let src = cache.entry_artifacts_dir(&report.kind, &report.hash);
    std::fs::create_dir_all(dest)?;
    for a in &report.artifacts {
        let to = match rename {
            Some(name) if report.artifacts.len() == 1 => dest.join(name),
            _ => dest.join(&a.rel),
        };
        if let Some(p) = to.parent() {
            std::fs::create_dir_all(p)?;
        }
        std::fs::copy(src.join(&a.rel), &to)?;
    }
    Ok(())
}

/// Read one named JSON artifact of a completed job.
fn job_artifact_json(cache: &ResultCache, report: &JobReport, name: &str) -> Result<Json> {
    let path = cache.entry_artifacts_dir(&report.kind, &report.hash).join(name);
    let text = std::fs::read_to_string(&path)
        .map_err(|e| anyhow!("read {} of {}: {e}", path.display(), report.label))?;
    Json::parse(&text).map_err(|e| anyhow!("parse {name} of {}: {e}", report.label))
}

// --------------------------------------------------------------------------
// train
// --------------------------------------------------------------------------

fn train_spec(args: &Args, variant: &str) -> Result<TrainSpec> {
    let container = container_of(args);
    if Variant::parse(variant, container).is_none() {
        return Err(anyhow!("unknown --variant {variant}"));
    }
    let stash_codec = match args.get("stash") {
        None => None,
        Some(s) => Some(
            CodecKind::parse(s).ok_or_else(|| anyhow!("unknown --stash codec {s} (gecko|sfp|raw|js)"))?,
        ),
    };
    let artifacts_dir = args.get_or("artifacts", "artifacts");
    let manifest = Path::new(&artifacts_dir).join("manifest.json");
    let manifest_hash = lab::hash::file_hash(&manifest)
        .map_err(|e| anyhow!("no AOT artifacts at {}: {e} (run `make artifacts`)", manifest.display()))?;
    Ok(TrainSpec {
        variant: variant.to_string(),
        container,
        epochs: args.get_usize("epochs", 6),
        steps_per_epoch: args.get_usize("steps", 40),
        eval_batches: args.get_usize("eval-batches", 4),
        lr0: args.get_f64("lr", 0.05),
        momentum: args.get_f64("momentum", 0.9),
        seed: args.get_usize("seed", 42) as u64,
        stash_codec,
        budget_bytes: args.get_usize("budget-bytes", 0),
        artifacts_dir,
        manifest_hash,
    })
}

/// Train as a single-job lab graph: identical configs against unchanged
/// AOT artifacts come straight out of the cache.
fn cmd_train(args: &Args) -> Result<()> {
    let variant_names = args.get_or("variant", "qm");
    let cache = open_cache(args)?;
    let mut graph = JobGraph::new();
    let mut specs = Vec::new();
    for name in variant_names.split(',') {
        let spec = train_spec(args, name.trim())?;
        specs.push(spec.clone());
        graph.push(JobSpec::Train(spec), vec![]);
    }
    let (reports, wall_ms, mode) = run_lab(&graph, &cache, args)?;
    let dir = out_dir(args);
    lab::write_manifest(&dir.join("lab_manifest.json"), &reports, wall_ms, mode)?;
    write_obs_exports(args, &dir)?;
    fail_on_errors(&reports)?;
    for (report, spec) in reports.iter().zip(&specs) {
        let label = Variant::parse(&spec.variant, spec.container)
            .expect("validated above")
            .label();
        let j = job_artifact_json(&cache, report, &format!("{label}_summary.json"))?;
        let num = |k: &str| j.get(k).and_then(Json::as_f64).unwrap_or(f64::NAN);
        oinfo!(
            "variant={label}{}",
            if report.status == JobStatus::Cached { " [cached]" } else { "" }
        );
        oinfo!("final_val_acc={:.4}", num("final_val_acc"));
        oinfo!("footprint_rel_fp32={:.4}", num("footprint_rel_fp32"));
        oinfo!("footprint_rel_bf16={:.4}", num("footprint_rel_bf16"));
        if j.get("stash_written_bits").is_some() {
            oinfo!(
                "stash: wrote {:.1} MB / read {:.1} MB compressed ({:.1}% of FP32)",
                num("stash_written_bits") / 8e6,
                num("stash_read_bits") / 8e6,
                100.0 * num("stash_ratio_vs_fp32"),
            );
        }
        surface_artifacts(&cache, report, &dir, None)?;
    }
    oinfo!("artifacts -> {}", dir.display());
    Ok(())
}

// --------------------------------------------------------------------------
// tables / figures / compress (direct, cheap paths)
// --------------------------------------------------------------------------

fn cmd_table1(_args: &Args) -> Result<()> {
    oinfo!("Table I — total footprint vs FP32 (trace models; paper values in brackets)");
    oinfo!("{:<22} {:>10} {:>16} {:>16}", "Network", "BF16", "SFP_QM", "SFP_BC");
    let paper = [("ResNet18", 0.147, 0.237), ("MobileNetV3-Small", 0.249, 0.272)];
    for (row, (pname, pqm, pbc)) in tables::table1().iter().zip(paper) {
        assert_eq!(row.network, pname);
        oinfo!(
            "{:<22} {:>9.1}% {:>8.1}% [{:>4.1}%] {:>8.1}% [{:>4.1}%]",
            row.network,
            100.0 * row.bf16_rel,
            100.0 * row.qm_rel,
            100.0 * pqm,
            100.0 * row.bc_rel,
            100.0 * pbc,
        );
    }
    Ok(())
}

fn cmd_table2(args: &Args) -> Result<()> {
    let batch = args.get_usize("batch", 256);
    let source = args.get_or("source", "model");
    let rows = match source.as_str() {
        "model" => tables::table2(&AccelConfig::default(), batch),
        "stash" => tables::table2_stash(&AccelConfig::default(), batch)?,
        other => return Err(anyhow!("unknown --source {other} (model|stash)")),
    };
    oinfo!(
        "Table II — gains vs FP32 baseline (batch {batch}, SFP bits from {source}; paper values in brackets)"
    );
    oinfo!(
        "{:<22} {:>22} {:>22} {:>22}",
        "Network", "BF16 speed/energy", "SFP_QM speed/energy", "SFP_BC speed/energy"
    );
    let paper = [
        ("ResNet18", (1.53, 2.00), (2.30, 6.12), (2.15, 4.54)),
        ("MobileNetV3-Small", (1.72, 2.00), (2.37, 3.95), (2.32, 3.84)),
    ];
    for (r, (pname, pbf, pqm, pbc)) in rows.iter().zip(paper) {
        assert_eq!(r.network, pname);
        oinfo!(
            "{:<22} {:>6.2}x/{:<6.2}x [{:.2}/{:.2}] {:>5.2}x/{:<5.2}x [{:.2}/{:.2}] {:>5.2}x/{:<5.2}x [{:.2}/{:.2}]",
            r.network, r.bf16.0, r.bf16.1, pbf.0, pbf.1, r.qm.0, r.qm.1, pqm.0, pqm.1,
            r.bc.0, r.bc.1, pbc.0, pbc.1,
        );
        oinfo!(
            "{:<22} memory-bound layer passes: {:.0}% (FP32) -> {:.0}% (SFP_QM)",
            "", 100.0 * r.membound_fp32, 100.0 * r.membound_qm
        );
    }
    Ok(())
}

fn load_runtime(args: &Args) -> Result<Runtime> {
    let dir = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let rt = Runtime::load(&dir)?;
    overbose!("runtime: platform={} artifacts={}", rt.platform(), rt.manifest.artifacts.len());
    Ok(rt)
}

fn train_cfg_direct(args: &Args, variant: Variant) -> Result<sfp::coordinator::TrainConfig> {
    // A present-yet-unknown --stash codec must fail loudly rather than
    // silently running without the stash measurement.
    let stash = match args.get("stash") {
        None => None,
        Some(s) => Some(sfp::stash::StashConfig {
            codec: CodecKind::parse(s)
                .ok_or_else(|| anyhow!("unknown --stash codec {s} (gecko|sfp|raw|js)"))?,
            threads: args.get_usize("threads", 0),
            queue_depth: args.get_usize("queue", 0),
            chunk_values: args.get_usize("chunk-values", 0),
            budget_bytes: args.get_usize("budget-bytes", 0),
        }),
    };
    Ok(sfp::coordinator::TrainConfig {
        variant,
        epochs: args.get_usize("epochs", 6),
        steps_per_epoch: args.get_usize("steps", 40),
        eval_batches: args.get_usize("eval-batches", 4),
        lr0: args.get_f64("lr", 0.05) as f32,
        momentum: args.get_f64("momentum", 0.9) as f32,
        seed: args.get_usize("seed", 42) as u64,
        out_dir: Some(out_dir(args)),
        stash,
    })
}

fn trained_histograms(rt: &Runtime, args: &Args) -> Result<(ExponentHistogram, ExponentHistogram)> {
    // Short warm-up training, then histogram real stash tensors.
    use sfp::coordinator::Trainer;
    let mut cfg = train_cfg_direct(args, Variant::Fp32)?;
    cfg.epochs = args.get_usize("epochs", 2);
    cfg.steps_per_epoch = args.get_usize("steps", 20);
    cfg.out_dir = None;
    let mut tr = Trainer::new(rt, cfg);
    tr.run()?;
    let mut hw = ExponentHistogram::new();
    let mut ha = ExponentHistogram::new();
    for w in tr.weights() {
        hw.add_vals(w.as_f32()?);
    }
    for a in tr.dump_acts(0)? {
        ha.add_vals(a.as_f32()?);
    }
    Ok((hw, ha))
}

fn cmd_fig(args: &Args) -> Result<()> {
    use sfp::coordinator::Trainer;
    let id = args.get_usize("id", 0);
    let dir = out_dir(args);
    std::fs::create_dir_all(&dir)?;
    let source = args.get_or("source", "trace");
    match id {
        2 | 3 | 4 => {
            let rt = load_runtime(args)?;
            let qm =
                Trainer::new(&rt, train_cfg_direct(args, Variant::SfpQm(container_of(args)))?)
                    .run()?;
            match id {
                2 => {
                    let base = Trainer::new(&rt, train_cfg_direct(args, Variant::Fp32)?).run()?;
                    figures::fig_accuracy(&dir.join("fig2_accuracy_qm.csv"), &base, &qm)?;
                    oinfo!("fig2 -> {}", dir.join("fig2_accuracy_qm.csv").display());
                }
                3 => {
                    figures::fig3_bitlengths(&dir.join("fig3_qm_bitlengths.csv"), &qm)?;
                    oinfo!("fig3 -> {}", dir.join("fig3_qm_bitlengths.csv").display());
                }
                _ => {
                    figures::fig4_per_layer(&dir.join("fig4_qm_per_layer.csv"), &qm)?;
                    oinfo!("fig4 -> {}", dir.join("fig4_qm_per_layer.csv").display());
                }
            }
        }
        6 | 7 | 8 => {
            let rt = load_runtime(args)?;
            let bc =
                Trainer::new(&rt, train_cfg_direct(args, Variant::SfpBc(Container::Bf16))?).run()?;
            match id {
                6 => {
                    let base = Trainer::new(&rt, train_cfg_direct(args, Variant::Bf16)?).run()?;
                    figures::fig_accuracy(&dir.join("fig6_accuracy_bc.csv"), &base, &bc)?;
                    oinfo!("fig6 -> {}", dir.join("fig6_accuracy_bc.csv").display());
                }
                7 => {
                    let fp = Trainer::new(&rt, train_cfg_direct(args, Variant::SfpBc(Container::Fp32))?)
                        .run()?;
                    figures::fig7_bc_bits(&dir.join("fig7_bc_bits.csv"), &bc, Some(&fp))?;
                    oinfo!("fig7 -> {}", dir.join("fig7_bc_bits.csv").display());
                }
                _ => {
                    figures::fig8_bc_histogram(&dir.join("fig8_bc_histogram.csv"), &bc)?;
                    oinfo!("fig8 -> {}", dir.join("fig8_bc_histogram.csv").display());
                }
            }
        }
        9 if source == "e2e" => {
            let rt = load_runtime(args)?;
            let (hw, ha) = trained_histograms(&rt, args)?;
            figures::fig9_exponents(&dir.join("fig9_exponents.csv"), &hw, &ha)?;
            oinfo!("fig9 (e2e) -> {}", dir.join("fig9_exponents.csv").display());
        }
        10 if source == "e2e" => {
            return Err(anyhow!("fig10 e2e source: use examples/train_e2e which dumps tensors"));
        }
        9 | 10 | 12 | 13 => {
            let sample = args.get_usize("sample", 64 * 512);
            let files = figures::trace_figure(&dir, id, args.get_usize("batch", 256), sample)?;
            for f in files {
                oinfo!("fig{id} -> {}", dir.join(f).display());
            }
        }
        other => return Err(anyhow!("unknown figure id {other} (2|3|4|6|7|8|9|10|12|13)")),
    }
    Ok(())
}

fn cmd_compress(args: &Args) -> Result<()> {
    let count = args.get_usize("count", 64 * 1024);
    let n = args.get_usize("mantissa", 3) as u32;
    let model = ValueModel::relu_act();
    let vals = model.sample_values(count, 7, true);
    for (label, codec) in [
        ("FP32 container", SfpCodec::new(Container::Fp32, false)),
        ("BF16 container", SfpCodec::new(Container::Bf16, false)),
        ("BF16 + sign elision", SfpCodec::new(Container::Bf16, true)),
    ] {
        let c = codec.compress(&vals, n);
        let back = codec.decompress(&c);
        let lossless = vals
            .iter()
            .zip(&back)
            .all(|(&v, &b)| sfp::formats::quantize(v, n, codec.container).to_bits() == b.to_bits());
        oinfo!(
            "{label:<20} n={n}: {:.2} b/value (ratio {:.3} vs container), cycles/value {:.3}, lossless-after-quant: {lossless}",
            c.total_bits() as f64 / count as f64,
            c.ratio(codec.container),
            c.cycles as f64 / count as f64,
        );
    }
    Ok(())
}

// --------------------------------------------------------------------------
// stash (lab-backed)
// --------------------------------------------------------------------------

/// Stash sweep as lab jobs — one per `--budget-bytes` point plus a
/// consolidation job emitting `stash_sweep.json`.  Warm re-runs of
/// unchanged configs come from the cache.
fn cmd_stash(args: &Args) -> Result<()> {
    let budgets = parse_budgets(args, vec![0])?;
    let codec = CodecKind::parse(&args.get_or("codec", "gecko"))
        .ok_or_else(|| anyhow!("unknown --codec (gecko|sfp|raw|js)"))?;
    let spec_of = |budget: usize| -> StashSpec {
        StashSpec {
            model: args.get_or("model", "resnet18"),
            policy: args.get_or("policy", "qm"),
            codec,
            container: container_of(args),
            batch: args.get_usize("batch", 256),
            budget_bytes: budget,
            sample: args.get_usize("sample", SAMPLE),
            seed: args.get_usize("seed", STREAM_SEED as usize) as u64,
            threads: args.get_usize("threads", 0),
            layout: args.get_or("layout", ""),
        }
    };
    let cache = open_cache(args)?;
    let mut graph = JobGraph::new();
    let runs: Vec<usize> = budgets
        .iter()
        .map(|&b| graph.push(JobSpec::StashRun(spec_of(b)), vec![]))
        .collect();
    let summary = graph.push(JobSpec::StashSummary, runs.clone());

    let (reports, wall_ms, mode) = run_lab(&graph, &cache, args)?;
    let dir = out_dir(args);
    std::fs::create_dir_all(&dir)?;
    lab::write_manifest(&dir.join("lab_manifest.json"), &reports, wall_ms, mode)?;
    write_obs_exports(args, &dir)?;
    fail_on_errors(&reports)?;

    let verbose = budgets.len() == 1;
    for &id in &runs {
        let j = job_artifact_json(&cache, &reports[id], "stash.json")?;
        print_stash_row(&j, reports[id].status == JobStatus::Cached, verbose);
    }
    surface_artifacts(&cache, &reports[summary], &dir, None)?;
    append_restore_latency_summary(&dir.join("stash_sweep.json"))?;
    oinfo!("stash sweep JSON -> {}", dir.join("stash_sweep.json").display());
    Ok(())
}

fn print_stash_row(j: &Json, cached: bool, verbose: bool) {
    let num = |k: &str| j.get(k).and_then(Json::as_f64).unwrap_or(f64::NAN);
    let s = |k: &str| j.get(k).and_then(Json::as_str).unwrap_or("?").to_string();
    let budget = num("budget_bytes");
    oinfo!(
        "stash {} @ batch {}, policy {}, codec {}, budget {}{}",
        s("model"),
        num("batch"),
        s("policy"),
        s("codec"),
        if budget == 0.0 {
            "unlimited".to_string()
        } else {
            format!("{:.2} MB", budget / 1e6)
        },
        if cached { " [cached]" } else { "" },
    );
    if verbose {
        if let Some(layers) = j.get("layers").and_then(Json::as_arr) {
            oinfo!(
                "{:<18} {:>4} {:>4} {:>12} {:>12} {:>9}",
                "layer", "n_a", "n_w", "stash MB", "analytic MB", "delta %"
            );
            for l in layers {
                let ln = |k: &str| l.get(k).and_then(Json::as_f64).unwrap_or(f64::NAN);
                let measured = ln("measured_bits");
                let expected = ln("analytic_bits");
                oinfo!(
                    "{:<18} {:>4} {:>4} {:>12.2} {:>12.2} {:>8.3}%",
                    l.get("name").and_then(Json::as_str).unwrap_or("?"),
                    ln("n_a"),
                    ln("n_w"),
                    measured / 8e6,
                    expected / 8e6,
                    100.0 * (measured - expected) / expected,
                );
            }
        }
    }
    oinfo!(
        "totals: stash {:.2} MB vs analytic {:.2} MB — {:.1}% of FP32; \
         hwsim {:.2}x speed / {:.2}x energy (DRAM traffic {:.1}%)",
        num("measured_mb"),
        num("analytic_mb"),
        100.0 * num("frac_of_fp32"),
        num("hwsim_speedup"),
        num("hwsim_energy"),
        100.0 * num("dram_frac"),
    );
    // run_stash_measurement errors on any mismatch, so a row implies the
    // round-trip verified; keep the historical confirmation line.
    if matches!(j.get("restore_bit_exact"), Some(Json::Bool(true))) {
        let tensors = j
            .get("layers")
            .and_then(Json::as_arr)
            .map(|l| 2 * l.len())
            .unwrap_or(0);
        oinfo!("restore: {tensors}/{tensors} tensors bit-exact after stash round-trip");
    }
    if budget > 0.0 {
        oinfo!(
            "spill: DRAM peak {:.2} MB / spill peak {:.2} MB; evicted {:.2} MB ({} chunks), faulted {:.2} MB ({} chunks)",
            num("dram_peak_bytes") / 1e6,
            num("spill_peak_bytes") / 1e6,
            num("spill_written_bytes") / 1e6,
            num("evictions"),
            num("spill_read_bytes") / 1e6,
            num("faults"),
        );
    }
}

// --------------------------------------------------------------------------
// serve (multi-tenant stash service, lab-backed)
// --------------------------------------------------------------------------

fn parse_tenant_counts(args: &Args, default: Vec<usize>) -> Result<Vec<usize>> {
    match args.get("tenants") {
        None => Ok(default),
        Some(s) => {
            let mut v = Vec::new();
            for tok in s.split(',') {
                let n = tok.trim().parse::<usize>().map_err(|_| {
                    anyhow!("bad --tenants entry '{tok}' (comma-separated session counts)")
                })?;
                if n == 0 {
                    return Err(anyhow!("--tenants entries must be >= 1"));
                }
                v.push(n);
            }
            Ok(v)
        }
    }
}

/// Multi-tenant serve scenario as lab jobs — one [`ServeSpec`] per
/// `--tenants` count plus a consolidation job emitting `serve_sweep.json`.
/// Cached artifacts carry only deterministic counters (traffic, evictions,
/// faults, the fairness-probe verdict); this driver appends the process's
/// own wall-clock observations — per-tenant p50/p99 restore latency split
/// DRAM-hit vs spill-fault, and aggregate throughput per scale point — to
/// the *surfaced* sweep file.  A fully cached warm run executes nothing,
/// observes nothing, and appends nothing, so `--expect-cached` re-runs
/// stay fingerprint-stable.
fn cmd_serve(args: &Args) -> Result<()> {
    let smoke = args.has_flag("smoke");
    let tenant_counts =
        parse_tenant_counts(args, if smoke { vec![1, 2] } else { vec![1, 8, 64] })?;
    let codec = CodecKind::parse(&args.get_or("codec", "raw"))
        .ok_or_else(|| anyhow!("unknown --codec (gecko|sfp|raw|js)"))?;
    // Default lease budget: a few chunks, small enough that every session's
    // working set overflows DRAM and exercises eviction + spill faulting.
    let budget = args.get_usize("budget-bytes", 4 * sfp::stash::CHUNK_BYTES);
    if budget == 0 {
        return Err(anyhow!("serve needs a non-zero per-lease --budget-bytes"));
    }
    let spec_of = |tenants: usize| -> ServeSpec {
        ServeSpec {
            model: args.get_or("model", "resnet18"),
            policy: args.get_or("policy", "qm"),
            codec,
            container: container_of(args),
            tenants,
            steps: args.get_usize("steps", 2),
            budget_bytes: budget,
            sample: args.get_usize("sample", if smoke { 512 } else { 2048 }),
            seed: args.get_usize("seed", STREAM_SEED as usize) as u64,
        }
    };
    let cache = open_cache(args)?;
    let mut graph = JobGraph::new();
    let runs: Vec<usize> = tenant_counts
        .iter()
        .map(|&n| graph.push(JobSpec::ServeRun(spec_of(n)), vec![]))
        .collect();
    let summary = graph.push(JobSpec::ServeSummary, runs.clone());

    let (reports, wall_ms, mode) = run_lab(&graph, &cache, args)?;
    let dir = out_dir(args);
    std::fs::create_dir_all(&dir)?;
    let totals = lab::write_manifest(&dir.join("lab_manifest.json"), &reports, wall_ms, mode)?;
    write_obs_exports(args, &dir)?;
    fail_on_errors(&reports)?;

    for &id in &runs {
        let j = job_artifact_json(&cache, &reports[id], "serve.json")?;
        print_serve_row(&j, reports[id].status == JobStatus::Cached);
    }
    surface_artifacts(&cache, &reports[summary], &dir, None)?;
    append_serve_observations(&dir.join("serve_sweep.json"))?;
    oinfo!("serve sweep JSON -> {}", dir.join("serve_sweep.json").display());

    if args.has_flag("expect-cached") {
        if totals.executed > 0 || totals.cached != totals.total {
            return Err(anyhow!(
                "--expect-cached: wanted 100% cache hits with zero jobs executed, got {} executed / {} cached of {}",
                totals.executed,
                totals.cached,
                totals.total,
            ));
        }
        oinfo!("warm cache verified: 100% hits, zero jobs executed");
    }
    Ok(())
}

fn print_serve_row(j: &Json, cached: bool) {
    let num = |k: &str| j.get(k).and_then(Json::as_f64).unwrap_or(f64::NAN);
    let s = |k: &str| j.get(k).and_then(Json::as_str).unwrap_or("?").to_string();
    let flag = |k: &str| matches!(j.get(k), Some(Json::Bool(true)));
    oinfo!(
        "serve {} codec {} policy {}: {} tenants x {} steps, {:.0} KiB/lease{}",
        s("model"),
        s("codec"),
        s("policy"),
        num("tenants"),
        num("steps"),
        num("budget_bytes") / 1024.0,
        if cached { " [cached]" } else { "" },
    );
    oinfo!(
        "  traffic: wrote {:.2} MB / read {:.2} MB; {} evictions, {} faults (DRAM peak {:.2} MB, spill peak {:.2} MB)",
        num("written_mb"),
        num("read_mb"),
        num("evictions"),
        num("faults"),
        num("dram_high_water_bytes") / 1e6,
        num("spill_high_water_bytes") / 1e6,
    );
    oinfo!(
        "  fairness probe: victim faults {} solo vs {} contended (10x churn neighbour) -> fair_eviction={}, bit_exact={}",
        num("solo_faults"),
        num("contended_faults"),
        flag("fair_eviction"),
        flag("restore_bit_exact"),
    );
}

/// Append this process's serve observations to the *surfaced*
/// `serve_sweep.json`: one `latency_observation` row per (scale point,
/// tenant) with p50/p99 restore latency split DRAM-hit vs spill-fault,
/// and one `throughput_observation` row per scale point with aggregate
/// restored MB/s.  The cached artifact is never touched — wall-clock is
/// an observation of this process, not part of the content-addressed
/// result — and a run that executed nothing appends nothing.
fn append_serve_observations(path: &Path) -> Result<()> {
    let obs = sfp::serve::take_observations();
    if obs.is_empty() {
        return Ok(());
    }
    let text = std::fs::read_to_string(path)?;
    let parsed = Json::parse(&text).map_err(|e| anyhow!("parse {}: {e}", path.display()))?;
    let Json::Arr(mut rows) = parsed else {
        return Err(anyhow!("{} is not a JSON array", path.display()));
    };
    let mut scales: Vec<usize> = obs.iter().map(|o| o.scale_tenants).collect();
    scales.sort_unstable();
    scales.dedup();
    for o in &obs {
        let mut row = std::collections::BTreeMap::new();
        row.insert(
            "kind".to_string(),
            Json::Str("latency_observation".to_string()),
        );
        row.insert("tenants".to_string(), Json::Num(o.scale_tenants as f64));
        row.insert("tenant".to_string(), Json::Str(o.tenant.clone()));
        row.insert("dram_hit_us".to_string(), o.dram.to_json());
        row.insert("spill_fault_us".to_string(), o.fault.to_json());
        rows.push(Json::Obj(row));
    }
    for scale in scales {
        let at_scale: Vec<_> = obs.iter().filter(|o| o.scale_tenants == scale).collect();
        let bytes: f64 = at_scale.iter().map(|o| o.restored_bytes).sum();
        // sessions run interleaved on one driver, so the scale point's wall
        // clock is the longest session wall, not the sum
        let wall_us = at_scale.iter().map(|o| o.wall_us).max().unwrap_or(0);
        let mut row = std::collections::BTreeMap::new();
        row.insert(
            "kind".to_string(),
            Json::Str("throughput_observation".to_string()),
        );
        row.insert("tenants".to_string(), Json::Num(scale as f64));
        row.insert("restored_mb".to_string(), Json::Num(bytes / 1e6));
        row.insert("wall_us".to_string(), Json::Num(wall_us as f64));
        row.insert(
            "restored_mb_per_s".to_string(),
            Json::Num(if wall_us > 0 { bytes / wall_us as f64 } else { 0.0 }),
        );
        rows.push(Json::Obj(row));
    }
    std::fs::write(path, Json::Arr(rows).to_string())?;
    overbose!(
        "serve: appended {} latency observation rows (this process)",
        obs.len()
    );
    Ok(())
}

// --------------------------------------------------------------------------
// policy (lab-backed)
// --------------------------------------------------------------------------

/// Adaptation-policy sweep as parallel lab jobs: one `(network, policy)`
/// run each plus a consolidation job, trajectories surfaced into
/// `<out>/policy/`, paper ordering printed from the cached artifacts.
fn cmd_policy(args: &Args) -> Result<()> {
    let model_names: Vec<&str> = match args.get_or("model", "all").as_str() {
        "resnet18" => vec!["resnet18"],
        "mobilenet" | "mobilenet_v3_small" | "mnv3" => vec!["mobilenet"],
        "all" => vec!["resnet18", "mobilenet"],
        other => return Err(anyhow!("unknown --model {other} (resnet18|mobilenet|all)")),
    };
    let kinds: Vec<PolicyKind> = match args.get_or("policy", "all").as_str() {
        "all" => PolicyKind::all().to_vec(),
        s => vec![PolicyKind::parse(s).ok_or_else(|| {
            anyhow!("unknown --policy {s} (qmqe|bitwave|qm|af|flexpoint|fp8|bf16|all)")
        })?],
    };
    let cfg = SweepConfig {
        epochs: args.get_usize("epochs", 9),
        steps_per_epoch: args.get_usize("steps", 30),
        batch: args.get_usize("batch", 256),
        container: container_of(args),
        sample: args.get_usize("sample", SAMPLE),
        seed: args.get_usize("seed", STREAM_SEED as usize) as u64,
    };

    let cache = open_cache(args)?;
    let mut graph = JobGraph::new();
    let mut runs: Vec<(usize, &str, PolicyKind)> = Vec::new();
    for &model in &model_names {
        for &policy in &kinds {
            let id = graph.push(
                JobSpec::PolicyRun {
                    model: model.into(),
                    policy,
                    cfg: cfg.clone(),
                },
                vec![],
            );
            runs.push((id, model, policy));
        }
    }
    let summary = graph.push(JobSpec::PolicySummary, runs.iter().map(|r| r.0).collect());
    let crosspaper = graph.push(JobSpec::CrossPaper, runs.iter().map(|r| r.0).collect());

    let (reports, wall_ms, mode) = run_lab(&graph, &cache, args)?;
    let dir = out_dir(args).join("policy");
    std::fs::create_dir_all(&dir)?;
    lab::write_manifest(&out_dir(args).join("lab_manifest.json"), &reports, wall_ms, mode)?;
    write_obs_exports(args, &out_dir(args))?;
    fail_on_errors(&reports)?;

    oinfo!(
        "Policy sweep — {} epochs x {} steps, batch {}, container {}, {} values/tensor ({mode})",
        cfg.epochs, cfg.steps_per_epoch, cfg.batch, cfg.container, cfg.sample
    );
    oinfo!(
        "(paper averages in brackets: QM+QE 4.74x -> +Gecko 5.64x; BitWave 3.19x -> +Gecko 4.56x)"
    );
    oinfo!(
        "\n{:<20} {:<9} {:>11} {:>12} {:>11} {:>10}",
        "network", "policy", "no-gecko", "gecko", "mant_a", "exp_a"
    );
    for &(id, model, policy) in &runs {
        let j = job_artifact_json(&cache, &reports[id], "policy.json")?;
        let num = |k: &str| j.get(k).and_then(Json::as_f64).unwrap_or(f64::NAN);
        let last = |k: &str| {
            j.get(k)
                .and_then(Json::as_arr)
                .and_then(|a| a.last())
                .and_then(Json::as_f64)
                .unwrap_or(f64::NAN)
        };
        oinfo!(
            "{:<20} {:<9} {:>10.2}x {:>11.2}x {:>11.2} {:>10.2}{}",
            j.get("network").and_then(Json::as_str).unwrap_or(model),
            policy.label(),
            num("plan_reduction"),
            num("gecko_reduction"),
            last("mean_mant_a"),
            last("mean_exp_a"),
            if reports[id].status == JobStatus::Cached { "  [cached]" } else { "" },
        );
        let traj_name = format!("{}_{}.json", model, policy.label().replace('+', "_"));
        surface_artifacts(&cache, &reports[id], &dir, Some(traj_name.as_str()))?;
    }
    oinfo!("");
    let sj = job_artifact_json(&cache, &reports[summary], "policy_summary.json")?;
    if let Some(policies) = sj.get("policies").and_then(Json::as_arr) {
        for p in policies {
            oinfo!(
                "{:<9} average: {:.2}x footprint reduction, {:.2}x with Gecko exponents",
                p.get("policy").and_then(Json::as_str).unwrap_or("?"),
                p.get("avg_plan_reduction").and_then(Json::as_f64).unwrap_or(f64::NAN),
                p.get("avg_gecko_reduction").and_then(Json::as_f64).unwrap_or(f64::NAN),
            );
        }
    }
    surface_artifacts(&cache, &reports[crosspaper], &dir, None)?;
    oinfo!("trajectories -> {}", dir.display());
    oinfo!("cross-paper comparison -> {}", dir.join("crosspaper.json").display());

    if args.has_flag("verify-restore") {
        let quick = SweepConfig {
            sample: 4 * 1024,
            ..cfg.clone()
        };
        for &model in &model_names {
            let net = lab::measure::trace_model(model)?;
            for &k in &kinds {
                let split = quick.steps_per_epoch * (quick.epochs / 3).max(1) + 3;
                sweep::verify_restore_continuation(&net, k, &quick, split, 40)?;
                oinfo!(
                    "restore-continuity OK: {} / {} (split at step {split})",
                    net.name,
                    k.label()
                );
            }
        }
    }
    Ok(())
}

// --------------------------------------------------------------------------
// all (the paper grid)
// --------------------------------------------------------------------------

/// Materialize the paper grid as one lab DAG; `--smoke` is the tiny CI
/// grid, `--expect-cached` asserts a warm cache (100% hits, zero jobs
/// executed) and fails otherwise.
fn cmd_all(args: &Args) -> Result<()> {
    let grid = if args.has_flag("smoke") {
        lab::smoke_grid()
    } else {
        let artifacts_dir = PathBuf::from(args.get_or("artifacts", "artifacts"));
        lab::paper_grid(&lab::GridOptions {
            batch: args.get_usize("batch", 256),
            budgets: parse_budgets(args, vec![0, 1 << 20])?,
            artifacts_dir: Some(artifacts_dir),
        })
    };
    let cache = open_cache(args)?;
    let (reports, wall_ms, mode) = run_lab(&grid.graph, &cache, args)?;

    for r in &reports {
        let status = match &r.status {
            JobStatus::Executed => format!("executed {:>6.0}ms", r.wall_ms),
            JobStatus::Cached => "cached          ".to_string(),
            JobStatus::Failed(_) => "FAILED          ".to_string(),
            JobStatus::Skipped => "skipped         ".to_string(),
        };
        oinfo!("[{status}] {} ({})", r.label, r.hash);
    }

    let dir = out_dir(args);
    std::fs::create_dir_all(&dir)?;
    let totals = lab::write_manifest(&dir.join("lab_manifest.json"), &reports, wall_ms, mode)?;
    write_obs_exports(args, &dir)?;

    // surface the consolidated artifacts next to the manifest
    for (idx, rename) in [
        (grid.policy_summary, None::<&str>),
        (grid.crosspaper, None),
        (grid.stash_summary, None),
    ] {
        if let Some(id) = idx {
            if reports[id].ok() {
                surface_artifacts(&cache, &reports[id], &dir, rename)?;
            }
        }
    }
    for r in &reports {
        if !r.ok() {
            continue;
        }
        match r.kind.as_str() {
            "table1" | "figure" => surface_artifacts(&cache, r, &dir, None)?,
            "table2" => {
                let name = if r.label.ends_with("stash") {
                    "table2_stash.json"
                } else {
                    "table2.json"
                };
                surface_artifacts(&cache, r, &dir, Some(name))?;
            }
            _ => {}
        }
    }

    oinfo!(
        "\nlab: {} jobs — {} executed, {} cached ({:.1}% cache hits), {} failed, {} skipped in {:.1} s ({mode})",
        totals.total,
        totals.executed,
        totals.cached,
        100.0 * totals.cache_hit_rate(),
        totals.failed,
        totals.skipped,
        wall_ms / 1e3,
    );
    oinfo!("manifest -> {}", dir.join("lab_manifest.json").display());

    fail_on_errors(&reports)?;
    if args.has_flag("expect-cached") {
        if totals.executed > 0 || totals.cached != totals.total {
            return Err(anyhow!(
                "--expect-cached: wanted 100% cache hits with zero jobs executed, got {} executed / {} cached of {}",
                totals.executed,
                totals.cached,
                totals.total,
            ));
        }
        oinfo!("warm cache verified: 100% hits, zero jobs executed");
    }
    Ok(())
}

// --------------------------------------------------------------------------
// inspect (flight-recorder readout)
// --------------------------------------------------------------------------

/// Everything `repro inspect` reads from one run directory: the manifest
/// (required) plus the metrics snapshot and adaptation-event stream when
/// present.
struct RunData {
    manifest: Json,
    metrics: Option<Json>,
    events: Vec<obs::AdaptEvent>,
}

fn load_run(dir: &Path) -> Result<RunData> {
    let mpath = dir.join("lab_manifest.json");
    let text = std::fs::read_to_string(&mpath)
        .map_err(|e| anyhow!("read {}: {e} (not a lab run directory?)", mpath.display()))?;
    let manifest = Json::parse(&text).map_err(|e| anyhow!("parse {}: {e}", mpath.display()))?;
    let metrics = std::fs::read_to_string(dir.join("metrics.json"))
        .ok()
        .and_then(|t| Json::parse(&t).ok());
    let events = std::fs::read_to_string(dir.join("events.jsonl"))
        .map(|t| obs::events::parse_jsonl(&t))
        .unwrap_or_default();
    Ok(RunData {
        manifest,
        metrics,
        events,
    })
}

fn manifest_num(m: &Json, key: &str) -> f64 {
    m.get(key).and_then(Json::as_f64).unwrap_or(f64::NAN)
}

/// Per-job view of a manifest: label → (content hash, wall-clock ms,
/// sorted `rel:hash:bytes` artifact fingerprints).
fn manifest_jobs(m: &Json) -> std::collections::BTreeMap<String, (String, f64, Vec<String>)> {
    let mut out = std::collections::BTreeMap::new();
    let Some(jobs) = m.get("jobs").and_then(Json::as_arr) else {
        return out;
    };
    for j in jobs {
        let label = j.get("label").and_then(Json::as_str).unwrap_or("?").to_string();
        let hash = j.get("hash").and_then(Json::as_str).unwrap_or("").to_string();
        let wall = j.get("wall_ms").and_then(Json::as_f64).unwrap_or(0.0);
        let mut arts: Vec<String> = j
            .get("artifacts")
            .and_then(Json::as_arr)
            .map(|a| {
                a.iter()
                    .map(|x| {
                        format!(
                            "{}:{}:{}",
                            x.get("rel").and_then(Json::as_str).unwrap_or("?"),
                            x.get("hash").and_then(Json::as_str).unwrap_or("?"),
                            x.get("bytes").and_then(Json::as_f64).unwrap_or(0.0),
                        )
                    })
                    .collect()
            })
            .unwrap_or_default();
        arts.sort();
        out.insert(label, (hash, wall, arts));
    }
    out
}

fn print_health(dir: &Path, run: &RunData) {
    let m = &run.manifest;
    oinfo!(
        "run {} — {:.0} jobs: {:.0} executed, {:.0} cached, {:.0} failed, {:.0} skipped in {:.1} s ({})",
        dir.display(),
        manifest_num(m, "total_jobs"),
        manifest_num(m, "executed"),
        manifest_num(m, "cached"),
        manifest_num(m, "failed"),
        manifest_num(m, "skipped"),
        manifest_num(m, "wall_ms") / 1e3,
        m.get("mode").and_then(Json::as_str).unwrap_or("?"),
    );
    if let Some(jobs) = m.get("jobs").and_then(Json::as_arr) {
        for j in jobs {
            if j.get("status").and_then(Json::as_str) == Some("failed") {
                oinfo!(
                    "  FAILED {}: {}",
                    j.get("label").and_then(Json::as_str).unwrap_or("?"),
                    j.get("error").and_then(Json::as_str).unwrap_or("?"),
                );
            }
        }
        let mut executed: Vec<(&str, f64)> = jobs
            .iter()
            .filter(|j| j.get("status").and_then(Json::as_str) == Some("executed"))
            .map(|j| {
                (
                    j.get("label").and_then(Json::as_str).unwrap_or("?"),
                    j.get("wall_ms").and_then(Json::as_f64).unwrap_or(0.0),
                )
            })
            .collect();
        executed.sort_by(|a, b| b.1.total_cmp(&a.1));
        for (label, wall) in executed.iter().take(3) {
            oinfo!("  slowest: {label} ({wall:.0} ms)");
        }
    }
    let bits = run.events.iter().filter(|e| e.kind == "bitlength").count();
    let layouts = run.events.iter().filter(|e| e.kind == "layout").count();
    let pressure = run
        .events
        .iter()
        .filter(|e| e.kind == "stash_pressure")
        .count();
    oinfo!(
        "  events: {bits} bitlength changes, {layouts} exponent-layout changes, \
         {pressure} stash-pressure episodes"
    );
    if pressure > 0 {
        // attribute thrash to the tenant that caused it: pressure events
        // carry the owner label of the lease (or trainer) they came from
        let mut by_owner: std::collections::BTreeMap<&str, usize> =
            std::collections::BTreeMap::new();
        for e in run.events.iter().filter(|e| e.kind == "stash_pressure") {
            *by_owner
                .entry(e.owner.as_deref().unwrap_or("(unattributed)"))
                .or_default() += 1;
        }
        let parts: Vec<String> = by_owner
            .iter()
            .map(|(owner, n)| format!("{owner}: {n}"))
            .collect();
        oinfo!("  stash-pressure by owner: {}", parts.join(", "));
    }
    match &run.metrics {
        Some(metrics) => print_codec_throughput(metrics),
        None => oinfo!("  (no metrics.json in this run directory)"),
    }
}

/// Derive per-codec encode/decode GB/s from the metrics snapshot (byte
/// counters over latency-histogram `sum_us`) and summarize run-granular
/// spill syscall coalescing; silent when the run stashed nothing.
fn print_codec_throughput(metrics: &Json) {
    let num = |key: &str| metrics.get(key).and_then(Json::as_f64).unwrap_or(0.0);
    let mut rows = Vec::new();
    for codec in obs::metrics::CODEC_LABELS {
        let gbps = |bytes_key: &str, us_key: &str| -> Option<f64> {
            let bytes = metrics.get(bytes_key)?.get(codec)?.as_f64()?;
            let us = metrics.get(us_key)?.get(codec)?.get("sum_us")?.as_f64()?;
            if bytes > 0.0 && us > 0.0 {
                Some(bytes / 1e3 / us)
            } else {
                None
            }
        };
        let enc = gbps("stash_encode_bytes_total", "stash_encode_us");
        let dec = gbps("stash_decode_bytes_total", "stash_decode_us");
        if enc.is_some() || dec.is_some() {
            let fmt = |v: Option<f64>| match v {
                Some(g) => format!("{g:.2} GB/s"),
                None => "-".to_string(),
            };
            rows.push(format!("{codec} enc {} dec {}", fmt(enc), fmt(dec)));
        }
    }
    if !rows.is_empty() {
        oinfo!("  codec throughput: {}", rows.join(", "));
    }
    let chunks = num("stash_spill_chunks_read_total") + num("stash_spill_chunks_written_total");
    if chunks > 0.0 {
        let calls = num("stash_spill_pread_calls_total") + num("stash_spill_pwrite_calls_total");
        oinfo!(
            "  spill I/O: {:.0} chunks in {:.0} syscalls ({:.1} chunks/call, run-granular)",
            chunks,
            calls,
            chunks / calls.max(1.0),
        );
    }
}

/// Per-layer stored-bitlength trajectories, replayed from the recorded
/// adaptation events: one line per (policy, tensor class, component,
/// layer) stream, oldest decision first.
fn print_trajectories(events: &[obs::AdaptEvent]) {
    let mut groups: std::collections::BTreeMap<(String, String), Vec<&obs::AdaptEvent>> =
        std::collections::BTreeMap::new();
    for e in events.iter().filter(|e| e.kind == "bitlength") {
        let stream = format!(
            "{}/{}/{}",
            e.source,
            e.tensor_class.as_deref().unwrap_or("?"),
            e.component.as_deref().unwrap_or("?"),
        );
        let lane = e
            .layer
            .map(|l| format!("L{l:02}"))
            .unwrap_or_else(|| "net".to_string());
        groups.entry((stream, lane)).or_default().push(e);
    }
    if groups.is_empty() {
        oinfo!("  no bitlength trajectories recorded (fixed containers, or no events.jsonl)");
        return;
    }
    oinfo!("bitlength trajectories (stored bits):");
    for ((stream, lane), mut evs) in groups {
        evs.sort_by_key(|e| (e.epoch.unwrap_or(0), e.step.unwrap_or(0)));
        let mut path = vec![format!("{:.0}", evs[0].from)];
        path.extend(evs.iter().map(|e| format!("{:.0}", e.to)));
        let last = evs.last().expect("group is non-empty");
        oinfo!(
            "  {stream} {lane}: {} ({} @ e{} s{})",
            path.join(" -> "),
            last.trigger,
            last.epoch.unwrap_or(0),
            last.step.unwrap_or(0),
        );
    }
}

/// Per-layer exponent-layout trajectories, replayed from the recorded
/// `layout` events: every lane prints the chain of layout labels
/// (`w8 -> af4b121 -> ...`) the adaptation walked through.
fn print_layout_trajectories(events: &[obs::AdaptEvent]) {
    let mut groups: std::collections::BTreeMap<(String, String), Vec<&obs::AdaptEvent>> =
        std::collections::BTreeMap::new();
    for e in events.iter().filter(|e| e.kind == "layout") {
        let stream = format!("{}/{}", e.source, e.tensor_class.as_deref().unwrap_or("?"));
        let lane = e
            .layer
            .map(|l| format!("L{l:02}"))
            .unwrap_or_else(|| "net".to_string());
        groups.entry((stream, lane)).or_default().push(e);
    }
    if groups.is_empty() {
        return; // per-value-width runs: the layout axis never moved
    }
    oinfo!("exponent-layout trajectories:");
    for ((stream, lane), mut evs) in groups {
        evs.sort_by_key(|e| (e.epoch.unwrap_or(0), e.step.unwrap_or(0)));
        // each event's detail reads "<from-label> -> <to-label>": seed the
        // path with the first from-label, then chain the to-labels
        let mut path: Vec<&str> = Vec::new();
        for (i, e) in evs.iter().enumerate() {
            let d = e.detail.as_deref().unwrap_or("? -> ?");
            let (from, to) = d.split_once(" -> ").unwrap_or(("?", d));
            if i == 0 {
                path.push(from);
            }
            path.push(to);
        }
        let last = evs.last().expect("group is non-empty");
        oinfo!(
            "  {stream} {lane}: {} ({} @ e{} s{})",
            path.join(" -> "),
            last.trigger,
            last.epoch.unwrap_or(0),
            last.step.unwrap_or(0),
        );
    }
}

/// Diff two runs: job sets, artifact fingerprints, per-job wall-clock,
/// and metrics counter deltas.
fn print_diff(a_dir: &Path, a: &RunData, b_dir: &Path, b: &RunData) {
    let ja = manifest_jobs(&a.manifest);
    let jb = manifest_jobs(&b.manifest);
    for label in ja.keys().filter(|l| !jb.contains_key(*l)) {
        oinfo!("  only in {}: {label}", a_dir.display());
    }
    for label in jb.keys().filter(|l| !ja.contains_key(*l)) {
        oinfo!("  only in {}: {label}", b_dir.display());
    }
    let mut identical = 0usize;
    let mut differing = 0usize;
    let mut deltas: Vec<(&str, f64, f64)> = Vec::new();
    for (label, (ha, wa, aa)) in &ja {
        let Some((hb, wb, ab)) = jb.get(label) else {
            continue;
        };
        if ha != hb {
            differing += 1;
            oinfo!("  {label}: config hash differs ({ha} vs {hb})");
        } else if aa != ab {
            differing += 1;
            oinfo!("  {label}: artifact fingerprints DIFFER");
        } else {
            identical += 1;
        }
        if *wa > 0.0 && *wb > 0.0 {
            deltas.push((label.as_str(), *wa, *wb));
        }
    }
    oinfo!(
        "  {identical} jobs fingerprint-identical, {differing} differ; total wall {:.0} ms vs {:.0} ms",
        manifest_num(&a.manifest, "wall_ms"),
        manifest_num(&b.manifest, "wall_ms"),
    );
    deltas.sort_by(|x, y| (y.2 - y.1).abs().total_cmp(&(x.2 - x.1).abs()));
    for (label, wa, wb) in deltas.iter().take(5) {
        oinfo!("  wall {label}: {wa:.0} ms -> {wb:.0} ms ({:+.0} ms)", wb - wa);
    }
    if let (Some(Json::Obj(ma)), Some(Json::Obj(mb))) = (&a.metrics, &b.metrics) {
        let mut rows: Vec<(&str, f64, f64)> = Vec::new();
        for (k, va) in ma {
            if let (Some(x), Some(y)) = (va.as_f64(), mb.get(k).and_then(Json::as_f64)) {
                if x != y {
                    rows.push((k.as_str(), x, y));
                }
            }
        }
        for (k, x, y) in &rows {
            oinfo!("  counter {k}: {x:.0} -> {y:.0} ({:+.0})", y - x);
        }
        if rows.is_empty() {
            oinfo!("  all shared metrics counters equal");
        }
    }
}

/// Write a `BENCH_<name>.json` perf baseline from the run's manifest:
/// total wall clock and the slowest job, for later `--baseline --gate`
/// comparisons.
fn write_baseline(path: &Path, run: &RunData) -> Result<()> {
    let jobs = manifest_jobs(&run.manifest);
    let max_job = jobs.values().map(|(_, w, _)| *w).fold(0.0, f64::max);
    let mut m = std::collections::BTreeMap::new();
    m.insert(
        "total_wall_ms".to_string(),
        Json::Num(manifest_num(&run.manifest, "wall_ms")),
    );
    m.insert("max_job_wall_ms".to_string(), Json::Num(max_job));
    m.insert(
        "total_jobs".to_string(),
        Json::Num(manifest_num(&run.manifest, "total_jobs")),
    );
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(path, Json::Obj(m).to_string())?;
    Ok(())
}

/// Gate the run against a checked-in baseline: fail when its total wall
/// clock exceeds `baseline.total_wall_ms × (1 + gate/100)`.
fn gate_against_baseline(run: &RunData, baseline: &Path, gate_pct: f64) -> Result<()> {
    let text = std::fs::read_to_string(baseline)
        .map_err(|e| anyhow!("read baseline {}: {e}", baseline.display()))?;
    let b = Json::parse(&text).map_err(|e| anyhow!("parse {}: {e}", baseline.display()))?;
    let base = b
        .get("total_wall_ms")
        .and_then(Json::as_f64)
        .ok_or_else(|| anyhow!("{}: no total_wall_ms field", baseline.display()))?;
    let wall = manifest_num(&run.manifest, "wall_ms");
    let limit = base * (1.0 + gate_pct / 100.0);
    // NaN wall (manifest missing wall_ms) must fail the gate, not pass it
    if wall > limit || wall.is_nan() {
        return Err(anyhow!(
            "perf regression: run took {wall:.0} ms, baseline {base:.0} ms — gate +{gate_pct:.0}% allows {limit:.0} ms"
        ));
    }
    oinfo!("perf gate OK: {wall:.0} ms <= {limit:.0} ms (baseline {base:.0} ms +{gate_pct:.0}%)");
    Ok(())
}

/// `repro inspect RUN_DIR [RUN_DIR2]` — the flight-recorder readout:
/// health summary + bitlength trajectories of one run, a structured diff
/// of two, and `--baseline BENCH.json --gate PCT` regression gating.
fn cmd_inspect(args: &Args) -> Result<()> {
    let dirs: Vec<&String> = args.positional.iter().skip(1).collect();
    let Some(first) = dirs.first() else {
        return Err(anyhow!(
            "usage: repro inspect RUN_DIR [RUN_DIR2] [--baseline FILE [--gate PCT]] [--write-baseline FILE]"
        ));
    };
    let a_dir = PathBuf::from(first);
    let a = load_run(&a_dir)?;
    print_health(&a_dir, &a);
    print_trajectories(&a.events);
    print_layout_trajectories(&a.events);
    if let Some(second) = dirs.get(1) {
        let b_dir = PathBuf::from(second);
        let b = load_run(&b_dir)?;
        oinfo!("");
        print_health(&b_dir, &b);
        oinfo!("diff {} vs {}:", a_dir.display(), b_dir.display());
        print_diff(&a_dir, &a, &b_dir, &b);
    }
    if let Some(path) = args.get("write-baseline") {
        write_baseline(Path::new(path), &a)?;
        oinfo!("baseline -> {path}");
    }
    if let Some(bpath) = args.get("baseline") {
        gate_against_baseline(&a, Path::new(bpath), args.get_f64("gate", 100.0))?;
    }
    Ok(())
}

// --------------------------------------------------------------------------
// worker (the process backend's serve loop)
// --------------------------------------------------------------------------

/// Serve lab jobs from stdin against the shared content-addressed cache —
/// the subprocess side of `--backend process`.  One JSON request line in,
/// one response line out, until the orchestrator closes the pipe; all
/// artifacts flow through `<cache>/<kind>-<hash>` entries, never the pipe.
fn cmd_worker(args: &Args) -> Result<()> {
    let cache = args
        .get("cache")
        .ok_or_else(|| anyhow!("worker: --cache DIR is required"))?;
    lab::worker_main(Path::new(cache))
}
