//! `repro` — the Schrödinger's FP leader binary.
//!
//! Subcommands (DESIGN.md §4 experiment index):
//!   train    run one training variant end-to-end through PJRT
//!   table1   footprint columns of Table I (trace models)
//!   table2   performance / energy of Table II (hwsim)
//!   fig      regenerate a figure's CSV (--id 2|3|4|6|7|8|9|10|12|13)
//!   compress demo the Gecko/SFP codecs on a synthetic tensor
//!   all      every trace-model table + figure in one go

use anyhow::{anyhow, Result};
use sfp::coordinator::{TrainConfig, Trainer, Variant};
use sfp::formats::Container;
use sfp::hwsim::AccelConfig;
use sfp::report::{figures, tables};
use sfp::runtime::Runtime;
use sfp::sfp::SfpCodec;
use sfp::stats::{EncodedWidthCdf, ExponentHistogram};
use sfp::traces::{mobilenet_v3_small, resnet18, ValueModel};
use sfp::util::cli::Args;
use std::path::{Path, PathBuf};

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    let code = match run(cmd, &args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(cmd: &str, args: &Args) -> Result<()> {
    match cmd {
        "train" => cmd_train(args),
        "table1" => cmd_table1(args),
        "table2" => cmd_table2(args),
        "fig" => cmd_fig(args),
        "compress" => cmd_compress(args),
        "all" => cmd_all(args),
        _ => {
            print_help();
            Ok(())
        }
    }
}

fn print_help() {
    println!(
        "repro — Schrödinger's FP reproduction\n\
         \n\
         USAGE: repro <command> [--options]\n\
         \n\
         train     --variant fp32|bf16|qm|bc [--container bf16|fp32]\n\
         \u{20}         [--epochs N] [--steps N] [--out DIR] [--artifacts DIR]\n\
         table1    print Table I footprint columns (trace models)\n\
         table2    print Table II perf/energy (hwsim) [--batch N]\n\
         fig       --id 2|3|4|6|7|8|9|10|12|13 [--out DIR] [--source trace|e2e]\n\
         compress  codec demo [--count N] [--mantissa N]\n\
         all       regenerate all trace-model tables + figures [--out DIR]"
    );
}

fn container_of(args: &Args) -> Container {
    match args.get_or("container", "bf16").as_str() {
        "fp32" => Container::Fp32,
        _ => Container::Bf16,
    }
}

fn out_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.get_or("out", "results"))
}

fn load_runtime(args: &Args) -> Result<Runtime> {
    let dir = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let rt = Runtime::load(&dir)?;
    eprintln!("runtime: platform={} artifacts={}", rt.platform(), rt.manifest.artifacts.len());
    Ok(rt)
}

fn train_cfg(args: &Args, variant: Variant) -> TrainConfig {
    TrainConfig {
        variant,
        epochs: args.get_usize("epochs", 6),
        steps_per_epoch: args.get_usize("steps", 40),
        eval_batches: args.get_usize("eval-batches", 4),
        lr0: args.get_f64("lr", 0.05) as f32,
        momentum: args.get_f64("momentum", 0.9) as f32,
        seed: args.get_usize("seed", 42) as u64,
        out_dir: Some(out_dir(args)),
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let container = container_of(args);
    let variant = Variant::parse(&args.get_or("variant", "qm"), container)
        .ok_or_else(|| anyhow!("unknown --variant"))?;
    let rt = load_runtime(args)?;
    let cfg = train_cfg(args, variant);
    eprintln!("training {:?}: {} epochs x {} steps", variant, cfg.epochs, cfg.steps_per_epoch);
    let res = Trainer::new(&rt, cfg).run()?;
    println!("variant={}", res.label);
    println!("final_val_acc={:.4}", res.final_val_acc);
    println!("footprint_rel_fp32={:.4}", res.footprint.relative_to(&res.footprint_fp32));
    println!("footprint_rel_bf16={:.4}", res.footprint.relative_to(&res.footprint_bf16));
    println!("final_n_a={:?}", res.final_n_a);
    println!("final_n_w={:?}", res.final_n_w);
    Ok(())
}

fn cmd_table1(_args: &Args) -> Result<()> {
    println!("Table I — total footprint vs FP32 (trace models; paper values in brackets)");
    println!("{:<22} {:>10} {:>16} {:>16}", "Network", "BF16", "SFP_QM", "SFP_BC");
    let paper = [("ResNet18", 0.147, 0.237), ("MobileNetV3-Small", 0.249, 0.272)];
    for (row, (pname, pqm, pbc)) in tables::table1().iter().zip(paper) {
        assert_eq!(row.network, pname);
        println!(
            "{:<22} {:>9.1}% {:>8.1}% [{:>4.1}%] {:>8.1}% [{:>4.1}%]",
            row.network,
            100.0 * row.bf16_rel,
            100.0 * row.qm_rel,
            100.0 * pqm,
            100.0 * row.bc_rel,
            100.0 * pbc,
        );
    }
    Ok(())
}

fn cmd_table2(args: &Args) -> Result<()> {
    let batch = args.get_usize("batch", 256);
    let rows = tables::table2(&AccelConfig::default(), batch);
    println!("Table II — gains vs FP32 baseline (batch {batch}; paper values in brackets)");
    println!(
        "{:<22} {:>22} {:>22} {:>22}",
        "Network", "BF16 speed/energy", "SFP_QM speed/energy", "SFP_BC speed/energy"
    );
    let paper = [
        ("ResNet18", (1.53, 2.00), (2.30, 6.12), (2.15, 4.54)),
        ("MobileNetV3-Small", (1.72, 2.00), (2.37, 3.95), (2.32, 3.84)),
    ];
    for (r, (pname, pbf, pqm, pbc)) in rows.iter().zip(paper) {
        assert_eq!(r.network, pname);
        println!(
            "{:<22} {:>6.2}x/{:<6.2}x [{:.2}/{:.2}] {:>5.2}x/{:<5.2}x [{:.2}/{:.2}] {:>5.2}x/{:<5.2}x [{:.2}/{:.2}]",
            r.network, r.bf16.0, r.bf16.1, pbf.0, pbf.1, r.qm.0, r.qm.1, pqm.0, pqm.1,
            r.bc.0, r.bc.1, pbc.0, pbc.1,
        );
        println!(
            "{:<22} memory-bound layer passes: {:.0}% (FP32) -> {:.0}% (SFP_QM)",
            "", 100.0 * r.membound_fp32, 100.0 * r.membound_qm
        );
    }
    Ok(())
}

fn trained_histograms(rt: &Runtime, args: &Args) -> Result<(ExponentHistogram, ExponentHistogram)> {
    // Short warm-up training, then histogram real stash tensors.
    let mut cfg = train_cfg(args, Variant::Fp32);
    cfg.epochs = args.get_usize("epochs", 2);
    cfg.steps_per_epoch = args.get_usize("steps", 20);
    cfg.out_dir = None;
    let mut tr = Trainer::new(rt, cfg);
    tr.run()?;
    let mut hw = ExponentHistogram::new();
    let mut ha = ExponentHistogram::new();
    for w in tr.weights() {
        hw.add_vals(w.as_f32()?);
    }
    for a in tr.dump_acts(0)? {
        ha.add_vals(a.as_f32()?);
    }
    Ok((hw, ha))
}

fn cmd_fig(args: &Args) -> Result<()> {
    let id = args.get_usize("id", 0);
    let dir = out_dir(args);
    std::fs::create_dir_all(&dir)?;
    let source = args.get_or("source", "trace");
    match id {
        2 | 3 | 4 => {
            let rt = load_runtime(args)?;
            let qm = Trainer::new(&rt, train_cfg(args, Variant::SfpQm(container_of(args)))).run()?;
            match id {
                2 => {
                    let base = Trainer::new(&rt, train_cfg(args, Variant::Fp32)).run()?;
                    figures::fig_accuracy(&dir.join("fig2_accuracy_qm.csv"), &base, &qm)?;
                    println!("fig2 -> {}", dir.join("fig2_accuracy_qm.csv").display());
                }
                3 => {
                    figures::fig3_bitlengths(&dir.join("fig3_qm_bitlengths.csv"), &qm)?;
                    println!("fig3 -> {}", dir.join("fig3_qm_bitlengths.csv").display());
                }
                _ => {
                    figures::fig4_per_layer(&dir.join("fig4_qm_per_layer.csv"), &qm)?;
                    println!("fig4 -> {}", dir.join("fig4_qm_per_layer.csv").display());
                }
            }
        }
        6 | 7 | 8 => {
            let rt = load_runtime(args)?;
            let bc = Trainer::new(&rt, train_cfg(args, Variant::SfpBc(Container::Bf16))).run()?;
            match id {
                6 => {
                    let base = Trainer::new(&rt, train_cfg(args, Variant::Bf16)).run()?;
                    figures::fig_accuracy(&dir.join("fig6_accuracy_bc.csv"), &base, &bc)?;
                    println!("fig6 -> {}", dir.join("fig6_accuracy_bc.csv").display());
                }
                7 => {
                    let fp = Trainer::new(&rt, train_cfg(args, Variant::SfpBc(Container::Fp32))).run()?;
                    figures::fig7_bc_bits(&dir.join("fig7_bc_bits.csv"), &bc, Some(&fp))?;
                    println!("fig7 -> {}", dir.join("fig7_bc_bits.csv").display());
                }
                _ => {
                    figures::fig8_bc_histogram(&dir.join("fig8_bc_histogram.csv"), &bc)?;
                    println!("fig8 -> {}", dir.join("fig8_bc_histogram.csv").display());
                }
            }
        }
        9 => {
            let (hw, ha) = if source == "e2e" {
                let rt = load_runtime(args)?;
                trained_histograms(&rt, args)?
            } else {
                figures::fig9_from_trace(&resnet18(), 64 * 512)
            };
            figures::fig9_exponents(&dir.join("fig9_exponents.csv"), &hw, &ha)?;
            println!("fig9 ({source}) -> {}", dir.join("fig9_exponents.csv").display());
        }
        10 => {
            let (cw, ca) = if source == "e2e" {
                let rt = load_runtime(args)?;
                let (hw, ha) = trained_histograms(&rt, args)?;
                // rebuild streams from histograms is lossy; use trace path
                // for CDFs unless e2e tensors are dumped directly
                let _ = (hw, ha);
                return Err(anyhow!("fig10 e2e source: use examples/train_e2e which dumps tensors"));
            } else {
                figures::fig10_from_trace(&resnet18(), 64 * 512)
            };
            figures::fig10_cdf(&dir.join("fig10_gecko_cdf.csv"), &cw, &ca)?;
            println!("fig10 ({source}) -> {}", dir.join("fig10_gecko_cdf.csv").display());
        }
        12 => {
            for net in [resnet18(), mobilenet_v3_small()] {
                let p = dir.join(format!("fig12_components_{}.csv", net.name.to_lowercase()));
                figures::fig12_components(&p, &net, 256)?;
                println!("fig12 -> {}", p.display());
            }
        }
        13 => {
            for net in [resnet18(), mobilenet_v3_small()] {
                let p = dir.join(format!("fig13_activation_{}.csv", net.name.to_lowercase()));
                figures::fig13(&p, &net, 256)?;
                println!("fig13 -> {}", p.display());
            }
        }
        other => return Err(anyhow!("unknown figure id {other} (2|3|4|6|7|8|9|10|12|13)")),
    }
    Ok(())
}

fn cmd_compress(args: &Args) -> Result<()> {
    let count = args.get_usize("count", 64 * 1024);
    let n = args.get_usize("mantissa", 3) as u32;
    let model = ValueModel::relu_act();
    let vals = model.sample_values(count, 7, true);
    for (label, codec) in [
        ("FP32 container", SfpCodec::new(Container::Fp32, false)),
        ("BF16 container", SfpCodec::new(Container::Bf16, false)),
        ("BF16 + sign elision", SfpCodec::new(Container::Bf16, true)),
    ] {
        let c = codec.compress(&vals, n);
        let back = codec.decompress(&c);
        let lossless = vals
            .iter()
            .zip(&back)
            .all(|(&v, &b)| sfp::formats::quantize(v, n, codec.container).to_bits() == b.to_bits());
        println!(
            "{label:<20} n={n}: {:.2} b/value (ratio {:.3} vs container), cycles/value {:.3}, lossless-after-quant: {lossless}",
            c.total_bits() as f64 / count as f64,
            c.ratio(codec.container),
            c.cycles as f64 / count as f64,
        );
    }
    Ok(())
}

fn cmd_all(args: &Args) -> Result<()> {
    cmd_table1(args)?;
    println!();
    cmd_table2(args)?;
    let dir = out_dir(args);
    std::fs::create_dir_all(&dir)?;
    for id in [9usize, 10, 12, 13] {
        let mut a = args.clone();
        a.options.insert("id".into(), id.to_string());
        cmd_fig(&a)?;
    }
    println!("\ntrace-model outputs in {}; run `repro fig --id 2|3|4|6|7|8` for the e2e training figures", dir.display());
    Ok(())
}
