//! `repro` — the Schrödinger's FP leader binary.
//!
//! Subcommands (DESIGN.md §4 experiment index):
//!   train    run one training variant end-to-end through PJRT
//!   table1   footprint columns of Table I (trace models)
//!   table2   performance / energy of Table II (hwsim)
//!   fig      regenerate a figure's CSV (--id 2|3|4|6|7|8|9|10|12|13)
//!   compress demo the Gecko/SFP codecs on a synthetic tensor
//!   stash    stash-subsystem sweep over a trace model: store/restore real
//!            compressed tensors, cross-check stored bytes against the
//!            analytic footprint model, measure pool throughput + hwsim
//!   policy   adaptation-policy sweep over the trace models: run QM+QE,
//!            BitWave, and QM-only through the unified BitPolicy engine,
//!            emit per-epoch bitlength trajectories (JSON) and end-of-run
//!            footprints with/without Gecko on the exponent streams
//!   all      every trace-model table + figure in one go

use anyhow::{anyhow, Result};
use sfp::coordinator::{TrainConfig, Trainer, Variant};
use sfp::formats::Container;
use sfp::hwsim::{gains, simulate_pass_with_bits, AccelConfig, ComputeType, LayerBits};
use sfp::policy::sweep::{self, PolicyKind, SweepConfig};
use sfp::report::footprint::{
    ACT_EXP_SEED, ACT_VAL_SEED, SAMPLE, STREAM_SEED, WEIGHT_EXP_SEED, WEIGHT_VAL_SEED,
};
use sfp::report::{figures, tables, FootprintModel, MantissaPolicy};
use sfp::runtime::Runtime;
use sfp::sfp::SfpCodec;
use sfp::stash::{CodecKind, ContainerMeta, Stash, StashConfig, TensorId};
use sfp::stats::ExponentHistogram;
use sfp::traces::{mobilenet_v3_small, resnet18, values_with_exponents, NetworkTrace, ValueModel};
use sfp::util::cli::Args;
use sfp::util::json::Json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::time::Instant;

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    let code = match run(cmd, &args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(cmd: &str, args: &Args) -> Result<()> {
    match cmd {
        "train" => cmd_train(args),
        "table1" => cmd_table1(args),
        "table2" => cmd_table2(args),
        "fig" => cmd_fig(args),
        "compress" => cmd_compress(args),
        "stash" => cmd_stash(args),
        "policy" => cmd_policy(args),
        "all" => cmd_all(args),
        _ => {
            print_help();
            Ok(())
        }
    }
}

fn print_help() {
    println!(
        "repro — Schrödinger's FP reproduction\n\
         \n\
         USAGE: repro <command> [--options]\n\
         \n\
         train     --variant fp32|bf16|qm|bc|qmqe|bw [--container bf16|fp32]\n\
         \u{20}         [--epochs N] [--steps N] [--out DIR] [--artifacts DIR]\n\
         \u{20}         [--stash gecko|sfp|raw] (store real compressed tensors per step)\n\
         \u{20}         [--budget-bytes N] (arena DRAM budget; cold chunks spill to disk)\n\
         table1    print Table I footprint columns (trace models)\n\
         table2    print Table II perf/energy (hwsim) [--batch N] [--source model|stash]\n\
         fig       --id 2|3|4|6|7|8|9|10|12|13 [--out DIR] [--source trace|e2e]\n\
         compress  codec demo [--count N] [--mantissa N]\n\
         stash     --model resnet18|mobilenet [--policy qm|bc|full] [--codec gecko|sfp|raw]\n\
         \u{20}         [--batch N] [--threads N] [--queue N] [--chunk-values N]\n\
         \u{20}         [--budget-bytes N[,N...]] (spill-tier sweep axis; JSON in <out>)\n\
         policy    --model resnet18|mobilenet|all [--policy qmqe|bitwave|qm|all]\n\
         \u{20}         [--epochs N] [--steps N] [--batch N] [--sample N] [--out DIR]\n\
         \u{20}         [--verify-restore] (check mid-run checkpoint/restore continuity)\n\
         all       regenerate all trace-model tables + figures [--out DIR]"
    );
}

fn container_of(args: &Args) -> Container {
    match args.get_or("container", "bf16").as_str() {
        "fp32" => Container::Fp32,
        _ => Container::Bf16,
    }
}

fn out_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.get_or("out", "results"))
}

fn load_runtime(args: &Args) -> Result<Runtime> {
    let dir = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let rt = Runtime::load(&dir)?;
    eprintln!("runtime: platform={} artifacts={}", rt.platform(), rt.manifest.artifacts.len());
    Ok(rt)
}

fn train_cfg(args: &Args, variant: Variant) -> Result<TrainConfig> {
    // A present-yet-unknown --stash codec must fail loudly rather than
    // silently running without the stash measurement.
    let stash = match args.get("stash") {
        None => None,
        Some(s) => Some(StashConfig {
            codec: CodecKind::parse(s)
                .ok_or_else(|| anyhow!("unknown --stash codec {s} (gecko|sfp|raw)"))?,
            threads: args.get_usize("threads", 0),
            queue_depth: args.get_usize("queue", 0),
            chunk_values: args.get_usize("chunk-values", 0),
            budget_bytes: args.get_usize("budget-bytes", 0),
        }),
    };
    Ok(TrainConfig {
        variant,
        epochs: args.get_usize("epochs", 6),
        steps_per_epoch: args.get_usize("steps", 40),
        eval_batches: args.get_usize("eval-batches", 4),
        lr0: args.get_f64("lr", 0.05) as f32,
        momentum: args.get_f64("momentum", 0.9) as f32,
        seed: args.get_usize("seed", 42) as u64,
        out_dir: Some(out_dir(args)),
        stash,
    })
}

fn cmd_train(args: &Args) -> Result<()> {
    let container = container_of(args);
    let variant = Variant::parse(&args.get_or("variant", "qm"), container)
        .ok_or_else(|| anyhow!("unknown --variant"))?;
    let rt = load_runtime(args)?;
    let cfg = train_cfg(args, variant)?;
    eprintln!("training {:?}: {} epochs x {} steps", variant, cfg.epochs, cfg.steps_per_epoch);
    let res = Trainer::new(&rt, cfg).run()?;
    println!("variant={}", res.label);
    println!("final_val_acc={:.4}", res.final_val_acc);
    println!("footprint_rel_fp32={:.4}", res.footprint.relative_to(&res.footprint_fp32));
    println!("footprint_rel_bf16={:.4}", res.footprint.relative_to(&res.footprint_bf16));
    println!("final_n_a={:?}", res.final_n_a);
    println!("final_n_w={:?}", res.final_n_w);
    if let Some(ls) = &res.stash {
        println!(
            "stash: wrote {:.1} MB / read {:.1} MB compressed ({:.1}% of FP32), peak resident {:.1} MB",
            ls.written_bits / 8e6,
            ls.read_bits / 8e6,
            100.0 * ls.ratio_vs_fp32(),
            ls.peak_resident_bits / 8e6,
        );
    }
    if !res.stash_epochs.is_empty() {
        let p = out_dir(args).join(format!("{}_footprint_over_time.csv", res.label));
        figures::footprint_over_time(&p, &res)?;
        println!("footprint-over-time -> {}", p.display());
    }
    Ok(())
}

fn cmd_table1(_args: &Args) -> Result<()> {
    println!("Table I — total footprint vs FP32 (trace models; paper values in brackets)");
    println!("{:<22} {:>10} {:>16} {:>16}", "Network", "BF16", "SFP_QM", "SFP_BC");
    let paper = [("ResNet18", 0.147, 0.237), ("MobileNetV3-Small", 0.249, 0.272)];
    for (row, (pname, pqm, pbc)) in tables::table1().iter().zip(paper) {
        assert_eq!(row.network, pname);
        println!(
            "{:<22} {:>9.1}% {:>8.1}% [{:>4.1}%] {:>8.1}% [{:>4.1}%]",
            row.network,
            100.0 * row.bf16_rel,
            100.0 * row.qm_rel,
            100.0 * pqm,
            100.0 * row.bc_rel,
            100.0 * pbc,
        );
    }
    Ok(())
}

fn cmd_table2(args: &Args) -> Result<()> {
    let batch = args.get_usize("batch", 256);
    let source = args.get_or("source", "model");
    let rows = match source.as_str() {
        "model" => tables::table2(&AccelConfig::default(), batch),
        "stash" => tables::table2_stash(&AccelConfig::default(), batch)?,
        other => return Err(anyhow!("unknown --source {other} (model|stash)")),
    };
    println!(
        "Table II — gains vs FP32 baseline (batch {batch}, SFP bits from {source}; paper values in brackets)"
    );
    println!(
        "{:<22} {:>22} {:>22} {:>22}",
        "Network", "BF16 speed/energy", "SFP_QM speed/energy", "SFP_BC speed/energy"
    );
    let paper = [
        ("ResNet18", (1.53, 2.00), (2.30, 6.12), (2.15, 4.54)),
        ("MobileNetV3-Small", (1.72, 2.00), (2.37, 3.95), (2.32, 3.84)),
    ];
    for (r, (pname, pbf, pqm, pbc)) in rows.iter().zip(paper) {
        assert_eq!(r.network, pname);
        println!(
            "{:<22} {:>6.2}x/{:<6.2}x [{:.2}/{:.2}] {:>5.2}x/{:<5.2}x [{:.2}/{:.2}] {:>5.2}x/{:<5.2}x [{:.2}/{:.2}]",
            r.network, r.bf16.0, r.bf16.1, pbf.0, pbf.1, r.qm.0, r.qm.1, pqm.0, pqm.1,
            r.bc.0, r.bc.1, pbc.0, pbc.1,
        );
        println!(
            "{:<22} memory-bound layer passes: {:.0}% (FP32) -> {:.0}% (SFP_QM)",
            "", 100.0 * r.membound_fp32, 100.0 * r.membound_qm
        );
    }
    Ok(())
}

fn trained_histograms(rt: &Runtime, args: &Args) -> Result<(ExponentHistogram, ExponentHistogram)> {
    // Short warm-up training, then histogram real stash tensors.
    let mut cfg = train_cfg(args, Variant::Fp32)?;
    cfg.epochs = args.get_usize("epochs", 2);
    cfg.steps_per_epoch = args.get_usize("steps", 20);
    cfg.out_dir = None;
    let mut tr = Trainer::new(rt, cfg);
    tr.run()?;
    let mut hw = ExponentHistogram::new();
    let mut ha = ExponentHistogram::new();
    for w in tr.weights() {
        hw.add_vals(w.as_f32()?);
    }
    for a in tr.dump_acts(0)? {
        ha.add_vals(a.as_f32()?);
    }
    Ok((hw, ha))
}

fn cmd_fig(args: &Args) -> Result<()> {
    let id = args.get_usize("id", 0);
    let dir = out_dir(args);
    std::fs::create_dir_all(&dir)?;
    let source = args.get_or("source", "trace");
    match id {
        2 | 3 | 4 => {
            let rt = load_runtime(args)?;
            let qm = Trainer::new(&rt, train_cfg(args, Variant::SfpQm(container_of(args)))?).run()?;
            match id {
                2 => {
                    let base = Trainer::new(&rt, train_cfg(args, Variant::Fp32)?).run()?;
                    figures::fig_accuracy(&dir.join("fig2_accuracy_qm.csv"), &base, &qm)?;
                    println!("fig2 -> {}", dir.join("fig2_accuracy_qm.csv").display());
                }
                3 => {
                    figures::fig3_bitlengths(&dir.join("fig3_qm_bitlengths.csv"), &qm)?;
                    println!("fig3 -> {}", dir.join("fig3_qm_bitlengths.csv").display());
                }
                _ => {
                    figures::fig4_per_layer(&dir.join("fig4_qm_per_layer.csv"), &qm)?;
                    println!("fig4 -> {}", dir.join("fig4_qm_per_layer.csv").display());
                }
            }
        }
        6 | 7 | 8 => {
            let rt = load_runtime(args)?;
            let bc = Trainer::new(&rt, train_cfg(args, Variant::SfpBc(Container::Bf16))?).run()?;
            match id {
                6 => {
                    let base = Trainer::new(&rt, train_cfg(args, Variant::Bf16)?).run()?;
                    figures::fig_accuracy(&dir.join("fig6_accuracy_bc.csv"), &base, &bc)?;
                    println!("fig6 -> {}", dir.join("fig6_accuracy_bc.csv").display());
                }
                7 => {
                    let fp = Trainer::new(&rt, train_cfg(args, Variant::SfpBc(Container::Fp32))?).run()?;
                    figures::fig7_bc_bits(&dir.join("fig7_bc_bits.csv"), &bc, Some(&fp))?;
                    println!("fig7 -> {}", dir.join("fig7_bc_bits.csv").display());
                }
                _ => {
                    figures::fig8_bc_histogram(&dir.join("fig8_bc_histogram.csv"), &bc)?;
                    println!("fig8 -> {}", dir.join("fig8_bc_histogram.csv").display());
                }
            }
        }
        9 => {
            let (hw, ha) = if source == "e2e" {
                let rt = load_runtime(args)?;
                trained_histograms(&rt, args)?
            } else {
                figures::fig9_from_trace(&resnet18(), 64 * 512)
            };
            figures::fig9_exponents(&dir.join("fig9_exponents.csv"), &hw, &ha)?;
            println!("fig9 ({source}) -> {}", dir.join("fig9_exponents.csv").display());
        }
        10 => {
            let (cw, ca) = if source == "e2e" {
                let rt = load_runtime(args)?;
                let (hw, ha) = trained_histograms(&rt, args)?;
                // rebuild streams from histograms is lossy; use trace path
                // for CDFs unless e2e tensors are dumped directly
                let _ = (hw, ha);
                return Err(anyhow!("fig10 e2e source: use examples/train_e2e which dumps tensors"));
            } else {
                figures::fig10_from_trace(&resnet18(), 64 * 512)
            };
            figures::fig10_cdf(&dir.join("fig10_gecko_cdf.csv"), &cw, &ca)?;
            println!("fig10 ({source}) -> {}", dir.join("fig10_gecko_cdf.csv").display());
        }
        12 => {
            for net in [resnet18(), mobilenet_v3_small()] {
                let p = dir.join(format!("fig12_components_{}.csv", net.name.to_lowercase()));
                figures::fig12_components(&p, &net, 256)?;
                println!("fig12 -> {}", p.display());
            }
        }
        13 => {
            for net in [resnet18(), mobilenet_v3_small()] {
                let p = dir.join(format!("fig13_activation_{}.csv", net.name.to_lowercase()));
                figures::fig13(&p, &net, 256)?;
                println!("fig13 -> {}", p.display());
            }
        }
        other => return Err(anyhow!("unknown figure id {other} (2|3|4|6|7|8|9|10|12|13)")),
    }
    Ok(())
}

fn cmd_compress(args: &Args) -> Result<()> {
    let count = args.get_usize("count", 64 * 1024);
    let n = args.get_usize("mantissa", 3) as u32;
    let model = ValueModel::relu_act();
    let vals = model.sample_values(count, 7, true);
    for (label, codec) in [
        ("FP32 container", SfpCodec::new(Container::Fp32, false)),
        ("BF16 container", SfpCodec::new(Container::Bf16, false)),
        ("BF16 + sign elision", SfpCodec::new(Container::Bf16, true)),
    ] {
        let c = codec.compress(&vals, n);
        let back = codec.decompress(&c);
        let lossless = vals
            .iter()
            .zip(&back)
            .all(|(&v, &b)| sfp::formats::quantize(v, n, codec.container).to_bits() == b.to_bits());
        println!(
            "{label:<20} n={n}: {:.2} b/value (ratio {:.3} vs container), cycles/value {:.3}, lossless-after-quant: {lossless}",
            c.total_bits() as f64 / count as f64,
            c.ratio(codec.container),
            c.cycles as f64 / count as f64,
        );
    }
    Ok(())
}

fn stash_net(args: &Args) -> Result<NetworkTrace> {
    match args.get_or("model", "resnet18").as_str() {
        "resnet18" => Ok(resnet18()),
        "mobilenet" | "mobilenet_v3_small" | "mnv3" => Ok(mobilenet_v3_small()),
        other => Err(anyhow!("unknown --model {other} (resnet18|mobilenet)")),
    }
}

/// Stash sweep over a trace model: encode one sampled value stream per
/// tensor through the worker pool (the same exponent streams the analytic
/// footprint model sizes Gecko on), report measured stored bytes scaled to
/// full tensor size against the analytic numbers, verify bit-exact
/// restore, and feed the measured bits to the hwsim DRAM model.
/// `--budget-bytes N[,N...]` adds the spill tier as a sweep axis; every
/// run lands as a row in `<out>/stash_sweep.json` with the
/// resident/spill byte split and eviction/fault counts.
fn cmd_stash(args: &Args) -> Result<()> {
    let budgets: Vec<usize> = match args.get("budget-bytes") {
        None => vec![0],
        Some(s) => {
            let mut v = Vec::new();
            for tok in s.split(',') {
                v.push(tok.trim().parse::<usize>().map_err(|_| {
                    anyhow!("bad --budget-bytes entry '{tok}' (comma-separated bytes; 0 = unlimited)")
                })?);
            }
            v
        }
    };
    let verbose = budgets.len() == 1;
    let mut rows = Vec::new();
    for &budget in &budgets {
        rows.push(stash_run(args, budget, verbose)?);
    }
    let dir = out_dir(args);
    std::fs::create_dir_all(&dir)?;
    let path = dir.join("stash_sweep.json");
    std::fs::write(&path, Json::Arr(rows).to_string())?;
    println!("stash sweep JSON -> {}", path.display());
    Ok(())
}

/// One stash measurement run at a fixed arena budget (0 = unlimited);
/// returns the JSON row for the sweep output.
fn stash_run(args: &Args, budget: usize, verbose: bool) -> Result<Json> {
    let container = container_of(args);
    let net = stash_net(args)?;
    let policy_name = args.get_or("policy", "qm");
    let policy = match policy_name.as_str() {
        "qm" => MantissaPolicy::qm_default(),
        "bc" => MantissaPolicy::bc_default(container),
        "full" => MantissaPolicy::Full,
        other => return Err(anyhow!("unknown --policy {other} (qm|bc|full)")),
    };
    let kind = CodecKind::parse(&args.get_or("codec", "gecko"))
        .ok_or_else(|| anyhow!("unknown --codec (gecko|sfp|raw)"))?;
    let batch = args.get_usize("batch", 256);
    let stash = Stash::new(StashConfig {
        codec: kind,
        threads: args.get_usize("threads", 0),
        queue_depth: args.get_usize("queue", 0),
        chunk_values: args.get_usize("chunk-values", 0),
        budget_bytes: budget,
    });

    let n_layers = net.layers.len();
    let sched = policy.integer_schedule(n_layers, container);
    // What the measured bytes should land on: the SFP schedule for the
    // compressing codecs, the dense container for the raw baseline.  The
    // gecko codec's layout matches the analytic accounting bit-for-bit;
    // the sfp codec differs only in metadata framing (reported, ungated).
    let analytic = match kind {
        CodecKind::Raw => match container {
            Container::Fp32 => FootprintModel::fp32(),
            Container::Bf16 => FootprintModel::bf16(),
        },
        _ => FootprintModel::from_schedule(container, &sched),
    };

    println!(
        "Stash sweep — {} @ batch {batch}, policy {policy_name}, codec {}, container {container}, {} worker threads, budget {}",
        net.name,
        stash.codec_name(),
        stash.threads(),
        if budget == 0 {
            "unlimited".to_string()
        } else {
            format!("{:.2} MB", budget as f64 / 1e6)
        },
    );
    if verbose {
        println!(
            "(each tensor stashed as a {SAMPLE}-value sampled stream; reported MB scale to full tensor size)"
        );
    }

    // One sampled stream per tensor, sharing the analytic model's exponent
    // streams (seeds mirror FootprintModel::layer) so measured == analytic
    // for the component-stream codec.
    let mut streams: Vec<(TensorId, Vec<f32>, ContainerMeta, f64)> = Vec::new();
    for (i, l) in net.layers.iter().enumerate() {
        let seed = STREAM_SEED ^ i as u64;
        let (n_a, n_w) = sched[i];
        let a_exps = l.act_model.sample_exponents(SAMPLE, seed ^ ACT_EXP_SEED);
        let a_vals = values_with_exponents(&a_exps, seed ^ ACT_VAL_SEED, l.nonneg_act);
        let a_meta = ContainerMeta::new(container, n_a).with_sign_elision(l.nonneg_act);
        let a_scale = (l.act_elems * batch) as f64 / SAMPLE as f64;
        streams.push((TensorId::act(i), a_vals, a_meta, a_scale));

        let w_count = SAMPLE.min(l.weight_elems.max(64));
        let w_exps = l.weight_model.sample_exponents(w_count, seed ^ WEIGHT_EXP_SEED);
        let w_vals = values_with_exponents(&w_exps, seed ^ WEIGHT_VAL_SEED, false);
        let w_meta = ContainerMeta::new(container, n_w);
        let w_scale = l.weight_elems as f64 / w_count as f64;
        streams.push((TensorId::weight(i), w_vals, w_meta, w_scale));
    }
    let total_vals: usize = streams.iter().map(|(_, v, _, _)| v.len()).sum();

    // --- encode throughput: direct single-thread codec vs the pool.  The
    // pool path hands over an owned copy per tensor (put takes Vec<f32>),
    // so the baseline clones too — like-for-like timing.
    let codec = kind.build();
    let t0 = Instant::now();
    for (_, v, m, _) in &streams {
        let owned = v.clone();
        std::hint::black_box(codec.encode(&owned, m));
    }
    let t_single = t0.elapsed().as_secs_f64().max(1e-9);

    let t0 = Instant::now();
    for (id, v, m, _) in &streams {
        stash.put(*id, v.clone(), *m);
    }
    stash.flush();
    let t_pool = t0.elapsed().as_secs_f64().max(1e-9);
    if stash.failures() > 0 {
        return Err(anyhow!("{} stash worker jobs failed", stash.failures()));
    }

    // --- stored bytes vs the analytic footprint model --------------------
    let mb = |bits: f64| bits / 8e6;
    if verbose {
        println!(
            "\n{:<18} {:>4} {:>4} {:>12} {:>12} {:>9}",
            "layer", "n_a", "n_w", "stash MB", "analytic MB", "delta %"
        );
    }
    let mut measured_bits = Vec::with_capacity(n_layers);
    let mut stash_total = 0.0;
    let mut analytic_total = 0.0;
    for (i, l) in net.layers.iter().enumerate() {
        // centered depth fraction => PerLayer policy index is exactly i
        let frac = (i as f64 + 0.5) / n_layers as f64;
        let lf = analytic.layer(l, frac, batch, STREAM_SEED ^ i as u64);
        let a = stash
            .stored_bits(TensorId::act(i))
            .ok_or_else(|| anyhow!("activation {i} not resident"))?;
        let w = stash
            .stored_bits(TensorId::weight(i))
            .ok_or_else(|| anyhow!("weight {i} not resident"))?;
        let (a_scale, w_scale) = (streams[2 * i].3, streams[2 * i + 1].3);
        let measured = a.total() * a_scale + w.total() * w_scale;
        let expected = lf.total_act_bits() + lf.total_weight_bits();
        measured_bits.push(LayerBits {
            weight: w.total() * w_scale,
            act: a.total() * a_scale,
        });
        stash_total += measured;
        analytic_total += expected;
        if verbose {
            println!(
                "{:<18} {:>4} {:>4} {:>12.2} {:>12.2} {:>8.3}%",
                l.name,
                sched[i].0,
                sched[i].1,
                mb(measured),
                mb(expected),
                100.0 * (measured - expected) / expected,
            );
        }
    }
    let fp32_total = FootprintModel::fp32().network(&net, batch).total();
    let delta = 100.0 * (stash_total - analytic_total).abs() / analytic_total;
    println!(
        "totals: stash {:.2} MB vs analytic {:.2} MB (delta {delta:.4}%) — {:.1}% of FP32",
        mb(stash_total),
        mb(analytic_total),
        100.0 * stash_total / fp32_total,
    );
    if kind != CodecKind::Sfp && delta > 1.0 {
        return Err(anyhow!(
            "stash/analytic footprint divergence {delta:.3}% exceeds 1%"
        ));
    }

    // --- restore: parallel decode, verified bit-exact --------------------
    let ids: Vec<TensorId> = streams.iter().map(|(id, ..)| *id).collect();
    let t0 = Instant::now();
    let restored = stash.take_all(&ids);
    let t_restore = t0.elapsed().as_secs_f64().max(1e-9);
    for ((id, vals, meta, _), back) in streams.iter().zip(&restored) {
        let back = back
            .as_ref()
            .ok_or_else(|| anyhow!("{id:?} missing at restore"))?;
        if back.len() != vals.len() {
            return Err(anyhow!("{id:?} restore length mismatch"));
        }
        for (&v, &b) in vals.iter().zip(back) {
            if meta.quantized(v).to_bits() != b.to_bits() {
                return Err(anyhow!("{id:?} restore not bit-exact"));
            }
        }
    }
    println!(
        "restore: {}/{} tensors bit-exact after stash round-trip",
        restored.len(),
        streams.len()
    );

    // --- spill tier: resident/spill byte split + eviction counts ---------
    let snap = stash.ledger();
    let dram_peak = stash.arena_high_water_bytes();
    let spill_peak = stash.arena_spill_high_water_bytes();
    if budget > 0 {
        println!(
            "spill: DRAM peak {:.2} MB / spill peak {:.2} MB; evicted {:.2} MB ({} chunks), faulted {:.2} MB ({} chunks)",
            dram_peak as f64 / 1e6,
            spill_peak as f64 / 1e6,
            snap.spill_written_bits / 8e6,
            snap.evictions,
            snap.spill_read_bits / 8e6,
            snap.faults,
        );
        // a budget below what the run needs resident MUST engage the tier
        if snap.evictions == 0 && dram_peak + spill_peak > budget {
            return Err(anyhow!(
                "budget {budget} B is below the {}-B working set but the spill tier never engaged",
                dram_peak + spill_peak
            ));
        }
    }

    // --- throughput + arena + hwsim --------------------------------------
    let mvals = total_vals as f64 / 1e6;
    println!(
        "encode: single-thread {:.1} Mvals/s, pool {:.1} Mvals/s ({:.2}x); decode (pool) {:.1} Mvals/s",
        mvals / t_single,
        mvals / t_pool,
        t_single / t_pool,
        mvals / t_restore,
    );
    println!(
        "arena: high-water {:.2} MB, allocated {:.2} MB (free-listed for reuse); pool queue bounded",
        stash.arena_high_water_bytes() as f64 / 1e6,
        stash.arena_allocated_bytes() as f64 / 1e6,
    );

    let accel = AccelConfig::default();
    let fp32_bits: Vec<LayerBits> = net
        .layers
        .iter()
        .enumerate()
        .map(|(i, l)| {
            let lf = FootprintModel::fp32().layer(l, (i as f64 + 0.5) / n_layers as f64, batch, 0);
            LayerBits {
                weight: lf.total_weight_bits(),
                act: lf.total_act_bits(),
            }
        })
        .collect();
    let compute = match container {
        Container::Fp32 => ComputeType::Fp32,
        Container::Bf16 => ComputeType::Bf16,
    };
    let base = simulate_pass_with_bits(&accel, &net, batch, ComputeType::Fp32, &fp32_bits);
    let ours = simulate_pass_with_bits(&accel, &net, batch, compute, &measured_bits);
    let (speed, energy) = gains(&base, &ours);
    println!(
        "hwsim on measured stash bytes: {speed:.2}x speedup, {energy:.2}x energy vs FP32 (DRAM traffic {:.1}%)",
        100.0 * ours.dram_bits / base.dram_bits,
    );

    let mut row = BTreeMap::new();
    let mut put = |k: &str, v: Json| {
        row.insert(k.to_string(), v);
    };
    put("model", Json::Str(net.name.clone()));
    put("codec", Json::Str(stash.codec_name().to_string()));
    put("policy", Json::Str(policy_name.clone()));
    put("batch", Json::Num(batch as f64));
    put("budget_bytes", Json::Num(budget as f64));
    put("stash_mb", Json::Num(mb(stash_total)));
    put("analytic_mb", Json::Num(mb(analytic_total)));
    put("frac_of_fp32", Json::Num(stash_total / fp32_total));
    put("dram_peak_bytes", Json::Num(dram_peak as f64));
    put("spill_peak_bytes", Json::Num(spill_peak as f64));
    put("spill_written_bytes", Json::Num(snap.spill_written_bits / 8.0));
    put("spill_read_bytes", Json::Num(snap.spill_read_bits / 8.0));
    put("evictions", Json::Num(snap.evictions as f64));
    put("faults", Json::Num(snap.faults as f64));
    put("encode_pool_mvals_s", Json::Num(mvals / t_pool));
    put("decode_mvals_s", Json::Num(mvals / t_restore));
    put("restore_bit_exact", Json::Bool(true));
    Ok(Json::Obj(row))
}

/// Adaptation-policy sweep over the trace models through the unified
/// `BitPolicy` engine: per-epoch bitlength trajectories as JSON, end-of-run
/// footprints with and without Gecko on the exponent streams, and the
/// paper's QM+QE / BitWave / +Gecko ordering printed with reference values.
fn cmd_policy(args: &Args) -> Result<()> {
    let nets: Vec<NetworkTrace> = match args.get_or("model", "all").as_str() {
        "resnet18" => vec![resnet18()],
        "mobilenet" | "mobilenet_v3_small" | "mnv3" => vec![mobilenet_v3_small()],
        "all" => vec![resnet18(), mobilenet_v3_small()],
        other => return Err(anyhow!("unknown --model {other} (resnet18|mobilenet|all)")),
    };
    let kinds: Vec<PolicyKind> = match args.get_or("policy", "all").as_str() {
        "all" => PolicyKind::all().to_vec(),
        s => vec![PolicyKind::parse(s)
            .ok_or_else(|| anyhow!("unknown --policy {s} (qmqe|bitwave|qm|all)"))?],
    };
    let cfg = SweepConfig {
        epochs: args.get_usize("epochs", 9),
        steps_per_epoch: args.get_usize("steps", 30),
        batch: args.get_usize("batch", 256),
        container: container_of(args),
        sample: args.get_usize("sample", SAMPLE),
        seed: args.get_usize("seed", STREAM_SEED as usize) as u64,
    };
    let dir = out_dir(args).join("policy");
    std::fs::create_dir_all(&dir)?;

    println!(
        "Policy sweep — {} epochs x {} steps, batch {}, container {}, {} values/tensor",
        cfg.epochs, cfg.steps_per_epoch, cfg.batch, cfg.container, cfg.sample
    );
    println!(
        "(paper averages in brackets: QM+QE 4.74x -> +Gecko 5.64x; BitWave 3.19x -> +Gecko 4.56x)"
    );
    println!(
        "\n{:<20} {:<9} {:>11} {:>12} {:>11} {:>10}",
        "network", "policy", "no-gecko", "gecko", "mant_a", "exp_a"
    );
    let mut by_kind: Vec<(PolicyKind, Vec<f64>, Vec<f64>)> =
        kinds.iter().map(|&k| (k, Vec::new(), Vec::new())).collect();
    for net in &nets {
        for (k, plans, geckos) in by_kind.iter_mut() {
            let res = sweep::run_policy(net, *k, &cfg)?;
            let last = res.epochs.last().expect("at least one epoch");
            println!(
                "{:<20} {:<9} {:>10.2}x {:>11.2}x {:>11.2} {:>10.2}",
                res.network,
                res.policy,
                res.plan_reduction(),
                res.gecko_reduction(),
                last.mean_mant_a,
                last.mean_exp_a,
            );
            let path = dir.join(format!(
                "{}_{}.json",
                net.name.to_lowercase().replace('-', "_"),
                res.policy.replace('+', "_")
            ));
            res.write_json(&path)?;
            plans.push(res.plan_reduction());
            geckos.push(res.gecko_reduction());
        }
    }
    println!();
    for (k, plans, geckos) in &by_kind {
        let avg = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
        println!(
            "{:<9} average: {:.2}x footprint reduction, {:.2}x with Gecko exponents",
            k.label(),
            avg(plans),
            avg(geckos),
        );
    }
    println!("trajectories -> {}", dir.display());

    if args.has_flag("verify-restore") {
        let quick = SweepConfig {
            sample: 4 * 1024,
            ..cfg.clone()
        };
        for net in &nets {
            for &k in &kinds {
                let split = quick.steps_per_epoch * (quick.epochs / 3).max(1) + 3;
                sweep::verify_restore_continuation(net, k, &quick, split, 40)?;
                println!(
                    "restore-continuity OK: {} / {} (split at step {split})",
                    net.name,
                    k.label()
                );
            }
        }
    }
    Ok(())
}

fn cmd_all(args: &Args) -> Result<()> {
    cmd_table1(args)?;
    println!();
    cmd_table2(args)?;
    let dir = out_dir(args);
    std::fs::create_dir_all(&dir)?;
    for id in [9usize, 10, 12, 13] {
        let mut a = args.clone();
        a.options.insert("id".into(), id.to_string());
        cmd_fig(&a)?;
    }
    println!("\ntrace-model outputs in {}; run `repro fig --id 2|3|4|6|7|8` for the e2e training figures", dir.display());
    Ok(())
}
