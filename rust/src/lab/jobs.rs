//! Job execution bodies: one function per [`JobSpec`] kind, writing
//! deterministic artifacts into the staging directory the executor hands
//! over.  Jobs never print and never time themselves — stdout belongs to
//! the CLI drivers and timings to the run manifest — so artifact bytes
//! depend only on the spec (the parallel-vs-serial byte-equivalence
//! guarantee).  Consolidation jobs read their inputs exclusively through
//! the dependency records' cached artifact directories.

use super::cache::JobRecord;
use super::measure::{run_stash_measurement, trace_model};
use super::spec::{JobSpec, TrainSpec};
use crate::coordinator::{TrainConfig, Trainer, Variant};
use crate::hwsim::AccelConfig;
use crate::policy::sweep;
use crate::report::{figures, tables};
use crate::runtime::Runtime;
use crate::stash::StashConfig;
use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Execute `spec`, writing artifacts under `art_dir`; `deps` are the
/// completed dependency records in graph-edge order and `threads` is the
/// scheduler's resolved worker-pool budget for this job (0 = whole
/// machine) — an execution knob, never part of the job identity, because
/// thread counts don't change artifact bytes.
pub fn execute_spec(
    spec: &JobSpec,
    art_dir: &Path,
    deps: &[JobRecord],
    threads: usize,
) -> Result<()> {
    match spec {
        JobSpec::PolicyRun { model, policy, cfg } => {
            let net = trace_model(model)?;
            let res = sweep::run_policy(&net, *policy, cfg)?;
            res.write_json(&art_dir.join("policy.json"))
        }
        JobSpec::PolicySummary => policy_summary(art_dir, deps),
        JobSpec::CrossPaper => crosspaper(art_dir, deps),
        JobSpec::StashRun(sp) => {
            let m = run_stash_measurement(sp, threads)?;
            std::fs::write(art_dir.join("stash.json"), m.to_json().to_string())?;
            Ok(())
        }
        JobSpec::StashSummary => stash_summary(art_dir, deps),
        JobSpec::ServeRun(sp) => {
            let m = crate::serve::run_serve_measurement(sp)?;
            std::fs::write(art_dir.join("serve.json"), m.to_json().to_string())?;
            Ok(())
        }
        JobSpec::ServeSummary => serve_summary(art_dir, deps),
        JobSpec::Table1 => {
            let rows = tables::table1();
            std::fs::write(
                art_dir.join("table1.json"),
                tables::table1_json(&rows).to_string(),
            )?;
            Ok(())
        }
        JobSpec::Table2 { batch, source } => {
            let rows = match source.as_str() {
                "model" => tables::table2(&AccelConfig::default(), *batch),
                "stash" => tables::table2_stash(&AccelConfig::default(), *batch)?,
                other => return Err(anyhow!("unknown table2 source {other} (model|stash)")),
            };
            std::fs::write(
                art_dir.join("table2.json"),
                tables::table2_json(&rows).to_string(),
            )?;
            Ok(())
        }
        JobSpec::Figure { id, batch, sample } => {
            figures::trace_figure(art_dir, *id, *batch, *sample)?;
            Ok(())
        }
        JobSpec::Train(t) => run_train(t, art_dir, threads),
        JobSpec::Probe { mode, payload } => match mode.as_str() {
            "ok" => {
                let mut m = BTreeMap::new();
                m.insert("payload".to_string(), Json::Num(*payload as f64));
                std::fs::write(art_dir.join("probe.json"), Json::Obj(m).to_string())?;
                Ok(())
            }
            "panic" => panic!("probe panic (payload {payload})"),
            "abort" => std::process::abort(),
            other => Err(anyhow!("unknown probe mode {other} (ok|panic|abort)")),
        },
    }
}

/// Read one named JSON artifact from a dependency record.
fn dep_json(rec: &JobRecord, name: &str) -> Result<Json> {
    let path = rec.artifacts_dir.join(name);
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("read dependency artifact {}", path.display()))?;
    Json::parse(&text).map_err(|e| anyhow!("parse {}: {e}", path.display()))
}

/// Consolidate upstream policy runs: per-policy averages of the footprint
/// reductions (the paper's QM+QE 4.74×→5.64× / BitWave 3.19×→4.56× axis)
/// plus every run's own numbers.
fn policy_summary(art_dir: &Path, deps: &[JobRecord]) -> Result<()> {
    // BTreeMap keyed by policy label: deterministic iteration order
    let mut by_policy: BTreeMap<String, Vec<Json>> = BTreeMap::new();
    for rec in deps.iter().filter(|r| r.kind == "policy") {
        let j = dep_json(rec, "policy.json")?;
        let policy = j
            .get("policy")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("policy.json missing 'policy'"))?
            .to_string();
        let mut run = BTreeMap::new();
        for key in ["network", "plan_reduction", "gecko_reduction", "final_plan_bits"] {
            if let Some(v) = j.get(key) {
                run.insert(key.to_string(), v.clone());
            }
        }
        by_policy.entry(policy).or_default().push(Json::Obj(run));
    }
    let avg = |runs: &[Json], key: &str| -> f64 {
        let vals: Vec<f64> = runs
            .iter()
            .filter_map(|r| r.get(key).and_then(Json::as_f64))
            .collect();
        vals.iter().sum::<f64>() / vals.len().max(1) as f64
    };
    let policies: Vec<Json> = by_policy
        .iter()
        .map(|(policy, runs)| {
            let mut m = BTreeMap::new();
            m.insert("policy".to_string(), Json::Str(policy.clone()));
            m.insert(
                "avg_plan_reduction".to_string(),
                Json::Num(avg(runs, "plan_reduction")),
            );
            m.insert(
                "avg_gecko_reduction".to_string(),
                Json::Num(avg(runs, "gecko_reduction")),
            );
            m.insert("runs".to_string(), Json::Arr(runs.clone()));
            Json::Obj(m)
        })
        .collect();
    let mut root = BTreeMap::new();
    root.insert("policies".to_string(), Json::Arr(policies));
    std::fs::write(
        art_dir.join("policy_summary.json"),
        Json::Obj(root).to_string(),
    )?;
    Ok(())
}

/// Consolidate upstream policy runs into `crosspaper.json`: one row per
/// `(policy, network)` putting the container families from different
/// papers side by side — QM+QE and BitWave (per-value learned widths),
/// QM+AdaptivFloat (per-tensor bias windows), Flexpoint (block-shared
/// exponents) and the static fp8/bf16 presets — by footprint reduction
/// with and without Gecko.  Rows are sorted by `(policy, network)`, so the
/// artifact is byte-stable for any dependency order.
fn crosspaper(art_dir: &Path, deps: &[JobRecord]) -> Result<()> {
    let mut keyed: BTreeMap<(String, String), Json> = BTreeMap::new();
    for rec in deps.iter().filter(|r| r.kind == "policy") {
        let j = dep_json(rec, "policy.json")?;
        let field = |k: &str| -> Result<Json> {
            j.get(k)
                .cloned()
                .ok_or_else(|| anyhow!("policy.json missing '{k}'"))
        };
        let policy = field("policy")?;
        let network = field("network")?;
        let key = (
            policy.as_str().unwrap_or_default().to_string(),
            network.as_str().unwrap_or_default().to_string(),
        );
        let mut row = BTreeMap::new();
        row.insert("policy".to_string(), policy);
        row.insert("network".to_string(), network);
        for k in ["final_plan_bits", "plan_reduction", "gecko_reduction"] {
            row.insert(k.to_string(), field(k)?);
        }
        keyed.insert(key, Json::Obj(row));
    }
    if keyed.is_empty() {
        return Err(anyhow!("crosspaper: no upstream policy runs"));
    }
    let mut root = BTreeMap::new();
    root.insert(
        "rows".to_string(),
        Json::Arr(keyed.into_values().collect()),
    );
    std::fs::write(art_dir.join("crosspaper.json"), Json::Obj(root).to_string())?;
    Ok(())
}

/// Consolidate upstream stash runs into one `stash_sweep.json` array (the
/// `repro stash` sweep output, now cache-addressed per budget point).
fn stash_summary(art_dir: &Path, deps: &[JobRecord]) -> Result<()> {
    let mut rows = Vec::new();
    for rec in deps.iter().filter(|r| r.kind == "stash") {
        rows.push(dep_json(rec, "stash.json")?);
    }
    std::fs::write(art_dir.join("stash_sweep.json"), Json::Arr(rows).to_string())?;
    Ok(())
}

/// Consolidate upstream serve runs into one `serve_sweep.json` array (the
/// `repro serve` scaling output, one row per tenant count — deterministic
/// counters only; the CLI appends wall-clock latency/throughput
/// observations to its *surfaced* copy).
fn serve_summary(art_dir: &Path, deps: &[JobRecord]) -> Result<()> {
    let mut rows = Vec::new();
    for rec in deps.iter().filter(|r| r.kind == "serve") {
        rows.push(dep_json(rec, "serve.json")?);
    }
    std::fs::write(art_dir.join("serve_sweep.json"), Json::Arr(rows).to_string())?;
    Ok(())
}

/// One e2e training run against the compiled AOT artifacts; the Trainer's
/// metric sinks (summary JSON, step CSV, footprint-over-time CSV) land
/// directly in the job's artifact directory.
fn run_train(t: &TrainSpec, art_dir: &Path, threads: usize) -> Result<()> {
    let variant = Variant::parse(&t.variant, t.container)
        .ok_or_else(|| anyhow!("unknown train variant {}", t.variant))?;
    let rt = Runtime::load(Path::new(&t.artifacts_dir))?;
    let cfg = TrainConfig {
        variant,
        epochs: t.epochs,
        steps_per_epoch: t.steps_per_epoch,
        eval_batches: t.eval_batches,
        lr0: t.lr0 as f32,
        momentum: t.momentum as f32,
        seed: t.seed,
        out_dir: Some(art_dir.to_path_buf()),
        stash: t.stash_codec.map(|codec| StashConfig {
            codec,
            threads,
            queue_depth: 0,
            chunk_values: 0,
            budget_bytes: t.budget_bytes,
        }),
    };
    Trainer::new(&rt, cfg).run()?;
    Ok(())
}
