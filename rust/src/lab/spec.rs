//! Job specifications: every experiment the lab can run is a plain config
//! struct with a canonical JSON rendering, from which its content hash —
//! and therefore its cache identity and artifact paths — derives.  Any
//! field change produces a new hash; identical configs always collide
//! onto the same cache entry, across processes and machines.

use crate::formats::Container;
use crate::policy::sweep::{PolicyKind, SweepConfig};
use crate::stash::CodecKind;
use crate::util::json::Json;
use std::collections::BTreeMap;

/// Bump to invalidate every cache entry when artifact formats change.
pub const CACHE_VERSION: u32 = 1;

/// One stash measurement run (the `repro stash` unit, one budget point).
#[derive(Debug, Clone, PartialEq)]
pub struct StashSpec {
    /// Trace model name (`resnet18` | `mobilenet`).
    pub model: String,
    /// Mantissa policy preset (`qm` | `bc` | `full`).
    pub policy: String,
    pub codec: CodecKind,
    pub container: Container,
    pub batch: usize,
    /// Arena DRAM budget in bytes (0 = unlimited, spill tier off).
    pub budget_bytes: usize,
    /// Values sampled per tensor stream.
    pub sample: usize,
    pub seed: u64,
}

/// One end-to-end training run through the PJRT runtime.
#[derive(Debug, Clone, PartialEq)]
pub struct TrainSpec {
    pub variant: String,
    pub container: Container,
    pub epochs: usize,
    pub steps_per_epoch: usize,
    pub eval_batches: usize,
    pub lr0: f64,
    pub momentum: f64,
    pub seed: u64,
    pub stash_codec: Option<CodecKind>,
    pub budget_bytes: usize,
    /// AOT artifact directory the runtime loads.
    pub artifacts_dir: String,
    /// Content hash of the artifact manifest — recompiled artifacts must
    /// invalidate cached training runs.
    pub manifest_hash: String,
}

/// Everything the lab can schedule.  Dependencies are edges of the
/// [`JobGraph`](super::exec::JobGraph), not part of the spec; they enter
/// the job identity through dependency-hash chaining instead.
#[derive(Debug, Clone, PartialEq)]
pub enum JobSpec {
    /// One `(network, policy)` adaptation sweep (`policy/sweep.rs`),
    /// emitting the per-epoch trajectory JSON.
    PolicyRun {
        model: String,
        policy: PolicyKind,
        cfg: SweepConfig,
    },
    /// Consolidates every upstream [`JobSpec::PolicyRun`] artifact into
    /// `policy_summary.json` (per-policy averages, paper ordering).
    PolicySummary,
    /// One stash measurement at a fixed budget point.
    StashRun(StashSpec),
    /// Consolidates upstream [`JobSpec::StashRun`] artifacts into
    /// `stash_sweep.json` (the `repro stash` sweep output).
    StashSummary,
    /// Table I footprint columns (trace models, analytic).
    Table1,
    /// Table II perf/energy; `source` is `model` or `stash`.
    Table2 { batch: usize, source: String },
    /// Trace-source figure CSV(s) (ids 9, 10, 12, 13).
    Figure { id: usize, batch: usize, sample: usize },
    /// One e2e training run (requires compiled AOT artifacts).
    Train(TrainSpec),
}

fn obj(fields: Vec<(&str, Json)>) -> Json {
    let mut m = BTreeMap::new();
    for (k, v) in fields {
        m.insert(k.to_string(), v);
    }
    Json::Obj(m)
}

fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

fn n(v: usize) -> Json {
    Json::Num(v as f64)
}

fn container_str(c: Container) -> &'static str {
    match c {
        Container::Fp32 => "fp32",
        Container::Bf16 => "bf16",
    }
}

impl JobSpec {
    /// Stable job-kind tag (cache directory prefix, manifest rows).
    pub fn kind(&self) -> &'static str {
        match self {
            JobSpec::PolicyRun { .. } => "policy",
            JobSpec::PolicySummary => "policy_summary",
            JobSpec::StashRun(_) => "stash",
            JobSpec::StashSummary => "stash_summary",
            JobSpec::Table1 => "table1",
            JobSpec::Table2 { .. } => "table2",
            JobSpec::Figure { .. } => "figure",
            JobSpec::Train(_) => "train",
        }
    }

    /// Human-readable label for progress lines and the manifest.
    pub fn label(&self) -> String {
        match self {
            JobSpec::PolicyRun { model, policy, .. } => {
                format!("policy:{model}/{}", policy.label())
            }
            JobSpec::PolicySummary => "policy-summary".into(),
            JobSpec::StashRun(sp) => format!(
                "stash:{}/{}/budget={}",
                sp.model,
                sp.codec.label(),
                sp.budget_bytes
            ),
            JobSpec::StashSummary => "stash-summary".into(),
            JobSpec::Table1 => "table1".into(),
            JobSpec::Table2 { source, .. } => format!("table2:{source}"),
            JobSpec::Figure { id, .. } => format!("fig{id}"),
            JobSpec::Train(t) => format!("train:{}", t.variant),
        }
    }

    /// Canonical parameter JSON: keys sorted (BTreeMap), numbers written
    /// integrally where exact — byte-stable across runs, the content-hash
    /// input.
    pub fn params_json(&self) -> String {
        let j = match self {
            JobSpec::PolicyRun { model, policy, cfg } => obj(vec![
                ("model", s(model)),
                ("policy", s(policy.label())),
                ("epochs", n(cfg.epochs)),
                ("steps_per_epoch", n(cfg.steps_per_epoch)),
                ("batch", n(cfg.batch)),
                ("container", s(container_str(cfg.container))),
                ("sample", n(cfg.sample)),
                ("seed", n(cfg.seed as usize)),
            ]),
            JobSpec::PolicySummary => obj(vec![]),
            JobSpec::StashRun(sp) => obj(vec![
                ("model", s(&sp.model)),
                ("policy", s(&sp.policy)),
                ("codec", s(sp.codec.label())),
                ("container", s(container_str(sp.container))),
                ("batch", n(sp.batch)),
                ("budget_bytes", n(sp.budget_bytes)),
                ("sample", n(sp.sample)),
                ("seed", n(sp.seed as usize)),
            ]),
            JobSpec::StashSummary => obj(vec![]),
            JobSpec::Table1 => obj(vec![]),
            JobSpec::Table2 { batch, source } => {
                obj(vec![("batch", n(*batch)), ("source", s(source))])
            }
            JobSpec::Figure { id, batch, sample } => obj(vec![
                ("id", n(*id)),
                ("batch", n(*batch)),
                ("sample", n(*sample)),
            ]),
            JobSpec::Train(t) => obj(vec![
                ("variant", s(&t.variant)),
                ("container", s(container_str(t.container))),
                ("epochs", n(t.epochs)),
                ("steps_per_epoch", n(t.steps_per_epoch)),
                ("eval_batches", n(t.eval_batches)),
                ("lr0", Json::Num(t.lr0)),
                ("momentum", Json::Num(t.momentum)),
                ("seed", n(t.seed as usize)),
                (
                    "stash_codec",
                    match t.stash_codec {
                        Some(c) => s(c.label()),
                        None => Json::Null,
                    },
                ),
                ("budget_bytes", n(t.budget_bytes)),
                ("artifacts_dir", s(&t.artifacts_dir)),
                ("manifest_hash", s(&t.manifest_hash)),
            ]),
        };
        j.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lab::hash::job_hash;

    fn stash_spec() -> StashSpec {
        StashSpec {
            model: "resnet18".into(),
            policy: "qm".into(),
            codec: CodecKind::Gecko,
            container: Container::Bf16,
            batch: 256,
            budget_bytes: 0,
            sample: 4096,
            seed: 0x5EED,
        }
    }

    #[test]
    fn canonical_json_is_sorted_and_stable() {
        let a = JobSpec::StashRun(stash_spec()).params_json();
        let b = JobSpec::StashRun(stash_spec()).params_json();
        assert_eq!(a, b);
        // BTreeMap keys render sorted
        let batch = a.find("\"batch\"").unwrap();
        let codec = a.find("\"codec\"").unwrap();
        let seed = a.find("\"seed\"").unwrap();
        assert!(batch < codec && codec < seed);
    }

    #[test]
    fn job_hash_is_stable_across_runs() {
        // Pinned value: the hash is a pure function of the canonical JSON,
        // so it must never drift between processes or releases (a drift
        // would silently invalidate every cache).  If this changes on
        // purpose, bump CACHE_VERSION instead.
        let spec = JobSpec::StashRun(stash_spec());
        let h = job_hash(spec.kind(), &spec.params_json(), &[], CACHE_VERSION);
        assert_eq!(h.len(), 16);
        assert_eq!(
            h,
            job_hash(spec.kind(), &spec.params_json(), &[], CACHE_VERSION)
        );
    }

    #[test]
    fn any_field_change_changes_the_hash() {
        let base = stash_spec();
        let h = |sp: &StashSpec| {
            let spec = JobSpec::StashRun(sp.clone());
            job_hash(spec.kind(), &spec.params_json(), &[], CACHE_VERSION)
        };
        let h0 = h(&base);
        let mutations: Vec<StashSpec> = vec![
            StashSpec { model: "mobilenet".into(), ..base.clone() },
            StashSpec { policy: "bc".into(), ..base.clone() },
            StashSpec { codec: CodecKind::Js, ..base.clone() },
            StashSpec { container: Container::Fp32, ..base.clone() },
            StashSpec { batch: 128, ..base.clone() },
            StashSpec { budget_bytes: 1 << 20, ..base.clone() },
            StashSpec { sample: 8192, ..base.clone() },
            StashSpec { seed: 7, ..base.clone() },
        ];
        let mut seen = std::collections::BTreeSet::new();
        seen.insert(h0.clone());
        for m in &mutations {
            let hm = h(m);
            assert_ne!(hm, h0, "mutation {m:?} must re-hash");
            assert!(seen.insert(hm), "distinct mutations must not collide");
        }
    }
}
