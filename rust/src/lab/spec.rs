//! Job specifications: every experiment the lab can run is a plain config
//! struct with a canonical JSON rendering, from which its content hash —
//! and therefore its cache identity and artifact paths — derives.  Any
//! field change produces a new hash; identical configs always collide
//! onto the same cache entry, across processes and machines.

use crate::formats::Container;
use crate::policy::sweep::{PolicyKind, SweepConfig};
use crate::stash::CodecKind;
use crate::util::json::Json;
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;

/// Bump to invalidate every cache entry when artifact formats change.
pub const CACHE_VERSION: u32 = 1;

/// One stash measurement run (the `repro stash` unit, one budget point).
#[derive(Debug, Clone, PartialEq)]
pub struct StashSpec {
    /// Trace model name (`resnet18` | `mobilenet`).
    pub model: String,
    /// Mantissa policy preset (`qm` | `bc` | `full`).
    pub policy: String,
    pub codec: CodecKind,
    pub container: Container,
    pub batch: usize,
    /// Arena DRAM budget in bytes (0 = unlimited, spill tier off).
    pub budget_bytes: usize,
    /// Values sampled per tensor stream.
    pub sample: usize,
    pub seed: u64,
    /// Stash worker-pool thread hint: 0 lets the scheduler budget threads
    /// against the machine's parallelism (cores / concurrent jobs), any
    /// other value is used verbatim.  The default hint is omitted from the
    /// canonical JSON, so it never perturbs existing cache identities, and
    /// thread counts never change artifact bytes either way.
    pub threads: usize,
    /// Exponent-layout override as an [`ExponentLayout`] spec string
    /// (`width:BITS` | `bias:BITS:BIAS` | `block:BLOCK[:BITS]`); empty
    /// keeps the policy's per-value default.  Like `threads`, the default
    /// stays out of the canonical JSON so the axis's introduction left
    /// every existing cache identity untouched.
    ///
    /// [`ExponentLayout`]: crate::formats::ExponentLayout
    pub layout: String,
}

/// One multi-tenant serve scenario (the `repro serve` unit, one tenant
/// count).  No thread hint: the scenario pins every session facade to a
/// single worker so the shared arena sees one deterministic operation
/// order — the artifact is a pure function of these fields.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeSpec {
    /// Trace model name (`resnet18` | `mobilenet`).
    pub model: String,
    /// Mantissa policy preset (`qm` | `bc` | `full`).
    pub policy: String,
    pub codec: CodecKind,
    pub container: Container,
    /// Concurrent leased sessions sharing one arena.
    pub tenants: usize,
    /// Put → restore-verify → epoch-cut cycles per session.
    pub steps: usize,
    /// Per-tenant DRAM budget in bytes (the service's global budget is
    /// `tenants × budget_bytes`, fully leased).  Must be non-zero: the
    /// scenario exists to exercise the spill tier under sharing.
    pub budget_bytes: usize,
    /// Values sampled per tensor stream.
    pub sample: usize,
    pub seed: u64,
}

/// One end-to-end training run through the PJRT runtime.
#[derive(Debug, Clone, PartialEq)]
pub struct TrainSpec {
    pub variant: String,
    pub container: Container,
    pub epochs: usize,
    pub steps_per_epoch: usize,
    pub eval_batches: usize,
    pub lr0: f64,
    pub momentum: f64,
    pub seed: u64,
    pub stash_codec: Option<CodecKind>,
    pub budget_bytes: usize,
    /// AOT artifact directory the runtime loads.
    pub artifacts_dir: String,
    /// Content hash of the artifact manifest — recompiled artifacts must
    /// invalidate cached training runs.
    pub manifest_hash: String,
}

/// Everything the lab can schedule.  Dependencies are edges of the
/// [`JobGraph`](super::exec::JobGraph), not part of the spec; they enter
/// the job identity through dependency-hash chaining instead.
#[derive(Debug, Clone, PartialEq)]
pub enum JobSpec {
    /// One `(network, policy)` adaptation sweep (`policy/sweep.rs`),
    /// emitting the per-epoch trajectory JSON.
    PolicyRun {
        model: String,
        policy: PolicyKind,
        cfg: SweepConfig,
    },
    /// Consolidates every upstream [`JobSpec::PolicyRun`] artifact into
    /// `policy_summary.json` (per-policy averages, paper ordering).
    PolicySummary,
    /// Consolidates upstream [`JobSpec::PolicyRun`] artifacts into
    /// `crosspaper.json` — one row per (policy, network) comparing the
    /// container families across papers (QM+QE, BitWave, AdaptivFloat,
    /// Flexpoint block-shared, fp8/bf16 presets) by footprint reduction
    /// with and without Gecko.
    CrossPaper,
    /// One stash measurement at a fixed budget point.
    StashRun(StashSpec),
    /// Consolidates upstream [`JobSpec::StashRun`] artifacts into
    /// `stash_sweep.json` (the `repro stash` sweep output).
    StashSummary,
    /// One multi-tenant serve scenario at a fixed tenant count.
    ServeRun(ServeSpec),
    /// Consolidates upstream [`JobSpec::ServeRun`] artifacts into
    /// `serve_sweep.json` (the `repro serve` scaling output).
    ServeSummary,
    /// Table I footprint columns (trace models, analytic).
    Table1,
    /// Table II perf/energy; `source` is `model` or `stash`.
    Table2 { batch: usize, source: String },
    /// Trace-source figure CSV(s) (ids 9, 10, 12, 13).
    Figure { id: usize, batch: usize, sample: usize },
    /// One e2e training run (requires compiled AOT artifacts).
    Train(TrainSpec),
    /// Diagnostic probe (tests and backend health checks): `ok` writes a
    /// one-line artifact, `panic` panics inside the job body, `abort`
    /// aborts the executing process — the latter two exercise the crash
    /// isolation paths (in-process `catch_unwind`, worker-death recovery).
    Probe { mode: String, payload: usize },
}

fn obj(fields: Vec<(&str, Json)>) -> Json {
    let mut m = BTreeMap::new();
    for (k, v) in fields {
        m.insert(k.to_string(), v);
    }
    Json::Obj(m)
}

fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

fn n(v: usize) -> Json {
    Json::Num(v as f64)
}

fn container_str(c: Container) -> &'static str {
    match c {
        Container::Fp32 => "fp32",
        Container::Bf16 => "bf16",
    }
}

impl JobSpec {
    /// Stable job-kind tag (cache directory prefix, manifest rows).
    pub fn kind(&self) -> &'static str {
        match self {
            JobSpec::PolicyRun { .. } => "policy",
            JobSpec::PolicySummary => "policy_summary",
            JobSpec::CrossPaper => "crosspaper",
            JobSpec::StashRun(_) => "stash",
            JobSpec::StashSummary => "stash_summary",
            JobSpec::ServeRun(_) => "serve",
            JobSpec::ServeSummary => "serve_summary",
            JobSpec::Table1 => "table1",
            JobSpec::Table2 { .. } => "table2",
            JobSpec::Figure { .. } => "figure",
            JobSpec::Train(_) => "train",
            JobSpec::Probe { .. } => "probe",
        }
    }

    /// Human-readable label for progress lines and the manifest.
    pub fn label(&self) -> String {
        match self {
            JobSpec::PolicyRun { model, policy, .. } => {
                format!("policy:{model}/{}", policy.label())
            }
            JobSpec::PolicySummary => "policy-summary".into(),
            JobSpec::CrossPaper => "crosspaper".into(),
            JobSpec::StashRun(sp) => {
                let layout = if sp.layout.is_empty() {
                    String::new()
                } else {
                    format!("/{}", sp.layout)
                };
                format!(
                    "stash:{}/{}{layout}/budget={}",
                    sp.model,
                    sp.codec.label(),
                    sp.budget_bytes
                )
            }
            JobSpec::StashSummary => "stash-summary".into(),
            JobSpec::ServeRun(sp) => format!(
                "serve:{}/{}/tenants={}",
                sp.model,
                sp.codec.label(),
                sp.tenants
            ),
            JobSpec::ServeSummary => "serve-summary".into(),
            JobSpec::Table1 => "table1".into(),
            JobSpec::Table2 { source, .. } => format!("table2:{source}"),
            JobSpec::Figure { id, .. } => format!("fig{id}"),
            JobSpec::Train(t) => format!("train:{}", t.variant),
            JobSpec::Probe { mode, .. } => format!("probe:{mode}"),
        }
    }

    /// Worker-pool threads this job should use, given the scheduler's
    /// per-job budget (0 = whole machine).  Jobs carrying an explicit
    /// non-zero hint keep it; everything else takes the budget.
    pub fn resolve_threads(&self, budget: usize) -> usize {
        match self {
            JobSpec::StashRun(sp) if sp.threads != 0 => sp.threads,
            _ => budget,
        }
    }

    /// Canonical parameter JSON: keys sorted (BTreeMap), numbers written
    /// integrally where exact — byte-stable across runs, the content-hash
    /// input.
    pub fn params_json(&self) -> String {
        let j = match self {
            JobSpec::PolicyRun { model, policy, cfg } => obj(vec![
                ("model", s(model)),
                ("policy", s(policy.label())),
                ("epochs", n(cfg.epochs)),
                ("steps_per_epoch", n(cfg.steps_per_epoch)),
                ("batch", n(cfg.batch)),
                ("container", s(container_str(cfg.container))),
                ("sample", n(cfg.sample)),
                ("seed", n(cfg.seed as usize)),
            ]),
            JobSpec::PolicySummary => obj(vec![]),
            JobSpec::CrossPaper => obj(vec![]),
            JobSpec::StashRun(sp) => {
                let mut fields = vec![
                    ("model", s(&sp.model)),
                    ("policy", s(&sp.policy)),
                    ("codec", s(sp.codec.label())),
                    ("container", s(container_str(sp.container))),
                    ("batch", n(sp.batch)),
                    ("budget_bytes", n(sp.budget_bytes)),
                    ("sample", n(sp.sample)),
                    ("seed", n(sp.seed as usize)),
                ];
                // the default hint stays out of the canonical JSON so the
                // field's introduction never invalidated existing caches
                if sp.threads != 0 {
                    fields.push(("threads", n(sp.threads)));
                }
                // like threads: the default layout stays out of the
                // canonical JSON, so historical identities are untouched
                if !sp.layout.is_empty() {
                    fields.push(("layout", s(&sp.layout)));
                }
                obj(fields)
            }
            JobSpec::StashSummary => obj(vec![]),
            JobSpec::ServeRun(sp) => obj(vec![
                ("model", s(&sp.model)),
                ("policy", s(&sp.policy)),
                ("codec", s(sp.codec.label())),
                ("container", s(container_str(sp.container))),
                ("tenants", n(sp.tenants)),
                ("steps", n(sp.steps)),
                ("budget_bytes", n(sp.budget_bytes)),
                ("sample", n(sp.sample)),
                ("seed", n(sp.seed as usize)),
            ]),
            JobSpec::ServeSummary => obj(vec![]),
            JobSpec::Table1 => obj(vec![]),
            JobSpec::Table2 { batch, source } => {
                obj(vec![("batch", n(*batch)), ("source", s(source))])
            }
            JobSpec::Figure { id, batch, sample } => obj(vec![
                ("id", n(*id)),
                ("batch", n(*batch)),
                ("sample", n(*sample)),
            ]),
            JobSpec::Train(t) => obj(vec![
                ("variant", s(&t.variant)),
                ("container", s(container_str(t.container))),
                ("epochs", n(t.epochs)),
                ("steps_per_epoch", n(t.steps_per_epoch)),
                ("eval_batches", n(t.eval_batches)),
                ("lr0", Json::Num(t.lr0)),
                ("momentum", Json::Num(t.momentum)),
                ("seed", n(t.seed as usize)),
                (
                    "stash_codec",
                    match t.stash_codec {
                        Some(c) => s(c.label()),
                        None => Json::Null,
                    },
                ),
                ("budget_bytes", n(t.budget_bytes)),
                ("artifacts_dir", s(&t.artifacts_dir)),
                ("manifest_hash", s(&t.manifest_hash)),
            ]),
            JobSpec::Probe { mode, payload } => {
                obj(vec![("mode", s(mode)), ("payload", n(*payload))])
            }
        };
        j.to_string()
    }

    /// Reconstruct a spec from its kind tag and parsed canonical parameter
    /// JSON — the inverse of [`JobSpec::params_json`], used by remote
    /// workers to rebuild the job a request line describes.  Round-tripping
    /// is byte-exact: `from_parts(kind, parse(params_json)).params_json()`
    /// equals the original string, so content hashes agree across the
    /// process boundary.
    pub fn from_parts(kind: &str, params: &Json) -> Result<JobSpec> {
        let str_of = |k: &str| -> Result<String> {
            params
                .get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| anyhow!("{kind} params missing string '{k}'"))
        };
        let usize_of = |k: &str| -> Result<usize> {
            params
                .get(k)
                .and_then(Json::as_f64)
                .map(|v| v as usize)
                .ok_or_else(|| anyhow!("{kind} params missing number '{k}'"))
        };
        let f64_of = |k: &str| -> Result<f64> {
            params
                .get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("{kind} params missing number '{k}'"))
        };
        let container_of = |k: &str| -> Result<Container> {
            match str_of(k)?.as_str() {
                "fp32" => Ok(Container::Fp32),
                "bf16" => Ok(Container::Bf16),
                other => Err(anyhow!("{kind} params: unknown container '{other}'")),
            }
        };
        let codec_of = |k: &str| -> Result<CodecKind> {
            let name = str_of(k)?;
            CodecKind::parse(&name)
                .ok_or_else(|| anyhow!("{kind} params: unknown codec '{name}'"))
        };
        match kind {
            "policy" => {
                let name = str_of("policy")?;
                Ok(JobSpec::PolicyRun {
                    model: str_of("model")?,
                    policy: PolicyKind::parse(&name)
                        .ok_or_else(|| anyhow!("unknown policy '{name}'"))?,
                    cfg: SweepConfig {
                        epochs: usize_of("epochs")?,
                        steps_per_epoch: usize_of("steps_per_epoch")?,
                        batch: usize_of("batch")?,
                        container: container_of("container")?,
                        sample: usize_of("sample")?,
                        seed: usize_of("seed")? as u64,
                    },
                })
            }
            "policy_summary" => Ok(JobSpec::PolicySummary),
            "crosspaper" => Ok(JobSpec::CrossPaper),
            "stash" => Ok(JobSpec::StashRun(StashSpec {
                model: str_of("model")?,
                policy: str_of("policy")?,
                codec: codec_of("codec")?,
                container: container_of("container")?,
                batch: usize_of("batch")?,
                budget_bytes: usize_of("budget_bytes")?,
                sample: usize_of("sample")?,
                seed: usize_of("seed")? as u64,
                threads: params
                    .get("threads")
                    .and_then(Json::as_f64)
                    .map(|v| v as usize)
                    .unwrap_or(0),
                layout: params
                    .get("layout")
                    .and_then(Json::as_str)
                    .unwrap_or("")
                    .to_string(),
            })),
            "stash_summary" => Ok(JobSpec::StashSummary),
            "serve" => Ok(JobSpec::ServeRun(ServeSpec {
                model: str_of("model")?,
                policy: str_of("policy")?,
                codec: codec_of("codec")?,
                container: container_of("container")?,
                tenants: usize_of("tenants")?,
                steps: usize_of("steps")?,
                budget_bytes: usize_of("budget_bytes")?,
                sample: usize_of("sample")?,
                seed: usize_of("seed")? as u64,
            })),
            "serve_summary" => Ok(JobSpec::ServeSummary),
            "table1" => Ok(JobSpec::Table1),
            "table2" => Ok(JobSpec::Table2 {
                batch: usize_of("batch")?,
                source: str_of("source")?,
            }),
            "figure" => Ok(JobSpec::Figure {
                id: usize_of("id")?,
                batch: usize_of("batch")?,
                sample: usize_of("sample")?,
            }),
            "train" => Ok(JobSpec::Train(TrainSpec {
                variant: str_of("variant")?,
                container: container_of("container")?,
                epochs: usize_of("epochs")?,
                steps_per_epoch: usize_of("steps_per_epoch")?,
                eval_batches: usize_of("eval_batches")?,
                lr0: f64_of("lr0")?,
                momentum: f64_of("momentum")?,
                seed: usize_of("seed")? as u64,
                stash_codec: match params.get("stash_codec") {
                    Some(Json::Null) | None => None,
                    Some(_) => Some(codec_of("stash_codec")?),
                },
                budget_bytes: usize_of("budget_bytes")?,
                artifacts_dir: str_of("artifacts_dir")?,
                manifest_hash: str_of("manifest_hash")?,
            })),
            "probe" => Ok(JobSpec::Probe {
                mode: str_of("mode")?,
                payload: usize_of("payload")?,
            }),
            other => Err(anyhow!("unknown job kind '{other}'")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lab::hash::job_hash;

    fn stash_spec() -> StashSpec {
        StashSpec {
            model: "resnet18".into(),
            policy: "qm".into(),
            codec: CodecKind::Gecko,
            container: Container::Bf16,
            batch: 256,
            budget_bytes: 0,
            sample: 4096,
            seed: 0x5EED,
            threads: 0,
            layout: String::new(),
        }
    }

    #[test]
    fn canonical_json_is_sorted_and_stable() {
        let a = JobSpec::StashRun(stash_spec()).params_json();
        let b = JobSpec::StashRun(stash_spec()).params_json();
        assert_eq!(a, b);
        // BTreeMap keys render sorted
        let batch = a.find("\"batch\"").unwrap();
        let codec = a.find("\"codec\"").unwrap();
        let seed = a.find("\"seed\"").unwrap();
        assert!(batch < codec && codec < seed);
    }

    #[test]
    fn job_hash_is_stable_across_runs() {
        // Pinned value: the hash is a pure function of the canonical JSON,
        // so it must never drift between processes or releases (a drift
        // would silently invalidate every cache).  If this changes on
        // purpose, bump CACHE_VERSION instead.
        let spec = JobSpec::StashRun(stash_spec());
        let h = job_hash(spec.kind(), &spec.params_json(), &[], CACHE_VERSION);
        assert_eq!(h.len(), 16);
        assert_eq!(
            h,
            job_hash(spec.kind(), &spec.params_json(), &[], CACHE_VERSION)
        );
    }

    #[test]
    fn any_field_change_changes_the_hash() {
        let base = stash_spec();
        let h = |sp: &StashSpec| {
            let spec = JobSpec::StashRun(sp.clone());
            job_hash(spec.kind(), &spec.params_json(), &[], CACHE_VERSION)
        };
        let h0 = h(&base);
        let mutations: Vec<StashSpec> = vec![
            StashSpec { model: "mobilenet".into(), ..base.clone() },
            StashSpec { policy: "bc".into(), ..base.clone() },
            StashSpec { codec: CodecKind::Js, ..base.clone() },
            StashSpec { container: Container::Fp32, ..base.clone() },
            StashSpec { batch: 128, ..base.clone() },
            StashSpec { budget_bytes: 1 << 20, ..base.clone() },
            StashSpec { sample: 8192, ..base.clone() },
            StashSpec { seed: 7, ..base.clone() },
            StashSpec { threads: 2, ..base.clone() },
            StashSpec { layout: "block:16".into(), ..base.clone() },
            StashSpec { layout: "bias:4:121".into(), ..base.clone() },
        ];
        let mut seen = std::collections::BTreeSet::new();
        seen.insert(h0.clone());
        for m in &mutations {
            let hm = h(m);
            assert_ne!(hm, h0, "mutation {m:?} must re-hash");
            assert!(seen.insert(hm), "distinct mutations must not collide");
        }
    }

    #[test]
    fn default_thread_hint_keeps_the_historical_hash() {
        // the hint rides outside the identity at its default, so adding
        // the field never invalidated existing caches
        let base = JobSpec::StashRun(stash_spec());
        assert!(!base.params_json().contains("threads"));
        let hinted = JobSpec::StashRun(StashSpec {
            threads: 4,
            ..stash_spec()
        });
        assert!(hinted.params_json().contains("\"threads\":4"));
        assert_ne!(
            job_hash(base.kind(), &base.params_json(), &[], CACHE_VERSION),
            job_hash(hinted.kind(), &hinted.params_json(), &[], CACHE_VERSION),
        );
    }

    #[test]
    fn default_layout_keeps_the_historical_identity() {
        // Pinned canonical JSON: this is the byte string historical cache
        // identities hashed before the layout axis existed.  If this
        // assertion ever needs to change, bump CACHE_VERSION.
        let base = JobSpec::StashRun(stash_spec());
        assert_eq!(
            base.params_json(),
            "{\"batch\":256,\"budget_bytes\":0,\"codec\":\"gecko\",\
             \"container\":\"bf16\",\"model\":\"resnet18\",\"policy\":\"qm\",\
             \"sample\":4096,\"seed\":24301}",
        );
        let laid = JobSpec::StashRun(StashSpec {
            layout: "block:16".into(),
            ..stash_spec()
        });
        assert!(laid.params_json().contains("\"layout\":\"block:16\""));
        assert_ne!(
            job_hash(base.kind(), &base.params_json(), &[], CACHE_VERSION),
            job_hash(laid.kind(), &laid.params_json(), &[], CACHE_VERSION),
        );
    }

    #[test]
    fn resolve_threads_prefers_the_explicit_hint() {
        let auto = JobSpec::StashRun(stash_spec());
        assert_eq!(auto.resolve_threads(3), 3);
        assert_eq!(auto.resolve_threads(0), 0);
        let hinted = JobSpec::StashRun(StashSpec {
            threads: 2,
            ..stash_spec()
        });
        assert_eq!(hinted.resolve_threads(3), 2);
        assert_eq!(JobSpec::Table1.resolve_threads(5), 5);
    }

    #[test]
    fn every_spec_kind_round_trips_through_canonical_json() {
        let specs = vec![
            JobSpec::PolicyRun {
                model: "resnet18".into(),
                policy: PolicyKind::QmQe,
                cfg: SweepConfig::default(),
            },
            JobSpec::PolicyRun {
                model: "mobilenet".into(),
                policy: PolicyKind::Flexpoint,
                cfg: SweepConfig::default(),
            },
            JobSpec::PolicySummary,
            JobSpec::CrossPaper,
            JobSpec::StashRun(stash_spec()),
            JobSpec::StashRun(StashSpec {
                threads: 2,
                ..stash_spec()
            }),
            JobSpec::StashRun(StashSpec {
                layout: "bias:4:121".into(),
                ..stash_spec()
            }),
            JobSpec::StashSummary,
            JobSpec::ServeRun(ServeSpec {
                model: "resnet18".into(),
                policy: "qm".into(),
                codec: CodecKind::Raw,
                container: Container::Fp32,
                tenants: 8,
                steps: 2,
                budget_bytes: 1 << 17,
                sample: 1024,
                seed: 0x5EED,
            }),
            JobSpec::ServeSummary,
            JobSpec::Table1,
            JobSpec::Table2 {
                batch: 128,
                source: "stash".into(),
            },
            JobSpec::Figure {
                id: 13,
                batch: 256,
                sample: 4096,
            },
            JobSpec::Train(TrainSpec {
                variant: "qmqe".into(),
                container: Container::Bf16,
                epochs: 6,
                steps_per_epoch: 40,
                eval_batches: 4,
                lr0: 0.05,
                momentum: 0.9,
                seed: 42,
                stash_codec: Some(CodecKind::Gecko),
                budget_bytes: 1 << 20,
                artifacts_dir: "artifacts".into(),
                manifest_hash: "deadbeefdeadbeef".into(),
            }),
            JobSpec::Train(TrainSpec {
                variant: "fp32".into(),
                container: Container::Fp32,
                epochs: 1,
                steps_per_epoch: 2,
                eval_batches: 1,
                lr0: 0.1,
                momentum: 0.0,
                seed: 7,
                stash_codec: None,
                budget_bytes: 0,
                artifacts_dir: "a".into(),
                manifest_hash: "0".into(),
            }),
            JobSpec::Probe {
                mode: "panic".into(),
                payload: 3,
            },
        ];
        for spec in specs {
            let json = spec.params_json();
            let parsed = Json::parse(&json).expect("canonical json parses");
            let back = JobSpec::from_parts(spec.kind(), &parsed)
                .unwrap_or_else(|e| panic!("{}: {e}", spec.kind()));
            assert_eq!(back, spec, "reconstructed spec equals the original");
            assert_eq!(
                back.params_json(),
                json,
                "round-trip is byte-exact, so hashes agree across processes"
            );
        }
    }
}
