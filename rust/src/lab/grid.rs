//! Grid builders (`repro all`) and the consolidated run manifest.
//!
//! [`paper_grid`] materializes the full evaluation cross-product the
//! paper's headline numbers come from — every adaptation policy × trace
//! model through the sweep engine, every stash codec × model × budget
//! point through the measurement path, the Table I/II emitters, and the
//! trace-source figures — as one dependency graph; [`smoke_grid`] is the
//! tiny CI/bench variant (a 2×2×2 stash core plus two policy runs and the
//! cheap emitters).  Train jobs join the grid only when compiled AOT
//! artifacts are present, keyed by the manifest's content hash so a
//! recompile invalidates cached runs.
//!
//! [`write_manifest`] renders one `lab_manifest.json` for a run: every
//! job's kind, label, content hash, status, wall-clock, and artifact
//! fingerprints, plus the executed/cached totals the warm-cache CI gate
//! asserts on.

use super::exec::{JobGraph, JobReport, JobStatus};
use super::spec::{JobSpec, StashSpec, TrainSpec};
use crate::formats::Container;
use crate::policy::sweep::{PolicyKind, SweepConfig};
use crate::report::footprint::{SAMPLE, STREAM_SEED};
use crate::stash::CodecKind;
use crate::util::json::Json;
use anyhow::Result;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Knobs of a grid build.
#[derive(Debug, Clone)]
pub struct GridOptions {
    pub batch: usize,
    /// Stash-sweep budget axis in bytes (0 = unlimited tier).
    pub budgets: Vec<usize>,
    /// AOT artifact directory; train jobs are added when its
    /// `manifest.json` exists.
    pub artifacts_dir: Option<PathBuf>,
}

impl Default for GridOptions {
    fn default() -> Self {
        Self {
            batch: 256,
            budgets: vec![0, 1 << 20],
            artifacts_dir: None,
        }
    }
}

/// A built grid: the graph plus the indices of the jobs whose artifacts
/// the CLI surfaces into the output directory.
pub struct Grid {
    pub graph: JobGraph,
    pub policy_summary: Option<usize>,
    pub crosspaper: Option<usize>,
    pub stash_summary: Option<usize>,
}

fn stash_spec(model: &str, codec: CodecKind, budget: usize, batch: usize, sample: usize) -> JobSpec {
    JobSpec::StashRun(StashSpec {
        model: model.into(),
        policy: "qm".into(),
        codec,
        container: Container::Bf16,
        batch,
        budget_bytes: budget,
        sample,
        seed: STREAM_SEED,
        threads: 0,
        layout: String::new(),
    })
}

/// A stash run pinned to an explicit exponent layout (the block-shared /
/// bias-window axis) through the Gecko codec.
fn layout_stash_spec(model: &str, layout: &str, batch: usize, sample: usize) -> JobSpec {
    JobSpec::StashRun(StashSpec {
        model: model.into(),
        policy: "qm".into(),
        codec: CodecKind::Gecko,
        container: Container::Bf16,
        batch,
        budget_bytes: 0,
        sample,
        seed: STREAM_SEED,
        threads: 0,
        layout: layout.into(),
    })
}

/// Push the policy axis plus both consolidators over the same runs;
/// returns `(policy_summary, crosspaper)` indices.
fn push_policy_block(
    g: &mut JobGraph,
    models: &[&str],
    kinds: &[PolicyKind],
    cfg: &SweepConfig,
) -> (usize, usize) {
    let mut runs = Vec::new();
    for &model in models {
        for &policy in kinds {
            runs.push(g.push(
                JobSpec::PolicyRun {
                    model: model.into(),
                    policy,
                    cfg: cfg.clone(),
                },
                vec![],
            ));
        }
    }
    let summary = g.push(JobSpec::PolicySummary, runs.clone());
    let crosspaper = g.push(JobSpec::CrossPaper, runs);
    (summary, crosspaper)
}

fn push_stash_block(
    g: &mut JobGraph,
    models: &[&str],
    codecs: &[CodecKind],
    budgets: &[usize],
    batch: usize,
    sample: usize,
    layouts: &[&str],
) -> usize {
    let mut runs = Vec::new();
    for &model in models {
        for &codec in codecs {
            for &budget in budgets {
                runs.push(g.push(stash_spec(model, codec, budget, batch, sample), vec![]));
            }
        }
        for &layout in layouts {
            runs.push(g.push(layout_stash_spec(model, layout, batch, sample), vec![]));
        }
    }
    g.push(JobSpec::StashSummary, runs)
}

/// Train-variant axis of the paper grid (base containers + every
/// adaptation method, stashing through the gecko codec).
fn push_train_block(g: &mut JobGraph, artifacts_dir: &Path, budgets: &[usize]) {
    let manifest = artifacts_dir.join("manifest.json");
    let Ok(hash) = super::hash::file_hash(&manifest) else {
        return; // no compiled artifacts: the e2e leg stays out of the grid
    };
    for variant in ["fp32", "bf16", "qm", "bc", "qmqe", "bw"] {
        let stash_codec = match variant {
            "fp32" | "bf16" => None,
            _ => Some(CodecKind::Gecko),
        };
        let budget = budgets.first().copied().unwrap_or(0);
        g.push(
            JobSpec::Train(TrainSpec {
                variant: variant.into(),
                container: Container::Bf16,
                epochs: 6,
                steps_per_epoch: 40,
                eval_batches: 4,
                lr0: 0.05,
                momentum: 0.9,
                seed: 42,
                stash_codec,
                budget_bytes: budget,
                artifacts_dir: artifacts_dir.to_string_lossy().into_owned(),
                manifest_hash: hash.clone(),
            }),
            vec![],
        );
    }
}

/// The full paper grid: every policy kind (QM+QE / BitWave / QM plus the
/// cross-paper AdaptivFloat, Flexpoint, fp8 and bf16 families) × trace
/// models, every stash codec × model × budget point plus a block-shared
/// layout point, both tables (analytic and stash-measured), the
/// trace-source figures, and — when artifacts exist — the e2e train
/// variants.  The policy runs feed both `policy_summary.json` and the
/// cross-paper comparison `crosspaper.json`.
pub fn paper_grid(opts: &GridOptions) -> Grid {
    let mut g = JobGraph::new();
    let models = ["resnet18", "mobilenet"];
    let (policy_summary, crosspaper) = push_policy_block(
        &mut g,
        &models,
        &PolicyKind::all(),
        &SweepConfig {
            batch: opts.batch,
            ..Default::default()
        },
    );
    let stash_summary = push_stash_block(
        &mut g,
        &models,
        &CodecKind::all(),
        &opts.budgets,
        opts.batch,
        SAMPLE,
        &["block:16"],
    );
    g.push(JobSpec::Table1, vec![]);
    g.push(
        JobSpec::Table2 {
            batch: opts.batch,
            source: "model".into(),
        },
        vec![],
    );
    g.push(
        JobSpec::Table2 {
            batch: opts.batch,
            source: "stash".into(),
        },
        vec![],
    );
    for id in [9usize, 10, 12, 13] {
        g.push(
            JobSpec::Figure {
                id,
                batch: opts.batch,
                sample: 64 * 512,
            },
            vec![],
        );
    }
    if let Some(dir) = &opts.artifacts_dir {
        push_train_block(&mut g, dir, &opts.budgets);
    }
    Grid {
        graph: g,
        policy_summary: Some(policy_summary),
        crosspaper: Some(crosspaper),
        stash_summary: Some(stash_summary),
    }
}

/// The tiny CI/bench grid: a 2 models × 2 codecs × 2 budgets stash core
/// plus one block-shared layout point per model, short policy sweeps over
/// the cross-paper container families, both cheap tables, and the trace
/// figures at a reduced sample — small enough to run twice per CI job.
pub fn smoke_grid() -> Grid {
    let mut g = JobGraph::new();
    let (policy_summary, crosspaper) = push_policy_block(
        &mut g,
        &["resnet18"],
        &[
            PolicyKind::QmQe,
            PolicyKind::QmOnly,
            PolicyKind::AdaptivFloat,
            PolicyKind::Flexpoint,
            PolicyKind::Fp8,
            PolicyKind::Bf16,
        ],
        &SweepConfig {
            epochs: 6,
            steps_per_epoch: 20,
            batch: 128,
            sample: 8 * 1024,
            ..Default::default()
        },
    );
    let stash_summary = push_stash_block(
        &mut g,
        &["resnet18", "mobilenet"],
        &[CodecKind::Gecko, CodecKind::Js],
        &[0, 256 * 1024],
        128,
        8 * 1024,
        &["block:16"],
    );
    g.push(JobSpec::Table1, vec![]);
    g.push(
        JobSpec::Table2 {
            batch: 256,
            source: "model".into(),
        },
        vec![],
    );
    for id in [9usize, 10, 12, 13] {
        g.push(
            JobSpec::Figure {
                id,
                batch: 256,
                sample: 4096,
            },
            vec![],
        );
    }
    Grid {
        graph: g,
        policy_summary: Some(policy_summary),
        crosspaper: Some(crosspaper),
        stash_summary: Some(stash_summary),
    }
}

/// Aggregate outcome counts of a run.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct RunTotals {
    pub total: usize,
    pub executed: usize,
    pub cached: usize,
    pub failed: usize,
    pub skipped: usize,
}

impl RunTotals {
    pub fn of(reports: &[JobReport]) -> RunTotals {
        let mut t = RunTotals {
            total: reports.len(),
            ..Default::default()
        };
        for r in reports {
            match r.status {
                JobStatus::Executed => t.executed += 1,
                JobStatus::Cached => t.cached += 1,
                JobStatus::Failed(_) => t.failed += 1,
                JobStatus::Skipped => t.skipped += 1,
            }
        }
        t
    }

    pub fn cache_hit_rate(&self) -> f64 {
        if self.total == 0 {
            return 1.0;
        }
        self.cached as f64 / self.total as f64
    }
}

/// Write the consolidated `lab_manifest.json` for one run: per-job rows
/// (kind, label, content hash, status, wall-clock, artifact fingerprints)
/// plus the totals the warm-cache acceptance gate asserts on.
pub fn write_manifest(
    path: &Path,
    reports: &[JobReport],
    wall_ms: f64,
    mode: &str,
) -> Result<RunTotals> {
    let totals = RunTotals::of(reports);
    let jobs: Vec<Json> = reports
        .iter()
        .map(|r| {
            let mut m = BTreeMap::new();
            m.insert("id".to_string(), Json::Num(r.id as f64));
            m.insert("kind".to_string(), Json::Str(r.kind.clone()));
            m.insert("label".to_string(), Json::Str(r.label.clone()));
            m.insert("hash".to_string(), Json::Str(r.hash.clone()));
            let (status, error) = match &r.status {
                JobStatus::Executed => ("executed", None),
                JobStatus::Cached => ("cached", None),
                JobStatus::Failed(e) => ("failed", Some(e.clone())),
                JobStatus::Skipped => ("skipped", None),
            };
            m.insert("status".to_string(), Json::Str(status.to_string()));
            if let Some(e) = error {
                m.insert("error".to_string(), Json::Str(e));
            }
            if let Some(tail) = &r.stderr_tail {
                m.insert("stderr_tail".to_string(), Json::Str(tail.clone()));
            }
            m.insert("wall_ms".to_string(), Json::Num(r.wall_ms));
            m.insert(
                "artifacts".to_string(),
                Json::Arr(
                    r.artifacts
                        .iter()
                        .map(super::cache::ArtifactInfo::to_json)
                        .collect(),
                ),
            );
            Json::Obj(m)
        })
        .collect();
    let mut root = BTreeMap::new();
    root.insert("mode".to_string(), Json::Str(mode.to_string()));
    root.insert("wall_ms".to_string(), Json::Num(wall_ms));
    root.insert("total_jobs".to_string(), Json::Num(totals.total as f64));
    root.insert("executed".to_string(), Json::Num(totals.executed as f64));
    root.insert("cached".to_string(), Json::Num(totals.cached as f64));
    root.insert("failed".to_string(), Json::Num(totals.failed as f64));
    root.insert("skipped".to_string(), Json::Num(totals.skipped as f64));
    root.insert(
        "cache_hit_rate".to_string(),
        Json::Num(totals.cache_hit_rate()),
    );
    root.insert("jobs".to_string(), Json::Arr(jobs));
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, Json::Obj(root).to_string())?;
    Ok(totals)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_grid_shape() {
        let grid = smoke_grid();
        // 6 policy + summary + crosspaper + 10 stash (8 core + 2 layout)
        // + summary + 2 tables + 4 figures
        assert_eq!(grid.graph.len(), 25);
        let hashes = grid.graph.hashes();
        let unique: std::collections::BTreeSet<_> = hashes.iter().collect();
        assert_eq!(unique.len(), hashes.len(), "every job hash distinct");
        let kinds: Vec<&str> = grid.graph.nodes.iter().map(|n| n.spec.kind()).collect();
        assert!(kinds.contains(&"crosspaper"));
        // the cross-paper container families all ride the smoke grid
        let labels: Vec<String> = grid.graph.nodes.iter().map(|n| n.spec.label()).collect();
        for policy in ["qm+qe", "qm", "qm+af", "flexpoint", "fp8", "bf16"] {
            assert!(
                labels.iter().any(|l| l == &format!("policy:resnet18/{policy}")),
                "missing {policy}"
            );
        }
        assert!(labels.iter().any(|l| l.contains("block:16")));
    }

    #[test]
    fn manifest_attaches_stderr_tail_to_failed_rows_only() {
        let reports = vec![
            JobReport {
                id: 0,
                kind: "probe".into(),
                label: "probe:ok".into(),
                hash: "aaaa".into(),
                status: JobStatus::Executed,
                wall_ms: 1.0,
                artifacts: Vec::new(),
                stderr_tail: None,
            },
            JobReport {
                id: 1,
                kind: "probe".into(),
                label: "probe:boom".into(),
                hash: "bbbb".into(),
                status: JobStatus::Failed("worker died".into()),
                wall_ms: 2.0,
                artifacts: Vec::new(),
                stderr_tail: Some("panic at job body\nsecond line".into()),
            },
        ];
        let dir = std::env::temp_dir().join(format!("sfp_grid_tail_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("lab_manifest.json");
        let totals = write_manifest(&path, &reports, 3.0, "test").unwrap();
        assert_eq!(totals.failed, 1);
        let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let jobs = j.get("jobs").unwrap();
        assert!(jobs.idx(0).unwrap().get("stderr_tail").is_none());
        assert_eq!(
            jobs.idx(1)
                .unwrap()
                .get("stderr_tail")
                .and_then(Json::as_str),
            Some("panic at job body\nsecond line")
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn paper_grid_covers_the_axes() {
        let grid = paper_grid(&GridOptions::default());
        let kinds: Vec<&str> = grid
            .graph
            .nodes
            .iter()
            .map(|n| n.spec.kind())
            .collect();
        // 14 policy runs (2 models × 7 policies)
        assert_eq!(kinds.iter().filter(|k| **k == "policy").count(), 14);
        // 18 stash runs (2 models × (4 codecs × 2 budgets + 1 layout))
        assert_eq!(kinds.iter().filter(|k| **k == "stash").count(), 18);
        assert!(kinds.contains(&"crosspaper"));
        assert!(kinds.contains(&"table1") && kinds.contains(&"table2"));
        assert_eq!(kinds.iter().filter(|k| **k == "figure").count(), 4);
        // no artifacts dir: the e2e leg stays out
        assert!(!kinds.contains(&"train"));
    }
}
