//! Process-level remote execution backend: job specs ship to `repro
//! worker` subprocesses over a one-line-per-message JSON protocol, and the
//! shared content-addressed cache is the *only* artifact channel — workers
//! commit `<kind>-<hash>` entries exactly as the in-process path does, so
//! manifest fingerprints stay byte-identical to `--serial` no matter where
//! a job ran.  Scaling past one machine is therefore a cache-layout
//! question (point workers at a shared root), not an architecture one.
//!
//! Protocol (orchestrator → worker on stdin, worker → orchestrator on
//! stdout, one JSON object per line):
//!
//! ```text
//! → {"kind":"stash","label":"...","hash":"<cone-chained content hash>",
//!    "threads":2,"params":{...canonical spec params...},
//!    "deps":[{"kind":"stash","hash":"..."}]}
//! ← {"hash":"...","ok":true}            entry committed (or already present)
//! ← {"hash":"...","ok":false,"error":"..."}
//! ```
//!
//! The worker rebuilds the spec via [`JobSpec::from_parts`] (round-trip is
//! byte-exact, so params re-render identically), resolves dependency
//! artifacts through fingerprint-verified cache lookups, executes under
//! `catch_unwind` (a panicking job answers `ok:false` and the worker lives
//! on), and commits by atomic rename.  Job bodies never write to stdout,
//! so the protocol stream stays clean; worker stderr is piped into a
//! bounded per-slot tail buffer whose contents attach to a failed job's
//! manifest row (diagnosable without a serial re-run).
//!
//! The flight recorder rides the protocol without touching job identity:
//! a traced request carries `"trace":true` (transport-level — never part
//! of `params`, so job hashes are unchanged), and the worker answers with
//! an extra `{"hash":…,"spans":[…],"counters":[…],"events":[…]}` line
//! *before* the response.  Spans and counter samples ship only when
//! traced; adaptation events are always-on (they carry the paper's core
//! signal) and ship on the same line even untraced — the `"spans"` key is
//! the batch marker either way.  The orchestrator absorbs batch lines in
//! its receive loop and merges all three streams into the host timeline
//! keyed by job hash ([`crate::obs::trace::absorb_remote_batch`]).
//!
//! Crash isolation: each scheduler thread leases one persistent worker
//! subprocess.  A worker that dies mid-job (killed, aborted, OOM) surfaces
//! as an I/O error on the protocol pipe — the orchestrator fails just that
//! job (poisoning its dependent cone) and respawns the slot's worker
//! lazily for the next job.  A killed worker can leave only a `.tmp-`
//! staging directory, never a partial committed entry; dead-pid staging is
//! swept on the next [`ResultCache::open`].  Warm runs resolve every job
//! orchestrator-side, so a 100%-cached run spawns zero subprocesses.

use super::cache::{JobRecord, ResultCache};
use super::exec::{stage_execute_commit, ExecBackend, ExecRequest};
use super::spec::JobSpec;
use crate::obs;
use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::collections::{BTreeMap, VecDeque};
use std::io::{BufRead, BufReader, Write};
use std::path::{Path, PathBuf};
use std::process::{Child, ChildStdin, ChildStdout, Command, Stdio};
use std::sync::{Arc, Mutex};

/// Lines kept from the end of a worker's stderr stream.
const STDERR_TAIL_LINES: usize = 50;
/// Per-line byte cap of the stderr tail (keeps manifests bounded).
const STDERR_TAIL_LINE_BYTES: usize = 400;

/// Rolling tail of one worker subprocess's stderr, fed by a drain thread.
type StderrTail = Arc<Mutex<VecDeque<String>>>;

/// One leased worker subprocess (protocol pipes + the child handle).
struct Worker {
    child: Child,
    stdin: ChildStdin,
    stdout: BufReader<ChildStdout>,
}

/// A dispatch slot: the live worker (if any) plus the stderr tail of the
/// slot's current or most recently retired worker — kept outside
/// [`Worker`] so a failed job can still attach the tail after the worker
/// was killed and reaped.
#[derive(Default)]
struct SlotState {
    worker: Option<Worker>,
    tail: Option<StderrTail>,
}

/// [`ExecBackend`] that dispatches cache misses to `repro worker`
/// subprocesses: one persistent worker per scheduler thread, spawned
/// lazily on first use and respawned after a death.
pub struct ProcessBackend {
    cache_root: PathBuf,
    program: PathBuf,
    slots: Vec<Mutex<SlotState>>,
}

impl ProcessBackend {
    /// `workers` slots dispatching into the cache at `cache_root`;
    /// `program` is the worker binary (defaults to this executable, which
    /// is the `repro` binary in production).
    pub fn new(
        cache_root: &Path,
        workers: usize,
        program: Option<PathBuf>,
    ) -> Result<ProcessBackend> {
        let program = match program {
            Some(p) => p,
            None => std::env::current_exe().context("resolve current executable")?,
        };
        Ok(ProcessBackend {
            cache_root: cache_root.to_path_buf(),
            program,
            slots: (0..workers.max(1))
                .map(|_| Mutex::new(SlotState::default()))
                .collect(),
        })
    }

    pub fn workers(&self) -> usize {
        self.slots.len()
    }

    fn spawn_worker(&self) -> Result<(Worker, StderrTail)> {
        let mut child = Command::new(&self.program)
            .arg("worker")
            .arg("--cache")
            .arg(&self.cache_root)
            .stdin(Stdio::piped())
            .stdout(Stdio::piped())
            .stderr(Stdio::piped())
            .spawn()
            .with_context(|| format!("spawn worker {}", self.program.display()))?;
        let stdin = child.stdin.take().expect("piped stdin");
        let stdout = BufReader::new(child.stdout.take().expect("piped stdout"));
        let stderr = child.stderr.take().expect("piped stderr");
        let tail: StderrTail = Arc::new(Mutex::new(VecDeque::new()));
        let sink = Arc::clone(&tail);
        // The drain thread exits when the pipe closes (worker death or
        // shutdown); it holds only the tail Arc, so it never blocks a reap.
        std::thread::spawn(move || {
            for line in BufReader::new(stderr).lines() {
                let Ok(mut line) = line else { break };
                if line.len() > STDERR_TAIL_LINE_BYTES {
                    let mut cut = STDERR_TAIL_LINE_BYTES;
                    while !line.is_char_boundary(cut) {
                        cut -= 1;
                    }
                    line.truncate(cut);
                }
                let Ok(mut t) = sink.lock() else { break };
                if t.len() == STDERR_TAIL_LINES {
                    t.pop_front();
                }
                t.push_back(line);
            }
        });
        Ok((
            Worker {
                child,
                stdin,
                stdout,
            },
            tail,
        ))
    }

    fn ensure_worker(&self, slot: &mut SlotState) -> Result<()> {
        if slot.worker.is_none() {
            let (w, tail) = self.spawn_worker()?;
            slot.worker = Some(w);
            slot.tail = Some(tail);
        }
        Ok(())
    }
}

impl ExecBackend for ProcessBackend {
    fn execute(
        &self,
        worker: usize,
        cache: &ResultCache,
        req: &ExecRequest,
    ) -> Result<JobRecord> {
        let slot = &self.slots[worker % self.slots.len()];
        let mut guard = slot.lock().unwrap();
        self.ensure_worker(&mut guard)?;

        let line = render_request(req);
        let send = |w: &mut Worker| -> std::io::Result<()> {
            w.stdin.write_all(line.as_bytes())?;
            w.stdin.write_all(b"\n")?;
            w.stdin.flush()
        };
        // A send failure means the slot's worker died while *idle* (between
        // jobs): the request provably never reached it, so a fresh worker
        // can take the job with no double-execution risk — respawn once and
        // retry rather than spuriously poisoning the cone.
        if let Err(first) = send(guard.worker.as_mut().expect("worker just ensured")) {
            retire(&mut guard);
            self.ensure_worker(&mut guard)?;
            if let Err(second) = send(guard.worker.as_mut().expect("worker respawned")) {
                retire(&mut guard);
                return Err(anyhow!(
                    "worker died before accepting the request (twice: {first}; {second}) [{}]",
                    req.label
                ));
            }
        }

        let recv = |w: &mut Worker| -> std::io::Result<String> {
            loop {
                let mut resp = String::new();
                if w.stdout.read_line(&mut resp)? == 0 {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::UnexpectedEof,
                        "worker closed its protocol stream",
                    ));
                }
                // A flight-recorder batch (spans / counter samples /
                // adaptation events) is an auxiliary line the worker
                // sends just before its reply: merge it into the host
                // timeline and keep reading for the actual response.
                if let Ok(j) = Json::parse(resp.trim()) {
                    if j.get("spans").is_some() {
                        obs::trace::absorb_remote_batch(&j);
                        continue;
                    }
                }
                return Ok(resp);
            }
        };
        match recv(guard.worker.as_mut().expect("worker present")) {
            Err(io) => {
                // the worker died mid-job (killed / aborted / OOM): reap it
                // and leave the slot empty so the next job respawns.  Only
                // this job fails — its cone poisons, siblings keep going.
                let status = retire(&mut guard)
                    .map(|s| s.to_string())
                    .unwrap_or_else(|| "unreaped".to_string());
                // a death between commit and response still leaves a valid
                // entry in the shared cache — recover it rather than
                // wasting the dependent cone on an already-computed result
                if let Some(rec) = cache.lookup(req.spec.kind(), req.hash) {
                    return Ok(rec);
                }
                Err(anyhow!(
                    "worker subprocess died mid-job ({status}): {io} [{}]",
                    req.label
                ))
            }
            Ok(resp) => {
                let reply = match parse_response(&resp) {
                    Ok(reply) if reply.hash == req.hash => reply,
                    parsed => {
                        // unparseable or wrong-hash response: the stream is
                        // misaligned and every later exchange on it would be
                        // off by one — retire this worker so the slot
                        // respawns clean for its next job
                        retire(&mut guard);
                        return Err(match parsed {
                            Ok(reply) => anyhow!(
                                "worker protocol desync: sent {} got {} (worker retired)",
                                req.hash,
                                reply.hash
                            ),
                            Err(e) => anyhow!("{e:#} (worker retired)"),
                        });
                    }
                };
                if let Some(err) = reply.error {
                    return Err(anyhow!("{err}"));
                }
                // The committed entry in the shared cache is the only
                // artifact channel; re-read it through the verifying lookup.
                cache.lookup(req.spec.kind(), req.hash).ok_or_else(|| {
                    anyhow!(
                        "worker reported success but {}-{} is missing or corrupt in the cache",
                        req.spec.kind(),
                        req.hash
                    )
                })
            }
        }
    }

    /// The tail of the slot's worker stderr — still available after the
    /// worker was retired, which is exactly when a failed job needs it.
    fn failure_context(&self, worker: usize) -> Option<String> {
        let slot = &self.slots[worker % self.slots.len()];
        let guard = slot.lock().unwrap();
        let tail = guard.tail.as_ref()?;
        let lines: Vec<String> = tail.lock().ok()?.iter().cloned().collect();
        if lines.is_empty() {
            None
        } else {
            Some(lines.join("\n"))
        }
    }
}

impl Drop for ProcessBackend {
    fn drop(&mut self) {
        for slot in &self.slots {
            if let Some(mut w) = slot.lock().unwrap().worker.take() {
                // closing stdin ends the serve loop; reap to avoid zombies
                drop(w.stdin);
                let _ = w.child.wait();
            }
        }
    }
}

/// Kill and reap a slot's worker (if any), leaving the slot empty so the
/// next job respawns lazily — the stderr tail stays behind for diagnosis.
/// Returns the exit status when reaped.
fn retire(slot: &mut SlotState) -> Option<std::process::ExitStatus> {
    let mut w = slot.worker.take()?;
    let _ = w.child.kill();
    w.child.wait().ok()
}

/// Render one request line for `req` (the orchestrator side).
fn render_request(req: &ExecRequest) -> String {
    let mut m = BTreeMap::new();
    m.insert("kind".to_string(), Json::Str(req.spec.kind().to_string()));
    m.insert("label".to_string(), Json::Str(req.label.to_string()));
    m.insert("hash".to_string(), Json::Str(req.hash.to_string()));
    m.insert("threads".to_string(), Json::Num(req.threads as f64));
    m.insert(
        "params".to_string(),
        Json::parse(&req.spec.params_json()).expect("canonical params parse"),
    );
    let deps: Vec<Json> = req
        .deps
        .iter()
        .map(|d| {
            let mut dm = BTreeMap::new();
            dm.insert("kind".to_string(), Json::Str(d.kind.clone()));
            dm.insert("hash".to_string(), Json::Str(d.hash.clone()));
            Json::Obj(dm)
        })
        .collect();
    m.insert("deps".to_string(), Json::Arr(deps));
    // transport-level tracing flag: never part of `params`, so it cannot
    // affect job hashes or artifact bytes
    if obs::enabled() {
        m.insert("trace".to_string(), Json::Bool(true));
    }
    Json::Obj(m).to_string()
}

struct Reply {
    hash: String,
    /// `None` = success; `Some` carries the worker's failure message.
    error: Option<String>,
}

fn parse_response(line: &str) -> Result<Reply> {
    let j = Json::parse(line.trim())
        .map_err(|e| anyhow!("bad worker response line: {e} ({:?})", line.trim()))?;
    let hash = j
        .get("hash")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("worker response missing 'hash'"))?
        .to_string();
    let ok = matches!(j.get("ok"), Some(Json::Bool(true)));
    let error = if ok {
        None
    } else {
        Some(
            j.get("error")
                .and_then(Json::as_str)
                .unwrap_or("worker reported failure without a message")
                .to_string(),
        )
    };
    Ok(Reply { hash, error })
}

fn render_response(hash: &str, error: Option<&str>) -> String {
    let mut m = BTreeMap::new();
    m.insert("hash".to_string(), Json::Str(hash.to_string()));
    m.insert("ok".to_string(), Json::Bool(error.is_none()));
    if let Some(e) = error {
        m.insert("error".to_string(), Json::Str(e.to_string()));
    }
    Json::Obj(m).to_string()
}

/// Serve one parsed request against the shared cache: lookup → (maybe)
/// execute under `catch_unwind` → commit.  Returns the request's hash so
/// the response echoes it even on failure.
fn serve_request(cache: &ResultCache, line: &str, nonce: &mut u64) -> (String, Option<String>) {
    let run = |nonce: &mut u64| -> Result<String> {
        let j = Json::parse(line).map_err(|e| anyhow!("bad request line: {e}"))?;
        let kind = j
            .get("kind")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("request missing 'kind'"))?
            .to_string();
        let hash = j
            .get("hash")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("request missing 'hash'"))?
            .to_string();
        let label = j.get("label").and_then(Json::as_str).unwrap_or(&kind);
        let threads = j.get("threads").and_then(Json::as_usize).unwrap_or(0);
        let params = j
            .get("params")
            .ok_or_else(|| anyhow!("request missing 'params'"))?;
        let spec = JobSpec::from_parts(&kind, params)?;

        // another worker/process may have committed this entry meanwhile —
        // the verified entry is equivalent by content-addressing
        if cache.lookup(&kind, &hash).is_some() {
            return Ok(hash);
        }
        let mut deps = Vec::new();
        for d in j.get("deps").and_then(Json::as_arr).unwrap_or(&[]) {
            let dk = d
                .get("kind")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("dep ref missing 'kind'"))?;
            let dh = d
                .get("hash")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("dep ref missing 'hash'"))?;
            deps.push(cache.lookup(dk, dh).ok_or_else(|| {
                anyhow!("dependency {dk}-{dh} missing from the shared cache")
            })?);
        }
        *nonce += 1;
        stage_execute_commit(cache, &spec, label, &hash, *nonce, &deps, threads)?;
        Ok(hash)
    };
    match run(nonce) {
        Ok(hash) => (hash, None),
        Err(e) => {
            // echo the hash when the line parsed far enough to carry one
            let hash = Json::parse(line)
                .ok()
                .and_then(|j| j.get("hash").and_then(Json::as_str).map(str::to_string))
                .unwrap_or_default();
            (hash, Some(format!("{e:#}")))
        }
    }
}

/// The `repro worker` body: serve requests from stdin until EOF (the
/// orchestrator closing the pipe is the shutdown signal).  stdout carries
/// exactly one response line per request — job bodies are quiet by the
/// lab's determinism contract, so nothing else ever lands there.
pub fn worker_main(cache_root: &Path) -> Result<()> {
    let cache = ResultCache::open(cache_root)?;
    let stdin = std::io::stdin().lock();
    let mut stdout = std::io::stdout().lock();
    let mut nonce = 0u64;
    for line in stdin.lines() {
        let line = line.context("read request line")?;
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        // a traced request turns span collection on for this worker; the
        // flag is transport-level, so parsing it twice is hash-neutral
        let traced = Json::parse(line)
            .ok()
            .map(|j| matches!(j.get("trace"), Some(Json::Bool(true))))
            .unwrap_or(false);
        if traced && !obs::enabled() {
            obs::set_enabled(true);
        }
        let (hash, error) = serve_request(&cache, line, &mut nonce);
        // ship this job's flight-recorder streams back before the reply,
        // so the orchestrator's receive loop can absorb then answer.
        // Spans and counter samples exist only when traced; adaptation
        // events are always recorded and ride along even untraced.
        let spans = if traced {
            obs::trace::take_events()
        } else {
            Vec::new()
        };
        let samples = if traced {
            obs::timeseries::take_samples()
        } else {
            Vec::new()
        };
        let adapt = obs::events::take_events();
        if !spans.is_empty() || !samples.is_empty() || !adapt.is_empty() {
            let batch = obs::trace::render_flight_batch(&hash, &spans, &samples, &adapt);
            writeln!(stdout, "{batch}").context("write flight batch line")?;
        }
        let resp = render_response(&hash, error.as_deref());
        writeln!(stdout, "{resp}").context("write response line")?;
        stdout.flush().context("flush response")?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::Container;
    use crate::lab::spec::StashSpec;
    use crate::stash::CodecKind;

    fn request() -> (JobSpec, Vec<JobRecord>) {
        let spec = JobSpec::StashRun(StashSpec {
            model: "resnet18".into(),
            policy: "qm".into(),
            codec: CodecKind::Gecko,
            container: Container::Bf16,
            batch: 64,
            budget_bytes: 0,
            sample: 1024,
            seed: 1,
            threads: 0,
            layout: String::new(),
        });
        let dep = JobRecord {
            kind: "stash".into(),
            label: "dep".into(),
            hash: "aaaa0000aaaa0000".into(),
            params_json: "{}".into(),
            artifacts: Vec::new(),
            artifacts_dir: PathBuf::from("/nonexistent"),
        };
        (spec, vec![dep])
    }

    #[test]
    fn request_line_round_trips_spec_hash_and_deps() {
        let (spec, deps) = request();
        let req = ExecRequest {
            spec: &spec,
            hash: "0123456789abcdef",
            label: "stash:resnet18",
            threads: 3,
            deps: &deps,
        };
        let line = render_request(&req);
        assert!(!line.contains('\n'), "one request = one line");
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.get("hash").unwrap().as_str(), Some("0123456789abcdef"));
        assert_eq!(j.get("threads").unwrap().as_usize(), Some(3));
        let back = JobSpec::from_parts(
            j.get("kind").unwrap().as_str().unwrap(),
            j.get("params").unwrap(),
        )
        .unwrap();
        assert_eq!(back, spec);
        assert_eq!(back.params_json(), spec.params_json());
        let dep = j.get("deps").unwrap().idx(0).unwrap();
        assert_eq!(dep.get("hash").unwrap().as_str(), Some("aaaa0000aaaa0000"));
    }

    #[test]
    fn trace_flag_rides_the_protocol_only_when_enabled() {
        let _g = crate::obs::test_guard();
        crate::obs::set_enabled(false);
        let (spec, deps) = request();
        let req = ExecRequest {
            spec: &spec,
            hash: "0123456789abcdef",
            label: "stash:resnet18",
            threads: 1,
            deps: &deps,
        };
        let plain = Json::parse(&render_request(&req)).unwrap();
        assert!(plain.get("trace").is_none(), "untraced request stays lean");
        crate::obs::set_enabled(true);
        let traced = Json::parse(&render_request(&req)).unwrap();
        assert_eq!(traced.get("trace"), Some(&Json::Bool(true)));
        // the flag lives outside params: job identity is untouched
        assert_eq!(traced.get("params"), plain.get("params"));
        crate::obs::set_enabled(false);
    }

    #[test]
    fn response_lines_round_trip() {
        let ok = parse_response(&render_response("abcd", None)).unwrap();
        assert_eq!(ok.hash, "abcd");
        assert!(ok.error.is_none());
        let err = parse_response(&render_response("abcd", Some("boom\nline2"))).unwrap();
        assert_eq!(err.error.as_deref(), Some("boom\nline2"));
        assert!(parse_response("not json").is_err());
    }

    #[test]
    fn worker_serves_a_request_against_a_fresh_cache() {
        let dir = std::env::temp_dir().join(format!("sfp_remote_serve_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cache = ResultCache::open(&dir).unwrap();
        let spec = JobSpec::Probe {
            mode: "ok".into(),
            payload: 9,
        };
        let req = ExecRequest {
            spec: &spec,
            hash: "feedfacefeedface",
            label: "probe:ok",
            threads: 0,
            deps: &[],
        };
        let mut nonce = 0;
        let (hash, error) = serve_request(&cache, &render_request(&req), &mut nonce);
        assert_eq!(hash, "feedfacefeedface");
        assert_eq!(error, None);
        let rec = cache.lookup("probe", "feedfacefeedface").expect("committed");
        assert_eq!(rec.artifacts.len(), 1);
        // second serve resolves from the cache without re-executing
        let (_, error) = serve_request(&cache, &render_request(&req), &mut nonce);
        assert_eq!(error, None);

        // a panicking body answers ok:false and leaves no committed entry
        let boom = JobSpec::Probe {
            mode: "panic".into(),
            payload: 1,
        };
        let req = ExecRequest {
            spec: &boom,
            hash: "0000111122223333",
            label: "probe:panic",
            threads: 0,
            deps: &[],
        };
        let (hash, error) = serve_request(&cache, &render_request(&req), &mut nonce);
        assert_eq!(hash, "0000111122223333");
        assert!(error.unwrap().contains("panicked"));
        assert!(cache.lookup("probe", "0000111122223333").is_none());
    }
}
