//! Deterministic content hashing for job identities and artifact
//! fingerprints (FNV-1a 64-bit — the environment is offline, so no crypto
//! crates; collision resistance at lab-grid scale is ample and the hash is
//! stable across runs, platforms, and compilers by construction).

/// FNV-1a over a byte slice.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Canonical 16-hex-digit rendering of a content hash.
pub fn hex16(h: u64) -> String {
    format!("{h:016x}")
}

/// Hash a job identity: its kind, canonical parameter JSON, the hashes of
/// its dependencies (so one upstream config change re-hashes — and
/// therefore re-runs — exactly the downstream cone), and the cache schema
/// version.  Field separators are unambiguous (`\x1f`), so adjacent
/// fields can never alias.
pub fn job_hash(kind: &str, params_json: &str, dep_hashes: &[String], version: u32) -> String {
    let mut buf = String::with_capacity(params_json.len() + 64);
    buf.push_str(kind);
    buf.push('\x1f');
    buf.push_str(params_json);
    buf.push('\x1f');
    for d in dep_hashes {
        buf.push_str(d);
        buf.push(',');
    }
    buf.push('\x1f');
    buf.push_str(&version.to_string());
    hex16(fnv1a64(buf.as_bytes()))
}

/// Hash a file's contents (artifact fingerprints in the cache records and
/// the lab manifest — what the byte-equivalence acceptance check compares).
pub fn file_hash(path: &std::path::Path) -> std::io::Result<String> {
    let bytes = std::fs::read(path)?;
    Ok(hex16(fnv1a64(&bytes)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_known_vectors() {
        // standard FNV-1a test vectors
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn job_hash_separates_fields() {
        // kind/params must not alias across the separator
        let a = job_hash("ab", "c", &[], 1);
        let b = job_hash("a", "bc", &[], 1);
        assert_ne!(a, b);
        // dep hashes feed the identity
        let no_dep = job_hash("k", "p", &[], 1);
        let dep = job_hash("k", "p", &["x".into()], 1);
        assert_ne!(no_dep, dep);
        // schema version bumps invalidate everything
        assert_ne!(job_hash("k", "p", &[], 1), job_hash("k", "p", &[], 2));
    }
}
