//! Content-addressed on-disk result cache.  Each completed job owns one
//! entry directory named `<kind>-<hash>` containing its artifacts plus a
//! `job.json` record; a warm re-run of an unchanged grid resolves every
//! job here and executes nothing.
//!
//! Layout:
//!
//! ```text
//! <root>/<kind>-<hash>/job.json        record: params, artifact fingerprints
//! <root>/<kind>-<hash>/artifacts/...   the job's output files
//! ```
//!
//! Commits are atomic-by-rename: a job executes into a private staging
//! directory and the finished entry is renamed into place, so concurrent
//! workers (or a killed run) can never expose a half-written entry.  The
//! record stores per-artifact byte counts and FNV fingerprints;
//! [`ResultCache::lookup`] re-verifies them so a truncated entry is
//! treated as a miss and re-executed rather than trusted.

use super::hash::file_hash;
use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Fingerprint of one artifact file inside a cache entry.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactInfo {
    /// Path relative to the entry's `artifacts/` directory.
    pub rel: String,
    pub bytes: u64,
    /// FNV-1a content hash (hex).
    pub hash: String,
}

impl ArtifactInfo {
    /// The one JSON rendering shared by cache records and run manifests.
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("rel".to_string(), Json::Str(self.rel.clone()));
        m.insert("bytes".to_string(), Json::Num(self.bytes as f64));
        m.insert("hash".to_string(), Json::Str(self.hash.clone()));
        Json::Obj(m)
    }
}

/// A committed (or freshly looked-up) cache entry.
#[derive(Debug, Clone)]
pub struct JobRecord {
    pub kind: String,
    pub label: String,
    pub hash: String,
    pub params_json: String,
    pub artifacts: Vec<ArtifactInfo>,
    /// Absolute path of the entry's `artifacts/` directory.
    pub artifacts_dir: PathBuf,
}

/// The cache root.
pub struct ResultCache {
    root: PathBuf,
}

impl ResultCache {
    /// Open (creating if needed) a cache rooted at `root`.  Staging
    /// directories orphaned by a *dead* run (`.tmp-<kind>-<hash>-<pid>-<n>`
    /// whose pid no longer exists) are swept here — their pid+nonce names
    /// never collide with a new run's, so nothing else would reclaim them.
    /// Live processes sharing the cache root keep their staging dirs.
    pub fn open(root: &Path) -> Result<ResultCache> {
        std::fs::create_dir_all(root)
            .with_context(|| format!("create cache root {}", root.display()))?;
        if let Ok(entries) = std::fs::read_dir(root) {
            for entry in entries.flatten() {
                let name = entry.file_name().to_string_lossy().into_owned();
                if (name.starts_with(".tmp-") || name.starts_with(".trash-"))
                    && staging_pid_is_dead(&name)
                {
                    let _ = std::fs::remove_dir_all(entry.path());
                }
            }
        }
        Ok(ResultCache {
            root: root.to_path_buf(),
        })
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    fn entry_dir(&self, kind: &str, hash: &str) -> PathBuf {
        self.root.join(format!("{kind}-{hash}"))
    }

    /// Artifact directory of a (possibly not yet existing) entry — for
    /// callers that already hold a verified [`JobReport`]'s artifact list
    /// and only need the files, without a re-verifying [`ResultCache::lookup`].
    pub fn entry_artifacts_dir(&self, kind: &str, hash: &str) -> PathBuf {
        self.entry_dir(kind, hash).join("artifacts")
    }

    /// Look a job up by content hash; verifies the record and every
    /// artifact fingerprint so a corrupt entry reads as a miss.
    pub fn lookup(&self, kind: &str, hash: &str) -> Option<JobRecord> {
        read_entry(&self.entry_dir(kind, hash))
    }

    /// Begin a job execution: returns a private staging directory whose
    /// `artifacts/` subdirectory the job writes into.
    pub fn stage(&self, kind: &str, hash: &str, nonce: u64) -> Result<PathBuf> {
        let dir = self
            .root
            .join(format!(".tmp-{kind}-{hash}-{}-{nonce}", std::process::id()));
        if dir.exists() {
            std::fs::remove_dir_all(&dir)?;
        }
        std::fs::create_dir_all(dir.join("artifacts"))?;
        Ok(dir)
    }

    /// Commit a staged execution: fingerprint every artifact, write the
    /// record, and rename the staging directory into place.  If another
    /// worker committed the same hash first, the staging copy is discarded
    /// and the winner's record is returned.
    pub fn commit(
        &self,
        kind: &str,
        label: &str,
        hash: &str,
        params_json: &str,
        staging: &Path,
    ) -> Result<JobRecord> {
        let art_dir = staging.join("artifacts");
        let mut artifacts = Vec::new();
        collect_artifacts(&art_dir, Path::new(""), &mut artifacts)?;
        artifacts.sort_by(|a, b| a.rel.cmp(&b.rel));

        let mut rec = BTreeMap::new();
        rec.insert("version".to_string(), Json::Num(super::spec::CACHE_VERSION as f64));
        rec.insert("kind".to_string(), Json::Str(kind.to_string()));
        rec.insert("label".to_string(), Json::Str(label.to_string()));
        rec.insert("hash".to_string(), Json::Str(hash.to_string()));
        rec.insert(
            "params".to_string(),
            Json::parse(params_json).map_err(|e| anyhow!("bad params json: {e}"))?,
        );
        rec.insert(
            "artifacts".to_string(),
            Json::Arr(artifacts.iter().map(ArtifactInfo::to_json).collect()),
        );
        std::fs::write(staging.join("job.json"), Json::Obj(rec).to_string())?;

        let final_dir = self.entry_dir(kind, hash);
        let mut attempts = 0;
        while let Err(e) = std::fs::rename(staging, &final_dir) {
            // The slot is occupied.  A *verified* occupant means another
            // worker or process won the commit race — by content-addressing
            // its artifacts are equivalent, so ours are surplus and the
            // winner's record is the result.
            if let Some(winner) = self.lookup(kind, hash) {
                let _ = std::fs::remove_dir_all(staging);
                return Ok(winner);
            }
            attempts += 1;
            if attempts > 8 {
                return Err(anyhow!(
                    "commit rename to {} failed after {attempts} attempts: {e}",
                    final_dir.display()
                ));
            }
            // The occupant looked corrupt (truncated by a killed run,
            // tampered with, or half-deleted).  Evict it by renaming it
            // aside — never remove_dir_all in place — then re-verify the
            // renamed-aside copy: if it is actually a *valid* entry, a
            // fresh commit of the same hash raced in between our lookup
            // and the eviction, and deleting it would destroy the winner
            // while its dependents may already be reading it — so rename
            // it straight back (the next loop pass then yields to it).
            // Only a copy that re-verifies as corrupt is deleted.  The
            // trash name keeps the staging dir's pid+nonce suffix so a
            // dead run's leftovers are swept by `open` like any orphaned
            // staging directory.
            let staging_name = staging
                .file_name()
                .map(|n| n.to_string_lossy().into_owned())
                .unwrap_or_default();
            // attempt counter up front: the name must keep `<pid>-<nonce>`
            // as its trailing segments for the dead-pid sweep to parse
            let trash = self.root.join(format!(
                ".trash-{attempts}-{}",
                staging_name.trim_start_matches(".tmp-")
            ));
            let _ = std::fs::rename(&final_dir, &trash);
            if read_entry(&trash).is_some() {
                // we grabbed a racing winner: restore it; if yet another
                // equivalent entry landed meanwhile, ours-aside is surplus
                if std::fs::rename(&trash, &final_dir).is_err() {
                    let _ = std::fs::remove_dir_all(&trash);
                }
            } else {
                let _ = std::fs::remove_dir_all(&trash);
            }
        }
        // The fingerprints were computed from the files just written; no
        // need to re-read the whole entry through a verifying lookup.
        Ok(JobRecord {
            kind: kind.to_string(),
            label: label.to_string(),
            hash: hash.to_string(),
            params_json: params_json.to_string(),
            artifacts,
            artifacts_dir: final_dir.join("artifacts"),
        })
    }

    /// Abort a staged execution, removing its directory.
    pub fn discard(&self, staging: &Path) {
        let _ = std::fs::remove_dir_all(staging);
    }
}

/// Read and fingerprint-verify one entry directory (a committed
/// `<kind>-<hash>` slot, or a renamed-aside candidate during a commit-race
/// eviction).  Any truncated or tampered artifact reads as `None`.
fn read_entry(dir: &Path) -> Option<JobRecord> {
    let record = std::fs::read_to_string(dir.join("job.json")).ok()?;
    let j = Json::parse(&record).ok()?;
    let artifacts_dir = dir.join("artifacts");
    let mut artifacts = Vec::new();
    for a in j.get("artifacts")?.as_arr()? {
        let info = ArtifactInfo {
            rel: a.get("rel")?.as_str()?.to_string(),
            bytes: a.get("bytes")?.as_f64()? as u64,
            hash: a.get("hash")?.as_str()?.to_string(),
        };
        let path = artifacts_dir.join(&info.rel);
        let meta = std::fs::metadata(&path).ok()?;
        if meta.len() != info.bytes || file_hash(&path).ok()? != info.hash {
            return None; // truncated or tampered entry: treat as miss
        }
        artifacts.push(info);
    }
    Some(JobRecord {
        kind: j.get("kind")?.as_str()?.to_string(),
        label: j.get("label")?.as_str()?.to_string(),
        hash: j.get("hash")?.as_str()?.to_string(),
        params_json: j.get("params")?.to_string(),
        artifacts,
        artifacts_dir,
    })
}

/// Does the staging-dir name `.tmp-<kind>-<hash>-<pid>-<nonce>` (or a
/// commit-eviction `.trash-<n>-…-<pid>-<nonce>` leftover) belong to a
/// process that no longer exists?  Unparseable names read as live (never
/// delete what we can't attribute); our own pid reads as dead — a
/// same-pid leftover can only be from a previous process instance.
fn staging_pid_is_dead(name: &str) -> bool {
    let mut parts = name.rsplit('-');
    let _nonce = parts.next();
    let Some(pid) = parts.next().and_then(|p| p.parse::<u32>().ok()) else {
        return false;
    };
    if pid == std::process::id() {
        return true;
    }
    !Path::new("/proc").join(pid.to_string()).exists()
}

/// Recursively fingerprint every file under `dir` (relative paths sorted
/// by the caller).
fn collect_artifacts(dir: &Path, rel: &Path, out: &mut Vec<ArtifactInfo>) -> Result<()> {
    for entry in std::fs::read_dir(dir.join(rel))
        .with_context(|| format!("read artifact dir {}", dir.join(rel).display()))?
    {
        let entry = entry?;
        let name = entry.file_name();
        let sub = rel.join(&name);
        if entry.file_type()?.is_dir() {
            collect_artifacts(dir, &sub, out)?;
        } else {
            let path = dir.join(&sub);
            out.push(ArtifactInfo {
                rel: sub.to_string_lossy().replace('\\', "/"),
                bytes: entry.metadata()?.len(),
                hash: file_hash(&path)?,
            });
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("sfp_lab_cache_{name}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn stage_commit_lookup_roundtrip() {
        let cache = ResultCache::open(&tdir("roundtrip")).unwrap();
        assert!(cache.lookup("t", "abc").is_none());
        let staging = cache.stage("t", "abc", 0).unwrap();
        std::fs::write(staging.join("artifacts/out.json"), b"{\"x\":1}").unwrap();
        std::fs::create_dir_all(staging.join("artifacts/sub")).unwrap();
        std::fs::write(staging.join("artifacts/sub/data.csv"), b"a,b\n1,2\n").unwrap();
        let rec = cache.commit("t", "label", "abc", "{}", &staging).unwrap();
        assert_eq!(rec.artifacts.len(), 2);
        assert_eq!(rec.artifacts[0].rel, "out.json");
        assert_eq!(rec.artifacts[1].rel, "sub/data.csv");
        let hit = cache.lookup("t", "abc").expect("warm lookup");
        assert_eq!(hit.artifacts, rec.artifacts);
        assert!(hit.artifacts_dir.join("sub/data.csv").exists());
    }

    #[test]
    fn corrupt_entry_reads_as_miss() {
        let cache = ResultCache::open(&tdir("corrupt")).unwrap();
        let staging = cache.stage("t", "h1", 0).unwrap();
        std::fs::write(staging.join("artifacts/a.json"), b"payload").unwrap();
        let rec = cache.commit("t", "l", "h1", "{}", &staging).unwrap();
        // truncate the artifact behind the record's back
        std::fs::write(rec.artifacts_dir.join("a.json"), b"pay").unwrap();
        assert!(cache.lookup("t", "h1").is_none(), "size mismatch = miss");
    }

    #[test]
    fn commit_race_keeps_first_winner() {
        let cache = ResultCache::open(&tdir("race")).unwrap();
        let s1 = cache.stage("t", "h2", 1).unwrap();
        std::fs::write(s1.join("artifacts/a"), b"one").unwrap();
        cache.commit("t", "l", "h2", "{}", &s1).unwrap();
        let s2 = cache.stage("t", "h2", 2).unwrap();
        std::fs::write(s2.join("artifacts/a"), b"one").unwrap();
        let rec = cache.commit("t", "l", "h2", "{}", &s2).unwrap();
        assert_eq!(rec.artifacts.len(), 1);
        assert!(!s2.exists(), "loser staging discarded");
    }

    /// No `.tmp-` / `.trash-` residue under the cache root.
    fn assert_no_residue(root: &Path) {
        for entry in std::fs::read_dir(root).unwrap().flatten() {
            let name = entry.file_name().to_string_lossy().into_owned();
            assert!(
                !name.starts_with(".tmp-") && !name.starts_with(".trash-"),
                "leftover staging/trash dir {name}"
            );
        }
    }

    #[test]
    fn corrupt_entry_is_replaced_by_a_fresh_commit() {
        // a fingerprint-mismatched occupant must not make the re-executed
        // job's commit read as a lost race (which would discard the fresh
        // artifacts and leave the corrupt entry in place forever)
        let cache = ResultCache::open(&tdir("evict")).unwrap();
        let s1 = cache.stage("t", "h3", 1).unwrap();
        std::fs::write(s1.join("artifacts/a.json"), b"payload").unwrap();
        let rec = cache.commit("t", "l", "h3", "{}", &s1).unwrap();
        std::fs::write(rec.artifacts_dir.join("a.json"), b"pay").unwrap();
        assert!(cache.lookup("t", "h3").is_none(), "corrupt entry = miss");

        let s2 = cache.stage("t", "h3", 2).unwrap();
        std::fs::write(s2.join("artifacts/a.json"), b"payload").unwrap();
        let fresh = cache.commit("t", "l", "h3", "{}", &s2).unwrap();
        assert_eq!(fresh.artifacts.len(), 1);
        let hit = cache.lookup("t", "h3").expect("fresh entry verifies");
        assert_eq!(hit.artifacts, fresh.artifacts);
        assert_no_residue(cache.root());
    }

    #[test]
    fn concurrent_same_hash_commits_leave_one_clean_entry() {
        // many committers, one content hash: every commit must succeed
        // (winner or graceful loser), the surviving entry must verify, and
        // no partial directories may remain — including when the slot
        // starts out corrupt and eviction races the fresh commits
        let cache = ResultCache::open(&tdir("stress")).unwrap();
        for round in 0..8u64 {
            let hash = format!("h{round}");
            if round % 2 == 1 {
                // pre-corrupt the slot: a truncated artifact from a "killed run"
                let s = cache.stage("t", &hash, 1000 + round).unwrap();
                std::fs::write(s.join("artifacts/a.json"), b"full-payload").unwrap();
                let rec = cache.commit("t", "l", &hash, "{}", &s).unwrap();
                std::fs::write(rec.artifacts_dir.join("a.json"), b"x").unwrap();
            }
            std::thread::scope(|scope| {
                for t in 0..8u64 {
                    let cache = &cache;
                    let hash = &hash;
                    scope.spawn(move || {
                        let s = cache.stage("t", hash, 10 * round + t).unwrap();
                        std::fs::write(s.join("artifacts/a.json"), b"full-payload").unwrap();
                        let rec = cache.commit("t", "l", hash, "{}", &s).unwrap();
                        assert_eq!(rec.artifacts.len(), 1);
                    });
                }
            });
            let hit = cache.lookup("t", &hash).expect("winner verifies");
            assert_eq!(hit.artifacts.len(), 1);
            assert_eq!(
                std::fs::read(hit.artifacts_dir.join("a.json")).unwrap(),
                b"full-payload"
            );
        }
        assert_no_residue(cache.root());
    }
}
