//! Quiet, deterministic stash measurement — the `repro stash` experiment
//! body factored out of `main.rs` so it can run as a lab job: no printing,
//! no wall-clock timing, and a JSON rendering whose bytes depend only on
//! the [`StashSpec`](super::spec::StashSpec) (the parallel-vs-serial
//! byte-equivalence acceptance check diffs these artifacts).
//!
//! The run stores one sampled value stream per tensor through the real
//! worker pool (the same exponent streams the analytic footprint model
//! sizes Gecko on), cross-checks measured stored bytes against the
//! analytic expectation, verifies bit-exact restore, checks that an
//! undersized budget actually engages the spill tier, and couples the
//! measured bytes into the hwsim DRAM model.

use super::spec::StashSpec;
use crate::formats::{Container, ExponentLayout};
use crate::hwsim::{gains, simulate_pass_with_bits, AccelConfig, ComputeType, LayerBits};
use crate::report::footprint::{
    FootprintModel, MantissaPolicy, ACT_EXP_SEED, ACT_VAL_SEED, SAMPLE, STREAM_SEED,
    WEIGHT_EXP_SEED, WEIGHT_VAL_SEED,
};
use crate::stash::{
    CodecKind, ContainerMeta, LedgerSnapshot, Stash, StashConfig, TensorId,
};
use crate::traces::{mobilenet_v3_small, resnet18, values_with_exponents, NetworkTrace};
use crate::util::json::Json;
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;

/// Resolve a trace model by CLI name.
pub fn trace_model(name: &str) -> Result<NetworkTrace> {
    match name {
        "resnet18" => Ok(resnet18()),
        "mobilenet" | "mobilenet_v3_small" | "mnv3" => Ok(mobilenet_v3_small()),
        other => Err(anyhow!("unknown model {other} (resnet18|mobilenet)")),
    }
}

/// Resolve a mantissa-policy preset by CLI name.
pub fn mantissa_policy(name: &str, container: Container) -> Result<MantissaPolicy> {
    match name {
        "qm" => Ok(MantissaPolicy::qm_default()),
        "bc" => Ok(MantissaPolicy::bc_default(container)),
        "full" => Ok(MantissaPolicy::Full),
        other => Err(anyhow!("unknown policy {other} (qm|bc|full)")),
    }
}

/// One layer of the measurement (the verbose `repro stash` table row).
#[derive(Debug, Clone)]
pub struct LayerRow {
    pub name: String,
    pub n_a: u32,
    pub n_w: u32,
    /// Measured stored bits, scaled to full tensor size.
    pub measured_bits: f64,
    /// Analytic expectation for the same tensors.
    pub analytic_bits: f64,
}

/// The full deterministic result of one stash measurement run.
#[derive(Debug, Clone)]
pub struct StashMeasurement {
    pub spec: StashSpec,
    pub codec_name: &'static str,
    pub layers: Vec<LayerRow>,
    pub measured_total_bits: f64,
    pub analytic_total_bits: f64,
    pub fp32_total_bits: f64,
    pub ledger: LedgerSnapshot,
    pub dram_peak_bytes: usize,
    pub spill_peak_bytes: usize,
    /// hwsim on the measured bytes: (speedup, energy gain) vs FP32.
    pub hwsim_speedup: f64,
    pub hwsim_energy: f64,
    /// DRAM traffic fraction vs the FP32 baseline pass.
    pub dram_frac: f64,
    pub restore_bit_exact: bool,
}

impl StashMeasurement {
    pub fn delta_pct(&self) -> f64 {
        100.0 * (self.measured_total_bits - self.analytic_total_bits).abs()
            / self.analytic_total_bits.max(1.0)
    }

    pub fn frac_of_fp32(&self) -> f64 {
        self.measured_total_bits / self.fp32_total_bits
    }

    /// Deterministic JSON row (the lab artifact; no timings — those live
    /// in the run manifest, not in content-addressed artifacts).
    pub fn to_json(&self) -> Json {
        let mut row = BTreeMap::new();
        let mut put = |k: &str, v: Json| {
            row.insert(k.to_string(), v);
        };
        put("model", Json::Str(self.spec.model.clone()));
        put("codec", Json::Str(self.codec_name.to_string()));
        put("policy", Json::Str(self.spec.policy.clone()));
        put("batch", Json::Num(self.spec.batch as f64));
        put("budget_bytes", Json::Num(self.spec.budget_bytes as f64));
        // omitted at default, so historical artifact bytes are unchanged
        if !self.spec.layout.is_empty() {
            put("layout", Json::Str(self.spec.layout.clone()));
        }
        put("measured_mb", Json::Num(self.measured_total_bits / 8e6));
        put("analytic_mb", Json::Num(self.analytic_total_bits / 8e6));
        put("frac_of_fp32", Json::Num(self.frac_of_fp32()));
        put("dram_peak_bytes", Json::Num(self.dram_peak_bytes as f64));
        put("spill_peak_bytes", Json::Num(self.spill_peak_bytes as f64));
        put(
            "spill_written_bytes",
            Json::Num(self.ledger.spill_written_bits / 8.0),
        );
        put(
            "spill_read_bytes",
            Json::Num(self.ledger.spill_read_bits / 8.0),
        );
        put("evictions", Json::Num(self.ledger.evictions as f64));
        put("faults", Json::Num(self.ledger.faults as f64));
        put("hwsim_speedup", Json::Num(self.hwsim_speedup));
        put("hwsim_energy", Json::Num(self.hwsim_energy));
        put("dram_frac", Json::Num(self.dram_frac));
        put("restore_bit_exact", Json::Bool(self.restore_bit_exact));
        let layers: Vec<Json> = self
            .layers
            .iter()
            .map(|l| {
                let mut m = BTreeMap::new();
                m.insert("name".to_string(), Json::Str(l.name.clone()));
                m.insert("n_a".to_string(), Json::Num(l.n_a as f64));
                m.insert("n_w".to_string(), Json::Num(l.n_w as f64));
                m.insert("measured_bits".to_string(), Json::Num(l.measured_bits));
                m.insert("analytic_bits".to_string(), Json::Num(l.analytic_bits));
                Json::Obj(m)
            })
            .collect();
        put("layers", Json::Arr(layers));
        Json::Obj(row)
    }
}

/// Run one stash measurement.  Errors are real experiment failures: codec
/// divergence from the analytic model beyond 1%, a non-bit-exact restore,
/// or a budget below the working set that never engaged the spill tier.
/// `threads` is the resolved worker-pool size for this job (0 = whole
/// machine) — the scheduler budgets it so N parallel measurements don't
/// spin N full-machine pools; stored bytes are identical at any count.
pub fn run_stash_measurement(spec: &StashSpec, threads: usize) -> Result<StashMeasurement> {
    let net = trace_model(&spec.model)?;
    let policy = mantissa_policy(&spec.policy, spec.container)?;
    // exponent-layout override: empty keeps the per-value default
    let layout = if spec.layout.is_empty() {
        None
    } else {
        Some(ExponentLayout::parse_spec(&spec.layout)?)
    };
    let n_layers = net.layers.len();
    let sched = policy.integer_schedule(n_layers, spec.container);
    let stash = Stash::new(StashConfig {
        codec: spec.codec,
        threads,
        queue_depth: 0,
        chunk_values: 0,
        budget_bytes: spec.budget_bytes,
    });

    // One sampled stream per tensor, sharing the analytic model's exponent
    // streams (seeds mirror FootprintModel::layer) so measured == analytic
    // for the component-stream codec.
    let mut streams: Vec<(TensorId, Vec<f32>, ContainerMeta, f64)> = Vec::new();
    for (i, l) in net.layers.iter().enumerate() {
        let seed = spec.seed ^ i as u64;
        let (n_a, n_w) = sched[i];
        let a_exps = l.act_model.sample_exponents(spec.sample, seed ^ ACT_EXP_SEED);
        let a_vals = values_with_exponents(&a_exps, seed ^ ACT_VAL_SEED, l.nonneg_act);
        let mut a_meta = ContainerMeta::new(spec.container, n_a).with_sign_elision(l.nonneg_act);
        if let Some(l) = layout {
            a_meta = a_meta.with_layout(l);
        }
        let a_scale = (l.act_elems * spec.batch) as f64 / spec.sample as f64;
        streams.push((TensorId::act(i), a_vals, a_meta, a_scale));

        let w_count = spec.sample.min(l.weight_elems.max(64));
        let w_exps = l.weight_model.sample_exponents(w_count, seed ^ WEIGHT_EXP_SEED);
        let w_vals = values_with_exponents(&w_exps, seed ^ WEIGHT_VAL_SEED, false);
        let mut w_meta = ContainerMeta::new(spec.container, n_w);
        if let Some(l) = layout {
            w_meta = w_meta.with_layout(l);
        }
        let w_scale = l.weight_elems as f64 / w_count as f64;
        streams.push((TensorId::weight(i), w_vals, w_meta, w_scale));
    }

    for (id, v, m, _) in &streams {
        stash.put(*id, v.clone(), *m);
    }
    stash.flush();
    if stash.failures() > 0 {
        return Err(anyhow!("{} stash worker jobs failed", stash.failures()));
    }

    // --- stored bytes vs the analytic expectation ------------------------
    // gecko matches the analytic accounting bit-for-bit (on the analytic
    // model's own streams), raw and js are exact by construction, sfp
    // differs only in metadata framing (reported, ungated).
    let analytic_model = match spec.codec {
        CodecKind::Raw => Some(match spec.container {
            Container::Fp32 => FootprintModel::fp32(),
            Container::Bf16 => FootprintModel::bf16(),
        }),
        CodecKind::Js => None, // computed from the quantized streams below
        _ => Some(FootprintModel::from_schedule(spec.container, &sched)),
    };
    let cbits = spec.container.total_bits() as f64;
    // bias / block-shared overrides carry their own exact stream accounting
    let structured_layout = matches!(
        layout,
        Some(ExponentLayout::Bias { .. } | ExponentLayout::BlockShared { .. })
    );
    let mut layers = Vec::with_capacity(n_layers);
    let mut measured_total = 0.0;
    let mut analytic_total = 0.0;
    let mut measured_bits = Vec::with_capacity(n_layers);
    for (i, l) in net.layers.iter().enumerate() {
        let a = stash
            .stored_bits(TensorId::act(i))
            .ok_or_else(|| anyhow!("activation {i} not resident"))?;
        let w = stash
            .stored_bits(TensorId::weight(i))
            .ok_or_else(|| anyhow!("weight {i} not resident"))?;
        let (a_scale, w_scale) = (streams[2 * i].3, streams[2 * i + 1].3);
        let measured = a.total() * a_scale + w.total() * w_scale;
        // Exact per-stream accounting for the stream-structured layouts
        // under the component codec: bias windows store `field_bits` per
        // exponent; block-shared layouts store one field per (ragged)
        // block and one extra leading mantissa bit per value.
        let exact_layout_bits = |vals: &[f32], meta: &ContainerMeta, scale: f64| -> f64 {
            let count = vals.len() as f64;
            let n = meta.mant() as f64;
            let sign = if meta.elide_sign { 0.0 } else { count };
            let (exp, mant) = match meta.layout {
                ExponentLayout::BlockShared { block, bits } => (
                    vals.len().div_ceil(block) as f64 * bits as f64,
                    count * (n + 1.0),
                ),
                lay => (count * lay.field_bits() as f64, count * n),
            };
            (sign + exp + mant) * scale
        };
        let expected = if structured_layout && spec.codec == CodecKind::Gecko {
            let (_, av, am, asc) = &streams[2 * i];
            let (_, wv, wm, wsc) = &streams[2 * i + 1];
            exact_layout_bits(av, am, *asc) + exact_layout_bits(wv, wm, *wsc)
        } else {
            match &analytic_model {
                Some(model) => {
                    // centered depth fraction => PerLayer policy index is i
                    let frac = (i as f64 + 0.5) / n_layers as f64;
                    let lf = model.layer(l, frac, spec.batch, spec.seed ^ i as u64);
                    lf.total_act_bits() + lf.total_weight_bits()
                }
                None => {
                    // JS accounting on the actual quantized streams: one tag
                    // bit per value + container bits per non-zero (exact)
                    let js_of = |vals: &[f32], meta: &ContainerMeta, scale: f64| {
                        let nz = meta
                            .quantized_slice(vals)
                            .iter()
                            .filter(|v| v.to_bits() != 0)
                            .count() as f64;
                        (vals.len() as f64 + nz * cbits) * scale
                    };
                    let (_, av, am, asc) = &streams[2 * i];
                    let (_, wv, wm, wsc) = &streams[2 * i + 1];
                    js_of(av, am, *asc) + js_of(wv, wm, *wsc)
                }
            }
        };
        measured_bits.push(LayerBits {
            weight: w.total() * w_scale,
            act: a.total() * a_scale,
        });
        measured_total += measured;
        analytic_total += expected;
        layers.push(LayerRow {
            name: l.name.clone(),
            n_a: sched[i].0,
            n_w: sched[i].1,
            measured_bits: measured,
            analytic_bits: expected,
        });
    }
    let fp32_total = FootprintModel::fp32().network(&net, spec.batch).total();
    let delta = 100.0 * (measured_total - analytic_total).abs() / analytic_total;
    // The gecko gate only holds on the analytic model's own streams (its
    // internal sample count and seed scheme); raw and js are exact at any
    // sample, sfp's metadata framing is a known deviation.
    let gate = match spec.codec {
        CodecKind::Raw | CodecKind::Js => true,
        // the structured-layout accounting is exact at any sample/seed
        CodecKind::Gecko => {
            structured_layout || (spec.sample == SAMPLE && spec.seed == STREAM_SEED)
        }
        CodecKind::Sfp => false,
    };
    if gate && delta > 1.0 {
        return Err(anyhow!(
            "stash/analytic footprint divergence {delta:.3}% exceeds 1% \
             ({} codec, {})",
            spec.codec.label(),
            spec.model,
        ));
    }

    // --- restore: parallel decode, verified bit-exact --------------------
    let ids: Vec<TensorId> = streams.iter().map(|(id, ..)| *id).collect();
    let restored = stash.take_all(&ids);
    for ((id, vals, meta, _), back) in streams.iter().zip(&restored) {
        let back = back
            .as_ref()
            .ok_or_else(|| anyhow!("{id:?} missing at restore"))?;
        if back.len() != vals.len() {
            return Err(anyhow!("{id:?} restore length mismatch"));
        }
        // quantized_slice is the layout-generic oracle (block-shared
        // layouts have no per-value quantizer)
        let q = meta.quantized_slice(vals);
        for (&v, &b) in q.iter().zip(back) {
            if v.to_bits() != b.to_bits() {
                return Err(anyhow!("{id:?} restore not bit-exact"));
            }
        }
    }

    // --- spill tier: an undersized budget MUST engage ---------------------
    let snap = stash.ledger();
    let dram_peak = stash.arena_high_water_bytes();
    let spill_peak = stash.arena_spill_high_water_bytes();
    if spec.budget_bytes > 0
        && snap.evictions == 0
        && dram_peak + spill_peak > spec.budget_bytes
    {
        return Err(anyhow!(
            "budget {} B is below the {}-B working set but the spill tier never engaged",
            spec.budget_bytes,
            dram_peak + spill_peak
        ));
    }

    // --- hwsim on the measured bytes --------------------------------------
    let accel = AccelConfig::default();
    let fp32_bits: Vec<LayerBits> = net
        .layers
        .iter()
        .enumerate()
        .map(|(i, l)| {
            let lf = FootprintModel::fp32().layer(
                l,
                (i as f64 + 0.5) / n_layers as f64,
                spec.batch,
                0,
            );
            LayerBits {
                weight: lf.total_weight_bits(),
                act: lf.total_act_bits(),
            }
        })
        .collect();
    let compute = match spec.container {
        Container::Fp32 => ComputeType::Fp32,
        Container::Bf16 => ComputeType::Bf16,
    };
    let base = simulate_pass_with_bits(&accel, &net, spec.batch, ComputeType::Fp32, &fp32_bits);
    let ours = simulate_pass_with_bits(&accel, &net, spec.batch, compute, &measured_bits);
    let (speed, energy) = gains(&base, &ours);

    Ok(StashMeasurement {
        spec: spec.clone(),
        codec_name: stash.codec_name(),
        layers,
        measured_total_bits: measured_total,
        analytic_total_bits: analytic_total,
        fp32_total_bits: fp32_total,
        ledger: snap,
        dram_peak_bytes: dram_peak,
        spill_peak_bytes: spill_peak,
        hwsim_speedup: speed,
        hwsim_energy: energy,
        dram_frac: ours.dram_bits / base.dram_bits,
        restore_bit_exact: true,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(codec: CodecKind, budget: usize, sample: usize) -> StashSpec {
        StashSpec {
            model: "resnet18".into(),
            policy: "qm".into(),
            codec,
            container: Container::Bf16,
            batch: 64,
            budget_bytes: budget,
            sample,
            seed: STREAM_SEED,
            threads: 0,
            layout: String::new(),
        }
    }

    #[test]
    fn gecko_measurement_matches_analytic_at_full_sample() {
        let m = run_stash_measurement(&spec(CodecKind::Gecko, 0, SAMPLE), 0).unwrap();
        assert!(m.delta_pct() < 1.0, "delta {}", m.delta_pct());
        assert!(m.frac_of_fp32() < 0.5);
        assert!(m.restore_bit_exact);
        assert!(m.hwsim_speedup > 1.0 && m.hwsim_energy > 1.0);
    }

    #[test]
    fn js_measurement_is_exact_at_any_sample() {
        let m = run_stash_measurement(&spec(CodecKind::Js, 0, 2048), 0).unwrap();
        assert!(m.delta_pct() < 1e-9, "js accounting is exact: {}", m.delta_pct());
        // JS on BF16 beats dense FP32 but not the adaptive-container codecs
        assert!(m.frac_of_fp32() < 0.6);
        let g = run_stash_measurement(&spec(CodecKind::Gecko, 0, 2048), 0).unwrap();
        assert!(g.frac_of_fp32() < m.frac_of_fp32());
    }

    #[test]
    fn undersized_budget_engages_spill_tier() {
        let m = run_stash_measurement(&spec(CodecKind::Raw, 256 * 1024, 8192), 0).unwrap();
        assert!(m.ledger.evictions > 0);
        assert!(m.spill_peak_bytes > 0);
        let json = m.to_json();
        assert_eq!(json.get("codec").unwrap().as_str(), Some("raw"));
        assert!(json.get("evictions").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn block_shared_layout_measurement_is_exact_and_restores() {
        // the exact block-shared accounting gates gecko at any sample
        let m = run_stash_measurement(
            &StashSpec {
                layout: "block:16".into(),
                ..spec(CodecKind::Gecko, 0, 2048)
            },
            0,
        )
        .unwrap();
        assert!(m.delta_pct() < 1e-9, "block accounting exact: {}", m.delta_pct());
        assert!(m.restore_bit_exact);
        assert_eq!(
            m.to_json().get("layout").and_then(Json::as_str),
            Some("block:16")
        );
        // one 8-bit field per 16 values beats the default per-value
        // exponent stream on the same streams
        let d = run_stash_measurement(&spec(CodecKind::Gecko, 0, 2048), 0).unwrap();
        assert!(m.frac_of_fp32() < 0.5);
        assert!(d.measured_total_bits > 0.0 && m.measured_total_bits > 0.0);
    }

    #[test]
    fn bias_layout_measurement_is_exact() {
        let m = run_stash_measurement(
            &StashSpec {
                layout: "bias:4:127".into(),
                ..spec(CodecKind::Gecko, 0, 2048)
            },
            0,
        )
        .unwrap();
        assert!(m.delta_pct() < 1e-9, "bias accounting exact: {}", m.delta_pct());
        assert!(m.restore_bit_exact);
    }

    #[test]
    fn measurement_json_is_deterministic() {
        let a = run_stash_measurement(&spec(CodecKind::Gecko, 0, 4096), 0).unwrap();
        let b = run_stash_measurement(&spec(CodecKind::Gecko, 0, 4096), 2).unwrap();
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
    }
}
