//! Dependency-aware work-stealing executor over a [`JobGraph`].
//!
//! Scheduling model: every job starts with a count of unfinished
//! dependencies; jobs at zero are seeded round-robin across per-worker
//! deques.  A worker pops from the *front* of its own deque (LIFO — a
//! just-unblocked dependent likely has its inputs warm) and steals from
//! the *back* of a victim's deque when its own runs dry, so long chains
//! stay local while idle workers drain whoever is busiest.  Completing a
//! job decrements its dependents' counts; a dependent reaching zero is
//! pushed onto the completing worker's own deque.
//!
//! Execution of one job: content-hash lookup in the
//! [`ResultCache`](super::cache::ResultCache) first — a hit skips
//! execution entirely (`cached` in the report); a miss is handed to the
//! run's [`ExecBackend`], which runs the spec into a staging directory and
//! commits by rename — either on a thread of this process
//! ([`InProcessBackend`], job body fenced by `catch_unwind` so a panic
//! fails one job instead of aborting the run) or in a `repro worker`
//! subprocess ([`ProcessBackend`](super::remote::ProcessBackend), where
//! even a killed worker only fails its job).  A failed job poisons its
//! transitive dependents (reported `skipped`), but independent branches
//! keep running — one broken figure doesn't waste the rest of the grid.
//!
//! Jobs whose bodies spin a stash worker pool take a per-job thread budget
//! of `cores / scheduler workers` (unless their spec pins an explicit
//! hint), so a wide grid never oversubscribes the machine with N
//! full-sized pools.  Thread counts are an execution knob, not identity:
//! artifact bytes are the same at any count.
//!
//! [`run_serial`] executes the same graph on the caller's thread in
//! insertion order (a topological order by construction — edges only
//! point backwards).  The acceptance check diffs its artifact bytes
//! against a parallel run's: both orders must produce bit-identical
//! artifacts, which holds because job execution is deterministic and jobs
//! only communicate through declared dependency artifacts.

use super::cache::{JobRecord, ResultCache};
use super::hash::job_hash;
use super::jobs::execute_spec;
use super::spec::{JobSpec, CACHE_VERSION};
use crate::obs;
use anyhow::{anyhow, Result};
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::Instant;

/// One node: a spec plus the indices of the jobs it needs finished first.
pub struct JobNode {
    pub spec: JobSpec,
    pub deps: Vec<usize>,
}

/// A DAG of jobs.  Edges may only point to already-added jobs, so the
/// insertion order is always a valid topological order and cycles are
/// impossible by construction.
#[derive(Default)]
pub struct JobGraph {
    pub nodes: Vec<JobNode>,
}

impl JobGraph {
    pub fn new() -> JobGraph {
        JobGraph::default()
    }

    /// Add a job depending on previously added jobs; returns its id.
    pub fn push(&mut self, spec: JobSpec, deps: Vec<usize>) -> usize {
        for &d in &deps {
            assert!(d < self.nodes.len(), "dependency {d} not yet added");
        }
        self.nodes.push(JobNode { spec, deps });
        self.nodes.len() - 1
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Content hash of every job, dependency hashes chained in (so an
    /// upstream config change re-hashes exactly its downstream cone).
    pub fn hashes(&self) -> Vec<String> {
        let mut out: Vec<String> = Vec::with_capacity(self.nodes.len());
        for node in &self.nodes {
            let dep_hashes: Vec<String> =
                node.deps.iter().map(|&d| out[d].clone()).collect();
            out.push(job_hash(
                node.spec.kind(),
                &node.spec.params_json(),
                &dep_hashes,
                CACHE_VERSION,
            ));
        }
        out
    }
}

/// How one job ended.
#[derive(Debug, Clone, PartialEq)]
pub enum JobStatus {
    /// Executed in this run.
    Executed,
    /// Served from the content-addressed cache without executing.
    Cached,
    /// Execution failed.
    Failed(String),
    /// Not attempted: a transitive dependency failed.
    Skipped,
}

/// Per-job outcome row (the manifest's unit).
#[derive(Debug, Clone)]
pub struct JobReport {
    pub id: usize,
    pub kind: String,
    pub label: String,
    pub hash: String,
    pub status: JobStatus,
    /// Wall-clock of this run's handling (≈0 for cache hits).
    pub wall_ms: f64,
    pub artifacts: Vec<super::cache::ArtifactInfo>,
    /// For failed jobs under the process backend: the tail of the worker
    /// subprocess's stderr, so a poisoned cone is diagnosable from the
    /// manifest without re-running serially.
    pub stderr_tail: Option<String>,
}

impl JobReport {
    pub fn ok(&self) -> bool {
        matches!(self.status, JobStatus::Executed | JobStatus::Cached)
    }
}

/// Everything a backend needs to run one cache-miss job to a committed
/// entry: the spec, its content hash (the cache address, chained through
/// the whole dependency cone by the orchestrator), the resolved thread
/// budget, and the completed dependency records in graph-edge order.
pub struct ExecRequest<'a> {
    pub spec: &'a JobSpec,
    pub hash: &'a str,
    pub label: &'a str,
    /// Worker-pool threads this job may spin (0 = whole machine).
    pub threads: usize,
    pub deps: &'a [JobRecord],
}

/// Where job bodies run.  The scheduler (DAG order, cache lookups, cone
/// poisoning) is backend-agnostic; a backend only turns one cache miss
/// into a committed `<kind>-<hash>` entry — in this process, in a worker
/// subprocess, or on another machine entirely: the content-addressed cache
/// is the only artifact channel either way, so artifacts are byte-identical
/// across backends.
pub trait ExecBackend: Sync {
    /// Execute one job (`worker` is the scheduler thread index, letting
    /// process backends pin one subprocess per scheduler worker).  `Ok`
    /// returns the committed record; `Err` fails the job and poisons its
    /// dependent cone — it must never leave a committed partial entry.
    fn execute(
        &self,
        worker: usize,
        cache: &ResultCache,
        req: &ExecRequest,
    ) -> Result<JobRecord>;

    /// Diagnostic context for the job that just failed on `worker` — the
    /// process backend returns the tail of the worker subprocess's stderr.
    fn failure_context(&self, _worker: usize) -> Option<String> {
        None
    }
}

/// The default backend: stage → execute on this thread → commit.  The job
/// body runs under `catch_unwind`, so a panicking job is a normal failure
/// (its cone is poisoned, siblings keep running) instead of aborting the
/// whole grid.
#[derive(Default)]
pub struct InProcessBackend {
    nonce: AtomicUsize,
}

impl InProcessBackend {
    pub fn new() -> InProcessBackend {
        InProcessBackend::default()
    }
}

/// Render a panic payload (`&str` / `String` are the common cases).
pub(crate) fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// The one stage → `catch_unwind(execute)` → commit/discard sequence both
/// execution sites share (in-process backend and the worker serve loop),
/// so the byte-identical-across-backends contract has a single
/// implementation: a panic or error discards the staging directory and
/// never commits a partial entry.
pub(crate) fn stage_execute_commit(
    cache: &ResultCache,
    spec: &JobSpec,
    label: &str,
    hash: &str,
    nonce: u64,
    deps: &[JobRecord],
    threads: usize,
) -> Result<JobRecord> {
    let kind = spec.kind();
    let staging = {
        let _sp = obs::span("lab", "stage");
        cache.stage(kind, hash, nonce)?
    };
    let art_dir = staging.join("artifacts");
    let outcome = {
        let _sp = obs::span("lab", "execute");
        catch_unwind(AssertUnwindSafe(|| {
            execute_spec(spec, &art_dir, deps, threads)
        }))
    };
    match outcome {
        Ok(Ok(())) => {
            let _sp = obs::span("lab", "commit");
            cache.commit(kind, label, hash, &spec.params_json(), &staging)
        }
        Ok(Err(e)) => {
            cache.discard(&staging);
            Err(e)
        }
        Err(payload) => {
            cache.discard(&staging);
            Err(anyhow!("job panicked: {}", panic_message(payload)))
        }
    }
}

impl ExecBackend for InProcessBackend {
    fn execute(
        &self,
        _worker: usize,
        cache: &ResultCache,
        req: &ExecRequest,
    ) -> Result<JobRecord> {
        let nonce = self.nonce.fetch_add(1, Ordering::SeqCst) as u64;
        stage_execute_commit(
            cache, req.spec, req.label, req.hash, nonce, req.deps, req.threads,
        )
    }
}

/// Per-job stash-pool thread budget for a run with `workers` concurrent
/// scheduler threads on a `cores`-wide machine: concurrent jobs split the
/// cores evenly (never below 1), so total pool threads stay ≤ cores.  A
/// single-worker (serial) run keeps 0 = whole machine.
fn budget_for(cores: usize, workers: usize) -> usize {
    if workers <= 1 {
        0
    } else {
        (cores / workers).max(1)
    }
}

fn detected_cores() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

/// Resolve a run's scheduler worker count: 0 = available parallelism,
/// always clamped to `[1, graph size]`.  Callers sizing an external
/// resource one-to-one with scheduler workers (the process backend's
/// subprocess slots) use this to stay in lockstep with
/// [`run_with_backend`].
pub fn resolve_workers(graph: &JobGraph, threads: usize) -> usize {
    let threads = if threads == 0 { detected_cores() } else { threads };
    threads.clamp(1, graph.len().max(1))
}

struct Scheduler<'g> {
    graph: &'g JobGraph,
    hashes: Vec<String>,
    cache: &'g ResultCache,
    backend: &'g dyn ExecBackend,
    /// Per-job stash-pool thread budget (0 = whole machine).
    thread_budget: usize,
    deques: Vec<Mutex<VecDeque<usize>>>,
    remaining: Vec<AtomicUsize>,
    dependents: Vec<Vec<usize>>,
    /// Completed-job records (cache entries) for dependency artifact access.
    records: Vec<Mutex<Option<JobRecord>>>,
    /// Jobs whose subtree is poisoned by an upstream failure.
    poisoned: Vec<AtomicUsize>,
    reports: Mutex<Vec<Option<JobReport>>>,
    done: AtomicUsize,
    idle: (Mutex<usize>, Condvar),
}

impl<'g> Scheduler<'g> {
    fn new(
        graph: &'g JobGraph,
        cache: &'g ResultCache,
        workers: usize,
        backend: &'g dyn ExecBackend,
        thread_budget: usize,
    ) -> Scheduler<'g> {
        let n = graph.len();
        let mut dependents = vec![Vec::new(); n];
        for (id, node) in graph.nodes.iter().enumerate() {
            for &d in &node.deps {
                dependents[d].push(id);
            }
        }
        Scheduler {
            hashes: graph.hashes(),
            graph,
            cache,
            backend,
            thread_budget,
            deques: (0..workers).map(|_| Mutex::new(VecDeque::new())).collect(),
            remaining: graph
                .nodes
                .iter()
                .map(|node| AtomicUsize::new(node.deps.len()))
                .collect(),
            dependents,
            records: (0..n).map(|_| Mutex::new(None)).collect(),
            poisoned: (0..n).map(|_| AtomicUsize::new(0)).collect(),
            reports: Mutex::new((0..n).map(|_| None).collect()),
            done: AtomicUsize::new(0),
            idle: (Mutex::new(0), Condvar::new()),
        }
    }

    fn seed(&self) {
        let mut w = 0;
        for (id, node) in self.graph.nodes.iter().enumerate() {
            if node.deps.is_empty() {
                self.deques[w].lock().unwrap().push_back(id);
                w = (w + 1) % self.deques.len();
            }
        }
    }

    /// Pop local front, then steal from victims' backs.
    fn next_job(&self, worker: usize) -> Option<usize> {
        if let Some(id) = self.deques[worker].lock().unwrap().pop_front() {
            return Some(id);
        }
        let n = self.deques.len();
        for off in 1..n {
            let victim = (worker + off) % n;
            if let Some(id) = self.deques[victim].lock().unwrap().pop_back() {
                obs::metrics::STEALS.inc();
                return Some(id);
            }
        }
        None
    }

    /// Execute (or resolve from cache) one job, record its report, and
    /// release its dependents.
    fn run_job(&self, worker: usize, id: usize) {
        let node = &self.graph.nodes[id];
        let hash = &self.hashes[id];
        let kind = node.spec.kind();
        let label = node.spec.label();
        obs::metrics::JOBS_STARTED.inc();
        let _job_span = obs::span_with("lab", || (format!("job:{label}"), Some(hash.clone())));
        let t0 = Instant::now();

        let poisoned = self.poisoned[id].load(Ordering::SeqCst) != 0;
        let status_and_record: (JobStatus, Option<JobRecord>) = if poisoned {
            (JobStatus::Skipped, None)
        } else {
            let lookup_t0 = Instant::now();
            let hit = self.cache.lookup(kind, hash);
            obs::metrics::CACHE_LOOKUP_US.record_duration(lookup_t0.elapsed());
            if let Some(rec) = hit {
                obs::metrics::CACHE_HITS.inc();
                (JobStatus::Cached, Some(rec))
            } else {
                obs::metrics::CACHE_MISSES.inc();
                // gather dependency artifact directories, in edge order
                let deps: Vec<JobRecord> = node
                    .deps
                    .iter()
                    .map(|&d| {
                        self.records[d]
                            .lock()
                            .unwrap()
                            .clone()
                            .expect("dependency completed before dependent")
                    })
                    .collect();
                let req = ExecRequest {
                    spec: &node.spec,
                    hash,
                    label: &label,
                    threads: node.spec.resolve_threads(self.thread_budget),
                    deps: &deps,
                };
                match self.backend.execute(worker, self.cache, &req) {
                    Ok(rec) => (JobStatus::Executed, Some(rec)),
                    Err(e) => (JobStatus::Failed(format!("{e:#}")), None),
                }
            }
        };

        let (status, record) = status_and_record;
        let failed = !matches!(status, JobStatus::Executed | JobStatus::Cached);
        match &status {
            JobStatus::Executed => obs::metrics::JOBS_EXECUTED.inc(),
            JobStatus::Cached => obs::metrics::JOBS_CACHED.inc(),
            JobStatus::Failed(_) => obs::metrics::JOBS_FAILED.inc(),
            JobStatus::Skipped => {}
        }
        let stderr_tail = if matches!(status, JobStatus::Failed(_)) {
            self.backend.failure_context(worker)
        } else {
            None
        };
        let artifacts = record
            .as_ref()
            .map(|r| r.artifacts.clone())
            .unwrap_or_default();
        *self.records[id].lock().unwrap() = record;
        self.reports.lock().unwrap()[id] = Some(JobReport {
            id,
            kind: kind.to_string(),
            label,
            hash: hash.clone(),
            status,
            wall_ms: t0.elapsed().as_secs_f64() * 1e3,
            artifacts,
            stderr_tail,
        });

        // release dependents (poisoning them first on failure, so the
        // release below can never race a clean execution)
        for &dep in &self.dependents[id] {
            if failed {
                self.poisoned[dep].fetch_add(1, Ordering::SeqCst);
            }
            if self.remaining[dep].fetch_sub(1, Ordering::SeqCst) == 1 {
                self.deques[worker].lock().unwrap().push_front(dep);
            }
        }
        self.done.fetch_add(1, Ordering::SeqCst);
        obs::metrics::JOBS_DONE.inc();
        // wake idle workers: new jobs may be stealable, or the run is over
        let (lock, cv) = &self.idle;
        let mut gen = lock.lock().unwrap();
        *gen += 1;
        drop(gen);
        cv.notify_all();
    }

    fn worker_loop(&self, worker: usize) {
        loop {
            if let Some(id) = self.next_job(worker) {
                self.run_job(worker, id);
                continue;
            }
            if self.done.load(Ordering::SeqCst) >= self.graph.len() {
                return;
            }
            // nothing runnable here: sleep until some job completes
            let (lock, cv) = &self.idle;
            let gen = lock.lock().unwrap();
            let seen = *gen;
            if self.done.load(Ordering::SeqCst) >= self.graph.len() {
                return;
            }
            // re-check the deques under no deque lock is fine: a push that
            // happened before we read `gen` bumps it, so the wait below
            // cannot miss it
            let wait_t0 = Instant::now();
            let _unused = cv
                .wait_timeout_while(gen, std::time::Duration::from_millis(50), |g| *g == seen)
                .unwrap();
            obs::metrics::EXEC_IDLE_US.add(wait_t0.elapsed().as_micros() as u64);
        }
    }
}

/// Run the graph on `threads` workers (0 = available parallelism, capped
/// at the job count) with job bodies executing in this process.  Returns
/// one report per job, in graph order.
pub fn run_parallel(graph: &JobGraph, cache: &ResultCache, threads: usize) -> Vec<JobReport> {
    run_with_backend(graph, cache, threads, &InProcessBackend::new())
}

/// Run the graph on `threads` scheduler workers (0 = available
/// parallelism, capped at the job count), dispatching cache misses to
/// `backend`.  Per-job stash-pool budgets split the machine's cores across
/// the workers so concurrent jobs never oversubscribe.
pub fn run_with_backend(
    graph: &JobGraph,
    cache: &ResultCache,
    threads: usize,
    backend: &dyn ExecBackend,
) -> Vec<JobReport> {
    if graph.is_empty() {
        return Vec::new();
    }
    let threads = resolve_workers(graph, threads);
    let sched = Scheduler::new(
        graph,
        cache,
        threads,
        backend,
        budget_for(detected_cores(), threads),
    );
    sched.seed();
    std::thread::scope(|scope| {
        for w in 1..threads {
            let s = &sched;
            scope.spawn(move || s.worker_loop(w));
        }
        sched.worker_loop(0);
    });
    sched
        .reports
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|r| r.expect("every job reported"))
        .collect()
}

/// Run the graph on the caller's thread in insertion order — the
/// deterministic reference the parallel mode's artifacts are
/// byte-compared against.
pub fn run_serial(graph: &JobGraph, cache: &ResultCache) -> Vec<JobReport> {
    if graph.is_empty() {
        return Vec::new();
    }
    let backend = InProcessBackend::new();
    let sched = Scheduler::new(graph, cache, 1, &backend, 0);
    for id in 0..graph.len() {
        // insertion order is topological: all deps already ran
        sched.run_job(0, id);
    }
    sched
        .reports
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|r| r.expect("every job reported"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::Container;
    use crate::lab::spec::StashSpec;
    use crate::stash::CodecKind;
    use std::path::PathBuf;

    fn tdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("sfp_lab_exec_{name}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn tiny_stash(model: &str, codec: CodecKind, budget: usize) -> JobSpec {
        JobSpec::StashRun(StashSpec {
            model: model.into(),
            policy: "qm".into(),
            codec,
            container: Container::Bf16,
            batch: 64,
            budget_bytes: budget,
            sample: 2048,
            seed: 0x5EED,
            threads: 0,
            layout: String::new(),
        })
    }

    #[test]
    fn graph_hash_chaining_reruns_only_the_cone() {
        let mut g1 = JobGraph::new();
        let a = g1.push(tiny_stash("resnet18", CodecKind::Gecko, 0), vec![]);
        let b = g1.push(tiny_stash("resnet18", CodecKind::Raw, 0), vec![]);
        g1.push(JobSpec::StashSummary, vec![a, b]);
        let h1 = g1.hashes();

        // change one leaf: its hash and the summary's change, the sibling's
        // stays identical
        let mut g2 = JobGraph::new();
        let a2 = g2.push(tiny_stash("resnet18", CodecKind::Gecko, 4096), vec![]);
        let b2 = g2.push(tiny_stash("resnet18", CodecKind::Raw, 0), vec![]);
        g2.push(JobSpec::StashSummary, vec![a2, b2]);
        let h2 = g2.hashes();

        assert_ne!(h1[0], h2[0], "edited leaf re-hashes");
        assert_eq!(h1[1], h2[1], "untouched sibling keeps its hash");
        assert_ne!(h1[2], h2[2], "summary is in the edited cone");
    }

    #[test]
    fn parallel_executes_all_then_warm_run_executes_none() {
        let cache = ResultCache::open(&tdir("warm")).unwrap();
        let mut g = JobGraph::new();
        let a = g.push(tiny_stash("resnet18", CodecKind::Gecko, 0), vec![]);
        let b = g.push(tiny_stash("resnet18", CodecKind::Js, 0), vec![]);
        g.push(JobSpec::StashSummary, vec![a, b]);

        let cold = run_parallel(&g, &cache, 2);
        assert_eq!(cold.len(), 3);
        assert!(cold.iter().all(|r| r.status == JobStatus::Executed), "{cold:?}");
        assert!(cold.iter().all(|r| !r.artifacts.is_empty()));

        let warm = run_parallel(&g, &cache, 2);
        assert!(
            warm.iter().all(|r| r.status == JobStatus::Cached),
            "warm re-run must execute zero jobs: {warm:?}"
        );
        // cache hits resolve to the same artifact fingerprints
        for (c, w) in cold.iter().zip(&warm) {
            assert_eq!(c.artifacts, w.artifacts);
        }
    }

    #[test]
    fn serial_and_parallel_artifacts_are_byte_identical() {
        let cache_s = ResultCache::open(&tdir("ser")).unwrap();
        let cache_p = ResultCache::open(&tdir("par")).unwrap();
        let mut g = JobGraph::new();
        let mut leaves = Vec::new();
        for codec in [CodecKind::Gecko, CodecKind::Raw, CodecKind::Js] {
            leaves.push(g.push(tiny_stash("resnet18", codec, 0), vec![]));
        }
        g.push(JobSpec::StashSummary, leaves);

        let rs = run_serial(&g, &cache_s);
        let rp = run_parallel(&g, &cache_p, 3);
        for (s, p) in rs.iter().zip(&rp) {
            assert!(s.ok() && p.ok());
            assert_eq!(s.hash, p.hash);
            assert_eq!(
                s.artifacts, p.artifacts,
                "artifact bytes must not depend on execution order ({})",
                s.label
            );
        }
    }

    #[test]
    fn failure_poisons_only_the_dependent_cone() {
        let cache = ResultCache::open(&tdir("poison")).unwrap();
        let mut g = JobGraph::new();
        // unknown model → the job itself fails
        let bad = g.push(tiny_stash("no_such_model", CodecKind::Gecko, 0), vec![]);
        let good = g.push(tiny_stash("resnet18", CodecKind::Raw, 0), vec![]);
        let summary = g.push(JobSpec::StashSummary, vec![bad, good]);
        let lone = g.push(tiny_stash("resnet18", CodecKind::Gecko, 0), vec![]);

        let reports = run_parallel(&g, &cache, 2);
        assert!(matches!(reports[bad].status, JobStatus::Failed(_)));
        assert_eq!(reports[good].status, JobStatus::Executed);
        assert_eq!(reports[summary].status, JobStatus::Skipped);
        assert_eq!(reports[lone].status, JobStatus::Executed);
    }

    #[test]
    fn panicking_job_fails_its_cone_while_siblings_complete() {
        // regression: job bodies used to run without catch_unwind, so one
        // panicking job aborted the entire grid run
        let cache = ResultCache::open(&tdir("panic")).unwrap();
        let mut g = JobGraph::new();
        let boom = g.push(
            JobSpec::Probe {
                mode: "panic".into(),
                payload: 1,
            },
            vec![],
        );
        let downstream = g.push(
            JobSpec::Probe {
                mode: "ok".into(),
                payload: 2,
            },
            vec![boom],
        );
        let sibling = g.push(
            JobSpec::Probe {
                mode: "ok".into(),
                payload: 3,
            },
            vec![],
        );

        let reports = run_parallel(&g, &cache, 2);
        match &reports[boom].status {
            JobStatus::Failed(e) => {
                assert!(e.contains("panicked"), "failure names the panic: {e}")
            }
            other => panic!("panicking job must fail, got {other:?}"),
        }
        assert_eq!(reports[downstream].status, JobStatus::Skipped);
        assert_eq!(reports[sibling].status, JobStatus::Executed);
        // no committed entry for the panicked job: a re-run attempts it again
        let rerun = run_parallel(&g, &cache, 2);
        assert!(matches!(rerun[boom].status, JobStatus::Failed(_)));
        assert_eq!(rerun[sibling].status, JobStatus::Cached);
    }

    #[test]
    fn thread_budget_never_oversubscribes() {
        // serial keeps the whole machine; parallel splits cores across
        // workers with a floor of one
        assert_eq!(budget_for(8, 1), 0);
        assert_eq!(budget_for(8, 2), 4);
        assert_eq!(budget_for(8, 3), 2);
        assert_eq!(budget_for(8, 16), 1);
        assert_eq!(budget_for(1, 4), 1);
        for cores in 1..=64usize {
            for workers in 2..=32usize {
                assert!(
                    budget_for(cores, workers) * workers <= cores.max(workers),
                    "workers x budget stays within cores ({cores} cores, {workers} workers)"
                );
            }
        }
    }
}
