//! Parallel experiment orchestration with content-addressed result
//! caching — the engine behind `repro all`, `repro policy`, `repro
//! stash`, and `repro train`.
//!
//! The paper's headline numbers come from a wide method × model × codec ×
//! budget cross-product; this subsystem turns every such sweep into a DAG
//! of [`spec::JobSpec`]s executed by a dependency-aware work-stealing
//! thread pool, with every completed job stored in a content-addressed
//! on-disk cache:
//!
//! ```text
//!  JobSpec ──canonical JSON──▶ content hash ──┬─▶ cache hit?  reuse artifacts
//!      │                        (dep hashes    │
//!      │ deps                    chained in)   └─▶ miss: execute into staging,
//!      ▼                                           commit by rename
//!  [JobGraph] ──▶ [work-stealing executor] ──▶ lab_manifest.json
//!                  per-worker deques, steal-       every job: hash, status,
//!                  from-back, failure poisons      wall-clock, artifact
//!                  only the dependent cone         fingerprints
//! ```
//!
//! * [`spec`] — job configs with canonical JSON renderings; the content
//!   hash derives from kind + params + dependency hashes, so a one-line
//!   config change re-runs exactly its downstream cone and nothing else.
//! * [`cache`] — `<root>/<kind>-<hash>/` entries committed atomically by
//!   rename; lookups re-verify artifact fingerprints, so a truncated
//!   entry re-executes instead of being trusted.
//! * [`exec`] — the scheduler: [`exec::run_parallel`] (work stealing) and
//!   [`exec::run_serial`] (insertion order) must produce byte-identical
//!   artifacts — jobs are deterministic and only communicate through
//!   declared dependency artifacts (CI diffs the two modes).  Cache misses
//!   dispatch through an [`exec::ExecBackend`] seam: in-process closures
//!   (job bodies fenced by `catch_unwind`) or subprocess workers.
//! * [`remote`] — the process backend: `repro worker` subprocesses speak a
//!   one-line JSON protocol and commit into the same content-addressed
//!   cache, so fingerprints stay byte-identical to `--serial` and a killed
//!   worker poisons only its job's dependent cone.
//! * [`jobs`] — execution bodies: policy sweeps, stash measurements,
//!   table/figure emitters, e2e train runs, and the consolidation jobs
//!   that read upstream artifacts through the cache.
//! * [`measure`] — the quiet `repro stash` experiment body (no printing,
//!   no timing in artifacts).
//! * [`grid`] — [`grid::paper_grid`] / [`grid::smoke_grid`] builders and
//!   the consolidated `lab_manifest.json` writer.
//!
//! A warm re-run of an unchanged grid reports 100% cache hits and
//! executes zero jobs (the CI gate runs `repro all --smoke` twice and
//! asserts exactly that).

pub mod cache;
pub mod exec;
pub mod grid;
pub mod hash;
pub mod jobs;
pub mod measure;
pub mod remote;
pub mod spec;

pub use cache::{ArtifactInfo, JobRecord, ResultCache};
pub use exec::{
    resolve_workers, run_parallel, run_serial, run_with_backend, ExecBackend, ExecRequest,
    InProcessBackend, JobGraph, JobReport, JobStatus,
};
pub use grid::{paper_grid, smoke_grid, write_manifest, Grid, GridOptions, RunTotals};
pub use measure::{run_stash_measurement, StashMeasurement};
pub use remote::{worker_main, ProcessBackend};
pub use spec::{JobSpec, ServeSpec, StashSpec, TrainSpec, CACHE_VERSION};
