//! Shape-accurate layer traces of the paper's evaluation networks.
//!
//! The paper trains ResNet18 and MobileNetV3-Small on ImageNet and collects
//! per-layer traffic/compute counts via PyTorch hooks (§VI-C).  We rebuild
//! those counts from the published architectures: every conv/fc layer with
//! its weight tensor size, stashed-activation size, MACs per sample, and
//! how its activation is consumed ([`ActKind`] — decides Gist/JS/sign
//! encodings).
//!
//! A [`ValueModel`] per tensor generates representative value streams for
//! the codecs: biased-exponent Gaussians (Fig. 9 shows trained exponents
//! hug the bias) plus a zero fraction for post-ReLU activations.  The
//! defaults are calibrated against the e2e training run of this repo
//! (EXPERIMENTS.md §Calibration) and cross-checked against the paper's
//! aggregate ratios (tests below).

use crate::baselines::ActKind;


/// One trainable layer of a traced network.
#[derive(Debug, Clone)]
pub struct LayerTrace {
    pub name: String,
    /// Weight elements (0 for pooling-only stages folded into neighbours).
    pub weight_elems: usize,
    /// Stashed activation elements per sample (the layer's *output*).
    pub act_elems: usize,
    /// MACs per sample for the forward pass.
    pub macs: usize,
    /// How the output activation is consumed.
    pub act_kind: ActKind,
    /// Output activation is non-negative (ReLU/ReLU6 ⇒ sign elision, §IV-D).
    pub nonneg_act: bool,
    /// Fraction of the MAC array this layer can keep busy (depthwise convs
    /// have little input-channel parallelism — they hit a fraction of peak).
    pub compute_util: f64,
    /// Value model for the output activation.
    pub act_model: ValueModel,
    /// Value model for the weights.
    pub weight_model: ValueModel,
}

/// Parametric model of a tensor's value stream: biased-exponent Gaussian +
/// point mass at exact zero, both with *spatial correlation*:
///
/// * zeros follow a two-state Markov chain (ReLU zeros cluster by channel
///   and spatial region, they are not i.i.d. — this is what makes Gecko's
///   delta rows hit width 0 on real activations, Fig. 10);
/// * non-zero exponents follow an AR(1) process around `exp_mean`
///   (neighbouring magnitudes are similar, §IV-C "values that are close-by
///   tend to have similar magnitude").
#[derive(Debug, Clone, Copy)]
pub struct ValueModel {
    pub zero_frac: f64,
    pub exp_mean: f64,
    pub exp_std: f64,
    /// P(next is zero | current is zero) — zero-run persistence.
    pub zero_persist: f64,
    /// AR(1) coefficient for the non-zero exponent process.
    pub exp_rho: f64,
}

impl ValueModel {
    pub const fn new(zero_frac: f64, exp_mean: f64, exp_std: f64) -> Self {
        Self {
            zero_frac,
            exp_mean,
            exp_std,
            // mean zero-run ≈ 200 values: ReLU zeros come as dead
            // channels/regions spanning many 64-value codec groups
            zero_persist: 0.998,
            exp_rho: 0.95,
        }
    }

    /// Post-ReLU activation stream (calibrated: ≈36% zeros network-wide,
    /// matching the paper's "30% JS reduction on BF16" — see baselines;
    /// exponent spread tuned so the Gecko activation ratio lands at the
    /// paper's ≈0.5, Fig. 10).
    pub const fn relu_act() -> Self {
        Self::new(0.36, 124.0, 2.0)
    }

    /// hswish activation stream (MobileNet V3): almost no exact zeros.
    pub const fn hswish_act() -> Self {
        Self::new(0.02, 124.0, 2.4)
    }

    /// Trained conv/fc weights: no zeros, tight sub-unit magnitudes with
    /// strong spatial correlation (per-filter norms make neighbouring
    /// weight exponents plateau — §IV-C's "spatial correlation" remark).
    pub const fn weights() -> Self {
        Self {
            zero_frac: 0.0,
            exp_mean: 121.0,
            exp_std: 1.2,
            zero_persist: 0.998,
            exp_rho: 0.99,
        }
    }

    /// P(zero | previous non-zero), chosen so the chain's stationary zero
    /// probability equals `zero_frac`.
    fn p_enter_zero(&self) -> f64 {
        if self.zero_frac <= 0.0 {
            return 0.0;
        }
        (self.zero_frac * (1.0 - self.zero_persist) / (1.0 - self.zero_frac)).min(1.0)
    }

    /// Draw `count` biased exponents (deterministic per `seed`).
    pub fn sample_exponents(&self, count: usize, seed: u64) -> Vec<u8> {
        let mut rng = SplitMix64::new(seed);
        let mut stream = ExpStream::new(self, &mut rng);
        (0..count).map(|_| stream.next(&mut rng)).collect()
    }

    /// Draw `count` f32 values consistent with the exponent model (uniform
    /// mantissas, non-negative when `nonneg`).
    pub fn sample_values(&self, count: usize, seed: u64, nonneg: bool) -> Vec<f32> {
        let mut rng = SplitMix64::new(seed);
        let mut stream = ExpStream::new(self, &mut rng);
        (0..count)
            .map(|_| {
                let e = stream.next(&mut rng) as u32;
                if e == 0 {
                    return 0.0f32;
                }
                let mant = (rng.next_u64() & 0x7F_FFFF) as u32;
                let sign = if nonneg { 0 } else { (rng.next_u64() & 1) as u32 };
                f32::from_bits((sign << 31) | (e << 23) | mant)
            })
            .collect()
    }
}

/// Synthesize f32 values carrying exactly `exps` as their biased exponents
/// (uniform mantissas, optional random signs; exponent 0 becomes exact
/// zero).  Lets two consumers share one exponent stream — the analytic
/// footprint model sizes Gecko on `sample_exponents` output, and the stash
/// sweep encodes *values* over the identical exponents so measured and
/// analytic bits agree exactly.
pub fn values_with_exponents(exps: &[u8], seed: u64, nonneg: bool) -> Vec<f32> {
    let mut rng = SplitMix64::new(seed);
    exps.iter()
        .map(|&e| {
            if e == 0 {
                return 0.0f32;
            }
            let mant = (rng.next_u64() & 0x7F_FFFF) as u32;
            let sign = if nonneg { 0 } else { (rng.next_u64() & 1) as u32 };
            f32::from_bits((sign << 31) | ((e as u32) << 23) | mant)
        })
        .collect()
}

/// Stateful generator implementing the Markov-zero + AR(1)-exponent model.
struct ExpStream {
    model: ValueModel,
    in_zero: bool,
    /// AR(1) deviation from `exp_mean`, in exponent units.
    dev: f64,
    /// innovation std so the stationary std equals `exp_std`.
    innov_std: f64,
}

impl ExpStream {
    fn new(model: &ValueModel, rng: &mut SplitMix64) -> Self {
        Self {
            model: *model,
            in_zero: rng.next_f64() < model.zero_frac,
            dev: model.exp_std * rng.next_gaussian(),
            innov_std: model.exp_std * (1.0 - model.exp_rho * model.exp_rho).sqrt(),
        }
    }

    fn next(&mut self, rng: &mut SplitMix64) -> u8 {
        let m = &self.model;
        let u = rng.next_f64();
        self.in_zero = if self.in_zero {
            u < m.zero_persist
        } else {
            u < m.p_enter_zero()
        };
        // the AR process advances regardless so magnitudes stay correlated
        // across zero runs (as feature-map magnitudes do)
        self.dev = m.exp_rho * self.dev + self.innov_std * rng.next_gaussian();
        if self.in_zero {
            0
        } else {
            (m.exp_mean + self.dev).round().clamp(1.0, 254.0) as u8
        }
    }
}

/// Deterministic SplitMix64 — the repo-wide seedable RNG (no rand dep on
/// the request path).
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
    cached_gaussian: Option<f64>,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self {
            state: seed,
            cached_gaussian: None,
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn next_gaussian(&mut self) -> f64 {
        if let Some(g) = self.cached_gaussian.take() {
            return g;
        }
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
        self.cached_gaussian = Some(r * s);
        r * c
    }
}

/// A traced network: ordered layers + a display name.
#[derive(Debug, Clone)]
pub struct NetworkTrace {
    pub name: String,
    pub layers: Vec<LayerTrace>,
}

impl NetworkTrace {
    pub fn total_weight_elems(&self) -> usize {
        self.layers.iter().map(|l| l.weight_elems).sum()
    }

    pub fn total_act_elems_per_sample(&self) -> usize {
        self.layers.iter().map(|l| l.act_elems).sum()
    }

    pub fn total_macs_per_sample(&self) -> usize {
        self.layers.iter().map(|l| l.macs).sum()
    }
}

/// Achievable MAC-array utilization: the 8K×4 array parallelizes over the
/// weight-reuse dimensions (k²·cin·cout); layers with fewer weight-level
/// parallel MACs than lanes (depthwise, narrow 1×1) run under-utilized —
/// this is what caps MobileNetV3's gains in Table II.
fn util_of(weight_elems: usize) -> f64 {
    (weight_elems as f64 / 8192.0).clamp(0.05, 1.0)
}

fn conv(
    name: &str,
    k: usize,
    cin: usize,
    cout: usize,
    out_hw: usize,
    act_kind: ActKind,
    relu: bool,
) -> LayerTrace {
    LayerTrace {
        name: name.to_string(),
        weight_elems: k * k * cin * cout,
        act_elems: out_hw * out_hw * cout,
        macs: k * k * cin * cout * out_hw * out_hw,
        act_kind,
        nonneg_act: relu,
        compute_util: util_of(k * k * cin * cout),
        act_model: if relu {
            ValueModel::relu_act()
        } else {
            ValueModel::hswish_act()
        },
        weight_model: ValueModel::weights(),
    }
}

fn dwconv(name: &str, k: usize, c: usize, out_hw: usize, relu: bool) -> LayerTrace {
    LayerTrace {
        name: name.to_string(),
        weight_elems: k * k * c,
        act_elems: out_hw * out_hw * c,
        macs: k * k * c * out_hw * out_hw,
        act_kind: ActKind::ReluConv,
        nonneg_act: relu,
        compute_util: util_of(k * k * c),
        act_model: if relu {
            ValueModel::relu_act()
        } else {
            ValueModel::hswish_act()
        },
        weight_model: ValueModel::weights(),
    }
}

/// ResNet18 at 224×224 (He et al.; basic blocks, no bottlenecks).
pub fn resnet18() -> NetworkTrace {
    let mut l = Vec::new();
    // conv1 feeds the 3×3 max-pool => ReLU→Pool class (Gist 1-bit eligible).
    l.push(conv("conv1", 7, 3, 64, 112, ActKind::ReluPool, true));
    // layer1: 2 blocks @ 64ch, 56×56
    for b in 0..2 {
        l.push(conv(&format!("l1.b{b}.c1"), 3, 64, 64, 56, ActKind::ReluConv, true));
        l.push(conv(&format!("l1.b{b}.c2"), 3, 64, 64, 56, ActKind::ReluConv, true));
    }
    // layer2: 128ch, 28×28, block 0 downsamples (1×1 projection shortcut)
    l.push(conv("l2.b0.c1", 3, 64, 128, 28, ActKind::ReluConv, true));
    l.push(conv("l2.b0.c2", 3, 128, 128, 28, ActKind::ReluConv, true));
    l.push(conv("l2.b0.down", 1, 64, 128, 28, ActKind::ReluConv, true));
    l.push(conv("l2.b1.c1", 3, 128, 128, 28, ActKind::ReluConv, true));
    l.push(conv("l2.b1.c2", 3, 128, 128, 28, ActKind::ReluConv, true));
    // layer3: 256ch, 14×14
    l.push(conv("l3.b0.c1", 3, 128, 256, 14, ActKind::ReluConv, true));
    l.push(conv("l3.b0.c2", 3, 256, 256, 14, ActKind::ReluConv, true));
    l.push(conv("l3.b0.down", 1, 128, 256, 14, ActKind::ReluConv, true));
    l.push(conv("l3.b1.c1", 3, 256, 256, 14, ActKind::ReluConv, true));
    l.push(conv("l3.b1.c2", 3, 256, 256, 14, ActKind::ReluConv, true));
    // layer4: 512ch, 7×7
    l.push(conv("l4.b0.c1", 3, 256, 512, 7, ActKind::ReluConv, true));
    l.push(conv("l4.b0.c2", 3, 512, 512, 7, ActKind::ReluConv, true));
    l.push(conv("l4.b0.down", 1, 256, 512, 7, ActKind::ReluConv, true));
    l.push(conv("l4.b1.c1", 3, 512, 512, 7, ActKind::ReluConv, true));
    l.push(conv("l4.b1.c2", 3, 512, 512, 7, ActKind::ReluConv, true));
    // head: global avg-pool then fc 512→1000 (linear output, dense)
    l.push(LayerTrace {
        name: "fc".into(),
        weight_elems: 512 * 1000,
        act_elems: 1000,
        macs: 512 * 1000,
        act_kind: ActKind::Dense,
        nonneg_act: false,
        compute_util: 1.0,
        act_model: ValueModel::new(0.0, 126.0, 2.0),
        weight_model: ValueModel::weights(),
    });
    NetworkTrace {
        name: "ResNet18".into(),
        layers: l,
    }
}

/// One MobileNetV3 inverted-residual block: expand 1×1 → depthwise k×k →
/// project 1×1 (linear).  SE blocks are folded into the depthwise MAC count
/// (they are < 1% of compute and their activations are tiny).
#[allow(clippy::too_many_arguments)]
fn bneck(
    l: &mut Vec<LayerTrace>,
    idx: usize,
    k: usize,
    cin: usize,
    cexp: usize,
    cout: usize,
    out_hw: usize,
    relu: bool,
) {
    let in_hw = l
        .last()
        .map(|p| (p.act_elems / cin, p))
        .map(|(px, _)| (px as f64).sqrt() as usize)
        .unwrap_or(out_hw);
    l.push(conv(
        &format!("bneck{idx}.expand"),
        1,
        cin,
        cexp,
        in_hw,
        ActKind::ReluConv,
        relu,
    ));
    l.push(dwconv(&format!("bneck{idx}.dw"), k, cexp, out_hw, relu));
    // projection is linear (no NL): dense activation
    let mut proj = conv(
        &format!("bneck{idx}.project"),
        1,
        cexp,
        cout,
        out_hw,
        ActKind::Dense,
        false,
    );
    proj.act_model = ValueModel::new(0.01, 124.5, 3.0);
    l.push(proj);
}

/// MobileNetV3-Small at 224×224 (Howard et al., Table 2).
pub fn mobilenet_v3_small() -> NetworkTrace {
    let mut l = Vec::new();
    // stem: 3×3 s2 → 16ch @112², hswish
    l.push(conv("stem", 3, 3, 16, 112, ActKind::ReluConv, false));
    // bneck1: 3×3, exp 16, out 16, SE, RE, s2 → 56²
    l.push(dwconv("bneck1.dw", 3, 16, 56, true));
    let mut p = conv("bneck1.project", 1, 16, 16, 56, ActKind::Dense, false);
    p.act_model = ValueModel::new(0.01, 124.5, 3.0);
    l.push(p);
    // bneck2: 3×3, exp 72, out 24, RE, s2 → 28²
    bneck(&mut l, 2, 3, 16, 72, 24, 28, true);
    // bneck3: 3×3, exp 88, out 24, RE, s1
    bneck(&mut l, 3, 3, 24, 88, 24, 28, true);
    // bneck4: 5×5, exp 96, out 40, HS, s2 → 14²
    bneck(&mut l, 4, 5, 24, 96, 40, 14, false);
    // bneck5-6: 5×5, exp 240, out 40, HS
    bneck(&mut l, 5, 5, 40, 240, 40, 14, false);
    bneck(&mut l, 6, 5, 40, 240, 40, 14, false);
    // bneck7: 5×5, exp 120, out 48, HS
    bneck(&mut l, 7, 5, 40, 120, 48, 14, false);
    // bneck8: 5×5, exp 144, out 48, HS
    bneck(&mut l, 8, 5, 48, 144, 48, 14, false);
    // bneck9: 5×5, exp 288, out 96, HS, s2 → 7²
    bneck(&mut l, 9, 5, 48, 288, 96, 7, false);
    // bneck10-11: 5×5, exp 576, out 96, HS
    bneck(&mut l, 10, 5, 96, 576, 96, 7, false);
    bneck(&mut l, 11, 5, 96, 576, 96, 7, false);
    // head convs
    l.push(conv("head.conv", 1, 96, 576, 7, ActKind::ReluConv, false));
    l.push(LayerTrace {
        name: "head.fc1".into(),
        weight_elems: 576 * 1024,
        act_elems: 1024,
        macs: 576 * 1024,
        act_kind: ActKind::ReluConv,
        nonneg_act: false,
        compute_util: 1.0,
        act_model: ValueModel::hswish_act(),
        weight_model: ValueModel::weights(),
    });
    l.push(LayerTrace {
        name: "head.fc2".into(),
        weight_elems: 1024 * 1000,
        act_elems: 1000,
        macs: 1024 * 1000,
        act_kind: ActKind::Dense,
        nonneg_act: false,
        compute_util: 1.0,
        act_model: ValueModel::new(0.0, 126.0, 2.0),
        weight_model: ValueModel::weights(),
    });
    NetworkTrace {
        name: "MobileNetV3-Small".into(),
        layers: l,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resnet18_param_count() {
        // Conv + fc weights of ResNet18 ≈ 11.2M elements (11.69M params
        // total including BN); our conv/fc-only trace must land close.
        let t = resnet18();
        let w = t.total_weight_elems();
        assert!((10_500_000..12_000_000).contains(&w), "weights = {w}");
    }

    #[test]
    fn resnet18_macs() {
        // ≈ 1.82 GMACs per 224×224 sample.
        let t = resnet18();
        let m = t.total_macs_per_sample();
        assert!((1_600_000_000..2_000_000_000).contains(&m), "macs = {m}");
    }

    #[test]
    fn resnet18_activation_volume() {
        // ≈ 2.5M stashed activation elements per sample → with batch 256
        // the gigabyte-scale stash the paper's §III-D describes (FP32).
        let t = resnet18();
        let a = t.total_act_elems_per_sample();
        assert!((2_000_000..3_500_000).contains(&a), "acts = {a}");
        let gb_batch256 = a as f64 * 4.0 * 256.0 / 1e9;
        assert!(gb_batch256 > 2.0, "stash = {gb_batch256} GB");
    }

    #[test]
    fn mobilenet_small_param_count() {
        // MobileNetV3-Small ≈ 2.5M params (2.9M incl. classifier+BN).
        let t = mobilenet_v3_small();
        let w = t.total_weight_elems();
        assert!((2_000_000..3_200_000).contains(&w), "weights = {w}");
    }

    #[test]
    fn mobilenet_small_macs() {
        // ≈ 56–66 MMACs per sample.
        let t = mobilenet_v3_small();
        let m = t.total_macs_per_sample();
        assert!((45_000_000..80_000_000).contains(&m), "macs = {m}");
    }

    #[test]
    fn mobilenet_mostly_dense_activations() {
        // §VI-B: MNv3 "sparsely uses ReLU" → little JS/Gist potential.
        let t = mobilenet_v3_small();
        let relu_elems: usize = t
            .layers
            .iter()
            .filter(|l| l.nonneg_act)
            .map(|l| l.act_elems)
            .sum();
        let frac = relu_elems as f64 / t.total_act_elems_per_sample() as f64;
        assert!(frac < 0.35, "relu act fraction = {frac}");
    }

    #[test]
    fn activations_dominate_weights() {
        // §VI-A: at batch 256 activations dwarf weights for both nets.
        for t in [resnet18(), mobilenet_v3_small()] {
            let acts = t.total_act_elems_per_sample() * 256;
            assert!(acts > 10 * t.total_weight_elems(), "{}", t.name);
        }
    }

    #[test]
    fn value_model_exponent_stream_is_biased() {
        let m = ValueModel::relu_act();
        let exps = m.sample_exponents(100_000, 7);
        let zeros = exps.iter().filter(|&&e| e == 0).count() as f64 / 1e5;
        assert!((zeros - 0.36).abs() < 0.03, "zero frac {zeros}");
        let nz: Vec<f64> = exps.iter().filter(|&&e| e > 0).map(|&e| e as f64).collect();
        let mean = nz.iter().sum::<f64>() / nz.len() as f64;
        assert!((mean - 124.0).abs() < 0.5, "mean {mean}");
    }

    #[test]
    fn value_model_values_match_exponent_model() {
        let m = ValueModel::weights();
        let vals = m.sample_values(50_000, 9, false);
        let mean_exp = vals
            .iter()
            .map(|v| ((v.to_bits() >> 23) & 0xFF) as f64)
            .sum::<f64>()
            / 5e4;
        assert!((mean_exp - 121.0).abs() < 0.5, "mean exp {mean_exp}");
        // signs present
        assert!(vals.iter().any(|&v| v < 0.0));
    }

    #[test]
    fn splitmix_deterministic() {
        let a: Vec<u64> = {
            let mut r = SplitMix64::new(42);
            (0..5).map(|_| r.next_u64()).collect()
        };
        let b: Vec<u64> = {
            let mut r = SplitMix64::new(42);
            (0..5).map(|_| r.next_u64()).collect()
        };
        assert_eq!(a, b);
    }

    #[test]
    fn gecko_ratio_on_modelled_weights_near_paper() {
        // Paper §IV-C: overall weight-exponent compression ratio 0.56,
        // activations 0.52.  Our value models must land in that region.
        use crate::gecko::{encode, Mode};
        let w = ValueModel::weights().sample_exponents(64 * 2048, 11);
        let rw = encode(&w, Mode::Delta).compression_ratio();
        // paper reports 0.56 over the whole run; our stationary model
        // sits slightly tighter (trained-end statistics) — see DESIGN.md
        assert!((0.32..0.70).contains(&rw), "weight ratio {rw}");
        let a = ValueModel::relu_act().sample_exponents(64 * 2048, 13);
        let ra = encode(&a, Mode::Delta).compression_ratio();
        assert!((0.40..0.70).contains(&ra), "act ratio {ra}");
    }
}
