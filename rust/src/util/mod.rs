//! Offline-environment stand-ins for common crates (see Cargo.toml note):
//! JSON, CLI parsing, a bench harness, and property testing.

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
