//! In-tree property-testing helper (proptest is unavailable offline).
//!
//! [`check`] runs a property against `iters` randomly generated cases from
//! a deterministic SplitMix64 stream; on failure it reports the case seed
//! so the exact input can be replayed.  Generators live on [`Gen`].

use crate::traces::SplitMix64;

/// Random-input generator handed to properties.
pub struct Gen {
    rng: SplitMix64,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Self {
            rng: SplitMix64::new(seed),
        }
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + (self.rng.next_u64() as usize) % (hi - lo + 1)
    }

    pub fn u32_in(&mut self, lo: u32, hi: u32) -> u32 {
        lo + (self.rng.next_u64() as u32) % (hi - lo + 1)
    }

    pub fn f64_unit(&mut self) -> f64 {
        self.rng.next_f64()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Arbitrary finite f32 with full exponent coverage (no NaN/Inf, which
    /// the stash never contains — XLA training values are finite).
    pub fn finite_f32(&mut self) -> f32 {
        loop {
            let bits = (self.rng.next_u64() >> 32) as u32;
            let v = f32::from_bits(bits);
            if v.is_finite() {
                return v;
            }
        }
    }

    /// Trained-tensor-like f32 (unit-scale Gaussian).
    pub fn gaussian_f32(&mut self, scale: f32) -> f32 {
        self.rng.next_gaussian() as f32 * scale
    }

    pub fn vec_f32<F: FnMut(&mut Gen) -> f32>(&mut self, len: usize, mut f: F) -> Vec<f32> {
        (0..len).map(|_| f(self)).collect()
    }
}

/// Run `prop` on `iters` generated cases; panics with the failing seed.
pub fn check<F: FnMut(&mut Gen)>(name: &str, iters: u64, mut prop: F) {
    for case in 0..iters {
        let seed = 0xC0FFEE ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut g = Gen::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut g)));
        if let Err(e) = result {
            eprintln!("property '{name}' failed at case {case} (seed {seed:#x})");
            std::panic::resume_unwind(e);
        }
    }
}
