//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, short `-x` flags,
//! and positional args.  A bare `--name` followed by a non-dash token is
//! parsed as an option (`--name value`); use `--name=value` or trailing
//! position for flags.  A single-dash token that parses as a number
//! (`-5`, `-0.5`) stays a value/positional, so negative option values
//! survive.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from `std::env::args` (skipping argv[0]).
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse<I: Iterator<Item = String>>(mut it: I) -> Self {
        let mut out = Args::default();
        let mut pending: Option<String> = None;
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if let Some(p) = pending.take() {
                    out.flags.push(p);
                }
                if let Some((k, v)) = key.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else {
                    pending = Some(key.to_string());
                }
            } else if let Some(k) = pending.take() {
                out.options.insert(k, a);
            } else if let Some(short) = a.strip_prefix('-') {
                if !short.is_empty() && short.parse::<f64>().is_err() {
                    out.flags.push(short.to_string());
                } else {
                    out.positional.push(a);
                }
            } else {
                out.positional.push(a);
            }
        }
        if let Some(p) = pending {
            out.flags.push(p);
        }
        out
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|s| s.to_string()))
    }

    #[test]
    fn mixed_forms() {
        let a = parse("train out.csv --epochs 5 --variant=qm --verbose");
        assert_eq!(a.positional, vec!["train", "out.csv"]);
        assert_eq!(a.get("epochs"), Some("5"));
        assert_eq!(a.get("variant"), Some("qm"));
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn typed_getters() {
        let a = parse("--lr 0.05 --steps 100");
        assert_eq!(a.get_f64("lr", 1.0), 0.05);
        assert_eq!(a.get_usize("steps", 0), 100);
        assert_eq!(a.get_usize("missing", 7), 7);
    }

    #[test]
    fn trailing_flag() {
        let a = parse("--quiet");
        assert!(a.has_flag("quiet"));
    }

    #[test]
    fn short_flags_vs_negative_numbers() {
        let a = parse("-v run --shift -5 --scale -0.5 -q");
        assert!(a.has_flag("v"));
        assert!(a.has_flag("q"));
        assert_eq!(a.positional, vec!["run"]);
        // negative numbers still bind as option values, not flags
        assert_eq!(a.get("shift"), Some("-5"));
        assert_eq!(a.get("scale"), Some("-0.5"));
        // and a bare negative number with no pending option is positional
        let b = parse("-3");
        assert!(b.flags.is_empty());
        assert_eq!(b.positional, vec!["-3"]);
    }
}
