//! In-tree micro-benchmark harness (criterion is unavailable offline).
//!
//! `cargo bench` runs each `[[bench]]` target's `main()` with
//! `harness = false`; targets build a [`Bench`] and register closures.
//! Methodology: warmup, then N timed epochs; reports min / median / mean
//! throughput so perf iterations (EXPERIMENTS.md §Perf) are comparable.

use std::time::Instant;

pub struct Bench {
    name: String,
    warmup_iters: usize,
    epochs: usize,
    min_epoch_iters: usize,
}

#[derive(Debug, Clone, Copy)]
pub struct Report {
    pub median_ns: f64,
    pub min_ns: f64,
    pub mean_ns: f64,
}

impl Bench {
    pub fn new(name: &str) -> Self {
        println!("== bench group: {name} ==");
        Self {
            name: name.to_string(),
            warmup_iters: 3,
            epochs: 7,
            min_epoch_iters: 1,
        }
    }

    pub fn with_epochs(mut self, epochs: usize) -> Self {
        self.epochs = epochs;
        self
    }

    /// Time `f`, which processes `items` logical items per call (used for
    /// throughput reporting: values/s, steps/s, ...).
    pub fn run<F: FnMut()>(&self, case: &str, items: f64, mut f: F) -> Report {
        for _ in 0..self.warmup_iters {
            f();
        }
        // size epochs to >= ~20ms each for stable numbers
        let t0 = Instant::now();
        f();
        let once = t0.elapsed().as_secs_f64().max(1e-9);
        let iters = ((0.02 / once).ceil() as usize).max(self.min_epoch_iters);

        let mut samples = Vec::with_capacity(self.epochs);
        for _ in 0..self.epochs {
            let t = Instant::now();
            for _ in 0..iters {
                f();
            }
            samples.push(t.elapsed().as_secs_f64() / iters as f64 * 1e9);
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let report = Report {
            median_ns: samples[samples.len() / 2],
            min_ns: samples[0],
            mean_ns: samples.iter().sum::<f64>() / samples.len() as f64,
        };
        let per_item = report.median_ns / items.max(1.0);
        let throughput = 1e9 / per_item;
        println!(
            "{}/{case}: median {:>10.1} ns  min {:>10.1} ns  ({:.3e} items/s)",
            self.name, report.median_ns, report.min_ns, throughput
        );
        report
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}
