//! Minimal JSON subset parser + writer (the environment is offline, so no
//! serde_json).  Covers everything `aot.py` emits into `manifest.json` and
//! everything the metrics sinks write: objects, arrays, strings (no escape
//! exotica beyond \" \\ \/ \n \t \r \u), f64 numbers, bools, null.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser {
            b: s.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing bytes at {}", p.i));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(a) => a.get(i),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Serialize (compact).
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{}", n);
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at {}", other.map(|c| c as char), self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u".to_string())?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u".to_string())?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(format!("bad escape at {}", self.i)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full utf-8 scalar
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "bad utf8".to_string())?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(format!("expected , or ] at {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(format!("expected , or }} at {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_like() {
        let s = r#"{"batch": 64, "layers": ["c0", "fc"], "artifacts": {"train": {"file": "t.hlo.txt", "inputs": [{"name": "x", "shape": [64, 16], "dtype": "f32"}]}}, "lambda": [0.5, 1e-3]}"#;
        let j = Json::parse(s).unwrap();
        assert_eq!(j.get("batch").unwrap().as_usize(), Some(64));
        assert_eq!(
            j.get("layers").unwrap().idx(1).unwrap().as_str(),
            Some("fc")
        );
        let inp = j
            .get("artifacts")
            .and_then(|a| a.get("train"))
            .and_then(|t| t.get("inputs"))
            .and_then(|i| i.idx(0))
            .unwrap();
        assert_eq!(inp.get("dtype").unwrap().as_str(), Some("f32"));
        assert_eq!(
            j.get("lambda").unwrap().idx(1).unwrap().as_f64(),
            Some(1e-3)
        );
    }

    #[test]
    fn roundtrip() {
        let s = r#"{"a":[1,2.5,-3],"b":"hi\nthere","c":true,"d":null}"#;
        let j = Json::parse(s).unwrap();
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{\"a\":}").is_err());
        assert!(Json::parse("[1,2").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn nested_arrays() {
        let j = Json::parse("[[1,2],[3,[4]]]").unwrap();
        assert_eq!(
            j.idx(1).unwrap().idx(1).unwrap().idx(0).unwrap().as_f64(),
            Some(4.0)
        );
    }
}
