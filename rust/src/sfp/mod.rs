//! SFP compressor / decompressor — the hardware encode path of §V, Fig. 11.
//!
//! The hardware consumes one row of 8 values per cycle; a group is 8 rows
//! (64 values) treated as an 8×8 matrix.  Column `c` shares a base exponent
//! (its row-0 exponent); rows 1..7 store exponent deltas from the column
//! bases.  Every row uses a single container bitlength:
//!
//! ```text
//! row bits/value = value-sign (1, elided for known-non-negative tensors)
//!                + exponent field   (8 raw for row 0;
//!                                    w+1 sign/mag delta, or 8 raw escape)
//!                + mantissa bits n  (from Quantum Mantissa or BitChop)
//! ```
//!
//! The per-row exponent width (3 b) goes to a separate metadata stream —
//! the hardware's second sequential DRAM stream.  Because every lane of a
//! row uses the same bitlength, the 8 packers fill their 32-bit output
//! registers in tandem (Proteus-style rotate-and-mask keeps values inside
//! their lane), so the compressor emits aligned 8×32 b bursts; the cycle
//! model below reflects that rate behaviour.
//!
//! Decompression restores the *container* value exactly: mantissa bits
//! beyond `n` come back as zeros, i.e. `decompress(compress(x, n)) ==
//! truncate_mantissa(x, n)` — lossless for tensors the quantizer already
//! truncated (property-tested in `rust/tests/props.rs`).

use crate::formats::{mag_width, Container, F32_MANT_BITS};
use crate::gecko::{BitWriter, Kernel, SegReader, RAW_ESCAPE, WIDTH_FIELD_BITS};

/// Values per hardware row (= packer lanes).
pub const LANES: usize = 8;
/// Rows per group.
pub const ROWS: usize = 8;
/// Values per group.
pub const GROUP: usize = LANES * ROWS;
/// Output register width drained to memory per lane per cycle (FP32 mode).
pub const LANE_DRAIN_BITS: usize = 32;

/// Static configuration of one compressor/decompressor unit.
#[derive(Debug, Clone, Copy)]
pub struct SfpCodec {
    pub container: Container,
    /// Elide the value sign bit (post-ReLU tensors are non-negative, §IV-D).
    pub elide_sign: bool,
    /// Learned per-tensor exponent bias register (Quantum Exponent).  When
    /// set, *every* row — including row 0 — stores sign/magnitude deltas
    /// against this register at a shared per-row width, instead of raw
    /// 8-bit row-0 column bases; the raw escape keeps the layout lossless
    /// over the full exponent range.  `None` = the §V row-0-base layout.
    pub bias: Option<u8>,
}

/// A compressed tensor: payload + width metadata streams and bookkeeping
/// needed for decompression and footprint accounting.
#[derive(Debug, Clone)]
pub struct Compressed {
    pub payload: Vec<u64>,
    pub payload_bits: usize,
    pub metadata: Vec<u64>,
    pub metadata_bits: usize,
    pub count: usize,
    pub mant_bits: u32,
    /// Compressor occupancy from the cycle model (see [`SfpCodec::cycles`]).
    pub cycles: u64,
}

impl Compressed {
    /// Total stored bits (payload + metadata).
    pub fn total_bits(&self) -> usize {
        self.payload_bits + self.metadata_bits
    }

    /// Ratio vs. the uncompressed container.
    pub fn ratio(&self, container: Container) -> f64 {
        self.total_bits() as f64 / (container.total_bits() as f64 * self.count as f64)
    }
}

impl SfpCodec {
    pub fn new(container: Container, elide_sign: bool) -> Self {
        Self {
            container,
            elide_sign,
            bias: None,
        }
    }

    /// Use a learned exponent bias register (see [`SfpCodec::bias`]).
    pub fn with_bias(mut self, bias: Option<u8>) -> Self {
        self.bias = bias;
        self
    }

    /// Compress `vals` with `n` mantissa bits per value (the external
    /// mantissa-length signal from Quantum Mantissa / BitChop).
    ///
    /// Values are expected in stream order; the trailing partial group is
    /// padded with the last value, as the hardware pads the final burst.
    /// Runs the process-wide [`Kernel::active`] implementation; both
    /// kernels emit bit-identical streams.
    pub fn compress(&self, vals: &[f32], n: u32) -> Compressed {
        self.compress_kernel(vals, n, Kernel::active())
    }

    /// [`SfpCodec::compress`] with an explicit kernel — [`Kernel::Word`]
    /// packs each 8-lane row with one [`BitWriter::pack_lanes`] call,
    /// [`Kernel::Scalar`] is the per-value reference; differential tests
    /// drive both and assert identical streams.
    pub fn compress_kernel(&self, vals: &[f32], n: u32, kernel: Kernel) -> Compressed {
        match kernel {
            Kernel::Word => self.compress_word(vals, n),
            Kernel::Scalar => self.compress_scalar(vals, n),
        }
    }

    fn compress_scalar(&self, vals: &[f32], n: u32) -> Compressed {
        let n = n.min(self.container.mant_bits());
        let sign_bits: u32 = if self.elide_sign { 0 } else { 1 };
        let mut payload = BitWriter::with_capacity(vals.len() * (n as usize + 8));
        let mut metadata = BitWriter::with_capacity(vals.len() / ROWS * 3);

        if vals.is_empty() {
            return Compressed {
                payload: Vec::new(),
                payload_bits: 0,
                metadata: Vec::new(),
                metadata_bits: 0,
                count: 0,
                mant_bits: n,
                cycles: 0,
            };
        }

        let mut padded = vals.to_vec();
        let pad = (GROUP - padded.len() % GROUP) % GROUP;
        let last = *padded.last().unwrap();
        padded.extend(std::iter::repeat(last).take(pad));

        // Perf note (EXPERIMENTS.md §Perf): each value is emitted with a
        // SINGLE BitWriter::push of a fused [sign | exp-field | mantissa]
        // word (≤ 32 bits) instead of three pushes — the bitstream layout
        // is identical, the per-value call overhead is 3× lower.
        for g in padded.chunks_exact(GROUP) {
            if let Some(bias) = self.bias {
                // Bias-register layout: all 8 rows delta against the
                // learned per-tensor register at a shared per-row width
                // (no raw row-0 bases), so Quantum Exponent's narrowing
                // reaches the hardware stream too.
                for r in 0..ROWS {
                    let row = &g[r * LANES..(r + 1) * LANES];
                    let w = row
                        .iter()
                        .map(|&v| {
                            let e = ((v.to_bits() >> 23) & 0xFF) as i32;
                            mag_width((e - bias as i32).unsigned_abs())
                        })
                        .max()
                        .unwrap();
                    let (code, raw) = if w <= 6 { (w, false) } else { (RAW_ESCAPE, true) };
                    metadata.push(code as u64, WIDTH_FIELD_BITS + 1);
                    for &v in row {
                        let b = v.to_bits();
                        let e = ((b >> 23) & 0xFF) as i32;
                        let mant = self.top_mantissa(b, n) as u64;
                        let (exp_field, exp_bits) = if raw {
                            (e as u64, 8)
                        } else {
                            let d = e - bias as i32;
                            ((((d < 0) as u64) << w) | d.unsigned_abs() as u64, w + 1)
                        };
                        if self.elide_sign {
                            payload.push((exp_field << n) | mant, exp_bits + n);
                        } else {
                            let word = (((b >> 31) as u64) << (exp_bits + n))
                                | (exp_field << n)
                                | mant;
                            payload.push(word, 1 + exp_bits + n);
                        }
                    }
                }
                continue;
            }
            let mut bases = [0u32; LANES];
            // Row 0: raw exponents become the column bases.
            for (c, &v) in g[..LANES].iter().enumerate() {
                let b = v.to_bits();
                bases[c] = (b >> 23) & 0xFF;
                let mant = self.top_mantissa(b, n) as u64;
                if self.elide_sign {
                    payload.push(((bases[c] as u64) << n) | mant, 8 + n);
                } else {
                    let word = (((b >> 31) as u64) << (8 + n))
                        | ((bases[c] as u64) << n)
                        | mant;
                    payload.push(word, 9 + n);
                }
            }
            metadata.push(8, WIDTH_FIELD_BITS + 1); // row-0 marker width (8 raw); 4b field keeps streams self-describing
            // Rows 1..7: delta exponents at a shared width.
            for r in 1..ROWS {
                let row = &g[r * LANES..(r + 1) * LANES];
                let w = row
                    .iter()
                    .zip(&bases)
                    .map(|(&v, &b)| {
                        let e = ((v.to_bits() >> 23) & 0xFF) as i32;
                        mag_width((e - b as i32).unsigned_abs())
                    })
                    .max()
                    .unwrap();
                let (code, raw) = if w <= 6 { (w, false) } else { (RAW_ESCAPE, true) };
                metadata.push(code as u64, WIDTH_FIELD_BITS + 1);
                for (c, &v) in row.iter().enumerate() {
                    let b = v.to_bits();
                    let e = ((b >> 23) & 0xFF) as i32;
                    let mant = self.top_mantissa(b, n) as u64;
                    // exp field: raw 8b, or [sign | mag] at width w+1
                    let (exp_field, exp_bits) = if raw {
                        (e as u64, 8)
                    } else {
                        let d = e - bases[c] as i32;
                        ((((d < 0) as u64) << w) | d.unsigned_abs() as u64, w + 1)
                    };
                    if self.elide_sign {
                        payload.push((exp_field << n) | mant, exp_bits + n);
                    } else {
                        let word = (((b >> 31) as u64) << (exp_bits + n))
                            | (exp_field << n)
                            | mant;
                        payload.push(word, 1 + exp_bits + n);
                    }
                }
                let _ = sign_bits;
            }
        }

        let (pw, pb) = payload.into_words();
        let (mw, mb) = metadata.into_words();
        let cycles = self.cycles_for(padded.len(), pb + mb);
        Compressed {
            payload: pw,
            payload_bits: pb,
            metadata: mw,
            metadata_bits: mb,
            count: vals.len(),
            mant_bits: n,
            cycles,
        }
    }

    /// Word-parallel compress: one [`BitWriter::pack_lanes`] splice per
    /// 8-lane row instead of eight scalar pushes.  Every lane of a row
    /// shares one width (`sign + exp_field + n`), which is exactly the
    /// property the hardware's tandem packers exploit — and what makes the
    /// row a uniform bit-plane the staging accumulator can stream.
    fn compress_word(&self, vals: &[f32], n: u32) -> Compressed {
        let n = n.min(self.container.mant_bits());
        if vals.is_empty() {
            return Compressed {
                payload: Vec::new(),
                payload_bits: 0,
                metadata: Vec::new(),
                metadata_bits: 0,
                count: 0,
                mant_bits: n,
                cycles: 0,
            };
        }
        let mut payload = BitWriter::with_capacity(vals.len() * (n as usize + 8));
        let mut metadata = BitWriter::with_capacity(vals.len() / ROWS * 3);

        let mut it = vals.chunks_exact(GROUP);
        for g in it.by_ref() {
            let g: &[f32; GROUP] = g.try_into().expect("GROUP-sized chunk");
            self.compress_group_word(g, n, &mut payload, &mut metadata);
        }
        let rem = it.remainder();
        if !rem.is_empty() {
            // Pad the final group with the last value — same stream as the
            // scalar path, without copying the whole input.
            let mut tail = [*vals.last().unwrap(); GROUP];
            tail[..rem.len()].copy_from_slice(rem);
            self.compress_group_word(&tail, n, &mut payload, &mut metadata);
        }

        let padded_len = vals.len().div_ceil(GROUP) * GROUP;
        let (pw, pb) = payload.into_words();
        let (mw, mb) = metadata.into_words();
        let cycles = self.cycles_for(padded_len, pb + mb);
        Compressed {
            payload: pw,
            payload_bits: pb,
            metadata: mw,
            metadata_bits: mb,
            count: vals.len(),
            mant_bits: n,
            cycles,
        }
    }

    /// Pack one 8×8 group row-by-row.  Per row: derive the shared exponent
    /// width from the OR of the eight delta magnitudes (one leading-one
    /// detector instead of eight), assemble the eight fused
    /// `[sign | exp-field | mantissa]` lane words, splice them in one
    /// `pack_lanes` call.
    fn compress_group_word(
        &self,
        g: &[f32; GROUP],
        n: u32,
        payload: &mut BitWriter,
        metadata: &mut BitWriter,
    ) {
        let sign_bits = u32::from(!self.elide_sign);
        let mut fields = [0u64; LANES];
        if let Some(bias) = self.bias {
            // Bias-register layout: every row deltas against the learned
            // register at a shared per-row width.
            for r in 0..ROWS {
                let row = &g[r * LANES..(r + 1) * LANES];
                let mut bits = [0u32; LANES];
                let mut exps = [0i32; LANES];
                let mut or = 0u32;
                for c in 0..LANES {
                    bits[c] = row[c].to_bits();
                    exps[c] = ((bits[c] >> 23) & 0xFF) as i32;
                    or |= (exps[c] - bias as i32).unsigned_abs();
                }
                let w = mag_width(or);
                let (code, raw) = if w <= 6 { (w, false) } else { (RAW_ESCAPE, true) };
                metadata.push(code as u64, WIDTH_FIELD_BITS + 1);
                let exp_bits = if raw { 8 } else { w + 1 };
                for c in 0..LANES {
                    let mant = self.top_mantissa(bits[c], n) as u64;
                    let exp_field = if raw {
                        exps[c] as u64
                    } else {
                        let d = exps[c] - bias as i32;
                        (((d < 0) as u64) << w) | d.unsigned_abs() as u64
                    };
                    let mut f = (exp_field << n) | mant;
                    if !self.elide_sign {
                        f |= ((bits[c] >> 31) as u64) << (exp_bits + n);
                    }
                    fields[c] = f;
                }
                payload.pack_lanes(&fields, sign_bits + exp_bits + n);
            }
            return;
        }
        // §V base layout: row 0 carries raw column bases.
        let mut bases = [0u32; LANES];
        for c in 0..LANES {
            let b = g[c].to_bits();
            bases[c] = (b >> 23) & 0xFF;
            let mant = self.top_mantissa(b, n) as u64;
            let mut f = ((bases[c] as u64) << n) | mant;
            if !self.elide_sign {
                f |= ((b >> 31) as u64) << (8 + n);
            }
            fields[c] = f;
        }
        payload.pack_lanes(&fields, sign_bits + 8 + n);
        metadata.push(8, WIDTH_FIELD_BITS + 1); // row-0 marker (see scalar path)
        for r in 1..ROWS {
            let row = &g[r * LANES..(r + 1) * LANES];
            let mut bits = [0u32; LANES];
            let mut exps = [0i32; LANES];
            let mut or = 0u32;
            for c in 0..LANES {
                bits[c] = row[c].to_bits();
                exps[c] = ((bits[c] >> 23) & 0xFF) as i32;
                or |= (exps[c] - bases[c] as i32).unsigned_abs();
            }
            let w = mag_width(or);
            let (code, raw) = if w <= 6 { (w, false) } else { (RAW_ESCAPE, true) };
            metadata.push(code as u64, WIDTH_FIELD_BITS + 1);
            let exp_bits = if raw { 8 } else { w + 1 };
            for c in 0..LANES {
                let mant = self.top_mantissa(bits[c], n) as u64;
                let exp_field = if raw {
                    exps[c] as u64
                } else {
                    let d = exps[c] - bases[c] as i32;
                    (((d < 0) as u64) << w) | d.unsigned_abs() as u64
                };
                let mut f = (exp_field << n) | mant;
                if !self.elide_sign {
                    f |= ((bits[c] >> 31) as u64) << (exp_bits + n);
                }
                fields[c] = f;
            }
            payload.pack_lanes(&fields, sign_bits + exp_bits + n);
        }
    }

    /// Decompress back into container-format values (trimmed mantissa bits
    /// return as zeros, signs return as + when elided).
    pub fn decompress(&self, c: &Compressed) -> Vec<f32> {
        let mut payload = SegReader::single(&c.payload, c.payload_bits);
        let mut metadata = SegReader::single(&c.metadata, c.metadata_bits);
        self.decompress_readers(&mut payload, &mut metadata, c.count, c.mant_bits)
    }

    /// [`SfpCodec::decompress`] from already-positioned payload/metadata
    /// readers — the zero-copy restore path (the readers may span arena
    /// chunk segments).
    pub fn decompress_readers(
        &self,
        payload: &mut SegReader,
        metadata: &mut SegReader,
        count: usize,
        n: u32,
    ) -> Vec<f32> {
        self.decompress_readers_kernel(payload, metadata, count, n, Kernel::active())
    }

    /// [`SfpCodec::decompress_readers`] with an explicit kernel (see
    /// [`SfpCodec::compress_kernel`]).
    pub fn decompress_readers_kernel(
        &self,
        payload: &mut SegReader,
        metadata: &mut SegReader,
        count: usize,
        n: u32,
        kernel: Kernel,
    ) -> Vec<f32> {
        match kernel {
            Kernel::Word => self.decompress_readers_word(payload, metadata, count, n),
            Kernel::Scalar => self.decompress_readers_scalar(payload, metadata, count, n),
        }
    }

    fn decompress_readers_scalar(
        &self,
        payload: &mut SegReader,
        metadata: &mut SegReader,
        count: usize,
        n: u32,
    ) -> Vec<f32> {
        let padded_len = count.div_ceil(GROUP) * GROUP;
        let mut out = Vec::with_capacity(padded_len);

        // Mirror of the fused-write layout: one read per value, fields
        // split with shifts (perf §Perf).
        let sign_bits = u32::from(!self.elide_sign);
        for _ in 0..padded_len / GROUP {
            if let Some(bias) = self.bias {
                for _ in 0..ROWS {
                    let code = metadata.read(WIDTH_FIELD_BITS + 1) as u32;
                    let exp_bits = if code == RAW_ESCAPE { 8 } else { code + 1 };
                    for _ in 0..LANES {
                        let word = payload.read(sign_bits + exp_bits + n);
                        let sign = if self.elide_sign {
                            0
                        } else {
                            (word >> (exp_bits + n)) as u32 & 1
                        };
                        let exp_field = (word >> n) & ((1u64 << exp_bits) - 1);
                        let e = if code == RAW_ESCAPE {
                            exp_field as u32
                        } else {
                            let mag = (exp_field & ((1 << code) - 1)) as i32;
                            let d = if exp_field >> code == 1 { -mag } else { mag };
                            (bias as i32 + d) as u32
                        };
                        let m = word as u32 & mant_mask(n);
                        out.push(self.assemble(sign, e, m, n));
                    }
                }
                continue;
            }
            let marker = metadata.read(WIDTH_FIELD_BITS + 1) as u32;
            debug_assert_eq!(marker, 8);
            let mut bases = [0u32; LANES];
            for base in bases.iter_mut() {
                let word = payload.read(sign_bits + 8 + n);
                let sign = if self.elide_sign { 0 } else { (word >> (8 + n)) as u32 & 1 };
                let e = (word >> n) as u32 & 0xFF;
                *base = e;
                let m = word as u32 & mant_mask(n);
                out.push(self.assemble(sign, e, m, n));
            }
            for _ in 1..ROWS {
                let code = metadata.read(WIDTH_FIELD_BITS + 1) as u32;
                let exp_bits = if code == RAW_ESCAPE { 8 } else { code + 1 };
                for base in bases.iter() {
                    let word = payload.read(sign_bits + exp_bits + n);
                    let sign = if self.elide_sign {
                        0
                    } else {
                        (word >> (exp_bits + n)) as u32 & 1
                    };
                    let exp_field = (word >> n) & ((1u64 << exp_bits) - 1);
                    let e = if code == RAW_ESCAPE {
                        exp_field as u32
                    } else {
                        let mag = (exp_field & ((1 << code) - 1)) as i32;
                        let d = if exp_field >> code == 1 { -mag } else { mag };
                        (*base as i32 + d) as u32
                    };
                    let m = word as u32 & mant_mask(n);
                    out.push(self.assemble(sign, e, m, n));
                }
            }
        }
        out.truncate(count);
        out
    }

    /// Word-parallel decompress: one [`SegReader::unpack_lanes`] call per
    /// 8-lane row, then lane fields split with shifts/masks — the mirror
    /// of [`SfpCodec::compress_group_word`].
    fn decompress_readers_word(
        &self,
        payload: &mut SegReader,
        metadata: &mut SegReader,
        count: usize,
        n: u32,
    ) -> Vec<f32> {
        let padded_len = count.div_ceil(GROUP) * GROUP;
        let mut out = Vec::with_capacity(padded_len);
        let sign_bits = u32::from(!self.elide_sign);
        let mut fields = [0u64; LANES];
        for _ in 0..padded_len / GROUP {
            if let Some(bias) = self.bias {
                for _ in 0..ROWS {
                    let code = metadata.read(WIDTH_FIELD_BITS + 1) as u32;
                    let exp_bits = if code == RAW_ESCAPE { 8 } else { code + 1 };
                    payload.unpack_lanes(sign_bits + exp_bits + n, &mut fields);
                    for &word in &fields {
                        let sign = if self.elide_sign {
                            0
                        } else {
                            (word >> (exp_bits + n)) as u32 & 1
                        };
                        let exp_field = (word >> n) & ((1u64 << exp_bits) - 1);
                        let e = if code == RAW_ESCAPE {
                            exp_field as u32
                        } else {
                            let mag = (exp_field & ((1 << code) - 1)) as i32;
                            let d = if exp_field >> code == 1 { -mag } else { mag };
                            (bias as i32 + d) as u32
                        };
                        let m = word as u32 & mant_mask(n);
                        out.push(self.assemble(sign, e, m, n));
                    }
                }
                continue;
            }
            let marker = metadata.read(WIDTH_FIELD_BITS + 1) as u32;
            debug_assert_eq!(marker, 8);
            let mut bases = [0u32; LANES];
            payload.unpack_lanes(sign_bits + 8 + n, &mut fields);
            for (c, &word) in fields.iter().enumerate() {
                let sign = if self.elide_sign { 0 } else { (word >> (8 + n)) as u32 & 1 };
                let e = (word >> n) as u32 & 0xFF;
                bases[c] = e;
                let m = word as u32 & mant_mask(n);
                out.push(self.assemble(sign, e, m, n));
            }
            for _ in 1..ROWS {
                let code = metadata.read(WIDTH_FIELD_BITS + 1) as u32;
                let exp_bits = if code == RAW_ESCAPE { 8 } else { code + 1 };
                payload.unpack_lanes(sign_bits + exp_bits + n, &mut fields);
                for (c, &word) in fields.iter().enumerate() {
                    let sign = if self.elide_sign {
                        0
                    } else {
                        (word >> (exp_bits + n)) as u32 & 1
                    };
                    let exp_field = (word >> n) & ((1u64 << exp_bits) - 1);
                    let e = if code == RAW_ESCAPE {
                        exp_field as u32
                    } else {
                        let mag = (exp_field & ((1 << code) - 1)) as i32;
                        let d = if exp_field >> code == 1 { -mag } else { mag };
                        (bases[c] as i32 + d) as u32
                    };
                    let m = word as u32 & mant_mask(n);
                    out.push(self.assemble(sign, e, m, n));
                }
            }
        }
        out.truncate(count);
        out
    }

    #[inline]
    fn top_mantissa(&self, bits: u32, n: u32) -> u32 {
        // top n mantissa bits of the container (bf16 mantissa is the top 7
        // f32 mantissa bits, so one expression covers both containers).
        if n == 0 {
            0
        } else {
            (bits >> (F32_MANT_BITS - n)) & ((1 << n) - 1)
        }
    }

    #[inline]
    fn assemble(&self, sign: u32, exp: u32, top_mant: u32, n: u32) -> f32 {
        let mant = if n == 0 {
            0
        } else {
            top_mant << (F32_MANT_BITS - n)
        };
        f32::from_bits((sign << 31) | (exp << 23) | mant)
    }

    /// Cycle-count model of the 8-lane unit (§V-A): the input side consumes
    /// one row (8 values) per cycle; the output side drains 8×32 b (8×16 b
    /// for BF16) per cycle.  Unit occupancy is whichever is slower.
    pub fn cycles_for(&self, padded_count: usize, total_bits: usize) -> u64 {
        let input_cycles = (padded_count / LANES) as u64;
        let drain_per_cycle = match self.container {
            Container::Fp32 => LANES * LANE_DRAIN_BITS,
            Container::Bf16 => LANES * LANE_DRAIN_BITS / 2,
        };
        let output_cycles = total_bits.div_ceil(drain_per_cycle) as u64;
        input_cycles.max(output_cycles)
    }
}

#[inline]
fn mant_mask(n: u32) -> u32 {
    if n == 0 {
        0
    } else {
        (1u32 << n) - 1
    }
}

/// Footprint (bits) of one tensor under the full SFP scheme without
/// materializing a bitstream — mantissa `n` per value, Gecko-delta
/// exponents, optional sign elision.  Used by the ImageNet-scale footprint
/// models; matches [`SfpCodec::compress`] totals exactly for the default
/// row-0-base layout (unit-tested; the bias-register layout stores fewer
/// bits and is measured through the stash instead).
pub fn sfp_bits(vals: &[f32], n: u32, container: Container, elide_sign: bool) -> usize {
    let n = n.min(container.mant_bits()) as usize;
    if vals.is_empty() {
        return 0;
    }
    let mut padded: Vec<u8> = vals
        .iter()
        .map(|v| ((v.to_bits() >> 23) & 0xFF) as u8)
        .collect();
    let pad = (GROUP - padded.len() % GROUP) % GROUP;
    let last = *padded.last().unwrap();
    padded.extend(std::iter::repeat(last).take(pad));

    let sign = usize::from(!elide_sign);
    let mut bits = 0usize;
    for g in padded.chunks_exact(GROUP) {
        bits += (WIDTH_FIELD_BITS as usize + 1) * ROWS; // metadata per row
        bits += LANES * (sign + 8 + n); // row 0
        let bases = &g[..LANES];
        for r in 1..ROWS {
            let row = &g[r * LANES..(r + 1) * LANES];
            let w = row
                .iter()
                .zip(bases)
                .map(|(&e, &b)| mag_width((e as i32 - b as i32).unsigned_abs()))
                .max()
                .unwrap() as usize;
            let exp_bits = if w <= 6 { w + 1 } else { 8 };
            bits += LANES * (sign + exp_bits + n);
        }
    }
    bits
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::truncate_mantissa;

    fn pseudo_vals(n: usize, seed: u64, scale: f32) -> Vec<f32> {
        let mut s = seed.wrapping_add(0x9E3779B97F4A7C15);
        (0..n)
            .map(|_| {
                s ^= s >> 12;
                s ^= s << 25;
                s ^= s >> 27;
                let u = (s.wrapping_mul(0x2545F4914F6CDD1D) >> 40) as f32 / (1u64 << 24) as f32;
                (u - 0.5) * 2.0 * scale
            })
            .collect()
    }

    #[test]
    fn roundtrip_equals_truncation_fp32() {
        let vals = pseudo_vals(1000, 1, 5.0);
        for n in [0u32, 1, 4, 11, 23] {
            let codec = SfpCodec::new(Container::Fp32, false);
            let c = codec.compress(&vals, n);
            let back = codec.decompress(&c);
            for (i, (&v, &b)) in vals.iter().zip(&back).enumerate() {
                assert_eq!(
                    truncate_mantissa(v, n).to_bits(),
                    b.to_bits(),
                    "n={n} i={i}"
                );
            }
        }
    }

    #[test]
    fn roundtrip_bf16_container() {
        let vals = pseudo_vals(513, 2, 100.0);
        let codec = SfpCodec::new(Container::Bf16, false);
        for n in [0u32, 3, 7] {
            let c = codec.compress(&vals, n);
            let back = codec.decompress(&c);
            for (&v, &b) in vals.iter().zip(&back) {
                assert_eq!(truncate_mantissa(v, n).to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn sign_elision_nonnegative() {
        let vals: Vec<f32> = pseudo_vals(256, 3, 9.0).iter().map(|v| v.abs()).collect();
        let with = SfpCodec::new(Container::Fp32, false).compress(&vals, 5);
        let without = SfpCodec::new(Container::Fp32, true).compress(&vals, 5);
        // exactly one bit per (padded) value saved
        assert_eq!(with.payload_bits - without.payload_bits, 256);
        let back = SfpCodec::new(Container::Fp32, true).decompress(&without);
        for (&v, &b) in vals.iter().zip(&back) {
            assert_eq!(truncate_mantissa(v, 5).to_bits(), b.to_bits());
        }
    }

    #[test]
    fn compresses_trained_like_tensor() {
        // unit-scale values, 4 mantissa bits: well under half of FP32
        let vals = pseudo_vals(4096, 4, 1.0);
        let c = SfpCodec::new(Container::Fp32, false).compress(&vals, 4);
        assert!(c.ratio(Container::Fp32) < 0.5, "{}", c.ratio(Container::Fp32));
    }

    #[test]
    fn sfp_bits_matches_compressor() {
        for seed in 0..4u64 {
            let vals = pseudo_vals(700, seed, 3.0);
            for n in [0u32, 2, 7] {
                for elide in [false, true] {
                    let c = SfpCodec::new(Container::Fp32, elide).compress(&vals, n);
                    assert_eq!(sfp_bits(&vals, n, Container::Fp32, elide), c.total_bits());
                }
            }
        }
    }

    #[test]
    fn cycle_model_rates() {
        let codec = SfpCodec::new(Container::Fp32, false);
        // Incompressible stream: output side dominates.
        let c_in = 64 * 100;
        let worst_bits = c_in * 32;
        assert_eq!(
            codec.cycles_for(c_in, worst_bits),
            (worst_bits / 256) as u64
        );
        // Highly compressed: input side (8 values/cycle) dominates.
        assert_eq!(codec.cycles_for(c_in, 64), (c_in / 8) as u64);
    }

    #[test]
    fn zeros_heavy_stream_roundtrip() {
        let mut vals = pseudo_vals(300, 6, 2.0);
        for v in vals.iter_mut().step_by(3) {
            *v = 0.0;
        }
        let codec = SfpCodec::new(Container::Fp32, false);
        let c = codec.compress(&vals, 3);
        let back = codec.decompress(&c);
        for (&v, &b) in vals.iter().zip(&back) {
            assert_eq!(truncate_mantissa(v, 3).to_bits(), b.to_bits());
        }
    }

    #[test]
    fn empty_input() {
        let codec = SfpCodec::new(Container::Fp32, false);
        let c = codec.compress(&[], 4);
        assert_eq!(c.total_bits(), 0);
        assert!(codec.decompress(&c).is_empty());
    }

    #[test]
    fn bias_register_roundtrip_is_truncation() {
        let vals = pseudo_vals(1000, 8, 4.0);
        for bias in [0u8, 100, 127, 254] {
            for n in [0u32, 1, 5, 23] {
                for elide in [false, true] {
                    let vals: Vec<f32> = if elide {
                        vals.iter().map(|v| v.abs()).collect()
                    } else {
                        vals.clone()
                    };
                    let codec = SfpCodec::new(Container::Fp32, elide).with_bias(Some(bias));
                    let c = codec.compress(&vals, n);
                    let back = codec.decompress(&c);
                    for (i, (&v, &b)) in vals.iter().zip(&back).enumerate() {
                        assert_eq!(
                            truncate_mantissa(v, n).to_bits(),
                            b.to_bits(),
                            "bias={bias} n={n} elide={elide} i={i}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn bias_register_narrows_trained_like_stream() {
        // Unit-scale values hug exponent 127: a learned 127 register turns
        // row-0's raw 8-bit bases into narrow deltas, so the bias layout
        // must store strictly fewer payload bits than the §V base layout.
        let vals = pseudo_vals(64 * 64, 12, 1.0);
        let base = SfpCodec::new(Container::Bf16, false).compress(&vals, 3);
        let biased = SfpCodec::new(Container::Bf16, false)
            .with_bias(Some(127))
            .compress(&vals, 3);
        assert!(
            biased.payload_bits < base.payload_bits,
            "biased {} vs base {}",
            biased.payload_bits,
            base.payload_bits
        );
        let back = SfpCodec::new(Container::Bf16, false)
            .with_bias(Some(127))
            .decompress(&biased);
        for (&v, &b) in vals.iter().zip(&back) {
            assert_eq!(truncate_mantissa(v, 3).to_bits(), b.to_bits());
        }
    }

    /// Word and scalar kernels must emit bit-identical streams across both
    /// exponent layouts, sign elision, mantissa extremes (0 and 1 bits, and
    /// the full container), ragged tails, and raw-escape exponent mixes.
    #[test]
    fn word_kernel_streams_bit_identical_to_scalar() {
        let mut streams: Vec<Vec<f32>> = vec![
            pseudo_vals(1000, 41, 5.0),
            pseudo_vals(64, 42, 1.0),
            pseudo_vals(137, 43, 2.0),
            pseudo_vals(7, 44, 0.5),
            vec![0.0; 64],
        ];
        let mut extreme = pseudo_vals(100, 45, 1e30);
        extreme.extend(pseudo_vals(100, 46, 1e-30));
        extreme[9] = 0.0;
        streams.push(extreme);
        streams.push(Vec::new());

        for vals in &streams {
            for container in [Container::Fp32, Container::Bf16] {
                for n in [0u32, 1, 7, 23] {
                    for elide in [false, true] {
                        for bias in [None, Some(127u8), Some(3)] {
                            let vals: Vec<f32> = if elide {
                                vals.iter().map(|v| v.abs()).collect()
                            } else {
                                vals.clone()
                            };
                            let codec = SfpCodec::new(container, elide).with_bias(bias);
                            let w = codec.compress_kernel(&vals, n, Kernel::Word);
                            let s = codec.compress_kernel(&vals, n, Kernel::Scalar);
                            let ctx = format!(
                                "{container:?} n={n} elide={elide} bias={bias:?} len={}",
                                vals.len()
                            );
                            assert_eq!(w.payload, s.payload, "{ctx}");
                            assert_eq!(w.payload_bits, s.payload_bits, "{ctx}");
                            assert_eq!(w.metadata, s.metadata, "{ctx}");
                            assert_eq!(w.metadata_bits, s.metadata_bits, "{ctx}");
                            assert_eq!(w.cycles, s.cycles, "{ctx}");
                            for kernel in [Kernel::Word, Kernel::Scalar] {
                                let mut p = SegReader::single(&w.payload, w.payload_bits);
                                let mut m = SegReader::single(&w.metadata, w.metadata_bits);
                                let back = codec.decompress_readers_kernel(
                                    &mut p,
                                    &mut m,
                                    w.count,
                                    w.mant_bits,
                                    kernel,
                                );
                                let n_eff = n.min(container.mant_bits());
                                for (i, (&v, &b)) in vals.iter().zip(&back).enumerate() {
                                    assert_eq!(
                                        truncate_mantissa(v, n_eff).to_bits(),
                                        b.to_bits(),
                                        "{ctx} {kernel:?} i={i}"
                                    );
                                }
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn bias_register_extreme_exponents_escape_raw() {
        let mut vals = pseudo_vals(300, 13, 1e30);
        vals.extend(pseudo_vals(300, 14, 1e-30));
        let codec = SfpCodec::new(Container::Fp32, false).with_bias(Some(127));
        let c = codec.compress(&vals, 7);
        let back = codec.decompress(&c);
        for (&v, &b) in vals.iter().zip(&back) {
            assert_eq!(truncate_mantissa(v, 7).to_bits(), b.to_bits());
        }
    }
}
