//! Streaming statistics: exponent histograms (Fig. 9), post-encoding
//! bitlength CDFs (Fig. 10), BitChop bitlength histograms (Fig. 8), and
//! the per-component footprint ledger behind Table I / Fig. 12 / Fig. 13.

use crate::formats::mag_width;
use crate::gecko;


/// Fixed 256-bin histogram over biased exponent bytes.
#[derive(Debug, Clone)]
pub struct ExponentHistogram {
    pub bins: Vec<u64>,
    pub total: u64,
}

impl Default for ExponentHistogram {
    fn default() -> Self {
        Self {
            bins: vec![0; 256],
            total: 0,
        }
    }
}

impl ExponentHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add_vals(&mut self, vals: &[f32]) {
        for &v in vals {
            self.bins[((v.to_bits() >> 23) & 0xFF) as usize] += 1;
            self.total += 1;
        }
    }

    pub fn merge(&mut self, other: &Self) {
        for (a, b) in self.bins.iter_mut().zip(&other.bins) {
            *a += b;
        }
        self.total += other.total;
    }

    /// Fraction of mass within ±`radius` of the bias (127) — the Fig. 9
    /// "heavily biased around 127" summary statistic.
    pub fn mass_near_bias(&self, radius: usize) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let lo = 127usize.saturating_sub(radius);
        let hi = (127 + radius).min(255);
        let m: u64 = self.bins[lo..=hi].iter().sum();
        m as f64 / self.total as f64
    }

    /// (exponent, count) pairs for non-empty bins, for figure CSVs.
    pub fn nonzero(&self) -> Vec<(u8, u64)> {
        self.bins
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| (i as u8, c))
            .collect()
    }
}

/// Distribution of per-value *encoded* exponent widths after Gecko delta
/// encoding (Fig. 10: x = bits, y = cumulative fraction of values).
///
/// Each value is charged the bits Gecko actually stores for it: 8 for a
/// row-0 base or a raw-escape row, `w+1` for a delta row of width `w`.
#[derive(Debug, Clone, Default)]
pub struct EncodedWidthCdf {
    /// counts[b] = values stored with exactly `b` bits (b in 0..=8).
    pub counts: [u64; 9],
    pub total: u64,
}

impl EncodedWidthCdf {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add_vals(&mut self, vals: &[f32]) {
        let exps = gecko::exponents(vals);
        self.add_exponents(&exps);
    }

    pub fn add_exponents(&mut self, exps: &[u8]) {
        if exps.is_empty() {
            return;
        }
        let mut v = exps.to_vec();
        let pad = (gecko::GROUP - v.len() % gecko::GROUP) % gecko::GROUP;
        let last = *v.last().unwrap();
        v.extend(std::iter::repeat(last).take(pad));
        for g in v.chunks_exact(gecko::GROUP) {
            let bases = &g[..8];
            for _ in bases {
                self.counts[8] += 1;
            }
            for r in 1..8 {
                let row = &g[r * 8..(r + 1) * 8];
                let w = row
                    .iter()
                    .zip(bases)
                    .map(|(&e, &b)| mag_width((e as i32 - b as i32).unsigned_abs()))
                    .max()
                    .unwrap();
                let per_val = if w <= 6 { w as usize + 1 } else { 8 };
                self.counts[per_val] += 8;
            }
        }
        self.total += v.len() as u64;
    }

    /// Cumulative fraction of values encoded in <= `bits` bits.
    pub fn cdf_at(&self, bits: usize) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let c: u64 = self.counts[..=bits.min(8)].iter().sum();
        c as f64 / self.total as f64
    }
}

/// Histogram over mantissa bitlengths 0..=23 (Fig. 8: BitChop's choices
/// over the batches of an epoch; Fig. 4 per-layer snapshots).
#[derive(Debug, Clone)]
pub struct BitlengthHistogram {
    pub counts: Vec<u64>,
}

impl Default for BitlengthHistogram {
    fn default() -> Self {
        Self {
            counts: vec![0; 24],
        }
    }
}

impl BitlengthHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, bits: u32) {
        self.counts[(bits as usize).min(23)] += 1;
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    pub fn mean(&self) -> f64 {
        let t = self.total();
        if t == 0 {
            return 0.0;
        }
        self.counts
            .iter()
            .enumerate()
            .map(|(b, &c)| b as f64 * c as f64)
            .sum::<f64>()
            / t as f64
    }
}

/// Footprint ledger split by datatype component — the Fig. 12 breakdown.
/// All fields are bits, accumulated over a training run or one pass.
#[derive(Debug, Clone, Copy, Default)]
pub struct ComponentBits {
    pub sign: f64,
    pub exponent: f64,
    pub mantissa: f64,
    pub metadata: f64,
}

impl ComponentBits {
    pub fn total(&self) -> f64 {
        self.sign + self.exponent + self.mantissa + self.metadata
    }

    pub fn add(&mut self, other: ComponentBits) {
        self.sign += other.sign;
        self.exponent += other.exponent;
        self.mantissa += other.mantissa;
        self.metadata += other.metadata;
    }

    pub fn scaled(&self, k: f64) -> ComponentBits {
        ComponentBits {
            sign: self.sign * k,
            exponent: self.exponent * k,
            mantissa: self.mantissa * k,
            metadata: self.metadata * k,
        }
    }
}

/// Weights + activations footprint for one configuration (Table I rows,
/// Fig. 12 bars).
#[derive(Debug, Clone, Copy, Default)]
pub struct Footprint {
    pub weights: ComponentBits,
    pub activations: ComponentBits,
}

impl Footprint {
    pub fn total(&self) -> f64 {
        self.weights.total() + self.activations.total()
    }

    pub fn add(&mut self, other: &Footprint) {
        self.weights.add(other.weights);
        self.activations.add(other.activations);
    }

    /// Footprint relative to a baseline (Table I's "% of FP32" column).
    pub fn relative_to(&self, base: &Footprint) -> f64 {
        self.total() / base.total()
    }
}

/// Per-tensor exponent-range statistics driving the exponent-side
/// adaptation policies (Quantum Exponent, BitWave): zero mass, non-zero
/// exponent extremes and mean, a signed-delta width histogram around the
/// tensor's estimated bias, and the measured Gecko cost of the observed
/// stream under both encoder modes (so policies can pick the cheaper
/// lossless exponent layout per tensor).
#[derive(Debug, Clone)]
pub struct ExpRangeStats {
    pub count: u64,
    pub zeros: u64,
    /// Non-zero biased-exponent extremes (255/0 sentinels when empty).
    pub min_exp: u8,
    pub max_exp: u8,
    /// Mean biased exponent over the non-zero values.
    pub mean_exp: f64,
    /// `widths[w]` = non-zero values whose delta from `bias` fits a signed
    /// field of exactly `w` bits (w in 1..=7); `widths[8]` counts values
    /// only a raw 8-bit absolute field covers.  Index 0 is unused.
    pub widths: [u64; 9],
    /// Estimated bias the width histogram was computed against.
    pub bias: u8,
    /// Measured Gecko encoded bits of the observed exponent stream.
    pub gecko_delta_bits: u64,
    /// Same stream under `Mode::FixedBias { bias, group: 8 }`.
    pub gecko_fixed_bits: u64,
}

impl Default for ExpRangeStats {
    fn default() -> Self {
        Self {
            count: 0,
            zeros: 0,
            min_exp: 255,
            max_exp: 0,
            mean_exp: 0.0,
            widths: [0; 9],
            bias: 127,
            gecko_delta_bits: 0,
            gecko_fixed_bits: 0,
        }
    }
}

/// Smallest signed-field width (1..=7) representing delta `d`
/// (covering `[-2^(w-1), 2^(w-1) - 1]`); 8 = raw absolute escape.
fn signed_width(d: i32) -> usize {
    for w in 1..=7usize {
        let half = 1i32 << (w - 1);
        if d >= -half && d < half {
            return w;
        }
    }
    8
}

impl ExpRangeStats {
    pub fn new() -> Self {
        Self::default()
    }

    /// Two-pass build from a biased-exponent stream: mean → bias, then the
    /// width histogram and both Gecko measurements against that bias.
    pub fn from_exponents(exps: &[u8]) -> Self {
        let mut zeros = 0u64;
        let mut min_exp = 255u8;
        let mut max_exp = 0u8;
        let mut sum = 0.0f64;
        for &e in exps {
            if e == 0 {
                zeros += 1;
            } else {
                sum += e as f64;
                min_exp = min_exp.min(e);
                max_exp = max_exp.max(e);
            }
        }
        let count = exps.len() as u64;
        let nz = count - zeros;
        let (mean_exp, bias) = if nz > 0 {
            let m = sum / nz as f64;
            (m, m.round().clamp(1.0, 254.0) as u8)
        } else {
            (0.0, 127u8)
        };
        let mut widths = [0u64; 9];
        for &e in exps {
            if e != 0 {
                widths[signed_width(e as i32 - bias as i32)] += 1;
            }
        }
        Self {
            count,
            zeros,
            min_exp,
            max_exp,
            mean_exp,
            widths,
            bias,
            gecko_delta_bits: gecko::encoded_bits(exps, gecko::Mode::Delta) as u64,
            gecko_fixed_bits: gecko::encoded_bits(
                exps,
                gecko::Mode::FixedBias { bias, group: 8 },
            ) as u64,
        }
    }

    pub fn from_vals(vals: &[f32]) -> Self {
        Self::from_exponents(&gecko::exponents(vals))
    }

    /// Fold another tensor/period's stats in (width histograms were built
    /// against each part's own bias — an approximation the policies accept,
    /// since biases of one tensor drift slowly between periods).
    pub fn merge(&mut self, other: &Self) {
        let nz_a = (self.count - self.zeros) as f64;
        let nz_b = (other.count - other.zeros) as f64;
        if nz_a + nz_b > 0.0 {
            self.mean_exp = (self.mean_exp * nz_a + other.mean_exp * nz_b) / (nz_a + nz_b);
            self.bias = self.mean_exp.round().clamp(1.0, 254.0) as u8;
        }
        self.count += other.count;
        self.zeros += other.zeros;
        self.min_exp = self.min_exp.min(other.min_exp);
        self.max_exp = self.max_exp.max(other.max_exp);
        for (a, b) in self.widths.iter_mut().zip(&other.widths) {
            *a += b;
        }
        self.gecko_delta_bits += other.gecko_delta_bits;
        self.gecko_fixed_bits += other.gecko_fixed_bits;
    }

    pub fn nonzeros(&self) -> u64 {
        self.count - self.zeros
    }

    /// Smallest exponent-field width `e` (1..=8) such that the fraction of
    /// non-zero values overflowing a signed e-bit delta field stays ≤ `tol`
    /// — the streaming overflow statistic Quantum Exponent descends to.
    pub fn needed_exp_bits(&self, tol: f64) -> u32 {
        let nz = self.nonzeros();
        if nz == 0 {
            return 1;
        }
        let budget = tol * nz as f64;
        let mut over = 0u64; // values needing more than `e` bits
        let mut need = 8u32;
        for e in (1..8usize).rev() {
            over += self.widths[e + 1];
            if over as f64 <= budget {
                need = e as u32;
            } else {
                break;
            }
        }
        need
    }

    /// The cheaper lossless Gecko layout for this stream (bits, mode).
    pub fn gecko_best(&self) -> (u64, gecko::Mode) {
        let fixed = gecko::Mode::FixedBias {
            bias: self.bias,
            group: 8,
        };
        if self.gecko_fixed_bits < self.gecko_delta_bits {
            (self.gecko_fixed_bits, fixed)
        } else {
            (self.gecko_delta_bits, gecko::Mode::Delta)
        }
    }
}

/// Simple streaming mean (Welford, no variance needed here).
#[derive(Debug, Clone, Copy, Default)]
pub struct Mean {
    pub n: u64,
    pub mean: f64,
}

impl Mean {
    pub fn add(&mut self, x: f64) {
        self.n += 1;
        self.mean += (x - self.mean) / self.n as f64;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exponent_histogram_counts() {
        let mut h = ExponentHistogram::new();
        h.add_vals(&[1.0, 2.0, 0.5, 1.5, 0.0]);
        assert_eq!(h.bins[127], 2); // 1.0, 1.5
        assert_eq!(h.bins[128], 1); // 2.0
        assert_eq!(h.bins[126], 1); // 0.5
        assert_eq!(h.bins[0], 1); // 0.0
        assert_eq!(h.total, 5);
        assert!((h.mass_near_bias(2) - 0.8).abs() < 1e-12);
    }

    #[test]
    fn histogram_merge() {
        let mut a = ExponentHistogram::new();
        a.add_vals(&[1.0]);
        let mut b = ExponentHistogram::new();
        b.add_vals(&[2.0, 4.0]);
        a.merge(&b);
        assert_eq!(a.total, 3);
        assert_eq!(a.bins[128], 1);
    }

    #[test]
    fn width_cdf_constant_stream() {
        // all-same exponents: 8 bases at 8 b, 56 deltas at 1 b per group
        let vals = vec![1.5f32; 64];
        let mut c = EncodedWidthCdf::new();
        c.add_vals(&vals);
        assert_eq!(c.counts[8], 8);
        assert_eq!(c.counts[1], 56);
        assert!((c.cdf_at(1) - 56.0 / 64.0).abs() < 1e-12);
        assert!((c.cdf_at(8) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn width_cdf_total_bits_consistent_with_gecko_payload() {
        // Sum over the CDF equals the gecko payload minus nothing: the CDF
        // charges exactly the per-value payload bits (metadata excluded).
        let vals: Vec<f32> = (0..640).map(|i| (i as f32 * 0.37).sin() * 8.0).collect();
        let mut c = EncodedWidthCdf::new();
        c.add_vals(&vals);
        let per_val_bits: u64 = c
            .counts
            .iter()
            .enumerate()
            .map(|(b, &n)| b as u64 * n)
            .sum();
        let enc = gecko::encode(&gecko::exponents(&vals), gecko::Mode::Delta);
        assert_eq!(per_val_bits as usize, enc.payload_bits);
    }

    #[test]
    fn bitlength_histogram_mean() {
        let mut h = BitlengthHistogram::new();
        h.add(2);
        h.add(4);
        assert_eq!(h.mean(), 3.0);
        assert_eq!(h.total(), 2);
    }

    #[test]
    fn exp_range_stats_widths_and_need() {
        // constant exponent stream: everything fits the 1-bit field
        let s = ExpRangeStats::from_exponents(&[127u8; 640]);
        assert_eq!(s.bias, 127);
        assert_eq!(s.widths[1], 640);
        assert_eq!(s.needed_exp_bits(0.0), 1);
        // a 1% tail at large deltas is ignored at tol 2% but not at 0
        let mut exps = vec![127u8; 990];
        exps.extend(vec![200u8; 10]);
        let s = ExpRangeStats::from_exponents(&exps);
        assert_eq!(s.needed_exp_bits(0.02), 1);
        assert_eq!(s.needed_exp_bits(0.0), 8);
    }

    #[test]
    fn exp_range_stats_zeros_excluded_from_widths() {
        let s = ExpRangeStats::from_exponents(&[0, 0, 124, 124, 125, 0]);
        assert_eq!(s.zeros, 3);
        assert_eq!(s.nonzeros(), 3);
        assert_eq!(s.min_exp, 124);
        assert_eq!(s.max_exp, 125);
        let wsum: u64 = s.widths.iter().sum();
        assert_eq!(wsum, 3);
    }

    #[test]
    fn exp_range_stats_gecko_measurements_match_encoder() {
        let exps: Vec<u8> = (0..512).map(|i| 120 + (i % 7) as u8).collect();
        let s = ExpRangeStats::from_exponents(&exps);
        assert_eq!(
            s.gecko_delta_bits as usize,
            gecko::encoded_bits(&exps, gecko::Mode::Delta)
        );
        let (best, _mode) = s.gecko_best();
        assert!(best <= s.gecko_delta_bits);
        assert!(best <= s.gecko_fixed_bits);
    }

    #[test]
    fn exp_range_stats_merge_accumulates() {
        let mut a = ExpRangeStats::from_exponents(&[127u8; 100]);
        let b = ExpRangeStats::from_exponents(&[130u8; 300]);
        a.merge(&b);
        assert_eq!(a.count, 400);
        assert_eq!(a.max_exp, 130);
        assert!((a.mean_exp - (127.0 * 100.0 + 130.0 * 300.0) / 400.0).abs() < 1e-9);
    }

    #[test]
    fn footprint_arithmetic() {
        let mut f = Footprint::default();
        f.activations.mantissa = 70.0;
        f.activations.exponent = 24.0;
        f.activations.sign = 6.0;
        let base = Footprint {
            weights: ComponentBits::default(),
            activations: ComponentBits {
                sign: 10.0,
                exponent: 80.0,
                mantissa: 110.0,
                metadata: 0.0,
            },
        };
        assert!((f.relative_to(&base) - 0.5).abs() < 1e-12);
    }
}
