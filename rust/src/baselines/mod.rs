//! Comparison compression schemes from the paper's evaluation (§VI-B).
//!
//! * **JS** — "a simple sparse Bfloat16 zero-compression method": one tag
//!   bit per value; non-zeros additionally store their 16-bit container.
//! * **GIST++** — the paper's tuned variant of Gist: ReLU→Pool activations
//!   store 1 bit/value; ReLU→Conv activations use sparse (zero-skipping)
//!   storage *only when that reduces footprint* (otherwise the dense
//!   container is kept, avoiding Gist's pathological inflation on dense
//!   tensors such as MobileNet V3's hswish activations).
//! * **Combined SFP** — Fig. 13's final bars: the JS zero-skip layered on
//!   top of the SFP-compressed payload (tag bit + compressed bits for
//!   non-zeros only).
//!
//! All functions return *bits* for one tensor; aggregation lives in
//! `stats::Footprint` and the table/figure drivers.

use crate::formats::Container;

/// How an activation tensor is consumed — decides which Gist encoding is
/// legal for it (§II, §VI-B).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ActKind {
    /// Produced by ReLU, feeds a max-pool: Gist stores 1 bit/value.
    ReluPool,
    /// Produced by ReLU, feeds a conv/fc: sparsity encoding applies.
    ReluConv,
    /// No ReLU in front (e.g. hswish in MobileNet V3): dense only.
    Dense,
}

/// Raw container footprint.
pub fn dense_bits(count: usize, container: Container) -> usize {
    count * container.total_bits() as usize
}

/// JS: 1 tag bit/value + container bits per non-zero.
pub fn js_bits(count: usize, zero_frac: f64, container: Container) -> usize {
    let nonzero = ((count as f64) * (1.0 - zero_frac)).round() as usize;
    count + nonzero * container.total_bits() as usize
}

/// Index metadata Gist's sparse activation format carries per non-zero
/// (value+offset pairs; JS's minimal 1-tag-bit scheme is this paper's own
/// leaner alternative, §VI-B).
pub const GIST_INDEX_BITS: usize = 4;

/// GIST++ for one activation tensor.
pub fn gist_pp_bits(
    count: usize,
    zero_frac: f64,
    kind: ActKind,
    container: Container,
) -> usize {
    match kind {
        ActKind::ReluPool => count, // 1 bit per value
        ActKind::ReluConv => {
            let nonzero = ((count as f64) * (1.0 - zero_frac)).round() as usize;
            let sparse = count + nonzero * (container.total_bits() as usize + GIST_INDEX_BITS);
            sparse.min(dense_bits(count, container)) // "++": only when it wins
        }
        ActKind::Dense => dense_bits(count, container),
    }
}

/// JS zero-skip layered over an SFP-compressed tensor: 1 tag bit/value,
/// compressed payload charged only for the non-zero fraction.
pub fn sfp_combined_bits(count: usize, zero_frac: f64, sfp_total_bits: usize) -> usize {
    count + ((sfp_total_bits as f64) * (1.0 - zero_frac)).round() as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn js_reduces_with_sparsity() {
        let dense = dense_bits(1000, Container::Bf16);
        assert!(js_bits(1000, 0.5, Container::Bf16) < dense);
        // no sparsity: JS pays the tag-bit overhead
        assert!(js_bits(1000, 0.0, Container::Bf16) > dense);
    }

    #[test]
    fn js_thirty_percent_at_paper_sparsity() {
        // §VI-B: "JS ... benefit[s] from the 30% reduction due to high
        // sparsity induced by ReLU" — at zero_frac ≈ 0.36 on BF16.
        let dense = dense_bits(10_000, Container::Bf16) as f64;
        let js = js_bits(10_000, 0.36, Container::Bf16) as f64;
        let reduction = 1.0 - js / dense;
        assert!((reduction - 0.30).abs() < 0.02, "reduction = {reduction}");
    }

    #[test]
    fn gist_pool_is_one_bit() {
        assert_eq!(
            gist_pp_bits(4096, 0.9, ActKind::ReluPool, Container::Bf16),
            4096
        );
    }

    #[test]
    fn gist_pp_never_inflates() {
        for zf in [0.0, 0.01, 0.3, 0.99] {
            for kind in [ActKind::ReluConv, ActKind::Dense] {
                assert!(
                    gist_pp_bits(5000, zf, kind, Container::Bf16)
                        <= dense_bits(5000, Container::Bf16)
                );
            }
        }
    }

    #[test]
    fn combined_beats_plain_sfp_when_sparse() {
        let sfp = 1000 * 9; // ~9 b/value compressed
        assert!(sfp_combined_bits(1000, 0.5, sfp) < sfp);
        // ...but not when dense (tag bits cost)
        assert!(sfp_combined_bits(1000, 0.0, sfp) > sfp);
    }
}
