//! Analytical accelerator + DRAM model behind Table II (§VI-C).
//!
//! The paper models "an accelerator with 8K units each capable of 4 MACs
//! per cycle and a 500 MHz clock for a peak compute bandwidth of 16 TFLOPS
//! ... 8 channels of LPDDR4-3200 DRAM memory and 32 MB of on-chip buffers",
//! with DRAMSIM3 timing/energy, CACTI buffers, and synthesized Gecko
//! codecs.  None of those tools are available here; per DESIGN.md §2 the
//! substitution is a consistent linear event-count model: a roofline
//! `time = max(compute, memory, codec)` per layer per pass and an energy
//! table multiplied into the same event counts.  The substitution preserves
//! the quantities the table actually reports — *ratios* between formats —
//! because all formats share the same counts and constants.
//!
//! Dataflow (§VI-C): forward runs layer-first per batch, reading weights
//! once per layer per batch; backward uses the 32 MB buffer for
//! mini-batching, re-reading weights once per mini-batch chunk; gradients
//! are produced and consumed on-chip.

use crate::traces::{LayerTrace, NetworkTrace};


/// Energy/time constants of the modelled accelerator.
///
/// Calibration note (DESIGN.md §2): Table II's published numbers pin the
/// paper's (unpublished) energy split — BF16's *exactly* 2.00× gain on both
/// networks and SFP_QM's 6.12× at a 14.7% footprint are only consistent
/// with DRAM ≈ 96–99% of baseline energy and BF16 MACs at half the FP32
/// MAC energy.  The defaults below reproduce that split: system-level
/// LPDDR4 energy at poor row locality (~40 pJ/b incl. controller + PHY)
/// against an aggressively energy-optimized 65 nm MAC array.  Absolute
/// joules are not comparable to silicon; ratios between formats are.
#[derive(Debug, Clone, Copy)]
pub struct AccelConfig {
    /// MAC units × MACs/unit/cycle.
    pub macs_per_cycle: f64,
    /// Core clock (Hz).
    pub freq: f64,
    /// Aggregate DRAM bandwidth (bits/s): 8 × LPDDR4-3200 x16.
    pub dram_bw_bits: f64,
    /// On-chip buffer for backward-pass mini-batching (bytes).
    pub buffer_bytes: f64,
    /// DRAM energy per bit moved (pJ).
    pub dram_pj_per_bit: f64,
    /// On-chip SRAM energy per bit (pJ); every DRAM bit also crosses SRAM.
    pub sram_pj_per_bit: f64,
    /// FP32 MAC energy (pJ); BF16 MACs cost half (see calibration note).
    pub mac_fp32_pj: f64,
    /// Gecko/SFP codec energy per bit (pJ) — synthesis-scale, tiny.
    pub codec_pj_per_bit: f64,
    /// Codec throughput: values/cycle/channel × channels × 2 units.
    pub codec_vals_per_cycle: f64,
}

impl Default for AccelConfig {
    fn default() -> Self {
        Self {
            macs_per_cycle: 8192.0 * 4.0,
            freq: 500e6,
            dram_bw_bits: 8.0 * 6.4e9 * 8.0, // 8 ch × 6.4 GB/s × 8 b
            buffer_bytes: 32.0 * 1024.0 * 1024.0,
            dram_pj_per_bit: 40.0,
            sram_pj_per_bit: 0.6,
            mac_fp32_pj: 0.06,
            codec_pj_per_bit: 0.05,
            codec_vals_per_cycle: 8.0 * 2.0 * 8.0,
        }
    }
}

impl AccelConfig {
    /// Peak MAC throughput (MACs/s) — 16.4 T for the default config.
    pub fn peak_macs(&self) -> f64 {
        self.macs_per_cycle * self.freq
    }
}

/// The compute datatype (decides MAC energy and on-chip word width).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ComputeType {
    Fp32,
    Bf16,
}

impl ComputeType {
    fn mac_pj(self, cfg: &AccelConfig) -> f64 {
        match self {
            ComputeType::Fp32 => cfg.mac_fp32_pj,
            ComputeType::Bf16 => cfg.mac_fp32_pj / 2.0,
        }
    }
}

/// Per-layer footprint (bits) the memory system actually moves — produced
/// by the footprint models (raw containers, SFP, baselines).
#[derive(Debug, Clone, Copy)]
pub struct LayerBits {
    /// One copy of the layer's weights.
    pub weight: f64,
    /// The layer's stashed output activations for the whole batch.
    pub act: f64,
}

/// Time/energy totals for one training pass (fwd+bwd) of one batch.
#[derive(Debug, Clone, Copy, Default)]
pub struct PassStats {
    pub time_s: f64,
    pub energy_j: f64,
    pub dram_bits: f64,
    pub macs: f64,
    /// Layers whose time is memory-bound (fwd+bwd counted separately).
    pub memory_bound_layers: usize,
    pub total_layer_passes: usize,
}

impl PassStats {
    pub fn add(&mut self, o: &PassStats) {
        self.time_s += o.time_s;
        self.energy_j += o.energy_j;
        self.dram_bits += o.dram_bits;
        self.macs += o.macs;
        self.memory_bound_layers += o.memory_bound_layers;
        self.total_layer_passes += o.total_layer_passes;
    }
}

/// Simulate one training pass of `net` at `batch`, with per-layer stored
/// footprints given by `bits_of` (which encodes the compression variant).
pub fn simulate_pass(
    cfg: &AccelConfig,
    net: &NetworkTrace,
    batch: usize,
    compute: ComputeType,
    bits_of: &dyn Fn(&LayerTrace) -> LayerBits,
) -> PassStats {
    let mut out = PassStats::default();
    let uncompressed_word = match compute {
        ComputeType::Fp32 => 32.0,
        ComputeType::Bf16 => 16.0,
    };

    for layer in &net.layers {
        let b = bits_of(layer);
        // effective MACs at the layer's achievable array utilization
        let macs_f = layer.macs as f64 * batch as f64 / layer.compute_util.max(1e-3);

        // ---- forward: read W once, stream in/out activations.  The input
        // activation bits are the previous layer's output; charging each
        // layer its own output once for write and once for read (by the
        // next layer) double-counts exactly like hardware does (one write
        // + one read per stashed tensor crossing DRAM).
        let fwd_bits = b.weight + 2.0 * b.act;
        out.add(&layer_pass(cfg, macs_f, fwd_bits, b.act, compute));

        // ---- backward: 2× the MACs (weight grad + input grad); reads the
        // stashed activations once; weights re-read per mini-batch chunk;
        // weight update written once.  Gradients stay on-chip (§VI-C).
        let act_bytes_per_sample = layer.act_elems as f64 * uncompressed_word / 8.0;
        let chunk = (cfg.buffer_bytes / (2.0 * act_bytes_per_sample))
            .floor()
            .clamp(1.0, batch as f64);
        let chunks = (batch as f64 / chunk).ceil();
        let bwd_bits = b.act + chunks * b.weight + b.weight;
        out.add(&layer_pass(cfg, 2.0 * macs_f, bwd_bits, b.act, compute));
    }
    out
}

fn layer_pass(
    cfg: &AccelConfig,
    macs: f64,
    dram_bits: f64,
    codec_value_bits: f64,
    compute: ComputeType,
) -> PassStats {
    let t_compute = macs / cfg.peak_macs();
    let t_memory = dram_bits / cfg.dram_bw_bits;
    // codec: values crossing the compressors; bits/32 approximates values
    let t_codec = (codec_value_bits / 32.0) / (cfg.codec_vals_per_cycle * cfg.freq);
    let time = t_compute.max(t_memory).max(t_codec);

    let energy_pj = dram_bits * cfg.dram_pj_per_bit
        + dram_bits * cfg.sram_pj_per_bit
        + macs * compute.mac_pj(cfg)
        + codec_value_bits * cfg.codec_pj_per_bit;

    PassStats {
        time_s: time,
        energy_j: energy_pj * 1e-12,
        dram_bits,
        macs,
        memory_bound_layers: usize::from(t_memory >= t_compute),
        total_layer_passes: 1,
    }
}

/// [`simulate_pass`] with explicit per-layer footprints — the entry point
/// for *measured* bits (e.g. the stash ledger's stored-bytes per layer)
/// rather than a footprint-model closure.  `bits[i]` is consumed for
/// `net.layers[i]`; this leans on `simulate_pass` requesting `bits_of`
/// exactly once per layer in iteration order, and panics (rather than
/// silently misattributing) if that contract ever changes.
pub fn simulate_pass_with_bits(
    cfg: &AccelConfig,
    net: &NetworkTrace,
    batch: usize,
    compute: ComputeType,
    bits: &[LayerBits],
) -> PassStats {
    assert_eq!(bits.len(), net.layers.len());
    let idx = std::cell::Cell::new(0usize);
    simulate_pass(cfg, net, batch, compute, &move |_| {
        let i = idx.get();
        idx.set(i + 1);
        *bits
            .get(i)
            .expect("simulate_pass must request bits once per layer, in order")
    })
}

/// Per-layer stored bits induced by an adaptation policy's
/// [`NetworkPlan`](crate::policy::NetworkPlan) — the coupling that lets
/// live container plans drive the Table II machinery
/// ([`simulate_pass_with_bits`]) and the sweep footprints directly.
pub fn layer_bits_from_plans(
    net: &NetworkTrace,
    plan: &crate::policy::NetworkPlan,
    batch: usize,
    container: crate::formats::Container,
) -> Vec<LayerBits> {
    assert_eq!(plan.acts.len(), net.layers.len());
    assert_eq!(plan.weights.len(), net.layers.len());
    net.layers
        .iter()
        .enumerate()
        .map(|(i, l)| LayerBits {
            weight: l.weight_elems as f64 * plan.weights[i].bits_per_value(container),
            act: (l.act_elems * batch) as f64 * plan.acts[i].bits_per_value(container),
        })
        .collect()
}

/// Speedup and energy-efficiency gain of `variant` over `baseline`
/// (Table II cells).
pub fn gains(baseline: &PassStats, variant: &PassStats) -> (f64, f64) {
    (
        baseline.time_s / variant.time_s,
        baseline.energy_j / variant.energy_j,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::traces::resnet18;

    fn raw_bits(word: f64, batch: usize) -> impl Fn(&LayerTrace) -> LayerBits {
        move |l: &LayerTrace| LayerBits {
            weight: l.weight_elems as f64 * word,
            act: l.act_elems as f64 * word * batch as f64,
        }
    }

    #[test]
    fn peak_is_16_tflops() {
        let cfg = AccelConfig::default();
        assert!((cfg.peak_macs() - 16.384e12).abs() < 1e9);
    }

    #[test]
    fn bf16_halves_traffic_not_time() {
        // §VI-C: BF16 gives < 2× speedup because layers go compute-bound.
        let cfg = AccelConfig::default();
        let net = resnet18();
        let fp32 = simulate_pass(&cfg, &net, 256, ComputeType::Fp32, &raw_bits(32.0, 256));
        let bf16 = simulate_pass(&cfg, &net, 256, ComputeType::Bf16, &raw_bits(16.0, 256));
        let (speed, energy) = gains(&fp32, &bf16);
        assert!(speed > 1.2 && speed < 2.0, "bf16 speedup {speed}");
        // the calibrated split makes BF16 land at the paper's exact 2.00×
        assert!((energy - 2.0).abs() < 0.05, "bf16 energy {energy}");
        // >= 2×: halving containers also fits more samples per backward
        // mini-batch chunk, saving weight re-reads on top of the 2×.
        let ratio = fp32.dram_bits / bf16.dram_bits;
        assert!((2.0..2.2).contains(&ratio), "traffic ratio {ratio}");
    }

    #[test]
    fn less_traffic_never_slower() {
        let cfg = AccelConfig::default();
        let net = resnet18();
        let hi = simulate_pass(&cfg, &net, 64, ComputeType::Fp32, &raw_bits(32.0, 64));
        let lo = simulate_pass(&cfg, &net, 64, ComputeType::Fp32, &raw_bits(8.0, 64));
        assert!(lo.time_s <= hi.time_s);
        assert!(lo.energy_j < hi.energy_j);
    }

    #[test]
    fn compute_bound_floor() {
        // With near-zero traffic, time approaches the compute roofline and
        // further compression stops helping (the paper's §VI-C observation).
        let cfg = AccelConfig::default();
        let net = resnet18();
        let tiny = simulate_pass(&cfg, &net, 256, ComputeType::Fp32, &raw_bits(0.5, 256));
        let tinier = simulate_pass(&cfg, &net, 256, ComputeType::Fp32, &raw_bits(0.25, 256));
        let (speed, _) = gains(&tiny, &tinier);
        assert!(speed < 1.05, "already compute bound, speed {speed}");
        let compute_time: f64 =
            3.0 * net.total_macs_per_sample() as f64 * 256.0 / cfg.peak_macs();
        assert!((tiny.time_s - compute_time) / compute_time < 0.25);
    }

    #[test]
    fn dram_energy_dominates_at_fp32() {
        let cfg = AccelConfig::default();
        let net = resnet18();
        let s = simulate_pass(&cfg, &net, 256, ComputeType::Fp32, &raw_bits(32.0, 256));
        let dram_j = s.dram_bits * (cfg.dram_pj_per_bit + cfg.sram_pj_per_bit) * 1e-12;
        // §VI-C: "energy consumption of DRAM accesses greatly outclasses
        // that of computation" — the calibrated split puts DRAM > 90%.
        assert!(dram_j / s.energy_j > 0.9, "dram share {}", dram_j / s.energy_j);
    }

    #[test]
    fn layer_bits_from_plans_matches_hand_count() {
        use crate::formats::Container;
        use crate::policy::NetworkPlan;
        let net = resnet18();
        let plan = NetworkPlan::full(Container::Fp32, net.layers.len());
        let bits = layer_bits_from_plans(&net, &plan, 4, Container::Fp32);
        for (b, l) in bits.iter().zip(&net.layers) {
            assert!((b.weight - 32.0 * l.weight_elems as f64).abs() < 1e-6);
            assert!((b.act - 32.0 * (l.act_elems * 4) as f64).abs() < 1e-6);
        }
    }

    #[test]
    fn minibatch_chunking_rereads_weights() {
        // A layer whose batch activations exceed the buffer must re-read
        // weights; verify traffic grows vs. an infinite buffer.
        let net = resnet18();
        let small = AccelConfig {
            buffer_bytes: 4.0 * 1024.0 * 1024.0,
            ..Default::default()
        };
        let big = AccelConfig {
            buffer_bytes: 1e12,
            ..Default::default()
        };
        let a = simulate_pass(&small, &net, 256, ComputeType::Fp32, &raw_bits(32.0, 256));
        let b = simulate_pass(&big, &net, 256, ComputeType::Fp32, &raw_bits(32.0, 256));
        assert!(a.dram_bits > b.dram_bits);
    }
}
