//! Quantum Exponent behind the [`BitPolicy`] trait (§IV): learned
//! per-layer *exponent* bitlengths.
//!
//! The paper learns exponent bitlengths with the same gradient machinery
//! as Quantum Mantissa; on the coordinator side that learner reduces to a
//! γ-paced descent of each tensor's exponent field width toward the
//! smallest width whose overflow (saturation) probability stays below a
//! tolerance — the quantity the streaming max-exponent/overflow statistics
//! ([`crate::stats::ExpRangeStats`]) measure directly.  AdaptivFloat's
//! per-tensor exponent bias and Flexpoint's range tracking are the same
//! signal; here the bias is the tensor's mean biased exponent and the
//! width descends under the shared [`GammaSchedule`], freezing ceiled in
//! the round-up endgame exactly like the mantissa learner.
//!
//! Each plan also carries the cheaper lossless Gecko layout for the
//! tensor's exponent stream (delta vs learned-fixed-bias mode), so the
//! stash stores what the policy learned and Gecko-on-exponents improves
//! the fixed-width footprint further (the paper's 4.74× → 5.64× step).

use super::schedule::GammaSchedule;
use super::{
    jnums_f32, modes_from_json, modes_to_json, state_bool, state_vec_f32, BitPolicy,
    ContainerPlan, NetworkPlan, StepSignals,
};
use crate::formats::Container;
use crate::gecko::Mode;
use crate::stats::ExpRangeStats;
use crate::util::json::Json;
use anyhow::Result;
use std::collections::BTreeMap;

/// Saturating a stashed tensor corrupts the values the backward pass
/// restores, so the learned width keeps overflow essentially impossible.
const OVERFLOW_TOL: f64 = 1e-5;

pub struct QuantumExponent {
    sched: GammaSchedule,
    container: Container,
    nonneg_act: Vec<bool>,
    /// Learned fractional exponent bitlengths per layer.
    e_a: Vec<f32>,
    e_w: Vec<f32>,
    /// Current required width per tensor (the overflow-tolerance floor the
    /// learned width descends to; widening ranges raise it immediately).
    req_a: Vec<f32>,
    req_w: Vec<f32>,
    /// Chosen lossless Gecko layout per tensor.
    mode_a: Vec<Mode>,
    mode_w: Vec<Mode>,
    /// Descent per unit lr_n·γ (run-length scaled, like the QM surrogate).
    scale: f32,
    rounded: bool,
    /// Last *stored* (ceil-clamped) widths reported to the flight
    /// recorder — observational only, outside checkpoint/restore.
    emitted_a: Vec<u32>,
    emitted_w: Vec<u32>,
}

impl QuantumExponent {
    pub fn new(
        container: Container,
        epochs: usize,
        steps_per_epoch: usize,
        nonneg_act: Vec<bool>,
    ) -> Self {
        let layers = nonneg_act.len();
        let sched = GammaSchedule::paper_like(epochs);
        let stage1_epochs = ((epochs as f64 * sched.stage_frac[1]).round() as usize).max(1);
        let stage1_obs = (stage1_epochs * steps_per_epoch.max(1)) as f32;
        // cover the full 8-bit range within 80% of the first γ stage
        let scale = 8.0 / (0.8 * stage1_obs * sched.lr_n * sched.gammas[0]);
        Self {
            sched,
            container,
            nonneg_act,
            e_a: vec![8.0; layers],
            e_w: vec![8.0; layers],
            req_a: vec![8.0; layers],
            req_w: vec![8.0; layers],
            mode_a: vec![Mode::Delta; layers],
            mode_w: vec![Mode::Delta; layers],
            scale,
            rounded: false,
            emitted_a: vec![8; layers],
            emitted_w: vec![8; layers],
        }
    }

    fn make_plan(&self) -> NetworkPlan {
        let mant = self.container.mant_bits() as f32;
        let acts = self
            .e_a
            .iter()
            .zip(&self.mode_a)
            .zip(&self.nonneg_act)
            .map(|((&e, &mode), &nonneg)| {
                ContainerPlan::width(mant, Self::stored_width(e), mode, nonneg)
            })
            .collect();
        let weights = self
            .e_w
            .iter()
            .zip(&self.mode_w)
            .map(|(&e, &mode)| ContainerPlan::width(mant, Self::stored_width(e), mode, false))
            .collect();
        NetworkPlan { acts, weights }
    }

    /// One tensor's update: requirement floor from the streaming stats,
    /// γ-paced descent of the learned width, storage-mode refresh.
    /// Returns `true` when the overflow floor forced the width up.
    fn update_one(
        e: &mut f32,
        req: &mut f32,
        mode: &mut Mode,
        stats: &ExpRangeStats,
        step: f32,
        frozen: bool,
    ) -> bool {
        if stats.count > 0 {
            *req = stats.needed_exp_bits(OVERFLOW_TOL) as f32;
            *mode = stats.gecko_best().1;
        }
        if *req > *e {
            // range violation: saturation would corrupt restored tensors,
            // so recovery overrides even the frozen endgame
            *e = *req;
            true
        } else {
            if !frozen {
                *e = (*e - step).max(*req);
            }
            false
        }
    }

    /// The integer width a learned value actually stores (the plan's).
    fn stored_width(e: f32) -> u32 {
        (e.ceil() as u32).clamp(1, 8)
    }
}

impl BitPolicy for QuantumExponent {
    fn name(&self) -> &'static str {
        "qe"
    }

    fn observe(&mut self, sig: &StepSignals) -> NetworkPlan {
        let (gamma, lr_n, _) = self.sched.hyper(sig.epoch);
        let in_roundup = self.sched.in_roundup(sig.epoch);
        let step = lr_n * gamma * self.scale;
        for (i, (e, req)) in self.e_a.iter_mut().zip(self.req_a.iter_mut()).enumerate() {
            if let Some(stats) = sig.act_stats.get(i) {
                let clamped =
                    Self::update_one(e, req, &mut self.mode_a[i], stats, step, in_roundup);
                let width = Self::stored_width(*e);
                if width != self.emitted_a[i] {
                    let trigger = if clamped {
                        "qe_overflow_floor"
                    } else {
                        "qe_gradient_step"
                    };
                    crate::obs::events::bit_change(
                        "qe",
                        trigger,
                        "act",
                        "exp",
                        Some(i),
                        sig.epoch,
                        sig.step,
                        self.emitted_a[i] as f64,
                        width as f64,
                    );
                    self.emitted_a[i] = width;
                }
            }
        }
        for (i, (e, req)) in self.e_w.iter_mut().zip(self.req_w.iter_mut()).enumerate() {
            if let Some(stats) = sig.weight_stats.get(i) {
                let clamped =
                    Self::update_one(e, req, &mut self.mode_w[i], stats, step, in_roundup);
                let width = Self::stored_width(*e);
                if width != self.emitted_w[i] {
                    let trigger = if clamped {
                        "qe_overflow_floor"
                    } else {
                        "qe_gradient_step"
                    };
                    crate::obs::events::bit_change(
                        "qe",
                        trigger,
                        "weight",
                        "exp",
                        Some(i),
                        sig.epoch,
                        sig.step,
                        self.emitted_w[i] as f64,
                        width as f64,
                    );
                    self.emitted_w[i] = width;
                }
            }
        }
        if in_roundup && !self.rounded {
            for e in self.e_a.iter_mut().chain(self.e_w.iter_mut()) {
                *e = e.ceil().clamp(1.0, 8.0);
            }
            self.rounded = true;
        }
        self.make_plan()
    }

    fn plan(&self) -> NetworkPlan {
        self.make_plan()
    }

    fn checkpoint(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("e_a".to_string(), jnums_f32(&self.e_a));
        o.insert("e_w".to_string(), jnums_f32(&self.e_w));
        o.insert("req_a".to_string(), jnums_f32(&self.req_a));
        o.insert("req_w".to_string(), jnums_f32(&self.req_w));
        o.insert("mode_a".to_string(), modes_to_json(&self.mode_a));
        o.insert("mode_w".to_string(), modes_to_json(&self.mode_w));
        o.insert("rounded".to_string(), Json::Bool(self.rounded));
        Json::Obj(o)
    }

    fn restore(&mut self, state: &Json) -> Result<()> {
        self.e_a = state_vec_f32(state, "e_a")?;
        self.e_w = state_vec_f32(state, "e_w")?;
        self.req_a = state_vec_f32(state, "req_a")?;
        self.req_w = state_vec_f32(state, "req_w")?;
        self.mode_a = modes_from_json(state, "mode_a")?;
        self.mode_w = modes_from_json(state, "mode_w")?;
        self.rounded = state_bool(state, "rounded")?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::traces::ValueModel;

    fn stats_for(model: ValueModel, seed: u64) -> ExpRangeStats {
        ExpRangeStats::from_exponents(&model.sample_exponents(16 * 1024, seed))
    }

    #[test]
    fn learns_narrow_widths_on_trained_streams() {
        let act = vec![stats_for(ValueModel::relu_act(), 7)];
        let wgt = vec![stats_for(ValueModel::weights(), 9)];
        let mut p = QuantumExponent::new(Container::Bf16, 6, 30, vec![true]);
        let mut step = 0;
        for epoch in 0..6 {
            for _ in 0..30 {
                p.observe(&StepSignals {
                    epoch,
                    step,
                    loss: 1.0,
                    lr_changed: false,
                    learned_n_a: None,
                    learned_n_w: None,
                    act_stats: &act,
                    weight_stats: &wgt,
                });
                step += 1;
            }
        }
        let plan = p.plan();
        // §IV: "3 or 4 exponent bits" — trained-like streams land there
        // (the tight-tolerance activation tail needs one more).
        assert!(
            (3..=5).contains(&plan.acts[0].exp_bits()),
            "act exp bits {}",
            plan.acts[0].exp_bits()
        );
        assert!(
            (3..=4).contains(&plan.weights[0].exp_bits()),
            "weight exp bits {}",
            plan.weights[0].exp_bits()
        );
        // learned widths must cover the observed range at the tolerance
        assert!(plan.acts[0].exp_bits() >= act[0].needed_exp_bits(1e-5));
        assert!(plan.weights[0].exp_bits() >= wgt[0].needed_exp_bits(1e-5));
    }

    #[test]
    fn no_stats_means_full_width() {
        let mut p = QuantumExponent::new(Container::Bf16, 6, 30, vec![false; 2]);
        for s in 0..60 {
            p.observe(&StepSignals {
                epoch: s / 30,
                step: s,
                loss: 1.0,
                lr_changed: false,
                learned_n_a: None,
                learned_n_w: None,
                act_stats: &[],
                weight_stats: &[],
            });
        }
        assert!(p.plan().acts.iter().all(|c| c.exp_bits() == 8));
    }

    #[test]
    fn widening_range_recovers_immediately() {
        let narrow = vec![ExpRangeStats::from_exponents(&[124u8; 4096])];
        let wgt = vec![ExpRangeStats::from_exponents(&[121u8; 4096])];
        let mut p = QuantumExponent::new(Container::Bf16, 6, 30, vec![false]);
        let sig = |epoch, step, a: &'_ [ExpRangeStats], w: &'_ [ExpRangeStats]| StepSignals {
            epoch,
            step,
            loss: 1.0,
            lr_changed: false,
            learned_n_a: None,
            learned_n_w: None,
            act_stats: a,
            weight_stats: w,
        };
        // epochs 0..3: adaptation phase, constant stream → width 1
        for s in 0..100 {
            p.observe(&sig(s / 30, s, &narrow, &wgt));
        }
        let before = p.plan().acts[0].exp_bits();
        assert!(before <= 2, "constant stream narrows hard: {before}");
        // the range blows up in the frozen endgame: widths must jump, not
        // drift — saturating stashed tensors is never acceptable
        let mut wide_exps = vec![124u8; 4096];
        for (k, e) in wide_exps.iter_mut().enumerate() {
            if k % 3 == 0 {
                *e = 90;
            }
        }
        let wide = vec![ExpRangeStats::from_exponents(&wide_exps)];
        let plan = p.observe(&sig(5, 210, &wide, &wgt));
        assert!(
            plan.acts[0].exp_bits() >= wide[0].needed_exp_bits(1e-5),
            "overflow guard must react in one period"
        );
    }

    #[test]
    fn width_changes_emit_events_with_overflow_floor_trigger() {
        crate::obs::events::capture_begin();
        let narrow = vec![ExpRangeStats::from_exponents(&[124u8; 4096])];
        let wgt = vec![ExpRangeStats::from_exponents(&[121u8; 4096])];
        let mut p = QuantumExponent::new(Container::Bf16, 6, 30, vec![false]);
        let sig = |epoch, step, a: &'_ [ExpRangeStats], w: &'_ [ExpRangeStats]| StepSignals {
            epoch,
            step,
            loss: 1.0,
            lr_changed: false,
            learned_n_a: None,
            learned_n_w: None,
            act_stats: a,
            weight_stats: w,
        };
        for s in 0..100 {
            p.observe(&sig(s / 30, s, &narrow, &wgt));
        }
        let mut wide_exps = vec![124u8; 4096];
        for (k, e) in wide_exps.iter_mut().enumerate() {
            if k % 3 == 0 {
                *e = 90;
            }
        }
        let wide = vec![ExpRangeStats::from_exponents(&wide_exps)];
        p.observe(&sig(5, 210, &wide, &wgt));
        let events = crate::obs::events::capture_end();
        let qe: Vec<_> = events.iter().filter(|e| e.source == "qe").collect();
        assert!(!qe.is_empty());
        assert!(qe.iter().all(|e| e.component.as_deref() == Some("exp")));
        // the descent crossed integer widths on the way down...
        assert!(qe.iter().any(|e| e.trigger == "qe_gradient_step" && e.to < e.from));
        // ...and the blown-up range fired the overflow floor on the way up
        assert!(qe.iter().any(|e| e.trigger == "qe_overflow_floor" && e.to > e.from));
    }

    #[test]
    fn checkpoint_roundtrip_stable() {
        let act = vec![stats_for(ValueModel::relu_act(), 3)];
        let wgt = vec![stats_for(ValueModel::weights(), 5)];
        let mut p = QuantumExponent::new(Container::Bf16, 9, 20, vec![true]);
        for s in 0..50 {
            p.observe(&StepSignals {
                epoch: s / 20,
                step: s,
                loss: 1.0,
                lr_changed: false,
                learned_n_a: None,
                learned_n_w: None,
                act_stats: &act,
                weight_stats: &wgt,
            });
        }
        let ck = p.checkpoint();
        let mut q = QuantumExponent::new(Container::Bf16, 9, 20, vec![true]);
        q.restore(&ck).unwrap();
        assert_eq!(ck, q.checkpoint());
        assert_eq!(p.plan(), q.plan());
    }
}
