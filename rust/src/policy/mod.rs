//! Unified adaptation-policy engine: every method that decides *how many
//! bits each tensor gets* — Quantum Mantissa, Quantum Exponent, BitWave,
//! BitChop, fixed baselines — implements one [`BitPolicy`] trait and emits
//! per-tensor [`ContainerPlan`]s that the rest of the system consumes:
//!
//! ```text
//!  StepSignals ──▶ BitPolicy::observe ──▶ NetworkPlan (ContainerPlan per tensor)
//!  (loss, learned                           │
//!   bitlengths,                             ├─▶ Trainer: n_w/n_a step knobs
//!   exponent-range                          ├─▶ stash: ContainerMeta per tensor
//!   stats)                                  ├─▶ hwsim: bits per layer pass
//!                                           └─▶ report: bitlength trajectories
//! ```
//!
//! A [`ContainerPlan`] carries the three axes the paper adapts (§IV):
//! fractional mantissa bitlength (ceiled for storage), an exponent
//! [`ExponentLayout`] (per-value learned width + lossless Gecko storage
//! mode, an AdaptivFloat per-tensor bias window, or a Flexpoint
//! block-shared exponent), and sign elision.  Policies checkpoint/restore
//! their full adaptation state as JSON ([`BitPolicy::checkpoint`]) so a
//! mid-run restore continues with identical plans.
//!
//! Implementations:
//! * [`qm::QuantumMantissa`] — §IV-A learned per-layer mantissa bitlengths
//!   (adopts the compiled step's in-graph learner in e2e runs; a surrogate
//!   descent stands in for it on the trace models).
//! * [`qe::QuantumExponent`] — §IV learned per-layer exponent bitlengths,
//!   driven by streaming max-exponent/overflow statistics
//!   ([`crate::stats::ExpRangeStats`]), sharing the γ-schedule machinery
//!   ([`schedule::GammaSchedule`]).
//! * [`bitwave::BitWave`] — the loss-EMA controller extended to drive
//!   exponent *and* mantissa network-wide (Eq. 8/9 semantics preserved via
//!   the embedded [`crate::coordinator::BitChop`]).
//! * [`adaptivfloat::AdaptivFloatPolicy`] — AdaptivFloat (PAPERS.md): a
//!   per-tensor exponent *bias window* fitted post-hoc from the streaming
//!   range statistics, emitted as [`ExponentLayout::Bias`] plans.
//! * [`Composite`] — mantissa bits from one policy, exponent layout from
//!   another: QM + QE is the paper's headline pair.
//! * [`FixedPolicy`] — static baselines: full containers (FP32/BF16) and
//!   the cross-paper presets (fp8 `Bias` window, Flexpoint `BlockShared`).
//!
//! The [`sweep`] module runs each policy over the ImageNet-scale trace
//! models (`repro policy`), emitting per-epoch bitlength trajectories and
//! end-of-run footprints with and without Gecko on the exponent streams.

pub mod adaptivfloat;
pub mod bitwave;
pub mod qe;
pub mod qm;
pub mod schedule;
pub mod sweep;

pub use adaptivfloat::AdaptivFloatPolicy;
pub use bitwave::{BitChopPolicy, BitWave};
pub use qe::QuantumExponent;
pub use qm::QuantumMantissa;
pub use schedule::GammaSchedule;
pub use sweep::{PolicyKind, PolicyRunResult, SweepConfig};

use crate::formats::{Container, ExponentLayout};
use crate::gecko::Mode;
use crate::stash::ContainerMeta;
use crate::stats::ExpRangeStats;
use crate::util::json::Json;
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;

/// One tensor's container decision for the upcoming period.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ContainerPlan {
    /// Fractional mantissa bitlength (drives the stochastic train-step
    /// quantizer); storage keeps `ceil(mant)` bits.
    pub mant: f32,
    /// How the exponent axis is shaped and stored (learned per-value
    /// width, AdaptivFloat bias window, or Flexpoint block-shared).
    pub layout: ExponentLayout,
    /// Elide value signs (valid only for known-non-negative tensors, §IV-D).
    pub elide_sign: bool,
}

impl ContainerPlan {
    /// Full-precision plan for `container` (the baseline / initial state).
    pub fn full(container: Container) -> Self {
        Self {
            mant: container.mant_bits() as f32,
            layout: ExponentLayout::default(),
            elide_sign: false,
        }
    }

    /// A per-value learned-width plan — the paper's historical shape.
    pub fn width(mant: f32, exp_bits: u32, exp_mode: Mode, elide_sign: bool) -> Self {
        Self {
            mant,
            layout: ExponentLayout::Width {
                bits: exp_bits,
                mode: exp_mode,
            },
            elide_sign,
        }
    }

    /// Integer mantissa bits the container actually stores.
    pub fn store_mant_bits(&self) -> u32 {
        self.mant.max(0.0).ceil() as u32
    }

    /// Stored exponent-field width in bits, clamped to the container's
    /// exponent field (a plan can never charge more than the 8 bits the
    /// container has).
    pub fn exp_bits(&self) -> u32 {
        self.layout.field_bits()
    }

    /// Amortized exponent bits per value (differs from [`Self::exp_bits`]
    /// only for block-shared layouts).
    pub fn exp_bits_per_value(&self) -> f64 {
        self.layout.exponent_bits_per_value()
    }

    /// The lossless Gecko storage mode for per-value exponent streams.
    pub fn exp_mode(&self) -> Mode {
        self.layout.gecko_mode()
    }

    /// Plan-accounted stored bits per value: sign + amortized exponent
    /// (field width clamped to the container's, shared exponents divided
    /// across the block) + ceiled mantissa (+ the explicit leading one a
    /// block-shared significand carries).  This is the *pre-Gecko* number
    /// (the paper's QM+QE / BitWave footprints); Gecko on the exponent
    /// stream only ever shrinks it further.
    pub fn bits_per_value(&self, container: Container) -> f64 {
        let sign = if self.elide_sign { 0.0 } else { 1.0 };
        sign + self.layout.exponent_bits_per_value()
            + self.store_mant_bits().min(container.mant_bits()) as f64
            + self.layout.mantissa_overhead_bits()
    }

    /// The stash container metadata this plan induces.
    pub fn meta(&self, container: Container) -> ContainerMeta {
        ContainerMeta::new(container, self.store_mant_bits())
            .with_layout(self.layout)
            .with_sign_elision(self.elide_sign)
    }
}

/// The full per-tensor plan set for one period.
#[derive(Debug, Clone, PartialEq)]
pub struct NetworkPlan {
    pub acts: Vec<ContainerPlan>,
    pub weights: Vec<ContainerPlan>,
}

impl NetworkPlan {
    pub fn full(container: Container, layers: usize) -> Self {
        Self {
            acts: vec![ContainerPlan::full(container); layers],
            weights: vec![ContainerPlan::full(container); layers],
        }
    }

    fn mean<F: Fn(&ContainerPlan) -> f64>(plans: &[ContainerPlan], f: F) -> f64 {
        if plans.is_empty() {
            return 0.0;
        }
        plans.iter().map(f).sum::<f64>() / plans.len() as f64
    }

    pub fn mean_act_mant(&self) -> f64 {
        Self::mean(&self.acts, |p| p.mant as f64)
    }

    pub fn mean_weight_mant(&self) -> f64 {
        Self::mean(&self.weights, |p| p.mant as f64)
    }

    pub fn mean_act_exp(&self) -> f64 {
        Self::mean(&self.acts, |p| p.exp_bits_per_value())
    }

    pub fn mean_weight_exp(&self) -> f64 {
        Self::mean(&self.weights, |p| p.exp_bits_per_value())
    }
}

/// Per-period training signals handed to [`BitPolicy::observe`].
pub struct StepSignals<'a> {
    pub epoch: usize,
    pub step: usize,
    /// Task loss of the period that just ran.
    pub loss: f64,
    /// The learning rate changed right before this period.
    pub lr_changed: bool,
    /// Learned per-layer fractional mantissa bitlengths from the compiled
    /// step's in-graph learner (QM); `None` when unavailable.
    pub learned_n_a: Option<&'a [f32]>,
    pub learned_n_w: Option<&'a [f32]>,
    /// Per-layer exponent-range statistics of this period's tensors
    /// (empty slices when the run does not materialize tensors).
    pub act_stats: &'a [ExpRangeStats],
    pub weight_stats: &'a [ExpRangeStats],
}

/// The adaptation-policy contract: observe one period's signals, emit the
/// container plan for the next period, and checkpoint/restore bit-exactly.
///
/// Every adaptive implementation also reports its decisions to the
/// flight recorder ([`crate::obs::events`]): whenever a *stored* integer
/// bitlength crosses to a new value inside `observe`, a `bit_change`
/// event is emitted with the triggering signal (`qm_gradient_step`,
/// `qe_overflow_floor`, `bitwave_loss_ema`, …).  The tracking state is
/// observational only and deliberately excluded from
/// checkpoint/restore.  [`Composite`] delegates `observe` to both
/// halves, so its events arrive under the inner policies' names;
/// [`FixedPolicy`] never changes its plan and emits nothing.
pub trait BitPolicy: Send {
    /// Short identifier for CLI rows / JSON summaries.
    fn name(&self) -> &'static str;

    /// Observe one period; returns the plan to apply to the next period's
    /// tensors.
    fn observe(&mut self, sig: &StepSignals) -> NetworkPlan;

    /// The current plan without new observations.
    fn plan(&self) -> NetworkPlan;

    /// (lr_n, γ, stochastic) knobs for the compiled train step (only the
    /// gradient-side learners use them).
    fn step_hyper(&self, _epoch: usize) -> (f32, f32, i32) {
        (0.0, 0.0, 0)
    }

    /// Learning-rate change notification (full-precision cooldowns).
    fn notify_lr_change(&mut self) {}

    /// Serialize the complete adaptation state.  `restore` of the result
    /// must reproduce identical subsequent plans (property-tested).
    fn checkpoint(&self) -> Json;

    /// Restore state produced by [`BitPolicy::checkpoint`].
    fn restore(&mut self, state: &Json) -> Result<()>;
}

/// Mantissa bits (and sign elision) from `mant`, exponent width/mode from
/// `exp` — the composition that makes QM + QE the paper's headline pair
/// while letting each half evolve (and checkpoint) independently.
pub struct Composite {
    name: &'static str,
    mant: Box<dyn BitPolicy>,
    exp: Box<dyn BitPolicy>,
}

impl Composite {
    pub fn new(name: &'static str, mant: Box<dyn BitPolicy>, exp: Box<dyn BitPolicy>) -> Self {
        Self { name, mant, exp }
    }

    fn merge(m: NetworkPlan, e: &NetworkPlan) -> NetworkPlan {
        let splice = |ms: Vec<ContainerPlan>, es: &[ContainerPlan]| -> Vec<ContainerPlan> {
            ms.into_iter()
                .zip(es)
                .map(|(mp, ep)| ContainerPlan {
                    mant: mp.mant,
                    layout: ep.layout,
                    elide_sign: mp.elide_sign || ep.elide_sign,
                })
                .collect()
        };
        NetworkPlan {
            acts: splice(m.acts, &e.acts),
            weights: splice(m.weights, &e.weights),
        }
    }
}

impl BitPolicy for Composite {
    fn name(&self) -> &'static str {
        self.name
    }

    fn observe(&mut self, sig: &StepSignals) -> NetworkPlan {
        let m = self.mant.observe(sig);
        let e = self.exp.observe(sig);
        Self::merge(m, &e)
    }

    fn plan(&self) -> NetworkPlan {
        Self::merge(self.mant.plan(), &self.exp.plan())
    }

    fn step_hyper(&self, epoch: usize) -> (f32, f32, i32) {
        // the mantissa half owns the compiled-step learner knobs
        self.mant.step_hyper(epoch)
    }

    fn notify_lr_change(&mut self) {
        self.mant.notify_lr_change();
        self.exp.notify_lr_change();
    }

    fn checkpoint(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("mant".to_string(), self.mant.checkpoint());
        o.insert("exp".to_string(), self.exp.checkpoint());
        Json::Obj(o)
    }

    fn restore(&mut self, state: &Json) -> Result<()> {
        self.mant
            .restore(state.get("mant").ok_or_else(|| anyhow!("missing mant state"))?)?;
        self.exp
            .restore(state.get("exp").ok_or_else(|| anyhow!("missing exp state"))?)
    }
}

/// Static-plan policy — the FP32/BF16 full-container baselines and the
/// cross-paper fixed presets (fp8 bias window, Flexpoint block-shared,
/// plain bf16) expressed through the same engine so the Trainer has
/// exactly one wiring path.
pub struct FixedPolicy {
    name: &'static str,
    plan: NetworkPlan,
}

impl FixedPolicy {
    pub fn new(container: Container, layers: usize) -> Self {
        Self {
            name: "fixed",
            plan: NetworkPlan::full(container, layers),
        }
    }

    /// A named preset with one uniform `ContainerPlan` for every tensor.
    pub fn preset(
        name: &'static str,
        layers: usize,
        mant: f32,
        layout: ExponentLayout,
    ) -> Self {
        let plan = ContainerPlan {
            mant,
            layout,
            elide_sign: false,
        };
        Self {
            name,
            plan: NetworkPlan {
                acts: vec![plan; layers],
                weights: vec![plan; layers],
            },
        }
    }

    /// Flexpoint (PAPERS.md): bf16-width mantissa under a 16-value shared
    /// 8-bit exponent — ~9.5 stored bits per value before Gecko.
    pub fn flexpoint(layers: usize) -> Self {
        Self::preset(
            "flexpoint",
            layers,
            7.0,
            ExponentLayout::BlockShared { block: 16, bits: 8 },
        )
    }

    /// An fp8 (e4m3-shaped) container: 4-bit exponent window centred at
    /// the IEEE bias, 3 mantissa bits — exactly 8 stored bits per value.
    pub fn fp8(layers: usize) -> Self {
        Self::preset(
            "fp8",
            layers,
            3.0,
            ExponentLayout::Bias { bits: 4, bias: 127 },
        )
    }

    /// Plain BF16 under the default full-width layout.
    pub fn bf16(layers: usize) -> Self {
        Self::preset("bf16", layers, 7.0, ExponentLayout::default())
    }
}

impl BitPolicy for FixedPolicy {
    fn name(&self) -> &'static str {
        self.name
    }

    fn observe(&mut self, _sig: &StepSignals) -> NetworkPlan {
        self.plan.clone()
    }

    fn plan(&self) -> NetworkPlan {
        self.plan.clone()
    }

    fn checkpoint(&self) -> Json {
        Json::Obj(BTreeMap::new())
    }

    fn restore(&mut self, _state: &Json) -> Result<()> {
        Ok(())
    }
}

// ---- JSON state helpers shared by the policy implementations -----------

pub(crate) fn state_f64(state: &Json, key: &str) -> Result<f64> {
    state
        .get(key)
        .and_then(Json::as_f64)
        .ok_or_else(|| anyhow!("policy state: missing number '{key}'"))
}

pub(crate) fn state_u32(state: &Json, key: &str) -> Result<u32> {
    Ok(state_f64(state, key)? as u32)
}

pub(crate) fn state_bool(state: &Json, key: &str) -> Result<bool> {
    match state.get(key) {
        Some(Json::Bool(b)) => Ok(*b),
        _ => Err(anyhow!("policy state: missing bool '{key}'")),
    }
}

pub(crate) fn state_vec_f32(state: &Json, key: &str) -> Result<Vec<f32>> {
    state
        .get(key)
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("policy state: missing array '{key}'"))?
        .iter()
        .map(|j| {
            j.as_f64()
                .map(|v| v as f32)
                .ok_or_else(|| anyhow!("policy state: non-number in '{key}'"))
        })
        .collect()
}

pub(crate) fn jnums_f32(vs: &[f32]) -> Json {
    Json::Arr(vs.iter().map(|&v| Json::Num(v as f64)).collect())
}

pub(crate) fn mode_to_json(mode: Mode) -> Json {
    match mode {
        Mode::Delta => Json::Str("delta".to_string()),
        Mode::FixedBias { bias, group } => {
            let mut o = BTreeMap::new();
            o.insert("bias".to_string(), Json::Num(bias as f64));
            o.insert("group".to_string(), Json::Num(group as f64));
            Json::Obj(o)
        }
    }
}

pub(crate) fn mode_from_json(j: &Json) -> Result<Mode> {
    match j {
        Json::Str(s) if s == "delta" => Ok(Mode::Delta),
        Json::Obj(_) => Ok(Mode::FixedBias {
            bias: state_f64(j, "bias")? as u8,
            group: state_f64(j, "group")? as usize,
        }),
        _ => Err(anyhow!("policy state: bad exponent mode")),
    }
}

pub(crate) fn modes_to_json(modes: &[Mode]) -> Json {
    Json::Arr(modes.iter().map(|&m| mode_to_json(m)).collect())
}

pub(crate) fn modes_from_json(state: &Json, key: &str) -> Result<Vec<Mode>> {
    state
        .get(key)
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("policy state: missing array '{key}'"))?
        .iter()
        .map(mode_from_json)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_bits_per_value() {
        let p = ContainerPlan::width(1.3, 4, Mode::Delta, true);
        // 0 sign + 4 exponent + ceil(1.3)=2 mantissa
        assert_eq!(p.bits_per_value(Container::Bf16), 6.0);
        assert_eq!(p.store_mant_bits(), 2);
        let full = ContainerPlan::full(Container::Fp32);
        assert_eq!(full.bits_per_value(Container::Fp32), 32.0);
        let full16 = ContainerPlan::full(Container::Bf16);
        assert_eq!(full16.bits_per_value(Container::Bf16), 16.0);
    }

    #[test]
    fn bits_per_value_clamps_exponent_to_container_field() {
        // an over-wide requested exponent field charges only the 8 bits
        // the container has (historically it billed the raw number)
        let p = ContainerPlan::width(30.0, 12, Mode::Delta, false);
        assert_eq!(p.exp_bits(), 8);
        // 1 sign + 8 exponent + 7 mantissa (both axes clamped)
        assert_eq!(p.bits_per_value(Container::Bf16), 16.0);
    }

    #[test]
    fn bits_per_value_by_layout() {
        // fp8 preset: 1 sign + 4-bit window + 3 mantissa = 8 exactly
        let fp8 = ContainerPlan {
            mant: 3.0,
            layout: ExponentLayout::Bias { bits: 4, bias: 127 },
            elide_sign: false,
        };
        assert_eq!(fp8.bits_per_value(Container::Fp32), 8.0);
        // flexpoint: 1 sign + 8/16 shared exponent + (7 + 1) significand
        let flex = ContainerPlan {
            mant: 7.0,
            layout: ExponentLayout::BlockShared { block: 16, bits: 8 },
            elide_sign: false,
        };
        assert_eq!(flex.bits_per_value(Container::Bf16), 9.5);
    }

    #[test]
    fn plan_meta_application() {
        let p = ContainerPlan::width(2.7, 4, Mode::FixedBias { bias: 124, group: 8 }, true);
        let m = p.meta(Container::Bf16);
        assert_eq!(m.mant_bits, 3);
        assert!(m.elide_sign);
        assert_eq!(m.exp_mode(), Mode::FixedBias { bias: 124, group: 8 });
        // non-width layouts pass through to the stash meta verbatim
        let b = ContainerPlan {
            mant: 3.0,
            layout: ExponentLayout::Bias { bits: 4, bias: 121 },
            elide_sign: false,
        };
        assert_eq!(
            b.meta(Container::Fp32).layout,
            ExponentLayout::Bias { bits: 4, bias: 121 }
        );
    }

    #[test]
    fn composite_merges_axes() {
        let m = NetworkPlan {
            acts: vec![ContainerPlan::width(1.0, 8, Mode::Delta, true)],
            weights: vec![ContainerPlan::full(Container::Bf16)],
        };
        let e = NetworkPlan {
            acts: vec![ContainerPlan::width(
                7.0,
                4,
                Mode::FixedBias { bias: 120, group: 8 },
                false,
            )],
            weights: vec![ContainerPlan::width(7.0, 3, Mode::Delta, false)],
        };
        let out = Composite::merge(m, &e);
        assert_eq!(out.acts[0].mant, 1.0);
        assert_eq!(out.acts[0].exp_bits(), 4);
        assert!(out.acts[0].elide_sign);
        assert_eq!(out.weights[0].exp_bits(), 3);
    }

    #[test]
    fn fixed_presets_have_the_advertised_footprints() {
        let fp8 = FixedPolicy::fp8(2).plan();
        assert_eq!(fp8.acts[0].bits_per_value(Container::Fp32), 8.0);
        let flex = FixedPolicy::flexpoint(2).plan();
        assert_eq!(flex.acts[0].bits_per_value(Container::Bf16), 9.5);
        let bf16 = FixedPolicy::bf16(2).plan();
        assert_eq!(bf16.acts[0].bits_per_value(Container::Bf16), 16.0);
    }

    #[test]
    fn mode_json_roundtrip() {
        for m in [
            Mode::Delta,
            Mode::FixedBias { bias: 121, group: 8 },
        ] {
            assert_eq!(mode_from_json(&mode_to_json(m)).unwrap(), m);
        }
    }
}
