//! Quantum Mantissa behind the [`BitPolicy`] trait (§IV-A): per-layer
//! learned mantissa bitlengths under the staged γ schedule with the
//! round-up endgame.
//!
//! Two operating modes share one state machine:
//!
//! * **e2e** — the actual bitlength gradients live *inside* the compiled
//!   train step (Eq. 7's penalty + the expected-value bitlength VJP); the
//!   policy adopts the learned values from
//!   [`StepSignals::learned_n_a`](super::StepSignals) each period and owns
//!   only the schedule (γ stages, lr_n, stochastic flag) and the endgame
//!   ceil-and-freeze.
//! * **surrogate** (trace sweeps, no compiled step) — a deterministic
//!   descent toward per-layer target bitlengths calibrated from this
//!   repo's e2e runs ([`crate::report::MantissaPolicy::qm_default`]),
//!   paced by the same lr_n·γ product the in-graph learner uses, so the
//!   per-epoch trajectories have the paper's Fig. 3 shape.

use super::schedule::GammaSchedule;
use super::{
    jnums_f32, state_bool, state_vec_f32, BitPolicy, ContainerPlan, NetworkPlan, StepSignals,
};
use crate::formats::Container;
use crate::gecko::Mode;
use crate::util::json::Json;
use anyhow::Result;
use std::collections::BTreeMap;

pub struct QuantumMantissa {
    sched: GammaSchedule,
    container: Container,
    nonneg_act: Vec<bool>,
    /// Learned fractional bitlengths (acts, weights) per layer.
    n_a: Vec<f32>,
    n_w: Vec<f32>,
    /// Trace-mode surrogate targets per layer; `None` in e2e runs.
    targets: Option<Vec<(f32, f32)>>,
    /// Surrogate descent per unit lr_n·γ, sized so the full container→target
    /// drop completes inside the first γ stage regardless of run length.
    surrogate_scale: f32,
    /// Round-up endgame entered (bitlengths ceiled and frozen).
    rounded: bool,
    /// Last *stored* (ceiled) bitlengths reported to the flight recorder
    /// — observational only, deliberately outside checkpoint/restore.
    emitted_a: Vec<u32>,
    emitted_w: Vec<u32>,
}

impl QuantumMantissa {
    /// e2e mode: bitlengths arrive via `StepSignals::learned_n_*`.
    pub fn e2e(container: Container, layers: usize, epochs: usize) -> Self {
        Self::build(container, layers, epochs, 1, vec![false; layers], None)
    }

    /// Trace-sweep mode: descend toward `targets` = per-layer
    /// (act_bits, weight_bits) over `epochs` × `steps_per_epoch`
    /// observations.
    pub fn surrogate(
        container: Container,
        epochs: usize,
        steps_per_epoch: usize,
        nonneg_act: Vec<bool>,
        targets: Vec<(f32, f32)>,
    ) -> Self {
        let layers = targets.len();
        Self::build(
            container,
            layers,
            epochs,
            steps_per_epoch,
            nonneg_act,
            Some(targets),
        )
    }

    fn build(
        container: Container,
        layers: usize,
        epochs: usize,
        steps_per_epoch: usize,
        nonneg_act: Vec<bool>,
        targets: Option<Vec<(f32, f32)>>,
    ) -> Self {
        let mmax = container.mant_bits() as f32;
        let sched = GammaSchedule::paper_like(epochs);
        // Observations inside the first γ stage; the surrogate covers the
        // whole container range in 80% of them so every layer reaches its
        // target with slack before γ decays.
        let stage1_epochs = ((epochs as f64 * sched.stage_frac[1]).round() as usize).max(1);
        let stage1_obs = (stage1_epochs * steps_per_epoch.max(1)) as f32;
        let surrogate_scale = mmax / (0.8 * stage1_obs * sched.lr_n * sched.gammas[0]);
        Self {
            sched,
            container,
            nonneg_act,
            n_a: vec![mmax; layers],
            n_w: vec![mmax; layers],
            targets,
            surrogate_scale,
            rounded: false,
            emitted_a: vec![mmax.ceil() as u32; layers],
            emitted_w: vec![mmax.ceil() as u32; layers],
        }
    }

    fn mmax(&self) -> f32 {
        self.container.mant_bits() as f32
    }

    /// Report any per-layer *stored* (ceiled) bitlength crossings to the
    /// flight recorder.  Fractional drift between integer boundaries is
    /// silent — only changes that alter artifact bytes are events.
    fn emit_bit_changes(&mut self, sig: &StepSignals, trigger: &'static str) {
        for (i, (&n, last)) in self.n_a.iter().zip(self.emitted_a.iter_mut()).enumerate() {
            let bits = n.max(0.0).ceil() as u32;
            if bits != *last {
                crate::obs::events::bit_change(
                    "qm",
                    trigger,
                    "act",
                    "mant",
                    Some(i),
                    sig.epoch,
                    sig.step,
                    *last as f64,
                    bits as f64,
                );
                *last = bits;
            }
        }
        for (i, (&n, last)) in self.n_w.iter().zip(self.emitted_w.iter_mut()).enumerate() {
            let bits = n.max(0.0).ceil() as u32;
            if bits != *last {
                crate::obs::events::bit_change(
                    "qm",
                    trigger,
                    "weight",
                    "mant",
                    Some(i),
                    sig.epoch,
                    sig.step,
                    *last as f64,
                    bits as f64,
                );
                *last = bits;
            }
        }
    }

    fn make_plan(&self) -> NetworkPlan {
        let acts = self
            .n_a
            .iter()
            .zip(&self.nonneg_act)
            .map(|(&n, &nonneg)| ContainerPlan::width(n, 8, Mode::Delta, nonneg))
            .collect();
        let weights = self
            .n_w
            .iter()
            .map(|&n| ContainerPlan::width(n, 8, Mode::Delta, false))
            .collect();
        NetworkPlan { acts, weights }
    }
}

impl BitPolicy for QuantumMantissa {
    fn name(&self) -> &'static str {
        "qm"
    }

    fn observe(&mut self, sig: &StepSignals) -> NetworkPlan {
        let mmax = self.mmax();
        let (gamma, lr_n, _stochastic) = self.sched.hyper(sig.epoch);
        if self.sched.in_roundup(sig.epoch) {
            if !self.rounded {
                // §IV-A-4: adopt any last learned values, then ceil-freeze.
                if let Some(n) = sig.learned_n_a {
                    self.n_a.copy_from_slice(n);
                }
                if let Some(n) = sig.learned_n_w {
                    self.n_w.copy_from_slice(n);
                }
                GammaSchedule::round_up(&mut self.n_a, mmax);
                GammaSchedule::round_up(&mut self.n_w, mmax);
                self.rounded = true;
                self.emit_bit_changes(sig, "qm_roundup");
            }
            return self.make_plan();
        }
        if let (Some(na), Some(nw)) = (sig.learned_n_a, sig.learned_n_w) {
            // e2e: the compiled step learned these; clamp into the container.
            for (n, &v) in self.n_a.iter_mut().zip(na) {
                *n = v.clamp(0.0, mmax);
            }
            for (n, &v) in self.n_w.iter_mut().zip(nw) {
                *n = v.clamp(0.0, mmax);
            }
        } else if let Some(targets) = &self.targets {
            // surrogate: γ-paced descent toward the calibrated targets.
            let step = lr_n * gamma * self.surrogate_scale;
            for (i, &(ta, tw)) in targets.iter().enumerate() {
                self.n_a[i] = (self.n_a[i] - step).clamp(ta.min(mmax), mmax);
                self.n_w[i] = (self.n_w[i] - step).clamp(tw.min(mmax), mmax);
            }
        }
        self.emit_bit_changes(sig, "qm_gradient_step");
        self.make_plan()
    }

    fn plan(&self) -> NetworkPlan {
        self.make_plan()
    }

    fn step_hyper(&self, epoch: usize) -> (f32, f32, i32) {
        let (gamma, lr_n, stochastic) = self.sched.hyper(epoch);
        (lr_n, gamma, stochastic)
    }

    fn checkpoint(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("n_a".to_string(), jnums_f32(&self.n_a));
        o.insert("n_w".to_string(), jnums_f32(&self.n_w));
        o.insert("rounded".to_string(), Json::Bool(self.rounded));
        Json::Obj(o)
    }

    fn restore(&mut self, state: &Json) -> Result<()> {
        self.n_a = state_vec_f32(state, "n_a")?;
        self.n_w = state_vec_f32(state, "n_w")?;
        self.rounded = state_bool(state, "rounded")?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sig(epoch: usize, step: usize) -> StepSignals<'static> {
        StepSignals {
            epoch,
            step,
            loss: 1.0,
            lr_changed: false,
            learned_n_a: None,
            learned_n_w: None,
            act_stats: &[],
            weight_stats: &[],
        }
    }

    #[test]
    fn surrogate_descends_to_targets_and_rounds_up() {
        let mut p = QuantumMantissa::surrogate(
            Container::Bf16,
            6,
            30,
            vec![true, true, false],
            vec![(1.0, 2.0), (1.5, 2.0), (2.0, 3.0)],
        );
        let mut step = 0;
        for epoch in 0..6 {
            for _ in 0..30 {
                p.observe(&sig(epoch, step));
                step += 1;
            }
        }
        let plan = p.plan();
        // endgame: ceiled integers at the targets
        assert_eq!(plan.acts[0].mant, 1.0);
        assert_eq!(plan.acts[1].mant, 2.0); // ceil(1.5)
        assert_eq!(plan.weights[2].mant, 3.0);
        assert!(plan.acts[0].elide_sign);
        assert!(!plan.acts[2].elide_sign);
        assert_eq!(plan.acts[0].exp_bits(), 8, "QM alone leaves exponents full");
    }

    #[test]
    fn e2e_adopts_learned_bits() {
        let mut p = QuantumMantissa::e2e(Container::Bf16, 2, 90);
        let na = [3.2f32, 1.1];
        let nw = [4.0f32, 2.5];
        let s = StepSignals {
            epoch: 1,
            step: 1,
            loss: 1.0,
            lr_changed: false,
            learned_n_a: Some(&na),
            learned_n_w: Some(&nw),
            act_stats: &[],
            weight_stats: &[],
        };
        let plan = p.observe(&s);
        assert_eq!(plan.acts[0].mant, 3.2);
        assert_eq!(plan.weights[1].mant, 2.5);
        // store bits are ceiled
        assert_eq!(plan.acts[1].store_mant_bits(), 2);
    }

    #[test]
    fn surrogate_descent_emits_integer_bitlength_events() {
        crate::obs::events::capture_begin();
        let mut p = QuantumMantissa::surrogate(
            Container::Bf16,
            6,
            30,
            vec![true, false],
            vec![(1.0, 2.0), (2.0, 3.0)],
        );
        let mut step = 0;
        for epoch in 0..6 {
            for _ in 0..30 {
                p.observe(&sig(epoch, step));
                step += 1;
            }
        }
        let events = crate::obs::events::capture_end();
        let qm: Vec<_> = events.iter().filter(|e| e.source == "qm").collect();
        assert!(!qm.is_empty(), "descent must cross integer boundaries");
        for e in &qm {
            assert_eq!(e.kind, "bitlength");
            assert_eq!(e.component.as_deref(), Some("mant"));
            assert_ne!(e.from, e.to, "events only on change");
            assert_eq!(e.from.fract(), 0.0, "stored bits are integers");
        }
        // layer 0 acts walked all the way down to its 1-bit target
        let reached = qm.iter().any(|e| {
            e.layer == Some(0) && e.tensor_class.as_deref() == Some("act") && e.to == 1.0
        });
        assert!(reached, "layer 0 acts never reached the 1-bit target");
    }

    #[test]
    fn checkpoint_restores_bitlengths() {
        let mut p = QuantumMantissa::surrogate(
            Container::Bf16,
            9,
            10,
            vec![false; 2],
            vec![(1.0, 2.0), (1.0, 2.0)],
        );
        for s in 0..40 {
            p.observe(&sig(s / 10, s));
        }
        let ck = p.checkpoint();
        let mut q = QuantumMantissa::surrogate(
            Container::Bf16,
            9,
            10,
            vec![false; 2],
            vec![(1.0, 2.0), (1.0, 2.0)],
        );
        q.restore(&ck).unwrap();
        assert_eq!(p.plan(), q.plan());
        assert_eq!(ck, q.checkpoint());
    }
}
