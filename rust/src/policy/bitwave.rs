//! BitWave behind the [`BitPolicy`] trait: the loss-EMA controller
//! (§IV-B's BitChop, Eq. 8/9 semantics untouched) extended to drive
//! exponent *and* mantissa bitlengths network-wide — the paper's 3.19×
//! hardware-friendly alternative to the learned per-layer pair.
//!
//! * **Mantissa** — exactly the embedded [`BitChop`] decision stream; the
//!   network-wide bitlength applies to activations and weights alike.
//! * **Exponent** — a single network-wide width rides the same decision
//!   stream at a slower cadence: while the loss is not degrading it shaves
//!   one bit per [`EXP_SHRINK_RUN`] periods, any "worsening" period
//!   restores one, and the streaming range statistics impose a hard floor
//!   (a width that would saturate any tensor's observed exponent range is
//!   never emitted — saturating the stash corrupts the values the backward
//!   pass restores).  Around LR changes the whole container returns to
//!   full precision, mirroring BitChop's cooldown.
//!
//! [`BitChopPolicy`] wraps a bare BitChop as a mantissa-only policy (acts
//! network-wide, weights at container precision) — the historical SFP_BC
//! variant expressed through the engine.

use super::{
    modes_from_json, modes_to_json, state_u32, BitPolicy, ContainerPlan, NetworkPlan, StepSignals,
};
use crate::coordinator::BitChop;
use crate::formats::Container;
use crate::gecko::Mode;
use crate::util::json::Json;
use anyhow::Result;
use std::collections::BTreeMap;

/// Non-degrading periods required to shave one exponent bit (the exponent
/// moves slower than the mantissa — its quantization failure mode is
/// saturation, not noise, so it descends steadily toward the range floor
/// and only a worsening loss backs it off).
const EXP_SHRINK_RUN: u32 = 4;

/// Overflow tolerance for the network-wide exponent floor.
const OVERFLOW_TOL: f64 = 1e-5;

pub struct BitWave {
    chop: BitChop,
    nonneg_act: Vec<bool>,
    /// Network-wide exponent width (outside cooldowns).
    exp_bits: u32,
    /// Hard floor: the widest requirement any tensor has shown.
    exp_floor: u32,
    /// Consecutive improving periods since the last exponent move.
    improve_run: u32,
    /// Per-tensor lossless Gecko layouts (storage only; the width above is
    /// the network-wide container decision).
    mode_a: Vec<Mode>,
    mode_w: Vec<Mode>,
    /// Last *effective* (cooldown-aware) stored bits reported to the
    /// flight recorder — observational only, outside checkpoint/restore.
    /// Network-wide, so events carry `layer: None` and class `"network"`.
    emitted_mant: u32,
    emitted_exp: u32,
}

impl BitWave {
    pub fn new(container: Container, nonneg_act: Vec<bool>) -> Self {
        let layers = nonneg_act.len();
        Self {
            chop: BitChop::new(container.mant_bits()),
            nonneg_act,
            exp_bits: 8,
            exp_floor: 1,
            improve_run: 0,
            mode_a: vec![Mode::Delta; layers],
            mode_w: vec![Mode::Delta; layers],
            emitted_mant: container.mant_bits(),
            emitted_exp: 8,
        }
    }

    fn effective(&self) -> (f32, u32) {
        // cooldown: full container precision on both axes (§IV-B)
        if self.chop.in_cooldown() {
            (self.chop.n_max() as f32, 8)
        } else {
            (self.chop.bits() as f32, self.exp_bits)
        }
    }

    fn make_plan(&self) -> NetworkPlan {
        let (mant, exp_bits) = self.effective();
        let acts = self
            .mode_a
            .iter()
            .zip(&self.nonneg_act)
            .map(|(&mode, &nonneg)| ContainerPlan::width(mant, exp_bits, mode, nonneg))
            .collect();
        let weights = self
            .mode_w
            .iter()
            .map(|&mode| ContainerPlan::width(mant, exp_bits, mode, false))
            .collect();
        NetworkPlan { acts, weights }
    }
}

impl BitPolicy for BitWave {
    fn name(&self) -> &'static str {
        "bitwave"
    }

    fn observe(&mut self, sig: &StepSignals) -> NetworkPlan {
        if sig.lr_changed {
            self.notify_lr_change();
        }
        // ---- exponent floor + storage modes from the range statistics
        let mut floor = 1u32;
        for (i, stats) in sig.act_stats.iter().enumerate() {
            if stats.count > 0 {
                floor = floor.max(stats.needed_exp_bits(OVERFLOW_TOL));
                if let Some(m) = self.mode_a.get_mut(i) {
                    *m = stats.gecko_best().1;
                }
            }
        }
        for (i, stats) in sig.weight_stats.iter().enumerate() {
            if stats.count > 0 {
                floor = floor.max(stats.needed_exp_bits(OVERFLOW_TOL));
                if let Some(m) = self.mode_w.get_mut(i) {
                    *m = stats.gecko_best().1;
                }
            }
        }
        // Narrowing needs range evidence for the *activations* (the widest
        // and footprint-dominating tensors); weight-only stats — the
        // no-stash e2e path — must not shrink the network-wide width.
        if sig.act_stats.iter().any(|s| s.count > 0) {
            self.exp_floor = floor;
        } else {
            self.exp_floor = 8;
        }
        let floor_clamped = self.exp_bits < self.exp_floor;
        self.exp_bits = self.exp_bits.max(self.exp_floor);

        // ---- mantissa: the unmodified Eq. 8/9 controller
        self.chop.observe(sig.loss);

        // ---- exponent rides the same decision at a slower cadence:
        // degrading loss backs off a bit, anything else (improving or
        // hold) counts toward the next shave
        if self.chop.last_decision() == -1 {
            self.exp_bits = (self.exp_bits + 1).min(8);
            self.improve_run = 0;
        } else {
            self.improve_run += 1;
            if self.improve_run >= EXP_SHRINK_RUN && self.exp_bits > self.exp_floor {
                self.exp_bits -= 1;
                self.improve_run = 0;
            }
        }

        // ---- flight recorder: report effective stored-bit crossings
        let (mant, exp) = self.effective();
        let mant_bits = mant.max(0.0).ceil() as u32;
        if mant_bits != self.emitted_mant {
            crate::obs::events::bit_change(
                "bitwave",
                "bitwave_loss_ema",
                "network",
                "mant",
                None,
                sig.epoch,
                sig.step,
                self.emitted_mant as f64,
                mant_bits as f64,
            );
            self.emitted_mant = mant_bits;
        }
        if exp != self.emitted_exp {
            let trigger = if floor_clamped && exp > self.emitted_exp {
                "bitwave_overflow_floor"
            } else {
                "bitwave_loss_ema"
            };
            crate::obs::events::bit_change(
                "bitwave",
                trigger,
                "network",
                "exp",
                None,
                sig.epoch,
                sig.step,
                self.emitted_exp as f64,
                exp as f64,
            );
            self.emitted_exp = exp;
        }
        self.make_plan()
    }

    fn plan(&self) -> NetworkPlan {
        self.make_plan()
    }

    fn notify_lr_change(&mut self) {
        self.chop.notify_lr_change();
        self.improve_run = 0;
    }

    fn checkpoint(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("chop".to_string(), self.chop.state_json());
        o.insert("exp_bits".to_string(), Json::Num(self.exp_bits as f64));
        o.insert("exp_floor".to_string(), Json::Num(self.exp_floor as f64));
        o.insert("improve_run".to_string(), Json::Num(self.improve_run as f64));
        o.insert("mode_a".to_string(), modes_to_json(&self.mode_a));
        o.insert("mode_w".to_string(), modes_to_json(&self.mode_w));
        Json::Obj(o)
    }

    fn restore(&mut self, state: &Json) -> Result<()> {
        self.chop = BitChop::from_state_json(
            state
                .get("chop")
                .ok_or_else(|| anyhow::anyhow!("bitwave state: missing chop"))?,
        )?;
        self.exp_bits = state_u32(state, "exp_bits")?;
        self.exp_floor = state_u32(state, "exp_floor")?;
        self.improve_run = state_u32(state, "improve_run")?;
        self.mode_a = modes_from_json(state, "mode_a")?;
        self.mode_w = modes_from_json(state, "mode_w")?;
        Ok(())
    }
}

/// The historical SFP_BC wiring as a [`BitPolicy`]: BitChop drives the
/// network-wide *activation* mantissa, weights stay at container precision,
/// exponents stay full ("presently, BitChop adjusts the mantissa only for
/// the activations", §IV-B).
pub struct BitChopPolicy {
    chop: BitChop,
    container: Container,
    layers: usize,
    /// Last effective stored activation mantissa reported to the flight
    /// recorder (observational only, outside checkpoint/restore).
    emitted_mant: u32,
}

impl BitChopPolicy {
    pub fn new(container: Container, layers: usize) -> Self {
        Self {
            chop: BitChop::new(container.mant_bits()),
            container,
            layers,
            emitted_mant: container.mant_bits(),
        }
    }

    fn make_plan(&self) -> NetworkPlan {
        let mut plan = NetworkPlan::full(self.container, self.layers);
        let bits = self.chop.bits() as f32;
        for p in plan.acts.iter_mut() {
            p.mant = bits;
        }
        plan
    }
}

impl BitPolicy for BitChopPolicy {
    fn name(&self) -> &'static str {
        "bc"
    }

    fn observe(&mut self, sig: &StepSignals) -> NetworkPlan {
        if sig.lr_changed {
            self.chop.notify_lr_change();
        }
        self.chop.observe(sig.loss);
        let bits = self.chop.bits();
        if bits != self.emitted_mant {
            crate::obs::events::bit_change(
                "bc",
                "bitchop_loss_ema",
                "act",
                "mant",
                None,
                sig.epoch,
                sig.step,
                self.emitted_mant as f64,
                bits as f64,
            );
            self.emitted_mant = bits;
        }
        self.make_plan()
    }

    fn plan(&self) -> NetworkPlan {
        self.make_plan()
    }

    fn notify_lr_change(&mut self) {
        self.chop.notify_lr_change();
    }

    fn checkpoint(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("chop".to_string(), self.chop.state_json());
        Json::Obj(o)
    }

    fn restore(&mut self, state: &Json) -> Result<()> {
        self.chop = BitChop::from_state_json(
            state
                .get("chop")
                .ok_or_else(|| anyhow::anyhow!("bc state: missing chop"))?,
        )?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::ExpRangeStats;
    use crate::traces::ValueModel;

    fn stats(seed: u64) -> (Vec<ExpRangeStats>, Vec<ExpRangeStats>) {
        let a = vec![
            ExpRangeStats::from_exponents(&ValueModel::relu_act().sample_exponents(8192, seed)),
        ];
        let w = vec![
            ExpRangeStats::from_exponents(&ValueModel::weights().sample_exponents(8192, seed ^ 1)),
        ];
        (a, w)
    }

    fn sig<'a>(
        epoch: usize,
        step: usize,
        loss: f64,
        a: &'a [ExpRangeStats],
        w: &'a [ExpRangeStats],
    ) -> StepSignals<'a> {
        StepSignals {
            epoch,
            step,
            loss,
            lr_changed: false,
            learned_n_a: None,
            learned_n_w: None,
            act_stats: a,
            weight_stats: w,
        }
    }

    #[test]
    fn improving_loss_shrinks_both_axes() {
        let (a, w) = stats(3);
        let mut bw = BitWave::new(Container::Bf16, vec![true]);
        for i in 0..60 {
            bw.observe(&sig(0, i, 5.0 - 0.08 * i as f64, &a, &w));
        }
        let plan = bw.plan();
        assert!(plan.acts[0].mant < 7.0, "mantissa chopped: {}", plan.acts[0].mant);
        assert!(plan.acts[0].exp_bits() < 8, "exponent chopped: {}", plan.acts[0].exp_bits());
        // the floor from the range stats is never violated
        let floor = a[0]
            .needed_exp_bits(1e-5)
            .max(w[0].needed_exp_bits(1e-5));
        assert!(plan.acts[0].exp_bits() >= floor);
        // weights ride the same network-wide container
        assert_eq!(plan.weights[0].exp_bits(), plan.acts[0].exp_bits());
        assert_eq!(plan.weights[0].mant, plan.acts[0].mant);
    }

    #[test]
    fn no_stats_keeps_exponent_full() {
        let mut bw = BitWave::new(Container::Bf16, vec![false]);
        for i in 0..60 {
            bw.observe(&sig(0, i, 5.0 - 0.08 * i as f64, &[], &[]));
        }
        assert_eq!(bw.plan().acts[0].exp_bits(), 8);
        assert!(bw.plan().acts[0].mant < 7.0);
    }

    #[test]
    fn lr_change_restores_full_container() {
        let (a, w) = stats(7);
        let mut bw = BitWave::new(Container::Bf16, vec![true]);
        for i in 0..60 {
            bw.observe(&sig(0, i, 5.0 - 0.08 * i as f64, &a, &w));
        }
        assert!(bw.plan().acts[0].exp_bits() < 8);
        bw.notify_lr_change();
        let plan = bw.plan();
        assert_eq!(plan.acts[0].mant, 7.0);
        assert_eq!(plan.acts[0].exp_bits(), 8);
    }

    #[test]
    fn worsening_loss_restores_exponent_bits() {
        let (a, w) = stats(13);
        let mut bw = BitWave::new(Container::Bf16, vec![true]);
        for i in 0..60 {
            bw.observe(&sig(0, i, 5.0 - 0.08 * i as f64, &a, &w));
        }
        let low = bw.plan().acts[0].exp_bits();
        for i in 0..40 {
            bw.observe(&sig(1, 60 + i, 1.0 + 0.2 * i as f64, &a, &w));
        }
        assert!(bw.plan().acts[0].exp_bits() > low);
    }

    #[test]
    fn loss_ema_crossings_emit_network_wide_events() {
        crate::obs::events::capture_begin();
        let (a, w) = stats(3);
        let mut bw = BitWave::new(Container::Bf16, vec![true]);
        for i in 0..60 {
            bw.observe(&sig(0, i, 5.0 - 0.08 * i as f64, &a, &w));
        }
        let events = crate::obs::events::capture_end();
        let ours: Vec<_> = events.iter().filter(|e| e.source == "bitwave").collect();
        assert!(!ours.is_empty());
        assert!(ours.iter().all(|e| e.layer.is_none()), "network-wide");
        assert!(ours.iter().any(|e| e.component.as_deref() == Some("mant")));
        assert!(ours.iter().any(|e| e.component.as_deref() == Some("exp")));
        assert!(ours.iter().all(|e| e.trigger.starts_with("bitwave_")));
    }

    #[test]
    fn checkpoint_roundtrip_continues_identically() {
        let (a, w) = stats(17);
        let mut bw = BitWave::new(Container::Bf16, vec![true]);
        let mut rng = crate::traces::SplitMix64::new(23);
        for i in 0..50 {
            bw.observe(&sig(0, i, 4.0 - 0.05 * i as f64 + 0.01 * rng.next_gaussian(), &a, &w));
        }
        let ck = bw.checkpoint();
        let mut bw2 = BitWave::new(Container::Bf16, vec![true]);
        bw2.restore(&ck).unwrap();
        assert_eq!(ck, bw2.checkpoint());
        for i in 0..40 {
            let loss = 2.0 + 0.03 * (i as f64) * if i % 2 == 0 { 1.0 } else { -1.0 };
            let p1 = bw.observe(&sig(1, 50 + i as usize, loss, &a, &w));
            let p2 = bw2.observe(&sig(1, 50 + i as usize, loss, &a, &w));
            assert_eq!(p1, p2, "step {i}");
        }
    }

    #[test]
    fn bitchop_policy_preserves_legacy_shape() {
        let mut p = BitChopPolicy::new(Container::Bf16, 3);
        for i in 0..50 {
            p.observe(&sig(0, i, 5.0 - 0.08 * i as f64, &[], &[]));
        }
        let plan = p.plan();
        assert!(plan.acts[0].mant < 7.0);
        assert_eq!(plan.weights[0].mant, 7.0, "weights stay at container");
        assert_eq!(plan.acts[0].exp_bits(), 8, "exponent untouched");
        assert!(plan.acts.iter().all(|c| c.mant == plan.acts[0].mant));
    }
}
