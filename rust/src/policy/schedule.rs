//! The staged γ-regularizer schedule shared by the learned-bitlength
//! policies (§IV-A for mantissas, §IV-B's exponent twin): three γ stages
//! expressed as fractions of the run, a bitlength learning rate, and the
//! round-up endgame that freezes ceiled bitlengths for the tail of the run.
//!
//! Generalized out of the original `coordinator::qm::QmSchedule` so that
//! Quantum Exponent (and any future gradient-side learner) reuses the same
//! machinery; `QmSchedule` remains as a name alias for compatibility.

/// γ regularizer schedule: the paper sets 0.1 / 0.01 / 0.001 at epochs
/// 0 / 30 / 60 of a 90-epoch run; we express the breakpoints as fractions
/// of the configured run length.
#[derive(Debug, Clone)]
pub struct GammaSchedule {
    pub epochs: usize,
    pub gammas: [f32; 3],
    /// Epoch fractions at which each γ stage begins.
    pub stage_frac: [f64; 3],
    /// Fraction of the run with rounded-up frozen bitlengths at the end
    /// (paper: last 10 of 90 epochs).
    pub roundup_frac: f64,
    /// Bitlength learning rate while adapting.
    pub lr_n: f32,
}

impl GammaSchedule {
    pub fn paper_like(epochs: usize) -> Self {
        Self {
            epochs,
            gammas: [0.1, 0.01, 0.001],
            stage_frac: [0.0, 1.0 / 3.0, 2.0 / 3.0],
            roundup_frac: 1.0 / 9.0,
            lr_n: 4.0,
        }
    }

    /// First epoch of the round-up endgame (§IV-A-4).  The endgame covers
    /// the last `roundup_frac` of the run rounded to whole epochs — but
    /// always at least one epoch, so short runs (e.g. the 6-epoch default)
    /// still freeze-and-round instead of skipping the endgame entirely
    /// (the historical `epochs * (1 - roundup_frac)` threshold was never
    /// reached by runs shorter than ⌈1/roundup_frac⌉ epochs).
    pub fn roundup_entry(&self) -> usize {
        let tail = ((self.epochs as f64 * self.roundup_frac).round() as usize).max(1);
        self.epochs.saturating_sub(tail)
    }

    /// Is `epoch` in the round-up endgame (§IV-A-4)?
    pub fn in_roundup(&self, epoch: usize) -> bool {
        epoch >= self.roundup_entry()
    }

    /// (γ, lr_n, stochastic) for this epoch.  In the endgame the bitlengths
    /// are frozen (lr_n = 0), deterministic (stochastic = 0), and the
    /// coordinator rounds the learned values up once on entry.
    pub fn hyper(&self, epoch: usize) -> (f32, f32, i32) {
        if self.in_roundup(epoch) {
            return (0.0, 0.0, 0);
        }
        let frac = epoch as f64 / self.epochs.max(1) as f64;
        let mut gamma = self.gammas[0];
        for (g, f) in self.gammas.iter().zip(self.stage_frac) {
            if frac >= f {
                gamma = *g;
            }
        }
        (gamma, self.lr_n, 1)
    }

    /// Round learned bitlengths up for deployment/endgame.
    pub fn round_up(bits: &mut [f32], mmax: f32) {
        for b in bits {
            *b = b.ceil().clamp(0.0, mmax);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_boundaries_exact() {
        let s = GammaSchedule::paper_like(90);
        // γ changes exactly at the stage_frac breakpoints, not one off.
        assert_eq!(s.hyper(0).0, 0.1);
        assert_eq!(s.hyper(29).0, 0.1);
        assert_eq!(s.hyper(30).0, 0.01);
        assert_eq!(s.hyper(59).0, 0.01);
        assert_eq!(s.hyper(60).0, 0.001);
        assert_eq!(s.hyper(79).0, 0.001);
    }

    #[test]
    fn stage_boundaries_exact_non_multiple() {
        // 9-epoch run: 3/9 and 6/9 land exactly on 1/3 and 2/3 in f64.
        let s = GammaSchedule::paper_like(9);
        assert_eq!(s.hyper(2).0, 0.1);
        assert_eq!(s.hyper(3).0, 0.01);
        assert_eq!(s.hyper(5).0, 0.01);
        assert_eq!(s.hyper(6).0, 0.001);
    }

    #[test]
    fn roundup_entry_matches_paper_run() {
        let s = GammaSchedule::paper_like(90);
        assert_eq!(s.roundup_entry(), 80); // last 10 of 90
        assert!(!s.in_roundup(79));
        assert!(s.in_roundup(80));
        assert_eq!(s.hyper(85), (0.0, 0.0, 0));
    }

    #[test]
    fn roundup_entry_short_runs_off_by_one_guard() {
        // 6-epoch run: 6/9 of an epoch rounds to a single endgame epoch;
        // the old floor-threshold formula skipped the endgame entirely.
        let s = GammaSchedule::paper_like(6);
        assert_eq!(s.roundup_entry(), 5);
        assert!(!s.in_roundup(4));
        assert!(s.in_roundup(5));
        // 9 epochs -> exactly one endgame epoch (9/9 = 1).
        let s = GammaSchedule::paper_like(9);
        assert_eq!(s.roundup_entry(), 8);
        // degenerate 1-epoch run keeps the at-least-one-epoch guarantee
        let s = GammaSchedule::paper_like(1);
        assert_eq!(s.roundup_entry(), 0);
        assert!(s.in_roundup(0));
    }

    #[test]
    fn adapting_phase_is_stochastic_with_live_lr() {
        let s = GammaSchedule::paper_like(90);
        let (_, lr_n, stoch) = s.hyper(10);
        assert!(lr_n > 0.0);
        assert_eq!(stoch, 1);
    }

    #[test]
    fn round_up_clamps() {
        let mut bits = vec![1.2, 0.0, -0.5, 22.9, 25.0];
        GammaSchedule::round_up(&mut bits, 23.0);
        assert_eq!(bits, vec![2.0, 0.0, 0.0, 23.0, 23.0]);
    }
}
