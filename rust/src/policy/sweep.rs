//! Trace-driven policy sweep (`repro policy`): run each adaptation policy
//! over the ImageNet-scale trace models, emit per-epoch bitlength
//! trajectories, and report end-of-run footprints two ways — the plan's
//! fixed-width containers (the paper's QM+QE 4.74× / BitWave 3.19×
//! numbers) and with Gecko losslessly compressing the resulting exponent
//! streams through the real stash (the 5.64× / 4.56× step).
//!
//! The sweep stands in for an ImageNet training run: per-tensor value
//! streams come from the calibrated [`crate::traces::ValueModel`]s (the
//! same streams the analytic footprint models measure), the loss curve is
//! a staged-decay model with the LR drops the Trainer applies at 1/3 and
//! 2/3 of the run, and — crucially for BitWave's feedback loop — the loss
//! carries a mantissa-quantization penalty term, so chopping bits too far
//! *raises* the observed loss exactly as it would in real training.

use super::{
    AdaptivFloatPolicy, BitPolicy, Composite, FixedPolicy, NetworkPlan, QuantumExponent,
    QuantumMantissa,
};
use crate::formats::Container;
use crate::hwsim;
use crate::report::footprint::{
    ACT_EXP_SEED, ACT_VAL_SEED, SAMPLE, STREAM_SEED, WEIGHT_EXP_SEED, WEIGHT_VAL_SEED,
};
use crate::report::MantissaPolicy;
use crate::stash::{CodecKind, ContainerMeta, LedgerSnapshot, Stash, StashConfig, TensorId};
use crate::stats::ExpRangeStats;
use crate::traces::{values_with_exponents, NetworkTrace, SplitMix64};
use crate::util::json::Json;
use anyhow::{anyhow, Result};
use std::path::Path;

/// Which policy a sweep run exercises.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicyKind {
    /// Quantum Mantissa + Quantum Exponent — the paper's headline pair.
    QmQe,
    /// BitWave — network-wide mantissa + exponent from the loss EMA.
    BitWave,
    /// Quantum Mantissa alone (exponents stay at the full 8-bit field) —
    /// shows that exponent adaptation is the load-bearing half.
    QmOnly,
    /// Quantum Mantissa + AdaptivFloat — cross-paper pair spending the
    /// range signal on a per-tensor exponent *bias* instead of a width.
    AdaptivFloat,
    /// Flexpoint-style block-shared exponent (one field per 16 values),
    /// fixed full mantissa — a static cross-paper baseline.
    Flexpoint,
    /// Static fp8-like preset (E4M3 footprint via a 4-bit bias window).
    Fp8,
    /// Static bf16 passthrough — the no-adaptation floor.
    Bf16,
}

impl PolicyKind {
    pub fn parse(s: &str) -> Option<PolicyKind> {
        match s {
            "qmqe" | "qm_qe" | "qm+qe" => Some(PolicyKind::QmQe),
            "bitwave" | "bw" => Some(PolicyKind::BitWave),
            "qm" | "qm_only" => Some(PolicyKind::QmOnly),
            "adaptivfloat" | "af" | "qm+af" => Some(PolicyKind::AdaptivFloat),
            "flexpoint" | "flex" => Some(PolicyKind::Flexpoint),
            "fp8" => Some(PolicyKind::Fp8),
            "bf16" => Some(PolicyKind::Bf16),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            PolicyKind::QmQe => "qm+qe",
            PolicyKind::BitWave => "bitwave",
            PolicyKind::QmOnly => "qm",
            PolicyKind::AdaptivFloat => "qm+af",
            PolicyKind::Flexpoint => "flexpoint",
            PolicyKind::Fp8 => "fp8",
            PolicyKind::Bf16 => "bf16",
        }
    }

    pub fn all() -> [PolicyKind; 7] {
        [
            PolicyKind::QmQe,
            PolicyKind::BitWave,
            PolicyKind::QmOnly,
            PolicyKind::AdaptivFloat,
            PolicyKind::Flexpoint,
            PolicyKind::Fp8,
            PolicyKind::Bf16,
        ]
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct SweepConfig {
    pub epochs: usize,
    pub steps_per_epoch: usize,
    pub batch: usize,
    pub container: Container,
    /// Values sampled per tensor stream (scaled to full tensor size).
    pub sample: usize,
    pub seed: u64,
}

impl Default for SweepConfig {
    fn default() -> Self {
        Self {
            epochs: 9,
            steps_per_epoch: 30,
            batch: 256,
            container: Container::Bf16,
            sample: SAMPLE,
            seed: STREAM_SEED,
        }
    }
}

/// One epoch of a policy's trajectory (the Fig. 3-style series the JSON
/// output carries).
#[derive(Debug, Clone, Copy)]
pub struct EpochPoint {
    pub epoch: usize,
    pub mean_mant_a: f64,
    pub mean_mant_w: f64,
    pub mean_exp_a: f64,
    pub mean_exp_w: f64,
    /// Mean per-step stored bits over the epoch (plan accounting).
    pub plan_bits: f64,
    pub ratio_vs_fp32: f64,
}

/// Result of one (network, policy) sweep run.
#[derive(Debug, Clone)]
pub struct PolicyRunResult {
    pub policy: String,
    pub network: String,
    pub epochs: Vec<EpochPoint>,
    /// Per-step FP32 footprint of the same tensors (the denominator).
    pub fp32_bits: f64,
    /// End-of-run per-step footprint, fixed-width plan containers
    /// (averaged over the final epoch, so controllers that oscillate
    /// around their equilibrium report the equilibrium).
    pub plan_bits: f64,
    /// The final plan's fixed-width footprint (the exact container set the
    /// Gecko measurement stored — differs from `plan_bits` only for
    /// oscillating controllers).
    pub final_plan_bits: f64,
    /// Same tensors stored through the stash with Gecko on the exponent
    /// streams (measured, scaled to full tensor size).
    pub gecko_bits: f64,
    /// Ledger of the final stash measurement.
    pub ledger: LedgerSnapshot,
}

impl PolicyRunResult {
    /// Footprint reduction vs FP32 without Gecko (paper: QM+QE 4.74×,
    /// BitWave 3.19×).
    pub fn plan_reduction(&self) -> f64 {
        self.fp32_bits / self.plan_bits
    }

    /// With Gecko on the exponents (paper: 5.64× / 4.56×).
    pub fn gecko_reduction(&self) -> f64 {
        self.fp32_bits / self.gecko_bits
    }

    /// Reduction of the exact end-state containers (the apples-to-apples
    /// baseline for [`PolicyRunResult::gecko_reduction`]: same mantissa
    /// and sign bits, fixed-width vs Gecko exponents).
    pub fn final_plan_reduction(&self) -> f64 {
        self.fp32_bits / self.final_plan_bits
    }

    /// Trajectory + summary as JSON (the `repro policy` artifact).
    pub fn write_json(&self, path: &Path) -> Result<()> {
        use crate::coordinator::metrics::Summary;
        let mut s = Summary::new();
        s.str("policy", &self.policy)
            .str("network", &self.network)
            .num("fp32_bits", self.fp32_bits)
            .num("plan_bits", self.plan_bits)
            .num("final_plan_bits", self.final_plan_bits)
            .num("gecko_bits", self.gecko_bits)
            .num("plan_reduction", self.plan_reduction())
            .num("gecko_reduction", self.gecko_reduction())
            .nums(
                "epoch",
                &self.epochs.iter().map(|e| e.epoch as f64).collect::<Vec<_>>(),
            )
            .nums(
                "mean_mant_a",
                &self.epochs.iter().map(|e| e.mean_mant_a).collect::<Vec<_>>(),
            )
            .nums(
                "mean_mant_w",
                &self.epochs.iter().map(|e| e.mean_mant_w).collect::<Vec<_>>(),
            )
            .nums(
                "mean_exp_a",
                &self.epochs.iter().map(|e| e.mean_exp_a).collect::<Vec<_>>(),
            )
            .nums(
                "mean_exp_w",
                &self.epochs.iter().map(|e| e.mean_exp_w).collect::<Vec<_>>(),
            )
            .nums(
                "ratio_vs_fp32",
                &self.epochs.iter().map(|e| e.ratio_vs_fp32).collect::<Vec<_>>(),
            );
        s.write(path)
    }
}

/// Build the policy a sweep run drives (also the constructor the
/// checkpoint/restore property tests use).
pub fn build_policy(kind: PolicyKind, net: &NetworkTrace, cfg: &SweepConfig) -> Box<dyn BitPolicy> {
    let nonneg: Vec<bool> = net.layers.iter().map(|l| l.nonneg_act).collect();
    let n = net.layers.len().max(1);
    // surrogate targets from the repo's calibrated e2e bitlengths
    let qm_t = MantissaPolicy::qm_default();
    let targets: Vec<(f32, f32)> = (0..net.layers.len())
        .map(|i| {
            let f = i as f64 / n as f64;
            (
                qm_t.bits_at(f, false, cfg.container) as f32,
                qm_t.bits_at(f, true, cfg.container) as f32,
            )
        })
        .collect();
    match kind {
        PolicyKind::QmQe => Box::new(Composite::new(
            "qm+qe",
            Box::new(QuantumMantissa::surrogate(
                cfg.container,
                cfg.epochs,
                cfg.steps_per_epoch,
                nonneg.clone(),
                targets,
            )),
            Box::new(QuantumExponent::new(
                cfg.container,
                cfg.epochs,
                cfg.steps_per_epoch,
                nonneg,
            )),
        )),
        PolicyKind::QmOnly => Box::new(QuantumMantissa::surrogate(
            cfg.container,
            cfg.epochs,
            cfg.steps_per_epoch,
            nonneg,
            targets,
        )),
        PolicyKind::BitWave => Box::new(super::BitWave::new(cfg.container, nonneg)),
        PolicyKind::AdaptivFloat => Box::new(Composite::new(
            "qm+af",
            Box::new(QuantumMantissa::surrogate(
                cfg.container,
                cfg.epochs,
                cfg.steps_per_epoch,
                nonneg.clone(),
                targets,
            )),
            Box::new(AdaptivFloatPolicy::new(cfg.container, cfg.epochs, nonneg)),
        )),
        PolicyKind::Flexpoint => Box::new(FixedPolicy::flexpoint(net.layers.len())),
        PolicyKind::Fp8 => Box::new(FixedPolicy::fp8(net.layers.len())),
        PolicyKind::Bf16 => Box::new(FixedPolicy::bf16(net.layers.len())),
    }
}

/// One per-tensor sampled stream with its scale to full tensor size.
pub struct TensorStream {
    pub id: TensorId,
    pub vals: Vec<f32>,
    pub stats: ExpRangeStats,
    pub scale: f64,
}

/// Sample every tensor of `net` once (seeds mirror the analytic footprint
/// model / `repro stash`, so all three measurement paths see the same
/// streams).
pub fn sample_streams(net: &NetworkTrace, cfg: &SweepConfig) -> Vec<TensorStream> {
    let mut out = Vec::with_capacity(2 * net.layers.len());
    for (i, l) in net.layers.iter().enumerate() {
        let seed = cfg.seed ^ i as u64;
        let a_exps = l.act_model.sample_exponents(cfg.sample, seed ^ ACT_EXP_SEED);
        let a_vals = values_with_exponents(&a_exps, seed ^ ACT_VAL_SEED, l.nonneg_act);
        out.push(TensorStream {
            id: TensorId::act(i),
            stats: ExpRangeStats::from_exponents(&a_exps),
            vals: a_vals,
            scale: (l.act_elems * cfg.batch) as f64 / cfg.sample as f64,
        });
        let w_count = cfg.sample.min(l.weight_elems.max(64));
        let w_exps = l.weight_model.sample_exponents(w_count, seed ^ WEIGHT_EXP_SEED);
        let w_vals = values_with_exponents(&w_exps, seed ^ WEIGHT_VAL_SEED, false);
        out.push(TensorStream {
            id: TensorId::weight(i),
            stats: ExpRangeStats::from_exponents(&w_exps),
            vals: w_vals,
            scale: l.weight_elems as f64 / w_count as f64,
        });
    }
    out
}

/// Staged-decay loss model with LR drops and a mantissa-quantization
/// penalty — the feedback that makes BitWave's Eq. 9 controller settle at
/// a finite bitlength instead of chopping to zero.
pub struct LossModel {
    rng: SplitMix64,
    epochs: usize,
    drops: [usize; 2],
    steps_per_epoch: usize,
    floor: f64,
    amps: [f64; 3],
    decay: f64,
    noise: f64,
    mant_penalty: f64,
}

impl LossModel {
    pub fn new(cfg: &SweepConfig) -> Self {
        Self {
            rng: SplitMix64::new(cfg.seed ^ 0x105),
            epochs: cfg.epochs.max(1),
            drops: [cfg.epochs / 3, 2 * cfg.epochs / 3],
            steps_per_epoch: cfg.steps_per_epoch.max(1),
            floor: 0.5,
            amps: [2.0, 0.6, 0.25],
            decay: 5.0,
            noise: 0.012,
            // Quantization-noise cliff: 12·2⁻ᵐ makes one more chopped bit
            // visibly worsen the loss once m reaches ~4, exactly where the
            // paper's Fig. 7 shows BitWave's controller settling — below
            // that the penalty step exceeds the Eq. 9 ε and the controller
            // restores; above it the step is lost in the noise.
            mant_penalty: 12.0,
        }
    }

    /// Segment index and its starting epoch for `epoch`.
    fn segment(&self, epoch: usize) -> (usize, usize) {
        if epoch < self.drops[0] {
            (0, 0)
        } else if epoch < self.drops[1] {
            (1, self.drops[0])
        } else {
            (2, self.drops[1])
        }
    }

    /// The LR drops before `epoch` begins (the Trainer's staged schedule).
    pub fn lr_drops_at(&self, epoch: usize, step_in_epoch: usize) -> bool {
        step_in_epoch == 0 && epoch > 0 && self.drops.contains(&epoch)
    }

    /// Observed task loss for this step given the mean activation mantissa
    /// bits currently applied (the quantization-noise feedback term).
    pub fn loss(&mut self, epoch: usize, step_in_epoch: usize, mean_mant: f64) -> f64 {
        let (seg, seg_start) = self.segment(epoch);
        let seg_epochs = match seg {
            0 => self.drops[0],
            1 => self.drops[1] - self.drops[0],
            _ => self.epochs.saturating_sub(self.drops[1]),
        }
        .max(1);
        let steps_in = ((epoch - seg_start) * self.steps_per_epoch + step_in_epoch) as f64;
        let t_in = steps_in / (seg_epochs * self.steps_per_epoch) as f64;
        self.floor
            + self.amps[seg] * (-self.decay * t_in).exp()
            + self.mant_penalty * 2f64.powf(-mean_mant)
            + self.noise * self.rng.next_gaussian()
    }
}

/// Per-step stored bits of the whole network under `plan` (plan
/// accounting, via the hwsim coupling).
pub fn plan_step_bits(
    net: &NetworkTrace,
    plan: &NetworkPlan,
    batch: usize,
    container: Container,
) -> f64 {
    hwsim::layer_bits_from_plans(net, plan, batch, container)
        .iter()
        .map(|b| b.weight + b.act)
        .sum()
}

/// Run one policy over one trace network.
pub fn run_policy(
    net: &NetworkTrace,
    kind: PolicyKind,
    cfg: &SweepConfig,
) -> Result<PolicyRunResult> {
    let streams = sample_streams(net, cfg);
    let n = net.layers.len();
    let act_stats: Vec<ExpRangeStats> =
        (0..n).map(|i| streams[2 * i].stats.clone()).collect();
    let weight_stats: Vec<ExpRangeStats> =
        (0..n).map(|i| streams[2 * i + 1].stats.clone()).collect();

    let fp32_bits: f64 = net
        .layers
        .iter()
        .map(|l| 32.0 * ((l.act_elems * cfg.batch) as f64 + l.weight_elems as f64))
        .sum();

    let mut policy = build_policy(kind, net, cfg);
    let mut loss_model = LossModel::new(cfg);
    let mut epochs = Vec::with_capacity(cfg.epochs);
    let mut step = 0usize;
    for epoch in 0..cfg.epochs {
        let mut epoch_bits = 0.0;
        for s in 0..cfg.steps_per_epoch {
            let lr_changed = loss_model.lr_drops_at(epoch, s);
            if lr_changed {
                policy.notify_lr_change();
            }
            let mean_mant = policy.plan().mean_act_mant();
            let loss = loss_model.loss(epoch, s, mean_mant);
            let plan = policy.observe(&super::StepSignals {
                epoch,
                step,
                loss,
                lr_changed,
                learned_n_a: None,
                learned_n_w: None,
                act_stats: &act_stats,
                weight_stats: &weight_stats,
            });
            epoch_bits += plan_step_bits(net, &plan, cfg.batch, cfg.container);
            step += 1;
        }
        let plan = policy.plan();
        let mean_bits = epoch_bits / cfg.steps_per_epoch.max(1) as f64;
        epochs.push(EpochPoint {
            epoch,
            mean_mant_a: plan.mean_act_mant(),
            mean_mant_w: plan.mean_weight_mant(),
            mean_exp_a: plan.mean_act_exp(),
            mean_exp_w: plan.mean_weight_exp(),
            plan_bits: mean_bits,
            ratio_vs_fp32: mean_bits / fp32_bits,
        });
    }

    // ---- end-of-run footprint: mean plan bits over the final epoch, and
    // the same tensors stored through the stash with Gecko exponents.
    let plan_bits = epochs
        .last()
        .map(|e| e.plan_bits)
        .ok_or_else(|| anyhow!("sweep ran zero epochs"))?;
    let plan = policy.plan();
    let final_plan_bits = plan_step_bits(net, &plan, cfg.batch, cfg.container);
    let stash = Stash::new(StashConfig {
        codec: CodecKind::Gecko,
        ..Default::default()
    });
    for s in &streams {
        let meta: ContainerMeta = match s.id.class {
            crate::stash::TensorClass::Activation => plan.acts[s.id.layer].meta(cfg.container),
            crate::stash::TensorClass::Weight => plan.weights[s.id.layer].meta(cfg.container),
        };
        stash.put(s.id, s.vals.clone(), meta);
    }
    stash.flush();
    if stash.failures() > 0 {
        return Err(anyhow!("{} stash encode jobs failed", stash.failures()));
    }
    let mut gecko_bits = 0.0;
    for s in &streams {
        let bits = stash
            .stored_bits(s.id)
            .ok_or_else(|| anyhow!("{:?} not resident after sweep encode", s.id))?;
        gecko_bits += bits.total() * s.scale;
    }
    let ledger = stash.ledger();

    Ok(PolicyRunResult {
        policy: kind.label().to_string(),
        network: net.name.clone(),
        epochs,
        fp32_bits,
        plan_bits,
        final_plan_bits,
        gecko_bits,
        ledger,
    })
}

/// Checkpoint a policy mid-run and verify (used by `repro policy
/// --verify-restore` and the property tests): a fresh policy restored from
/// the checkpoint must continue with identical plans.
pub fn verify_restore_continuation(
    net: &NetworkTrace,
    kind: PolicyKind,
    cfg: &SweepConfig,
    split_step: usize,
    extra_steps: usize,
) -> Result<Json> {
    let streams = sample_streams(net, cfg);
    let n = net.layers.len();
    let act_stats: Vec<ExpRangeStats> =
        (0..n).map(|i| streams[2 * i].stats.clone()).collect();
    let weight_stats: Vec<ExpRangeStats> =
        (0..n).map(|i| streams[2 * i + 1].stats.clone()).collect();
    let spe = cfg.steps_per_epoch.max(1);

    let drive = |policy: &mut dyn BitPolicy,
                 from: usize,
                 to: usize,
                 losses: &mut LossModel|
     -> Vec<NetworkPlan> {
        let mut plans = Vec::new();
        for step in from..to {
            let (epoch, s) = (step / spe, step % spe);
            let lr_changed = losses.lr_drops_at(epoch, s);
            if lr_changed {
                policy.notify_lr_change();
            }
            let mean_mant = policy.plan().mean_act_mant();
            let loss = losses.loss(epoch, s, mean_mant);
            plans.push(policy.observe(&super::StepSignals {
                epoch,
                step,
                loss,
                lr_changed,
                learned_n_a: None,
                learned_n_w: None,
                act_stats: &act_stats,
                weight_stats: &weight_stats,
            }));
        }
        plans
    };

    let mut p1 = build_policy(kind, net, cfg);
    let mut lm1 = LossModel::new(cfg);
    drive(p1.as_mut(), 0, split_step, &mut lm1);
    let ck = p1.checkpoint();

    let mut p2 = build_policy(kind, net, cfg);
    p2.restore(&ck)?;
    if p2.checkpoint() != ck {
        return Err(anyhow!("checkpoint not bit-stable through restore"));
    }
    // drive p2's loss model through the prefix so both see the same tail
    let mut lm2 = LossModel::new(cfg);
    for step in 0..split_step {
        let (epoch, s) = (step / spe, step % spe);
        // replay the exact mean-mantissa feedback p1 saw is unnecessary:
        // the RNG is the only stateful part, so burn the same draws
        let _ = lm2.loss(epoch, s, 0.0);
    }
    let a = drive(p1.as_mut(), split_step, split_step + extra_steps, &mut lm1);
    let b = drive(p2.as_mut(), split_step, split_step + extra_steps, &mut lm2);
    if a != b {
        return Err(anyhow!(
            "restored policy diverged within {extra_steps} steps of the split"
        ));
    }
    Ok(ck)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::traces::{mobilenet_v3_small, resnet18};

    fn quick_cfg() -> SweepConfig {
        SweepConfig {
            sample: 16 * 1024,
            ..Default::default()
        }
    }

    #[test]
    fn sweep_reproduces_paper_ordering() {
        let cfg = quick_cfg();
        let mut qmqe_sum = 0.0;
        let mut bw_sum = 0.0;
        for net in [resnet18(), mobilenet_v3_small()] {
            let qmqe = run_policy(&net, PolicyKind::QmQe, &cfg).unwrap();
            let bw = run_policy(&net, PolicyKind::BitWave, &cfg).unwrap();
            let qm = run_policy(&net, PolicyKind::QmOnly, &cfg).unwrap();
            // per-network ordering: QM+QE beats BitWave; Gecko on the
            // exponents improves both (the paper's 4.74→5.64 / 3.19→4.56)
            assert!(
                qmqe.plan_reduction() > bw.plan_reduction(),
                "{}: qm+qe {:.2}x vs bitwave {:.2}x",
                net.name,
                qmqe.plan_reduction(),
                bw.plan_reduction()
            );
            assert!(
                qmqe.gecko_reduction() > qmqe.final_plan_reduction(),
                "{}: gecko must improve qm+qe ({:.2}x vs {:.2}x)",
                net.name,
                qmqe.gecko_reduction(),
                qmqe.final_plan_reduction()
            );
            assert!(
                bw.gecko_reduction() > bw.final_plan_reduction(),
                "{}: gecko must improve bitwave ({:.2}x vs {:.2}x)",
                net.name,
                bw.gecko_reduction(),
                bw.final_plan_reduction()
            );
            // exponent adaptation is the load-bearing half: QM alone
            // (8-bit exponents) compresses far less than QM+QE
            assert!(
                qm.plan_reduction() < qmqe.plan_reduction() - 0.5,
                "{}: qm-only {:.2}x vs qm+qe {:.2}x",
                net.name,
                qm.plan_reduction(),
                qmqe.plan_reduction()
            );
            // Fig. 7 fidelity: BitWave's controller must settle at a few
            // mantissa bits, not collapse toward zero (a collapse would
            // also flip the QM+QE ordering above)
            let bw_mant = bw.epochs.last().unwrap().mean_mant_a;
            assert!(
                (3.0..=6.5).contains(&bw_mant),
                "{}: bitwave end mantissa {bw_mant:.1}",
                net.name
            );
            qmqe_sum += qmqe.plan_reduction();
            bw_sum += bw.plan_reduction();
        }
        // paper bands: QM+QE 4.74×, BitWave 3.19× (averaged over networks;
        // the sweep lands ≈4.9× and ≈3.4× — gates leave margin for the
        // controller settling one bit away across stream seeds)
        let qmqe_avg = qmqe_sum / 2.0;
        let bw_avg = bw_sum / 2.0;
        assert!(qmqe_avg >= 4.4, "qm+qe average reduction {qmqe_avg:.2}x");
        assert!(bw_avg >= 2.8, "bitwave average reduction {bw_avg:.2}x");
        assert!(bw_avg < qmqe_avg, "ordering");
    }

    #[test]
    fn trajectories_descend_and_emit() {
        let cfg = quick_cfg();
        let net = resnet18();
        let res = run_policy(&net, PolicyKind::QmQe, &cfg).unwrap();
        assert_eq!(res.epochs.len(), cfg.epochs);
        let first = &res.epochs[0];
        let last = res.epochs.last().unwrap();
        assert!(last.mean_mant_a < first.mean_mant_a, "mantissa descends");
        assert!(last.mean_exp_a < first.mean_exp_a, "exponent descends");
        assert!(last.ratio_vs_fp32 < first.ratio_vs_fp32);
        // JSON artifact writes and parses back
        let dir = std::env::temp_dir().join("sfp_policy_sweep_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("traj.json");
        res.write_json(&p).unwrap();
        let j = crate::util::json::Json::parse(&std::fs::read_to_string(&p).unwrap()).unwrap();
        assert_eq!(j.get("policy").unwrap().as_str(), Some("qm+qe"));
        assert_eq!(
            j.get("mean_exp_a").unwrap().as_arr().unwrap().len(),
            cfg.epochs
        );
    }

    #[test]
    fn stash_measurement_consistent_with_ledger() {
        let cfg = SweepConfig {
            sample: 8 * 1024,
            ..quick_cfg()
        };
        let net = mobilenet_v3_small();
        let res = run_policy(&net, PolicyKind::BitWave, &cfg).unwrap();
        // unscaled ledger totals must equal the sum the sweep scaled
        assert!(res.ledger.written_bits > 0.0);
        assert!(res.gecko_bits > 0.0);
        assert!(res.ledger.ratio_vs_fp32() < 1.0);
    }

    #[test]
    fn mid_run_restore_continues_identically_all_policies() {
        let cfg = SweepConfig {
            sample: 4 * 1024,
            ..quick_cfg()
        };
        let net = resnet18();
        for kind in PolicyKind::all() {
            // split inside epoch 1 and again right after the first LR drop
            for split in [40, cfg.steps_per_epoch * (cfg.epochs / 3) + 3] {
                verify_restore_continuation(&net, kind, &cfg, split, 50)
                    .unwrap_or_else(|e| panic!("{kind:?} split {split}: {e}"));
            }
        }
    }
}
