//! AdaptivFloat behind the [`BitPolicy`] trait (PAPERS.md): a per-tensor
//! *learned exponent bias* instead of a learned field width.
//!
//! AdaptivFloat keeps a short fixed-width exponent field and recenters it
//! on each tensor's observed dynamic range with a per-tensor bias — the
//! same range signal Quantum Exponent consumes, spent on window *position*
//! rather than window *size*.  The policy runs a full-precision warmup
//! (ranges early in training move too much to commit a window), then fits
//! each tensor's [`ExponentLayout::Bias`] from the streaming statistics
//! every period: the window top is pinned to the observed maximum
//! exponent, because saturating a stashed tensor corrupts the values the
//! backward pass restores, while the values below the window are the
//! tensor's smallest and flushing them is the quantization AdaptivFloat
//! accepts.
//!
//! The policy owns only the exponent axis (plans carry the container's
//! full mantissa); compose with Quantum Mantissa for the cross-paper
//! QM+AF variant.  Every window fit or shift is reported to the flight
//! recorder as an exponent-layout event, so `repro inspect` shows the
//! per-layer layout trajectory next to the bitlength one.

use super::{BitPolicy, ContainerPlan, NetworkPlan, StepSignals};
use crate::formats::{Container, ExponentLayout};
use crate::stats::ExpRangeStats;
use crate::util::json::Json;
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;

/// Exponent window width of the fitted layouts (AdaptivFloat's short
/// exponent field; 2⁴−1 codes cover 15 octaves, ample for trained
/// tensors whose ranges span ~6–10).
const WINDOW_BITS: u32 = 4;

pub struct AdaptivFloatPolicy {
    container: Container,
    nonneg_act: Vec<bool>,
    /// First epoch the windows are fitted; before it every tensor stays
    /// at the full-width default layout.
    fit_epoch: usize,
    /// Current per-tensor exponent layouts (default until fitted).
    layout_a: Vec<ExponentLayout>,
    layout_w: Vec<ExponentLayout>,
    /// Last layouts reported to the flight recorder — observational
    /// only, deliberately outside checkpoint/restore.
    emitted_a: Vec<ExponentLayout>,
    emitted_w: Vec<ExponentLayout>,
}

impl AdaptivFloatPolicy {
    pub fn new(container: Container, epochs: usize, nonneg_act: Vec<bool>) -> Self {
        let layers = nonneg_act.len();
        Self {
            container,
            nonneg_act,
            // the same warmup third the γ schedule spends at high noise
            fit_epoch: (epochs / 3).max(1),
            layout_a: vec![ExponentLayout::default(); layers],
            layout_w: vec![ExponentLayout::default(); layers],
            emitted_a: vec![ExponentLayout::default(); layers],
            emitted_w: vec![ExponentLayout::default(); layers],
        }
    }

    /// Fit one tensor's bias window: the window top sits on the observed
    /// maximum biased exponent (no saturation on the range seen so far).
    fn fit_layout(stats: &ExpRangeStats) -> ExponentLayout {
        let half = 1i32 << (WINDOW_BITS - 1);
        let bias = (stats.max_exp as i32 - half + 1).clamp(1, 254) as u8;
        ExponentLayout::Bias {
            bits: WINDOW_BITS,
            bias,
        }
    }

    fn make_plan(&self) -> NetworkPlan {
        let mant = self.container.mant_bits() as f32;
        let acts = self
            .layout_a
            .iter()
            .zip(&self.nonneg_act)
            .map(|(&layout, &nonneg)| ContainerPlan {
                mant,
                layout,
                elide_sign: nonneg,
            })
            .collect();
        let weights = self
            .layout_w
            .iter()
            .map(|&layout| ContainerPlan {
                mant,
                layout,
                elide_sign: false,
            })
            .collect();
        NetworkPlan { acts, weights }
    }

    /// Report layout switches for one tensor class to the flight recorder.
    fn emit_layout_changes(
        class: &'static str,
        layouts: &[ExponentLayout],
        emitted: &mut [ExponentLayout],
        sig: &StepSignals,
    ) {
        for (i, (l, last)) in layouts.iter().zip(emitted.iter_mut()).enumerate() {
            if *l != *last {
                let trigger = if last.is_default() {
                    "af_window_fit"
                } else {
                    "af_window_shift"
                };
                crate::obs::events::layout_change(
                    "af",
                    trigger,
                    class,
                    Some(i),
                    sig.epoch,
                    sig.step,
                    last.field_bits() as f64,
                    l.field_bits() as f64,
                    format!("{} -> {}", last.label(), l.label()),
                );
                *last = *l;
            }
        }
    }
}

fn layouts_to_json(ls: &[ExponentLayout]) -> Json {
    Json::Arr(ls.iter().map(|l| l.to_json()).collect())
}

fn layouts_from_json(state: &Json, key: &str) -> Result<Vec<ExponentLayout>> {
    state
        .get(key)
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("af state: missing array '{key}'"))?
        .iter()
        .map(ExponentLayout::from_json)
        .collect()
}

impl BitPolicy for AdaptivFloatPolicy {
    fn name(&self) -> &'static str {
        "af"
    }

    fn observe(&mut self, sig: &StepSignals) -> NetworkPlan {
        if sig.epoch >= self.fit_epoch {
            for (i, stats) in sig.act_stats.iter().enumerate() {
                if stats.count > 0 {
                    if let Some(l) = self.layout_a.get_mut(i) {
                        *l = Self::fit_layout(stats);
                    }
                }
            }
            for (i, stats) in sig.weight_stats.iter().enumerate() {
                if stats.count > 0 {
                    if let Some(l) = self.layout_w.get_mut(i) {
                        *l = Self::fit_layout(stats);
                    }
                }
            }
        }
        Self::emit_layout_changes("act", &self.layout_a, &mut self.emitted_a, sig);
        Self::emit_layout_changes("weight", &self.layout_w, &mut self.emitted_w, sig);
        self.make_plan()
    }

    fn plan(&self) -> NetworkPlan {
        self.make_plan()
    }

    fn checkpoint(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("layout_a".to_string(), layouts_to_json(&self.layout_a));
        o.insert("layout_w".to_string(), layouts_to_json(&self.layout_w));
        Json::Obj(o)
    }

    fn restore(&mut self, state: &Json) -> Result<()> {
        self.layout_a = layouts_from_json(state, "layout_a")?;
        self.layout_w = layouts_from_json(state, "layout_w")?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::traces::ValueModel;

    fn stats_for(model: ValueModel, seed: u64) -> ExpRangeStats {
        ExpRangeStats::from_exponents(&model.sample_exponents(16 * 1024, seed))
    }

    fn sig<'a>(
        epoch: usize,
        step: usize,
        a: &'a [ExpRangeStats],
        w: &'a [ExpRangeStats],
    ) -> StepSignals<'a> {
        StepSignals {
            epoch,
            step,
            loss: 1.0,
            lr_changed: false,
            learned_n_a: None,
            learned_n_w: None,
            act_stats: a,
            weight_stats: w,
        }
    }

    #[test]
    fn warmup_stays_full_width_then_fits_windows() {
        let act = vec![stats_for(ValueModel::relu_act(), 11)];
        let wgt = vec![stats_for(ValueModel::weights(), 13)];
        let mut p = AdaptivFloatPolicy::new(Container::Bf16, 9, vec![true]);
        // epoch 0-2: warmup third of a 9-epoch run
        let plan = p.observe(&sig(0, 0, &act, &wgt));
        assert!(plan.acts[0].layout.is_default());
        assert_eq!(plan.acts[0].exp_bits(), 8);
        // past the warmup: fitted 4-bit bias windows
        let plan = p.observe(&sig(3, 90, &act, &wgt));
        let (_, hi) = plan.acts[0].layout.bias_window().expect("bias layout");
        assert_eq!(hi, act[0].max_exp as i32, "window top on the observed max");
        assert_eq!(plan.acts[0].exp_bits(), WINDOW_BITS);
        assert_eq!(plan.weights[0].exp_bits(), WINDOW_BITS);
        // the exponent half leaves the mantissa at container precision
        assert_eq!(plan.acts[0].mant, 7.0);
        assert!(plan.acts[0].elide_sign);
        assert!(!plan.weights[0].elide_sign);
    }

    #[test]
    fn missing_stats_keep_the_default_layout() {
        let mut p = AdaptivFloatPolicy::new(Container::Bf16, 6, vec![false; 2]);
        for s in 0..80 {
            p.observe(&sig(s / 20, s, &[], &[]));
        }
        assert!(p.plan().acts.iter().all(|c| c.layout.is_default()));
    }

    #[test]
    fn window_fit_emits_layout_events() {
        crate::obs::events::capture_begin();
        let act = vec![stats_for(ValueModel::relu_act(), 5)];
        let wgt = vec![stats_for(ValueModel::weights(), 7)];
        let mut p = AdaptivFloatPolicy::new(Container::Bf16, 6, vec![false]);
        for s in 0..80 {
            p.observe(&sig(s / 20, s, &act, &wgt));
        }
        let events = crate::obs::events::capture_end();
        let af: Vec<_> = events.iter().filter(|e| e.source == "af").collect();
        assert_eq!(af.len(), 2, "one fit per tensor, then stable");
        for e in &af {
            assert_eq!(e.kind, "layout");
            assert_eq!(e.trigger, "af_window_fit");
            assert_eq!(e.component.as_deref(), Some("exp"));
            assert_eq!(e.from, 8.0);
            assert_eq!(e.to, WINDOW_BITS as f64);
            let d = e.detail.as_deref().expect("layout events carry labels");
            assert!(d.starts_with("w8 -> af"), "detail {d}");
        }
    }

    #[test]
    fn checkpoint_roundtrip_stable() {
        let act = vec![stats_for(ValueModel::relu_act(), 3)];
        let wgt = vec![stats_for(ValueModel::weights(), 5)];
        let mut p = AdaptivFloatPolicy::new(Container::Bf16, 6, vec![true]);
        for s in 0..70 {
            p.observe(&sig(s / 20, s, &act, &wgt));
        }
        let ck = p.checkpoint();
        let mut q = AdaptivFloatPolicy::new(Container::Bf16, 6, vec![true]);
        q.restore(&ck).unwrap();
        assert_eq!(ck, q.checkpoint());
        assert_eq!(p.plan(), q.plan());
    }
}
