//! The training orchestrator: drives the AOT-compiled train/eval steps
//! through PJRT, applies the active adaptation policy's per-tensor
//! [`ContainerPlan`]s (FP32 / BF16 baselines, SFP_QM, SFP_BC, SFP_QM+QE,
//! SFP_BitWave) to both the step knobs and the stash's container metadata
//! live each period, and keeps the exact footprint ledger the tables and
//! figures read.
//!
//! All adaptation decisions route through one [`BitPolicy`] engine
//! ([`crate::policy`]): the Trainer feeds it per-period
//! [`StepSignals`](crate::policy::StepSignals) (loss, learned bitlengths
//! from the compiled step, exponent-range stats of the stashed tensors)
//! and applies the returned plans; the compiled step only exposes knobs
//! (`n_w`, `n_a`, `lr_n`, `gamma`, `stochastic`, `mmax`).
//!
//! The stash round-trip is double-buffered: step N's encodes and step
//! N−1's restore-prefetch (queued via [`Stash::take_deferred`]) both run
//! on the stash worker pool *while* step N's compiled call executes, so
//! encode/decode latency hides behind compute; the post-call barrier
//! verifies the prefetched restores bit-exact, and epoch boundaries drain
//! the pipeline so ledger cuts stay step-aligned.

use super::data::{init_params, DataGen};
use super::metrics::{CsvSink, Summary};
use crate::formats::Container;
use crate::policy::{
    BitChopPolicy, BitPolicy, Composite, FixedPolicy, NetworkPlan, QuantumExponent,
    QuantumMantissa, StepSignals,
};
use crate::runtime::{HostTensor, Runtime};
use crate::stash::{
    ContainerMeta, EpochTraffic, LedgerSnapshot, RestoreTicket, Stash, StashConfig, TensorId,
};
use crate::stats::{BitlengthHistogram, ComponentBits, ExpRangeStats, Footprint};
use anyhow::{anyhow, Result};
use std::path::PathBuf;

/// Which compression scheme the run uses (Table I / II row labels).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    /// Uncompressed FP32 baseline.
    Fp32,
    /// Uncompressed BFloat16 baseline.
    Bf16,
    /// Gecko + Quantum Mantissa over the given container.
    SfpQm(Container),
    /// Gecko + BitChop over the given container.
    SfpBc(Container),
    /// Quantum Mantissa + Quantum Exponent (the paper's headline pair):
    /// learned per-layer mantissa *and* exponent bitlengths.
    SfpQmQe(Container),
    /// BitWave: loss-driven network-wide mantissa + exponent bitlengths.
    SfpBw(Container),
    /// Quantum Mantissa + AdaptivFloat: learned mantissa bitlengths with a
    /// per-tensor exponent bias window fitted from the range statistics.
    SfpAf(Container),
}

impl Variant {
    pub fn container(&self) -> Container {
        match self {
            Variant::Fp32 => Container::Fp32,
            Variant::Bf16 => Container::Bf16,
            Variant::SfpQm(c)
            | Variant::SfpBc(c)
            | Variant::SfpQmQe(c)
            | Variant::SfpBw(c)
            | Variant::SfpAf(c) => *c,
        }
    }

    pub fn label(&self) -> String {
        match self {
            Variant::Fp32 => "fp32".into(),
            Variant::Bf16 => "bf16".into(),
            Variant::SfpQm(c) => format!("sfp_qm_{}", c).to_lowercase(),
            Variant::SfpBc(c) => format!("sfp_bc_{}", c).to_lowercase(),
            Variant::SfpQmQe(c) => format!("sfp_qmqe_{}", c).to_lowercase(),
            Variant::SfpBw(c) => format!("sfp_bw_{}", c).to_lowercase(),
            Variant::SfpAf(c) => format!("sfp_af_{}", c).to_lowercase(),
        }
    }

    pub fn parse(s: &str, container: Container) -> Option<Variant> {
        match s {
            "fp32" => Some(Variant::Fp32),
            "bf16" => Some(Variant::Bf16),
            "qm" | "sfp_qm" => Some(Variant::SfpQm(container)),
            "bc" | "sfp_bc" => Some(Variant::SfpBc(container)),
            "qmqe" | "qm_qe" | "sfp_qmqe" => Some(Variant::SfpQmQe(container)),
            "bw" | "bitwave" | "sfp_bw" => Some(Variant::SfpBw(container)),
            "af" | "adaptivfloat" | "sfp_af" => Some(Variant::SfpAf(container)),
            _ => None,
        }
    }

    /// Adapts mantissa bitlengths through the compiled step's in-graph
    /// learner (the QM family).
    fn learns_mantissa_in_graph(&self) -> bool {
        matches!(
            self,
            Variant::SfpQm(_) | Variant::SfpQmQe(_) | Variant::SfpAf(_)
        )
    }

    /// Needs per-period exponent-range statistics (the exponent-adapting
    /// policies).
    fn needs_exp_stats(&self) -> bool {
        matches!(
            self,
            Variant::SfpQmQe(_) | Variant::SfpBw(_) | Variant::SfpAf(_)
        )
    }

    /// Build the adaptation policy driving this variant.
    fn build_policy(
        &self,
        layers: usize,
        epochs: usize,
        steps_per_epoch: usize,
    ) -> Box<dyn BitPolicy> {
        let c = self.container();
        // the e2e model's manifest does not declare non-negative outputs,
        // so sign elision stays off on this path (the trace sweeps set it
        // from the layer traces instead)
        let nonneg = vec![false; layers];
        match self {
            Variant::Fp32 | Variant::Bf16 => Box::new(FixedPolicy::new(c, layers)),
            Variant::SfpQm(_) => Box::new(QuantumMantissa::e2e(c, layers, epochs)),
            Variant::SfpBc(_) => Box::new(BitChopPolicy::new(c, layers)),
            Variant::SfpQmQe(_) => Box::new(Composite::new(
                "qm+qe",
                Box::new(QuantumMantissa::e2e(c, layers, epochs)),
                Box::new(QuantumExponent::new(c, epochs, steps_per_epoch, nonneg)),
            )),
            Variant::SfpBw(_) => Box::new(crate::policy::BitWave::new(c, nonneg)),
            Variant::SfpAf(_) => Box::new(Composite::new(
                "qm+af",
                Box::new(QuantumMantissa::e2e(c, layers, epochs)),
                Box::new(crate::policy::AdaptivFloatPolicy::new(c, epochs, nonneg)),
            )),
        }
    }
}

#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub variant: Variant,
    pub epochs: usize,
    pub steps_per_epoch: usize,
    pub eval_batches: usize,
    pub lr0: f32,
    pub momentum: f32,
    pub seed: u64,
    /// Where CSV/JSON metrics land (created if missing); None = no files.
    pub out_dir: Option<PathBuf>,
    /// Route every step's post-forward tensors through the compressed
    /// stash (encode via the worker pool, restore for backward).  None =
    /// the analytic footprint ledger only.
    pub stash: Option<StashConfig>,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            variant: Variant::Fp32,
            epochs: 6,
            steps_per_epoch: 50,
            eval_batches: 4,
            lr0: 0.05,
            momentum: 0.9,
            seed: 42,
            out_dir: None,
            stash: None,
        }
    }
}

/// Per-epoch record (rows of figs 2/3/6/7).
#[derive(Debug, Clone, Default)]
pub struct EpochStats {
    pub epoch: usize,
    pub train_loss: f64,
    pub val_acc: f64,
    pub val_loss: f64,
    pub mean_bits_w: f64,
    pub mean_bits_a: f64,
    /// Weighted (by footprint λ) mean activation bits — fig 3's solid line.
    pub wmean_bits_a: f64,
    pub per_layer_bits_a: Vec<f64>,
    pub per_layer_bits_w: Vec<f64>,
    /// Mean planned exponent field widths at epoch end (8 = full IEEE
    /// field; below 8 only for the exponent-adapting variants).
    pub mean_exp_bits_a: f64,
    pub mean_exp_bits_w: f64,
}

/// Result of one full run.
#[derive(Debug, Clone, Default)]
pub struct RunResult {
    pub label: String,
    pub epochs: Vec<EpochStats>,
    pub final_val_acc: f64,
    /// Cumulative stashed footprint over the whole run, this variant.
    pub footprint: Footprint,
    /// Same tensors at uncompressed FP32 / BF16 (Table I denominators).
    pub footprint_fp32: Footprint,
    pub footprint_bf16: Footprint,
    /// BitChop bitlength histogram across all batches (fig 8).
    pub bc_histogram: BitlengthHistogram,
    /// Final learned bitlengths (QM).
    pub final_n_w: Vec<f32>,
    pub final_n_a: Vec<f32>,
    /// Stash ledger totals when the run stored real compressed tensors
    /// (`TrainConfig::stash`): actually-written/read bytes vs FP32.
    pub stash: Option<LedgerSnapshot>,
    /// Per-epoch stash traffic (footprint-over-time; empty without stash).
    pub stash_epochs: Vec<EpochTraffic>,
    /// Adaptation events recorded on the training thread during the run
    /// (thread-local flight-recorder capture: program order, identical
    /// across backends) — the replay source for
    /// [`crate::report::figures::footprint_over_time`].
    pub events: Vec<crate::obs::AdaptEvent>,
}

/// Sources and metadata of one step's stashed tensors, held across the
/// double-buffered pipeline (stashed during step N, restore-prefetched
/// while step N+1's compiled call runs) for post-restore verification.
struct StashedStep {
    acts: Vec<HostTensor>,
    ws: Vec<HostTensor>,
    meta_a: Vec<ContainerMeta>,
    meta_w: Vec<ContainerMeta>,
}

impl StashedStep {
    fn ids(&self) -> Vec<TensorId> {
        (0..self.acts.len())
            .map(TensorId::act)
            .chain((0..self.ws.len()).map(TensorId::weight))
            .collect()
    }
}

pub struct Trainer<'rt> {
    rt: &'rt Runtime,
    cfg: TrainConfig,
    gen: DataGen,
    // state
    ws: Vec<HostTensor>,
    bs: Vec<HostTensor>,
    mws: Vec<HostTensor>,
    mbs: Vec<HostTensor>,
    n_w: Vec<f32>,
    n_a: Vec<f32>,
    /// The unified adaptation engine driving this variant.
    policy: Box<dyn BitPolicy>,
    /// Plan currently applied to the step knobs + stash metadata.
    plan: NetworkPlan,
    /// Exponent-range stats of the latest period's tensors (collected on
    /// the stash path; empty otherwise).
    stats_a: Vec<ExpRangeStats>,
    stats_w: Vec<ExpRangeStats>,
    lr: f32,
    step: i32,
    stash: Option<Stash>,
    /// Previous step's stashed tensors, encoded and visible but not yet
    /// restored — the in-flight half of the double buffer.
    pending: Option<StashedStep>,
}

impl<'rt> Trainer<'rt> {
    pub fn new(rt: &'rt Runtime, cfg: TrainConfig) -> Trainer<'rt> {
        let m = &rt.manifest;
        let (ws, bs) = init_params(&m.weight_shapes, &m.bias_shapes, cfg.seed);
        let mws = ws
            .iter()
            .map(|w| HostTensor::f32(&w.shape, vec![0.0; w.elems()]))
            .collect();
        let mbs = bs
            .iter()
            .map(|b| HostTensor::f32(&b.shape, vec![0.0; b.elems()]))
            .collect();
        let mmax = cfg.variant.container().mant_bits() as f32;
        let l = m.num_layers();
        let gen = DataGen::new(&m.image, m.num_classes, m.batch, cfg.seed ^ 0xDA7A);
        let policy = cfg.variant.build_policy(l, cfg.epochs, cfg.steps_per_epoch);
        let plan = policy.plan();
        Trainer {
            rt,
            gen,
            ws,
            bs,
            mws,
            mbs,
            n_w: vec![mmax; l],
            n_a: vec![mmax; l],
            policy,
            plan,
            stats_a: Vec::new(),
            stats_w: Vec::new(),
            lr: cfg.lr0,
            step: 0,
            stash: cfg.stash.map(Stash::new),
            pending: None,
            cfg,
        }
    }

    fn mmax(&self) -> f32 {
        self.cfg.variant.container().mant_bits() as f32
    }

    /// Write the current plan's mantissa bitlengths into the step's `n`
    /// vectors (fractional for the in-graph learners; the stash ceils).
    fn apply_plan(&mut self) {
        let mmax = self.mmax();
        for (n, p) in self.n_a.iter_mut().zip(&self.plan.acts) {
            *n = p.mant.clamp(0.0, mmax);
        }
        for (n, p) in self.n_w.iter_mut().zip(&self.plan.weights) {
            *n = p.mant.clamp(0.0, mmax);
        }
    }

    /// Execute one training step; returns (task_loss, per-layer used bits,
    /// gecko exponent bits, zero fractions).
    #[allow(clippy::type_complexity)]
    fn train_step(
        &mut self,
        epoch: usize,
    ) -> Result<(f64, Vec<i32>, Vec<i32>, Vec<f32>, Vec<f32>, Vec<f32>)> {
        let (lr_n, gamma, stochastic) = self.policy.step_hyper(epoch);
        self.apply_plan();
        // Double-buffered stash pipeline: queue the *previous* step's
        // restore-prefetch first (its entries leave the stash now, so this
        // step's puts under the same ids can't race it), then queue this
        // step's encodes — both directions run on the worker pool while
        // the compiled step below executes, hiding stash latency behind
        // compute.  The barrier + bit-exact verification happen after the
        // step returns.
        let prefetch = {
            let _sp = crate::obs::span("train", "restore_prefetch");
            self.stash_begin_restore()
        };
        let stashed = {
            let _sp = crate::obs::span("train", "stash_put");
            self.stash_put_prestep()?
        };
        let l = self.rt.manifest.num_layers();
        let (x, y) = self.gen.batch(0, self.step as u64);

        let mut inputs = Vec::with_capacity(4 * l + 9);
        inputs.extend(self.ws.iter().cloned());
        inputs.extend(self.bs.iter().cloned());
        inputs.extend(self.mws.iter().cloned());
        inputs.extend(self.mbs.iter().cloned());
        inputs.push(HostTensor::f32(&[l], self.n_w.clone()));
        inputs.push(HostTensor::f32(&[l], self.n_a.clone()));
        inputs.push(x);
        inputs.push(y);
        inputs.push(HostTensor::scalar_f32(self.lr));
        inputs.push(HostTensor::scalar_f32(self.cfg.momentum));
        inputs.push(HostTensor::scalar_f32(lr_n));
        inputs.push(HostTensor::scalar_f32(gamma));
        inputs.push(HostTensor::scalar_f32(self.mmax()));
        inputs.push(HostTensor::scalar_i32(stochastic));
        inputs.push(HostTensor::scalar_i32(self.step));

        let out = {
            let _sp = crate::obs::span("train", "compiled_call");
            self.rt.call("train_step", &inputs)?
        };
        let mut it = out.into_iter();
        self.ws = (0..l).map(|_| it.next().unwrap()).collect();
        self.bs = (0..l).map(|_| it.next().unwrap()).collect();
        self.mws = (0..l).map(|_| it.next().unwrap()).collect();
        self.mbs = (0..l).map(|_| it.next().unwrap()).collect();
        let n_w2 = it.next().unwrap();
        let n_a2 = it.next().unwrap();
        if self.cfg.variant.learns_mantissa_in_graph() {
            self.n_w = n_w2.as_f32()?.to_vec();
            self.n_a = n_a2.as_f32()?.to_vec();
        }
        let task_loss = it.next().unwrap().item()?;
        let _total_loss = it.next().unwrap();
        let n_used_w = it.next().unwrap().as_i32()?.to_vec();
        let n_used_a = it.next().unwrap().as_i32()?.to_vec();
        let a_gecko = it.next().unwrap().as_f32()?.to_vec();
        let w_gecko = it.next().unwrap().as_f32()?.to_vec();
        let zfrac = it.next().unwrap().as_f32()?.to_vec();

        // Feed the period's signals to the policy engine; its plan applies
        // to the next step's knobs and stash metadata.
        let learned = self.cfg.variant.learns_mantissa_in_graph();
        self.plan = self.policy.observe(&StepSignals {
            epoch,
            step: self.step as usize,
            loss: task_loss,
            lr_changed: false,
            learned_n_a: if learned { Some(&self.n_a) } else { None },
            learned_n_w: if learned { Some(&self.n_w) } else { None },
            act_stats: &self.stats_a,
            weight_stats: &self.stats_w,
        });
        self.step += 1;
        // Pipeline barrier: wait for this step's encodes and the previous
        // step's prefetched decodes, then verify the restores bit-exact.
        {
            let _sp = crate::obs::span("train", "barrier");
            if let Some(stash) = &self.stash {
                stash.flush();
                if stash.failures() > 0 {
                    return Err(anyhow!("stash worker failed"));
                }
            }
            if let Some((prev, ticket)) = prefetch {
                Self::verify_restored(&prev, &ticket.collect())?;
            }
        }
        self.pending = stashed;
        Ok((task_loss, n_used_w, n_used_a, a_gecko, w_gecko, zfrac))
    }

    /// First half of the stash round-trip: dump this step's post-forward
    /// activations (forward with the *pre-update* weights, this step's
    /// batch) and queue them plus the live weights on the encode pool
    /// under the per-tensor [`ContainerMeta`] the active policy's plan
    /// induces — so QM/QE/BitWave/BitChop decisions change *real stored
    /// bytes* (mantissa width, exponent layout, sign handling) step by
    /// step.  Also refreshes the exponent-range statistics the
    /// exponent-side policies observe.  Returns the sources for post-step
    /// verification.
    fn stash_put_prestep(&mut self) -> Result<Option<StashedStep>> {
        // Refreshing ExpRangeStats runs two extra Gecko measurement passes
        // per tensor (delta + fixed-bias), so amortize it: exponent ranges
        // drift over many steps, not per batch.
        const STATS_REFRESH_STEPS: i32 = 8;
        let needs_stats = self.cfg.variant.needs_exp_stats()
            && (self.stats_w.is_empty() || self.step % STATS_REFRESH_STEPS == 0);
        if self.stash.is_none() {
            // No materialized activations without the stash path; feed the
            // policies weight-side stats at least (cheap, host-resident).
            if needs_stats {
                let mut stats = Vec::with_capacity(self.ws.len());
                for w in &self.ws {
                    stats.push(ExpRangeStats::from_vals(w.as_f32()?));
                }
                self.stats_w = stats;
            }
            return Ok(None);
        }
        let container = self.cfg.variant.container();
        let acts = self.dump_acts(self.step as u64)?;
        // Fractional learned bitlengths ceil into the stored container
        // (the round-up the QM endgame also applies); exponent mode and
        // sign elision come straight from the plan.
        let meta_a: Vec<ContainerMeta> = self
            .plan
            .acts
            .iter()
            .map(|p| p.meta(container))
            .collect();
        let meta_w: Vec<ContainerMeta> = self
            .plan
            .weights
            .iter()
            .map(|p| p.meta(container))
            .collect();
        if needs_stats {
            let mut sa = Vec::with_capacity(acts.len());
            for a in &acts {
                sa.push(ExpRangeStats::from_vals(a.as_f32()?));
            }
            let mut sw = Vec::with_capacity(self.ws.len());
            for w in &self.ws {
                sw.push(ExpRangeStats::from_vals(w.as_f32()?));
            }
            self.stats_a = sa;
            self.stats_w = sw;
        }
        let stash = self.stash.as_ref().expect("checked above");
        for (i, a) in acts.iter().enumerate() {
            stash.put(TensorId::act(i), a.as_f32()?.to_vec(), meta_a[i]);
        }
        for (i, w) in self.ws.iter().enumerate() {
            stash.put(TensorId::weight(i), w.as_f32()?.to_vec(), meta_w[i]);
        }
        // No flush here: the encodes drain on the pool while the compiled
        // step runs; train_step's post-call barrier syncs and verifies.
        Ok(Some(StashedStep {
            acts,
            ws: self.ws.clone(),
            meta_a,
            meta_w,
        }))
    }

    /// Start the previous step's restore-prefetch: its entries leave the
    /// stash synchronously and the decode jobs queue on the worker pool,
    /// overlapping the compiled step that runs next.
    fn stash_begin_restore(&mut self) -> Option<(StashedStep, RestoreTicket)> {
        let prev = self.pending.take()?;
        let stash = self.stash.as_ref()?;
        let ticket = stash.take_deferred(&prev.ids());
        Some((prev, ticket))
    }

    /// Drain the double-buffered pipeline: restore and verify the last
    /// in-flight step's tensors (epoch boundaries and run end, so epoch
    /// ledger cuts and evaluation never see a half-finished step).
    fn stash_drain(&mut self) -> Result<()> {
        let Some(prev) = self.pending.take() else {
            return Ok(());
        };
        let Some(stash) = &self.stash else {
            return Ok(());
        };
        let restored = stash.take_all(&prev.ids());
        if stash.failures() > 0 {
            return Err(anyhow!("stash restore worker failed"));
        }
        Self::verify_restored(&prev, &restored)
    }

    /// Verify restored tensors against the quantized sources, as the
    /// backward would consume them.  Restores are spot-checked bit-exact
    /// (full scan in debug builds; strided sample in release so the check
    /// stays off the critical path — the exhaustive guarantee lives in the
    /// codec property tests).
    fn verify_restored(stashed: &StashedStep, restored: &[Option<Vec<f32>>]) -> Result<()> {
        let l = stashed.acts.len();
        for (k, back) in restored.iter().enumerate() {
            let back = back
                .as_ref()
                .ok_or_else(|| anyhow!("stashed tensor {k} missing at restore"))?;
            let (src, meta) = if k < l {
                (&stashed.acts[k], stashed.meta_a[k])
            } else {
                (&stashed.ws[k - l], stashed.meta_w[k - l])
            };
            if back.len() != src.elems() {
                return Err(anyhow!("stash restore length mismatch for tensor {k}"));
            }
            let stride = if cfg!(debug_assertions) {
                1
            } else {
                (back.len() / 64).max(1)
            };
            let vals = src.as_f32()?;
            for i in (0..back.len()).step_by(stride) {
                if meta.quantized(vals[i]).to_bits() != back[i].to_bits() {
                    return Err(anyhow!("stash restore not bit-exact for tensor {k}"));
                }
            }
        }
        Ok(())
    }

    /// Validation over the held-out stream.
    pub fn evaluate(&self) -> Result<(f64, f64)> {
        let m = &self.rt.manifest;
        let l = m.num_layers();
        let mut correct = 0usize;
        let mut loss = 0.0f64;
        for i in 0..self.cfg.eval_batches {
            let (x, y) = self.gen.batch(1, i as u64);
            let mut inputs = Vec::with_capacity(2 * l + 5);
            inputs.extend(self.ws.iter().cloned());
            inputs.extend(self.bs.iter().cloned());
            inputs.push(HostTensor::f32(&[l], self.n_w.clone()));
            inputs.push(HostTensor::f32(&[l], self.n_a.clone()));
            inputs.push(HostTensor::scalar_f32(self.mmax()));
            inputs.push(x);
            inputs.push(y);
            let out = self.rt.call("eval_step", &inputs)?;
            correct += out[0].item()? as usize;
            loss += out[1].item()?;
        }
        let total = (self.cfg.eval_batches * m.batch) as f64;
        Ok((
            correct as f64 / total,
            loss / self.cfg.eval_batches as f64,
        ))
    }

    /// Dump the post-quantization activations of one batch (figure input).
    pub fn dump_acts(&self, batch_index: u64) -> Result<Vec<HostTensor>> {
        let m = &self.rt.manifest;
        let l = m.num_layers();
        let (x, _) = self.gen.batch(0, batch_index);
        let mut inputs = Vec::with_capacity(2 * l + 6);
        inputs.extend(self.ws.iter().cloned());
        inputs.extend(self.bs.iter().cloned());
        inputs.push(HostTensor::f32(&[l], self.n_w.clone()));
        inputs.push(HostTensor::f32(&[l], self.n_a.clone()));
        inputs.push(HostTensor::scalar_f32(self.mmax()));
        inputs.push(HostTensor::scalar_i32(0));
        inputs.push(HostTensor::scalar_i32(self.step));
        inputs.push(x);
        self.rt.call("forward_acts", &inputs)
    }

    pub fn weights(&self) -> &[HostTensor] {
        &self.ws
    }

    /// Force all bitlengths to a fixed value (test/figure helper).
    pub fn into_bits_forced(mut self, bits: f32) -> Self {
        self.n_w.iter_mut().for_each(|n| *n = bits);
        self.n_a.iter_mut().for_each(|n| *n = bits);
        self
    }

    /// Single uninstrumented step (bench harness hook).
    pub fn run_one_step_for_bench(&mut self) -> Result<f64> {
        let (loss, ..) = self.train_step(0)?;
        Ok(loss)
    }

    pub fn bitlengths(&self) -> (&[f32], &[f32]) {
        (&self.n_w, &self.n_a)
    }

    /// Run the configured training; produces the full metrics bundle.
    pub fn run(&mut self) -> Result<RunResult> {
        let m = &self.rt.manifest;
        let l = m.num_layers();
        let label = self.cfg.variant.label();
        // Thread-local flight-recorder capture: the policy decisions this
        // run makes come back in program order, untouched by concurrently
        // running jobs, so they may feed deterministic artifacts.
        crate::obs::events::capture_begin();
        let mut res = RunResult {
            label: label.clone(),
            ..Default::default()
        };
        let mut step_csv = match &self.cfg.out_dir {
            Some(dir) => Some(CsvSink::create(
                &dir.join(format!("{label}_steps.csv")),
                &["step", "epoch", "loss", "mean_bits_a", "mean_bits_w"],
            )?),
            None => None,
        };

        // LR drops at 1/3 and 2/3 of the run (paper's staged schedule).
        let drops = [self.cfg.epochs / 3, 2 * self.cfg.epochs / 3];

        let a_elems: Vec<f64> = m
            .act_shapes
            .iter()
            .map(|s| s.iter().product::<usize>() as f64)
            .collect();
        let w_elems: Vec<f64> = m
            .weight_shapes
            .iter()
            .map(|s| s.iter().product::<usize>() as f64)
            .collect();

        for epoch in 0..self.cfg.epochs {
            if epoch > 0 && drops.contains(&epoch) {
                self.lr *= 0.1;
                self.policy.notify_lr_change();
                self.plan = self.policy.plan();
            }
            let mut epoch_loss = 0.0;
            let mut sum_bits_a = vec![0.0f64; l];
            let mut sum_bits_w = vec![0.0f64; l];

            for _ in 0..self.cfg.steps_per_epoch {
                let (loss, n_used_w, n_used_a, a_gecko, w_gecko, zfrac) =
                    self.train_step(epoch)?;
                epoch_loss += loss;
                if matches!(self.cfg.variant, Variant::SfpBc(_) | Variant::SfpBw(_)) {
                    let bits = self
                        .plan
                        .acts
                        .first()
                        .map(|p| p.store_mant_bits())
                        .unwrap_or(0);
                    res.bc_histogram.add(bits);
                }

                // ---- exact per-step footprint ledger ------------------
                let container_bits = self.cfg.variant.container().total_bits() as f64;
                let is_sfp = matches!(
                    self.cfg.variant,
                    Variant::SfpQm(_)
                        | Variant::SfpBc(_)
                        | Variant::SfpQmQe(_)
                        | Variant::SfpBw(_)
                        | Variant::SfpAf(_)
                );
                // exponent-adapting variants charge the plan's amortized
                // exponent bits (learned field width, bias window, or
                // block-shared — the paper's pre-Gecko QM+QE / BitWave
                // accounting); the others charge Gecko's measured bits
                let plan_exp = self.cfg.variant.needs_exp_stats();
                for i in 0..l {
                    sum_bits_a[i] += n_used_a[i] as f64;
                    sum_bits_w[i] += n_used_w[i] as f64;
                    let (acts, weights) = if is_sfp {
                        // acts: post-ReLU => sign elided; exponents via
                        // Gecko (the step reports exact encoded bits);
                        // mantissa = adaptive bits × elements.
                        let exp_a = if plan_exp {
                            self.plan.acts[i].exp_bits_per_value() * a_elems[i]
                        } else {
                            a_gecko[i] as f64
                        };
                        let exp_w = if plan_exp {
                            self.plan.weights[i].exp_bits_per_value() * w_elems[i]
                        } else {
                            w_gecko[i] as f64
                        };
                        (
                            ComponentBits {
                                sign: 0.0,
                                exponent: exp_a,
                                mantissa: n_used_a[i] as f64 * a_elems[i],
                                metadata: 0.0,
                            },
                            ComponentBits {
                                sign: w_elems[i],
                                exponent: exp_w,
                                mantissa: n_used_w[i] as f64 * w_elems[i],
                                metadata: 0.0,
                            },
                        )
                    } else {
                        (
                            ComponentBits {
                                sign: a_elems[i],
                                exponent: 8.0 * a_elems[i],
                                mantissa: (container_bits - 9.0) * a_elems[i],
                                metadata: 0.0,
                            },
                            ComponentBits {
                                sign: w_elems[i],
                                exponent: 8.0 * w_elems[i],
                                mantissa: (container_bits - 9.0) * w_elems[i],
                                metadata: 0.0,
                            },
                        )
                    };
                    res.footprint.activations.add(acts);
                    res.footprint.weights.add(weights);
                    res.footprint_fp32.activations.add(ComponentBits {
                        sign: a_elems[i],
                        exponent: 8.0 * a_elems[i],
                        mantissa: 23.0 * a_elems[i],
                        metadata: 0.0,
                    });
                    res.footprint_fp32.weights.add(ComponentBits {
                        sign: w_elems[i],
                        exponent: 8.0 * w_elems[i],
                        mantissa: 23.0 * w_elems[i],
                        metadata: 0.0,
                    });
                    res.footprint_bf16.activations.add(ComponentBits {
                        sign: a_elems[i],
                        exponent: 8.0 * a_elems[i],
                        mantissa: 7.0 * a_elems[i],
                        metadata: 0.0,
                    });
                    res.footprint_bf16.weights.add(ComponentBits {
                        sign: w_elems[i],
                        exponent: 8.0 * w_elems[i],
                        mantissa: 7.0 * w_elems[i],
                        metadata: 0.0,
                    });
                    let _ = zfrac[i];
                }

                if let Some(csv) = step_csv.as_mut() {
                    let mean_a = n_used_a.iter().map(|&b| b as f64).sum::<f64>() / l as f64;
                    let mean_w = n_used_w.iter().map(|&b| b as f64).sum::<f64>() / l as f64;
                    csv.row(&[
                        (self.step - 1) as f64,
                        epoch as f64,
                        epoch_loss / ((self.step as f64) % self.cfg.steps_per_epoch as f64 + 1.0),
                        mean_a,
                        mean_w,
                    ])?;
                }
            }

            // Epoch boundary: drain the in-flight stash step so the
            // ledger's epoch cut and the evaluation see a settled stash.
            self.stash_drain()?;
            let (val_acc, val_loss) = self.evaluate()?;
            let steps = self.cfg.steps_per_epoch as f64;
            let lam_a = &self.rt.manifest.lambda_a;
            let per_a: Vec<f64> = sum_bits_a.iter().map(|s| s / steps).collect();
            let per_w: Vec<f64> = sum_bits_w.iter().map(|s| s / steps).collect();
            let lam_sum: f64 = lam_a.iter().sum();
            let wmean = per_a
                .iter()
                .zip(lam_a)
                .map(|(b, l)| b * l)
                .sum::<f64>()
                / lam_sum;
            res.epochs.push(EpochStats {
                epoch,
                train_loss: epoch_loss / steps,
                val_acc,
                val_loss,
                mean_bits_a: per_a.iter().sum::<f64>() / l as f64,
                mean_bits_w: per_w.iter().sum::<f64>() / l as f64,
                wmean_bits_a: wmean,
                per_layer_bits_a: per_a,
                per_layer_bits_w: per_w,
                mean_exp_bits_a: self.plan.mean_act_exp(),
                mean_exp_bits_w: self.plan.mean_weight_exp(),
            });
            if let Some(stash) = &self.stash {
                stash.mark_epoch();
            }
        }

        if let Some(csv) = step_csv.as_mut() {
            csv.flush()?;
        }
        res.final_val_acc = res.epochs.last().map(|e| e.val_acc).unwrap_or(0.0);
        res.final_n_w = self.n_w.clone();
        res.final_n_a = self.n_a.clone();
        res.stash = self.stash.as_ref().map(Stash::ledger);
        res.stash_epochs = self
            .stash
            .as_ref()
            .map(Stash::epoch_traffic)
            .unwrap_or_default();
        res.events = crate::obs::events::capture_end();

        if let Some(dir) = &self.cfg.out_dir {
            let mut s = Summary::new();
            s.str("variant", &label)
                .str("policy", self.policy.name())
                .num("final_val_acc", res.final_val_acc)
                .num("footprint_rel_fp32", res.footprint.relative_to(&res.footprint_fp32))
                .num("footprint_rel_bf16", res.footprint.relative_to(&res.footprint_bf16))
                .nums("final_n_a", &res.final_n_a.iter().map(|&v| v as f64).collect::<Vec<_>>())
                .nums("final_n_w", &res.final_n_w.iter().map(|&v| v as f64).collect::<Vec<_>>())
                .nums(
                    "val_acc_per_epoch",
                    &res.epochs.iter().map(|e| e.val_acc).collect::<Vec<_>>(),
                )
                .nums(
                    "mean_bits_a_per_epoch",
                    &res.epochs.iter().map(|e| e.mean_bits_a).collect::<Vec<_>>(),
                )
                .nums(
                    "mean_exp_bits_a_per_epoch",
                    &res.epochs.iter().map(|e| e.mean_exp_bits_a).collect::<Vec<_>>(),
                );
            if let Some(ls) = &res.stash {
                s.num("stash_written_bits", ls.written_bits)
                    .num("stash_read_bits", ls.read_bits)
                    .num("stash_peak_resident_bits", ls.peak_resident_bits)
                    .num("stash_ratio_vs_fp32", ls.ratio_vs_fp32())
                    .num("stash_spill_written_bits", ls.spill_written_bits)
                    .num("stash_spill_read_bits", ls.spill_read_bits)
                    .num("stash_evictions", ls.evictions as f64)
                    .num("stash_faults", ls.faults as f64);
            }
            s.write(&dir.join(format!("{label}_summary.json")))?;
            if !res.stash_epochs.is_empty() {
                crate::report::figures::footprint_over_time(
                    &dir.join(format!("{label}_footprint_over_time.csv")),
                    &res,
                )?;
            }
        }
        Ok(res)
    }
}
