//! BitChop (§IV-B): the history-based, hardware-style mantissa controller.
//!
//! Observes the per-period training loss (the only signal the hardware
//! gets, via a user-level register), smooths it with an exponential moving
//! average (Eq. 8), and decides −1 / 0 / +1 on the network-wide mantissa
//! bitlength (Eq. 9) with a threshold ε tracking the average relative
//! error between loss and EMA.  Full precision is restored around learning
//! rate changes ("the network is more sensitive").

#[derive(Debug, Clone)]
pub struct BitChop {
    /// Current mantissa bitlength (applied to the *next* period).
    n: u32,
    /// Container ceiling (23 FP32, 7 BF16).
    n_max: u32,
    /// Eq. 8 decay factor α.
    alpha: f64,
    /// EMA of the loss (Mavg).
    mavg: Option<f64>,
    /// Streaming mean of |L - Mavg| / |Mavg| — the ε estimator.
    rel_err_mean: f64,
    rel_err_count: u64,
    /// Batches per period (N; the paper lands on N = 1).
    period: u32,
    in_period: u32,
    period_loss_acc: f64,
    /// Remaining periods at forced full precision after an LR change.
    cooldown: u32,
    cooldown_len: u32,
    /// Periods observed (ε needs a short warm-up before decisions count).
    periods: u64,
    /// Stall recovery (§IV-B prose: "otherwise keep it the same or even
    /// increase it"): if the EMA has stopped improving for a window while
    /// bits are chopped, restore one bit — a stalled network at low
    /// precision produces a flat loss that Eq. 9's worsening branch alone
    /// would never react to.
    stall_window: u32,
    stall_count: u32,
    best_mavg: f64,
    /// Eq. 9 branch taken at the latest completed period: +1 chop (loss
    /// improving), −1 restore (worsening), 0 hold/warm-up.  BitWave's
    /// exponent side keys off this without re-deriving the EMA.
    last_decision: i8,
}

impl BitChop {
    pub fn new(n_max: u32) -> Self {
        Self {
            n: n_max,
            n_max,
            alpha: 0.1,
            mavg: None,
            rel_err_mean: 0.0,
            rel_err_count: 0,
            period: 1,
            in_period: 0,
            period_loss_acc: 0.0,
            cooldown: 0,
            cooldown_len: 8,
            periods: 0,
            stall_window: 16,
            stall_count: 0,
            best_mavg: f64::INFINITY,
            last_decision: 0,
        }
    }

    pub fn with_alpha(mut self, alpha: f64) -> Self {
        self.alpha = alpha;
        self
    }

    pub fn with_period(mut self, period: u32) -> Self {
        self.period = period.max(1);
        self
    }

    /// Mantissa bitlength to use for the upcoming batch.
    pub fn bits(&self) -> u32 {
        if self.cooldown > 0 {
            self.n_max
        } else {
            self.n
        }
    }

    /// §IV-B: "Full precision is used during LR changes".
    pub fn notify_lr_change(&mut self) {
        self.cooldown = self.cooldown_len;
        self.mavg = None; // the loss scale shifts; restart the EMA
        self.best_mavg = f64::INFINITY;
        self.stall_count = 0;
        self.periods = 0;
        self.last_decision = 0;
    }

    /// Still inside the forced-full-precision window after an LR change.
    pub fn in_cooldown(&self) -> bool {
        self.cooldown > 0
    }

    /// Container ceiling this controller was built with.
    pub fn n_max(&self) -> u32 {
        self.n_max
    }

    /// Eq. 9 branch of the latest completed period (+1 chop / −1 restore /
    /// 0 hold).
    pub fn last_decision(&self) -> i8 {
        self.last_decision
    }

    /// Feed the loss of the batch that just ran; returns the bitlength for
    /// the next batch.
    pub fn observe(&mut self, loss: f64) -> u32 {
        self.period_loss_acc += loss;
        self.in_period += 1;
        if self.in_period < self.period {
            return self.bits();
        }
        let l_i = self.period_loss_acc / self.period as f64;
        self.in_period = 0;
        self.period_loss_acc = 0.0;

        if self.cooldown > 0 {
            self.cooldown -= 1;
        }

        let mavg = match self.mavg {
            None => {
                self.mavg = Some(l_i);
                return self.bits();
            }
            Some(m) => m,
        };

        // ε_i: running average relative gap between L and Mavg (Eq. 9 text)
        let rel = ((l_i - mavg) / mavg.abs().max(1e-12)).abs();
        self.rel_err_count += 1;
        self.rel_err_mean += (rel - self.rel_err_mean) / self.rel_err_count as f64;
        let eps = self.rel_err_mean * mavg.abs();
        self.periods += 1;

        // Eq. 9 needs a meaningful ε; hold decisions for a short warm-up.
        self.last_decision = 0;
        if self.periods > 4 {
            if mavg > l_i + eps {
                // improving => try fewer bits
                self.n = self.n.saturating_sub(1);
                self.stall_count = 0;
                self.last_decision = 1;
            } else if mavg < l_i - eps {
                // degrading => back off
                self.n = (self.n + 1).min(self.n_max);
                self.stall_count = 0;
                self.last_decision = -1;
            } else {
                // flat: count toward stall recovery
                self.stall_count += 1;
            }
        }

        // Stall recovery: chopped bits + no EMA progress for a window =>
        // precision is limiting learning; restore one bit.
        let new_mavg = mavg + self.alpha * (l_i - mavg);
        if new_mavg < self.best_mavg * (1.0 - self.rel_err_mean * 0.25) {
            self.best_mavg = new_mavg;
            self.stall_count = 0;
        } else if self.stall_count >= self.stall_window && self.n < self.n_max {
            self.n += 1;
            self.stall_count = 0;
        }

        // Eq. 8: Mavg += α (L - Mavg)
        self.mavg = Some(new_mavg);
        self.bits()
    }

    /// Serialize the complete controller state (policy checkpointing).
    /// Finite f64s round-trip bit-exactly through the JSON layer's
    /// shortest-representation formatting; the two possibly-non-finite
    /// slots (`mavg` unset, `best_mavg` = ∞) serialize as null.
    pub fn state_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut o = std::collections::BTreeMap::new();
        let mut num = |k: &str, v: f64| {
            o.insert(k.to_string(), Json::Num(v));
        };
        num("n", self.n as f64);
        num("n_max", self.n_max as f64);
        num("alpha", self.alpha);
        num("rel_err_mean", self.rel_err_mean);
        num("rel_err_count", self.rel_err_count as f64);
        num("period", self.period as f64);
        num("in_period", self.in_period as f64);
        num("period_loss_acc", self.period_loss_acc);
        num("cooldown", self.cooldown as f64);
        num("cooldown_len", self.cooldown_len as f64);
        num("periods", self.periods as f64);
        num("stall_window", self.stall_window as f64);
        num("stall_count", self.stall_count as f64);
        num("last_decision", self.last_decision as f64);
        o.insert(
            "mavg".to_string(),
            match self.mavg {
                Some(m) => Json::Num(m),
                None => Json::Null,
            },
        );
        o.insert(
            "best_mavg".to_string(),
            if self.best_mavg.is_finite() {
                Json::Num(self.best_mavg)
            } else {
                Json::Null
            },
        );
        Json::Obj(o)
    }

    /// Restore a controller from [`BitChop::state_json`] output.
    pub fn from_state_json(state: &crate::util::json::Json) -> anyhow::Result<BitChop> {
        use crate::util::json::Json;
        let f = |k: &str| -> anyhow::Result<f64> {
            state
                .get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("bitchop state: missing '{k}'"))
        };
        Ok(BitChop {
            n: f("n")? as u32,
            n_max: f("n_max")? as u32,
            alpha: f("alpha")?,
            mavg: match state.get("mavg") {
                Some(Json::Num(v)) => Some(*v),
                Some(Json::Null) => None,
                _ => return Err(anyhow::anyhow!("bitchop state: missing 'mavg'")),
            },
            rel_err_mean: f("rel_err_mean")?,
            rel_err_count: f("rel_err_count")? as u64,
            period: f("period")? as u32,
            in_period: f("in_period")? as u32,
            period_loss_acc: f("period_loss_acc")?,
            cooldown: f("cooldown")? as u32,
            cooldown_len: f("cooldown_len")? as u32,
            periods: f("periods")? as u64,
            stall_window: f("stall_window")? as u32,
            stall_count: f("stall_count")? as u32,
            best_mavg: match state.get("best_mavg") {
                Some(Json::Num(v)) => *v,
                Some(Json::Null) => f64::INFINITY,
                _ => return Err(anyhow::anyhow!("bitchop state: missing 'best_mavg'")),
            },
            last_decision: f("last_decision")? as i8,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn starts_at_full_precision() {
        assert_eq!(BitChop::new(7).bits(), 7);
        assert_eq!(BitChop::new(23).bits(), 23);
    }

    #[test]
    fn improving_loss_chops_bits() {
        let mut bc = BitChop::new(7);
        for i in 0..50 {
            bc.observe(5.0 - 0.08 * i as f64);
        }
        assert!(bc.bits() < 5, "bits {}", bc.bits());
    }

    #[test]
    fn worsening_loss_restores_bits() {
        let mut bc = BitChop::new(7);
        for i in 0..30 {
            bc.observe(5.0 - 0.1 * i as f64);
        }
        let low = bc.bits();
        for i in 0..30 {
            bc.observe(2.0 + 0.2 * i as f64);
        }
        assert!(bc.bits() > low, "bits {} vs {low}", bc.bits());
    }

    #[test]
    fn never_exceeds_container_or_underflows() {
        let mut bc = BitChop::new(7);
        for i in 0..200 {
            let loss = if i % 2 == 0 { 1.0 } else { 100.0 };
            let b = bc.observe(loss);
            assert!(b <= 7);
        }
        let mut bc = BitChop::new(7);
        for i in 0..200 {
            bc.observe(100.0 - i as f64); // monotone improvement
        }
        assert_eq!(bc.bits(), 0); // clipped at zero, no panic
    }

    #[test]
    fn lr_change_forces_full_precision() {
        let mut bc = BitChop::new(7);
        for i in 0..40 {
            bc.observe(5.0 - 0.1 * i as f64);
        }
        assert!(bc.bits() < 7);
        bc.notify_lr_change();
        assert_eq!(bc.bits(), 7);
        // decays back to adaptive behaviour after the cooldown
        for i in 0..20 {
            bc.observe(1.0 - 0.01 * i as f64);
        }
        assert!(bc.bits() < 7);
    }

    #[test]
    fn plateau_triggers_stall_recovery() {
        // §IV-B prose: "otherwise keep it the same or even increase it" —
        // a long plateau at chopped precision must drift bits back up
        // rather than staying frozen (the failure mode that killed BC
        // accuracy in the first e2e run; see EXPERIMENTS.md).
        let mut bc = BitChop::new(7);
        for i in 0..30 {
            bc.observe(5.0 - 0.1 * i as f64);
        }
        let before = bc.bits();
        assert!(before < 7);
        let mut rng = crate::traces::SplitMix64::new(3);
        for _ in 0..200 {
            bc.observe(2.0 + 0.01 * rng.next_gaussian());
        }
        let after = bc.bits();
        assert!(after > before, "stall must restore bits: {before} -> {after}");
        assert!(after <= 7);
    }

    #[test]
    fn progressing_loss_does_not_trigger_stall_recovery() {
        // while the EMA keeps improving, stall recovery stays quiet and
        // the controller keeps chopping
        let mut bc = BitChop::new(23);
        for i in 0..120 {
            bc.observe(10.0 - 0.07 * i as f64);
        }
        assert!(bc.bits() < 12, "bits {}", bc.bits());
    }

    #[test]
    fn cooldown_preserves_chopped_bits_underneath() {
        // The LR-change cooldown forces n_max at the *output* but must not
        // forget the learned bitlength: once the window expires, the
        // controller resumes from where it was, not from full precision.
        let mut bc = BitChop::new(7);
        for i in 0..40 {
            bc.observe(5.0 - 0.1 * i as f64);
        }
        let chopped = bc.bits();
        assert!(chopped < 7);
        bc.notify_lr_change();
        assert!(bc.in_cooldown());
        assert_eq!(bc.bits(), 7);
        // flat-ish loss through the cooldown: no Eq. 9 movement (EMA
        // restarted, warm-up holds decisions), so after exactly
        // cooldown_len completed periods the old bitlength resurfaces
        let mut cooldown_periods = 0;
        for _ in 0..8 {
            assert_eq!(bc.bits(), 7, "cooldown must pin full precision");
            bc.observe(1.0);
            cooldown_periods += 1;
        }
        assert!(!bc.in_cooldown(), "after {cooldown_periods} periods");
        assert_eq!(bc.bits(), chopped, "chopped bits resume after cooldown");
    }

    #[test]
    fn cooldown_decrements_per_period_not_per_batch() {
        let mut bc = BitChop::new(7).with_period(4);
        for i in 0..60 {
            bc.observe(5.0 - 0.05 * i as f64);
        }
        let chopped = bc.bits();
        assert!(chopped < 7);
        bc.notify_lr_change();
        // 8 periods × 4 batches: every batch inside the window sees n_max
        for _ in 0..32 {
            assert!(bc.in_cooldown());
            assert_eq!(bc.bits(), 7);
            bc.observe(1.0);
        }
        assert!(!bc.in_cooldown());
        assert_eq!(bc.bits(), chopped);
    }

    #[test]
    fn stall_recovery_climbs_gradually_but_never_past_ceiling() {
        let mut bc = BitChop::new(7);
        for i in 0..30 {
            bc.observe(5.0 - 0.1 * i as f64);
        }
        let low = bc.bits();
        let mut prev = low;
        assert!(low < 7);
        // long dead-flat plateau: recovery restores at most one bit per
        // period and never crosses the container ceiling
        let mut rng = crate::traces::SplitMix64::new(11);
        for _ in 0..1000 {
            let b = bc.observe(2.0 + 0.001 * rng.next_gaussian());
            assert!(b <= 7);
            assert!(b as i64 - prev as i64 <= 1, "one bit per period max");
            prev = b;
        }
        assert!(bc.bits() > low, "plateau must drift bits back up: {low} -> {}", bc.bits());
    }

    #[test]
    fn state_json_roundtrip_mid_run() {
        let mut bc = BitChop::new(23).with_period(2).with_alpha(0.2);
        let mut rng = crate::traces::SplitMix64::new(5);
        for i in 0..57 {
            bc.observe(4.0 - 0.05 * i as f64 + 0.01 * rng.next_gaussian());
        }
        bc.notify_lr_change();
        for i in 0..7 {
            bc.observe(2.0 - 0.01 * i as f64);
        }
        let state = bc.state_json();
        let mut restored = BitChop::from_state_json(&state).unwrap();
        assert_eq!(restored.state_json(), state);
        // identical continuation, including mid-period and cooldown state
        for i in 0..40 {
            let loss = 2.0 + 0.05 * i as f64;
            assert_eq!(bc.observe(loss), restored.observe(loss), "step {i}");
        }
        assert_eq!(bc.last_decision(), restored.last_decision());
    }

    #[test]
    fn period_aggregation() {
        let mut bc = BitChop::new(7).with_period(4);
        // only every 4th observe can change the bitlength
        let mut changes = 0;
        let mut prev = bc.bits();
        for i in 0..40 {
            let b = bc.observe(5.0 - 0.05 * i as f64);
            if b != prev {
                changes += 1;
                prev = b;
            }
        }
        assert!(changes <= 10);
    }
}
