//! Synthetic structured classification data (DESIGN.md §2 substitution for
//! ImageNet): each class owns a fixed random template image; samples are
//! `template * 0.8 + noise * 0.4`.  Deterministic per seed, generated
//! entirely in Rust — the request path never touches Python.

use crate::runtime::HostTensor;
use crate::traces::SplitMix64;

pub struct DataGen {
    templates: Vec<f32>, // [classes * pixels]
    pixels: usize,
    classes: usize,
    image: Vec<usize>,
    batch: usize,
}

impl DataGen {
    pub fn new(image: &[usize], classes: usize, batch: usize, seed: u64) -> Self {
        let pixels: usize = image.iter().product();
        let mut rng = SplitMix64::new(seed);
        let templates = (0..classes * pixels)
            .map(|_| rng.next_gaussian() as f32)
            .collect();
        Self {
            templates,
            pixels,
            classes,
            image: image.to_vec(),
            batch,
        }
    }

    /// Generate batch `index` of the training stream (stream 0) or the
    /// held-out validation stream (stream 1).
    pub fn batch(&self, stream: u64, index: u64) -> (HostTensor, HostTensor) {
        let mut rng = SplitMix64::new(0x00DA7A ^ (stream << 56) ^ index);
        let mut x = Vec::with_capacity(self.batch * self.pixels);
        let mut y = Vec::with_capacity(self.batch);
        for _ in 0..self.batch {
            let class = (rng.next_u64() as usize) % self.classes;
            y.push(class as i32);
            let t = &self.templates[class * self.pixels..(class + 1) * self.pixels];
            for &tv in t {
                x.push(tv * 0.8 + rng.next_gaussian() as f32 * 0.4);
            }
        }
        let mut shape = vec![self.batch];
        shape.extend(&self.image);
        (HostTensor::f32(&shape, x), HostTensor::i32(&[self.batch], y))
    }

    pub fn batch_size(&self) -> usize {
        self.batch
    }
}

/// He-normal initialization for the model parameters, shaped per manifest.
pub fn init_params(
    weight_shapes: &[Vec<usize>],
    bias_shapes: &[Vec<usize>],
    seed: u64,
) -> (Vec<HostTensor>, Vec<HostTensor>) {
    let mut rng = SplitMix64::new(seed);
    let ws = weight_shapes
        .iter()
        .map(|s| {
            let fan_in: usize = s[..s.len() - 1].iter().product();
            let std = (2.0 / fan_in as f64).sqrt();
            let n: usize = s.iter().product();
            HostTensor::f32(
                s,
                (0..n).map(|_| (rng.next_gaussian() * std) as f32).collect(),
            )
        })
        .collect();
    let bs = bias_shapes
        .iter()
        .map(|s| HostTensor::f32(s, vec![0.0; s.iter().product()]))
        .collect();
    (ws, bs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_batches() {
        let g = DataGen::new(&[4, 4, 3], 10, 8, 1);
        let (x1, y1) = g.batch(0, 5);
        let (x2, y2) = g.batch(0, 5);
        assert_eq!(x1.as_f32().unwrap(), x2.as_f32().unwrap());
        assert_eq!(y1.as_i32().unwrap(), y2.as_i32().unwrap());
        let (x3, _) = g.batch(0, 6);
        assert_ne!(x1.as_f32().unwrap(), x3.as_f32().unwrap());
    }

    #[test]
    fn train_and_val_streams_differ() {
        let g = DataGen::new(&[4, 4, 3], 10, 8, 1);
        let (x1, _) = g.batch(0, 0);
        let (x2, _) = g.batch(1, 0);
        assert_ne!(x1.as_f32().unwrap(), x2.as_f32().unwrap());
    }

    #[test]
    fn labels_in_range() {
        let g = DataGen::new(&[4, 4, 3], 10, 64, 2);
        let (_, y) = g.batch(0, 0);
        assert!(y.as_i32().unwrap().iter().all(|&c| (0..10).contains(&c)));
    }

    #[test]
    fn init_shapes_and_scale() {
        let (ws, bs) = init_params(&[vec![3, 3, 3, 16]], &[vec![16]], 3);
        assert_eq!(ws[0].elems(), 432);
        assert_eq!(bs[0].as_f32().unwrap(), &[0.0; 16]);
        let std = (ws[0].as_f32().unwrap().iter().map(|v| v * v).sum::<f32>() / 432.0).sqrt();
        let expect = (2.0f32 / 27.0).sqrt();
        assert!((std - expect).abs() / expect < 0.2, "std {std} vs {expect}");
    }
}
