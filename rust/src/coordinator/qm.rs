//! Quantum Mantissa policy state (§IV-A): the gradient-side learning of
//! bitlengths happens *inside* the compiled train step (L2's Eq. 7 penalty
//! + the expected-value bitlength gradient in L1's custom VJP); this module
//! owns the coordinator-side policy — the γ schedule and the §IV-A-4
//! round-up endgame.

/// γ regularizer schedule: the paper sets 0.1 / 0.01 / 0.001 at epochs
/// 0 / 30 / 60 of a 90-epoch run; we express the breakpoints as fractions
/// of the configured run length.
#[derive(Debug, Clone)]
pub struct QmSchedule {
    pub epochs: usize,
    pub gammas: [f32; 3],
    /// Epoch fractions at which each γ stage begins.
    pub stage_frac: [f64; 3],
    /// Fraction of the run with rounded-up frozen bitlengths at the end
    /// (paper: last 10 of 90 epochs).
    pub roundup_frac: f64,
    /// Bitlength learning rate while adapting.
    pub lr_n: f32,
}

impl QmSchedule {
    pub fn paper_like(epochs: usize) -> Self {
        Self {
            epochs,
            gammas: [0.1, 0.01, 0.001],
            stage_frac: [0.0, 1.0 / 3.0, 2.0 / 3.0],
            roundup_frac: 1.0 / 9.0,
            lr_n: 4.0,
        }
    }

    /// Is `epoch` in the round-up endgame (§IV-A-4)?
    pub fn in_roundup(&self, epoch: usize) -> bool {
        epoch as f64 >= self.epochs as f64 * (1.0 - self.roundup_frac)
    }

    /// (γ, lr_n, stochastic) for this epoch.  In the endgame the bitlengths
    /// are frozen (lr_n = 0), deterministic (stochastic = 0), and the
    /// coordinator rounds the learned values up once on entry.
    pub fn hyper(&self, epoch: usize) -> (f32, f32, i32) {
        if self.in_roundup(epoch) {
            return (0.0, 0.0, 0);
        }
        let frac = epoch as f64 / self.epochs.max(1) as f64;
        let mut gamma = self.gammas[0];
        for (g, f) in self.gammas.iter().zip(self.stage_frac) {
            if frac >= f {
                gamma = *g;
            }
        }
        (gamma, self.lr_n, 1)
    }

    /// Round learned bitlengths up for deployment/endgame.
    pub fn round_up(bits: &mut [f32], mmax: f32) {
        for b in bits {
            *b = b.ceil().clamp(0.0, mmax);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gamma_schedule_stages() {
        let s = QmSchedule::paper_like(90);
        assert_eq!(s.hyper(0).0, 0.1);
        assert_eq!(s.hyper(29).0, 0.1);
        assert_eq!(s.hyper(30).0, 0.01);
        assert_eq!(s.hyper(60).0, 0.001);
    }

    #[test]
    fn roundup_endgame() {
        let s = QmSchedule::paper_like(90);
        assert!(!s.in_roundup(79));
        assert!(s.in_roundup(80)); // last 10 of 90
        let (gamma, lr_n, stoch) = s.hyper(85);
        assert_eq!((gamma, lr_n, stoch), (0.0, 0.0, 0));
        // adapting phase is stochastic with a live lr_n
        let (_, lr_n, stoch) = s.hyper(10);
        assert!(lr_n > 0.0);
        assert_eq!(stoch, 1);
    }

    #[test]
    fn round_up_clamps() {
        let mut bits = vec![1.2, 0.0, -0.5, 22.9, 25.0];
        QmSchedule::round_up(&mut bits, 23.0);
        assert_eq!(bits, vec![2.0, 0.0, 0.0, 23.0, 23.0]);
    }
}
