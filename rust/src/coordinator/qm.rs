//! Quantum Mantissa schedule (§IV-A): the gradient-side learning of
//! bitlengths happens *inside* the compiled train step (L2's Eq. 7 penalty
//! + the expected-value bitlength gradient in L1's custom VJP); the
//! coordinator-side γ schedule and §IV-A-4 round-up endgame now live in
//! [`crate::policy::schedule::GammaSchedule`], shared with Quantum
//! Exponent.  This module keeps the historical `QmSchedule` name plus the
//! stage-boundary regression tests that pin the schedule's exact epoch
//! arithmetic (γ switches precisely at the `stage_frac` breakpoints; the
//! round-up endgame always covers at least one epoch, even on runs shorter
//! than ⌈1/roundup_frac⌉ epochs).

pub use crate::policy::schedule::GammaSchedule as QmSchedule;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gamma_schedule_stages() {
        let s = QmSchedule::paper_like(90);
        assert_eq!(s.hyper(0).0, 0.1);
        assert_eq!(s.hyper(29).0, 0.1);
        assert_eq!(s.hyper(30).0, 0.01);
        assert_eq!(s.hyper(60).0, 0.001);
    }

    #[test]
    fn gamma_pinned_at_exact_stage_breakpoints() {
        // the fractions 30/90 and 60/90 must compare equal to the stored
        // stage_frac values (1/3, 2/3) in f64 — no epsilon drift allowed
        let s = QmSchedule::paper_like(90);
        assert_eq!(s.hyper(59).0, 0.01);
        assert_eq!(s.hyper(60).0, 0.001);
        // a run length that is not a multiple of 3: breakpoints land on
        // the first epoch at-or-after the fraction
        let s = QmSchedule::paper_like(10);
        assert_eq!(s.hyper(3).0, 0.1); // 3/10 < 1/3
        assert_eq!(s.hyper(4).0, 0.01); // 4/10 >= 1/3
        assert_eq!(s.hyper(6).0, 0.01); // 6/10 < 2/3
        assert_eq!(s.hyper(7).0, 0.001); // 7/10 >= 2/3
    }

    #[test]
    fn roundup_endgame() {
        let s = QmSchedule::paper_like(90);
        assert!(!s.in_roundup(79));
        assert!(s.in_roundup(80)); // last 10 of 90
        let (gamma, lr_n, stoch) = s.hyper(85);
        assert_eq!((gamma, lr_n, stoch), (0.0, 0.0, 0));
        // adapting phase is stochastic with a live lr_n
        let (_, lr_n, stoch) = s.hyper(10);
        assert!(lr_n > 0.0);
        assert_eq!(stoch, 1);
    }

    #[test]
    fn roundup_entry_epoch_off_by_one_guard() {
        // regression: the endgame must exist on short runs — the Trainer's
        // 6-epoch default previously computed a 5.33-epoch threshold that
        // epoch 5 (the last) never reached, so QM runs ended un-rounded
        let s = QmSchedule::paper_like(6);
        assert_eq!(s.roundup_entry(), 5);
        assert!(s.in_roundup(5));
        assert!(!s.in_roundup(4));
        assert_eq!(s.hyper(5), (0.0, 0.0, 0));
        // and the paper-length run keeps its exact entry epoch
        assert_eq!(QmSchedule::paper_like(90).roundup_entry(), 80);
        assert_eq!(QmSchedule::paper_like(45).roundup_entry(), 40);
    }

    #[test]
    fn round_up_clamps() {
        let mut bits = vec![1.2, 0.0, -0.5, 22.9, 25.0];
        QmSchedule::round_up(&mut bits, 23.0);
        assert_eq!(bits, vec![2.0, 0.0, 0.0, 23.0, 23.0]);
    }
}
