//! Training metrics sinks: per-step CSV rows and run-level JSON summaries
//! (the table/figure drivers read these back).

use crate::util::json::Json;
use anyhow::Result;
use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;

/// Append-oriented CSV writer with a fixed header.
pub struct CsvSink {
    file: std::io::BufWriter<std::fs::File>,
    columns: usize,
}

impl CsvSink {
    pub fn create(path: &Path, header: &[&str]) -> Result<Self> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut file = std::io::BufWriter::new(std::fs::File::create(path)?);
        writeln!(file, "{}", header.join(","))?;
        Ok(Self {
            file,
            columns: header.len(),
        })
    }

    pub fn row(&mut self, values: &[f64]) -> Result<()> {
        debug_assert_eq!(values.len(), self.columns);
        let mut line = String::with_capacity(values.len() * 12);
        for (i, v) in values.iter().enumerate() {
            if i > 0 {
                line.push(',');
            }
            line.push_str(&format!("{v}"));
        }
        writeln!(self.file, "{line}")?;
        Ok(())
    }

    pub fn flush(&mut self) -> Result<()> {
        self.file.flush()?;
        Ok(())
    }
}

/// Run-level summary: arbitrary key → number/string/array, written as JSON.
#[derive(Default)]
pub struct Summary {
    entries: BTreeMap<String, Json>,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn num(&mut self, key: &str, v: f64) -> &mut Self {
        self.entries.insert(key.into(), Json::Num(v));
        self
    }

    pub fn str(&mut self, key: &str, v: &str) -> &mut Self {
        self.entries.insert(key.into(), Json::Str(v.into()));
        self
    }

    pub fn nums(&mut self, key: &str, vs: &[f64]) -> &mut Self {
        self.entries.insert(
            key.into(),
            Json::Arr(vs.iter().map(|&v| Json::Num(v)).collect()),
        );
        self
    }

    pub fn write(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let obj = Json::Obj(self.entries.clone());
        std::fs::write(path, obj.to_string())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip() {
        let dir = std::env::temp_dir().join("sfp_metrics_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("m.csv");
        let mut s = CsvSink::create(&p, &["step", "loss"]).unwrap();
        s.row(&[0.0, 2.5]).unwrap();
        s.row(&[1.0, 2.25]).unwrap();
        s.flush().unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text.lines().count(), 3);
        assert!(text.starts_with("step,loss\n0,2.5\n"));
    }

    #[test]
    fn summary_json() {
        let dir = std::env::temp_dir().join("sfp_metrics_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("s.json");
        let mut s = Summary::new();
        s.num("acc", 0.93).str("variant", "qm").nums("bits", &[1.0, 2.0]);
        s.write(&p).unwrap();
        let j = Json::parse(&std::fs::read_to_string(&p).unwrap()).unwrap();
        assert_eq!(j.get("acc").unwrap().as_f64(), Some(0.93));
        assert_eq!(j.get("bits").unwrap().idx(1).unwrap().as_f64(), Some(2.0));
    }
}
