//! L3 coordinator: the request-path training orchestrator.
//!
//! * [`train::Trainer`] — epoch/step loop over the compiled PJRT step,
//!   per-variant container policy, metrics + exact footprint ledger.
//!   With [`train::TrainConfig::stash`] set, every step also routes its
//!   post-forward tensors through the compressed stash
//!   ([`crate::stash`]): the policy's bitlengths become per-tensor
//!   container metadata, the worker pool encodes into the chunk arena,
//!   and the tensors are restored (bit-exact) for the backward — so
//!   BitChop/QM decisions move real stored bytes, not just counters.
//! * [`bitchop::BitChop`] — the §IV-B loss-EMA mantissa controller.
//! * [`qm::QmSchedule`] — the §IV-A γ schedule and round-up endgame.
//! * [`data::DataGen`] — deterministic synthetic classification data.
//! * [`metrics`] — CSV / JSON sinks the figure drivers read back.

pub mod bitchop;
pub mod data;
pub mod metrics;
pub mod qm;
pub mod train;

pub use bitchop::BitChop;
pub use train::{RunResult, TrainConfig, Trainer, Variant};
