//! L3 coordinator: the request-path training orchestrator.
//!
//! * [`train::Trainer`] — epoch/step loop over the compiled PJRT step,
//!   per-variant container policy, metrics + exact footprint ledger.
//! * [`bitchop::BitChop`] — the §IV-B loss-EMA mantissa controller.
//! * [`qm::QmSchedule`] — the §IV-A γ schedule and round-up endgame.
//! * [`data::DataGen`] — deterministic synthetic classification data.
//! * [`metrics`] — CSV / JSON sinks the figure drivers read back.

pub mod bitchop;
pub mod data;
pub mod metrics;
pub mod qm;
pub mod train;

pub use bitchop::BitChop;
pub use train::{RunResult, TrainConfig, Trainer, Variant};
