//! L3 coordinator: the request-path training orchestrator.
//!
//! * [`train::Trainer`] — epoch/step loop over the compiled PJRT step.
//!   Every variant's adaptation decisions route through the unified
//!   policy engine ([`crate::policy`]): each period the Trainer feeds the
//!   active [`crate::policy::BitPolicy`] the step's signals (loss,
//!   learned bitlengths, exponent-range stats) and applies the returned
//!   per-tensor container plans to the step knobs.  With
//!   [`train::TrainConfig::stash`] set, the plans also become per-tensor
//!   container metadata on the compressed stash ([`crate::stash`]): the
//!   worker pool encodes into the chunk arena and the tensors are
//!   restored (bit-exact) for the backward — so QM/QE/BitWave/BitChop
//!   decisions move real stored bytes, not just counters.
//! * [`bitchop::BitChop`] — the §IV-B loss-EMA mantissa controller (also
//!   embedded in [`crate::policy::BitWave`]).
//! * [`qm::QmSchedule`] — alias of the shared γ schedule
//!   ([`crate::policy::GammaSchedule`]) plus its boundary regressions.
//! * [`data::DataGen`] — deterministic synthetic classification data.
//! * [`metrics`] — CSV / JSON sinks the figure drivers read back.

pub mod bitchop;
pub mod data;
pub mod metrics;
pub mod qm;
pub mod train;

pub use bitchop::BitChop;
pub use train::{RunResult, TrainConfig, Trainer, Variant};
