//! # Schrödinger's FP — reproduction library
//!
//! Reproduction of *"Schrödinger's FP: Dynamic Adaptation of Floating-Point
//! Containers for Deep Learning Training"* (Nikolić et al., 2022) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L1** (`python/compile/kernels/`): Pallas mantissa-quantization and
//!   Gecko-statistics kernels, AOT-lowered into the training step.
//! * **L2** (`python/compile/model.py`): JAX fwd/bwd of a residual CNN with
//!   fake-quantized stash tensors, exported as HLO text.
//! * **L3** (this crate): everything on the request path — the PJRT runtime
//!   ([`runtime`]), the training coordinator with the BitChop / Quantum
//!   Mantissa adaptation policies ([`coordinator`]), and the hardware
//!   substrates: bit-exact Gecko and SFP codecs ([`gecko`], [`sfp`]),
//!   compression baselines ([`baselines`]), the analytical accelerator +
//!   DRAM model ([`hwsim`]), ImageNet-scale layer traces ([`traces`]), and
//!   streaming statistics ([`stats`]).
//!
//! Python never runs on the request path: `make artifacts` lowers the model
//! once; the `repro` binary is self-contained afterwards.

pub mod baselines;
pub mod coordinator;
pub mod formats;
pub mod gecko;
pub mod hwsim;
pub mod report;
pub mod runtime;
pub mod sfp;
pub mod stats;
pub mod traces;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
