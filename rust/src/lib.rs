//! # Schrödinger's FP — reproduction library
//!
//! Reproduction of *"Schrödinger's FP: Dynamic Adaptation of Floating-Point
//! Containers for Deep Learning Training"* (Nikolić et al., 2022) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L1** (`python/compile/kernels/`): Pallas mantissa-quantization and
//!   Gecko-statistics kernels, AOT-lowered into the training step.
//! * **L2** (`python/compile/model.py`): JAX fwd/bwd of a residual CNN with
//!   fake-quantized stash tensors, exported as HLO text.
//! * **L3** (this crate): everything on the request path — the PJRT runtime
//!   ([`runtime`]), the training coordinator ([`coordinator`]), the unified
//!   adaptation-policy engine ([`policy`]), the concurrent compressed-tensor
//!   stash that holds post-forward tensors until the backward pass
//!   ([`stash`]), and the hardware substrates: bit-exact Gecko and SFP
//!   codecs ([`gecko`], [`sfp`]), compression baselines ([`baselines`]),
//!   the analytical accelerator + DRAM model ([`hwsim`]), ImageNet-scale
//!   layer traces ([`traces`]), and streaming statistics ([`stats`]).
//!
//! The policy engine ([`policy`]) is where the paper's adaptation methods
//! live: Quantum Mantissa, Quantum Exponent, BitWave, and BitChop all
//! implement one `BitPolicy` trait (`observe(signals) → ContainerPlan` per
//! tensor, plus bit-exact checkpoint/restore).  The Trainer applies each
//! period's plans to the stash's per-tensor container metadata live, the
//! hwsim consumes the plans' bits-per-pass, and `repro policy` sweeps every
//! policy over the trace models to reproduce the paper's QM+QE / BitWave /
//! +Gecko footprint ordering.
//!
//! The exponent axis of every plan is a first-class
//! [`formats::ExponentLayout`]: per-value learned widths (the paper's
//! axis), a fixed-bias window (AdaptivFloat's per-tensor post-hoc fit,
//! [`policy::AdaptivFloatPolicy`]), or a block shared exponent
//! (Flexpoint, one max-exponent per block).  The layout threads through
//! the codecs, the stash measurement (`repro stash --layout`), hwsim,
//! and the flight recorder, and the cross-paper container families —
//! `qm+af`, `flexpoint`, `fp8`, `bf16` presets — sweep next to the
//! paper's controllers into one `crosspaper.json` comparison table
//! (EXPERIMENTS.md §Cross-paper comparison).
//!
//! The codec hot paths are *word-parallel*: bit-plane transposed
//! pack/unpack kernels ([`gecko::bitstream`]) stage a whole 8-lane row
//! (or a uniform-width lane group) in one `u64`/`u128` and splice it
//! with a single `push_word`/`read_word` call, for all four stash
//! codecs.  The original per-field scalar pipeline is kept as the
//! differential reference behind the same `Kernel` dispatch
//! (`SFP_CODEC_KERNELS=scalar`); both kernels produce bit-identical
//! streams, so content hashes and lab cache fingerprints never depend
//! on the kernel — proven by property tests (`tests/codec_kernels.rs`)
//! and a CI job that replays a scalar-populated cache under the word
//! kernels.  `EXPERIMENTS.md §Perf` logs the iteration history and the
//! measured GB/s.
//!
//! The stash layer ([`stash`]) is the memory path the paper's claims hinge
//! on: tensors are encoded by a bounded worker pool into a *tiered*
//! chunk-recycling arena (a DRAM tier plus a budget-driven file-backed
//! spill tier for cold chunk runs) under per-tensor container metadata,
//! and restored zero-copy — decoders read pinned arena chunks in place
//! through segmented bit readers instead of materialized stream copies.
//! The Trainer double-buffers the round-trip: encodes and the previous
//! step's restore-prefetch overlap the compiled step on the worker pool.
//! The ledger reports the *actually stored* bytes split into DRAM and
//! spill traffic — cross-checked against the analytic
//! [`report::footprint`] models (`repro stash`, with `--budget-bytes` as
//! a spill sweep axis), cut atomically per epoch for the
//! footprint-over-time reports, and fed to [`hwsim`]'s DRAM model.
//!
//! On top of the stash sits the multi-tenant serve layer ([`serve`]):
//! a [`serve::StashService`] owns one shared chunk arena, and each
//! concurrent session takes a [`serve::StashLease`] — tenant id, DRAM
//! byte budget, eviction priority, and a private owner-tagged ledger —
//! then opens ordinary [`stash::Stash`] facades over it
//! ([`serve::StashLease::open`]).  Admission caps the sum of lease
//! budgets at the service total, and placement evicts an over-budget
//! tenant's *own* coldest runs before the global backstop ever looks at
//! a neighbour — so one session churning at 10× its budget cannot push
//! another into spill thrash (property-tested).  `repro serve` scales a
//! simulated session fleet over one service and emits
//! `serve_sweep.json`: per-tenant p50/p99 restore latency split
//! DRAM-hit vs spill-fault, plus aggregate throughput by tenant count.
//!
//! The observability layer ([`obs`]) makes the pipeline's time visible
//! without ever touching its bytes: RAII spans (thread-local rings, a
//! global collector, `--trace out.json` Chrome trace-event export with
//! worker-process batches merged by job hash), lock-free counters and
//! p50/p99 latency histograms snapshotted to `metrics.json`, one leveled
//! CLI log sink (`--quiet`/`-v`), and a live TTY progress line.  Job
//! bodies never print or time themselves, so artifacts and manifests
//! stay fingerprint-identical with tracing on or off — CI proves it.
//!
//! On top of the spans sits a *flight recorder*: sampled gauges
//! ([`obs::timeseries`] — resident/spill stash bytes, encode-queue
//! depth, cache hit ratio, worker utilization) render as Chrome-trace
//! counter tracks next to the span timeline, and an always-on
//! structured event stream ([`obs::events`]) records every per-layer
//! stored-bitlength change a policy makes (and stash eviction/fault
//! bursts) with its triggering signal, serialized to `events.jsonl`
//! beside the lab manifest — written even when a run aborts partway,
//! and shipped across the process backend's pipe keyed by job hash.
//! The recorded events are the replay source for the
//! footprint-over-time figures, and `repro inspect RUN_DIR` reads the
//! whole recording back: per-layer bitlength trajectories, a health
//! summary, a structured two-run diff (artifact fingerprints, per-job
//! wall clock, metrics counters), and `--baseline BENCH.json --gate
//! PCT` perf-regression gating against a checked-in baseline.
//!
//! The lab layer ([`lab`]) scales the evaluation surface itself: every
//! sweep (`repro policy`, `repro stash`, `repro train`, the table/figure
//! emitters, and the full `repro all` paper grid) is a DAG of content-
//! hashed jobs run by a dependency-aware work-stealing executor over a
//! content-addressed on-disk result cache — a warm re-run skips every
//! unchanged job, a one-line config change re-runs only its cone, and
//! parallel artifacts are byte-identical to a serial run's.  One
//! `lab_manifest.json` per run records every artifact + hash + timing.
//!
//! Python never runs on the request path: `make artifacts` lowers the model
//! once; the `repro` binary is self-contained afterwards.  Builds without
//! the `pjrt` feature substitute a manifest-only runtime stub so the codec,
//! trace-model, and stash paths work everywhere.

pub mod baselines;
pub mod coordinator;
pub mod formats;
pub mod gecko;
pub mod hwsim;
pub mod lab;
pub mod obs;
pub mod policy;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod sfp;
pub mod stash;
pub mod stats;
pub mod traces;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
