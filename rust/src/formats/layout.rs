//! [`ExponentLayout`] — the exponent axis as a first-class shape.
//!
//! Schrödinger's FP learns a per-value exponent *field width* (stored
//! losslessly by Gecko); the two strongest related container families
//! shape the exponent differently:
//!
//! * [`ExponentLayout::Width`] — the paper's shape: every value keeps its
//!   own biased exponent in a learned `bits`-wide field, stored under a
//!   lossless Gecko [`Mode`] (delta or fixed-bias).  Quantization is pure
//!   mantissa truncation; the exponent never loses information.
//! * [`ExponentLayout::Bias`] — AdaptivFloat: a per-tensor *learned bias*
//!   centres a fixed `bits`-wide exponent window on the tensor's observed
//!   range.  Exponents below the window flush to (signed) zero; above it
//!   they saturate to the window top with a full mantissa.
//! * [`ExponentLayout::BlockShared`] — Flexpoint: one shared exponent per
//!   `block` values.  Each value stores an explicit-leading-one
//!   significand of `mant + 1` bits, right-shifted by its distance from
//!   the block maximum (small values lose low mantissa bits; values more
//!   than `mant` octaves below the block max flush to zero).
//!
//! Every layout defines a deterministic quantizer ([`ExponentLayout::
//! quantize_slice`]); the stash codecs round-trip bit-exactly to that
//! quantizer for all four codecs and both kernels (property-tested).

use super::{assemble, exponent, mag_width, quantize, Container, EXP_BITS, F32_MANT_BITS};
use crate::gecko::Mode;
use crate::util::json::Json;
use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

/// How a tensor's exponents are shaped and stored (see module docs).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ExponentLayout {
    /// Per-value exponent in a learned `bits`-wide field, stored under a
    /// lossless Gecko `mode` (today's Quantum-Exponent/BitWave shape).
    Width { bits: u32, mode: Mode },
    /// AdaptivFloat: fixed `bits`-wide field centred on a learned
    /// per-tensor `bias`; out-of-window values flush/saturate.
    Bias { bits: u32, bias: u8 },
    /// Flexpoint: one `bits`-wide exponent shared by each `block` values;
    /// values store `mant + 1`-bit explicit-leading-one significands.
    BlockShared { block: usize, bits: u32 },
}

impl Default for ExponentLayout {
    fn default() -> Self {
        ExponentLayout::Width {
            bits: EXP_BITS,
            mode: Mode::Delta,
        }
    }
}

impl ExponentLayout {
    /// The full-width per-value layout (the historical default).
    pub fn is_default(&self) -> bool {
        *self == Self::default()
    }

    /// Stored exponent-field width in bits, clamped to the container
    /// exponent field ([`EXP_BITS`]) — a plan can never charge more
    /// exponent bits than the container has.
    pub fn field_bits(&self) -> u32 {
        match *self {
            ExponentLayout::Width { bits, .. } => bits.min(EXP_BITS),
            ExponentLayout::Bias { bits, .. } => bits.clamp(1, EXP_BITS),
            ExponentLayout::BlockShared { bits, .. } => bits.clamp(1, EXP_BITS),
        }
    }

    /// Amortized exponent storage per value: the full field for per-value
    /// layouts, `bits / block` for a shared exponent.
    pub fn exponent_bits_per_value(&self) -> f64 {
        match *self {
            ExponentLayout::BlockShared { block, .. } => {
                self.field_bits() as f64 / block.max(1) as f64
            }
            _ => self.field_bits() as f64,
        }
    }

    /// Extra per-value mantissa-stream bits the layout costs: the
    /// block-shared significand carries an explicit leading one.
    pub fn mantissa_overhead_bits(&self) -> f64 {
        match self {
            ExponentLayout::BlockShared { .. } => 1.0,
            _ => 0.0,
        }
    }

    /// The Gecko storage mode for per-value exponent streams (`Delta`
    /// for the non-Width layouts, which do not use Gecko's adaptive path).
    pub fn gecko_mode(&self) -> Mode {
        match *self {
            ExponentLayout::Width { mode, .. } => mode,
            _ => Mode::Delta,
        }
    }

    /// Block size for shared-exponent layouts.
    pub fn block(&self) -> Option<usize> {
        match *self {
            ExponentLayout::BlockShared { block, .. } => Some(block.max(1)),
            _ => None,
        }
    }

    /// Short human label for event streams and tables.
    pub fn label(&self) -> String {
        match *self {
            ExponentLayout::Width { bits, mode: Mode::Delta } => format!("w{bits}"),
            ExponentLayout::Width {
                bits,
                mode: Mode::FixedBias { bias, .. },
            } => format!("w{bits}b{bias}"),
            ExponentLayout::Bias { bits, bias } => format!("af{bits}b{bias}"),
            ExponentLayout::BlockShared { block, bits } => format!("blk{block}e{bits}"),
        }
    }

    /// The exponent window `[lo, hi]` (biased) a `Bias` layout keeps;
    /// `None` for other layouts.
    pub fn bias_window(&self) -> Option<(i32, i32)> {
        match *self {
            ExponentLayout::Bias { bias, .. } => {
                let b = self.field_bits();
                // field value 0 is reserved for zero; the remaining
                // 2^b - 1 codes cover [lo, hi] centred on the bias
                let lo = bias as i32 - (1i32 << (b - 1)) + 1;
                Some((lo, lo + (1i32 << b) - 2))
            }
            _ => None,
        }
    }

    /// The container value every stored f32 is reduced to under this
    /// layout, for layouts whose quantizer is per-value.  Panics for
    /// `BlockShared` (use [`ExponentLayout::quantize_slice`]).
    pub fn quantize_value(&self, v: f32, mant: u32, container: Container) -> f32 {
        match *self {
            ExponentLayout::Width { .. } => quantize(v, mant, container),
            ExponentLayout::Bias { .. } => {
                let (lo, hi) = self.bias_window().unwrap();
                bias_quantize(v, mant, container, lo, hi)
            }
            ExponentLayout::BlockShared { .. } => {
                panic!("block-shared quantization needs the whole slice")
            }
        }
    }

    /// Quantize a whole tensor under this layout — the fixed point every
    /// stash codec round-trips to.
    pub fn quantize_slice(&self, vals: &[f32], mant: u32, container: Container) -> Vec<f32> {
        match *self {
            ExponentLayout::BlockShared { block, .. } => {
                let n = mant.min(container.mant_bits());
                let block = block.max(1);
                let (emaxs, fields) = block_fields(vals, n, container, block, self.field_bits());
                vals.iter()
                    .enumerate()
                    .map(|(i, &v)| {
                        block_value(emaxs[i / block], fields[i], v.to_bits() >> 31, n)
                    })
                    .collect()
            }
            _ => vals
                .iter()
                .map(|&v| self.quantize_value(v, mant, container))
                .collect(),
        }
    }

    // ---- serialization --------------------------------------------------

    /// Compact CLI/spec string (inverse of [`ExponentLayout::parse_spec`]);
    /// the default layout renders as `""`.
    pub fn spec_string(&self) -> String {
        match *self {
            _ if self.is_default() => String::new(),
            ExponentLayout::Width { bits, mode: Mode::Delta } => format!("width:{bits}"),
            ExponentLayout::Width { .. } => {
                panic!("fixed-bias width layouts are policy-internal, not spec-addressable")
            }
            ExponentLayout::Bias { bits, bias } => format!("bias:{bits}:{bias}"),
            ExponentLayout::BlockShared { block, bits } => format!("block:{block}:{bits}"),
        }
    }

    /// Parse a CLI/spec string: `""`/`"width"` (default), `"width:BITS"`,
    /// `"bias:BITS:BIAS"`, `"block:BLOCK"` (8-bit shared exponent) or
    /// `"block:BLOCK:BITS"`.
    pub fn parse_spec(s: &str) -> Result<Self> {
        let parts: Vec<&str> = s.split(':').collect();
        let num = |p: &str| -> Result<u32> {
            p.parse::<u32>()
                .map_err(|_| anyhow!("bad exponent-layout number '{p}' in '{s}'"))
        };
        match parts.as_slice() {
            [""] | ["width"] => Ok(Self::default()),
            ["width", b] => Ok(ExponentLayout::Width {
                bits: num(b)?,
                mode: Mode::Delta,
            }),
            ["bias", b, bias] => Ok(ExponentLayout::Bias {
                bits: num(b)?,
                bias: num(bias)?.min(254) as u8,
            }),
            ["block", blk] => Ok(ExponentLayout::BlockShared {
                block: num(blk)?.max(1) as usize,
                bits: EXP_BITS,
            }),
            ["block", blk, b] => Ok(ExponentLayout::BlockShared {
                block: num(blk)?.max(1) as usize,
                bits: num(b)?,
            }),
            _ => bail!("unknown exponent layout '{s}' (width:BITS|bias:BITS:BIAS|block:BLOCK[:BITS])"),
        }
    }

    /// JSON form for policy checkpoints (inverse of
    /// [`ExponentLayout::from_json`]).
    pub fn to_json(&self) -> Json {
        let obj = |k: &str, fields: Vec<(&str, f64)>| {
            let mut inner = BTreeMap::new();
            for (name, v) in fields {
                inner.insert(name.to_string(), Json::Num(v));
            }
            let mut o = BTreeMap::new();
            o.insert(k.to_string(), Json::Obj(inner));
            Json::Obj(o)
        };
        match *self {
            ExponentLayout::Width { bits, mode } => {
                let mut inner = BTreeMap::new();
                inner.insert("bits".to_string(), Json::Num(bits as f64));
                inner.insert(
                    "mode".to_string(),
                    match mode {
                        Mode::Delta => Json::Str("delta".to_string()),
                        Mode::FixedBias { bias, group } => {
                            let mut m = BTreeMap::new();
                            m.insert("bias".to_string(), Json::Num(bias as f64));
                            m.insert("group".to_string(), Json::Num(group as f64));
                            Json::Obj(m)
                        }
                    },
                );
                let mut o = BTreeMap::new();
                o.insert("width".to_string(), Json::Obj(inner));
                Json::Obj(o)
            }
            ExponentLayout::Bias { bits, bias } => obj(
                "bias",
                vec![("bits", bits as f64), ("bias", bias as f64)],
            ),
            ExponentLayout::BlockShared { block, bits } => obj(
                "block",
                vec![("block", block as f64), ("bits", bits as f64)],
            ),
        }
    }

    /// Parse the JSON form produced by [`ExponentLayout::to_json`].
    pub fn from_json(j: &Json) -> Result<Self> {
        let n = |j: &Json, k: &str| -> Result<f64> {
            j.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("exponent layout: missing number '{k}'"))
        };
        if let Some(w) = j.get("width") {
            let mode = match w.get("mode") {
                Some(Json::Str(s)) if s == "delta" => Mode::Delta,
                Some(m @ Json::Obj(_)) => Mode::FixedBias {
                    bias: n(m, "bias")? as u8,
                    group: n(m, "group")? as usize,
                },
                _ => bail!("exponent layout: bad width mode"),
            };
            Ok(ExponentLayout::Width {
                bits: n(w, "bits")? as u32,
                mode,
            })
        } else if let Some(b) = j.get("bias") {
            Ok(ExponentLayout::Bias {
                bits: n(b, "bits")? as u32,
                bias: n(b, "bias")? as u8,
            })
        } else if let Some(b) = j.get("block") {
            Ok(ExponentLayout::BlockShared {
                block: n(b, "block")? as usize,
                bits: n(b, "bits")? as u32,
            })
        } else {
            bail!("exponent layout: unknown shape")
        }
    }
}

/// AdaptivFloat per-value quantizer: mantissa truncation, then clamp the
/// biased exponent to the window `[lo, hi]` — below flushes to signed
/// zero, above saturates to `hi` with a full mantissa.
#[inline]
pub fn bias_quantize(v: f32, mant: u32, container: Container, lo: i32, hi: i32) -> f32 {
    let q = quantize(v, mant, container);
    let e = exponent(q) as i32;
    if e == 0 || e < lo {
        return f32::from_bits(q.to_bits() & 0x8000_0000);
    }
    if e > hi {
        let n = mant.min(container.mant_bits());
        let full = if n == 0 { 0 } else { ((1u32 << n) - 1) << (F32_MANT_BITS - n) };
        return assemble(q.to_bits() >> 31, hi as u32, full);
    }
    q
}

/// Flexpoint block fields: per block the shared (clamped) maximum biased
/// exponent, and per value the `mant + 1`-bit explicit-leading-one
/// significand shifted by its distance from the block maximum.  Handles
/// ragged final blocks (any `vals.len()`).
pub fn block_fields(
    vals: &[f32],
    mant: u32,
    container: Container,
    block: usize,
    exp_bits: u32,
) -> (Vec<u8>, Vec<u32>) {
    let n = mant.min(container.mant_bits());
    let block = block.max(1);
    let cap = ((1u32 << exp_bits.clamp(1, EXP_BITS)) - 1) as i32;
    let mut emaxs = Vec::with_capacity(vals.len().div_ceil(block));
    let mut fields = Vec::with_capacity(vals.len());
    for chunk in vals.chunks(block) {
        let emax = chunk.iter().map(|&v| exponent(v) as i32).max().unwrap_or(0);
        let emax_q = emax.min(cap);
        emaxs.push(emax_q as u8);
        for &v in chunk {
            let e = exponent(v) as i32;
            fields.push(if e == 0 || emax_q - e > n as i32 {
                0
            } else if e > emax_q {
                // the shared exponent was clamped below this value:
                // saturate to the block top with a full significand
                (1u32 << (n + 1)) - 1
            } else {
                let top = if n == 0 {
                    0
                } else {
                    (v.to_bits() >> (F32_MANT_BITS - n)) & ((1u32 << n) - 1)
                };
                ((1u32 << n) | top) >> (emax_q - e) as u32
            });
        }
    }
    (emaxs, fields)
}

/// Reconstruct one value from its block's shared exponent and its
/// significand field (inverse of [`block_fields`]; `sign` is the raw
/// sign bit).
#[inline]
pub fn block_value(emax: u8, field: u32, sign: u32, mant: u32) -> f32 {
    if field == 0 {
        return f32::from_bits(sign << 31);
    }
    let delta = mant + 1 - mag_width(field);
    let e = emax as u32 - delta;
    let m = if mant == 0 {
        0
    } else {
        ((field << delta) & ((1u32 << mant) - 1)) << (F32_MANT_BITS - mant)
    };
    assemble(sign, e, m)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_full_width_delta() {
        let d = ExponentLayout::default();
        assert!(d.is_default());
        assert_eq!(d.field_bits(), 8);
        assert_eq!(d.exponent_bits_per_value(), 8.0);
        assert_eq!(d.mantissa_overhead_bits(), 0.0);
        assert!(!ExponentLayout::Bias { bits: 4, bias: 127 }.is_default());
    }

    #[test]
    fn field_bits_clamps_to_container_field() {
        let w = ExponentLayout::Width { bits: 12, mode: Mode::Delta };
        assert_eq!(w.field_bits(), 8);
        let b = ExponentLayout::Bias { bits: 99, bias: 127 };
        assert_eq!(b.field_bits(), 8);
    }

    #[test]
    fn block_shared_amortizes_exponent() {
        let l = ExponentLayout::BlockShared { block: 16, bits: 8 };
        assert_eq!(l.exponent_bits_per_value(), 0.5);
        assert_eq!(l.mantissa_overhead_bits(), 1.0);
    }

    #[test]
    fn width_quantize_matches_plain_truncation() {
        let l = ExponentLayout::Width { bits: 5, mode: Mode::Delta };
        for &v in &[1.234f32, -9.75e-3, 0.0, -0.0, 6.022e23] {
            assert_eq!(
                l.quantize_value(v, 3, Container::Bf16).to_bits(),
                quantize(v, 3, Container::Bf16).to_bits()
            );
        }
    }

    #[test]
    fn bias_window_flush_and_saturate() {
        let l = ExponentLayout::Bias { bits: 4, bias: 127 };
        let (lo, hi) = l.bias_window().unwrap();
        assert_eq!((lo, hi), (120, 134));
        // in-window value survives as plain quantization
        let v = 1.5f32; // e = 127
        assert_eq!(
            l.quantize_value(v, 7, Container::Fp32).to_bits(),
            quantize(v, 7, Container::Fp32).to_bits()
        );
        // tiny value flushes to signed zero
        let tiny = -1e-20f32;
        let f = l.quantize_value(tiny, 7, Container::Fp32);
        assert_eq!(f.to_bits(), (-0.0f32).to_bits());
        // huge value saturates to the window top with full mantissa
        let huge = 1e20f32;
        let s = l.quantize_value(huge, 3, Container::Fp32);
        let (sg, e, m) = crate::formats::split(s);
        assert_eq!((sg, e as i32), (0, hi));
        assert_eq!(m, 0b111 << 20);
    }

    #[test]
    fn bias_full_width_window_is_lossless() {
        // an 8-bit window centred at 127 covers every normal exponent
        let l = ExponentLayout::Bias { bits: 8, bias: 127 };
        for &v in &[1.0f32, -3.5e-38, 2.9e38, 0.25, -7.0] {
            assert_eq!(
                l.quantize_value(v, 23, Container::Fp32).to_bits(),
                v.to_bits()
            );
        }
    }

    #[test]
    fn block_fields_roundtrip_block_max() {
        // the block max survives with its full (truncated) mantissa
        let vals = [8.0f32, 1.0, -0.5, 0.0, 6.5, 0.125];
        let n = 4;
        let (emaxs, fields) = block_fields(&vals, n, Container::Fp32, 3, 8);
        assert_eq!(emaxs.len(), 2);
        let back: Vec<f32> = vals
            .iter()
            .enumerate()
            .map(|(i, &v)| block_value(emaxs[i / 3], fields[i], v.to_bits() >> 31, n))
            .collect();
        assert_eq!(back[0], 8.0);
        assert_eq!(back[4], 6.5);
        // values within n octaves of the max keep their exponent
        assert_eq!(crate::formats::exponent(back[1]), crate::formats::exponent(1.0f32));
        // a value > n octaves below the block max flushes to zero
        assert_eq!(back[3].to_bits(), 0);
    }

    #[test]
    fn block_quantize_slice_is_idempotent() {
        let l = ExponentLayout::BlockShared { block: 4, bits: 8 };
        let vals: Vec<f32> = (0..23).map(|i| ((i * 37) % 19) as f32 * 0.37 - 3.0).collect();
        let q1 = l.quantize_slice(&vals, 3, Container::Bf16);
        let q2 = l.quantize_slice(&q1, 3, Container::Bf16);
        for (a, b) in q1.iter().zip(&q2) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn block_zero_mantissa_corner() {
        // n = 0: one-bit significands — values either hold the block
        // exponent exactly or flush
        let vals = [4.0f32, 5.5, 2.0, 0.0];
        let (emaxs, fields) = block_fields(&vals, 0, Container::Bf16, 4, 8);
        let back: Vec<f32> = vals
            .iter()
            .enumerate()
            .map(|(i, &v)| block_value(emaxs[i / 4], fields[i], v.to_bits() >> 31, 0))
            .collect();
        assert_eq!(back[0], 4.0);
        assert_eq!(back[1], 4.0); // mantissa truncated away at the shared exponent
        assert_eq!(back[2].to_bits(), 0); // > 0 octaves below max flushes
        assert_eq!(back[3].to_bits(), 0);
    }

    #[test]
    fn spec_string_roundtrip() {
        for l in [
            ExponentLayout::default(),
            ExponentLayout::Width { bits: 5, mode: Mode::Delta },
            ExponentLayout::Bias { bits: 4, bias: 127 },
            ExponentLayout::BlockShared { block: 16, bits: 8 },
            ExponentLayout::BlockShared { block: 32, bits: 6 },
        ] {
            assert_eq!(ExponentLayout::parse_spec(&l.spec_string()).unwrap(), l);
        }
        assert_eq!(
            ExponentLayout::parse_spec("block:16").unwrap(),
            ExponentLayout::BlockShared { block: 16, bits: 8 }
        );
        assert!(ExponentLayout::parse_spec("nope:3").is_err());
    }

    #[test]
    fn json_roundtrip_all_shapes() {
        for l in [
            ExponentLayout::default(),
            ExponentLayout::Width {
                bits: 4,
                mode: Mode::FixedBias { bias: 121, group: 8 },
            },
            ExponentLayout::Bias { bits: 4, bias: 130 },
            ExponentLayout::BlockShared { block: 16, bits: 8 },
        ] {
            assert_eq!(ExponentLayout::from_json(&l.to_json()).unwrap(), l);
        }
    }
}
