//! IEEE-754 container utilities — the numeric-format ground truth.
//!
//! Mirrors `python/compile/kernels/ref.py` bit-for-bit; the cross-language
//! golden tests in `rust/tests/golden.rs` pin the two implementations
//! together.  Everything operates on the raw `u32` pattern of an `f32`:
//! `[sign(1) | exponent(8, bias 127) | mantissa(23)]`.  A BFloat16 value is
//! modelled as an `f32` whose low 16 mantissa bits are zero (the hardware
//! ships 16-bit containers; the arithmetic value is identical).

pub mod layout;

pub use layout::ExponentLayout;

/// Mantissa bits of an IEEE-754 binary32.
pub const F32_MANT_BITS: u32 = 23;
/// Mantissa bits of BFloat16.
pub const BF16_MANT_BITS: u32 = 7;
/// Exponent field width shared by FP32 and BFloat16.
pub const EXP_BITS: u32 = 8;
/// Exponent bias shared by FP32 and BFloat16.
pub const EXP_BIAS: i32 = 127;

/// The floating-point container values are stashed in (§IV).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Container {
    Fp32,
    Bf16,
}

impl Container {
    /// Mantissa bits the container can hold (the paper's `m`).
    pub fn mant_bits(self) -> u32 {
        match self {
            Container::Fp32 => F32_MANT_BITS,
            Container::Bf16 => BF16_MANT_BITS,
        }
    }

    /// Uncompressed bits per value in this container.
    pub fn total_bits(self) -> u32 {
        1 + EXP_BITS + self.mant_bits()
    }
}

impl std::fmt::Display for Container {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Container::Fp32 => write!(f, "FP32"),
            Container::Bf16 => write!(f, "BF16"),
        }
    }
}

/// Split an `f32` into `(sign, biased exponent, mantissa)` fields.
#[inline]
pub fn split(x: f32) -> (u32, u32, u32) {
    let b = x.to_bits();
    (b >> 31, (b >> 23) & 0xFF, b & 0x7F_FFFF)
}

/// Reassemble an `f32` from `(sign, biased exponent, mantissa)` fields.
#[inline]
pub fn assemble(sign: u32, exp: u32, mant: u32) -> f32 {
    f32::from_bits((sign << 31) | ((exp & 0xFF) << 23) | (mant & 0x7F_FFFF))
}

/// Biased exponent byte of an `f32` (0 for zeros/denormals, 255 for inf/NaN).
#[inline]
pub fn exponent(x: f32) -> u8 {
    ((x.to_bits() >> 23) & 0xFF) as u8
}

/// Eq. 5: keep the top `n` mantissa bits (`n` counted within the f32
/// mantissa field), truncating the rest.  `n = 23` is the identity,
/// `n = 0` keeps only sign + exponent (value becomes ±2^e).
#[inline]
pub fn truncate_mantissa(x: f32, n: u32) -> f32 {
    debug_assert!(n <= F32_MANT_BITS);
    let mask = (u32::MAX) << (F32_MANT_BITS - n);
    f32::from_bits(x.to_bits() & mask)
}

/// Truncate a full `f32` into its BFloat16-contained twin (drop the low
/// 16 bits — round-toward-zero, matching the Pallas kernel semantics).
#[inline]
pub fn to_bf16(x: f32) -> f32 {
    f32::from_bits(x.to_bits() & 0xFFFF_0000)
}

/// The 16-bit BFloat16 payload of an `f32` (after [`to_bf16`] truncation).
#[inline]
pub fn bf16_bits(x: f32) -> u16 {
    (x.to_bits() >> 16) as u16
}

/// Quantize into a container: clamp `n` to the container's mantissa length
/// and truncate; for BF16 this also drops the low 16 f32 bits.
#[inline]
pub fn quantize(x: f32, n: u32, container: Container) -> f32 {
    let n = n.min(container.mant_bits());
    let drop = F32_MANT_BITS - n;
    f32::from_bits(x.to_bits() & (u32::MAX << drop))
}

/// Bits needed to represent `mag` (0 for 0): `32 - clz`, the hardware's
/// leading-one detector (§IV-C).
#[inline]
pub fn mag_width(mag: u32) -> u32 {
    32 - mag.leading_zeros()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_assemble_roundtrip() {
        for &x in &[0.0f32, -0.0, 1.0, -1.5, 3.141592, 1e-38, 1e38, 255.75] {
            let (s, e, m) = split(x);
            assert_eq!(assemble(s, e, m).to_bits(), x.to_bits());
        }
    }

    #[test]
    fn truncate_full_width_is_identity() {
        for &x in &[1.234f32, -9.75e-3, 6.022e23] {
            assert_eq!(truncate_mantissa(x, 23).to_bits(), x.to_bits());
        }
    }

    #[test]
    fn truncate_zero_keeps_sign_exponent() {
        let x = -13.37f32;
        let t = truncate_mantissa(x, 0);
        let (s, e, m) = split(t);
        assert_eq!((s, e, m), (1, split(x).1, 0));
        // magnitude is the power of two at x's exponent
        assert_eq!(t, -8.0);
    }

    #[test]
    fn truncate_monotone_in_bits() {
        // more bits kept => error does not grow
        let x = 0.7853981f32;
        let mut prev = f32::INFINITY;
        for n in 0..=23 {
            let err = (x - truncate_mantissa(x, n)).abs();
            assert!(err <= prev);
            prev = err;
        }
    }

    #[test]
    fn truncate_error_bound() {
        // truncation error < 2^(e - n)
        let xs: Vec<f32> = (1..1000).map(|i| (i as f32) * 0.37 - 180.0).collect();
        for &x in &xs {
            for n in [1u32, 4, 8, 15] {
                let q = truncate_mantissa(x, n);
                let e = x.abs().log2().floor();
                assert!((x - q).abs() <= 2f32.powf(e - n as f32));
            }
        }
    }

    #[test]
    fn bf16_container_zeroes_low_16() {
        let x = 1.2345678f32;
        assert_eq!(to_bf16(x).to_bits() & 0xFFFF, 0);
        assert_eq!(quantize(x, 23, Container::Bf16).to_bits() & 0xFFFF, 0);
        // bf16 quantize with n=7 == plain bf16 truncation
        assert_eq!(
            quantize(x, 7, Container::Bf16).to_bits(),
            to_bf16(x).to_bits()
        );
    }

    #[test]
    fn bf16_bits_roundtrip() {
        let x = -2.71828f32;
        let payload = bf16_bits(x);
        assert_eq!(f32::from_bits((payload as u32) << 16), to_bf16(x));
    }

    #[test]
    fn exponent_field() {
        assert_eq!(exponent(1.0), 127);
        assert_eq!(exponent(2.0), 128);
        assert_eq!(exponent(0.5), 126);
        assert_eq!(exponent(0.0), 0);
        assert_eq!(exponent(f32::INFINITY), 255);
    }

    #[test]
    fn mag_width_matches_leading_one_detector() {
        assert_eq!(mag_width(0), 0);
        assert_eq!(mag_width(1), 1);
        assert_eq!(mag_width(2), 2);
        assert_eq!(mag_width(3), 2);
        assert_eq!(mag_width(4), 3);
        assert_eq!(mag_width(255), 8);
    }

    #[test]
    fn container_totals() {
        assert_eq!(Container::Fp32.total_bits(), 32);
        assert_eq!(Container::Bf16.total_bits(), 16);
    }
}
