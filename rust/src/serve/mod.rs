//! Multi-tenant stash service — a shared chunk-store that training
//! sessions *lease* slices of.
//!
//! The stash was built as a private resource: one [`Stash`], one
//! [`ChunkArena`], one budget.  Serving several concurrent sessions
//! (fine-tunes, eval jobs, a second experiment on the same box) from one
//! memory pool needs one more layer: a [`StashService`] owns a single
//! shared arena, and each session takes a [`StashLease`] — a tenant id, a
//! DRAM byte budget, an eviction priority, and a private
//! [`StashLedger`] — then opens ordinary [`Stash`] facades over it:
//!
//! ```text
//!  StashService::new(total_budget) ─── owns ──▶ [shared ChunkArena]
//!        │ lease("t0", budget, pri)                  ▲  ▲
//!        ▼                                           │  │ store_for(tenant)
//!  StashLease ── open(cfg) ──▶ Stash facade ─────────┘  │
//!  StashLease ── open(cfg) ──▶ Stash facade ────────────┘
//!     │ per-tenant ledger (owner-tagged pressure events,
//!     ▼  restore-latency tier split, epoch cuts)
//!  metrics.json / events.jsonl / serve_sweep.json
//! ```
//!
//! **Fair eviction.**  Placement enforces the *per-tenant* budgets first:
//! a tenant that crosses its own budget evicts its own coldest runs, and
//! the arena-global budget only acts as a backstop (by priority, then
//! age).  Because admission caps the sum of leased budgets at the
//! service's total, the backstop never fires under leases alone — so a
//! tenant churning at 10× its budget cannot drive a well-behaved
//! neighbour into spill thrash (property-tested below and in
//! `arena::tests`).
//!
//! **Observability.**  Each lease's ledger is owner-tagged
//! ([`StashLedger::set_owner`]), so eviction storms and fault bursts in
//! `events.jsonl` carry the offending tenant, `repro inspect` can
//! attribute thrash, and per-tenant restore-latency digests split
//! DRAM-hit vs spill-fault.  The [`measure`] submodule is the `repro
//! serve` load scenario: N simulated sessions round-robin over one
//! service, emitting a deterministic lab artifact plus wall-clock
//! latency/throughput observations collected through the process-global
//! registry here ([`take_observations`]).

pub mod measure;

pub use measure::{run_serve_measurement, ServeMeasurement, ServeTenantRow};

use crate::obs::metrics::HistSummary;
use crate::stash::{ChunkArena, Stash, StashConfig, StashLedger, TenantStats};
use anyhow::{anyhow, Result};
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

/// A shared chunk-store sessions lease from: one arena, many tenants.
pub struct StashService {
    arena: Arc<ChunkArena>,
    /// Arena-global DRAM budget (0 = unbounded service).
    total_budget_bytes: usize,
    /// Sum of admitted lease budgets — admission state.
    leased_bytes: Mutex<usize>,
}

impl StashService {
    /// Create a service with `total_budget_bytes` of resident DRAM across
    /// all tenants (0 = unbounded, spill tier off) spilling cold runs
    /// under `spill_dir` (`None` = temp dir).
    pub fn new(total_budget_bytes: usize, spill_dir: Option<PathBuf>) -> StashService {
        StashService {
            arena: Arc::new(ChunkArena::with_budget(total_budget_bytes, spill_dir, None)),
            total_budget_bytes,
            leased_bytes: Mutex::new(0),
        }
    }

    /// Admit one tenant: reserve `budget_bytes` of the service's DRAM
    /// budget under `label` at `priority` (higher survives the global
    /// backstop longer).  Admission fails when the lease would
    /// oversubscribe the service — keeping the sum of lease budgets
    /// within the total is exactly what makes eviction fair (no tenant
    /// can push another into the spill tier).  On a bounded service every
    /// lease must be bounded too.
    pub fn lease(&self, label: &str, budget_bytes: usize, priority: u8) -> Result<StashLease> {
        if self.total_budget_bytes != 0 {
            if budget_bytes == 0 {
                return Err(anyhow!(
                    "lease '{label}': unbounded lease on a bounded service"
                ));
            }
            let mut leased = self.leased_bytes.lock().unwrap();
            if *leased + budget_bytes > self.total_budget_bytes {
                return Err(anyhow!(
                    "lease '{label}': {budget_bytes} B oversubscribes the service \
                     ({} of {} B already leased)",
                    *leased,
                    self.total_budget_bytes
                ));
            }
            *leased += budget_bytes;
        }
        let ledger = Arc::new(StashLedger::new());
        ledger.set_owner(label);
        let tenant = self
            .arena
            .register_tenant(budget_bytes, priority, Some(Arc::clone(&ledger)));
        Ok(StashLease {
            arena: Arc::clone(&self.arena),
            ledger,
            tenant,
            label: label.to_string(),
            budget_bytes,
            priority,
        })
    }

    /// The shared arena (aggregate accounting: in-use/spill/high-water).
    pub fn arena(&self) -> &Arc<ChunkArena> {
        &self.arena
    }

    /// Sum of admitted lease budgets.
    pub fn leased_bytes(&self) -> usize {
        *self.leased_bytes.lock().unwrap()
    }

    /// The service's arena-global budget (0 = unbounded).
    pub fn total_budget_bytes(&self) -> usize {
        self.total_budget_bytes
    }
}

/// One tenant's handle on a [`StashService`]: identity, budget, priority,
/// and the private owner-tagged ledger its traffic lands in.
pub struct StashLease {
    arena: Arc<ChunkArena>,
    ledger: Arc<StashLedger>,
    tenant: u32,
    label: String,
    budget_bytes: usize,
    priority: u8,
}

impl StashLease {
    /// Open a [`Stash`] facade over the shared arena under this lease.
    /// `cfg.budget_bytes` is ignored — the lease's budget governs
    /// placement.  Several facades may share one lease (they share its
    /// budget and ledger).
    pub fn open(&self, cfg: StashConfig) -> Stash {
        Stash::with_arena(
            cfg,
            Arc::clone(&self.arena),
            Arc::clone(&self.ledger),
            self.tenant,
        )
    }

    /// Arena tenant id this lease stores under.
    pub fn tenant(&self) -> u32 {
        self.tenant
    }

    pub fn label(&self) -> &str {
        &self.label
    }

    pub fn budget_bytes(&self) -> usize {
        self.budget_bytes
    }

    pub fn priority(&self) -> u8 {
        self.priority
    }

    /// The lease's private ledger (owner-tagged at admission).
    pub fn ledger(&self) -> &Arc<StashLedger> {
        &self.ledger
    }

    /// This tenant's accounting slice of the shared arena.
    pub fn stats(&self) -> TenantStats {
        self.arena.tenant_stats(self.tenant)
    }
}

/// One wall-clock observation from a serve scenario: a tenant's restore
/// latency digests (DRAM-hit vs spill-fault) and restored volume at one
/// tenant-count scale point.  Latency never enters content-addressed
/// artifacts — observations flow through the process-global registry and
/// are appended only to the *surfaced* `serve_sweep.json`.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeObservation {
    /// Tenant count of the scenario this sample came from.
    pub scale_tenants: usize,
    /// Lease label (`t0`, `t1`, …).
    pub tenant: String,
    /// Restore latency, all chunks DRAM-resident.
    pub dram: HistSummary,
    /// Restore latency, ≥1 chunk faulted back from the spill tier.
    pub fault: HistSummary,
    /// Bytes this tenant restored (decoded stream bytes).
    pub restored_bytes: f64,
    /// Wall-clock of the whole scenario's measured section, µs (shared by
    /// every tenant of the scale point; aggregate throughput =
    /// Σ restored_bytes / wall).
    pub wall_us: u64,
}

static OBSERVATIONS: Mutex<Vec<ServeObservation>> = Mutex::new(Vec::new());

/// Record one serve observation in the process-global registry.
pub fn push_observation(o: ServeObservation) {
    if let Ok(mut sink) = OBSERVATIONS.lock() {
        sink.push(o);
    }
}

/// Drain the registry — the `repro serve` driver calls this after the lab
/// run and appends the samples to the surfaced sweep JSON (cache-warm
/// re-runs execute nothing, drain nothing, and append nothing).
pub fn take_observations() -> Vec<ServeObservation> {
    match OBSERVATIONS.lock() {
        Ok(mut sink) => std::mem::take(&mut *sink),
        Err(_) => Vec::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::Container;
    use crate::stash::{CodecKind, ContainerMeta, TensorId, CHUNK_BYTES};
    use crate::traces::ValueModel;

    fn raw_cfg() -> StashConfig {
        StashConfig {
            codec: CodecKind::Raw,
            threads: 1,
            queue_depth: 2,
            chunk_values: 4096,
            budget_bytes: 0,
        }
    }

    #[test]
    fn lease_admission_enforces_the_service_budget() {
        let svc = StashService::new(4 * CHUNK_BYTES, None);
        let a = svc.lease("t0", 2 * CHUNK_BYTES, 0).unwrap();
        assert_eq!(a.label(), "t0");
        assert_eq!(a.budget_bytes(), 2 * CHUNK_BYTES);
        assert_eq!(a.ledger().owner().as_deref(), Some("t0"));
        let b = svc.lease("t1", 2 * CHUNK_BYTES, 1).unwrap();
        assert_ne!(a.tenant(), b.tenant());
        assert_eq!(svc.leased_bytes(), 4 * CHUNK_BYTES);
        // the service is fully subscribed: one more byte is refused…
        assert!(svc.lease("t2", CHUNK_BYTES, 0).is_err());
        // …and a bounded service never admits an unbounded lease
        assert!(svc.lease("t3", 0, 0).is_err());
        // an unbounded service admits anything
        let open = StashService::new(0, None);
        assert!(open.lease("x", 0, 0).is_ok());
        assert!(open.lease("y", 123 * CHUNK_BYTES, 0).is_ok());
    }

    #[test]
    fn churning_lease_cannot_thrash_a_neighbor() {
        // The ISSUE's fairness property at the service level: tenant A
        // churning a working set ~10× its own budget, concurrently, must
        // not raise well-behaved tenant B's fault count at all — B stays
        // under its budget, so per-tenant placement never touches it and
        // the global backstop never fires (Σ lease budgets = total).
        let svc = StashService::new(6 * CHUNK_BYTES, None);
        let victim = svc.lease("calm", 4 * CHUNK_BYTES, 0).unwrap();
        let churner = svc.lease("churn", 2 * CHUNK_BYTES, 0).unwrap();
        let vs = victim.open(raw_cfg());
        let meta = ContainerMeta::new(Container::Fp32, 23);
        // victim: 3 one-chunk tensors, comfortably under its 4-chunk lease
        let tensors: Vec<Vec<f32>> = (0..3)
            .map(|i| ValueModel::weights().sample_values(4000, i as u64, false))
            .collect();
        for (i, t) in tensors.iter().enumerate() {
            vs.put(TensorId::act(i), t.clone(), meta);
        }
        vs.flush();
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let churn_thread = {
            let stop = Arc::clone(&stop);
            let cs = churner.open(raw_cfg());
            std::thread::spawn(move || {
                let churn: Vec<Vec<f32>> = (0..20)
                    .map(|i| ValueModel::weights().sample_values(4000, 100 + i as u64, false))
                    .collect();
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    for (i, t) in churn.iter().enumerate() {
                        cs.put(TensorId::weight(i), t.clone(), meta);
                    }
                    let ids: Vec<TensorId> = (0..20).map(TensorId::weight).collect();
                    for v in cs.take_all(&ids) {
                        assert!(v.is_some());
                    }
                }
                assert_eq!(cs.failures(), 0);
            })
        };
        // sample the victim's takes while the churn is live
        for round in 0..30 {
            let i = round % 3;
            let back = vs.get(TensorId::act(i)).unwrap();
            for (&v, &b) in tensors[i].iter().zip(&back) {
                assert_eq!(meta.quantized(v).to_bits(), b.to_bits());
            }
        }
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
        churn_thread.join().unwrap();
        // the churner thrashed itself…
        assert!(churner.stats().evictions > 0, "churner must self-evict");
        assert!(churner.stats().faults > 0);
        // …and never displaced a single victim chunk
        assert_eq!(victim.stats().evictions, 0, "victim must not be evicted");
        assert_eq!(victim.stats().faults, 0, "victim must not fault");
        assert_eq!(vs.failures(), 0);
    }

    #[test]
    fn observation_registry_drains_once() {
        let o = ServeObservation {
            scale_tenants: 99,
            tenant: "t0".into(),
            dram: HistSummary::default(),
            fault: HistSummary::default(),
            restored_bytes: 1.0,
            wall_us: 2,
        };
        push_observation(o.clone());
        let got = take_observations();
        assert!(got.contains(&o));
        assert!(!take_observations().contains(&o));
    }
}
