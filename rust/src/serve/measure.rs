//! The `repro serve` load scenario: N simulated training sessions —
//! trace-model streams under an adaptive mantissa policy, like `repro
//! stash` — each holding a [`StashLease`](super::StashLease) on one
//! shared [`StashService`](super::StashService), put/restore cycling
//! every step.
//!
//! Determinism contract: the artifact ([`ServeMeasurement::to_json`])
//! carries only counter-derived values (bits, evictions, faults, the
//! fairness-probe verdict), never timings.  Sessions run round-robin on
//! the driver thread with single-worker facade pools, so the arena sees
//! one deterministic operation order and the artifact bytes depend only
//! on the [`ServeSpec`] — cache fingerprints stay stable across re-runs
//! and machines.  Wall-clock restore latency (the p50/p99 DRAM-hit vs
//! spill-fault split) and throughput are *observations*: they flow
//! through the process-global registry
//! ([`super::push_observation`]/[`super::take_observations`]) and the
//! CLI appends them to the *surfaced* sweep JSON only.
//!
//! The embedded fairness probe replays the ISSUE's property end-to-end:
//! the same victim session runs once alone and once beside a tenant
//! churning ten victim-sized working sets through its own equal-sized
//! lease every step; per-tenant placement must keep the victim's fault
//! count flat (within a two-chunk slack), or the measurement reports
//! `fair_eviction: false`.

use super::{push_observation, ServeObservation, StashService};
use crate::lab::measure::{mantissa_policy, trace_model};
use crate::lab::spec::ServeSpec;
use crate::report::footprint::{ACT_EXP_SEED, ACT_VAL_SEED, WEIGHT_EXP_SEED, WEIGHT_VAL_SEED};
use crate::stash::{ContainerMeta, Stash, StashConfig, TensorId};
use crate::traces::{values_with_exponents, NetworkTrace};
use crate::util::json::Json;
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;

/// Extra faults the contended fairness-probe phase may show over the solo
/// phase before the measurement calls the eviction policy unfair (absorbs
/// chunk-boundary rounding; cross-tenant eviction would blow far past it).
const FAIR_FAULT_SLACK: u64 = 2;

/// One tenant's deterministic slice of a serve run.
#[derive(Debug, Clone)]
pub struct ServeTenantRow {
    pub label: String,
    pub written_bits: f64,
    pub read_bits: f64,
    pub spill_written_bits: f64,
    pub spill_read_bits: f64,
    pub evictions: u64,
    pub faults: u64,
    /// Epoch cuts recorded on the tenant's ledger (one per step).
    pub epochs: usize,
}

/// The full result of one serve scenario at one tenant count.
#[derive(Debug, Clone)]
pub struct ServeMeasurement {
    pub spec: ServeSpec,
    pub codec_name: &'static str,
    /// Arena-global budget: `tenants × spec.budget_bytes` (fully leased).
    pub global_budget_bytes: usize,
    pub tenants: Vec<ServeTenantRow>,
    pub total_written_bits: f64,
    pub total_read_bits: f64,
    pub total_evictions: u64,
    pub total_faults: u64,
    pub dram_high_water_bytes: usize,
    pub spill_high_water_bytes: usize,
    /// Fairness probe: the victim session's faults running alone…
    pub solo_faults: u64,
    /// …and beside a 10× churner on an equal lease.
    pub contended_faults: u64,
    pub fair_eviction: bool,
    pub restore_bit_exact: bool,
    /// Wall-clock latency/throughput samples (also pushed to the serve
    /// registry) — observations only, never part of [`Self::to_json`].
    pub observations: Vec<ServeObservation>,
}

impl ServeMeasurement {
    /// Deterministic JSON row (the lab artifact; counters only, no
    /// timings — latency observations ride the serve registry instead).
    pub fn to_json(&self) -> Json {
        let mut row = BTreeMap::new();
        let mut put = |k: &str, v: Json| {
            row.insert(k.to_string(), v);
        };
        put("model", Json::Str(self.spec.model.clone()));
        put("codec", Json::Str(self.codec_name.to_string()));
        put("policy", Json::Str(self.spec.policy.clone()));
        put("tenants", Json::Num(self.spec.tenants as f64));
        put("steps", Json::Num(self.spec.steps as f64));
        put("budget_bytes", Json::Num(self.spec.budget_bytes as f64));
        put(
            "global_budget_bytes",
            Json::Num(self.global_budget_bytes as f64),
        );
        put("written_mb", Json::Num(self.total_written_bits / 8e6));
        put("read_mb", Json::Num(self.total_read_bits / 8e6));
        put("evictions", Json::Num(self.total_evictions as f64));
        put("faults", Json::Num(self.total_faults as f64));
        put(
            "dram_high_water_bytes",
            Json::Num(self.dram_high_water_bytes as f64),
        );
        put(
            "spill_high_water_bytes",
            Json::Num(self.spill_high_water_bytes as f64),
        );
        put("solo_faults", Json::Num(self.solo_faults as f64));
        put("contended_faults", Json::Num(self.contended_faults as f64));
        put("fair_eviction", Json::Bool(self.fair_eviction));
        put("restore_bit_exact", Json::Bool(self.restore_bit_exact));
        let tenants: Vec<Json> = self
            .tenants
            .iter()
            .map(|t| {
                let mut m = BTreeMap::new();
                m.insert("tenant".to_string(), Json::Str(t.label.clone()));
                m.insert("written_bits".to_string(), Json::Num(t.written_bits));
                m.insert("read_bits".to_string(), Json::Num(t.read_bits));
                m.insert(
                    "spill_written_bits".to_string(),
                    Json::Num(t.spill_written_bits),
                );
                m.insert("spill_read_bits".to_string(), Json::Num(t.spill_read_bits));
                m.insert("evictions".to_string(), Json::Num(t.evictions as f64));
                m.insert("faults".to_string(), Json::Num(t.faults as f64));
                m.insert("epochs".to_string(), Json::Num(t.epochs as f64));
                Json::Obj(m)
            })
            .collect();
        put("per_tenant", Json::Arr(tenants));
        Json::Obj(row)
    }
}

/// One session's tensor streams: the trace model's layers under the
/// policy's integer schedule, sampled with the tenant-mixed seed (the
/// `repro stash` seed idiom, so two tenants never share value streams).
fn session_streams(
    spec: &ServeSpec,
    net: &NetworkTrace,
    sched: &[(u32, u32)],
    tseed: u64,
) -> Vec<(TensorId, Vec<f32>, ContainerMeta)> {
    let mut streams = Vec::with_capacity(2 * net.layers.len());
    for (i, l) in net.layers.iter().enumerate() {
        let seed = tseed ^ i as u64;
        let (n_a, n_w) = sched[i];
        let a_exps = l.act_model.sample_exponents(spec.sample, seed ^ ACT_EXP_SEED);
        let a_vals = values_with_exponents(&a_exps, seed ^ ACT_VAL_SEED, l.nonneg_act);
        let a_meta = ContainerMeta::new(spec.container, n_a).with_sign_elision(l.nonneg_act);
        streams.push((TensorId::act(i), a_vals, a_meta));

        let w_count = spec.sample.min(l.weight_elems.max(64));
        let w_exps = l.weight_model.sample_exponents(w_count, seed ^ WEIGHT_EXP_SEED);
        let w_vals = values_with_exponents(&w_exps, seed ^ WEIGHT_VAL_SEED, false);
        let w_meta = ContainerMeta::new(spec.container, n_w);
        streams.push((TensorId::weight(i), w_vals, w_meta));
    }
    streams
}

/// Submit every stream and barrier until the encodes land.
fn put_all(stash: &Stash, streams: &[(TensorId, Vec<f32>, ContainerMeta)]) {
    for (id, vals, meta) in streams {
        stash.put(*id, vals.clone(), *meta);
    }
    stash.flush();
}

/// Restore every stream (faulting spilled runs back) and verify each
/// value against the quantized original; returns bit-exactness.
fn take_verify(stash: &Stash, streams: &[(TensorId, Vec<f32>, ContainerMeta)]) -> bool {
    let ids: Vec<TensorId> = streams.iter().map(|(id, ..)| *id).collect();
    let back = stash.take_all(&ids);
    let mut exact = true;
    for ((_, vals, meta), b) in streams.iter().zip(&back) {
        match b {
            Some(b) if b.len() == vals.len() => {
                for (&v, &x) in vals.iter().zip(b) {
                    if meta.quantized(v).to_bits() != x.to_bits() {
                        exact = false;
                        break;
                    }
                }
            }
            _ => exact = false,
        }
    }
    exact
}

/// Two-phase fairness probe: the same victim session runs solo, then
/// beside a churner streaming ten victim-sized working sets through an
/// equal lease every step.  Returns `(solo_faults, contended_faults)` —
/// both deterministic (serialized single-worker sessions).
fn fairness_probe(
    spec: &ServeSpec,
    net: &NetworkTrace,
    sched: &[(u32, u32)],
    cfg: StashConfig,
) -> Result<(u64, u64)> {
    let victim_seed = spec.seed ^ 0xFA1E_0000_0000_0001;
    let steps = spec.steps.max(1);
    let streams = session_streams(spec, net, sched, victim_seed);

    let solo = {
        let svc = StashService::new(spec.budget_bytes, None);
        let lease = svc.lease("probe.victim", spec.budget_bytes, 0)?;
        let stash = lease.open(cfg);
        for _ in 0..steps {
            put_all(&stash, &streams);
            take_verify(&stash, &streams);
        }
        if stash.failures() > 0 {
            return Err(anyhow!("fairness probe: solo session worker failed"));
        }
        lease.stats().faults
    };

    let contended = {
        let svc = StashService::new(2 * spec.budget_bytes, None);
        let victim = svc.lease("probe.victim", spec.budget_bytes, 0)?;
        let churner = svc.lease("probe.churn", spec.budget_bytes, 0)?;
        let vstash = victim.open(cfg);
        let cstash = churner.open(cfg);
        let churn_sets: Vec<Vec<(TensorId, Vec<f32>, ContainerMeta)>> = (0..10u64)
            .map(|k| session_streams(spec, net, sched, spec.seed ^ ((k + 1) << 40)))
            .collect();
        for _ in 0..steps {
            // victim resident, then the churner floods its own lease —
            // any cross-tenant eviction would surface as victim faults on
            // the take below
            put_all(&vstash, &streams);
            for set in &churn_sets {
                put_all(&cstash, set);
                take_verify(&cstash, set);
            }
            take_verify(&vstash, &streams);
        }
        if vstash.failures() + cstash.failures() > 0 {
            return Err(anyhow!("fairness probe: contended session worker failed"));
        }
        victim.stats().faults
    };

    Ok((solo, contended))
}

/// Run one serve scenario: `spec.tenants` leased sessions, each cycling
/// its stream set through put → restore-verify → epoch cut for
/// `spec.steps` steps over one fully-leased shared arena.  Deterministic
/// by construction (see the module docs); latency/throughput samples are
/// pushed to the serve registry as a side channel.
pub fn run_serve_measurement(spec: &ServeSpec) -> Result<ServeMeasurement> {
    if spec.tenants == 0 {
        return Err(anyhow!("serve needs at least one tenant"));
    }
    if spec.budget_bytes == 0 {
        return Err(anyhow!(
            "serve needs a per-tenant budget (0 would disable the spill tier)"
        ));
    }
    let net = trace_model(&spec.model)?;
    let policy = mantissa_policy(&spec.policy, spec.container)?;
    let sched = policy.integer_schedule(net.layers.len(), spec.container);
    let global_budget = spec.budget_bytes * spec.tenants;
    let svc = StashService::new(global_budget, None);
    // single-worker facades: the scenario's operation order — and with it
    // every counter in the artifact — is a pure function of the spec
    let cfg = StashConfig {
        codec: spec.codec,
        threads: 1,
        queue_depth: 2,
        chunk_values: 4096,
        budget_bytes: 0, // the lease budget governs placement
    };

    let mut sessions = Vec::with_capacity(spec.tenants);
    for t in 0..spec.tenants {
        let label = format!("t{t}");
        let lease = svc.lease(&label, spec.budget_bytes, 0)?;
        let stash = lease.open(cfg);
        let tseed = spec.seed ^ (t as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let streams = session_streams(spec, &net, &sched, tseed);
        sessions.push((lease, stash, streams));
    }

    let t0 = std::time::Instant::now();
    let mut bit_exact = true;
    for _ in 0..spec.steps {
        for (lease, stash, streams) in &sessions {
            put_all(stash, streams);
            if crate::obs::enabled() {
                // per-tenant resident-bytes counter track (Chrome trace)
                crate::obs::timeseries::record_owned(
                    format!("serve_bytes.{}", lease.label()),
                    lease.stats().in_use_bytes as f64,
                );
            }
        }
        for (_, stash, streams) in &sessions {
            if !take_verify(stash, streams) {
                bit_exact = false;
            }
            stash.mark_epoch();
        }
    }
    let wall_us = t0.elapsed().as_micros() as u64;

    let mut rows = Vec::with_capacity(sessions.len());
    let mut observations = Vec::with_capacity(sessions.len());
    let (mut written, mut read) = (0.0f64, 0.0f64);
    let (mut evictions, mut faults) = (0u64, 0u64);
    for (lease, stash, _) in &sessions {
        if stash.failures() > 0 {
            return Err(anyhow!(
                "serve session {}: {} worker jobs failed",
                lease.label(),
                stash.failures()
            ));
        }
        let snap = stash.ledger();
        let stats = lease.stats();
        written += snap.written_bits;
        read += snap.read_bits;
        evictions += stats.evictions;
        faults += stats.faults;
        rows.push(ServeTenantRow {
            label: lease.label().to_string(),
            written_bits: snap.written_bits,
            read_bits: snap.read_bits,
            spill_written_bits: snap.spill_written_bits,
            spill_read_bits: snap.spill_read_bits,
            evictions: stats.evictions,
            faults: stats.faults,
            epochs: stash.epoch_traffic().len(),
        });
        let (dram, fault) = stash.restore_latency();
        observations.push(ServeObservation {
            scale_tenants: spec.tenants,
            tenant: lease.label().to_string(),
            dram,
            fault,
            restored_bytes: snap.read_bits / 8.0,
            wall_us,
        });
    }
    let dram_hw = svc.arena().high_water_bytes();
    let spill_hw = svc.arena().spill_high_water_bytes();
    if evictions == 0 && dram_hw + spill_hw > global_budget {
        return Err(anyhow!(
            "per-tenant budget {} B is below the working set but the spill \
             tier never engaged",
            spec.budget_bytes
        ));
    }

    let (solo_faults, contended_faults) = fairness_probe(spec, &net, &sched, cfg)?;
    for o in &observations {
        push_observation(o.clone());
    }
    Ok(ServeMeasurement {
        spec: spec.clone(),
        codec_name: cfg.codec.label(),
        global_budget_bytes: global_budget,
        tenants: rows,
        total_written_bits: written,
        total_read_bits: read,
        total_evictions: evictions,
        total_faults: faults,
        dram_high_water_bytes: dram_hw,
        spill_high_water_bytes: spill_hw,
        solo_faults,
        contended_faults,
        fair_eviction: contended_faults <= solo_faults + FAIR_FAULT_SLACK,
        restore_bit_exact: bit_exact,
        observations,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::Container;
    use crate::stash::{CodecKind, CHUNK_BYTES};

    fn spec(tenants: usize, budget_chunks: usize, sample: usize) -> ServeSpec {
        ServeSpec {
            model: "resnet18".into(),
            policy: "qm".into(),
            codec: CodecKind::Raw,
            container: Container::Fp32,
            tenants,
            steps: 2,
            budget_bytes: budget_chunks * CHUNK_BYTES,
            sample,
            seed: 0x5EED,
        }
    }

    #[test]
    fn serve_measurement_is_deterministic_and_fair() {
        // raw FP32 streams at sample 1024 put each session's working set
        // well past a 2-chunk lease, so every tenant self-spills
        let sp = spec(2, 2, 1024);
        let a = run_serve_measurement(&sp).unwrap();
        let b = run_serve_measurement(&sp).unwrap();
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
        assert!(a.restore_bit_exact);
        assert!(a.fair_eviction, "contended {} vs solo {}", a.contended_faults, a.solo_faults);
        assert!(a.total_evictions > 0, "undersized leases must spill");
        assert!(a.total_faults > 0, "restores must fault spilled runs back");
        // per-tenant rows partition the totals
        let sum_w: f64 = a.tenants.iter().map(|t| t.written_bits).sum();
        let sum_f: u64 = a.tenants.iter().map(|t| t.faults).sum();
        assert!((sum_w - a.total_written_bits).abs() < 1e-6);
        assert_eq!(sum_f, a.total_faults);
        assert!(a.tenants.iter().all(|t| t.epochs == sp.steps));
    }

    #[test]
    fn serve_observations_cover_every_tenant() {
        let m = run_serve_measurement(&spec(3, 2, 1024)).unwrap();
        assert_eq!(m.observations.len(), 3);
        for o in &m.observations {
            assert_eq!(o.scale_tenants, 3);
            assert!(o.restored_bytes > 0.0);
            // every session restored something in at least one tier
            assert!(o.dram.count + o.fault.count > 0, "{}", o.tenant);
        }
        // labels are the lease labels, in tenant order
        let labels: Vec<&str> = m.observations.iter().map(|o| o.tenant.as_str()).collect();
        assert_eq!(labels, ["t0", "t1", "t2"]);
    }
}
