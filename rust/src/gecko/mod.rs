//! Gecko: lossless, value-adaptive exponent compression (§IV-C).
//!
//! Exponents of trained tensors cluster tightly around the bias (Fig. 9),
//! so Gecko stores each exponent with only as many bits as its magnitude
//! needs, amortizing the width metadata over groups:
//!
//! * **Delta mode** (the evaluated configuration): values stream in groups
//!   of 64 viewed as an 8×8 matrix.  Each *column* shares a base exponent —
//!   the column's row-0 exponent, stored raw (8 b).  Rows 1..7 hold deltas
//!   from the column base in sign/magnitude; each *row* carries a 3-bit
//!   width field sized by a leading-one detector across its 8 magnitudes.
//! * **Fixed-bias mode**: deltas against a programmable bias (127 works
//!   best for the studied models), groups of 8, one 3-bit width per group.
//!
//! Width codes 0..=6 mean "w magnitude bits + 1 sign bit per delta"; code 7
//! is a raw escape (8 b exponent per value, no sign bit) that keeps the
//! scheme lossless across the whole exponent range — deltas can span ±255.
//!
//! The width fields live in a *separate* metadata stream, exactly like the
//! hardware's second sequential DRAM write stream (§V-A).  Encoded sizes
//! match `python/compile/kernels/gecko_stats.py` bit-for-bit (golden test).

pub mod bitstream;

pub use bitstream::{BitReader, BitWriter, Kernel, SegReader};

use crate::formats::mag_width;

/// Values per delta-mode group (8×8).
pub const GROUP: usize = 64;
/// Rows (and lanes) per group.
pub const ROWS: usize = 8;
/// Width metadata bits per row/group.
pub const WIDTH_FIELD_BITS: u32 = 3;
/// Width code signalling the raw 8-bit escape.
pub const RAW_ESCAPE: u32 = 7;

/// Encoded exponent stream: payload + width metadata, as two sequential
/// (DRAM-friendly) streams.
#[derive(Debug, Clone)]
pub struct Encoded {
    pub payload: Vec<u64>,
    pub payload_bits: usize,
    pub metadata: Vec<u64>,
    pub metadata_bits: usize,
    /// Number of exponents encoded (excluding padding).
    pub count: usize,
}

impl Encoded {
    /// Total encoded bits `M + C` (§IV-C's compression-ratio numerator).
    pub fn total_bits(&self) -> usize {
        self.payload_bits + self.metadata_bits
    }

    /// `(M + C) / O` against raw 8-bit exponents.
    pub fn compression_ratio(&self) -> f64 {
        self.total_bits() as f64 / (8.0 * self.count as f64)
    }
}

/// Gecko operating mode.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// 8×8 groups, per-column base from row 0, per-row widths.
    Delta,
    /// Groups of `group`, deltas against a fixed `bias`.
    FixedBias { bias: u8, group: usize },
}

impl Default for Mode {
    fn default() -> Self {
        Mode::Delta
    }
}

/// Extract biased exponents from an f32 slice.
pub fn exponents(vals: &[f32]) -> Vec<u8> {
    vals.iter()
        .map(|v| ((v.to_bits() >> 23) & 0xFF) as u8)
        .collect()
}

/// Encode a stream of biased exponents.  Trailing partial groups are padded
/// by repeating the last exponent (zero deltas), as the hardware pads the
/// final burst; padding costs are charged to the stream.
///
/// Runs the process-wide [`Kernel::active`] implementation; both kernels
/// emit bit-identical streams (see [`encode_kernel`]).
pub fn encode(exps: &[u8], mode: Mode) -> Encoded {
    encode_kernel(exps, mode, Kernel::active())
}

/// [`encode`] with an explicit kernel — [`Kernel::Word`] is the
/// word-parallel production path, [`Kernel::Scalar`] the per-value
/// reference; differential tests drive both and assert identical streams.
pub fn encode_kernel(exps: &[u8], mode: Mode, kernel: Kernel) -> Encoded {
    match (mode, kernel) {
        (Mode::Delta, Kernel::Word) => encode_delta_word(exps),
        (Mode::Delta, Kernel::Scalar) => encode_delta(exps),
        (Mode::FixedBias { bias, group }, Kernel::Word) => encode_fixed_word(exps, bias, group),
        (Mode::FixedBias { bias, group }, Kernel::Scalar) => encode_fixed(exps, bias, group),
    }
}

/// Decode an [`Encoded`] stream back to exponent bytes (exactly `count`).
pub fn decode(enc: &Encoded, mode: Mode) -> Vec<u8> {
    let mut payload = SegReader::single(&enc.payload, enc.payload_bits);
    let mut metadata = SegReader::single(&enc.metadata, enc.metadata_bits);
    decode_readers(&mut payload, &mut metadata, enc.count, mode)
}

/// Decode `count` exponents from already-positioned payload/metadata
/// readers — the zero-copy restore path (the readers may span arena chunk
/// segments; [`decode`] is this over single-segment readers).
pub fn decode_readers(
    payload: &mut SegReader,
    metadata: &mut SegReader,
    count: usize,
    mode: Mode,
) -> Vec<u8> {
    decode_readers_kernel(payload, metadata, count, mode, Kernel::active())
}

/// [`decode_readers`] with an explicit kernel (see [`encode_kernel`]).
pub fn decode_readers_kernel(
    payload: &mut SegReader,
    metadata: &mut SegReader,
    count: usize,
    mode: Mode,
    kernel: Kernel,
) -> Vec<u8> {
    match (mode, kernel) {
        (Mode::Delta, Kernel::Word) => decode_delta_word(payload, metadata, count),
        (Mode::Delta, Kernel::Scalar) => decode_delta(payload, metadata, count),
        (Mode::FixedBias { bias, group }, Kernel::Word) => {
            decode_fixed_word(payload, metadata, count, bias, group)
        }
        (Mode::FixedBias { bias, group }, Kernel::Scalar) => {
            decode_fixed(payload, metadata, count, bias, group)
        }
    }
}

fn padded(exps: &[u8], group: usize) -> Vec<u8> {
    let mut v = exps.to_vec();
    if v.is_empty() {
        return v;
    }
    let pad = (group - v.len() % group) % group;
    let last = *v.last().unwrap();
    v.extend(std::iter::repeat(last).take(pad));
    v
}

fn encode_delta(exps: &[u8]) -> Encoded {
    let v = padded(exps, GROUP);
    let mut payload = BitWriter::with_capacity(v.len() * 6);
    let mut metadata = BitWriter::with_capacity(v.len() / ROWS * 3);

    for g in v.chunks_exact(GROUP) {
        // Row 0: the 8 column bases, raw.
        let bases = &g[0..ROWS];
        for &b in bases {
            payload.push(b as u64, 8);
        }
        // Rows 1..7: sign/magnitude deltas, shared per-row width.
        for r in 1..ROWS {
            let row = &g[r * ROWS..(r + 1) * ROWS];
            let w = row
                .iter()
                .zip(bases)
                .map(|(&e, &b)| mag_width((e as i32 - b as i32).unsigned_abs()))
                .max()
                .unwrap();
            if w <= 6 {
                metadata.push(w as u64, WIDTH_FIELD_BITS);
                for (&e, &b) in row.iter().zip(bases) {
                    let d = e as i32 - b as i32;
                    // fused [sign | magnitude] single push (perf §Perf)
                    payload.push((((d < 0) as u64) << w) | d.unsigned_abs() as u64, w + 1);
                }
            } else {
                metadata.push(RAW_ESCAPE as u64, WIDTH_FIELD_BITS);
                for &e in row {
                    payload.push(e as u64, 8);
                }
            }
        }
    }

    let (pw, pb) = payload.into_words();
    let (mw, mb) = metadata.into_words();
    Encoded {
        payload: pw,
        payload_bits: pb,
        metadata: mw,
        metadata_bits: mb,
        count: exps.len(),
    }
}

fn decode_delta(payload: &mut SegReader, metadata: &mut SegReader, count: usize) -> Vec<u8> {
    let padded_len = count.div_ceil(GROUP) * GROUP;
    let mut out = Vec::with_capacity(padded_len);

    let groups = padded_len / GROUP;
    for _ in 0..groups {
        let mut bases = [0u8; ROWS];
        for b in bases.iter_mut() {
            *b = payload.read(8) as u8;
        }
        out.extend_from_slice(&bases);
        for _ in 1..ROWS {
            let w = metadata.read(WIDTH_FIELD_BITS) as u32;
            if w == RAW_ESCAPE {
                for _ in 0..ROWS {
                    out.push(payload.read(8) as u8);
                }
            } else {
                // fused [sign | magnitude] single read (perf §Perf)
                for c in 0..ROWS {
                    let field = payload.read(w + 1);
                    let mag = (field & ((1 << w) - 1)) as i32;
                    let d = if field >> w == 1 { -mag } else { mag };
                    out.push((bases[c] as i32 + d) as u8);
                }
            }
        }
    }
    out.truncate(count);
    out
}

fn encode_fixed(exps: &[u8], bias: u8, group: usize) -> Encoded {
    assert!(group > 0);
    let v = padded(exps, group);
    let mut payload = BitWriter::with_capacity(v.len() * 6);
    let mut metadata = BitWriter::with_capacity(v.len() / group * 3);

    for g in v.chunks_exact(group) {
        let w = g
            .iter()
            .map(|&e| mag_width((e as i32 - bias as i32).unsigned_abs()))
            .max()
            .unwrap();
        if w <= 6 {
            metadata.push(w as u64, WIDTH_FIELD_BITS);
            for &e in g {
                let d = e as i32 - bias as i32;
                payload.push((((d < 0) as u64) << w) | d.unsigned_abs() as u64, w + 1);
            }
        } else {
            metadata.push(RAW_ESCAPE as u64, WIDTH_FIELD_BITS);
            for &e in g {
                payload.push(e as u64, 8);
            }
        }
    }

    let (pw, pb) = payload.into_words();
    let (mw, mb) = metadata.into_words();
    Encoded {
        payload: pw,
        payload_bits: pb,
        metadata: mw,
        metadata_bits: mb,
        count: exps.len(),
    }
}

fn decode_fixed(
    payload: &mut SegReader,
    metadata: &mut SegReader,
    count: usize,
    bias: u8,
    group: usize,
) -> Vec<u8> {
    let padded_len = count.div_ceil(group) * group;
    let mut out = Vec::with_capacity(padded_len);
    for _ in 0..padded_len / group {
        let w = metadata.read(WIDTH_FIELD_BITS) as u32;
        for _ in 0..group {
            if w == RAW_ESCAPE {
                out.push(payload.read(8) as u8);
            } else {
                let field = payload.read(w + 1);
                let mag = (field & ((1 << w) - 1)) as i32;
                let d = if field >> w == 1 { -mag } else { mag };
                out.push((bias as i32 + d) as u8);
            }
        }
    }
    out.truncate(count);
    out
}

// ---------------------------------------------------------------------------
// Word-parallel kernels (Kernel::Word) — bit-identical to the scalar
// reference above, but one whole row is spliced per BitWriter call.
// ---------------------------------------------------------------------------

/// Pack one 8×8 delta-mode group with row-granular word splices.
///
/// Bit-plane view of one row (width code `w <= 6`, field `f = w + 1`):
///
/// ```text
///   lane:        0         1        ...       7
///   field:   [s|mag]   [s|mag]      ...   [s|mag]     f bits each
///   row word = l0 << 7f | l1 << 6f | ... | l7          (8f <= 56 bits)
/// ```
///
/// The row word is assembled lane-major with shifts/ORs and spliced into
/// the payload in ONE `push_word` instead of eight scalar pushes.  The
/// raw-escape row is the degenerate `f = 8` case, where the row word is
/// just the eight exponent bytes big-endian.  The shared row width comes
/// from one leading-one detector over the OR of the eight magnitudes
/// (`mag_width(m0 | .. | m7) == max(mag_width(m_i))`, monotone in the OR).
fn encode_delta_group(g: &[u8; GROUP], payload: &mut BitWriter, metadata: &mut BitWriter) {
    // Row 0: the 8 column bases, raw — already a big-endian byte word.
    let bases: &[u8; ROWS] = g[..ROWS].try_into().expect("8 bases");
    payload.push_word(u64::from_be_bytes(*bases), 64);
    let mut meta_word = 0u64;
    for r in 1..ROWS {
        let row: &[u8; ROWS] = g[r * ROWS..(r + 1) * ROWS].try_into().expect("8-lane row");
        let mut mags = [0u32; ROWS];
        let mut neg = [false; ROWS];
        let mut or = 0u32;
        for c in 0..ROWS {
            let d = row[c] as i32 - bases[c] as i32;
            neg[c] = d < 0;
            mags[c] = d.unsigned_abs();
            or |= mags[c];
        }
        let w = mag_width(or);
        if w <= 6 {
            let f = w + 1;
            let mut roww = 0u64;
            for c in 0..ROWS {
                roww = (roww << f) | ((neg[c] as u64) << w) | mags[c] as u64;
            }
            payload.push_word(roww, 8 * f);
            meta_word = (meta_word << WIDTH_FIELD_BITS) | w as u64;
        } else {
            payload.push_word(u64::from_be_bytes(*row), 64);
            meta_word = (meta_word << WIDTH_FIELD_BITS) | RAW_ESCAPE as u64;
        }
    }
    // 7 row-width codes, 3 bits each, in one 21-bit splice (MSB-first, so
    // row 1's code lands first — same stream as seven scalar pushes).
    metadata.push_word(meta_word, (ROWS as u32 - 1) * WIDTH_FIELD_BITS);
}

fn encode_delta_word(exps: &[u8]) -> Encoded {
    let mut payload = BitWriter::with_capacity(exps.len() * 6);
    let mut metadata = BitWriter::with_capacity(exps.len() / ROWS * 3);
    let mut it = exps.chunks_exact(GROUP);
    for g in it.by_ref() {
        encode_delta_group(g.try_into().expect("GROUP-sized chunk"), &mut payload, &mut metadata);
    }
    let rem = it.remainder();
    if !rem.is_empty() {
        // Pad the final group by repeating the last exponent — same stream
        // as the scalar `padded` path, without copying the whole input.
        let mut tail = [rem[rem.len() - 1]; GROUP];
        tail[..rem.len()].copy_from_slice(rem);
        encode_delta_group(&tail, &mut payload, &mut metadata);
    }
    let (pw, pb) = payload.into_words();
    let (mw, mb) = metadata.into_words();
    Encoded {
        payload: pw,
        payload_bits: pb,
        metadata: mw,
        metadata_bits: mb,
        count: exps.len(),
    }
}

fn decode_delta_word(payload: &mut SegReader, metadata: &mut SegReader, count: usize) -> Vec<u8> {
    let padded_len = count.div_ceil(GROUP) * GROUP;
    let mut out = Vec::with_capacity(padded_len);
    for _ in 0..padded_len / GROUP {
        let bases = payload.read_word(64).to_be_bytes();
        out.extend_from_slice(&bases);
        // All 7 row-width codes in one 21-bit read; codes peel MSB-first.
        let codes = metadata.read_word((ROWS as u32 - 1) * WIDTH_FIELD_BITS);
        for r in 1..ROWS {
            let w = ((codes >> ((ROWS - 1 - r) as u32 * WIDTH_FIELD_BITS)) & 0x7) as u32;
            if w == RAW_ESCAPE {
                out.extend_from_slice(&payload.read_word(64).to_be_bytes());
            } else {
                let f = w + 1;
                let roww = payload.read_word(8 * f);
                // lane c sits at bit offset (7 - c)·f — peel MSB-first
                for c in 0..ROWS {
                    let field = (roww >> ((ROWS - 1 - c) as u32 * f)) & ((1u64 << f) - 1);
                    let mag = (field & ((1 << w) - 1)) as i32;
                    let d = if field >> w == 1 { -mag } else { mag };
                    out.push((bases[c] as i32 + d) as u8);
                }
            }
        }
    }
    out.truncate(count);
    out
}

/// Fixed-bias groups have runtime-sized groups (typically 8), so fields
/// route through the general [`BitWriter::pack_lanes`] staging path
/// instead of a single-word splice.
fn encode_fixed_group(
    g: &[u8],
    bias: u8,
    payload: &mut BitWriter,
    metadata: &mut BitWriter,
    fields: &mut Vec<u64>,
) {
    let b = bias as i32;
    let mut or = 0u32;
    for &e in g {
        or |= (e as i32 - b).unsigned_abs();
    }
    let w = mag_width(or);
    fields.clear();
    if w <= 6 {
        metadata.push(w as u64, WIDTH_FIELD_BITS);
        fields.extend(g.iter().map(|&e| {
            let d = e as i32 - b;
            (((d < 0) as u64) << w) | d.unsigned_abs() as u64
        }));
        payload.pack_lanes(fields, w + 1);
    } else {
        metadata.push(RAW_ESCAPE as u64, WIDTH_FIELD_BITS);
        fields.extend(g.iter().map(|&e| e as u64));
        payload.pack_lanes(fields, 8);
    }
}

fn encode_fixed_word(exps: &[u8], bias: u8, group: usize) -> Encoded {
    assert!(group > 0);
    let mut payload = BitWriter::with_capacity(exps.len() * 6);
    let mut metadata = BitWriter::with_capacity(exps.len() / group * 3);
    let mut fields: Vec<u64> = Vec::with_capacity(group);
    let mut it = exps.chunks_exact(group);
    for g in it.by_ref() {
        encode_fixed_group(g, bias, &mut payload, &mut metadata, &mut fields);
    }
    let rem = it.remainder();
    if !rem.is_empty() {
        let mut tail = vec![rem[rem.len() - 1]; group];
        tail[..rem.len()].copy_from_slice(rem);
        encode_fixed_group(&tail, bias, &mut payload, &mut metadata, &mut fields);
    }
    let (pw, pb) = payload.into_words();
    let (mw, mb) = metadata.into_words();
    Encoded {
        payload: pw,
        payload_bits: pb,
        metadata: mw,
        metadata_bits: mb,
        count: exps.len(),
    }
}

fn decode_fixed_word(
    payload: &mut SegReader,
    metadata: &mut SegReader,
    count: usize,
    bias: u8,
    group: usize,
) -> Vec<u8> {
    let padded_len = count.div_ceil(group) * group;
    let mut out = Vec::with_capacity(padded_len);
    let mut fields = vec![0u64; group];
    let b = bias as i32;
    for _ in 0..padded_len / group {
        let w = metadata.read(WIDTH_FIELD_BITS) as u32;
        if w == RAW_ESCAPE {
            payload.unpack_lanes(8, &mut fields);
            out.extend(fields.iter().map(|&f| f as u8));
        } else {
            payload.unpack_lanes(w + 1, &mut fields);
            out.extend(fields.iter().map(|&field| {
                let mag = (field & ((1 << w) - 1)) as i32;
                let d = if field >> w == 1 { -mag } else { mag };
                (b + d) as u8
            }));
        }
    }
    out.truncate(count);
    out
}

/// Encoded size in bits without materializing the bitstream — the fast
/// accounting path used by the footprint models (identical arithmetic to
/// the Pallas `gecko_stats` kernel).
pub fn encoded_bits(exps: &[u8], mode: Mode) -> usize {
    match mode {
        Mode::Delta => {
            let v = padded(exps, GROUP);
            let mut bits = 0usize;
            for g in v.chunks_exact(GROUP) {
                bits += ROWS * 8;
                let bases = &g[0..ROWS];
                for r in 1..ROWS {
                    let row = &g[r * ROWS..(r + 1) * ROWS];
                    let w = row
                        .iter()
                        .zip(bases)
                        .map(|(&e, &b)| mag_width((e as i32 - b as i32).unsigned_abs()))
                        .max()
                        .unwrap();
                    bits += WIDTH_FIELD_BITS as usize
                        + if w <= 6 { ROWS * (w as usize + 1) } else { ROWS * 8 };
                }
            }
            bits
        }
        Mode::FixedBias { bias, group } => {
            let v = padded(exps, group);
            let mut bits = 0usize;
            for g in v.chunks_exact(group) {
                let w = g
                    .iter()
                    .map(|&e| mag_width((e as i32 - bias as i32).unsigned_abs()))
                    .max()
                    .unwrap();
                bits += WIDTH_FIELD_BITS as usize
                    + if w <= 6 {
                        group * (w as usize + 1)
                    } else {
                        group * 8
                    };
            }
            bits
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn exps_from(vals: &[f32]) -> Vec<u8> {
        exponents(vals)
    }

    fn pseudo_vals(n: usize, seed: u64, scale: f32) -> Vec<f32> {
        let mut s = seed.wrapping_add(0x9E3779B97F4A7C15);
        (0..n)
            .map(|_| {
                s ^= s >> 12;
                s ^= s << 25;
                s ^= s >> 27;
                let u = (s.wrapping_mul(0x2545F4914F6CDD1D) >> 40) as f32 / (1u64 << 24) as f32;
                (u - 0.5) * 2.0 * scale
            })
            .collect()
    }

    #[test]
    fn delta_roundtrip_gaussianish() {
        let vals = pseudo_vals(1000, 1, 10.0);
        let e = exps_from(&vals);
        let enc = encode(&e, Mode::Delta);
        assert_eq!(decode(&enc, Mode::Delta), e);
    }

    #[test]
    fn delta_roundtrip_extreme_exponents() {
        // forces raw escapes: mix tiny and huge magnitudes
        let mut vals = pseudo_vals(512, 2, 1e30);
        vals.extend(pseudo_vals(512, 3, 1e-30));
        let e = exps_from(&vals);
        let enc = encode(&e, Mode::Delta);
        assert_eq!(decode(&enc, Mode::Delta), e);
    }

    #[test]
    fn delta_roundtrip_with_zeros_and_partial_group() {
        let mut vals = pseudo_vals(137, 4, 2.0);
        vals[5] = 0.0;
        vals[77] = 0.0;
        let e = exps_from(&vals);
        let enc = encode(&e, Mode::Delta);
        assert_eq!(decode(&enc, Mode::Delta), e);
        assert_eq!(enc.count, 137);
    }

    #[test]
    fn fixed_roundtrip() {
        let vals = pseudo_vals(999, 5, 4.0);
        let e = exps_from(&vals);
        let mode = Mode::FixedBias { bias: 127, group: 8 };
        let enc = encode(&e, mode);
        assert_eq!(decode(&enc, mode), e);
    }

    #[test]
    fn constant_stream_minimal_size() {
        let e = vec![127u8; 64];
        let enc = encode(&e, Mode::Delta);
        // 64 base bits + 7 rows * (8 sign bits); metadata 7 * 3
        assert_eq!(enc.payload_bits, 64 + 7 * 8);
        assert_eq!(enc.metadata_bits, 7 * 3);
    }

    #[test]
    fn encoded_bits_matches_real_encoder() {
        for seed in 0..5u64 {
            let vals = pseudo_vals(473, seed, 7.0);
            let e = exps_from(&vals);
            for mode in [Mode::Delta, Mode::FixedBias { bias: 127, group: 8 }] {
                let enc = encode(&e, mode);
                assert_eq!(encoded_bits(&e, mode), enc.total_bits());
            }
        }
    }

    #[test]
    fn trained_like_values_compress_well() {
        // Unit-scale values: exponents hug 127 => well under 8 b/exponent.
        let vals = pseudo_vals(8192, 9, 1.0);
        let enc = encode(&exps_from(&vals), Mode::Delta);
        assert!(enc.compression_ratio() < 1.0, "{}", enc.compression_ratio());
    }

    #[test]
    fn empty_stream() {
        let enc = encode(&[], Mode::Delta);
        assert_eq!(enc.total_bits(), 0);
        assert!(decode(&enc, Mode::Delta).is_empty());
    }

    /// Concatenate chunk encodings (chunks must cover whole groups except
    /// the last).  The production chunk path is
    /// `stash::EncodedStreams::concat` over the same
    /// `BitWriter::append_words` primitive; this helper pins the invariant
    /// at the `Encoded` level.
    fn concat(chunks: &[Encoded]) -> Encoded {
        let mut payload = BitWriter::new();
        let mut metadata = BitWriter::new();
        let mut count = 0usize;
        for c in chunks {
            payload.append_words(&c.payload, c.payload_bits);
            metadata.append_words(&c.metadata, c.metadata_bits);
            count += c.count;
        }
        let (pw, pb) = payload.into_words();
        let (mw, mb) = metadata.into_words();
        Encoded {
            payload: pw,
            payload_bits: pb,
            metadata: mw,
            metadata_bits: mb,
            count,
        }
    }

    #[test]
    fn chunked_encode_concat_is_one_shot() {
        // Regression (chunk-boundary correctness): encoding a tensor in N
        // group-aligned chunks and concatenating must be bit-identical to
        // one-shot encoding — payload words, metadata words, and lengths.
        let vals = pseudo_vals(64 * 5 + 37, 21, 6.0);
        let e = exps_from(&vals);
        let one = encode(&e, Mode::Delta);
        for chunk in [GROUP, 2 * GROUP, 3 * GROUP] {
            let parts: Vec<Encoded> =
                e.chunks(chunk).map(|c| encode(c, Mode::Delta)).collect();
            let cat = concat(&parts);
            assert_eq!(cat.count, one.count, "chunk {chunk}");
            assert_eq!(cat.payload_bits, one.payload_bits, "chunk {chunk}");
            assert_eq!(cat.metadata_bits, one.metadata_bits, "chunk {chunk}");
            assert_eq!(cat.payload, one.payload, "chunk {chunk}");
            assert_eq!(cat.metadata, one.metadata, "chunk {chunk}");
            assert_eq!(decode(&cat, Mode::Delta), e);
        }
    }

    /// Word and scalar kernels must emit bit-identical streams — word for
    /// word, length for length — so content hashes and cache fingerprints
    /// are kernel-independent.  Covers tight clusters (narrow widths),
    /// mixed extreme exponents (raw escapes), zeros, and ragged tails.
    #[test]
    fn word_kernel_streams_bit_identical_to_scalar() {
        let mut streams: Vec<Vec<u8>> = Vec::new();
        for (len, seed, scale) in [(64, 1, 1.0), (1000, 2, 10.0), (137, 3, 2.0), (7, 4, 0.5)] {
            streams.push(exps_from(&pseudo_vals(len, seed, scale)));
        }
        let mut extreme = pseudo_vals(100, 5, 1e30);
        extreme.extend(pseudo_vals(100, 6, 1e-30));
        extreme[17] = 0.0;
        streams.push(exps_from(&extreme));
        streams.push(vec![127u8; 64]);
        streams.push(Vec::new());

        for e in &streams {
            for mode in [
                Mode::Delta,
                Mode::FixedBias { bias: 127, group: 8 },
                Mode::FixedBias { bias: 100, group: 5 },
            ] {
                let w = encode_kernel(e, mode, Kernel::Word);
                let s = encode_kernel(e, mode, Kernel::Scalar);
                assert_eq!(w.payload, s.payload, "{mode:?} len {}", e.len());
                assert_eq!(w.payload_bits, s.payload_bits, "{mode:?}");
                assert_eq!(w.metadata, s.metadata, "{mode:?}");
                assert_eq!(w.metadata_bits, s.metadata_bits, "{mode:?}");
                // and both kernels decode either stream back to the input
                for kernel in [Kernel::Word, Kernel::Scalar] {
                    let mut p = SegReader::single(&w.payload, w.payload_bits);
                    let mut m = SegReader::single(&w.metadata, w.metadata_bits);
                    let got = decode_readers_kernel(&mut p, &mut m, w.count, mode, kernel);
                    assert_eq!(&got, e, "{mode:?} decode {kernel:?}");
                }
            }
        }
    }

    #[test]
    fn word_kernel_decodes_across_segment_splits() {
        // Restore reads payload/metadata from arena chunk segments; the
        // word kernel's bulk reads must stitch across word boundaries.
        let e = exps_from(&pseudo_vals(64 * 4 + 19, 31, 8.0));
        let enc = encode_kernel(&e, Mode::Delta, Kernel::Scalar);
        for cut in [1, 2, 3] {
            let k = enc.payload.len() * cut / 4;
            let (a, b) = enc.payload.split_at(k);
            let mut p = SegReader::new(&[a, b], enc.payload_bits);
            let mut m = SegReader::single(&enc.metadata, enc.metadata_bits);
            let got = decode_readers_kernel(&mut p, &mut m, enc.count, Mode::Delta, Kernel::Word);
            assert_eq!(got, e, "cut {cut}");
        }
    }

    #[test]
    fn chunked_encode_concat_fixed_bias() {
        let vals = pseudo_vals(500, 23, 2.0);
        let e = exps_from(&vals);
        let mode = Mode::FixedBias { bias: 127, group: 8 };
        let one = encode(&e, mode);
        let parts: Vec<Encoded> = e.chunks(120).map(|c| encode(c, mode)).collect();
        let cat = concat(&parts);
        assert_eq!(cat.payload, one.payload);
        assert_eq!(cat.metadata, one.metadata);
        assert_eq!(cat.payload_bits, one.payload_bits);
        assert_eq!(decode(&cat, mode), e);
    }
}
