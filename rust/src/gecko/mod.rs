//! Gecko: lossless, value-adaptive exponent compression (§IV-C).
//!
//! Exponents of trained tensors cluster tightly around the bias (Fig. 9),
//! so Gecko stores each exponent with only as many bits as its magnitude
//! needs, amortizing the width metadata over groups:
//!
//! * **Delta mode** (the evaluated configuration): values stream in groups
//!   of 64 viewed as an 8×8 matrix.  Each *column* shares a base exponent —
//!   the column's row-0 exponent, stored raw (8 b).  Rows 1..7 hold deltas
//!   from the column base in sign/magnitude; each *row* carries a 3-bit
//!   width field sized by a leading-one detector across its 8 magnitudes.
//! * **Fixed-bias mode**: deltas against a programmable bias (127 works
//!   best for the studied models), groups of 8, one 3-bit width per group.
//!
//! Width codes 0..=6 mean "w magnitude bits + 1 sign bit per delta"; code 7
//! is a raw escape (8 b exponent per value, no sign bit) that keeps the
//! scheme lossless across the whole exponent range — deltas can span ±255.
//!
//! The width fields live in a *separate* metadata stream, exactly like the
//! hardware's second sequential DRAM write stream (§V-A).  Encoded sizes
//! match `python/compile/kernels/gecko_stats.py` bit-for-bit (golden test).

pub mod bitstream;

pub use bitstream::{BitReader, BitWriter, SegReader};

use crate::formats::mag_width;

/// Values per delta-mode group (8×8).
pub const GROUP: usize = 64;
/// Rows (and lanes) per group.
pub const ROWS: usize = 8;
/// Width metadata bits per row/group.
pub const WIDTH_FIELD_BITS: u32 = 3;
/// Width code signalling the raw 8-bit escape.
pub const RAW_ESCAPE: u32 = 7;

/// Encoded exponent stream: payload + width metadata, as two sequential
/// (DRAM-friendly) streams.
#[derive(Debug, Clone)]
pub struct Encoded {
    pub payload: Vec<u64>,
    pub payload_bits: usize,
    pub metadata: Vec<u64>,
    pub metadata_bits: usize,
    /// Number of exponents encoded (excluding padding).
    pub count: usize,
}

impl Encoded {
    /// Total encoded bits `M + C` (§IV-C's compression-ratio numerator).
    pub fn total_bits(&self) -> usize {
        self.payload_bits + self.metadata_bits
    }

    /// `(M + C) / O` against raw 8-bit exponents.
    pub fn compression_ratio(&self) -> f64 {
        self.total_bits() as f64 / (8.0 * self.count as f64)
    }
}

/// Gecko operating mode.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// 8×8 groups, per-column base from row 0, per-row widths.
    Delta,
    /// Groups of `group`, deltas against a fixed `bias`.
    FixedBias { bias: u8, group: usize },
}

impl Default for Mode {
    fn default() -> Self {
        Mode::Delta
    }
}

/// Extract biased exponents from an f32 slice.
pub fn exponents(vals: &[f32]) -> Vec<u8> {
    vals.iter()
        .map(|v| ((v.to_bits() >> 23) & 0xFF) as u8)
        .collect()
}

/// Encode a stream of biased exponents.  Trailing partial groups are padded
/// by repeating the last exponent (zero deltas), as the hardware pads the
/// final burst; padding costs are charged to the stream.
pub fn encode(exps: &[u8], mode: Mode) -> Encoded {
    match mode {
        Mode::Delta => encode_delta(exps),
        Mode::FixedBias { bias, group } => encode_fixed(exps, bias, group),
    }
}

/// Decode an [`Encoded`] stream back to exponent bytes (exactly `count`).
pub fn decode(enc: &Encoded, mode: Mode) -> Vec<u8> {
    let mut payload = SegReader::single(&enc.payload, enc.payload_bits);
    let mut metadata = SegReader::single(&enc.metadata, enc.metadata_bits);
    decode_readers(&mut payload, &mut metadata, enc.count, mode)
}

/// Decode `count` exponents from already-positioned payload/metadata
/// readers — the zero-copy restore path (the readers may span arena chunk
/// segments; [`decode`] is this over single-segment readers).
pub fn decode_readers(
    payload: &mut SegReader,
    metadata: &mut SegReader,
    count: usize,
    mode: Mode,
) -> Vec<u8> {
    match mode {
        Mode::Delta => decode_delta(payload, metadata, count),
        Mode::FixedBias { bias, group } => decode_fixed(payload, metadata, count, bias, group),
    }
}

fn padded(exps: &[u8], group: usize) -> Vec<u8> {
    let mut v = exps.to_vec();
    if v.is_empty() {
        return v;
    }
    let pad = (group - v.len() % group) % group;
    let last = *v.last().unwrap();
    v.extend(std::iter::repeat(last).take(pad));
    v
}

fn encode_delta(exps: &[u8]) -> Encoded {
    let v = padded(exps, GROUP);
    let mut payload = BitWriter::with_capacity(v.len() * 6);
    let mut metadata = BitWriter::with_capacity(v.len() / ROWS * 3);

    for g in v.chunks_exact(GROUP) {
        // Row 0: the 8 column bases, raw.
        let bases = &g[0..ROWS];
        for &b in bases {
            payload.push(b as u64, 8);
        }
        // Rows 1..7: sign/magnitude deltas, shared per-row width.
        for r in 1..ROWS {
            let row = &g[r * ROWS..(r + 1) * ROWS];
            let w = row
                .iter()
                .zip(bases)
                .map(|(&e, &b)| mag_width((e as i32 - b as i32).unsigned_abs()))
                .max()
                .unwrap();
            if w <= 6 {
                metadata.push(w as u64, WIDTH_FIELD_BITS);
                for (&e, &b) in row.iter().zip(bases) {
                    let d = e as i32 - b as i32;
                    // fused [sign | magnitude] single push (perf §Perf)
                    payload.push((((d < 0) as u64) << w) | d.unsigned_abs() as u64, w + 1);
                }
            } else {
                metadata.push(RAW_ESCAPE as u64, WIDTH_FIELD_BITS);
                for &e in row {
                    payload.push(e as u64, 8);
                }
            }
        }
    }

    let (pw, pb) = payload.into_words();
    let (mw, mb) = metadata.into_words();
    Encoded {
        payload: pw,
        payload_bits: pb,
        metadata: mw,
        metadata_bits: mb,
        count: exps.len(),
    }
}

fn decode_delta(payload: &mut SegReader, metadata: &mut SegReader, count: usize) -> Vec<u8> {
    let padded_len = count.div_ceil(GROUP) * GROUP;
    let mut out = Vec::with_capacity(padded_len);

    let groups = padded_len / GROUP;
    for _ in 0..groups {
        let mut bases = [0u8; ROWS];
        for b in bases.iter_mut() {
            *b = payload.read(8) as u8;
        }
        out.extend_from_slice(&bases);
        for _ in 1..ROWS {
            let w = metadata.read(WIDTH_FIELD_BITS) as u32;
            if w == RAW_ESCAPE {
                for _ in 0..ROWS {
                    out.push(payload.read(8) as u8);
                }
            } else {
                // fused [sign | magnitude] single read (perf §Perf)
                for c in 0..ROWS {
                    let field = payload.read(w + 1);
                    let mag = (field & ((1 << w) - 1)) as i32;
                    let d = if field >> w == 1 { -mag } else { mag };
                    out.push((bases[c] as i32 + d) as u8);
                }
            }
        }
    }
    out.truncate(count);
    out
}

fn encode_fixed(exps: &[u8], bias: u8, group: usize) -> Encoded {
    assert!(group > 0);
    let v = padded(exps, group);
    let mut payload = BitWriter::with_capacity(v.len() * 6);
    let mut metadata = BitWriter::with_capacity(v.len() / group * 3);

    for g in v.chunks_exact(group) {
        let w = g
            .iter()
            .map(|&e| mag_width((e as i32 - bias as i32).unsigned_abs()))
            .max()
            .unwrap();
        if w <= 6 {
            metadata.push(w as u64, WIDTH_FIELD_BITS);
            for &e in g {
                let d = e as i32 - bias as i32;
                payload.push((((d < 0) as u64) << w) | d.unsigned_abs() as u64, w + 1);
            }
        } else {
            metadata.push(RAW_ESCAPE as u64, WIDTH_FIELD_BITS);
            for &e in g {
                payload.push(e as u64, 8);
            }
        }
    }

    let (pw, pb) = payload.into_words();
    let (mw, mb) = metadata.into_words();
    Encoded {
        payload: pw,
        payload_bits: pb,
        metadata: mw,
        metadata_bits: mb,
        count: exps.len(),
    }
}

fn decode_fixed(
    payload: &mut SegReader,
    metadata: &mut SegReader,
    count: usize,
    bias: u8,
    group: usize,
) -> Vec<u8> {
    let padded_len = count.div_ceil(group) * group;
    let mut out = Vec::with_capacity(padded_len);
    for _ in 0..padded_len / group {
        let w = metadata.read(WIDTH_FIELD_BITS) as u32;
        for _ in 0..group {
            if w == RAW_ESCAPE {
                out.push(payload.read(8) as u8);
            } else {
                let field = payload.read(w + 1);
                let mag = (field & ((1 << w) - 1)) as i32;
                let d = if field >> w == 1 { -mag } else { mag };
                out.push((bias as i32 + d) as u8);
            }
        }
    }
    out.truncate(count);
    out
}

/// Encoded size in bits without materializing the bitstream — the fast
/// accounting path used by the footprint models (identical arithmetic to
/// the Pallas `gecko_stats` kernel).
pub fn encoded_bits(exps: &[u8], mode: Mode) -> usize {
    match mode {
        Mode::Delta => {
            let v = padded(exps, GROUP);
            let mut bits = 0usize;
            for g in v.chunks_exact(GROUP) {
                bits += ROWS * 8;
                let bases = &g[0..ROWS];
                for r in 1..ROWS {
                    let row = &g[r * ROWS..(r + 1) * ROWS];
                    let w = row
                        .iter()
                        .zip(bases)
                        .map(|(&e, &b)| mag_width((e as i32 - b as i32).unsigned_abs()))
                        .max()
                        .unwrap();
                    bits += WIDTH_FIELD_BITS as usize
                        + if w <= 6 { ROWS * (w as usize + 1) } else { ROWS * 8 };
                }
            }
            bits
        }
        Mode::FixedBias { bias, group } => {
            let v = padded(exps, group);
            let mut bits = 0usize;
            for g in v.chunks_exact(group) {
                let w = g
                    .iter()
                    .map(|&e| mag_width((e as i32 - bias as i32).unsigned_abs()))
                    .max()
                    .unwrap();
                bits += WIDTH_FIELD_BITS as usize
                    + if w <= 6 {
                        group * (w as usize + 1)
                    } else {
                        group * 8
                    };
            }
            bits
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn exps_from(vals: &[f32]) -> Vec<u8> {
        exponents(vals)
    }

    fn pseudo_vals(n: usize, seed: u64, scale: f32) -> Vec<f32> {
        let mut s = seed.wrapping_add(0x9E3779B97F4A7C15);
        (0..n)
            .map(|_| {
                s ^= s >> 12;
                s ^= s << 25;
                s ^= s >> 27;
                let u = (s.wrapping_mul(0x2545F4914F6CDD1D) >> 40) as f32 / (1u64 << 24) as f32;
                (u - 0.5) * 2.0 * scale
            })
            .collect()
    }

    #[test]
    fn delta_roundtrip_gaussianish() {
        let vals = pseudo_vals(1000, 1, 10.0);
        let e = exps_from(&vals);
        let enc = encode(&e, Mode::Delta);
        assert_eq!(decode(&enc, Mode::Delta), e);
    }

    #[test]
    fn delta_roundtrip_extreme_exponents() {
        // forces raw escapes: mix tiny and huge magnitudes
        let mut vals = pseudo_vals(512, 2, 1e30);
        vals.extend(pseudo_vals(512, 3, 1e-30));
        let e = exps_from(&vals);
        let enc = encode(&e, Mode::Delta);
        assert_eq!(decode(&enc, Mode::Delta), e);
    }

    #[test]
    fn delta_roundtrip_with_zeros_and_partial_group() {
        let mut vals = pseudo_vals(137, 4, 2.0);
        vals[5] = 0.0;
        vals[77] = 0.0;
        let e = exps_from(&vals);
        let enc = encode(&e, Mode::Delta);
        assert_eq!(decode(&enc, Mode::Delta), e);
        assert_eq!(enc.count, 137);
    }

    #[test]
    fn fixed_roundtrip() {
        let vals = pseudo_vals(999, 5, 4.0);
        let e = exps_from(&vals);
        let mode = Mode::FixedBias { bias: 127, group: 8 };
        let enc = encode(&e, mode);
        assert_eq!(decode(&enc, mode), e);
    }

    #[test]
    fn constant_stream_minimal_size() {
        let e = vec![127u8; 64];
        let enc = encode(&e, Mode::Delta);
        // 64 base bits + 7 rows * (8 sign bits); metadata 7 * 3
        assert_eq!(enc.payload_bits, 64 + 7 * 8);
        assert_eq!(enc.metadata_bits, 7 * 3);
    }

    #[test]
    fn encoded_bits_matches_real_encoder() {
        for seed in 0..5u64 {
            let vals = pseudo_vals(473, seed, 7.0);
            let e = exps_from(&vals);
            for mode in [Mode::Delta, Mode::FixedBias { bias: 127, group: 8 }] {
                let enc = encode(&e, mode);
                assert_eq!(encoded_bits(&e, mode), enc.total_bits());
            }
        }
    }

    #[test]
    fn trained_like_values_compress_well() {
        // Unit-scale values: exponents hug 127 => well under 8 b/exponent.
        let vals = pseudo_vals(8192, 9, 1.0);
        let enc = encode(&exps_from(&vals), Mode::Delta);
        assert!(enc.compression_ratio() < 1.0, "{}", enc.compression_ratio());
    }

    #[test]
    fn empty_stream() {
        let enc = encode(&[], Mode::Delta);
        assert_eq!(enc.total_bits(), 0);
        assert!(decode(&enc, Mode::Delta).is_empty());
    }

    /// Concatenate chunk encodings (chunks must cover whole groups except
    /// the last).  The production chunk path is
    /// `stash::EncodedStreams::concat` over the same
    /// `BitWriter::append_words` primitive; this helper pins the invariant
    /// at the `Encoded` level.
    fn concat(chunks: &[Encoded]) -> Encoded {
        let mut payload = BitWriter::new();
        let mut metadata = BitWriter::new();
        let mut count = 0usize;
        for c in chunks {
            payload.append_words(&c.payload, c.payload_bits);
            metadata.append_words(&c.metadata, c.metadata_bits);
            count += c.count;
        }
        let (pw, pb) = payload.into_words();
        let (mw, mb) = metadata.into_words();
        Encoded {
            payload: pw,
            payload_bits: pb,
            metadata: mw,
            metadata_bits: mb,
            count,
        }
    }

    #[test]
    fn chunked_encode_concat_is_one_shot() {
        // Regression (chunk-boundary correctness): encoding a tensor in N
        // group-aligned chunks and concatenating must be bit-identical to
        // one-shot encoding — payload words, metadata words, and lengths.
        let vals = pseudo_vals(64 * 5 + 37, 21, 6.0);
        let e = exps_from(&vals);
        let one = encode(&e, Mode::Delta);
        for chunk in [GROUP, 2 * GROUP, 3 * GROUP] {
            let parts: Vec<Encoded> =
                e.chunks(chunk).map(|c| encode(c, Mode::Delta)).collect();
            let cat = concat(&parts);
            assert_eq!(cat.count, one.count, "chunk {chunk}");
            assert_eq!(cat.payload_bits, one.payload_bits, "chunk {chunk}");
            assert_eq!(cat.metadata_bits, one.metadata_bits, "chunk {chunk}");
            assert_eq!(cat.payload, one.payload, "chunk {chunk}");
            assert_eq!(cat.metadata, one.metadata, "chunk {chunk}");
            assert_eq!(decode(&cat, Mode::Delta), e);
        }
    }

    #[test]
    fn chunked_encode_concat_fixed_bias() {
        let vals = pseudo_vals(500, 23, 2.0);
        let e = exps_from(&vals);
        let mode = Mode::FixedBias { bias: 127, group: 8 };
        let one = encode(&e, mode);
        let parts: Vec<Encoded> = e.chunks(120).map(|c| encode(c, mode)).collect();
        let cat = concat(&parts);
        assert_eq!(cat.payload, one.payload);
        assert_eq!(cat.metadata, one.metadata);
        assert_eq!(cat.payload_bits, one.payload_bits);
        assert_eq!(decode(&cat, mode), e);
    }
}
