//! Minimal MSB-first bit-packing primitives shared by the Gecko and SFP
//! codecs.  The writer packs into `u64` words (the hot path of the whole
//! compression stack — see EXPERIMENTS.md §Perf for the iteration log).

/// Append-only bit writer, MSB-first within each 64-bit word.
#[derive(Default, Debug, Clone)]
pub struct BitWriter {
    words: Vec<u64>,
    /// Total bits written.
    len: usize,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(bits: usize) -> Self {
        Self {
            words: Vec::with_capacity(bits / 64 + 1),
            len: 0,
        }
    }

    /// Append the low `n` bits of `v` (n <= 57 per call keeps the fast
    /// two-word path branch-light; codecs never need more than 32).
    #[inline]
    pub fn push(&mut self, v: u64, n: u32) {
        debug_assert!(n <= 57);
        debug_assert!(n == 64 || v < (1u64 << n));
        if n == 0 {
            return;
        }
        let bit = self.len & 63;
        let avail = 64 - bit as u32;
        if self.len % 64 == 0 {
            self.words.push(0);
        }
        let last = self.words.last_mut().unwrap();
        if n <= avail {
            *last |= v << (avail - n);
        } else {
            let hi = n - avail;
            *last |= v >> hi;
            self.words.push(v << (64 - hi));
        }
        self.len += n as usize;
    }

    /// Rebuild a writer from previously-emitted words (to extend or
    /// concatenate streams).  Bits at positions `>= len_bits` are cleared,
    /// restoring the writer invariant that unwritten bits are zero —
    /// without it, the first `push` after rebuilding would OR into stale
    /// tail bits.
    pub fn from_words(mut words: Vec<u64>, len_bits: usize) -> Self {
        debug_assert!(len_bits <= words.len() * 64);
        words.truncate(len_bits.div_ceil(64));
        let tail = len_bits % 64;
        if tail != 0 {
            if let Some(last) = words.last_mut() {
                *last &= u64::MAX << (64 - tail);
            }
        }
        Self {
            words,
            len: len_bits,
        }
    }

    /// Append `len_bits` bits from `words` (MSB-first, as produced by
    /// [`BitWriter::into_words`]) onto this stream.
    ///
    /// This is the chunk-boundary concatenation path: encoding a tensor in
    /// N chunks and appending the pieces is bit-identical to one-shot
    /// encoding.  A word-granular `Vec` concat is only correct when the
    /// left stream's length is a multiple of 64 — this handles the general
    /// case by re-pushing the appended bits at the current bit offset.
    pub fn append_words(&mut self, words: &[u64], len_bits: usize) {
        debug_assert!(len_bits <= words.len() * 64);
        if len_bits == 0 {
            return;
        }
        let used = len_bits.div_ceil(64);
        if self.len % 64 == 0 {
            // Word-aligned fast path: memcpy, then clear the tail so the
            // writer invariant (zero bits past `len`) holds even when the
            // source's final word carries garbage past its length.
            self.words.extend_from_slice(&words[..used]);
            self.len += len_bits;
            let tail = self.len % 64;
            if tail != 0 {
                if let Some(last) = self.words.last_mut() {
                    *last &= u64::MAX << (64 - tail);
                }
            }
            return;
        }
        let mut remaining = len_bits;
        for &w in &words[..used] {
            let take = remaining.min(64) as u32;
            // push() accepts <= 57 bits per call; split each word into two
            // MSB-first halves.
            let hi = take.min(32);
            self.push(w >> (64 - hi), hi);
            if take > 32 {
                let lo = take - 32;
                self.push((w >> (32 - lo)) & ((1u64 << lo) - 1), lo);
            }
            remaining -= take as usize;
        }
    }

    /// Append another writer's stream (see [`BitWriter::append_words`]).
    pub fn append(&mut self, other: &BitWriter) {
        self.append_words(other.words(), other.len_bits());
    }

    /// Total bits written so far.
    pub fn len_bits(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Finish and expose the packed words.
    pub fn into_words(self) -> (Vec<u64>, usize) {
        (self.words, self.len)
    }

    pub fn words(&self) -> &[u64] {
        &self.words
    }
}

/// Sequential reader over a [`BitWriter`]'s output.
pub struct BitReader<'a> {
    words: &'a [u64],
    pos: usize,
    len: usize,
}

impl<'a> BitReader<'a> {
    pub fn new(words: &'a [u64], len_bits: usize) -> Self {
        Self {
            words,
            pos: 0,
            len: len_bits,
        }
    }

    /// Read the next `n` bits (MSB-first); panics past the end in debug.
    #[inline]
    pub fn read(&mut self, n: u32) -> u64 {
        debug_assert!(n <= 57);
        debug_assert!(self.pos + n as usize <= self.len, "bitstream overrun");
        if n == 0 {
            return 0;
        }
        let word = self.pos / 64;
        let bit = (self.pos & 63) as u32;
        let avail = 64 - bit;
        let out = if n <= avail {
            (self.words[word] >> (avail - n)) & mask(n)
        } else {
            let hi = n - avail;
            let top = self.words[word] & mask(avail);
            (top << hi) | (self.words[word + 1] >> (64 - hi))
        };
        self.pos += n as usize;
        out
    }

    /// Bits remaining.
    pub fn remaining(&self) -> usize {
        self.len - self.pos
    }
}

#[inline]
fn mask(n: u32) -> u64 {
    if n >= 64 {
        u64::MAX
    } else {
        (1u64 << n) - 1
    }
}

/// Sequential MSB-first reader over a bit stream stored as a *run of word
/// segments* — the zero-copy restore path: arena chunk runs are read in
/// place instead of being materialized into one contiguous `Vec<u64>`.
///
/// Words are pulled through a 64-bit staging accumulator, so the hot
/// `read` has no per-call word-index arithmetic; crossing a segment
/// boundary costs one slice advance.  `SegReader::single` degenerates to
/// the contiguous case, which is how the materialized decode paths now
/// run too (one reader implementation, property-tested against
/// [`BitReader`]).
pub struct SegReader<'a> {
    /// Words of the current segment not yet pulled into the accumulator.
    cur: &'a [u64],
    /// Segments after `cur`.
    rest: &'a [&'a [u64]],
    /// Staging bits, MSB-aligned: the top `have` bits are the next bits.
    acc: u64,
    have: u32,
    pos: usize,
    len: usize,
}

impl<'a> SegReader<'a> {
    /// Reader over `len_bits` bits spread across `segs` in order.  Every
    /// segment may have any length; together they must hold at least
    /// `len_bits.div_ceil(64)` words.
    pub fn new(segs: &'a [&'a [u64]], len_bits: usize) -> Self {
        debug_assert!(len_bits.div_ceil(64) <= segs.iter().map(|s| s.len()).sum::<usize>());
        let (cur, rest): (&[u64], &[&[u64]]) = match segs.split_first() {
            Some((first, rest)) => (*first, rest),
            None => (&[], &[]),
        };
        Self {
            cur,
            rest,
            acc: 0,
            have: 0,
            pos: 0,
            len: len_bits,
        }
    }

    /// Reader over one contiguous word slice (the single-segment case).
    pub fn single(words: &'a [u64], len_bits: usize) -> Self {
        Self {
            cur: words,
            rest: &[],
            acc: 0,
            have: 0,
            pos: 0,
            len: len_bits,
        }
    }

    #[inline]
    fn fetch(&mut self) -> u64 {
        while self.cur.is_empty() {
            let (first, rest) = self.rest.split_first().expect("bitstream overrun");
            self.cur = *first;
            self.rest = rest;
        }
        let w = self.cur[0];
        self.cur = &self.cur[1..];
        w
    }

    /// Read the next `n` bits (MSB-first, n <= 57 like [`BitReader`]);
    /// panics past the declared length in debug builds.
    #[inline]
    pub fn read(&mut self, n: u32) -> u64 {
        debug_assert!(n <= 57);
        debug_assert!(self.pos + n as usize <= self.len, "bitstream overrun");
        if n == 0 {
            return 0;
        }
        self.pos += n as usize;
        if self.have >= n {
            let out = self.acc >> (64 - n);
            self.acc <<= n;
            self.have -= n;
            return out;
        }
        // Split read: top `have` bits from the accumulator, the rest from
        // the next word.  `lo` is in 1..=57 so every shift below is < 64.
        let hi_bits = self.have;
        let hi = if hi_bits == 0 {
            0
        } else {
            self.acc >> (64 - hi_bits)
        };
        let w = self.fetch();
        let lo = n - hi_bits;
        let out = (hi << lo) | (w >> (64 - lo));
        self.acc = w << lo;
        self.have = 64 - lo;
        out
    }

    /// Bits remaining.
    pub fn remaining(&self) -> usize {
        self.len - self.pos
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_varied_widths() {
        let mut w = BitWriter::new();
        let fields: Vec<(u64, u32)> = (0..500)
            .map(|i| {
                let n = (i % 33) as u32 + 1;
                ((i as u64).wrapping_mul(0x9E3779B97F4A7C15) & ((1u64 << n) - 1), n)
            })
            .collect();
        for &(v, n) in &fields {
            w.push(v, n);
        }
        let total: usize = fields.iter().map(|&(_, n)| n as usize).sum();
        assert_eq!(w.len_bits(), total);
        let (words, len) = w.into_words();
        let mut r = BitReader::new(&words, len);
        for &(v, n) in &fields {
            assert_eq!(r.read(n), v, "width {n}");
        }
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn zero_width_push_is_noop() {
        let mut w = BitWriter::new();
        w.push(0, 0);
        w.push(0b101, 3);
        assert_eq!(w.len_bits(), 3);
        let (words, len) = w.into_words();
        let mut r = BitReader::new(&words, len);
        assert_eq!(r.read(3), 0b101);
    }

    fn pseudo_fields(count: usize) -> Vec<(u64, u32)> {
        (0..count)
            .map(|i| {
                let n = (i % 33) as u32 + 1;
                (
                    (i as u64).wrapping_mul(0x9E3779B97F4A7C15) & ((1u64 << n) - 1),
                    n,
                )
            })
            .collect()
    }

    #[test]
    fn append_matches_contiguous_pushes() {
        // Regression for chunk-boundary correctness: splitting a stream at
        // ANY field boundary and appending the halves must reproduce the
        // one-shot stream bit for bit (word-aligned splits hide the bug;
        // unaligned ones caught the naive Vec-concat approach).
        let fields = pseudo_fields(300);
        let mut oneshot = BitWriter::new();
        for &(v, n) in &fields {
            oneshot.push(v, n);
        }
        for split in [0, 1, 7, 64, 65, 150, 299, 300] {
            let mut left = BitWriter::new();
            let mut right = BitWriter::new();
            for &(v, n) in &fields[..split] {
                left.push(v, n);
            }
            for &(v, n) in &fields[split..] {
                right.push(v, n);
            }
            left.append(&right);
            assert_eq!(left.len_bits(), oneshot.len_bits(), "split {split}");
            assert_eq!(left.words(), oneshot.words(), "split {split}");
        }
    }

    #[test]
    fn append_after_append_stays_consistent() {
        // Three-way unaligned concatenation, then read everything back.
        let fields = pseudo_fields(200);
        let mut w = BitWriter::new();
        for part in fields.chunks(67) {
            let mut chunk = BitWriter::new();
            for &(v, n) in part {
                chunk.push(v, n);
            }
            w.append(&chunk);
        }
        let (words, len) = w.into_words();
        let mut r = BitReader::new(&words, len);
        for &(v, n) in &fields {
            assert_eq!(r.read(n), v, "width {n}");
        }
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn from_words_clears_tail_garbage() {
        // Rebuilding from words whose final word has junk past the length
        // must not corrupt subsequent pushes (push ORs into the last word).
        let mut w = BitWriter::from_words(vec![u64::MAX], 3);
        assert_eq!(w.len_bits(), 3);
        w.push(0, 5);
        let (words, len) = w.into_words();
        let mut r = BitReader::new(&words, len);
        assert_eq!(r.read(3), 0b111);
        assert_eq!(r.read(5), 0);
    }

    #[test]
    fn append_words_source_longer_than_length() {
        // The source slice may carry extra words past len_bits; only the
        // declared bits must land.
        let mut w = BitWriter::new();
        w.push(0b10, 2);
        w.append_words(&[0xFFFF_FFFF_FFFF_FFFF, 0xDEAD_BEEF], 4);
        assert_eq!(w.len_bits(), 6);
        let (words, len) = w.into_words();
        let mut r = BitReader::new(&words, len);
        assert_eq!(r.read(6), 0b101111);
    }

    #[test]
    fn word_boundary_crossing() {
        let mut w = BitWriter::new();
        w.push((1u64 << 57) - 1, 57); // fill most of word 0
        w.push(0x3FF, 10); // crosses into word 1
        let (words, len) = w.into_words();
        let mut r = BitReader::new(&words, len);
        assert_eq!(r.read(57), (1u64 << 57) - 1);
        assert_eq!(r.read(10), 0x3FF);
    }

    #[test]
    fn seg_reader_single_matches_bit_reader() {
        let fields = pseudo_fields(400);
        let mut w = BitWriter::new();
        for &(v, n) in &fields {
            w.push(v, n);
        }
        let (words, len) = w.into_words();
        let mut a = BitReader::new(&words, len);
        let mut b = SegReader::single(&words, len);
        for &(_, n) in &fields {
            assert_eq!(a.read(n), b.read(n), "width {n}");
        }
        assert_eq!(b.remaining(), 0);
    }

    #[test]
    fn seg_reader_across_segment_splits() {
        // Any word-granular split of the stream (the arena's chunk
        // boundaries are word-aligned) must read back identically,
        // including splits that land inside a multi-word field read.
        let fields = pseudo_fields(600);
        let mut w = BitWriter::new();
        for &(v, n) in &fields {
            w.push(v, n);
        }
        let (words, len) = w.into_words();
        for split in [0usize, 1, 2, 7, 64, 100, words.len()] {
            let split = split.min(words.len());
            let segs: Vec<&[u64]> = vec![&words[..split], &words[split..]];
            let mut r = SegReader::new(&segs, len);
            for &(v, n) in &fields {
                assert_eq!(r.read(n), v, "split {split} width {n}");
            }
            assert_eq!(r.remaining(), 0);
        }
    }

    #[test]
    fn seg_reader_many_small_segments() {
        let fields = pseudo_fields(300);
        let mut w = BitWriter::new();
        for &(v, n) in &fields {
            w.push(v, n);
        }
        let (words, len) = w.into_words();
        // 1-word segments plus an interleaved empty segment
        let mut segs: Vec<&[u64]> = Vec::new();
        for chunk in words.chunks(1) {
            segs.push(chunk);
            segs.push(&[]);
        }
        let mut r = SegReader::new(&segs, len);
        for &(v, n) in &fields {
            assert_eq!(r.read(n), v, "width {n}");
        }
    }

    #[test]
    fn seg_reader_empty_stream() {
        let mut r = SegReader::new(&[], 0);
        assert_eq!(r.remaining(), 0);
        assert_eq!(r.read(0), 0);
    }
}
