//! Minimal MSB-first bit-packing primitives shared by the Gecko and SFP
//! codecs.  The writer packs into `u64` words (the hot path of the whole
//! compression stack — see EXPERIMENTS.md §Perf for the iteration log).
//!
//! Two tiers of primitives share one bitstream layout:
//!
//! * scalar: [`BitWriter::push`] / [`SegReader::read`] — one field per
//!   call, ≤ 57 bits.  The reference implementation.
//! * word-parallel: [`BitWriter::push_word`] / [`BitWriter::pack_lanes`]
//!   and [`SegReader::read_word`] / [`SegReader::unpack_lanes`] — a whole
//!   row of same-width fields spliced per call through a 128-bit staging
//!   accumulator (bitstream-SIMD with shifts and masks; std-only, no
//!   intrinsics).  Bit-identical to the equivalent scalar call sequence
//!   by construction, which [`Kernel`]-differential tests pin down.

use std::sync::OnceLock;

/// Which codec kernel implementation drives encode/decode.
///
/// Both kernels emit (and consume) *identical* bitstreams — the choice is
/// transport-level only, so content hashes, cache entries, and manifest
/// fingerprints never depend on it.  CI proves that by re-running the lab
/// grid with the word kernels against a cache populated by the scalar
/// reference and asserting 100% fingerprint-verified hits.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kernel {
    /// u64-lane word-parallel kernels (the production path).
    Word,
    /// Per-value scalar reference implementation.
    Scalar,
}

impl Kernel {
    /// Process-wide kernel selection: `SFP_CODEC_KERNELS=scalar` forces
    /// the reference implementation; anything else (including unset)
    /// selects the word-parallel kernels.  Read once, then cached.
    pub fn active() -> Kernel {
        static ACTIVE: OnceLock<Kernel> = OnceLock::new();
        *ACTIVE.get_or_init(|| match std::env::var("SFP_CODEC_KERNELS").as_deref() {
            Ok("scalar") => Kernel::Scalar,
            _ => Kernel::Word,
        })
    }
}

/// Append-only bit writer, MSB-first within each 64-bit word.
#[derive(Default, Debug, Clone)]
pub struct BitWriter {
    words: Vec<u64>,
    /// Total bits written.
    len: usize,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(bits: usize) -> Self {
        Self {
            words: Vec::with_capacity(bits / 64 + 1),
            len: 0,
        }
    }

    /// Append the low `n` bits of `v` (n <= 57 per call keeps the fast
    /// two-word path branch-light; codecs never need more than 32).
    #[inline]
    pub fn push(&mut self, v: u64, n: u32) {
        debug_assert!(n <= 57);
        debug_assert!(n == 64 || v < (1u64 << n));
        if n == 0 {
            return;
        }
        let bit = self.len & 63;
        let avail = 64 - bit as u32;
        if self.len % 64 == 0 {
            self.words.push(0);
        }
        let last = self.words.last_mut().unwrap();
        if n <= avail {
            *last |= v << (avail - n);
        } else {
            let hi = n - avail;
            *last |= v >> hi;
            self.words.push(v << (64 - hi));
        }
        self.len += n as usize;
    }

    /// Append the low `n` bits of `v` in one splice, `n <= 64` — the
    /// word-granular sibling of [`BitWriter::push`] used by the
    /// [`Kernel::Word`] encode paths: a whole row of fields is combined
    /// into one word with shifts/ORs, then spliced here in a single call
    /// instead of one `push` per field.  Bit-identical to pushing the
    /// fields individually.
    #[inline]
    pub fn push_word(&mut self, v: u64, n: u32) {
        debug_assert!(n <= 64);
        debug_assert!(n == 64 || v < (1u64 << n));
        if n == 0 {
            return;
        }
        let bit = (self.len & 63) as u32;
        if bit == 0 {
            // fresh word: the value lands MSB-aligned in one store
            self.words.push(if n == 64 { v } else { v << (64 - n) });
        } else {
            // bit >= 1, so avail <= 63 and every shift below is in 1..=63
            let avail = 64 - bit;
            let last = self.words.last_mut().expect("partial word exists");
            if n <= avail {
                *last |= v << (avail - n);
            } else {
                let hi = n - avail;
                *last |= v >> hi;
                self.words.push(v << (64 - hi));
            }
        }
        self.len += n as usize;
    }

    /// Append `fields.len()` fields of uniform `width` bits each — the
    /// bit-plane-transposed pack: instead of one bit-offset computation
    /// per field (scalar `push`), fields stream through a 128-bit staging
    /// accumulator and whole 64-bit words flush as they fill.
    ///
    /// Mask derivation (MSB-first stream order, `fill` = pending bits):
    ///
    /// ```text
    ///   acc (128 b):  [ pending tail (fill bits) | zeros ............ ]
    ///                   bit 127 ...                              bit 0
    ///   place field:  acc |= field << (128 - fill - width)
    ///   flush:        fill >= 64  =>  emit (acc >> 64), acc <<= 64
    /// ```
    ///
    /// `fill < 64` at every loop entry and `width <= 64`, so the place
    /// shift is in `1..=127` and never overflows the staging accumulator.
    /// Bit-identical to calling [`BitWriter::push`] once per field.
    pub fn pack_lanes(&mut self, fields: &[u64], width: u32) {
        debug_assert!(width <= 64);
        if width == 0 || fields.is_empty() {
            return;
        }
        let total_bits = fields.len() * width as usize;
        self.words.reserve(total_bits / 64 + 2);
        let mut fill = (self.len & 63) as u32;
        // Seed the accumulator with the current partial word (if any) so
        // the flushes below re-emit it completed.
        let mut acc: u128 = if fill == 0 {
            0
        } else {
            (self.words.pop().expect("partial word exists") as u128) << 64
        };
        for &f in fields {
            debug_assert!(width == 64 || f < (1u64 << width));
            acc |= (f as u128) << (128 - fill - width);
            fill += width;
            if fill >= 64 {
                self.words.push((acc >> 64) as u64);
                acc <<= 64;
                fill -= 64;
            }
        }
        if fill > 0 {
            self.words.push((acc >> 64) as u64);
        }
        self.len += total_bits;
    }

    /// Rebuild a writer from previously-emitted words (to extend or
    /// concatenate streams).  Bits at positions `>= len_bits` are cleared,
    /// restoring the writer invariant that unwritten bits are zero —
    /// without it, the first `push` after rebuilding would OR into stale
    /// tail bits.
    pub fn from_words(mut words: Vec<u64>, len_bits: usize) -> Self {
        debug_assert!(len_bits <= words.len() * 64);
        words.truncate(len_bits.div_ceil(64));
        let tail = len_bits % 64;
        if tail != 0 {
            if let Some(last) = words.last_mut() {
                *last &= u64::MAX << (64 - tail);
            }
        }
        Self {
            words,
            len: len_bits,
        }
    }

    /// Append `len_bits` bits from `words` (MSB-first, as produced by
    /// [`BitWriter::into_words`]) onto this stream.
    ///
    /// This is the chunk-boundary concatenation path: encoding a tensor in
    /// N chunks and appending the pieces is bit-identical to one-shot
    /// encoding.  A word-granular `Vec` concat is only correct when the
    /// left stream's length is a multiple of 64 — this handles the general
    /// case by re-pushing the appended bits at the current bit offset.
    pub fn append_words(&mut self, words: &[u64], len_bits: usize) {
        debug_assert!(len_bits <= words.len() * 64);
        if len_bits == 0 {
            return;
        }
        let used = len_bits.div_ceil(64);
        if self.len % 64 == 0 {
            // Word-aligned fast path: memcpy, then clear the tail so the
            // writer invariant (zero bits past `len`) holds even when the
            // source's final word carries garbage past its length.
            self.words.extend_from_slice(&words[..used]);
            self.len += len_bits;
            let tail = self.len % 64;
            if tail != 0 {
                if let Some(last) = self.words.last_mut() {
                    *last &= u64::MAX << (64 - tail);
                }
            }
            return;
        }
        let mut remaining = len_bits;
        for &w in &words[..used] {
            let take = remaining.min(64) as u32;
            // push() accepts <= 57 bits per call; split each word into two
            // MSB-first halves.
            let hi = take.min(32);
            self.push(w >> (64 - hi), hi);
            if take > 32 {
                let lo = take - 32;
                self.push((w >> (32 - lo)) & ((1u64 << lo) - 1), lo);
            }
            remaining -= take as usize;
        }
    }

    /// Append another writer's stream (see [`BitWriter::append_words`]).
    pub fn append(&mut self, other: &BitWriter) {
        self.append_words(other.words(), other.len_bits());
    }

    /// Total bits written so far.
    pub fn len_bits(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Finish and expose the packed words.
    pub fn into_words(self) -> (Vec<u64>, usize) {
        (self.words, self.len)
    }

    pub fn words(&self) -> &[u64] {
        &self.words
    }
}

/// Sequential reader over a [`BitWriter`]'s output.
pub struct BitReader<'a> {
    words: &'a [u64],
    pos: usize,
    len: usize,
}

impl<'a> BitReader<'a> {
    pub fn new(words: &'a [u64], len_bits: usize) -> Self {
        Self {
            words,
            pos: 0,
            len: len_bits,
        }
    }

    /// Read the next `n` bits (MSB-first); panics past the end in debug.
    #[inline]
    pub fn read(&mut self, n: u32) -> u64 {
        debug_assert!(n <= 57);
        debug_assert!(self.pos + n as usize <= self.len, "bitstream overrun");
        if n == 0 {
            return 0;
        }
        let word = self.pos / 64;
        let bit = (self.pos & 63) as u32;
        let avail = 64 - bit;
        let out = if n <= avail {
            (self.words[word] >> (avail - n)) & mask(n)
        } else {
            let hi = n - avail;
            let top = self.words[word] & mask(avail);
            (top << hi) | (self.words[word + 1] >> (64 - hi))
        };
        self.pos += n as usize;
        out
    }

    /// Bits remaining.
    pub fn remaining(&self) -> usize {
        self.len - self.pos
    }
}

#[inline]
fn mask(n: u32) -> u64 {
    if n >= 64 {
        u64::MAX
    } else {
        (1u64 << n) - 1
    }
}

/// Sequential MSB-first reader over a bit stream stored as a *run of word
/// segments* — the zero-copy restore path: arena chunk runs are read in
/// place instead of being materialized into one contiguous `Vec<u64>`.
///
/// Words are pulled through a 64-bit staging accumulator, so the hot
/// `read` has no per-call word-index arithmetic; crossing a segment
/// boundary costs one slice advance.  `SegReader::single` degenerates to
/// the contiguous case, which is how the materialized decode paths now
/// run too (one reader implementation, property-tested against
/// [`BitReader`]).
pub struct SegReader<'a> {
    /// Words of the current segment not yet pulled into the accumulator.
    cur: &'a [u64],
    /// Segments after `cur`.
    rest: &'a [&'a [u64]],
    /// Staging bits, MSB-aligned: the top `have` bits are the next bits.
    acc: u64,
    have: u32,
    pos: usize,
    len: usize,
}

impl<'a> SegReader<'a> {
    /// Reader over `len_bits` bits spread across `segs` in order.  Every
    /// segment may have any length; together they must hold at least
    /// `len_bits.div_ceil(64)` words.
    pub fn new(segs: &'a [&'a [u64]], len_bits: usize) -> Self {
        debug_assert!(len_bits.div_ceil(64) <= segs.iter().map(|s| s.len()).sum::<usize>());
        let (cur, rest): (&[u64], &[&[u64]]) = match segs.split_first() {
            Some((first, rest)) => (*first, rest),
            None => (&[], &[]),
        };
        Self {
            cur,
            rest,
            acc: 0,
            have: 0,
            pos: 0,
            len: len_bits,
        }
    }

    /// Reader over one contiguous word slice (the single-segment case).
    pub fn single(words: &'a [u64], len_bits: usize) -> Self {
        Self {
            cur: words,
            rest: &[],
            acc: 0,
            have: 0,
            pos: 0,
            len: len_bits,
        }
    }

    #[inline]
    fn fetch(&mut self) -> u64 {
        while self.cur.is_empty() {
            let (first, rest) = self.rest.split_first().expect("bitstream overrun");
            self.cur = *first;
            self.rest = rest;
        }
        let w = self.cur[0];
        self.cur = &self.cur[1..];
        w
    }

    /// Read the next `n` bits (MSB-first, n <= 57 like [`BitReader`]);
    /// panics past the declared length in debug builds.
    #[inline]
    pub fn read(&mut self, n: u32) -> u64 {
        debug_assert!(n <= 57);
        debug_assert!(self.pos + n as usize <= self.len, "bitstream overrun");
        if n == 0 {
            return 0;
        }
        self.pos += n as usize;
        if self.have >= n {
            let out = self.acc >> (64 - n);
            self.acc <<= n;
            self.have -= n;
            return out;
        }
        // Split read: top `have` bits from the accumulator, the rest from
        // the next word.  `lo` is in 1..=57 so every shift below is < 64.
        let hi_bits = self.have;
        let hi = if hi_bits == 0 {
            0
        } else {
            self.acc >> (64 - hi_bits)
        };
        let w = self.fetch();
        let lo = n - hi_bits;
        let out = (hi << lo) | (w >> (64 - lo));
        self.acc = w << lo;
        self.have = 64 - lo;
        out
    }

    /// Read the next `n` bits in one splice, `n <= 64` — the word-granular
    /// sibling of [`SegReader::read`] ([`Kernel::Word`] decode paths pull
    /// a whole row per call and peel lanes with shifts/masks).
    #[inline]
    pub fn read_word(&mut self, n: u32) -> u64 {
        debug_assert!(n <= 64);
        debug_assert!(self.pos + n as usize <= self.len, "bitstream overrun");
        if n == 0 {
            return 0;
        }
        self.pos += n as usize;
        // `have <= 63` always holds, so this branch implies n <= 63 and
        // both shifts below stay in range.
        if self.have >= n {
            let out = self.acc >> (64 - n);
            self.acc <<= n;
            self.have -= n;
            return out;
        }
        let hi_bits = self.have;
        let hi = if hi_bits == 0 {
            0
        } else {
            self.acc >> (64 - hi_bits)
        };
        let w = self.fetch();
        let lo = n - hi_bits; // 1..=64; lo == 64 only when have == 0, n == 64
        if lo == 64 {
            self.acc = 0;
            self.have = 0;
            return w;
        }
        let out = (hi << lo) | (w >> (64 - lo));
        self.acc = w << lo;
        self.have = 64 - lo;
        out
    }

    /// Read `out.len()` fields of uniform `width` bits each (`1..=64`) —
    /// the unpack mirror of [`BitWriter::pack_lanes`]: fields stream out
    /// of a 128-bit staging accumulator topped up one word at a time,
    /// extracted MSB-first with one shift per field.  Bit-identical to
    /// calling [`SegReader::read`] once per field.
    pub fn unpack_lanes(&mut self, width: u32, out: &mut [u64]) {
        debug_assert!((1..=64).contains(&width));
        debug_assert!(
            self.pos + out.len() * width as usize <= self.len,
            "bitstream overrun"
        );
        // Staging layout mirrors pack_lanes: the top `have` bits of `acc`
        // are the next bits of the stream.
        let mut acc: u128 = (self.acc as u128) << 64;
        let mut have = self.have;
        for o in out.iter_mut() {
            if have < width {
                // have <= 63 here, so the place shift is in 1..=64
                let w = self.fetch();
                acc |= (w as u128) << (64 - have);
                have += 64;
            }
            *o = (acc >> (128 - width)) as u64;
            acc <<= width;
            have -= width;
        }
        self.pos += out.len() * width as usize;
        // have < 64 on exit (have_new = have_old [+ 64] - width), so the
        // scalar accumulator invariant is restored.
        self.acc = (acc >> 64) as u64;
        self.have = have;
    }

    /// Bits remaining.
    pub fn remaining(&self) -> usize {
        self.len - self.pos
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_varied_widths() {
        let mut w = BitWriter::new();
        let fields: Vec<(u64, u32)> = (0..500)
            .map(|i| {
                let n = (i % 33) as u32 + 1;
                ((i as u64).wrapping_mul(0x9E3779B97F4A7C15) & ((1u64 << n) - 1), n)
            })
            .collect();
        for &(v, n) in &fields {
            w.push(v, n);
        }
        let total: usize = fields.iter().map(|&(_, n)| n as usize).sum();
        assert_eq!(w.len_bits(), total);
        let (words, len) = w.into_words();
        let mut r = BitReader::new(&words, len);
        for &(v, n) in &fields {
            assert_eq!(r.read(n), v, "width {n}");
        }
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn zero_width_push_is_noop() {
        let mut w = BitWriter::new();
        w.push(0, 0);
        w.push(0b101, 3);
        assert_eq!(w.len_bits(), 3);
        let (words, len) = w.into_words();
        let mut r = BitReader::new(&words, len);
        assert_eq!(r.read(3), 0b101);
    }

    fn pseudo_fields(count: usize) -> Vec<(u64, u32)> {
        (0..count)
            .map(|i| {
                let n = (i % 33) as u32 + 1;
                (
                    (i as u64).wrapping_mul(0x9E3779B97F4A7C15) & ((1u64 << n) - 1),
                    n,
                )
            })
            .collect()
    }

    #[test]
    fn append_matches_contiguous_pushes() {
        // Regression for chunk-boundary correctness: splitting a stream at
        // ANY field boundary and appending the halves must reproduce the
        // one-shot stream bit for bit (word-aligned splits hide the bug;
        // unaligned ones caught the naive Vec-concat approach).
        let fields = pseudo_fields(300);
        let mut oneshot = BitWriter::new();
        for &(v, n) in &fields {
            oneshot.push(v, n);
        }
        for split in [0, 1, 7, 64, 65, 150, 299, 300] {
            let mut left = BitWriter::new();
            let mut right = BitWriter::new();
            for &(v, n) in &fields[..split] {
                left.push(v, n);
            }
            for &(v, n) in &fields[split..] {
                right.push(v, n);
            }
            left.append(&right);
            assert_eq!(left.len_bits(), oneshot.len_bits(), "split {split}");
            assert_eq!(left.words(), oneshot.words(), "split {split}");
        }
    }

    #[test]
    fn append_after_append_stays_consistent() {
        // Three-way unaligned concatenation, then read everything back.
        let fields = pseudo_fields(200);
        let mut w = BitWriter::new();
        for part in fields.chunks(67) {
            let mut chunk = BitWriter::new();
            for &(v, n) in part {
                chunk.push(v, n);
            }
            w.append(&chunk);
        }
        let (words, len) = w.into_words();
        let mut r = BitReader::new(&words, len);
        for &(v, n) in &fields {
            assert_eq!(r.read(n), v, "width {n}");
        }
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn from_words_clears_tail_garbage() {
        // Rebuilding from words whose final word has junk past the length
        // must not corrupt subsequent pushes (push ORs into the last word).
        let mut w = BitWriter::from_words(vec![u64::MAX], 3);
        assert_eq!(w.len_bits(), 3);
        w.push(0, 5);
        let (words, len) = w.into_words();
        let mut r = BitReader::new(&words, len);
        assert_eq!(r.read(3), 0b111);
        assert_eq!(r.read(5), 0);
    }

    #[test]
    fn append_words_source_longer_than_length() {
        // The source slice may carry extra words past len_bits; only the
        // declared bits must land.
        let mut w = BitWriter::new();
        w.push(0b10, 2);
        w.append_words(&[0xFFFF_FFFF_FFFF_FFFF, 0xDEAD_BEEF], 4);
        assert_eq!(w.len_bits(), 6);
        let (words, len) = w.into_words();
        let mut r = BitReader::new(&words, len);
        assert_eq!(r.read(6), 0b101111);
    }

    #[test]
    fn word_boundary_crossing() {
        let mut w = BitWriter::new();
        w.push((1u64 << 57) - 1, 57); // fill most of word 0
        w.push(0x3FF, 10); // crosses into word 1
        let (words, len) = w.into_words();
        let mut r = BitReader::new(&words, len);
        assert_eq!(r.read(57), (1u64 << 57) - 1);
        assert_eq!(r.read(10), 0x3FF);
    }

    #[test]
    fn seg_reader_single_matches_bit_reader() {
        let fields = pseudo_fields(400);
        let mut w = BitWriter::new();
        for &(v, n) in &fields {
            w.push(v, n);
        }
        let (words, len) = w.into_words();
        let mut a = BitReader::new(&words, len);
        let mut b = SegReader::single(&words, len);
        for &(_, n) in &fields {
            assert_eq!(a.read(n), b.read(n), "width {n}");
        }
        assert_eq!(b.remaining(), 0);
    }

    #[test]
    fn seg_reader_across_segment_splits() {
        // Any word-granular split of the stream (the arena's chunk
        // boundaries are word-aligned) must read back identically,
        // including splits that land inside a multi-word field read.
        let fields = pseudo_fields(600);
        let mut w = BitWriter::new();
        for &(v, n) in &fields {
            w.push(v, n);
        }
        let (words, len) = w.into_words();
        for split in [0usize, 1, 2, 7, 64, 100, words.len()] {
            let split = split.min(words.len());
            let segs: Vec<&[u64]> = vec![&words[..split], &words[split..]];
            let mut r = SegReader::new(&segs, len);
            for &(v, n) in &fields {
                assert_eq!(r.read(n), v, "split {split} width {n}");
            }
            assert_eq!(r.remaining(), 0);
        }
    }

    #[test]
    fn seg_reader_many_small_segments() {
        let fields = pseudo_fields(300);
        let mut w = BitWriter::new();
        for &(v, n) in &fields {
            w.push(v, n);
        }
        let (words, len) = w.into_words();
        // 1-word segments plus an interleaved empty segment
        let mut segs: Vec<&[u64]> = Vec::new();
        for chunk in words.chunks(1) {
            segs.push(chunk);
            segs.push(&[]);
        }
        let mut r = SegReader::new(&segs, len);
        for &(v, n) in &fields {
            assert_eq!(r.read(n), v, "width {n}");
        }
    }

    #[test]
    fn seg_reader_empty_stream() {
        let mut r = SegReader::new(&[], 0);
        assert_eq!(r.remaining(), 0);
        assert_eq!(r.read(0), 0);
    }

    fn pseudo_word(i: u64) -> u64 {
        i.wrapping_mul(0x9E3779B97F4A7C15).rotate_left((i % 63) as u32)
    }

    #[test]
    fn push_word_matches_scalar_pushes() {
        // Any width 1..=64 at any starting bit offset must splice exactly
        // the bits two <=32-bit scalar pushes would.
        for lead in [0u32, 1, 7, 31, 33, 57] {
            for n in 1..=64u32 {
                let v = pseudo_word(u64::from(lead * 67 + n)) & mask(n);
                let mut scalar = BitWriter::new();
                let mut word = BitWriter::new();
                scalar.push(0, lead);
                word.push(0, lead);
                let hi = n.min(32);
                scalar.push(v >> (n - hi), hi);
                if n > hi {
                    scalar.push(v & mask(n - hi), n - hi);
                }
                word.push_word(v, n);
                assert_eq!(scalar.len_bits(), word.len_bits(), "lead {lead} n {n}");
                assert_eq!(scalar.words(), word.words(), "lead {lead} n {n}");
            }
        }
    }

    #[test]
    fn pack_lanes_matches_scalar_pushes() {
        for width in 1..=64u32 {
            for count in [1usize, 3, 8, 17, 64] {
                let fields: Vec<u64> = (0..count as u64)
                    .map(|i| pseudo_word(i + u64::from(width)) & mask(width))
                    .collect();
                for lead in [0u32, 5, 57] {
                    let mut scalar = BitWriter::new();
                    let mut word = BitWriter::new();
                    scalar.push(0, lead);
                    word.push(0, lead);
                    for &f in &fields {
                        let hi = width.min(32);
                        scalar.push(f >> (width - hi), hi);
                        if width > hi {
                            scalar.push(f & mask(width - hi), width - hi);
                        }
                    }
                    word.pack_lanes(&fields, width);
                    assert_eq!(scalar.words(), word.words(), "w {width} c {count} l {lead}");
                    assert_eq!(scalar.len_bits(), word.len_bits());
                }
            }
        }
    }

    #[test]
    fn read_word_and_unpack_lanes_match_scalar_reads() {
        // One stream, three readers: scalar read(), read_word(), and
        // unpack_lanes() must all see the same fields — including across
        // word-granular segment splits.
        for width in 1..=64u32 {
            let count = 37usize;
            let fields: Vec<u64> = (0..count as u64)
                .map(|i| pseudo_word(i * 3 + u64::from(width)) & mask(width))
                .collect();
            let mut w = BitWriter::new();
            w.pack_lanes(&fields, width);
            let (words, len) = w.into_words();
            let mid = words.len() / 2;
            let segs: Vec<&[u64]> = vec![&words[..mid], &words[mid..]];

            let mut scalar = SegReader::new(&segs, len);
            let mut word = SegReader::new(&segs, len);
            let mut lanes = SegReader::new(&segs, len);
            let mut got = vec![0u64; count];
            lanes.unpack_lanes(width, &mut got);
            for (i, &f) in fields.iter().enumerate() {
                let hi = width.min(32);
                let mut v = scalar.read(hi);
                if width > hi {
                    v = (v << (width - hi)) | scalar.read(width - hi);
                }
                assert_eq!(v, f, "scalar w {width} i {i}");
                assert_eq!(word.read_word(width), f, "read_word w {width} i {i}");
                assert_eq!(got[i], f, "unpack w {width} i {i}");
            }
            assert_eq!(word.remaining(), 0);
            assert_eq!(lanes.remaining(), 0);
        }
    }

    #[test]
    fn word_and_scalar_calls_interleave_on_one_stream() {
        // The staging accumulator must stay coherent when scalar and word
        // calls alternate mid-stream on both sides.
        let mut w = BitWriter::new();
        w.push(0b101, 3);
        w.push_word(0xDEAD_BEEF_CAFE_F00D, 64);
        w.pack_lanes(&[1, 2, 3, 4, 5], 11);
        w.push(0x3F, 6);
        w.push_word(0x1FFFF, 17);
        let (words, len) = w.into_words();
        let mut r = SegReader::single(&words, len);
        assert_eq!(r.read(3), 0b101);
        assert_eq!(r.read_word(64), 0xDEAD_BEEF_CAFE_F00D);
        let mut lanes = [0u64; 5];
        r.unpack_lanes(11, &mut lanes);
        assert_eq!(lanes, [1, 2, 3, 4, 5]);
        assert_eq!(r.read(6), 0x3F);
        assert_eq!(r.read_word(17), 0x1FFFF);
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn zero_width_word_calls_are_noops() {
        let mut w = BitWriter::new();
        w.push_word(0, 0);
        w.pack_lanes(&[], 7);
        w.pack_lanes(&[1, 2, 3], 0);
        assert_eq!(w.len_bits(), 0);
        let mut r = SegReader::new(&[], 0);
        assert_eq!(r.read_word(0), 0);
    }
}
