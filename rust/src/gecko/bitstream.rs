//! Minimal MSB-first bit-packing primitives shared by the Gecko and SFP
//! codecs.  The writer packs into `u64` words (the hot path of the whole
//! compression stack — see EXPERIMENTS.md §Perf for the iteration log).

/// Append-only bit writer, MSB-first within each 64-bit word.
#[derive(Default, Debug, Clone)]
pub struct BitWriter {
    words: Vec<u64>,
    /// Total bits written.
    len: usize,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(bits: usize) -> Self {
        Self {
            words: Vec::with_capacity(bits / 64 + 1),
            len: 0,
        }
    }

    /// Append the low `n` bits of `v` (n <= 57 per call keeps the fast
    /// two-word path branch-light; codecs never need more than 32).
    #[inline]
    pub fn push(&mut self, v: u64, n: u32) {
        debug_assert!(n <= 57);
        debug_assert!(n == 64 || v < (1u64 << n));
        if n == 0 {
            return;
        }
        let bit = self.len & 63;
        let avail = 64 - bit as u32;
        if self.len % 64 == 0 {
            self.words.push(0);
        }
        let last = self.words.last_mut().unwrap();
        if n <= avail {
            *last |= v << (avail - n);
        } else {
            let hi = n - avail;
            *last |= v >> hi;
            self.words.push(v << (64 - hi));
        }
        self.len += n as usize;
    }

    /// Total bits written so far.
    pub fn len_bits(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Finish and expose the packed words.
    pub fn into_words(self) -> (Vec<u64>, usize) {
        (self.words, self.len)
    }

    pub fn words(&self) -> &[u64] {
        &self.words
    }
}

/// Sequential reader over a [`BitWriter`]'s output.
pub struct BitReader<'a> {
    words: &'a [u64],
    pos: usize,
    len: usize,
}

impl<'a> BitReader<'a> {
    pub fn new(words: &'a [u64], len_bits: usize) -> Self {
        Self {
            words,
            pos: 0,
            len: len_bits,
        }
    }

    /// Read the next `n` bits (MSB-first); panics past the end in debug.
    #[inline]
    pub fn read(&mut self, n: u32) -> u64 {
        debug_assert!(n <= 57);
        debug_assert!(self.pos + n as usize <= self.len, "bitstream overrun");
        if n == 0 {
            return 0;
        }
        let word = self.pos / 64;
        let bit = (self.pos & 63) as u32;
        let avail = 64 - bit;
        let out = if n <= avail {
            (self.words[word] >> (avail - n)) & mask(n)
        } else {
            let hi = n - avail;
            let top = self.words[word] & mask(avail);
            (top << hi) | (self.words[word + 1] >> (64 - hi))
        };
        self.pos += n as usize;
        out
    }

    /// Bits remaining.
    pub fn remaining(&self) -> usize {
        self.len - self.pos
    }
}

#[inline]
fn mask(n: u32) -> u64 {
    if n >= 64 {
        u64::MAX
    } else {
        (1u64 << n) - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_varied_widths() {
        let mut w = BitWriter::new();
        let fields: Vec<(u64, u32)> = (0..500)
            .map(|i| {
                let n = (i % 33) as u32 + 1;
                ((i as u64).wrapping_mul(0x9E3779B97F4A7C15) & ((1u64 << n) - 1), n)
            })
            .collect();
        for &(v, n) in &fields {
            w.push(v, n);
        }
        let total: usize = fields.iter().map(|&(_, n)| n as usize).sum();
        assert_eq!(w.len_bits(), total);
        let (words, len) = w.into_words();
        let mut r = BitReader::new(&words, len);
        for &(v, n) in &fields {
            assert_eq!(r.read(n), v, "width {n}");
        }
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn zero_width_push_is_noop() {
        let mut w = BitWriter::new();
        w.push(0, 0);
        w.push(0b101, 3);
        assert_eq!(w.len_bits(), 3);
        let (words, len) = w.into_words();
        let mut r = BitReader::new(&words, len);
        assert_eq!(r.read(3), 0b101);
    }

    #[test]
    fn word_boundary_crossing() {
        let mut w = BitWriter::new();
        w.push((1u64 << 57) - 1, 57); // fill most of word 0
        w.push(0x3FF, 10); // crosses into word 1
        let (words, len) = w.into_words();
        let mut r = BitReader::new(&words, len);
        assert_eq!(r.read(57), (1u64 << 57) - 1);
        assert_eq!(r.read(10), 0x3FF);
    }
}
