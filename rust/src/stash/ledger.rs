//! Footprint + bandwidth ledger: every stash write, read, and release
//! lands here, giving (a) exact resident stored bits with the Fig. 12
//! component split — directly comparable to the analytic
//! `report::footprint` numbers — and (b) the cumulative DRAM write/read
//! traffic the `hwsim` memory model consumes.

use crate::stats::{ComponentBits, Footprint};
use std::sync::Mutex;

/// Which side of the [`Footprint`] ledger a tensor belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TensorClass {
    Activation,
    Weight,
}

/// Point-in-time copy of the ledger counters.
#[derive(Debug, Clone, Copy, Default)]
pub struct LedgerSnapshot {
    /// Bits currently resident in the stash, by component and class.
    pub resident: Footprint,
    /// Peak resident bits over the ledger's lifetime.
    pub peak_resident_bits: f64,
    /// Cumulative encoded bits written (stash-side DRAM write traffic).
    pub written_bits: f64,
    /// Cumulative encoded bits read back (restore-side DRAM read traffic).
    pub read_bits: f64,
    /// Uncompressed FP32 bits of everything ever written — the Table I
    /// denominator for the achieved ratio.
    pub written_fp32_bits: f64,
    pub writes: u64,
    pub reads: u64,
}

impl LedgerSnapshot {
    /// Achieved footprint relative to stashing the same tensors as FP32.
    pub fn ratio_vs_fp32(&self) -> f64 {
        if self.written_fp32_bits == 0.0 {
            return 1.0;
        }
        self.written_bits / self.written_fp32_bits
    }
}

/// Traffic accumulated between two [`StashLedger::mark_epoch`] cuts — the
/// footprint-over-time axis of the policy reports (how an adapting
/// container's stored bytes shrink epoch by epoch).
#[derive(Debug, Clone, Copy, Default)]
pub struct EpochTraffic {
    pub written_bits: f64,
    pub read_bits: f64,
    pub written_fp32_bits: f64,
}

impl EpochTraffic {
    pub fn ratio_vs_fp32(&self) -> f64 {
        if self.written_fp32_bits == 0.0 {
            return 1.0;
        }
        self.written_bits / self.written_fp32_bits
    }
}

/// Thread-safe ledger shared between pool workers and the caller.
#[derive(Default)]
pub struct StashLedger {
    inner: Mutex<LedgerSnapshot>,
    /// (snapshot at the last mark, per-epoch deltas so far).
    marks: Mutex<(LedgerSnapshot, Vec<EpochTraffic>)>,
}

impl StashLedger {
    pub fn new() -> Self {
        Self::default()
    }

    /// Cut an epoch boundary: record the traffic since the previous mark.
    pub fn mark_epoch(&self) {
        let now = self.snapshot();
        let mut m = self.marks.lock().unwrap();
        let last = m.0;
        m.1.push(EpochTraffic {
            written_bits: now.written_bits - last.written_bits,
            read_bits: now.read_bits - last.read_bits,
            written_fp32_bits: now.written_fp32_bits - last.written_fp32_bits,
        });
        m.0 = now;
    }

    /// Per-epoch traffic deltas recorded so far.
    pub fn epoch_traffic(&self) -> Vec<EpochTraffic> {
        self.marks.lock().unwrap().1.clone()
    }

    pub fn record_write(&self, class: TensorClass, bits: ComponentBits, count: usize) {
        let mut s = self.inner.lock().unwrap();
        match class {
            TensorClass::Activation => s.resident.activations.add(bits),
            TensorClass::Weight => s.resident.weights.add(bits),
        }
        s.written_bits += bits.total();
        s.written_fp32_bits += 32.0 * count as f64;
        s.writes += 1;
        s.peak_resident_bits = s.peak_resident_bits.max(s.resident.total());
    }

    pub fn record_read(&self, bits_total: f64) {
        let mut s = self.inner.lock().unwrap();
        s.read_bits += bits_total;
        s.reads += 1;
    }

    /// A tensor left the stash: subtract its components from residency.
    pub fn record_release(&self, class: TensorClass, bits: ComponentBits) {
        let mut s = self.inner.lock().unwrap();
        match class {
            TensorClass::Activation => s.resident.activations.add(bits.scaled(-1.0)),
            TensorClass::Weight => s.resident.weights.add(bits.scaled(-1.0)),
        }
    }

    pub fn snapshot(&self) -> LedgerSnapshot {
        *self.inner.lock().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cb(sign: f64, exp: f64, mant: f64, meta: f64) -> ComponentBits {
        ComponentBits {
            sign,
            exponent: exp,
            mantissa: mant,
            metadata: meta,
        }
    }

    #[test]
    fn write_read_release_cycle() {
        let l = StashLedger::new();
        l.record_write(TensorClass::Activation, cb(0.0, 400.0, 100.0, 21.0), 100);
        l.record_write(TensorClass::Weight, cb(50.0, 200.0, 150.0, 10.0), 50);
        let s = l.snapshot();
        assert_eq!(s.writes, 2);
        assert!((s.resident.total() - (521.0 + 410.0)).abs() < 1e-9);
        assert!((s.written_fp32_bits - 32.0 * 150.0).abs() < 1e-9);
        assert!((s.peak_resident_bits - 931.0).abs() < 1e-9);

        l.record_read(521.0);
        l.record_release(TensorClass::Activation, cb(0.0, 400.0, 100.0, 21.0));
        let s = l.snapshot();
        assert_eq!(s.reads, 1);
        assert!((s.resident.activations.total()).abs() < 1e-9);
        // peak unaffected by release
        assert!((s.peak_resident_bits - 931.0).abs() < 1e-9);
        assert!(s.ratio_vs_fp32() < 1.0);
    }

    #[test]
    fn epoch_marks_cut_traffic_deltas() {
        let l = StashLedger::new();
        l.record_write(TensorClass::Activation, cb(0.0, 100.0, 50.0, 0.0), 100);
        l.mark_epoch();
        l.record_write(TensorClass::Activation, cb(0.0, 60.0, 20.0, 0.0), 100);
        l.record_read(80.0);
        l.mark_epoch();
        let epochs = l.epoch_traffic();
        assert_eq!(epochs.len(), 2);
        assert!((epochs[0].written_bits - 150.0).abs() < 1e-9);
        assert!((epochs[0].read_bits).abs() < 1e-9);
        assert!((epochs[1].written_bits - 80.0).abs() < 1e-9);
        assert!((epochs[1].read_bits - 80.0).abs() < 1e-9);
        assert!((epochs[1].written_fp32_bits - 3200.0).abs() < 1e-9);
        assert!(epochs[1].ratio_vs_fp32() < 1.0);
        // an epoch with no traffic records a zero row, not a panic
        l.mark_epoch();
        assert!((l.epoch_traffic()[2].written_bits).abs() < 1e-9);
    }
}
