//! Footprint + bandwidth ledger: every stash write, read, release, and
//! spill-tier crossing lands here, giving (a) exact resident stored bits
//! with the Fig. 12 component split — directly comparable to the analytic
//! `report::footprint` numbers — and (b) the cumulative traffic split into
//! DRAM (encode writes / restore reads) and spill bytes (cold-chunk
//! evictions / demand faults), so the `hwsim` DRAM model never charges
//! spilled bytes as resident DRAM traffic.  All counters live under one
//! lock and [`StashLedger::mark_epoch`] cuts them in a single snapshot, so
//! a `footprint_over_time` row can never mix epochs across the two tiers.

use crate::obs::metrics::{HistBuckets, HistSummary, Histogram};
use crate::stats::{ComponentBits, Footprint};
use std::borrow::Cow;
use std::sync::Mutex;

/// Which side of the [`Footprint`] ledger a tensor belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TensorClass {
    Activation,
    Weight,
}

/// Point-in-time copy of the ledger counters.
#[derive(Debug, Clone, Copy, Default)]
pub struct LedgerSnapshot {
    /// Bits currently resident in the stash, by component and class.
    pub resident: Footprint,
    /// Peak resident bits over the ledger's lifetime.
    pub peak_resident_bits: f64,
    /// Cumulative encoded bits written (stash-side DRAM write traffic).
    pub written_bits: f64,
    /// Cumulative encoded bits read back (restore-side DRAM read traffic).
    pub read_bits: f64,
    /// Uncompressed FP32 bits of everything ever written — the Table I
    /// denominator for the achieved ratio.
    pub written_fp32_bits: f64,
    pub writes: u64,
    pub reads: u64,
    /// Bits moved DRAM → spill tier (cold-chunk evictions, whole-chunk
    /// granularity — that is what actually crosses the tier boundary).
    pub spill_written_bits: f64,
    /// Bits faulted back spill → DRAM on demand (whole-chunk granularity).
    pub spill_read_bits: f64,
    /// Chunk evictions to the spill tier.
    pub evictions: u64,
    /// Chunk faults back from the spill tier.
    pub faults: u64,
}

impl LedgerSnapshot {
    /// Achieved footprint relative to stashing the same tensors as FP32.
    pub fn ratio_vs_fp32(&self) -> f64 {
        if self.written_fp32_bits == 0.0 {
            return 1.0;
        }
        self.written_bits / self.written_fp32_bits
    }
}

/// Traffic accumulated between two [`StashLedger::mark_epoch`] cuts — the
/// footprint-over-time axis of the policy reports (how an adapting
/// container's stored bytes shrink epoch by epoch).
#[derive(Debug, Clone, Copy, Default)]
pub struct EpochTraffic {
    pub written_bits: f64,
    pub read_bits: f64,
    pub written_fp32_bits: f64,
    /// Spill-tier eviction bytes this epoch (bits, chunk-granular).
    pub spill_written_bits: f64,
    /// Spill-tier fault-back bytes this epoch (bits, chunk-granular).
    pub spill_read_bits: f64,
    /// Restore (pin+decode) latency digest for restores this epoch whose
    /// chunks were all DRAM-resident.  Latency is an observation, never an
    /// artifact input — the byte/bits fields above stay the only values
    /// that reach content-addressed outputs.
    pub restore_dram_us: HistSummary,
    /// Restore latency digest for restores that faulted ≥1 spilled chunk.
    pub restore_fault_us: HistSummary,
}

impl EpochTraffic {
    pub fn ratio_vs_fp32(&self) -> f64 {
        if self.written_fp32_bits == 0.0 {
            return 1.0;
        }
        self.written_bits / self.written_fp32_bits
    }
}

/// Mark-to-mark state: the counter + latency-bucket snapshots at the last
/// cut, plus the per-epoch delta rows recorded so far.
#[derive(Default)]
struct Marks {
    last: LedgerSnapshot,
    rows: Vec<EpochTraffic>,
    last_dram: HistBuckets,
    last_fault: HistBuckets,
}

/// Spill-tier crossings inside one window count toward a pressure event.
const BURST_THRESHOLD: u64 = 16;
/// Window length for the burst detector, µs.
const BURST_WINDOW_US: u64 = 250_000;

/// Sliding-window burst detector: `note` returns the crossing count when
/// the threshold is reached inside the window (then re-arms), `None`
/// otherwise.
#[derive(Default)]
struct BurstWindow {
    start_us: u64,
    count: u64,
}

impl BurstWindow {
    fn note(&mut self, now_us: u64) -> Option<u64> {
        if now_us.saturating_sub(self.start_us) > BURST_WINDOW_US {
            self.start_us = now_us;
            self.count = 0;
        }
        self.count += 1;
        if self.count >= BURST_THRESHOLD {
            let n = self.count;
            self.start_us = now_us;
            self.count = 0;
            Some(n)
        } else {
            None
        }
    }
}

/// Thread-safe ledger shared between pool workers and the caller.
#[derive(Default)]
pub struct StashLedger {
    inner: Mutex<LedgerSnapshot>,
    marks: Mutex<Marks>,
    /// Restore latency, DRAM-hit tier (no chunk faulted).
    restore_dram: Histogram,
    /// Restore latency, spill-fault tier (≥1 chunk faulted back).
    restore_fault: Histogram,
    /// Flight-recorder burst detectors (eviction storms / fault bursts).
    burst_evict: Mutex<BurstWindow>,
    burst_fault: Mutex<BurstWindow>,
    /// Owner / tenant label stamped onto this ledger's pressure events
    /// (set at lease time; `None` for single-owner stashes).
    owner: Mutex<Option<String>>,
}

impl StashLedger {
    pub fn new() -> Self {
        Self::default()
    }

    /// Tag this ledger's pressure events with an owner/tenant label so
    /// `repro inspect` can attribute eviction storms and fault bursts to
    /// the lease that caused them instead of reporting them globally.
    pub fn set_owner(&self, label: impl Into<String>) {
        *self.owner.lock().unwrap() = Some(label.into());
    }

    /// The owner/tenant label, if one was set.
    pub fn owner(&self) -> Option<String> {
        self.owner.lock().unwrap().clone()
    }

    fn owner_cow(&self) -> Option<Cow<'static, str>> {
        self.owner.lock().unwrap().clone().map(Cow::Owned)
    }

    /// Cut an epoch boundary: record the traffic since the previous mark.
    ///
    /// The marks lock is taken *before* the counter snapshot, so (a)
    /// concurrent cuts serialize into disjoint `[last, now]` intervals and
    /// (b) the DRAM and spill counters of one row come from a single
    /// atomic snapshot — a worker recording between the two reads cannot
    /// smear its traffic across adjacent epochs.
    pub fn mark_epoch(&self) {
        let mut m = self.marks.lock().unwrap();
        let now = self.snapshot();
        let dram = self.restore_dram.snapshot();
        let fault = self.restore_fault.snapshot();
        let last = m.last;
        let row = EpochTraffic {
            written_bits: now.written_bits - last.written_bits,
            read_bits: now.read_bits - last.read_bits,
            written_fp32_bits: now.written_fp32_bits - last.written_fp32_bits,
            spill_written_bits: now.spill_written_bits - last.spill_written_bits,
            spill_read_bits: now.spill_read_bits - last.spill_read_bits,
            restore_dram_us: dram.delta(&m.last_dram).summary(),
            restore_fault_us: fault.delta(&m.last_fault).summary(),
        };
        m.rows.push(row);
        m.last = now;
        m.last_dram = dram;
        m.last_fault = fault;
    }

    /// Per-epoch traffic deltas recorded so far.
    pub fn epoch_traffic(&self) -> Vec<EpochTraffic> {
        self.marks.lock().unwrap().rows.clone()
    }

    /// Record one restore's (pin+decode) latency, classified by tier:
    /// `faulted` = at least one chunk came back from the spill file.
    pub fn record_restore_latency(&self, faulted: bool, us: u64) {
        if faulted {
            self.restore_fault.record(us);
        } else {
            self.restore_dram.record(us);
        }
    }

    /// Cumulative restore-latency digests: `(DRAM hit, spill fault)`.
    pub fn restore_latency(&self) -> (HistSummary, HistSummary) {
        (self.restore_dram.summary(), self.restore_fault.summary())
    }

    pub fn record_write(&self, class: TensorClass, bits: ComponentBits, count: usize) {
        let mut s = self.inner.lock().unwrap();
        match class {
            TensorClass::Activation => s.resident.activations.add(bits),
            TensorClass::Weight => s.resident.weights.add(bits),
        }
        s.written_bits += bits.total();
        s.written_fp32_bits += 32.0 * count as f64;
        s.writes += 1;
        s.peak_resident_bits = s.peak_resident_bits.max(s.resident.total());
    }

    pub fn record_read(&self, bits_total: f64) {
        let mut s = self.inner.lock().unwrap();
        s.read_bits += bits_total;
        s.reads += 1;
    }

    /// A cold chunk was evicted DRAM → spill.
    pub fn record_spill_write(&self, bits: f64) {
        {
            let mut s = self.inner.lock().unwrap();
            s.spill_written_bits += bits;
            s.evictions += 1;
        }
        // flight recorder: many evictions inside one window = a storm
        // (the budget is actively thrashing, not just trimming cold data)
        let now = crate::obs::trace::now_us();
        if let Some(n) = self.burst_evict.lock().unwrap().note(now) {
            crate::obs::events::stash_pressure_for(
                self.owner_cow(),
                "eviction_storm",
                n,
                BURST_WINDOW_US,
            );
        }
    }

    /// A spilled chunk was faulted back spill → DRAM.
    pub fn record_spill_read(&self, bits: f64) {
        {
            let mut s = self.inner.lock().unwrap();
            s.spill_read_bits += bits;
            s.faults += 1;
        }
        let now = crate::obs::trace::now_us();
        if let Some(n) = self.burst_fault.lock().unwrap().note(now) {
            crate::obs::events::stash_pressure_for(
                self.owner_cow(),
                "fault_burst",
                n,
                BURST_WINDOW_US,
            );
        }
    }

    /// A tensor left the stash: subtract its components from residency.
    pub fn record_release(&self, class: TensorClass, bits: ComponentBits) {
        let mut s = self.inner.lock().unwrap();
        match class {
            TensorClass::Activation => s.resident.activations.add(bits.scaled(-1.0)),
            TensorClass::Weight => s.resident.weights.add(bits.scaled(-1.0)),
        }
    }

    pub fn snapshot(&self) -> LedgerSnapshot {
        *self.inner.lock().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cb(sign: f64, exp: f64, mant: f64, meta: f64) -> ComponentBits {
        ComponentBits {
            sign,
            exponent: exp,
            mantissa: mant,
            metadata: meta,
        }
    }

    #[test]
    fn write_read_release_cycle() {
        let l = StashLedger::new();
        l.record_write(TensorClass::Activation, cb(0.0, 400.0, 100.0, 21.0), 100);
        l.record_write(TensorClass::Weight, cb(50.0, 200.0, 150.0, 10.0), 50);
        let s = l.snapshot();
        assert_eq!(s.writes, 2);
        assert!((s.resident.total() - (521.0 + 410.0)).abs() < 1e-9);
        assert!((s.written_fp32_bits - 32.0 * 150.0).abs() < 1e-9);
        assert!((s.peak_resident_bits - 931.0).abs() < 1e-9);

        l.record_read(521.0);
        l.record_release(TensorClass::Activation, cb(0.0, 400.0, 100.0, 21.0));
        let s = l.snapshot();
        assert_eq!(s.reads, 1);
        assert!((s.resident.activations.total()).abs() < 1e-9);
        // peak unaffected by release
        assert!((s.peak_resident_bits - 931.0).abs() < 1e-9);
        assert!(s.ratio_vs_fp32() < 1.0);
    }

    #[test]
    fn epoch_marks_cut_traffic_deltas() {
        let l = StashLedger::new();
        l.record_write(TensorClass::Activation, cb(0.0, 100.0, 50.0, 0.0), 100);
        l.mark_epoch();
        l.record_write(TensorClass::Activation, cb(0.0, 60.0, 20.0, 0.0), 100);
        l.record_read(80.0);
        l.mark_epoch();
        let epochs = l.epoch_traffic();
        assert_eq!(epochs.len(), 2);
        assert!((epochs[0].written_bits - 150.0).abs() < 1e-9);
        assert!((epochs[0].read_bits).abs() < 1e-9);
        assert!((epochs[1].written_bits - 80.0).abs() < 1e-9);
        assert!((epochs[1].read_bits - 80.0).abs() < 1e-9);
        assert!((epochs[1].written_fp32_bits - 3200.0).abs() < 1e-9);
        assert!(epochs[1].ratio_vs_fp32() < 1.0);
        // an epoch with no traffic records a zero row, not a panic
        l.mark_epoch();
        assert!((l.epoch_traffic()[2].written_bits).abs() < 1e-9);
    }

    #[test]
    fn restore_latency_splits_tiers_and_cuts_per_epoch() {
        let l = StashLedger::new();
        l.record_restore_latency(false, 100);
        l.record_restore_latency(false, 100);
        l.record_restore_latency(true, 5000);
        let (dram, fault) = l.restore_latency();
        assert_eq!(dram.count, 2);
        assert_eq!(dram.sum_us, 200);
        assert_eq!(fault.count, 1);
        assert!(fault.p50_us >= 4096, "5 ms fault lands in a ms-scale bucket");
        assert!(dram.p99_us < fault.p50_us, "tiers stay separated");

        l.mark_epoch();
        l.record_restore_latency(true, 7000);
        l.mark_epoch();
        let rows = l.epoch_traffic();
        assert_eq!(rows[0].restore_dram_us.count, 2);
        assert_eq!(rows[0].restore_fault_us.count, 1);
        // the second epoch sees only its own fault, not epoch one's
        assert_eq!(rows[1].restore_dram_us.count, 0);
        assert_eq!(rows[1].restore_fault_us.count, 1);
        assert_eq!(rows[1].restore_fault_us.sum_us, 7000);
    }

    #[test]
    fn spill_bursts_emit_pressure_events() {
        crate::obs::events::capture_begin();
        let l = StashLedger::new();
        for _ in 0..BURST_THRESHOLD {
            l.record_spill_write(4096.0);
        }
        // one below the threshold: no fault event yet
        for _ in 0..BURST_THRESHOLD - 1 {
            l.record_spill_read(4096.0);
        }
        let mid = crate::obs::events::capture_end();
        assert!(mid.iter().any(|e| e.trigger == "eviction_storm"));
        assert!(!mid.iter().any(|e| e.trigger == "fault_burst"));
        crate::obs::events::capture_begin();
        l.record_spill_read(4096.0);
        let events = crate::obs::events::capture_end();
        let burst = events.iter().find(|e| e.trigger == "fault_burst").unwrap();
        assert_eq!(burst.kind, "stash_pressure");
        assert_eq!(burst.source, "stash");
        assert_eq!(burst.from, BURST_THRESHOLD as f64, "episode count");
        assert_eq!(burst.owner, None, "single-owner ledgers stay untagged");
    }

    #[test]
    fn pressure_events_carry_the_owner_tag() {
        crate::obs::events::capture_begin();
        let l = StashLedger::new();
        l.set_owner("serve.t1");
        for _ in 0..BURST_THRESHOLD {
            l.record_spill_write(4096.0);
        }
        let events = crate::obs::events::capture_end();
        let burst = events.iter().find(|e| e.trigger == "eviction_storm").unwrap();
        assert_eq!(burst.owner.as_deref(), Some("serve.t1"));
        assert_eq!(l.owner().as_deref(), Some("serve.t1"));
    }

    #[test]
    fn concurrent_epoch_cuts_are_disjoint_and_sum_consistent() {
        // Satellite coverage: two owners cutting epochs while workers
        // stream writes/reads.  The marks lock serializes cuts into
        // disjoint [last, now] intervals, so the per-row deltas must be
        // non-negative and sum exactly to the cumulative counters — an
        // overlapping or smeared cut breaks one of the two.
        use std::sync::Arc;
        let l = Arc::new(StashLedger::new());
        let writers: Vec<_> = (0..2)
            .map(|_| {
                let l = Arc::clone(&l);
                std::thread::spawn(move || {
                    for _ in 0..500 {
                        l.record_write(TensorClass::Activation, cb(0.0, 0.0, 64.0, 0.0), 2);
                        l.record_read(64.0);
                    }
                })
            })
            .collect();
        let cutters: Vec<_> = (0..2)
            .map(|_| {
                let l = Arc::clone(&l);
                std::thread::spawn(move || {
                    for _ in 0..25 {
                        l.mark_epoch();
                        std::thread::yield_now();
                    }
                })
            })
            .collect();
        for h in writers {
            h.join().unwrap();
        }
        for h in cutters {
            h.join().unwrap();
        }
        l.mark_epoch(); // final cut collects any tail traffic
        let rows = l.epoch_traffic();
        assert_eq!(rows.len(), 51);
        let s = l.snapshot();
        assert!(
            rows.iter().all(|r| r.written_bits >= 0.0 && r.read_bits >= 0.0),
            "overlapping cuts would produce a negative delta"
        );
        let written: f64 = rows.iter().map(|r| r.written_bits).sum();
        let read: f64 = rows.iter().map(|r| r.read_bits).sum();
        assert!((written - s.written_bits).abs() < 1e-6, "cuts partition writes");
        assert!((read - s.read_bits).abs() < 1e-6, "cuts partition reads");
        assert!((s.written_bits - 2.0 * 500.0 * 64.0).abs() < 1e-6);
        assert!((s.read_bits - 2.0 * 500.0 * 64.0).abs() < 1e-6);
    }

    #[test]
    fn epoch_marks_split_dram_and_spill() {
        let l = StashLedger::new();
        l.record_write(TensorClass::Activation, cb(0.0, 100.0, 50.0, 0.0), 100);
        l.record_spill_write(4096.0);
        l.record_spill_write(4096.0);
        l.mark_epoch();
        l.record_spill_read(4096.0);
        l.mark_epoch();
        let rows = l.epoch_traffic();
        assert!((rows[0].spill_written_bits - 8192.0).abs() < 1e-9);
        assert!((rows[0].spill_read_bits).abs() < 1e-9);
        assert!((rows[1].spill_written_bits).abs() < 1e-9);
        assert!((rows[1].spill_read_bits - 4096.0).abs() < 1e-9);
        // the DRAM-side row stayed clean of spill traffic
        assert!((rows[0].written_bits - 150.0).abs() < 1e-9);
        let s = l.snapshot();
        assert_eq!(s.evictions, 2);
        assert_eq!(s.faults, 1);
    }
}
