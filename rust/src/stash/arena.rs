//! Chunk-granular arena for compressed bit streams.
//!
//! Stashed tensors live exactly as long as one training step (written
//! post-forward, read back for backward), so the allocation pattern is a
//! tight produce/consume cycle.  The arena stores every stream as a run of
//! fixed-size `u64` chunks recycled through a free list: steady-state
//! training reuses the same chunks step after step instead of hitting the
//! allocator, and the chunk count gives the resident/high-water numbers
//! the ledger reports.

use std::sync::Mutex;

/// Words per arena chunk (32 KiB).  Small enough that a short stream wastes
/// little, large enough that multi-MB activation stashes need few slots.
pub const CHUNK_WORDS: usize = 4096;

/// Handle to one stored bit stream: its chunk slots plus the bit length.
/// Only the arena that issued it can resolve it.
#[derive(Debug, Clone)]
pub struct ChunkSeq {
    slots: Vec<u32>,
    pub len_bits: usize,
}

impl ChunkSeq {
    /// Whole-chunk bytes this stream pins in the arena.
    pub fn resident_bytes(&self) -> usize {
        self.slots.len() * CHUNK_WORDS * 8
    }
}

#[derive(Default)]
struct Slabs {
    /// Slot id → chunk storage (each `CHUNK_WORDS` long).
    chunks: Vec<Box<[u64]>>,
    free: Vec<u32>,
    in_use: usize,
    high_water: usize,
}

/// Shared, thread-safe chunk store (workers encode into it concurrently).
#[derive(Default)]
pub struct ChunkArena {
    inner: Mutex<Slabs>,
}

impl ChunkArena {
    pub fn new() -> Self {
        Self::default()
    }

    /// Store a packed bit stream; copies `len_bits.div_ceil(64)` words.
    pub fn store(&self, words: &[u64], len_bits: usize) -> ChunkSeq {
        let used = len_bits.div_ceil(64);
        debug_assert!(used <= words.len());
        let mut inner = self.inner.lock().unwrap();
        let mut slots = Vec::with_capacity(used.div_ceil(CHUNK_WORDS));
        for piece in words[..used].chunks(CHUNK_WORDS) {
            let slot = match inner.free.pop() {
                Some(s) => s,
                None => {
                    inner
                        .chunks
                        .push(vec![0u64; CHUNK_WORDS].into_boxed_slice());
                    (inner.chunks.len() - 1) as u32
                }
            };
            inner.chunks[slot as usize][..piece.len()].copy_from_slice(piece);
            slots.push(slot);
        }
        inner.in_use += slots.len();
        inner.high_water = inner.high_water.max(inner.in_use);
        ChunkSeq { slots, len_bits }
    }

    /// Copy a stored stream back out (exactly `len_bits.div_ceil(64)` words).
    pub fn load(&self, seq: &ChunkSeq) -> Vec<u64> {
        let used = seq.len_bits.div_ceil(64);
        let inner = self.inner.lock().unwrap();
        let mut out = Vec::with_capacity(used);
        let mut remaining = used;
        for &slot in &seq.slots {
            let take = remaining.min(CHUNK_WORDS);
            out.extend_from_slice(&inner.chunks[slot as usize][..take]);
            remaining -= take;
        }
        debug_assert_eq!(remaining, 0);
        out
    }

    /// Return a stream's chunks to the free list.
    pub fn release(&self, seq: ChunkSeq) {
        let mut inner = self.inner.lock().unwrap();
        inner.in_use -= seq.slots.len();
        inner.free.extend(seq.slots);
    }

    /// Bytes currently pinned by live streams (whole-chunk granularity).
    pub fn in_use_bytes(&self) -> usize {
        self.inner.lock().unwrap().in_use * CHUNK_WORDS * 8
    }

    /// Total bytes ever allocated (live + free-listed).
    pub fn allocated_bytes(&self) -> usize {
        self.inner.lock().unwrap().chunks.len() * CHUNK_WORDS * 8
    }

    /// Peak concurrently-live bytes over the arena's lifetime.
    pub fn high_water_bytes(&self) -> usize {
        self.inner.lock().unwrap().high_water * CHUNK_WORDS * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn store_load_roundtrip_multi_chunk() {
        let arena = ChunkArena::new();
        let words: Vec<u64> = (0..CHUNK_WORDS as u64 * 2 + 100)
            .map(|i| i.wrapping_mul(0x9E3779B97F4A7C15))
            .collect();
        let bits = words.len() * 64 - 13; // non-word-aligned tail
        let seq = arena.store(&words, bits);
        assert_eq!(seq.slots.len(), 3);
        let back = arena.load(&seq);
        assert_eq!(back.len(), bits.div_ceil(64));
        assert_eq!(&back[..], &words[..back.len()]);
        arena.release(seq);
        assert_eq!(arena.in_use_bytes(), 0);
    }

    #[test]
    fn free_list_reuse_bounds_allocation() {
        let arena = ChunkArena::new();
        let words = vec![7u64; CHUNK_WORDS];
        for _ in 0..50 {
            let seq = arena.store(&words, CHUNK_WORDS * 64);
            arena.release(seq);
        }
        // one chunk ever allocated despite 50 store/release cycles
        assert_eq!(arena.allocated_bytes(), CHUNK_WORDS * 8);
        assert_eq!(arena.high_water_bytes(), CHUNK_WORDS * 8);
    }

    #[test]
    fn empty_stream() {
        let arena = ChunkArena::new();
        let seq = arena.store(&[], 0);
        assert_eq!(seq.resident_bytes(), 0);
        assert!(arena.load(&seq).is_empty());
        arena.release(seq);
    }

    #[test]
    fn interleaved_streams_stay_disjoint() {
        let arena = ChunkArena::new();
        let a: Vec<u64> = (0..300).collect();
        let b: Vec<u64> = (1000..1000 + 300).collect();
        let sa = arena.store(&a, 300 * 64);
        let sb = arena.store(&b, 300 * 64);
        assert_eq!(arena.load(&sa), a);
        assert_eq!(arena.load(&sb), b);
        arena.release(sa);
        // releasing one must not disturb the other
        assert_eq!(arena.load(&sb), b);
        arena.release(sb);
    }
}
