//! Tiered chunk arena for compressed bit streams: a DRAM-resident tier of
//! fixed-size `u64` chunks recycled through a free list, plus an optional
//! budget-driven spill tier that evicts cold chunk runs to a file-backed
//! region and faults them back on demand.
//!
//! Stashed tensors live exactly as long as one training step (written
//! post-forward, read back for backward), so the allocation pattern is a
//! tight produce/consume cycle: steady-state training reuses the same
//! chunks step after step instead of hitting the allocator.  When a
//! resident-byte budget is set and crossed, the coldest live chunks (by
//! last-touch stamp) move to the spill file, letting batch sizes beyond
//! DRAM become a sweep axis; [`ChunkArena::pin`] faults spilled chunks
//! back transparently.  Every tier crossing is charged to the shared
//! [`StashLedger`](super::ledger::StashLedger) so DRAM and spill traffic
//! stay separable in the reports and the hwsim DRAM model.
//!
//! Spill I/O runs *off* the arena mutex: each slot carries an in-flight
//! [`IoState`], the lock is held only to transition tier state, and the
//! pread/pwrite itself happens with the lock released.  A concurrent
//! `pin` of a chunk mid-fault waits on that chunk (condvar, re-checked
//! per slot), not on the whole arena — parallel lab jobs sharing one
//! process stop serializing on each other's spill traffic.  Evictions
//! stay transparent because chunk buffers are immutable once stored: the
//! file copy written outside the lock is always bit-identical to the
//! buffer a concurrent reader may still be pinning.
//!
//! Spill I/O is also *run-granular*: eviction batches receive ascending
//! file slots and chunks occupying adjacent slots are staged into one
//! buffer and written with a single pwrite; faulting a pinned stream
//! claims every spilled chunk in one locked pass and reads each
//! adjacent-slot run back with a single pread.  Multi-chunk streams —
//! the normal case for activation stashes — thus pay one syscall per
//! *run*, not one per 32 KiB chunk; the per-arena
//! [`ChunkArena::spill_pread_calls`] / [`ChunkArena::spill_pwrite_calls`]
//! counters (and the matching `obs::metrics` globals) expose the ratio.
//!
//! Reads are zero-copy: [`ChunkArena::pin`] hands back `Arc` references to
//! the chunk buffers themselves (a [`PinnedStream`]), which a
//! [`SegReader`](crate::gecko::SegReader) decodes in place.  A pinned
//! chunk stays valid even if the arena concurrently releases, reuses, or
//! spills its slot — slot reuse allocates a fresh buffer whenever a reader
//! still holds the old one.
//!
//! The arena is also *multi-tenant*: [`ChunkArena::register_tenant`] hands
//! out tenant ids carrying a per-tenant DRAM budget, a placement priority,
//! and an optional per-tenant ledger; [`ChunkArena::store_for`] tags every
//! chunk with its owner.  Eviction planning then runs in two passes —
//! first each over-budget tenant's *own* coldest chunks, then the global
//! budget backstop keyed `(priority, stamp)` — so a tenant churning far
//! past its lease spills its own working set and cannot drive a
//! well-behaved neighbor into fault thrash (the fairness contract the
//! `repro serve` scenario measures).  Tenant 0 is the implicit legacy
//! owner: unlimited per-tenant budget, traffic charged to the arena-global
//! ledger, so single-owner arenas behave exactly as before.

use super::ledger::StashLedger;
use std::fs::{File, OpenOptions};
use std::os::unix::fs::FileExt;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Words per arena chunk (32 KiB).  Small enough that a short stream wastes
/// little, large enough that multi-MB activation stashes need few slots.
pub const CHUNK_WORDS: usize = 4096;
/// Bytes per arena chunk (the spill file's slot granularity).
pub const CHUNK_BYTES: usize = CHUNK_WORDS * 8;

/// Handle to one stored bit stream: its chunk slots plus the bit length.
/// Only the arena that issued it can resolve it.
#[derive(Debug, Clone)]
pub struct ChunkSeq {
    slots: Vec<u32>,
    pub len_bits: usize,
}

impl ChunkSeq {
    /// Whole-chunk bytes this stream occupies across both tiers.
    pub fn resident_bytes(&self) -> usize {
        self.slots.len() * CHUNK_BYTES
    }
}

/// A pinned stream: `Arc` references to the chunk buffers, valid for
/// in-place decoding regardless of concurrent arena activity.
pub struct PinnedStream {
    chunks: Vec<Arc<[u64]>>,
    pub len_bits: usize,
    /// True when any chunk came back from the spill tier during this pin
    /// (faulted by us, or by a concurrent pin we waited on) — the
    /// DRAM-hit vs. spill-fault restore-latency tier split.
    pub faulted: bool,
}

impl PinnedStream {
    /// Borrowed word segments (each trimmed to its used length), in stream
    /// order — feed to [`SegReader::new`](crate::gecko::SegReader::new).
    pub fn segs(&self) -> Vec<&[u64]> {
        let mut remaining = self.len_bits.div_ceil(64);
        self.chunks
            .iter()
            .map(|c| {
                let take = remaining.min(CHUNK_WORDS);
                remaining -= take;
                &c[..take]
            })
            .collect()
    }
}

/// Tier-crossing I/O currently in flight on a slot.  The pwrite/pread runs
/// with the arena lock released; the slot state keeps concurrent callers
/// coherent (pins of a `Reading` chunk wait on it, pins of a `Writing`
/// chunk keep using the still-resident buffer).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
enum IoState {
    #[default]
    Idle,
    /// Eviction pwrite in flight; `buf` stays set until it completes.
    Writing,
    /// Demand-fault pread in flight; `buf` appears when it completes.
    Reading,
}

/// One chunk slot.  Live slots are either DRAM-resident (`buf` set) or
/// spilled (`file_slot` set); free-listed slots keep their buffer for
/// reuse when no reader pins it.
#[derive(Default)]
struct Slot {
    buf: Option<Arc<[u64]>>,
    file_slot: Option<u32>,
    live: bool,
    io: IoState,
    /// Last-touch stamp (store or pin) — the cold-run eviction order.
    stamp: u64,
    /// Owning tenant (0 = the arena's legacy single owner).
    tenant: u32,
}

/// Per-tenant accounting and placement policy.  Index 0 is the implicit
/// legacy owner; [`ChunkArena::register_tenant`] appends leased tenants.
#[derive(Default)]
struct TenantState {
    /// Live DRAM-resident chunks owned by this tenant.
    in_use: usize,
    /// Live spilled chunks owned by this tenant.
    spilled: usize,
    /// Eviction pwrites in flight on this tenant's chunks.
    pending_writes: usize,
    /// DRAM budget in chunks (`None` = unlimited).  A tenant past its own
    /// budget has its own coldest chunks evicted first, before the global
    /// backstop runs — the fair-eviction half of the lease contract.
    budget_chunks: Option<usize>,
    /// Placement priority under the global backstop: lower-priority
    /// tenants evict first; ties fall back to cold-first stamps.
    priority: u8,
    /// Spill traffic on this tenant's chunks is charged here instead of
    /// the arena-global ledger.
    ledger: Option<Arc<StashLedger>>,
    evictions: u64,
    faults: u64,
}

/// Point-in-time accounting for one tenant of a shared arena.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct TenantStats {
    /// Live DRAM-resident bytes owned by the tenant (chunk granularity).
    pub in_use_bytes: usize,
    /// Live spilled bytes owned by the tenant.
    pub spilled_bytes: usize,
    /// Chunks of this tenant evicted DRAM → spill over the arena lifetime.
    pub evictions: u64,
    /// Chunks of this tenant faulted spill → DRAM over the arena lifetime.
    pub faults: u64,
}

#[derive(Default)]
struct Slabs {
    slots: Vec<Slot>,
    free: Vec<u32>,
    /// Live DRAM-resident chunks.
    in_use: usize,
    high_water: usize,
    /// Live spilled chunks.
    spilled: usize,
    spill_high_water: usize,
    /// Eviction pwrites currently in flight (their chunks still count in
    /// `in_use`, so budget planning must not re-select or double-count).
    pending_writes: usize,
    /// Recycled slots of the spill file.
    free_file_slots: Vec<u32>,
    /// Spill-file slots ever created (file length / CHUNK_BYTES).
    file_slots: u32,
    /// Lazily created, unlinked-on-create backing file of the spill tier
    /// (`Arc` so the pwrite/pread can run with the arena lock released).
    spill_file: Option<Arc<File>>,
    stamp: u64,
    evictions: u64,
    faults: u64,
    /// Spill-tier syscalls issued (run-granular batching: adjacent chunk
    /// slots coalesce, so these run well below `evictions`/`faults`).
    pread_calls: u64,
    pwrite_calls: u64,
    /// Per-tenant accounting; lazily grown, index = tenant id.
    tenants: Vec<TenantState>,
    /// Bounded pin waits taken (pass-1 retries that timed out or woke
    /// while their chunk was still in flight) — starvation observability.
    pin_stalls: u64,
}

impl Slabs {
    /// Tenant accounting slot, lazily materialized (tenant 0 appears on
    /// the legacy owner's first store).
    fn tenant_mut(&mut self, tenant: u32) -> &mut TenantState {
        let idx = tenant as usize;
        while self.tenants.len() <= idx {
            self.tenants.push(TenantState::default());
        }
        &mut self.tenants[idx]
    }
}

/// One planned eviction, carried out of the lock: the pwrite happens on
/// the caller's thread with the arena unlocked, then a short re-lock
/// finalizes the tier transition.
struct PendingSpill {
    id: u32,
    fslot: u32,
    buf: Arc<[u64]>,
    file: Arc<File>,
}

/// Shared, thread-safe tiered chunk store (workers encode into it
/// concurrently; restores decode from it zero-copy via [`ChunkArena::pin`]).
#[derive(Default)]
pub struct ChunkArena {
    inner: Mutex<Slabs>,
    /// Signals per-chunk I/O completion (pins waiting on a faulting chunk).
    cv: Condvar,
    /// DRAM budget in bytes; 0 = unlimited (spill tier disabled).
    budget_bytes: usize,
    /// Directory for the spill file (`None` = the OS temp dir).
    spill_dir: Option<PathBuf>,
    /// Spill traffic is charged here, under the ledger's own counters, so
    /// epoch cuts see DRAM and spill numbers atomically.
    ledger: Option<Arc<StashLedger>>,
}

fn create_spill_file(dir: Option<&Path>) -> File {
    static COUNTER: AtomicU64 = AtomicU64::new(0);
    let dir = dir.map(Path::to_path_buf).unwrap_or_else(std::env::temp_dir);
    let path = dir.join(format!(
        "sfp-stash-spill-{}-{}.bin",
        std::process::id(),
        COUNTER.fetch_add(1, Ordering::Relaxed)
    ));
    let file = OpenOptions::new()
        .read(true)
        .write(true)
        .create(true)
        .truncate(true)
        .open(&path)
        .expect("create stash spill file");
    // Unlink immediately: the region lives only as this open descriptor
    // and the OS reclaims it when the arena drops, even on a crash.
    let _ = std::fs::remove_file(&path);
    file
}

fn bytes_to_words(bytes: &[u8]) -> Vec<u64> {
    bytes
        .chunks_exact(8)
        .map(|b| u64::from_le_bytes(b.try_into().expect("8-byte chunk")))
        .collect()
}

impl ChunkArena {
    /// Unbounded arena (no spill tier), no ledger coupling.
    pub fn new() -> Self {
        Self::default()
    }

    /// Arena with a DRAM budget (`0` = unlimited) whose spill traffic is
    /// charged to `ledger`.  `spill_dir = None` places the backing file in
    /// the OS temp dir; it is unlinked on creation either way.
    pub fn with_budget(
        budget_bytes: usize,
        spill_dir: Option<PathBuf>,
        ledger: Option<Arc<StashLedger>>,
    ) -> Self {
        Self {
            inner: Mutex::default(),
            cv: Condvar::new(),
            budget_bytes,
            spill_dir,
            ledger,
        }
    }

    /// Register a leased tenant and return its id.  Chunks stored through
    /// [`Self::store_for`] under the id are accounted separately, keep to
    /// `budget_bytes` of DRAM (`0` = unlimited) by evicting the tenant's
    /// *own* coldest chunks first, and charge their spill traffic to
    /// `ledger` (falling back to the arena-global ledger when `None`).
    /// Higher `priority` tenants are evicted later by the global budget
    /// backstop.  Tenant 0 is reserved for the legacy owner
    /// ([`Self::store`]): unlimited budget, priority 0, global ledger.
    pub fn register_tenant(
        &self,
        budget_bytes: usize,
        priority: u8,
        ledger: Option<Arc<StashLedger>>,
    ) -> u32 {
        let mut inner = self.inner.lock().unwrap();
        inner.tenant_mut(0); // reserve the legacy owner's id
        inner.tenants.push(TenantState {
            budget_chunks: (budget_bytes != 0).then_some(budget_bytes / CHUNK_BYTES),
            priority,
            ledger,
            ..TenantState::default()
        });
        (inner.tenants.len() - 1) as u32
    }

    /// Store a packed bit stream; copies `len_bits.div_ceil(64)` words.
    /// May evict cold chunks to the spill tier to honor the budget (the
    /// eviction writes run after the arena lock is released).
    pub fn store(&self, words: &[u64], len_bits: usize) -> ChunkSeq {
        self.store_for(0, words, len_bits)
    }

    /// [`Self::store`] under a tenant lease: the stream's chunks are
    /// tagged with and accounted to `tenant`, and storing past the
    /// tenant's budget evicts the tenant's own cold chunks first.
    pub fn store_for(&self, tenant: u32, words: &[u64], len_bits: usize) -> ChunkSeq {
        let used = len_bits.div_ceil(64);
        debug_assert!(used <= words.len());
        let mut inner = self.inner.lock().unwrap();
        inner.stamp += 1;
        let stamp = inner.stamp;
        let mut slots = Vec::with_capacity(used.div_ceil(CHUNK_WORDS));
        for piece in words[..used].chunks(CHUNK_WORDS) {
            let id = match inner.free.pop() {
                Some(s) => s,
                None => {
                    inner.slots.push(Slot::default());
                    (inner.slots.len() - 1) as u32
                }
            };
            let slot = &mut inner.slots[id as usize];
            debug_assert!(!slot.live && slot.file_slot.is_none() && slot.io == IoState::Idle);
            // Reuse the free-listed buffer only when no reader still pins
            // it: a PinnedStream must keep observing the bits it pinned.
            let mut buf = slot
                .buf
                .take()
                .filter(|b| Arc::strong_count(b) == 1)
                .unwrap_or_else(|| vec![0u64; CHUNK_WORDS].into());
            Arc::get_mut(&mut buf).expect("exclusive chunk buffer")[..piece.len()]
                .copy_from_slice(piece);
            slot.buf = Some(buf);
            slot.live = true;
            slot.stamp = stamp;
            slot.tenant = tenant;
            slots.push(id);
        }
        inner.in_use += slots.len();
        inner.high_water = inner.high_water.max(inner.in_use);
        inner.tenant_mut(tenant).in_use += slots.len();
        let pending = self.plan_evictions(&mut inner);
        drop(inner);
        self.complete_evictions(pending);
        ChunkSeq { slots, len_bits }
    }

    /// Pin a stored stream for zero-copy decoding: spilled chunks fault
    /// back to DRAM (the preads run with the arena unlocked), resident
    /// chunks are `Arc`-shared in place.  Faulting is *run-granular*:
    /// every spilled chunk of the stream is claimed in one pass under the
    /// lock, then chunks occupying adjacent spill-file slots come back in
    /// a single coalesced pread per run instead of one syscall per chunk.
    /// A chunk another thread is already faulting is waited on per-chunk,
    /// not per-arena.
    pub fn pin(&self, seq: &ChunkSeq) -> PinnedStream {
        let mut inner = self.inner.lock().unwrap();
        let mut chunks: Vec<Option<Arc<[u64]>>> = vec![None; seq.slots.len()];
        let mut faulted = false;
        let mut wait_us = 0u64;
        let mut stalls = 0u64;
        let mut backoff_us = 100u64;
        loop {
            // A fresh stamp every pass: chunks this pin still needs must be
            // re-marked hot against the *current* clock.  Stamping once at
            // entry starves a pinner racing a sustained store stream — the
            // global stamp keeps advancing while it waits, so the chunk it
            // waits for looks ever colder and is re-evicted the moment the
            // faulting thread installs it.
            inner.stamp += 1;
            let stamp = inner.stamp;
            // Pass 1 (locked): resolve resident chunks in place and claim
            // every spilled-idle chunk for this thread's batched fault.
            let mut to_fault: Vec<(usize, u32, u32)> = Vec::new(); // (pos, id, fslot)
            let mut must_wait = false;
            for (pos, &id) in seq.slots.iter().enumerate() {
                if chunks[pos].is_some() {
                    continue;
                }
                let idx = id as usize;
                inner.slots[idx].stamp = stamp;
                if let Some(b) = inner.slots[idx].buf.clone() {
                    // Resident (possibly mid-eviction-write, which keeps
                    // the buffer valid until it completes): share in place.
                    chunks[pos] = Some(b);
                    continue;
                }
                if inner.slots[idx].io == IoState::Reading {
                    // Another pin is faulting this exact chunk: it resolves
                    // on a later pass, after that thread installs the buffer.
                    faulted = true;
                    must_wait = true;
                    continue;
                }
                debug_assert_eq!(inner.slots[idx].io, IoState::Idle);
                inner.slots[idx].io = IoState::Reading;
                let fslot = inner.slots[idx]
                    .file_slot
                    .take()
                    .expect("chunk neither resident nor spilled");
                to_fault.push((pos, id, fslot));
                faulted = true;
            }
            if to_fault.is_empty() {
                if !must_wait {
                    break; // every chunk resolved
                }
                // Nothing to fault ourselves; wait for the other thread's
                // pread — stores and pins of other chunks proceed under
                // the lock we release.  The wait is *bounded* with an
                // escalating backoff: under a sustained eviction stream
                // the installed buffer can be gone again before this
                // thread reacquires the lock, and the notification that
                // announced it is already consumed — an unbounded wait
                // would stall the pinner indefinitely.  Timing out simply
                // re-runs pass 1, which re-stamps the chunk hot and lets
                // this thread claim and fault it itself.
                let t0 = std::time::Instant::now();
                let (guard, _) = self
                    .cv
                    .wait_timeout(inner, std::time::Duration::from_micros(backoff_us))
                    .unwrap();
                inner = guard;
                wait_us += t0.elapsed().as_micros() as u64;
                backoff_us = (backoff_us * 2).min(2_000);
                stalls += 1;
                continue;
            }
            // Pass 2 (unlocked): sort the claimed chunks by spill-file
            // slot and fault each adjacent-slot run in one pread.
            let file = Arc::clone(
                inner
                    .spill_file
                    .as_ref()
                    .expect("spill file exists for spilled chunk"),
            );
            drop(inner);
            to_fault.sort_unstable_by_key(|&(_, _, fslot)| fslot);
            let mut bufs: Vec<(usize, u32, u32, Arc<[u64]>)> = Vec::with_capacity(to_fault.len());
            let mut preads = 0u64;
            let t0 = std::time::Instant::now();
            let mut i = 0;
            while i < to_fault.len() {
                let mut j = i + 1;
                while j < to_fault.len() && to_fault[j].2 == to_fault[j - 1].2 + 1 {
                    j += 1;
                }
                let run = &to_fault[i..j];
                let mut bytes = vec![0u8; run.len() * CHUNK_BYTES];
                file.read_exact_at(&mut bytes, run[0].2 as u64 * CHUNK_BYTES as u64)
                    .expect("spill tier read failed");
                preads += 1;
                for (k, &(pos, id, fslot)) in run.iter().enumerate() {
                    let piece = &bytes[k * CHUNK_BYTES..(k + 1) * CHUNK_BYTES];
                    bufs.push((pos, id, fslot, bytes_to_words(piece).into()));
                }
                i = j;
            }
            crate::obs::metrics::FAULT_US.record_duration(t0.elapsed());
            crate::obs::metrics::SPILL_PREAD_CALLS.add(preads);
            crate::obs::metrics::SPILL_CHUNKS_READ.add(to_fault.len() as u64);
            // Pass 3 (relocked): one lock acquisition installs the batch.
            inner = self.inner.lock().unwrap();
            inner.pread_calls += preads;
            for (pos, id, fslot, buf) in bufs {
                let idx = id as usize;
                inner.slots[idx].io = IoState::Idle;
                inner.slots[idx].buf = Some(Arc::clone(&buf));
                inner.free_file_slots.push(fslot);
                inner.spilled -= 1;
                inner.faults += 1;
                let tenant = inner.slots[idx].tenant;
                let live = inner.slots[idx].live;
                {
                    let ts = inner.tenant_mut(tenant);
                    ts.spilled -= 1;
                    ts.faults += 1;
                    if live {
                        ts.in_use += 1;
                    }
                }
                if live {
                    inner.in_use += 1;
                    inner.high_water = inner.high_water.max(inner.in_use);
                } else {
                    // Released while the fault was in flight: finish the
                    // deferred free (the buffer stays cached for reuse).
                    inner.free.push(id);
                }
                let tenant_ledger = inner.tenants[tenant as usize].ledger.clone();
                if let Some(l) = tenant_ledger.as_ref().or(self.ledger.as_ref()) {
                    l.record_spill_read((CHUNK_BYTES * 8) as f64);
                }
                chunks[pos] = Some(buf);
            }
            self.cv.notify_all();
        }
        // Faulting a run back in may overshoot the budget; re-evict cold
        // chunks (the pinned Arcs stay valid regardless).
        inner.pin_stalls += stalls;
        let pending = self.plan_evictions(&mut inner);
        drop(inner);
        self.complete_evictions(pending);
        if wait_us > 0 {
            crate::obs::metrics::PIN_WAIT_US.record(wait_us);
        }
        if stalls > 0 {
            crate::obs::metrics::PIN_STALL_RETRIES.add(stalls);
        }
        PinnedStream {
            chunks: chunks
                .into_iter()
                .map(|c| c.expect("all chunks resolved"))
                .collect(),
            len_bits: seq.len_bits,
            faulted,
        }
    }

    /// Copy a stored stream back out (exactly `len_bits.div_ceil(64)`
    /// words) — the materialized path, kept for cross-checks and as the
    /// decode bench's baseline; restores use [`ChunkArena::pin`].
    pub fn load(&self, seq: &ChunkSeq) -> Vec<u64> {
        let pin = self.pin(seq);
        let mut out = Vec::with_capacity(seq.len_bits.div_ceil(64));
        for s in pin.segs() {
            out.extend_from_slice(s);
        }
        out
    }

    /// Return a stream's chunks to the free list (spill-file slots of
    /// evicted chunks are recycled too).  A chunk with tier I/O in flight
    /// is only marked dead here; the I/O completion finishes the free.
    pub fn release(&self, seq: ChunkSeq) {
        let mut inner = self.inner.lock().unwrap();
        for id in seq.slots {
            let idx = id as usize;
            debug_assert!(inner.slots[idx].live);
            inner.slots[idx].live = false;
            if inner.slots[idx].io != IoState::Idle {
                continue; // complete_evictions / the faulting pin finalizes
            }
            let tenant = inner.slots[idx].tenant;
            match inner.slots[idx].file_slot.take() {
                Some(f) => {
                    inner.free_file_slots.push(f);
                    inner.spilled -= 1;
                    inner.tenant_mut(tenant).spilled -= 1;
                }
                None => {
                    inner.in_use -= 1;
                    inner.tenant_mut(tenant).in_use -= 1;
                }
            }
            inner.free.push(id);
        }
    }

    /// Pick cold live resident chunks to evict, reserve their spill slots,
    /// and mark them `Writing` — the caller performs the pwrites via
    /// [`ChunkArena::complete_evictions`] *after* dropping the lock.
    ///
    /// Planning runs in two passes.  Pass 1 enforces each tenant's own
    /// budget: an over-budget tenant contributes its own coldest chunks,
    /// regardless of global headroom, so one tenant's churn becomes that
    /// tenant's spill traffic and never a neighbor's fault storm.  Pass 2
    /// is the global DRAM budget backstop, keyed `(priority, stamp)` so
    /// lower-priority tenants evict first and equal priorities reduce to
    /// the historical cold-first order.
    fn plan_evictions(&self, inner: &mut Slabs) -> Vec<PendingSpill> {
        let eligible = |s: &Slot| {
            s.live && s.buf.is_some() && s.io == IoState::Idle && s.file_slot.is_none()
        };
        let mut selected: Vec<u32> = Vec::new();
        // Pass 1: per-tenant budget enforcement (skipped entirely for
        // legacy single-owner arenas, which register no budgets).
        if inner.tenants.iter().any(|t| t.budget_chunks.is_some()) {
            // Chunks already being written out will leave `in_use` when
            // their I/O completes; don't double-evict for them.
            let over: Vec<(u32, usize)> = inner
                .tenants
                .iter()
                .enumerate()
                .filter_map(|(t, ts)| {
                    let budget = ts.budget_chunks?;
                    let effective = ts.in_use.saturating_sub(ts.pending_writes);
                    (effective > budget).then_some((t as u32, effective - budget))
                })
                .collect();
            if !over.is_empty() {
                // One scan builds candidate lists only for the tenants
                // that are actually over budget.
                let mut cands: Vec<Vec<(u64, u32)>> = vec![Vec::new(); over.len()];
                for (i, s) in inner.slots.iter().enumerate() {
                    if !eligible(s) {
                        continue;
                    }
                    if let Some(oi) = over.iter().position(|&(t, _)| t == s.tenant) {
                        cands[oi].push((s.stamp, i as u32));
                    }
                }
                for (&(tenant, need), mut list) in over.iter().zip(cands) {
                    let k = need.min(list.len());
                    if k == 0 {
                        continue;
                    }
                    if k < list.len() {
                        list.select_nth_unstable(k - 1);
                        list.truncate(k);
                    }
                    for (_, id) in list {
                        inner.slots[id as usize].io = IoState::Writing;
                        inner.pending_writes += 1;
                        inner.tenant_mut(tenant).pending_writes += 1;
                        selected.push(id);
                    }
                }
            }
        }
        // Pass 2: global budget backstop (0 = unbounded DRAM tier).  Pass
        // 1's selections are already marked `Writing` and counted in
        // `pending_writes`, so they are neither re-selected nor
        // double-counted here.
        if self.budget_bytes != 0 {
            let budget_chunks = self.budget_bytes / CHUNK_BYTES;
            let effective = inner.in_use.saturating_sub(inner.pending_writes);
            if effective > budget_chunks {
                let tenants = &inner.tenants;
                let mut cands: Vec<(u8, u64, u32)> = inner
                    .slots
                    .iter()
                    .enumerate()
                    .filter(|&(_, s)| eligible(s))
                    .map(|(i, s)| {
                        let pri = tenants.get(s.tenant as usize).map_or(0, |t| t.priority);
                        (pri, s.stamp, i as u32)
                    })
                    .collect();
                // Only the k coldest need to go: partition them to the
                // front in O(n) instead of fully sorting the candidate
                // list (which would cost O(n log n) under the arena lock
                // on every over-budget store).
                let k = (effective - budget_chunks).min(cands.len());
                if k > 0 {
                    if k < cands.len() {
                        cands.select_nth_unstable(k - 1);
                        cands.truncate(k);
                    }
                    for (_, _, id) in cands {
                        let tenant = inner.slots[id as usize].tenant;
                        inner.slots[id as usize].io = IoState::Writing;
                        inner.pending_writes += 1;
                        inner.tenant_mut(tenant).pending_writes += 1;
                        selected.push(id);
                    }
                }
            }
        }
        if selected.is_empty() {
            return Vec::new();
        }
        if inner.spill_file.is_none() {
            inner.spill_file = Some(Arc::new(create_spill_file(self.spill_dir.as_deref())));
        }
        let file = Arc::clone(inner.spill_file.as_ref().expect("spill file just created"));
        // Hand out ascending file slots so one planning batch lands as a
        // contiguous spill-file run: complete_evictions coalesces adjacent
        // slots into a single pwrite, and the symmetric fault path gets
        // adjacency for free when the run is pinned back.
        inner.free_file_slots.sort_unstable_by(|a, b| b.cmp(a));
        let mut out = Vec::with_capacity(selected.len());
        for id in selected {
            let fslot = match inner.free_file_slots.pop() {
                Some(f) => f,
                None => {
                    let f = inner.file_slots;
                    inner.file_slots += 1;
                    f
                }
            };
            let buf = inner.slots[id as usize]
                .buf
                .clone()
                .expect("eviction candidate is resident");
            out.push(PendingSpill {
                id,
                fslot,
                buf,
                file: Arc::clone(&file),
            });
        }
        out
    }

    /// Write planned evictions to the spill file (arena unlocked — chunk
    /// buffers are immutable once stored, so the file copy is always
    /// coherent with concurrent pins), then re-lock briefly to flip the
    /// tier state.  The writes are *run-granular*: chunks holding adjacent
    /// spill-file slots (the common case, since plan_evictions hands out
    /// ascending slots) are staged into one buffer and written with a
    /// single pwrite per run.  A chunk released mid-write recycles its
    /// reserved file slot instead of landing spilled.
    fn complete_evictions(&self, mut pending: Vec<PendingSpill>) {
        if pending.is_empty() {
            return;
        }
        pending.sort_unstable_by_key(|p| p.fslot);
        let mut pwrites = 0u64;
        let t0 = std::time::Instant::now();
        let mut i = 0;
        while i < pending.len() {
            let mut j = i + 1;
            while j < pending.len() && pending[j].fslot == pending[j - 1].fslot + 1 {
                j += 1;
            }
            let run = &pending[i..j];
            let mut scratch = vec![0u8; run.len() * CHUNK_BYTES];
            for (k, p) in run.iter().enumerate() {
                let dst = &mut scratch[k * CHUNK_BYTES..(k + 1) * CHUNK_BYTES];
                for (d, w) in dst.chunks_exact_mut(8).zip(p.buf.iter()) {
                    d.copy_from_slice(&w.to_le_bytes());
                }
            }
            run[0]
                .file
                .write_all_at(&scratch, run[0].fslot as u64 * CHUNK_BYTES as u64)
                .expect("spill tier write failed");
            pwrites += 1;
            i = j;
        }
        crate::obs::metrics::EVICT_US.record_duration(t0.elapsed());
        crate::obs::metrics::SPILL_PWRITE_CALLS.add(pwrites);
        crate::obs::metrics::SPILL_CHUNKS_WRITTEN.add(pending.len() as u64);
        let mut inner = self.inner.lock().unwrap();
        inner.pwrite_calls += pwrites;
        for p in pending {
            let idx = p.id as usize;
            inner.pending_writes -= 1;
            inner.slots[idx].io = IoState::Idle;
            inner.in_use -= 1;
            let tenant = inner.slots[idx].tenant;
            {
                let ts = inner.tenant_mut(tenant);
                ts.pending_writes -= 1;
                ts.in_use -= 1;
            }
            if inner.slots[idx].live {
                inner.slots[idx].file_slot = Some(p.fslot);
                inner.slots[idx].buf = None;
                inner.spilled += 1;
                inner.spill_high_water = inner.spill_high_water.max(inner.spilled);
                inner.evictions += 1;
                {
                    let ts = inner.tenant_mut(tenant);
                    ts.spilled += 1;
                    ts.evictions += 1;
                }
                let tenant_ledger = inner.tenants[tenant as usize].ledger.clone();
                if let Some(l) = tenant_ledger.as_ref().or(self.ledger.as_ref()) {
                    l.record_spill_write((CHUNK_BYTES * 8) as f64);
                }
            } else {
                // Released mid-write: undo the reservation and finish the
                // deferred free (the buffer stays cached for reuse).
                inner.free_file_slots.push(p.fslot);
                inner.free.push(p.id);
            }
        }
        drop(inner);
        self.cv.notify_all();
    }

    /// Bytes currently pinned in DRAM by live streams (whole-chunk
    /// granularity; spilled chunks are excluded).
    pub fn in_use_bytes(&self) -> usize {
        self.inner.lock().unwrap().in_use * CHUNK_BYTES
    }

    /// DRAM chunk buffers currently allocated (live + free-listed).
    pub fn allocated_bytes(&self) -> usize {
        let inner = self.inner.lock().unwrap();
        inner.slots.iter().filter(|s| s.buf.is_some()).count() * CHUNK_BYTES
    }

    /// Peak concurrently-live DRAM bytes over the arena's lifetime.
    pub fn high_water_bytes(&self) -> usize {
        self.inner.lock().unwrap().high_water * CHUNK_BYTES
    }

    /// Bytes of live streams currently evicted to the spill tier.
    pub fn spill_in_use_bytes(&self) -> usize {
        self.inner.lock().unwrap().spilled * CHUNK_BYTES
    }

    /// Peak concurrently-spilled bytes over the arena's lifetime.
    pub fn spill_high_water_bytes(&self) -> usize {
        self.inner.lock().unwrap().spill_high_water * CHUNK_BYTES
    }

    /// Spill-file bytes ever allocated (slots are recycled like chunks).
    pub fn spill_file_bytes(&self) -> usize {
        self.inner.lock().unwrap().file_slots as usize * CHUNK_BYTES
    }

    /// Chunks evicted DRAM → spill over the arena's lifetime.
    pub fn evictions(&self) -> u64 {
        self.inner.lock().unwrap().evictions
    }

    /// Chunks faulted spill → DRAM over the arena's lifetime.
    pub fn faults(&self) -> u64 {
        self.inner.lock().unwrap().faults
    }

    /// Spill-tier pread syscalls issued over the arena's lifetime.
    /// Run-granular faulting keeps this at or below [`Self::faults`]:
    /// chunks in adjacent spill-file slots share one call.
    pub fn spill_pread_calls(&self) -> u64 {
        self.inner.lock().unwrap().pread_calls
    }

    /// Spill-tier pwrite syscalls issued over the arena's lifetime
    /// (at or below [`Self::evictions`]; see [`Self::spill_pread_calls`]).
    pub fn spill_pwrite_calls(&self) -> u64 {
        self.inner.lock().unwrap().pwrite_calls
    }

    /// Bounded pin waits taken over the arena's lifetime: pass-1 retries
    /// whose chunk was still in flight when the wait ended.  The
    /// starvation-observability counter next to `stash_pin_wait_us`.
    pub fn pin_stalls(&self) -> u64 {
        self.inner.lock().unwrap().pin_stalls
    }

    /// Point-in-time accounting for one tenant (zeros if the id was never
    /// registered or never stored).
    pub fn tenant_stats(&self, tenant: u32) -> TenantStats {
        let inner = self.inner.lock().unwrap();
        inner
            .tenants
            .get(tenant as usize)
            .map_or(TenantStats::default(), |t| TenantStats {
                in_use_bytes: t.in_use * CHUNK_BYTES,
                spilled_bytes: t.spilled * CHUNK_BYTES,
                evictions: t.evictions,
                faults: t.faults,
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn store_load_roundtrip_multi_chunk() {
        let arena = ChunkArena::new();
        let words: Vec<u64> = (0..CHUNK_WORDS as u64 * 2 + 100)
            .map(|i| i.wrapping_mul(0x9E3779B97F4A7C15))
            .collect();
        let bits = words.len() * 64 - 13; // non-word-aligned tail
        let seq = arena.store(&words, bits);
        assert_eq!(seq.resident_bytes(), 3 * CHUNK_BYTES);
        let back = arena.load(&seq);
        assert_eq!(back.len(), bits.div_ceil(64));
        assert_eq!(&back[..], &words[..back.len()]);
        arena.release(seq);
        assert_eq!(arena.in_use_bytes(), 0);
    }

    #[test]
    fn free_list_reuse_bounds_allocation() {
        let arena = ChunkArena::new();
        let words = vec![7u64; CHUNK_WORDS];
        for _ in 0..50 {
            let seq = arena.store(&words, CHUNK_WORDS * 64);
            arena.release(seq);
        }
        // one chunk ever allocated despite 50 store/release cycles
        assert_eq!(arena.allocated_bytes(), CHUNK_BYTES);
        assert_eq!(arena.high_water_bytes(), CHUNK_BYTES);
    }

    #[test]
    fn empty_stream() {
        let arena = ChunkArena::new();
        let seq = arena.store(&[], 0);
        assert_eq!(seq.resident_bytes(), 0);
        assert!(arena.load(&seq).is_empty());
        arena.release(seq);
    }

    #[test]
    fn interleaved_streams_stay_disjoint() {
        let arena = ChunkArena::new();
        let a: Vec<u64> = (0..300).collect();
        let b: Vec<u64> = (1000..1000 + 300).collect();
        let sa = arena.store(&a, 300 * 64);
        let sb = arena.store(&b, 300 * 64);
        assert_eq!(arena.load(&sa), a);
        assert_eq!(arena.load(&sb), b);
        arena.release(sa);
        // releasing one must not disturb the other
        assert_eq!(arena.load(&sb), b);
        arena.release(sb);
    }

    #[test]
    fn spill_tier_evicts_and_faults_back_exact() {
        // budget of one chunk: the second stream's store evicts the first
        let arena = ChunkArena::with_budget(CHUNK_BYTES, None, None);
        let a: Vec<u64> = (0..CHUNK_WORDS as u64).map(|i| i * 3).collect();
        let b: Vec<u64> = (0..CHUNK_WORDS as u64).map(|i| i * 7 + 1).collect();
        let sa = arena.store(&a, CHUNK_WORDS * 64);
        assert_eq!(arena.evictions(), 0);
        let sb = arena.store(&b, CHUNK_WORDS * 64);
        assert_eq!(arena.evictions(), 1, "cold chunk must spill");
        assert_eq!(arena.in_use_bytes(), CHUNK_BYTES);
        assert_eq!(arena.spill_in_use_bytes(), CHUNK_BYTES);
        // faulting the spilled stream back gives exact words (and spills b)
        assert_eq!(arena.load(&sa), a);
        assert_eq!(arena.faults(), 1);
        assert_eq!(arena.load(&sb), b);
        arena.release(sa);
        arena.release(sb);
        assert_eq!(arena.in_use_bytes(), 0);
        assert_eq!(arena.spill_in_use_bytes(), 0);
    }

    #[test]
    fn spill_file_slots_recycle() {
        let arena = ChunkArena::with_budget(CHUNK_BYTES, None, None);
        let words = vec![5u64; CHUNK_WORDS];
        for _ in 0..10 {
            let sa = arena.store(&words, CHUNK_WORDS * 64);
            let sb = arena.store(&words, CHUNK_WORDS * 64); // evicts sa
            arena.release(sa);
            arena.release(sb);
        }
        assert!(arena.evictions() >= 10);
        // released spill slots recycle: the file never grows past 1 slot
        assert_eq!(arena.spill_file_bytes(), CHUNK_BYTES);
    }

    #[test]
    fn pinned_chunk_survives_release_and_reuse() {
        let arena = ChunkArena::new();
        let a = vec![0xAAu64; CHUNK_WORDS];
        let b = vec![0xBBu64; CHUNK_WORDS];
        let sa = arena.store(&a, CHUNK_WORDS * 64);
        let pin = arena.pin(&sa);
        arena.release(sa);
        // the freed slot is reused for a new stream...
        let sb = arena.store(&b, CHUNK_WORDS * 64);
        // ...but the pinned reader still sees the old bits
        assert_eq!(pin.segs()[0], &a[..]);
        assert_eq!(arena.load(&sb), b);
        arena.release(sb);
    }

    #[test]
    fn pinned_chunk_survives_eviction() {
        let arena = ChunkArena::with_budget(CHUNK_BYTES, None, None);
        let a: Vec<u64> = (0..CHUNK_WORDS as u64).collect();
        let b = vec![9u64; CHUNK_WORDS];
        let sa = arena.store(&a, CHUNK_WORDS * 64);
        let pin = arena.pin(&sa);
        let sb = arena.store(&b, CHUNK_WORDS * 64); // evicts a's chunk
        assert_eq!(arena.evictions(), 1);
        assert_eq!(pin.segs()[0], &a[..], "pin must outlive eviction");
        // and the spilled copy is intact too
        assert_eq!(arena.load(&sa), a);
        arena.release(sa);
        arena.release(sb);
    }

    #[test]
    fn budget_smaller_than_one_chunk_spills_everything() {
        let arena = ChunkArena::with_budget(1024, None, None);
        let words: Vec<u64> = (0..CHUNK_WORDS as u64 * 2).collect();
        let seq = arena.store(&words, words.len() * 64);
        assert_eq!(arena.in_use_bytes(), 0);
        assert_eq!(arena.spill_in_use_bytes(), 2 * CHUNK_BYTES);
        assert_eq!(arena.load(&seq), words);
        arena.release(seq);
        assert_eq!(arena.spill_in_use_bytes(), 0);
    }

    #[test]
    fn multi_chunk_run_spills_and_faults_in_single_syscalls() {
        // 4-chunk stream + sub-chunk budget: the whole stream spills as
        // one batch of adjacent file slots (one pwrite) and faults back
        // as one run (one pread), while tier accounting stays per-chunk.
        let arena = ChunkArena::with_budget(1024, None, None);
        let words: Vec<u64> = (0..CHUNK_WORDS as u64 * 4)
            .map(|i| i.wrapping_mul(0x2545F4914F6CDD1D))
            .collect();
        let seq = arena.store(&words, words.len() * 64);
        assert_eq!(arena.evictions(), 4);
        assert_eq!(arena.spill_pwrite_calls(), 1, "adjacent chunks must share one pwrite");
        assert_eq!(arena.load(&seq), words);
        assert_eq!(arena.faults(), 4);
        assert_eq!(arena.spill_pread_calls(), 1, "adjacent chunks must share one pread");
        arena.release(seq);
    }

    #[test]
    fn concurrent_pins_of_one_spilled_chunk_fault_once() {
        // Several threads pin the same spilled stream at once: exactly one
        // performs the pread, the others wait on that chunk's slot state
        // (not on the whole arena) and share the faulted buffer.
        let arena = Arc::new(ChunkArena::with_budget(CHUNK_BYTES, None, None));
        let a: Vec<u64> = (0..CHUNK_WORDS as u64).map(|i| i ^ 0x5A5A).collect();
        let b = vec![1u64; CHUNK_WORDS];
        let sa = Arc::new(arena.store(&a, CHUNK_WORDS * 64));
        let _sb = arena.store(&b, CHUNK_WORDS * 64); // spills a
        assert_eq!(arena.evictions(), 1);
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let arena = Arc::clone(&arena);
                let sa = Arc::clone(&sa);
                let expect = a.clone();
                std::thread::spawn(move || {
                    let pin = arena.pin(&sa);
                    assert_eq!(pin.segs()[0], &expect[..]);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        // one fault serves every concurrent pin (b stays colder than a
        // afterwards, so a is never re-evicted and re-faulted)
        assert_eq!(arena.faults(), 1);
    }

    #[test]
    fn concurrent_store_pin_release_stress_under_budget_pressure() {
        // Tiny budget + several threads: every store/pin/release cycle
        // races evictions and faults whose I/O runs off the arena lock —
        // data must stay bit-exact and counters must return to zero.
        let arena = Arc::new(ChunkArena::with_budget(2 * CHUNK_BYTES, None, None));
        let handles: Vec<_> = (0..4)
            .map(|t| {
                let arena = Arc::clone(&arena);
                std::thread::spawn(move || {
                    for round in 0..25u64 {
                        let words: Vec<u64> = (0..CHUNK_WORDS as u64)
                            .map(|i| i.wrapping_mul(t as u64 + 1).wrapping_add(round << 32))
                            .collect();
                        let seq = arena.store(&words, CHUNK_WORDS * 64);
                        let pin = arena.pin(&seq);
                        assert_eq!(pin.segs()[0], &words[..], "thread {t} round {round}");
                        assert_eq!(arena.load(&seq), words);
                        arena.release(seq);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(arena.in_use_bytes(), 0);
        assert_eq!(arena.spill_in_use_bytes(), 0);
        assert!(arena.evictions() >= arena.faults());
    }

    #[test]
    fn over_budget_tenant_evicts_its_own_chunks_first() {
        // Global budget fits 4 chunks, so pass 2 never triggers here; the
        // over-budget tenant's own coldest chunk must spill even with
        // global headroom, and the neighbor's chunk must stay resident.
        let arena = ChunkArena::with_budget(4 * CHUNK_BYTES, None, None);
        let ta = arena.register_tenant(CHUNK_BYTES, 0, None);
        let tb = arena.register_tenant(CHUNK_BYTES, 0, None);
        let wb = vec![1u64; CHUNK_WORDS];
        let wa1 = vec![2u64; CHUNK_WORDS];
        let wa2 = vec![3u64; CHUNK_WORDS];
        let sb = arena.store_for(tb, &wb, CHUNK_WORDS * 64);
        let sa1 = arena.store_for(ta, &wa1, CHUNK_WORDS * 64);
        let sa2 = arena.store_for(ta, &wa2, CHUNK_WORDS * 64);
        assert_eq!(arena.tenant_stats(ta).evictions, 1, "a must evict its own");
        assert_eq!(arena.tenant_stats(ta).in_use_bytes, CHUNK_BYTES);
        assert_eq!(arena.tenant_stats(tb).evictions, 0);
        assert_eq!(arena.tenant_stats(tb).in_use_bytes, CHUNK_BYTES);
        // everything reads back exact, and b never faults
        assert_eq!(arena.load(&sa1), wa1);
        assert_eq!(arena.load(&sa2), wa2);
        assert_eq!(arena.load(&sb), wb);
        assert_eq!(arena.tenant_stats(tb).faults, 0);
        arena.release(sa1);
        arena.release(sa2);
        arena.release(sb);
        assert_eq!(arena.tenant_stats(ta).in_use_bytes, 0);
        assert_eq!(arena.tenant_stats(tb).in_use_bytes, 0);
    }

    #[test]
    fn churning_tenant_cannot_inflate_neighbor_faults() {
        // The arena-level fairness contract: a tenant churning far past
        // its lease spills only its own working set.  The calm tenant's
        // streams stay resident and fault exactly zero times.
        let arena = ChunkArena::with_budget(8 * CHUNK_BYTES, None, None);
        let churn = arena.register_tenant(2 * CHUNK_BYTES, 0, None);
        let calm = arena.register_tenant(4 * CHUNK_BYTES, 0, None);
        let calm_words: Vec<Vec<u64>> =
            (0..4u64).map(|i| vec![i + 10; CHUNK_WORDS]).collect();
        let calm_seqs: Vec<_> = calm_words
            .iter()
            .map(|w| arena.store_for(calm, w, CHUNK_WORDS * 64))
            .collect();
        // churner repeatedly holds 2 two-chunk streams against a 2-chunk
        // budget — 10x-style pressure, every round over budget
        let mut held: Option<ChunkSeq> = None;
        for round in 0..40u64 {
            let w = vec![round; CHUNK_WORDS * 2];
            let s = arena.store_for(churn, &w, CHUNK_WORDS * 2 * 64);
            assert_eq!(arena.load(&s), w);
            if let Some(prev) = held.replace(s) {
                arena.release(prev);
            }
        }
        if let Some(s) = held {
            arena.release(s);
        }
        assert!(arena.tenant_stats(churn).evictions > 0);
        assert_eq!(arena.tenant_stats(calm).evictions, 0);
        for (s, w) in calm_seqs.iter().zip(&calm_words) {
            let pin = arena.pin(s);
            assert!(!pin.faulted, "calm tenant must stay DRAM-resident");
            assert_eq!(pin.segs()[0], &w[..]);
        }
        assert_eq!(arena.tenant_stats(calm).faults, 0);
        for s in calm_seqs {
            arena.release(s);
        }
    }

    #[test]
    fn global_backstop_evicts_low_priority_tenants_first() {
        // No per-tenant budgets: the global pass keys on (priority, stamp),
        // so the low-priority tenant's chunk spills even though the
        // high-priority tenant's chunk is colder.
        let arena = ChunkArena::with_budget(2 * CHUNK_BYTES, None, None);
        let lo = arena.register_tenant(0, 0, None);
        let hi = arena.register_tenant(0, 1, None);
        let w_hi = vec![1u64; CHUNK_WORDS];
        let w_lo = vec![2u64; CHUNK_WORDS];
        let w_new = vec![3u64; CHUNK_WORDS];
        let s_hi = arena.store_for(hi, &w_hi, CHUNK_WORDS * 64); // coldest
        let s_lo = arena.store_for(lo, &w_lo, CHUNK_WORDS * 64);
        let s_new = arena.store_for(lo, &w_new, CHUNK_WORDS * 64); // over budget
        assert_eq!(arena.tenant_stats(lo).evictions, 1);
        assert_eq!(arena.tenant_stats(hi).evictions, 0);
        assert_eq!(arena.load(&s_lo), w_lo);
        assert_eq!(arena.load(&s_hi), w_hi);
        assert_eq!(arena.load(&s_new), w_new);
        arena.release(s_hi);
        arena.release(s_lo);
        arena.release(s_new);
    }

    #[test]
    fn pin_survives_sustained_eviction_churn() {
        // Regression for the pin retry-loop starvation: with the stamp
        // taken once at entry, a pinner racing a sustained store stream
        // kept re-marking its chunks with an ever-staler stamp, so they
        // were re-evicted the moment they landed and the pin could spin
        // indefinitely.  Fresh per-pass stamps + the bounded wait make
        // this terminate; completion with exact bits is the assertion.
        use std::sync::atomic::AtomicBool;
        let arena = Arc::new(ChunkArena::with_budget(2 * CHUNK_BYTES, None, None));
        let target: Vec<u64> = (0..CHUNK_WORDS as u64 * 2).map(|i| i ^ 0xABCD).collect();
        let seq = Arc::new(arena.store(&target, target.len() * 64));
        let stop = Arc::new(AtomicBool::new(false));
        let churn: Vec<_> = (0..2u64)
            .map(|t| {
                let arena = Arc::clone(&arena);
                let stop = Arc::clone(&stop);
                std::thread::spawn(move || {
                    let words = vec![t; CHUNK_WORDS];
                    while !stop.load(Ordering::Relaxed) {
                        let s = arena.store(&words, CHUNK_WORDS * 64);
                        arena.release(s);
                    }
                })
            })
            .collect();
        for _ in 0..50 {
            let pin = arena.pin(&seq);
            let got: Vec<u64> = pin.segs().concat();
            assert_eq!(got, target);
        }
        stop.store(true, Ordering::Relaxed);
        for h in churn {
            h.join().unwrap();
        }
    }
}
